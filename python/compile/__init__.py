"""Build-time compile path: JAX model (L2) + Pallas kernels (L1) + AOT lowering.

Never imported at serving time — the Rust binary consumes only the
artifacts this package emits (HLO text + .ptw checkpoints + manifest).
"""

"""Build-time trainer: pretrains the tiny model families on the
synthetic corpus (and a BitNet-style 1.58-bit QAT variant for the
Table 3 comparator), then writes `.ptw` checkpoints + config sidecars
the Rust engine loads directly.

Hand-rolled Adam (no optax in this image). Runs once under
`make artifacts`; every step is deterministic from the seed.

Usage: python -m compile.train --data ../data --out ../artifacts/models \
           [--families tiny,small,medium] [--steps 300] [--qat]
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod
from . import ptw
from .quant_jax import absmean_ternary


def adam_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": 0}


@jax.jit
def adam_update(params, grads, m, v, t, lr=3e-3, b1=0.9, b2=0.99, eps=1e-8):
    t = t + 1
    new_m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    new_v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    def upd(p, mm, vv):
        mhat = mm / (1 - b1 ** t)
        vhat = vv / (1 - b2 ** t)
        return p - lr * mhat / (jnp.sqrt(vhat) + eps)
    return jax.tree.map(upd, params, new_m, new_v), new_m, new_v, t


def ste_quantize(params, group):
    """Straight-through quantized view: linear weights projected to
    absmean ternary; gradients flow to the latent fp weights."""
    out = dict(params)
    for name, w in params.items():
        if w.ndim == 2 and name != "tok_embed":
            q = absmean_ternary(w, group)
            out[name] = w + jax.lax.stop_gradient(q - w)
    return out


def train_family(family, tok, ids, out_dir, steps, batch, seq, qat=False, seed=0):
    cfg = model_mod.make_config(family, tok.vocab_size, max_seq=256)
    params = model_mod.init_params(cfg, seed=seed)
    state = adam_init(params)
    m, v, t = state["m"], state["v"], state["t"]

    def loss(p, b):
        p_eff = ste_quantize(p, 128) if qat else p
        return model_mod.loss_fn(p_eff, b, cfg)

    grad_fn = jax.jit(jax.value_and_grad(loss))
    t0 = time.time()
    first = last = None
    for step, batch_np in enumerate(data_mod.batches(ids, batch, seq, steps, seed=seed + 1)):
        lv, grads = grad_fn(params, jnp.array(batch_np))
        params, m, v, t = adam_update(params, grads, m, v, t)
        if first is None:
            first = float(lv)
        last = float(lv)
        if step % 50 == 0:
            print(f"  [{family}{'-qat' if qat else ''}] step {step:4d} loss {float(lv):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
    name = f"{family}-qat" if qat else family
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{name}.ptw")
    save_params = params
    if qat:
        # persist the QUANTIZED weights: the deployed model is ternary
        save_params = {k: np.array(v) for k, v in ste_quantize(params, 128).items()}
    ptw.save(path, {k: np.array(v) for k, v in save_params.items()})
    with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
        json.dump(cfg, f, indent=2, sort_keys=True)
    print(f"  [{name}] loss {first:.3f} -> {last:.3f}; saved {path}", flush=True)
    return first, last


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--data", default="../data")
    ap.add_argument("--out", default="../artifacts/models")
    ap.add_argument("--families", default="tiny,small,medium")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--qat", action="store_true",
                    help="additionally train the small family with 1.58-bit QAT")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    tok, ids = data_mod.load_corpus(args.data)
    print(f"corpus: {len(ids)} tokens, vocab {tok.vocab_size}", flush=True)
    log = {}
    for fam in args.families.split(","):
        fam = fam.strip()
        first, last = train_family(fam, tok, ids, args.out, args.steps, args.batch,
                                   args.seq, seed=args.seed)
        log[fam] = {"first_loss": first, "last_loss": last}
    if args.qat:
        first, last = train_family("small", tok, ids, args.out, args.steps,
                                   args.batch, args.seq, qat=True, seed=args.seed)
        log["small-qat"] = {"first_loss": first, "last_loss": last}
    with open(os.path.join(args.out, "train_log.json"), "w") as f:
        json.dump(log, f, indent=2, sort_keys=True)


if __name__ == "__main__":
    main()

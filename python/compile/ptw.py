"""Python side of the `.ptw` tensor container (see
rust/src/serialize/tensorfile.rs for the format spec). Checkpoints
written here are loaded byte-for-byte by the Rust engine."""

import struct

import numpy as np

MAGIC = b"PTW1"
DTYPES = {0: np.float32, 1: np.int8, 2: np.uint8, 3: np.int32}
DTYPE_TAGS = {np.dtype(np.float32): 0, np.dtype(np.int8): 1,
              np.dtype(np.uint8): 2, np.dtype(np.int32): 3}


def save(path, tensors):
    """Write a dict[str, np.ndarray] as .ptw (sorted by name, little-endian)."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name in sorted(tensors):
            arr = np.ascontiguousarray(tensors[name])
            if arr.dtype not in DTYPE_TAGS:
                arr = arr.astype(np.float32)
            tag = DTYPE_TAGS[arr.dtype]
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", tag))
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(arr.astype(arr.dtype.newbyteorder("<")).tobytes())


def load(path):
    """Read a .ptw file into dict[str, np.ndarray]."""
    out = {}
    with open(path, "rb") as f:
        magic = f.read(4)
        if magic == b"PTW2":
            raise ValueError(
                "PTW2 (packed trit-plane) checkpoints are a Rust-engine "
                "deployment format; the Python build path reads/writes PTW1 only"
            )
        assert magic == MAGIC, f"bad magic {magic!r}"
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            (tag,) = struct.unpack("<B", f.read(1))
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}Q", f.read(8 * ndim)) if ndim else ()
            dtype = np.dtype(DTYPES[tag]).newbyteorder("<")
            numel = int(np.prod(dims)) if dims else 1
            data = np.frombuffer(f.read(numel * dtype.itemsize), dtype=dtype)
            out[name] = data.reshape(dims).astype(DTYPES[tag])
    return out

"""L2 quantization graph: PTQTP over whole checkpoints in JAX.

Wraps the L1 `ptqtp_step` Pallas kernel (python/compile/kernels/
ptqtp_step.py) with checkpoint traversal, and provides the absmean
(BitNet-style) ternary projector shared with the QAT trainer. The Rust
native implementation (rust/src/quant/ptqtp.rs) is the serving-path
twin; pytest cross-checks the two produce equivalent reconstruction
quality on the same inputs.
"""

import jax.numpy as jnp

from .kernels.ptqtp_step import ptqtp_quantize
from .kernels.ref import reconstruct_ref


def quantize_checkpoint(params, group=128, t_max=50, eps=1e-4):
    """PTQTP-quantize every linear weight in a checkpoint dict.

    Linear weights are the 2-D tensors except the embedding; returns
    (new_params_with_dense_reconstructions, planes) where planes maps
    name -> (t1, t2, a1, a2, group) for the ternary forward path.
    """
    out = dict(params)
    planes = {}
    for name, w in params.items():
        if w.ndim != 2 or name == "tok_embed":
            continue
        n, d = w.shape
        g = group if d % group == 0 else d
        t1, t2, a1, a2 = ptqtp_quantize(w, g, t_max=t_max, eps=eps)
        planes[name] = (t1, t2, a1, a2, g)
        out[name] = reconstruct_ref(t1, t2, a1, a2, g)
    return out, planes


def absmean_ternary(w, group=128):
    """BitNet-b1.58 projection with LS-optimal rescale (the QAT
    forward quantizer; mirrors rust/src/quant/absmean.rs)."""
    n, d = w.shape
    g = group if d % group == 0 else d
    gpr = d // g
    wg = w.reshape(n * gpr, g)
    gamma = jnp.mean(jnp.abs(wg), axis=1, keepdims=True)
    t = jnp.clip(jnp.round(wg / jnp.maximum(gamma, 1e-12)), -1, 1)
    tt = jnp.sum(t * t, axis=1, keepdims=True)
    tw = jnp.sum(t * wg, axis=1, keepdims=True)
    alpha = jnp.where(tt > 0, tw / jnp.maximum(tt, 1.0), 0.0)
    return (alpha * t).reshape(n, d)


def quant_error(w, w_hat):
    """Relative Frobenius error."""
    return float(jnp.linalg.norm(w - w_hat) / jnp.maximum(jnp.linalg.norm(w), 1e-30))

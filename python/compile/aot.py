"""AOT lowering: JAX (L2, calling L1 Pallas kernels) → HLO **text** →
artifacts/ for the Rust PJRT runtime.

HLO text — NOT `lowered.compiler_ir().serialize()` — is the interchange
format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids that
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md). All modules lower with return_tuple=True.

Emits:
  * ternary_matmul.hlo.txt — the L1 kernel wrapped at a serving shape.
  * ptqtp_step.hlo.txt     — one quantizer iteration (offload path).
  * decode_logits.hlo.txt  — tiny-model single-window forward via the
    ternary path (proves L2→L1 composition in one artifact).
  * manifest.json          — names, files, input shapes for the Rust
    ArtifactManifest loader.

Usage: python -m compile.aot --out ../artifacts [--models ../artifacts/models]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_mod
from .kernels.ptqtp_step import ptqtp_step, BLOCK_G
from .kernels.ternary_matmul import ternary_matmul


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_ternary_matmul(m, n, d, group):
    spec = jax.ShapeDtypeStruct

    def fn(x, t1, t2, a1, a2):
        return (ternary_matmul(x, t1, t2, a1, a2, group=group),)

    lowered = jax.jit(fn).lower(
        spec((m, d), jnp.float32),
        spec((n, d), jnp.float32),
        spec((n, d), jnp.float32),
        spec((n, d // group), jnp.float32),
        spec((n, d // group), jnp.float32),
    )
    return to_hlo_text(lowered), [[m, d], [n, d], [n, d], [n, d // group], [n, d // group]], 1


def lower_ptqtp_step(g, G):
    spec = jax.ShapeDtypeStruct

    def fn(w, t1, t2, lam):
        return ptqtp_step(w, t1, t2, lam)

    lowered = jax.jit(fn).lower(
        spec((g, G), jnp.float32),
        spec((g, G), jnp.float32),
        spec((g, G), jnp.float32),
        spec((g, 1), jnp.float32),
    )
    return to_hlo_text(lowered), [[g, G], [g, G], [g, G], [g, 1]], 5


def lower_decode_logits(cfg, window):
    """Single fixed-window forward returning last-position logits.
    Params are baked as constants (the artifact is model-specific, like
    a compiled engine in TensorRT terms)."""
    params = model_mod.init_params(cfg, seed=0)

    def fn(tokens):
        logits = model_mod.forward(params, tokens, cfg)
        return (logits[:, -1, :],)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((1, window), jnp.int32))
    return to_hlo_text(lowered), [[1, window]], 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--group", type=int, default=128)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = []

    # serving-shaped ternary matmul (small-family gate_proj shape)
    m, n, d, group = 1, 352, 128, 32
    # n must be a multiple of the kernel's BLOCK_N (16): 352 = 22*16
    text, inputs, n_out = lower_ternary_matmul(m, n, d, group)
    with open(os.path.join(args.out, "ternary_matmul.hlo.txt"), "w") as f:
        f.write(text)
    manifest.append({"name": "ternary_matmul", "file": "ternary_matmul.hlo.txt",
                     "inputs": inputs, "n_outputs": n_out})

    # quantizer step at G=32 over a BLOCK_G-aligned batch
    g, G = 4 * BLOCK_G, 32
    text, inputs, n_out = lower_ptqtp_step(g, G)
    with open(os.path.join(args.out, "ptqtp_step.hlo.txt"), "w") as f:
        f.write(text)
    manifest.append({"name": "ptqtp_step", "file": "ptqtp_step.hlo.txt",
                     "inputs": inputs, "n_outputs": n_out})

    # tiny-model decode logits over an 8-token window
    tok_path = os.path.join(os.path.dirname(args.out), "data", "tokenizer.json")
    if os.path.exists(tok_path):
        with open(tok_path) as f:
            vocab_size = len(json.load(f)["chars"]) + 3
    else:
        vocab_size = 64
    cfg = model_mod.make_config("tiny", vocab_size, max_seq=16)
    window = 8
    text, inputs, n_out = lower_decode_logits(cfg, window)
    with open(os.path.join(args.out, "decode_logits.hlo.txt"), "w") as f:
        f.write(text)
    manifest.append({"name": "decode_logits", "file": "decode_logits.hlo.txt",
                     "inputs": inputs, "n_outputs": n_out,
                     "dtype_note": "input is int32 token ids"})

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump({"artifacts": manifest}, f, indent=2)
    print(f"wrote {len(manifest)} artifacts to {args.out}", flush=True)

    # self-check: numerics of the lowered ternary_matmul against the ref
    rng = np.random.default_rng(0)
    x = jnp.array(rng.normal(size=(m, d)), jnp.float32)
    t1 = jnp.array(rng.integers(-1, 2, size=(n, d)), jnp.float32)
    t2 = jnp.array(rng.integers(-1, 2, size=(n, d)), jnp.float32)
    a1 = jnp.array(rng.normal(size=(n, d // group)), jnp.float32)
    a2 = jnp.array(rng.normal(size=(n, d // group)), jnp.float32)
    from .kernels.ref import ternary_matmul_ref
    got = ternary_matmul(x, t1, t2, a1, a2, group=group)
    want = ternary_matmul_ref(x, t1, t2, a1, a2, group)
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < 1e-4, f"ternary_matmul self-check failed: {err}"
    print(f"self-check ok (max err {err:.2e})", flush=True)


if __name__ == "__main__":
    main()

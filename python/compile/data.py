"""Build-time data loading: the corpus + tokenizer emitted by the Rust
CLI (`ptqtp gen-corpus`). The tokenizer contract matches
rust/src/data/tokenizer.rs exactly: ids 0/1/2 = pad/unk/eos, then the
sorted character list starting at id 3."""

import json
import os

import numpy as np

PAD, UNK, EOS = 0, 1, 2


class Tokenizer:
    def __init__(self, chars: str):
        self.chars = chars
        self.map = {c: i + 3 for i, c in enumerate(chars)}

    @classmethod
    def load(cls, path):
        with open(path) as f:
            return cls(json.load(f)["chars"])

    @property
    def vocab_size(self):
        return len(self.chars) + 3

    def encode(self, text):
        return [self.map.get(c, UNK) for c in text]

    def decode(self, ids):
        out = []
        for i in ids:
            if i >= 3:
                out.append(self.chars[i - 3])
            elif i == UNK:
                out.append("�")
        return "".join(out)


def load_corpus(data_dir):
    """Returns (tokenizer, train_ids np.int32). Lines are joined with
    EOS separators so the model learns line boundaries."""
    tok = Tokenizer.load(os.path.join(data_dir, "tokenizer.json"))
    with open(os.path.join(data_dir, "corpus_train.txt")) as f:
        lines = f.read().splitlines()
    ids = []
    for line in lines:
        ids.extend(tok.encode(line))
        ids.append(EOS)
    return tok, np.array(ids, dtype=np.int32)


def batches(ids, batch, seq, steps, seed=0):
    """Yield `steps` random (batch, seq+1) windows for LM training."""
    rng = np.random.default_rng(seed)
    n = len(ids) - seq - 1
    assert n > 0, "corpus too short for the requested sequence length"
    for _ in range(steps):
        starts = rng.integers(0, n, size=batch)
        yield np.stack([ids[s : s + seq + 1] for s in starts])

"""L2: the JAX transformer — forward/loss for training, decode-step for
AOT export, and a ternary mode whose linear layers call the L1 Pallas
kernel so the PTQTP data path lowers into the same HLO.

Numerical contract matches rust/src/model exactly (RMSNorm, paired-RoPE,
GQA, SwiGLU, tied LM head); pytest cross-checks checkpoint parity.
"""

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.ternary_matmul import ternary_matmul


# ---------------------------------------------------------------------
# config & params
# ---------------------------------------------------------------------

FAMILIES = {
    # name: (d_model, n_layers, n_heads, n_kv_heads, d_ff) — must mirror
    # rust/src/model/config.rs ModelConfig::family
    "tiny": (64, 2, 4, 2, 172),
    "small": (128, 4, 4, 2, 344),
    "medium": (192, 6, 6, 3, 512),
    "large": (256, 8, 8, 4, 688),
}


def make_config(family, vocab_size, max_seq=256):
    d, l, h, kv, ff = FAMILIES[family]
    return dict(
        name=family, vocab_size=vocab_size, d_model=d, n_layers=l,
        n_heads=h, n_kv_heads=kv, d_ff=ff, max_seq=max_seq,
        rope_theta=10_000.0, norm_eps=1e-5, tied_embeddings=True,
    )


def init_params(cfg, seed=0):
    """Scaled-normal init; names match the .ptw checkpoint contract."""
    rng = np.random.default_rng(seed)
    d, ff = cfg["d_model"], cfg["d_ff"]
    kv_dim = cfg["n_kv_heads"] * (d // cfg["n_heads"])
    std = 0.6 / math.sqrt(d)

    def mat(out_f, in_f):
        return jnp.array(rng.normal(0, std, size=(out_f, in_f)), jnp.float32)

    params = {
        "tok_embed": jnp.array(rng.normal(0, 0.02, size=(cfg["vocab_size"], d)), jnp.float32),
        "final_norm": jnp.ones((d,), jnp.float32),
    }
    for i in range(cfg["n_layers"]):
        params[f"L{i}.attn_norm"] = jnp.ones((d,), jnp.float32)
        params[f"L{i}.mlp_norm"] = jnp.ones((d,), jnp.float32)
        params[f"L{i}.wq"] = mat(d, d)
        params[f"L{i}.wk"] = mat(kv_dim, d)
        params[f"L{i}.wv"] = mat(kv_dim, d)
        params[f"L{i}.wo"] = mat(d, d)
        params[f"L{i}.w_gate"] = mat(ff, d)
        params[f"L{i}.w_up"] = mat(ff, d)
        params[f"L{i}.w_down"] = mat(d, ff)
    return params


# ---------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------

def rmsnorm(x, w, eps):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return w * x / jnp.sqrt(ms + eps)


def rope_tables(head_dim, max_seq, theta):
    half = head_dim // 2
    freqs = 1.0 / (theta ** (2.0 * np.arange(half) / head_dim))
    angles = np.arange(max_seq)[:, None] * freqs[None, :]
    return jnp.array(np.cos(angles), jnp.float32), jnp.array(np.sin(angles), jnp.float32)


def apply_rope(x, cos, sin):
    """x: (..., T, H, head_dim) with pair layout (2i, 2i+1); cos/sin (T, half)."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    r1 = x1 * c - x2 * s
    r2 = x1 * s + x2 * c
    out = jnp.stack([r1, r2], axis=-1)  # (..., half, 2)
    return out.reshape(x.shape)


def linear(params, name, x, ternary=None):
    """y = x @ W^T; if `ternary` holds planes for this layer, route the
    matmul through the L1 Pallas kernel instead of the dense weights."""
    if ternary is not None and name in ternary:
        t1, t2, a1, a2, group = ternary[name]
        shape = x.shape
        y = ternary_matmul(x.reshape(-1, shape[-1]), t1, t2, a1, a2, group=group)
        return y.reshape(*shape[:-1], -1)
    return x @ params[name].T


@functools.partial(jax.jit, static_argnames=("cfg_key",))
def _forward_jit(params, tokens, cos, sin, cfg_key):
    cfg = _CFG_CACHE[cfg_key]
    return _forward(params, tokens, cos, sin, cfg, None)


_CFG_CACHE = {}


def _forward(params, tokens, cos, sin, cfg, ternary):
    b, t = tokens.shape
    d = cfg["d_model"]
    h, kv = cfg["n_heads"], cfg["n_kv_heads"]
    hd = d // h
    x = params["tok_embed"][tokens]  # (B, T, d)
    mask = jnp.tril(jnp.ones((t, t), bool))
    for i in range(cfg["n_layers"]):
        xn = rmsnorm(x, params[f"L{i}.attn_norm"], cfg["norm_eps"])
        q = linear(params, f"L{i}.wq", xn, ternary).reshape(b, t, h, hd)
        k = linear(params, f"L{i}.wk", xn, ternary).reshape(b, t, kv, hd)
        v = linear(params, f"L{i}.wv", xn, ternary).reshape(b, t, kv, hd)
        q = apply_rope(q, cos[:t], sin[:t])
        k = apply_rope(k, cos[:t], sin[:t])
        # GQA: repeat kv heads
        rep = h // kv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
        att = jnp.where(mask[None, None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, t, d)
        x = x + linear(params, f"L{i}.wo", o, ternary)
        xn = rmsnorm(x, params[f"L{i}.mlp_norm"], cfg["norm_eps"])
        g = linear(params, f"L{i}.w_gate", xn, ternary)
        u = linear(params, f"L{i}.w_up", xn, ternary)
        x = x + linear(params, f"L{i}.w_down", jax.nn.silu(g) * u, ternary)
    x = rmsnorm(x, params["final_norm"], cfg["norm_eps"])
    return x @ params["tok_embed"].T  # tied head


def forward(params, tokens, cfg, ternary=None):
    """Logits (B, T, V). `ternary` maps layer name → (t1,t2,a1,a2,G)."""
    hd = cfg["d_model"] // cfg["n_heads"]
    cos, sin = rope_tables(hd, cfg["max_seq"], cfg["rope_theta"])
    if ternary is None:
        key = _cfg_key(cfg)
        return _forward_jit(params, tokens, cos, sin, key)
    return _forward(params, tokens, cos, sin, cfg, ternary)


def _cfg_key(cfg):
    key = tuple(sorted(cfg.items()))
    _CFG_CACHE[key] = cfg
    return key


def loss_fn(params, batch, cfg):
    """Next-token cross entropy. batch: (B, T+1) int32."""
    inp, tgt = batch[:, :-1], batch[:, 1:]
    logits = forward(params, inp, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------
# decode step (exported AOT)
# ---------------------------------------------------------------------

def decode_step_fn(cfg):
    """Returns f(params_flat..., hidden_state) suitable for AOT export:
    a single-token forward over a *fixed-length* context window
    (the Rust engine uses its native path for serving; this artifact
    exists to prove the L2→L1→HLO→PJRT chain end to end and is
    exercised by rust/tests/runtime_integration.rs)."""

    def step(params, tokens):
        # tokens: (1, T) fixed window; returns logits of the last position
        logits = forward(params, tokens, cfg)
        return (logits[:, -1, :],)

    return step

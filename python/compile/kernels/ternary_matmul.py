"""L1 Pallas kernel: two-trit-plane matmul (the inference hot-spot).

Computes `y = x @ W_hat^T` where `W_hat = a1*T1 + a2*T2` with group-wise
scales, WITHOUT materializing W_hat in HBM: each grid step streams one
(bn x d) tile of the trit planes into VMEM, forms the plane
contributions, and applies the two scales per group at the epilogue.

TPU mapping of the paper's CUDA kernel (DESIGN.md §Hardware-Adaptation):
  * trit planes live as (bn, d) VMEM tiles (i8 on real TPU; f32 here
    because interpret=True runs on the CPU backend);
  * the "multiplication-free" product is a select/sign-add on the VPU —
    expressed below with `jnp.where` masks so no x*t multiply appears in
    the kernel body;
  * the HBM->VMEM schedule the CUDA version did with threadblocks is the
    Pallas grid over output-column tiles with BlockSpec index maps.

interpret=True is mandatory on this CPU image: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# output-column tile (number of W rows per grid step)
BLOCK_N = 16


def _kernel(x_ref, t1_ref, t2_ref, a1_ref, a2_ref, o_ref, *, group):
    """One grid step: all m rows of x against BLOCK_N output channels."""
    x = x_ref[...]          # (m, d)
    t1 = t1_ref[...]        # (bn, d)
    t2 = t2_ref[...]        # (bn, d)
    a1 = a1_ref[...]        # (bn, gpr)
    a2 = a2_ref[...]        # (bn, gpr)
    m, d = x.shape
    bn = t1.shape[0]
    gpr = d // group

    # Select/sign-add formulation: for each output channel j and plane p,
    #   s_p[i, j, g] = sum_{c in group g} select(t_p[j,c]) * x[i, c]
    # expressed as masked adds (VPU), not an x*w multiply.
    xg = x.reshape(m, gpr, group)                # (m, gpr, G)
    t1g = t1.reshape(bn, gpr, group)             # (bn, gpr, G)
    t2g = t2.reshape(bn, gpr, group)

    def plane_sum(tg):
        # (m, 1, gpr, G) with (1, bn, gpr, G) select -> (m, bn, gpr)
        pos = jnp.where(tg[None] > 0.5, xg[:, None], 0.0)
        neg = jnp.where(tg[None] < -0.5, xg[:, None], 0.0)
        return jnp.sum(pos, axis=-1) - jnp.sum(neg, axis=-1)

    s1 = plane_sum(t1g)                          # (m, bn, gpr)
    s2 = plane_sum(t2g)
    # epilogue: the only multiplies are the two scale applications
    o_ref[...] = jnp.sum(s1 * a1[None] + s2 * a2[None], axis=-1)


@functools.partial(jax.jit, static_argnames=("group",))
def ternary_matmul(x, t1, t2, a1, a2, *, group=128):
    """Pallas two-plane ternary matmul.

    Args:
      x: (m, d) f32; t1/t2: (n, d) f32 trits; a1/a2: (n, d//group) f32.
    Returns (m, n) f32. `n` is padded to BLOCK_N internally.
    """
    m, d = x.shape
    n = t1.shape[0]
    gpr = d // group
    assert d % group == 0, "G must divide d"
    pad = (-n) % BLOCK_N
    if pad:
        zrow = jnp.zeros((pad, d), t1.dtype)
        zsc = jnp.zeros((pad, gpr), a1.dtype)
        out = ternary_matmul(
            x,
            jnp.concatenate([t1, zrow]),
            jnp.concatenate([t2, zrow]),
            jnp.concatenate([a1, zsc]),
            jnp.concatenate([a2, zsc]),
            group=group,
        )
        return out[:, :n]
    grid = (n // BLOCK_N,)
    return pl.pallas_call(
        functools.partial(_kernel, group=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_N, d), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_N, d), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_N, gpr), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_N, gpr), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((m, BLOCK_N), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, t1, t2, a1, a2)


def vmem_bytes_estimate(m, d, group):
    """Per-grid-step VMEM footprint estimate (bytes) for DESIGN.md §Perf.

    On real TPU the planes are int8 and x/out are bf16/f32; we count the
    deployment dtypes, not the interpret-mode f32 stand-ins.
    """
    gpr = d // group
    x_bytes = m * d * 4                  # f32 activations
    plane_bytes = 2 * BLOCK_N * d * 1    # two i8 planes
    scale_bytes = 2 * BLOCK_N * gpr * 2  # two bf16 scale tiles
    out_bytes = m * BLOCK_N * 4
    return x_bytes + plane_bytes + scale_bytes + out_bytes

"""L1 Pallas kernel: one PTQTP progressive-approximation step.

Quantization-time hot-spot (paper Appendix A.2: O(nd) per iteration).
Each grid step owns a tile of groups and performs, entirely in VMEM:

  1. the adaptive 2x2 ridge solve (Eq. 1/3/4, adjugate inverse Eq. 7);
  2. the exhaustive 9-way trit search (Eq. 5).

The batched layout mirrors the paper's group-wise reshape: the caller
flattens W (n, d) into (n*d/G, G) group rows; the kernel is oblivious to
the original matrix shape, which is what makes PTQTP model-agnostic.

interpret=True — see ternary_matmul.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# groups per grid step
BLOCK_G = 32

LAM_MAX = 1.0
KAPPA_THRESHOLD = 1e12


def _kernel(w_ref, t1_ref, t2_ref, lam_ref, t1o_ref, t2o_ref, a1o_ref, a2o_ref, lamo_ref):
    w = w_ref[...]      # (bg, G)
    t1 = t1_ref[...]
    t2 = t2_ref[...]
    lam = lam_ref[...]  # (bg, 1)

    # ---- ridge solve (Eq. 1) with adaptive lambda (Eq. 3)
    a11 = jnp.sum(t1 * t1, axis=1, keepdims=True)
    a22 = jnp.sum(t2 * t2, axis=1, keepdims=True)
    a12 = jnp.sum(t1 * t2, axis=1, keepdims=True)
    b1 = jnp.sum(t1 * w, axis=1, keepdims=True)
    b2 = jnp.sum(t2 * w, axis=1, keepdims=True)

    d11 = a11 + lam
    d22 = a22 + lam
    det = d11 * d22 - a12 * a12
    fro2 = d11 * d11 + d22 * d22 + 2.0 * a12 * a12
    kappa = fro2 / jnp.maximum(jnp.abs(det), 1e-30)
    grow = jnp.maximum(jnp.sqrt(kappa / KAPPA_THRESHOLD), 2.0)
    lam_new = jnp.where(
        kappa >= KAPPA_THRESHOLD,
        jnp.minimum(jnp.maximum(lam * grow, lam * 2.0), LAM_MAX),
        lam,
    )
    d11 = a11 + lam_new
    d22 = a22 + lam_new
    det = d11 * d22 - a12 * a12
    safe = jnp.abs(det) > 1e-30
    inv_det = jnp.where(safe, 1.0 / jnp.where(safe, det, 1.0), 0.0)
    a1 = (d22 * b1 - a12 * b2) * inv_det  # (bg, 1)
    a2 = (-a12 * b1 + d11 * b2) * inv_det

    # ---- 9-way exhaustive trit search (Eq. 5)
    # candidate index k in 0..9 encodes (c1, c2) = (k//3 - 1, k%3 - 1);
    # built from iota because Pallas kernels cannot capture array consts
    k = jax.lax.broadcasted_iota(jnp.float32, (1, 9), 1)   # (1, 9)
    c1 = jnp.floor(k / 3.0) - 1.0                          # (1, 9)
    c2 = jnp.mod(k, 3.0) - 1.0
    levels = a1 * c1 + a2 * c2                             # (bg, 9)
    err = (w[:, :, None] - levels[:, None, :]) ** 2        # (bg, G, 9)
    best = jnp.argmin(err, axis=2).astype(jnp.float32)     # (bg, G)
    t1o_ref[...] = jnp.floor(best / 3.0) - 1.0
    t2o_ref[...] = jnp.mod(best, 3.0) - 1.0
    a1o_ref[...] = a1
    a2o_ref[...] = a2
    lamo_ref[...] = lam_new


@jax.jit
def ptqtp_step(w, t1, t2, lam):
    """One alternating PTQTP iteration over a batch of groups.

    Args:
      w:  (g, G) group rows (g must be a multiple of BLOCK_G).
      t1, t2: (g, G) current planes (f32 trits).
      lam: (g, 1) regularization state.
    Returns (t1', t2', a1, a2, lam') with scales shaped (g, 1).
    """
    g, G = w.shape
    assert g % BLOCK_G == 0, f"group batch must be a multiple of {BLOCK_G}"
    grid = (g // BLOCK_G,)
    spec_wg = pl.BlockSpec((BLOCK_G, G), lambda i: (i, 0))
    spec_s = pl.BlockSpec((BLOCK_G, 1), lambda i: (i, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec_wg, spec_wg, spec_wg, spec_s],
        out_specs=[spec_wg, spec_wg, spec_s, spec_s, spec_s],
        out_shape=[
            jax.ShapeDtypeStruct((g, G), jnp.float32),
            jax.ShapeDtypeStruct((g, G), jnp.float32),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
        ],
        interpret=True,
    )(w, t1, t2, lam)


def ptqtp_quantize(w, group, t_max=50, eps=1e-4, lam0=1e-8):
    """Full PTQTP quantization of W (n, d) via the Pallas step kernel,
    with lax.while_loop convergence on max ||alpha_t - alpha_{t-1}||.

    Returns (t1, t2, a1, a2): planes (n, d), scales (n, d//group).
    """
    n, d = w.shape
    assert d % group == 0
    gpr = d // group
    g = n * gpr
    # pad the group batch to BLOCK_G
    pad = (-g) % BLOCK_G
    wg = w.reshape(g, group)
    if pad:
        wg = jnp.concatenate([wg, jnp.zeros((pad, group))], axis=0)
    t1 = jnp.where(wg < 0, -1.0, 1.0)
    t2 = t1
    lam = jnp.full((wg.shape[0], 1), lam0)
    a_prev = jnp.ones((wg.shape[0], 2))

    def cond(state):
        it, _, _, _, _, delta = state
        return jnp.logical_and(it < t_max, delta >= eps)

    def body(state):
        it, t1, t2, lam, a_prev, _ = state
        t1n, t2n, a1, a2, lamn = ptqtp_step(wg, t1, t2, lam)
        a_now = jnp.concatenate([a1, a2], axis=1)
        delta = jnp.max(jnp.sqrt(jnp.sum((a_now - a_prev) ** 2, axis=1)))
        return it + 1, t1n, t2n, lamn, a_now, delta

    state = (0, t1, t2, lam, a_prev, jnp.inf)
    _, t1, t2, _, a_now, _ = jax.lax.while_loop(cond, body, state)
    t1 = t1[:g].reshape(n, d)
    t2 = t2[:g].reshape(n, d)
    a1 = a_now[:g, 0].reshape(n, gpr)
    a2 = a_now[:g, 1].reshape(n, gpr)
    return t1, t2, a1, a2

"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Every Pallas kernel in this package has a reference implementation here
written in straight-line jax.numpy; pytest (with hypothesis sweeps)
asserts allclose between kernel and oracle. The oracles are also the
ground truth the Rust unit tests were written against, so all three
layers share one numerical contract.
"""

import jax.numpy as jnp


def ternary_matmul_ref(x, t1, t2, a1, a2, group):
    """y = x @ W_hat^T with W_hat = groupscale(a1)*t1 + groupscale(a2)*t2.

    Args:
      x:  (m, d) activations.
      t1, t2: (n, d) trit planes with values in {-1, 0, 1} (stored f32).
      a1, a2: (n, d // group) per-(row, group) scales.
      group: group size G along d; must divide d.

    Returns: (m, n) output.
    """
    n, d = t1.shape
    assert d % group == 0, "ref kernel requires G | d"
    gpr = d // group
    # expand group scales to full width
    a1_full = jnp.repeat(a1, group, axis=1)  # (n, d)
    a2_full = jnp.repeat(a2, group, axis=1)
    w_hat = a1_full * t1 + a2_full * t2
    return x @ w_hat.T


def reconstruct_ref(t1, t2, a1, a2, group):
    """Dense reconstruction W_hat (n, d) from planes + group scales."""
    a1_full = jnp.repeat(a1, group, axis=1)
    a2_full = jnp.repeat(a2, group, axis=1)
    return a1_full * t1 + a2_full * t2


def ridge_step_ref(w, t1, t2, lam, lam_max=1.0, kappa_threshold=1e12):
    """One adaptive-ridge solve (paper Eq. 1/3/4) for a batch of groups.

    Args:
      w:  (g, G) group values.
      t1, t2: (g, G) current trit planes.
      lam: (g,) regularization per group.

    Returns: (a1, a2, lam_new) each (g,).
    """
    a11 = jnp.sum(t1 * t1, axis=1)
    a22 = jnp.sum(t2 * t2, axis=1)
    a12 = jnp.sum(t1 * t2, axis=1)
    b1 = jnp.sum(t1 * w, axis=1)
    b2 = jnp.sum(t2 * w, axis=1)

    def solve(lam_v):
        d11 = a11 + lam_v
        d22 = a22 + lam_v
        det = d11 * d22 - a12 * a12
        fro2 = d11 * d11 + d22 * d22 + 2.0 * a12 * a12
        kappa = fro2 / jnp.maximum(jnp.abs(det), 1e-300)
        return d11, d22, det, kappa

    _, _, det0, kappa0 = solve(lam)
    # Eq. 3: grow lambda where kappa >= threshold (single adaptation,
    # mirroring the loop's first trigger; growth factor sqrt(k/thr), min 2x)
    grow = jnp.maximum(jnp.sqrt(kappa0 / kappa_threshold), 2.0)
    lam_new = jnp.where(
        kappa0 >= kappa_threshold,
        jnp.minimum(jnp.maximum(lam * grow, lam * 2.0), lam_max),
        lam,
    )
    d11, d22, det, _ = solve(lam_new)
    safe_det = jnp.where(jnp.abs(det) < 1e-30, 1.0, det)
    alpha1 = (d22 * b1 - a12 * b2) / safe_det
    alpha2 = (-a12 * b1 + d11 * b2) / safe_det
    alpha1 = jnp.where(jnp.abs(det) < 1e-30, 0.0, alpha1)
    alpha2 = jnp.where(jnp.abs(det) < 1e-30, 0.0, alpha2)
    return alpha1, alpha2, lam_new


def trit_search_ref(w, a1, a2):
    """Exhaustive 9-way trit search (paper Eq. 5) for a batch of groups.

    Args:
      w: (g, G); a1, a2: (g,).
    Returns: (t1, t2) each (g, G) in {-1, 0, +1}.
    """
    cands = jnp.array(
        [(c1, c2) for c1 in (-1.0, 0.0, 1.0) for c2 in (-1.0, 0.0, 1.0)]
    )  # (9, 2)
    # levels: (g, 9)
    levels = a1[:, None] * cands[None, :, 0] + a2[:, None] * cands[None, :, 1]
    # err: (g, G, 9)
    err = (w[:, :, None] - levels[:, None, :]) ** 2
    best = jnp.argmin(err, axis=2)  # (g, G)
    t1 = cands[best, 0]
    t2 = cands[best, 1]
    return t1, t2


def ptqtp_quantize_ref(w, group, t_max=50, eps=1e-4, lam0=1e-8):
    """Full PTQTP on one weight matrix (n, d): the Algorithm 1 oracle.

    Returns (t1, t2, a1, a2) with planes (n, d) and scales (n, d//group).
    Pure-jnp, python loop over iterations (build path only).
    """
    n, d = w.shape
    assert d % group == 0
    gpr = d // group
    wg = w.reshape(n * gpr, group)
    t1 = jnp.where(wg < 0, -1.0, 1.0)
    t2 = t1
    lam = jnp.full((n * gpr,), lam0)
    a1_prev = jnp.ones((n * gpr,))
    a2_prev = jnp.ones((n * gpr,))
    for _ in range(t_max):
        a1, a2, lam = ridge_step_ref(wg, t1, t2, lam)
        t1, t2 = trit_search_ref(wg, a1, a2)
        delta = jnp.sqrt((a1 - a1_prev) ** 2 + (a2 - a2_prev) ** 2)
        a1_prev, a2_prev = a1, a2
        if float(jnp.max(delta)) < eps:
            break
    return (
        t1.reshape(n, d),
        t2.reshape(n, d),
        a1_prev.reshape(n, gpr),
        a2_prev.reshape(n, gpr),
    )

"""L1 kernel correctness: Pallas vs pure-jnp oracle, hypothesis-swept
over shapes — the core correctness signal of the compile path."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.ptqtp_step import BLOCK_G, ptqtp_quantize, ptqtp_step
from compile.kernels.ternary_matmul import BLOCK_N, ternary_matmul, vmem_bytes_estimate


def rand_planes(rng, n, d):
    t1 = jnp.array(rng.integers(-1, 2, size=(n, d)), jnp.float32)
    t2 = jnp.array(rng.integers(-1, 2, size=(n, d)), jnp.float32)
    return t1, t2


# ---------------------------------------------------------------- matmul

@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 6),
    nb=st.integers(1, 3),
    gpr=st.integers(1, 4),
    group=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ternary_matmul_matches_ref(m, nb, gpr, group, seed):
    rng = np.random.default_rng(seed)
    n, d = nb * BLOCK_N, gpr * group
    x = jnp.array(rng.normal(size=(m, d)), jnp.float32)
    t1, t2 = rand_planes(rng, n, d)
    a1 = jnp.array(rng.normal(size=(n, gpr)), jnp.float32)
    a2 = jnp.array(rng.normal(size=(n, gpr)), jnp.float32)
    got = ternary_matmul(x, t1, t2, a1, a2, group=group)
    want = ref.ternary_matmul_ref(x, t1, t2, a1, a2, group)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-4, rtol=1e-4)


def test_ternary_matmul_zero_planes():
    x = jnp.ones((2, 32))
    z = jnp.zeros((BLOCK_N, 32))
    a = jnp.ones((BLOCK_N, 2))
    out = ternary_matmul(x, z, z, a, a, group=16)
    assert float(jnp.max(jnp.abs(out))) == 0.0


def test_vmem_estimate_reasonable():
    # serving shape: must fit VMEM (~16 MiB/core on modern TPUs)
    assert vmem_bytes_estimate(8, 4096, 128) < 4 * 1024 * 1024


# ---------------------------------------------------------------- quantizer

@settings(max_examples=15, deadline=None)
@given(
    gb=st.integers(1, 3),
    G=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ptqtp_step_matches_ref(gb, G, seed):
    rng = np.random.default_rng(seed)
    g = gb * BLOCK_G
    w = jnp.array(rng.normal(size=(g, G)) * 0.05, jnp.float32)
    t1 = jnp.where(w < 0, -1.0, 1.0)
    t2 = t1
    lam = jnp.full((g, 1), 1e-8)
    t1k, t2k, a1k, a2k, _ = ptqtp_step(w, t1, t2, lam)
    a1r, a2r, _ = ref.ridge_step_ref(w, t1, t2, lam[:, 0])
    np.testing.assert_allclose(np.array(a1k[:, 0]), np.array(a1r), atol=1e-5)
    np.testing.assert_allclose(np.array(a2k[:, 0]), np.array(a2r), atol=1e-5)
    t1r, t2r = ref.trit_search_ref(w, a1r, a2r)
    assert bool(jnp.all(t1k == t1r))
    assert bool(jnp.all(t2k == t2r))


def test_ptqtp_quantize_converges_and_matches_ref():
    rng = np.random.default_rng(0)
    w = jnp.array(rng.standard_t(4, size=(8, 64)) * 0.04, jnp.float32)
    t1, t2, a1, a2 = ptqtp_quantize(w, 16)
    wh = ref.reconstruct_ref(t1, t2, a1, a2, 16)
    rel = float(jnp.linalg.norm(w - wh) / jnp.linalg.norm(w))
    assert rel < 0.35, rel
    # exact agreement with the python-loop oracle
    t1r, t2r, a1r, a2r = ref.ptqtp_quantize_ref(w, 16)
    whr = ref.reconstruct_ref(t1r, t2r, a1r, a2r, 16)
    relr = float(jnp.linalg.norm(w - whr) / jnp.linalg.norm(w))
    assert abs(rel - relr) < 1e-5


def test_ptqtp_two_planes_beat_one():
    rng = np.random.default_rng(1)
    w = jnp.array(rng.standard_t(4, size=(8, 128)) * 0.04, jnp.float32)
    t1, t2, a1, a2 = ptqtp_quantize(w, 32)
    wh2 = ref.reconstruct_ref(t1, t2, a1, a2, 32)
    from compile.quant_jax import absmean_ternary
    wh1 = absmean_ternary(w, 32)
    e2 = float(jnp.sum((w - wh2) ** 2))
    e1 = float(jnp.sum((w - wh1) ** 2))
    assert e2 < e1 * 0.7, (e2, e1)


def test_trit_values_legal():
    rng = np.random.default_rng(2)
    w = jnp.array(rng.normal(size=(4, 64)) * 0.1, jnp.float32)
    t1, t2, _, _ = ptqtp_quantize(w, 16)
    for t in (t1, t2):
        vals = set(np.unique(np.array(t)).tolist())
        assert vals <= {-1.0, 0.0, 1.0}, vals


def test_zero_matrix_stable():
    w = jnp.zeros((4, 32))
    t1, t2, a1, a2 = ptqtp_quantize(w, 16)
    wh = ref.reconstruct_ref(t1, t2, a1, a2, 16)
    assert float(jnp.max(jnp.abs(wh))) < 1e-6

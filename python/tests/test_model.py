"""L2 model tests: shapes, loss behaviour, ternary-path composition,
and .ptw checkpoint parity with the Rust loader's contract."""

import json
import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as model_mod
from compile import ptw
from compile.quant_jax import quantize_checkpoint


@pytest.fixture(scope="module")
def tiny():
    cfg = model_mod.make_config("tiny", vocab_size=32, max_seq=32)
    params = model_mod.init_params(cfg, seed=0)
    return cfg, params


def test_forward_shapes(tiny):
    cfg, params = tiny
    tokens = jnp.array([[1, 2, 3, 4]], jnp.int32)
    logits = model_mod.forward(params, tokens, cfg)
    assert logits.shape == (1, 4, 32)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_causality(tiny):
    """Changing a later token must not affect earlier logits."""
    cfg, params = tiny
    a = jnp.array([[1, 2, 3, 4]], jnp.int32)
    b = jnp.array([[1, 2, 3, 9]], jnp.int32)
    la = model_mod.forward(params, a, cfg)
    lb = model_mod.forward(params, b, cfg)
    np.testing.assert_allclose(np.array(la[:, :3]), np.array(lb[:, :3]), atol=1e-5)
    assert not np.allclose(np.array(la[:, 3]), np.array(lb[:, 3]))


def test_loss_decreases_with_training_steps(tiny):
    import jax
    cfg, params = tiny
    rng = np.random.default_rng(0)
    # learnable pattern: repeated sequence
    batch = jnp.array(np.tile(rng.integers(3, 32, size=(1, 9)), (4, 1)), jnp.int32)
    loss0 = model_mod.loss_fn(params, batch, cfg)
    grad_fn = jax.jit(jax.value_and_grad(lambda p: model_mod.loss_fn(p, batch, cfg)))
    p = params
    for _ in range(20):
        _, g = grad_fn(p)
        p = jax.tree.map(lambda x, gg: x - 0.05 * gg, p, g)
    loss1 = model_mod.loss_fn(p, batch, cfg)
    assert float(loss1) < float(loss0) * 0.8, (float(loss0), float(loss1))


def test_ternary_path_close_to_dense_reconstruction(tiny):
    cfg, params = tiny
    qparams, planes = quantize_checkpoint(params, group=16)
    tokens = jnp.array([[1, 5, 9]], jnp.int32)
    # dense forward on reconstructed weights == ternary kernel forward
    dense = model_mod.forward(qparams, tokens, cfg)
    tern = model_mod.forward(params, tokens, cfg, ternary=planes)
    np.testing.assert_allclose(np.array(dense), np.array(tern), atol=1e-3, rtol=1e-3)


def test_quantized_model_correlates_with_fp(tiny):
    cfg, params = tiny
    qparams, _ = quantize_checkpoint(params, group=16)
    tokens = jnp.array([[2, 7, 11, 3]], jnp.int32)
    lf = np.array(model_mod.forward(params, tokens, cfg))[:, -1].ravel()
    lq = np.array(model_mod.forward(qparams, tokens, cfg))[:, -1].ravel()
    cos = float(np.dot(lf, lq) / (np.linalg.norm(lf) * np.linalg.norm(lq)))
    assert cos > 0.8, cos


def test_ptw_roundtrip(tiny):
    _, params = tiny
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "m.ptw")
        arrs = {k: np.array(v) for k, v in params.items()}
        # norms are 1-D in jax; rust expects (1, d) — reshape as train.py's
        # checkpoint contract does for real saves
        ptw.save(path, arrs)
        back = ptw.load(path)
        assert set(back) == set(arrs)
        for k in arrs:
            np.testing.assert_array_equal(back[k], arrs[k])


def test_ptw_dtypes():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.ptw")
        ptw.save(path, {
            "f": np.arange(6, dtype=np.float32).reshape(2, 3),
            "i8": np.array([-1, 0, 1], dtype=np.int8),
            "u8": np.array([0, 255], dtype=np.uint8),
        })
        back = ptw.load(path)
        assert back["f"].dtype == np.float32
        assert back["i8"].dtype == np.int8
        assert back["u8"][1] == 255


def test_family_grid_matches_rust():
    """The family table must mirror rust/src/model/config.rs."""
    rust_src = open(os.path.join(os.path.dirname(__file__), "..", "..",
                                 "rust", "src", "model", "config.rs")).read()
    for name, (d, l, h, kv, ff) in model_mod.FAMILIES.items():
        needle = f'"{name}" => base("{name}", {d}, {l}, {h}, {kv}, {ff})'
        assert needle in rust_src, f"family {name} diverged from Rust: {needle}"

//! Serve a PTQTP-quantized model through the full coordinator stack
//! (router → continuous batcher → KV pool → engine) and report serving
//! metrics — the "serving paper" workload.
//!
//! Uses the trained checkpoint from `make artifacts` when present,
//! falling back to a random model so the example always runs.
//!
//! Run: `cargo run --release --example serve_quantized`

use ptqtp::coordinator::{router::RoutePolicy, SamplingParams, ServeEngine, Server};
use ptqtp::data::{CorpusGen, Tokenizer};
use ptqtp::model::{ModelConfig, Transformer};
use ptqtp::quant::{Ptqtp, QuantCtx};
use ptqtp::rng::Rng;
use std::time::{Duration, Instant};

fn load_model() -> (Transformer, Tokenizer) {
    let ckpt = std::path::Path::new("artifacts/models/small.ptw");
    let tok_path = std::path::Path::new("data/tokenizer.json");
    if ckpt.exists() && tok_path.exists() {
        (
            Transformer::load(ckpt).expect("checkpoint"),
            Tokenizer::load(tok_path).expect("tokenizer"),
        )
    } else {
        eprintln!("(trained checkpoint not found — using random weights; run `make artifacts`)");
        let tok = Tokenizer::from_text("abcdefghijklmnopqrstuvwxyz 0123456789+-*=?.:QA");
        let mut cfg = ModelConfig::family("small").unwrap();
        cfg.vocab_size = tok.vocab_size();
        let mut rng = Rng::new(1);
        (Transformer::random(cfg, &mut rng), tok)
    }
}

fn main() -> anyhow::Result<()> {
    let (mut model, tok) = load_model();

    // quantize to trit-planes — the whole model now serves multiply-free
    let t0 = Instant::now();
    model.quantize_with(&Ptqtp::default(), &QuantCtx::default());
    println!(
        "PTQTP-quantized {} ({} params) in {:.2?} — resident {} KiB",
        model.config.name,
        model.config.param_count(),
        t0.elapsed(),
        model.resident_bytes() / 1024
    );

    // two replicas behind the least-loaded router
    let engines = vec![
        ServeEngine::new(model.clone(), Default::default()),
        ServeEngine::new(model, Default::default()),
    ];
    let mut server = Server::start(engines, RoutePolicy::LeastLoaded);

    // mixed workload: math prompts + free-form continuations
    let mut gen = CorpusGen::new(99);
    let n_requests = 24;
    let t0 = Instant::now();
    for i in 0..n_requests {
        let prompt = if i % 2 == 0 {
            gen.math_line().0
        } else {
            "the river ".to_string()
        };
        server.submit(
            tok.encode(&prompt),
            SamplingParams {
                max_new_tokens: 12,
                ..Default::default()
            },
            i as u64 % 4, // 4 sessions → affinity routing
        );
    }
    let responses = server.wait_for(n_requests, Duration::from_secs(120));
    let wall = t0.elapsed();
    println!("completed {}/{} requests in {:.2?}", responses.len(), n_requests, wall);
    let total_tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
    println!(
        "throughput: {:.1} tok/s decode;  mean ttft {:.1} ms",
        total_tokens as f64 / wall.as_secs_f64(),
        responses.iter().map(|r| r.ttft.as_secs_f64()).sum::<f64>() / responses.len().max(1) as f64
            * 1e3
    );
    for r in responses.iter().take(4) {
        println!("  req {}: {:?}", r.id, tok.decode(&r.tokens));
    }
    server.shutdown();
    Ok(())
}

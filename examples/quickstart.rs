//! Quickstart: quantize a weight matrix with PTQTP and inspect the
//! result — the 60-second tour of the core API.
//!
//! Run: `cargo run --release --example quickstart`

use ptqtp::quant::{Ptqtp, PtqtpOpts, QuantCtx, Quantizer};
use ptqtp::rng::Rng;
use ptqtp::tensor::Matrix;
use ptqtp::ternary::gemv::gemv_packed_alloc;

fn main() -> anyhow::Result<()> {
    // 1. a weight matrix with LLM-like heavy-tailed statistics
    let mut rng = Rng::new(42);
    let w = Matrix::rand_heavy(256, 512, 0.03, &mut rng);
    println!("weights: {}x{} ({} KiB fp32)", w.rows, w.cols, w.len() * 4 / 1024);

    // 2. PTQTP: decompose into two trit-planes + group scales (paper §3)
    let quantizer = Ptqtp::new(PtqtpOpts::default()); // G=128, T_max=50, ε=1e-4
    let (lin, report) = quantizer.quantize_with_report(&w);
    println!(
        "quantized: rel err {:.4}, mean iters {:.1}, bits/weight {:.2}",
        w.rel_err(&lin.reconstruct()),
        report.mean_iters(),
        lin.bits_per_weight()
    );
    println!(
        "plane sparsity: T1 {:.1}%  T2 {:.1}%",
        lin.t1.sparsity() * 100.0,
        lin.t2.sparsity() * 100.0
    );

    // 3. pack to the 2-bit deployment format and run the multiply-free
    //    GEMV — the serving hot path
    let packed = lin.to_packed();
    println!(
        "packed: {} KiB ({}x smaller than fp32)",
        packed.resident_bytes() / 1024,
        w.len() * 4 / packed.resident_bytes()
    );
    let x: Vec<f32> = (0..w.cols).map(|_| rng.normal()).collect();
    let y = gemv_packed_alloc(&packed, &x);
    let y_dense = ptqtp::tensor::ops::matvec(&lin.reconstruct(), &x);
    let max_diff = y
        .iter()
        .zip(&y_dense)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("multiply-free GEMV matches dense reconstruction (max diff {max_diff:.2e})");

    // 4. compare against a binary baseline
    let billm = ptqtp::quant::billm::BiLlm::new(128).quantize(&w, &QuantCtx::default());
    println!(
        "reconstruction error: PTQTP {:.4} vs BiLLM {:.4}",
        w.rel_err(&lin.reconstruct()),
        w.rel_err(&billm.w_hat)
    );
    Ok(())
}

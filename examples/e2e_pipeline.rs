//! End-to-end system validation — the full three-layer pipeline on a
//! real (small) workload, proving every layer composes:
//!
//!   1. load the JAX-trained checkpoint (L2 build output, `.ptw`);
//!   2. measure FP16 perplexity + task accuracy (Rust eval stack);
//!   3. PTQTP-quantize the whole model (L3 native quantizer);
//!   4. re-measure: perplexity near-FP16, math/cloze retention high;
//!   5. serve batched requests through the coordinator and report
//!      latency/throughput;
//!   6. execute the AOT HLO artifacts through PJRT (L1/L2 → runtime).
//!
//! This is the run recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `make artifacts && cargo run --release --example e2e_pipeline`

use ptqtp::coordinator::{Request, SamplingParams, ServeEngine};
use ptqtp::data::{CorpusDomain, TaskSuite, Tokenizer};
use ptqtp::eval::{eval_suite, perplexity};
use ptqtp::model::Transformer;
use ptqtp::quant::{Ptqtp, QuantCtx};
use ptqtp::runtime::{ArtifactManifest, PjrtEngine};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    // ---- 1. load trained checkpoint + data
    let model = Transformer::load("artifacts/models/small.ptw")
        .map_err(|e| anyhow::anyhow!("{e} — run `make artifacts` first"))?;
    let tok = Tokenizer::load("data/tokenizer.json")?;
    println!(
        "[1] loaded {} ({} params, vocab {})",
        model.config.name,
        model.config.param_count(),
        model.config.vocab_size
    );

    // ---- 2. FP16 baseline metrics
    let suite = TaskSuite::standard(1, 40, 40, 40);
    let eval_model = |m: &Transformer, tag: &str| -> anyhow::Result<(f64, f64)> {
        let mut ppl_sum = 0.0;
        for d in CorpusDomain::all() {
            let text = std::fs::read_to_string(format!("data/eval_{}.txt", d.name()))?;
            let prefix: String = text.chars().take(2000).collect();
            let p = perplexity(m, &tok, &prefix);
            ppl_sum += p;
            println!("    ppl[{}] = {p:.3}", d.name());
        }
        let s = eval_suite(m, &tok, &suite);
        println!(
            "    math {:.0}%  cloze {:.0}%  code {:.0}%   [{tag}]",
            s.math_acc * 100.0,
            s.cloze_acc * 100.0,
            s.code_acc * 100.0
        );
        Ok((ppl_sum / 3.0, s.mean()))
    };
    println!("[2] FP16 baseline:");
    let (ppl_fp, acc_fp) = eval_model(&model, "fp16")?;

    // ---- 3. PTQTP quantization (whole model)
    let mut qmodel = model.clone();
    let t0 = Instant::now();
    qmodel.quantize_with(&Ptqtp::default(), &QuantCtx::default());
    println!(
        "[3] PTQTP-quantized all linears in {:.2?} ({} -> {} KiB resident)",
        t0.elapsed(),
        model.resident_bytes() / 1024,
        qmodel.resident_bytes() / 1024
    );

    // ---- 4. quantized metrics
    println!("[4] PTQTP (1.58-bit) metrics:");
    let (ppl_q, acc_q) = eval_model(&qmodel, "ptqtp")?;
    println!(
        "    ppl ratio {:.3} (→1 is lossless); mean-acc retention {:.1}%",
        ppl_q / ppl_fp,
        acc_q / acc_fp.max(1e-9) * 100.0
    );

    // ---- 5. serve a batched workload on the quantized model
    let mut engine = ServeEngine::new(qmodel, Default::default());
    let t0 = Instant::now();
    for (i, task) in suite.math.iter().enumerate() {
        engine.submit(Request::new(
            i as u64,
            tok.encode(&task.prompt),
            SamplingParams {
                max_new_tokens: 6,
                ..Default::default()
            },
        ));
    }
    let responses = engine.run_to_completion();
    let wall = t0.elapsed();
    println!("[5] served {} batched requests:", responses.len());
    for line in engine.metrics.render(wall).lines() {
        println!("    {line}");
    }

    // ---- 6. PJRT: execute the AOT artifacts
    match ArtifactManifest::load("artifacts") {
        Ok(manifest) => {
            let mut pjrt = PjrtEngine::cpu()?;
            manifest.load_all(&mut pjrt)?;
            println!(
                "[6] PJRT {}: compiled artifacts {:?}",
                pjrt.platform(),
                pjrt.names()
            );
            let spec = manifest.get("ternary_matmul")?;
            let inputs: Vec<Vec<f32>> = spec
                .inputs
                .iter()
                .map(|s| vec![0.25f32; s.iter().product()])
                .collect();
            let borrowed: Vec<(&[usize], &[f32])> = spec
                .inputs
                .iter()
                .zip(&inputs)
                .map(|(s, d)| (s.as_slice(), d.as_slice()))
                .collect();
            let out = pjrt.run_f32("ternary_matmul", &borrowed)?;
            println!("    ternary_matmul OK ({} outputs)", out.len());
        }
        Err(e) => println!("[6] PJRT artifacts skipped: {e}"),
    }
    println!("E2E pipeline complete.");
    Ok(())
}

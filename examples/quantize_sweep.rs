//! Sweep every quantization method over one layer and print the
//! quality/cost frontier — the "which method should I use" example.
//!
//! Run: `cargo run --release --example quantize_sweep`

use ptqtp::quant::{self, QuantCtx};
use ptqtp::report::Table;
use ptqtp::rng::Rng;
use ptqtp::tensor::Matrix;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(7);
    let w = Matrix::rand_heavy(512, 1024, 0.03, &mut rng);
    let calib = Matrix::randn(64, 1024, 1.0, &mut rng);
    let ctx = QuantCtx::with_calib(calib);

    let mut table = Table::new(
        "Quantization frontier (512x1024 heavy-tailed layer, G=128)",
        &["Method", "#Bits", "rel err", "memory KiB", "compression", "time ms"],
    );
    for name in quant::paper_methods() {
        let q = quant::by_name(name, 128)?;
        let t0 = Instant::now();
        let r = q.quantize(&w, &ctx);
        let dur = t0.elapsed();
        let m = r.metrics(&w);
        table.row(vec![
            q.name(),
            format!("{:.2}", q.nominal_bits()),
            format!("{:.4}", m.rel_err),
            format!("{}", m.memory_bytes / 1024),
            format!("{:.1}x", m.compression_vs_fp16),
            format!("{:.1}", dur.as_secs_f64() * 1e3),
        ]);
    }
    println!("{}", table.render());
    println!("Expected shape: PTQTP's rel err beats every ≤1.7-bit method and");
    println!("approaches 3-bit grids at a fraction of GPTQ/ARB quantization time.");
    Ok(())
}

//! Chaos tests for the supervised serving layer: seeded fault plans
//! kill replicas mid-stream across the execution matrix — threads
//! {1, 2} × KV {contiguous, paged+prefix} × spec-decode {off, on} —
//! and the run must be indistinguishable from a fault-free one at the
//! token level: same responses, contiguous per-sequence streams, the
//! extended accounting identity intact, and no KV pages leaked.

use std::collections::HashMap;
use std::time::Duration;

use ptqtp::coordinator::router::RoutePolicy;
use ptqtp::coordinator::{
    DrainReport, FaultPlan, FinishReason, Metrics, PagedKvOpts, Response, RetryPolicy,
    ServerBuilder, ServerEvent, SpecDecodeOpts,
};
use ptqtp::model::{ModelConfig, Transformer};
use ptqtp::quant::{self, QuantCtx};
use ptqtp::rng::Rng;

const REPLICAS: usize = 3;
const REQUESTS: u64 = 12;
const NEW_TOKENS: usize = 8;

fn quantized_model(seed: u64) -> Transformer {
    let mut cfg = ModelConfig::family("tiny").unwrap();
    cfg.vocab_size = 32;
    cfg.max_seq = 48;
    let mut rng = Rng::new(seed);
    let mut model = Transformer::random(cfg, &mut rng);
    // ragged group keeps the packed kernel tier in play
    model.quantize_with(
        quant::by_name("ptqtp", 10).unwrap().as_ref(),
        &QuantCtx::default(),
    );
    model
}

/// One serve run: submit the standard workload, consume the event
/// stream (checking per-sequence index contiguity — the dedupe layer
/// must hide every replay seam), then drain. Returns the sorted
/// responses and the drain report.
fn run_serve(
    model: &Transformer,
    threads: usize,
    kv: PagedKvOpts,
    spec: Option<SpecDecodeOpts>,
    faults: Option<FaultPlan>,
) -> (Vec<Response>, DrainReport) {
    let mut builder = ServerBuilder::new()
        .replicas(REPLICAS)
        .route(RoutePolicy::RoundRobin)
        .threads(threads)
        .paged_kv(kv)
        .spec_decode(spec)
        .retry(RetryPolicy {
            max_attempts: 6,
            base: Duration::from_millis(1),
            cap: Duration::from_millis(20),
        });
    if let Some(plan) = faults {
        builder = builder.fault_plan(plan);
    }
    let mut server = builder.start(model.clone());
    for i in 0..REQUESTS {
        let prompt: Vec<u32> = (0..10).map(|j| 1 + ((i + j) % 7) as u32).collect();
        let params = ptqtp::coordinator::SamplingParams::greedy(NEW_TOKENS).with_stop(None);
        assert!(
            server.submit(prompt, params, 0).is_accepted(),
            "workload fits the default intake window"
        );
    }
    let mut streams: HashMap<(u64, usize), Vec<u32>> = HashMap::new();
    let mut done: Vec<Response> = Vec::new();
    let t0 = std::time::Instant::now();
    while done.len() < REQUESTS as usize && t0.elapsed() < Duration::from_secs(120) {
        match server.next_event(Duration::from_millis(10)) {
            Some(ServerEvent::Token { id, sample, token, index }) => {
                let s = streams.entry((id, sample)).or_default();
                assert_eq!(index, s.len(), "req {id}/{sample}: replay seam visible");
                s.push(token);
            }
            Some(ServerEvent::Done(r)) => done.push(r),
            Some(ServerEvent::ReplicaDown { .. }) | None => {}
        }
    }
    assert_eq!(done.len(), REQUESTS as usize, "every request completes");
    for r in &done {
        assert_eq!(r.finish, FinishReason::Length, "req {}: no request is lost", r.id);
        let stream = streams.remove(&(r.id, r.sample)).unwrap_or_default();
        assert_eq!(stream, r.tokens, "req {}: stream == final tokens", r.id);
    }
    let report = server.drain();
    done.sort_by_key(|r| (r.id, r.sample));
    (done, report)
}

/// The extended accounting identity over a finished run:
/// `completed + rejected + cancelled + expired + replica_lost ==
/// submitted`, request-granular (replays retire exactly once, on the
/// engine that finishes them).
fn assert_identity(report: &DrainReport) {
    let st = &report.stats;
    let agg = Metrics::aggregate(&report.metrics);
    let rejected = st.queue_full
        + st.invalid_params
        + st.server_stopped
        + st.replica_restarting
        + agg.rejected;
    let accounted =
        agg.requests_finished + rejected + agg.cancelled + agg.deadline_expired + st.replica_lost;
    assert_eq!(
        accounted, st.submitted,
        "accounting identity: completed + rejected + cancelled + expired + replica_lost \
         == submitted (stats {st:?})"
    );
}

#[test]
fn supervised_serve_under_injected_panics_matches_fault_free() {
    let model = quantized_model(77);
    let kv_legs = [
        // one max_seq page, no sharing = the legacy contiguous layout
        PagedKvOpts {
            page_size: 48,
            prefix_cache: false,
            page_budget: None,
        },
        PagedKvOpts {
            page_size: 8,
            prefix_cache: true,
            page_budget: None,
        },
    ];
    let mut cell = 0u64;
    for threads in [1usize, 2] {
        for kv in kv_legs {
            for spec in [None, Some(SpecDecodeOpts::default())] {
                // alternating seed parity: odd seeds add a forced
                // page-exhaustion fault on top of the 1–2 panics
                let seed = 0xC4A0_5000 + cell;
                cell += 1;
                let plan = FaultPlan::from_seed(seed, REPLICAS);
                assert!(!plan.is_empty(), "seeded plan always schedules faults");

                let (clean, clean_report) = run_serve(&model, threads, kv, spec, None);
                let (chaos, chaos_report) = run_serve(&model, threads, kv, spec, Some(plan));

                assert_eq!(clean_report.stats.replica_restarts, 0, "fault-free run never restarts");
                assert!(
                    chaos_report.stats.replica_restarts >= 1,
                    "threads={threads} kv={kv:?} spec={} seed={seed:#x}: \
                     the seeded panic must fire and restart a replica",
                    spec.is_some()
                );
                assert_eq!(chaos.len(), clean.len());
                for (a, b) in chaos.iter().zip(&clean) {
                    assert_eq!(
                        (a.id, a.sample, &a.tokens),
                        (b.id, b.sample, &b.tokens),
                        "threads={threads} kv={kv:?} spec={} seed={seed:#x}: \
                         replayed responses must be token-identical",
                        spec.is_some()
                    );
                }
                assert_identity(&clean_report);
                assert_identity(&chaos_report);
                if !kv.prefix_cache {
                    // with the prefix tree off, a drained server holds
                    // zero live pages — replica deaths included (a dead
                    // generation's pages die with its engine, and the
                    // folded snapshot keeps the live generation's gauge)
                    let live: usize = chaos_report.metrics.iter().map(|m| m.pages_in_use).sum();
                    assert_eq!(
                        live, 0,
                        "threads={threads} seed={seed:#x}: KV pages leaked across restarts"
                    );
                }
            }
        }
    }
}

#[test]
fn fault_plan_file_roundtrips_through_serve_schema() {
    // the exact JSON shape the CI chaos-smoke job writes
    let src = r#"{
        "schema": "ptqtp-fault-plan/1",
        "faults": [
            {"replica": 0, "step": 3, "kind": "panic"},
            {"replica": 1, "kind": "ckpt_io"}
        ]
    }"#;
    let plan = FaultPlan::parse(src).expect("CI plan shape parses");
    assert_eq!(plan.len(), 2);
    let model = quantized_model(78);
    let kv = PagedKvOpts {
        page_size: 8,
        prefix_cache: true,
        page_budget: None,
    };
    let (responses, report) = run_serve(&model, 1, kv, None, Some(plan));
    let (clean, _) = run_serve(&model, 1, kv, None, None);
    assert!(report.stats.replica_restarts >= 1);
    assert_eq!(responses.len(), clean.len());
    for (a, b) in responses.iter().zip(&clean) {
        assert_eq!(a.tokens, b.tokens, "req {}", a.id);
    }
    assert_identity(&report);
}

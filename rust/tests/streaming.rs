//! Streaming front-end integration tests: the per-token event stream
//! must be a faithful prefix view of the final [`Response`] under every
//! execution configuration — threads × SIMD × KV layout — and through
//! the threaded [`Server`] front-end, including `n > 1` fork streams
//! and the exported serve-metrics accounting identity.

use std::collections::HashMap;
use std::time::Duration;

use ptqtp::coordinator::batcher::BatchPolicy;
use ptqtp::coordinator::router::RoutePolicy;
use ptqtp::coordinator::{
    serve_metrics_json, PagedKvOpts, Request, Response, SamplingParams, ServeEngine,
    ServerBuilder, ServerEvent, SubmitOutcome,
};
use ptqtp::model::{ModelConfig, Transformer};
use ptqtp::quant::{self, QuantCtx};
use ptqtp::rng::Rng;
use ptqtp::serialize::Json;

fn quantized_model(seed: u64) -> Transformer {
    let mut cfg = ModelConfig::family("tiny").unwrap();
    cfg.vocab_size = 32;
    cfg.max_seq = 48;
    let mut rng = Rng::new(seed);
    let mut model = Transformer::random(cfg, &mut rng);
    // ragged group keeps the packed kernel tier in play
    model.quantize_with(
        quant::by_name("ptqtp", 10).unwrap().as_ref(),
        &QuantCtx::default(),
    );
    model
}

/// Per-`(id, sample)` token streams accumulated from `Token` events.
type Streams = HashMap<(u64, usize), Vec<u32>>;

/// Drive an engine to completion through `step_events`, checking the
/// stream invariants along the way. Returns per-`(id, sample)` token
/// streams and the final responses.
fn drain_events(e: &mut ServeEngine) -> (Streams, Vec<Response>) {
    let mut streams: Streams = HashMap::new();
    let mut done = Vec::new();
    let mut events = Vec::new();
    let mut guard = 0usize;
    while e.pending() > 0 {
        e.step_events(&mut events);
        for ev in events.drain(..) {
            match ev {
                ServerEvent::Token { id, sample, token, index } => {
                    let s = streams.entry((id, sample)).or_default();
                    assert_eq!(index, s.len(), "req {id}/{sample}: token index gap");
                    s.push(token);
                }
                ServerEvent::Done(r) => done.push(r),
                ServerEvent::ReplicaDown { .. } => {
                    panic!("bare engine never emits ReplicaDown")
                }
            }
        }
        guard += 1;
        assert!(guard < 100_000, "engine livelock");
    }
    (streams, done)
}

/// Tentpole acceptance: concatenating a request's `Token` events equals
/// `Response.tokens` exactly, for every cell of the execution matrix —
/// threads {1, 2} × SIMD {off, on} × KV {contiguous, paged} — and the
/// token streams themselves are bit-identical across all cells.
#[test]
fn stream_matches_final_across_threads_simd_kv() {
    let model = quantized_model(61);
    let contiguous = PagedKvOpts {
        page_size: 48, // one max_seq page = the legacy contiguous layout
        prefix_cache: false,
        page_budget: None,
    };
    let paged = PagedKvOpts {
        page_size: 8,
        prefix_cache: true,
        page_budget: None,
    };

    let run = |threads: usize, simd: bool, kv: PagedKvOpts| {
        let mut e = ServeEngine::with_opts(model.clone(), BatchPolicy::default(), threads, kv);
        e.set_simd(simd);
        for i in 0..5u64 {
            let prompt: Vec<u32> = (0..=(i % 3) + 2).map(|j| (j as u32 * 5 + i as u32) % 32).collect();
            let mut params = SamplingParams::greedy(5).with_stop(None);
            if i % 2 == 1 {
                params = params.with_temperature(0.7, 33 + i);
            }
            e.submit(Request::new(i, prompt, params));
        }
        let (streams, mut done) = drain_events(&mut e);
        assert_eq!(done.len(), 5, "threads={threads} simd={simd}: lost responses");
        for r in &done {
            assert_eq!(
                streams.get(&(r.id, r.sample)).map(Vec::as_slice),
                Some(r.tokens.as_slice()),
                "threads={threads} simd={simd}: stream for req {} diverged from final tokens",
                r.id
            );
        }
        done.sort_by_key(|r| r.id);
        done.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
    };

    let base = run(1, false, contiguous);
    for &threads in &[1usize, 2] {
        for &simd in &[false, true] {
            for (kv_name, kv) in [("contiguous", contiguous), ("paged", paged)] {
                assert_eq!(
                    run(threads, simd, kv),
                    base,
                    "threads={threads} simd={simd} kv={kv_name} diverged from scalar baseline"
                );
            }
        }
    }
}

/// ISSUE 9 satellite: speculative decoding must be invisible on the
/// wire. With `--spec-decode on`, an accepted draft burst emits one
/// `Token` event per committed token with contiguous `index`es
/// (checked inside [`drain_events`]), the concatenated stream equals
/// the final response, and every cell of the execution matrix —
/// threads {1, 2} × SIMD {off, on} × KV {contiguous, paged} — produces
/// token streams bit-identical to the spec-off scalar baseline, for
/// greedy and temperature sequences alike.
#[test]
fn speculative_stream_matches_plain_across_matrix() {
    use ptqtp::coordinator::SpecDecodeOpts;
    // 12-token vocab so a bigram-complete prompt (every `[x, t]` pair,
    // 25 tokens) fits max_seq with decode room: the drafter provably
    // fires at the first decode planning of each greedy request, so
    // the speculation-activity assert below cannot flake
    let mut cfg = ModelConfig::family("tiny").unwrap();
    cfg.vocab_size = 12;
    cfg.max_seq = 48;
    let mut rng = Rng::new(64);
    let mut model = Transformer::random(cfg, &mut rng);
    model.quantize_with(
        quant::by_name("ptqtp", 10).unwrap().as_ref(),
        &QuantCtx::default(),
    );
    let bigram_complete = |x: u32| -> Vec<u32> {
        let mut p = Vec::new();
        for t in 0..12u32 {
            p.push(x);
            p.push(t);
        }
        p.push(x);
        p
    };
    let contiguous = PagedKvOpts {
        page_size: 48,
        prefix_cache: false,
        page_budget: None,
    };
    let paged = PagedKvOpts {
        page_size: 8,
        prefix_cache: true,
        page_budget: None,
    };

    let run = |threads: usize, simd: bool, kv: PagedKvOpts, spec: Option<SpecDecodeOpts>| {
        let mut e = ServeEngine::with_opts(model.clone(), BatchPolicy::default(), threads, kv);
        e.set_simd(simd);
        e.set_spec_decode(spec);
        for i in 0..4u64 {
            let (prompt, mut params) = if i % 2 == 0 {
                (bigram_complete(3 + i as u32), SamplingParams::greedy(5).with_stop(None))
            } else {
                let p: Vec<u32> = (0..4).map(|j| (j * 5 + i as u32) % 12).collect();
                (p, SamplingParams::greedy(5).with_stop(None))
            };
            if i == 3 {
                params = params.with_temperature(0.7, 33 + i);
            }
            e.submit(Request::new(i, prompt, params));
        }
        let (streams, mut done) = drain_events(&mut e);
        assert_eq!(done.len(), 4, "threads={threads} simd={simd}: lost responses");
        for r in &done {
            assert_eq!(
                streams.get(&(r.id, r.sample)).map(Vec::as_slice),
                Some(r.tokens.as_slice()),
                "threads={threads} simd={simd} spec={}: stream for req {} diverged from final",
                spec.is_some(),
                r.id
            );
        }
        if spec.is_some() {
            assert!(
                e.metrics.spec_drafted > 0,
                "threads={threads} simd={simd}: speculation never fired"
            );
        } else {
            assert_eq!(e.metrics.spec_drafted, 0);
        }
        done.sort_by_key(|r| r.id);
        done.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
    };

    let base = run(1, false, contiguous, None);
    let spec = Some(SpecDecodeOpts::default());
    for &threads in &[1usize, 2] {
        for &simd in &[false, true] {
            for (kv_name, kv) in [("contiguous", contiguous), ("paged", paged)] {
                assert_eq!(
                    run(threads, simd, kv, spec),
                    base,
                    "spec-on threads={threads} simd={simd} kv={kv_name} diverged from plain baseline"
                );
            }
        }
    }
}

/// `n > 1` fork streams: one request fans out into `n` interleaved
/// token streams distinguished by the `sample` tag; each stream must
/// equal its own final response, and at temperature > 0 the per-sample
/// seeds make the streams diverge.
#[test]
fn fork_streams_carry_sample_tags() {
    let model = quantized_model(62);
    let mut e = ServeEngine::new(model, BatchPolicy::default());
    e.submit(Request::new(
        7,
        vec![3, 9, 4, 1],
        SamplingParams::greedy(6)
            .with_stop(None)
            .with_temperature(0.9, 123)
            .with_n(3),
    ));
    let (streams, done) = drain_events(&mut e);
    assert_eq!(done.len(), 3, "n=3 produces three responses");
    let mut samples: Vec<usize> = done.iter().map(|r| r.sample).collect();
    samples.sort_unstable();
    assert_eq!(samples, vec![0, 1, 2]);
    assert!(done.iter().all(|r| r.id == 7), "forks share the request id");
    for r in &done {
        assert_eq!(
            streams.get(&(r.id, r.sample)).map(Vec::as_slice),
            Some(r.tokens.as_slice()),
            "sample {} stream diverged from its response",
            r.sample
        );
    }
    let first = &done[0].tokens;
    assert!(
        done.iter().any(|r| &r.tokens != first),
        "temperature sampling with per-sample seeds should diverge: {done:?}"
    );
}

/// The exported serve-metrics artifact round-trips through the JSON
/// parser and satisfies the request-granular accounting identity
/// `completed + rejected + cancelled + expired == submitted` after a
/// graceful drain.
#[test]
fn serve_metrics_artifact_identity_through_server() {
    let model = quantized_model(63);
    let mut server = ServerBuilder::new()
        .replicas(2)
        .route(RoutePolicy::RoundRobin)
        .threads(1)
        .start(model);
    let t0 = std::time::Instant::now();
    let mut accepted = 0usize;
    for i in 0..8u64 {
        let prompt: Vec<u32> = (0..3).map(|j| (j * 7 + i as u32) % 32).collect();
        match server.submit(prompt, SamplingParams::greedy(4).with_stop(None), 0) {
            SubmitOutcome::Accepted(_) => accepted += 1,
            SubmitOutcome::Rejected(e) => panic!("default intake limit rejected: {e}"),
        }
    }
    let responses = server.wait_for(accepted, Duration::from_secs(60));
    assert_eq!(responses.len(), accepted);
    let wall = t0.elapsed();
    let stats = server.stats.clone();
    let report = server.drain();

    let artifact = serve_metrics_json(&stats, &report.metrics, wall);
    let parsed = Json::parse(&artifact.pretty()).expect("artifact parses back");
    assert_eq!(parsed.req_str("schema").unwrap(), "ptqtp-serve-metrics/2");
    let f = |k: &str| parsed.req_f64(k).unwrap();
    assert_eq!(
        f("completed") + f("rejected") + f("cancelled") + f("expired"),
        f("submitted"),
        "accounting identity violated: {parsed:?}"
    );
    assert_eq!(f("submitted") as usize, 8);
    assert_eq!(f("completed") as usize, 8);
    let per_replica = parsed.get("per_replica").and_then(Json::as_arr).expect("per_replica array");
    assert_eq!(per_replica.len(), 2, "one per-replica snapshot each");
    // latency blocks exist and carry the samples we served
    let ttft = parsed.get("ttft_ms").expect("ttft block");
    assert!(ttft.req_f64("p50_ms").unwrap() >= 0.0);
}

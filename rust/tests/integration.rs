//! Cross-module integration tests: quantize → model → eval → serve,
//! plus PJRT artifact execution when `make artifacts` has run.

use ptqtp::coordinator::{Request, SamplingParams, ServeEngine};
use ptqtp::data::{CorpusGen, TaskSuite, Tokenizer};
use ptqtp::eval::{eval_suite, perplexity};
use ptqtp::model::{ModelConfig, Transformer};
use ptqtp::quant::{self, QuantCtx, Quantizer};
use ptqtp::rng::Rng;

fn test_model(vocab: usize, seed: u64) -> Transformer {
    let mut cfg = ModelConfig::family("tiny").unwrap();
    cfg.vocab_size = vocab;
    cfg.max_seq = 64;
    let mut rng = Rng::new(seed);
    Transformer::random(cfg, &mut rng)
}

#[test]
fn quantize_then_eval_pipeline() {
    let tok = Tokenizer::from_text("abcdefghij 0123456789+-*=?.:QA");
    let model = test_model(tok.vocab_size(), 1);
    let text = CorpusGen::new(5).domain_text(ptqtp::data::CorpusDomain::WikiSyn, 20);
    let ppl_fp = perplexity(&model, &tok, &text);

    for method in ["ptqtp", "rtn4", "billm"] {
        let q = quant::by_name(method, 64).unwrap();
        let mut m = model.clone();
        m.quantize_with(q.as_ref(), &QuantCtx::default());
        let ppl_q = perplexity(&m, &tok, &text);
        assert!(ppl_q.is_finite(), "{method} ppl finite");
        // random-weight models have near-uniform predictions; quantized
        // ppl must stay in a sane band around the fp ppl
        assert!(
            ppl_q < ppl_fp * 50.0,
            "{method}: ppl exploded {ppl_q} vs {ppl_fp}"
        );
    }
}

#[test]
fn ptqtp_preserves_more_than_binary_on_trained_like_weights() {
    // reconstruction ordering on every layer of a model
    let model = test_model(32, 2);
    let mut err_ptqtp = 0.0f64;
    let mut err_billm = 0.0f64;
    let ptq = quant::by_name("ptqtp", 128).unwrap();
    let bil = quant::by_name("billm", 128).unwrap();
    for (_, lin) in model.linear_layers() {
        let w = lin.dense_weights();
        err_ptqtp += w.sq_err(&ptq.quantize(&w, &QuantCtx::default()).w_hat);
        err_billm += w.sq_err(&bil.quantize(&w, &QuantCtx::default()).w_hat);
    }
    assert!(err_ptqtp < err_billm, "{err_ptqtp} !< {err_billm}");
}

#[test]
fn serve_quantized_model_end_to_end() {
    let tok = Tokenizer::from_text("abcdefgh 0123456789+-*=?.:QA");
    let mut model = test_model(tok.vocab_size(), 3);
    model.quantize_with(
        quant::by_name("ptqtp", 128).unwrap().as_ref(),
        &QuantCtx::default(),
    );
    let mut engine = ServeEngine::new(model, Default::default());
    for i in 0..6 {
        engine.submit(Request::new(
            i,
            tok.encode("Q:2+2=? A:"),
            SamplingParams::greedy(4).with_stop(None),
        ));
    }
    let out = engine.run_to_completion();
    assert_eq!(out.len(), 6);
    assert!(out.iter().all(|r| r.tokens.len() == 4));
}

#[test]
fn task_suite_eval_runs_on_quantized_model() {
    let tok = Tokenizer::from_text("abcdefghijklmnopqrstuvwxyz 0123456789+-*=?.:!>()[]{}QA");
    let mut model = test_model(tok.vocab_size(), 4);
    model.quantize_with(
        quant::by_name("ptqtp", 128).unwrap().as_ref(),
        &QuantCtx::default(),
    );
    let suite = TaskSuite::standard(9, 5, 8, 5);
    let s = eval_suite(&model, &tok, &suite);
    assert!(s.math_acc >= 0.0 && s.cloze_acc <= 1.0);
}

#[test]
fn checkpoint_roundtrip_preserves_quantized_eval() {
    let tok = Tokenizer::from_text("abcdef 0123456789+-*=?.:QA");
    let mut model = test_model(tok.vocab_size(), 5);
    model.quantize_with(
        quant::by_name("ptqtp", 128).unwrap().as_ref(),
        &QuantCtx::default(),
    );
    let dir = std::env::temp_dir().join("ptqtp_it_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("q.ptw");
    model.save(&path).unwrap();
    let loaded = Transformer::load(&path).unwrap();
    // ternary backends persist as packed planes (PTW2): logits are
    // bit-exact after the roundtrip, not merely close
    let mut c1 = model.new_cache();
    let mut c2 = loaded.new_cache();
    assert_eq!(model.decode_step(1, &mut c1), loaded.decode_step(1, &mut c2));
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(dir.join("q.json")).ok();
    std::fs::remove_file(dir.join("q.manifest.json")).ok();
}

// ---------------------------------------------------------------------
// Fused-batch engine parity (the tentpole guarantee)
// ---------------------------------------------------------------------

/// Random mixed-length workloads through `ServeEngine` with
/// `max_running ∈ {1, N}` must generate identical tokens per request:
/// the fused batch path is bit-identical per row to sequential
/// decoding. Covers dense and ternary backends, aligned (G=128) and
/// ragged (G % 4 != 0) group packing, greedy and seeded temperature
/// sampling, and prefill budgets small enough to split prompts across
/// steps.
#[test]
fn fused_batch_matches_sequential_property() {
    use ptqtp::coordinator::batcher::BatchPolicy;
    use ptqtp::proptest::{check_seeded, prop_assert, Gen};

    check_seeded(0xBA7C4ED, 10, |g: &mut Gen| {
        let vocab = 32usize;
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = vocab;
        cfg.max_seq = 48;
        let mut rng = Rng::new(g.rng.next_u64());
        let mut model = Transformer::random(cfg, &mut rng);
        // 0 = dense fp32, 1 = ptqtp aligned G, 2 = ptqtp ragged G%4!=0
        match g.usize_in(0, 2) {
            1 => model.quantize_with(
                quant::by_name("ptqtp", 128).unwrap().as_ref(),
                &QuantCtx::default(),
            ),
            2 => model.quantize_with(
                quant::by_name("ptqtp", *g.pick(&[6usize, 10, 14])).unwrap().as_ref(),
                &QuantCtx::default(),
            ),
            _ => {}
        }

        let n_req = g.usize_in(1, 6);
        let reqs: Vec<(Vec<u32>, usize, f32, u64)> = (0..n_req)
            .map(|_| {
                let plen = g.usize_in(1, 9);
                let prompt: Vec<u32> = (0..plen).map(|_| g.rng.below(vocab) as u32).collect();
                let max_new = g.usize_in(1, 6);
                let temperature = *g.pick(&[0.0f32, 0.8]);
                (prompt, max_new, temperature, g.rng.next_u64())
            })
            .collect();

        let prefill_token_budget = *g.pick(&[3usize, 8, 64]);
        let max_running = *g.pick(&[2usize, 4, 8]);
        let run = |max_running: usize| {
            let mut e = ServeEngine::new(
                model.clone(),
                BatchPolicy {
                    max_running,
                    prefill_token_budget,
                    fcfs_prefill: true,
                },
            );
            for (i, (prompt, max_new, temperature, seed)) in reqs.iter().enumerate() {
                e.submit(Request::new(
                    i as u64,
                    prompt.clone(),
                    SamplingParams::greedy(*max_new)
                        .with_stop(None)
                        .with_temperature(*temperature, *seed),
                ));
            }
            let mut out = e.run_to_completion();
            out.sort_by_key(|r| r.id);
            out
        };

        let batched = run(max_running);
        let sequential = run(1);
        for (a, b) in batched.iter().zip(&sequential) {
            if a.tokens != b.tokens {
                return Err(format!(
                    "req {} diverged: batched {:?} vs sequential {:?} (max_running={max_running}, budget={prefill_token_budget})",
                    a.id, a.tokens, b.tokens
                ));
            }
        }
        prop_assert(batched.len() == sequential.len(), "response counts differ")
    });
}

// ---------------------------------------------------------------------
// Row-parallel execution parity (quantize → serve, any thread count)
// ---------------------------------------------------------------------

/// The whole pipeline under `--threads`: matrix-parallel quantization
/// must produce a bit-identical model, and a threaded engine must then
/// serve token-for-token what the sequential engine serves. Ragged
/// G = 10 keeps the packed tier in play; the aligned pass exercises the
/// activation-indexed LUT tier.
#[test]
fn threaded_pipeline_matches_sequential_end_to_end() {
    let tok = Tokenizer::from_text("abcdefgh 0123456789+-*=?.:QA");
    for group in [128usize, 10] {
        let base = test_model(tok.vocab_size(), 7);
        let q = quant::by_name("ptqtp", group).unwrap();

        let mut m_seq = base.clone();
        m_seq.quantize_with(q.as_ref(), &QuantCtx::default());
        let mut m_par = base.clone();
        m_par.quantize_with(q.as_ref(), &QuantCtx::with_threads(4));

        // quantized weights identical regardless of quantization threads
        let mut c1 = m_seq.new_cache();
        let mut c2 = m_par.new_cache();
        assert_eq!(
            m_seq.decode_step(1, &mut c1),
            m_par.decode_step(1, &mut c2),
            "G={group}: threaded quantization changed the model"
        );

        let serve = |model: &Transformer, threads: usize| {
            let mut e = ServeEngine::with_threads(model.clone(), Default::default(), threads);
            for i in 0..4 {
                e.submit(Request::new(
                    i,
                    tok.encode("Q:2+2=? A:"),
                    SamplingParams::greedy(5).with_stop(None),
                ));
            }
            let mut out = e.run_to_completion();
            out.sort_by_key(|r| r.id);
            out
        };
        let seq = serve(&m_seq, 1);
        let par = serve(&m_par, 4);
        assert_eq!(seq.len(), par.len());
        for (a, b) in par.iter().zip(&seq) {
            assert_eq!(a.tokens, b.tokens, "G={group} req {}", a.id);
        }
    }
}

// ---------------------------------------------------------------------
// SIMD kernel tier (row-vectorized, bit-identical to scalar)
// ---------------------------------------------------------------------

/// The SIMD × threads × layout matrix: for aligned, ragged-group,
/// ragged-cols, zero-plane, and interleaved-tail layouts, across
/// `threads ∈ {1, 2}`, interleave lane widths {none, 4, detected}, and
/// `simd on|off`, the model-layer dispatcher must produce output
/// `==`-bitwise-identical to the scalar per-row reference
/// (`forward_vec` → `gemv_packed`). No tolerance anywhere.
#[test]
fn simd_threads_layout_matrix_bit_identical() {
    use ptqtp::model::linear::Backend;
    use ptqtp::model::QuantLinear;
    use ptqtp::proptest::{check_seeded, prop_assert, Gen};
    use ptqtp::tensor::Matrix;
    use ptqtp::ternary::gemm::GemmScratch;
    use ptqtp::ternary::simd;
    use ptqtp::ternary::TernaryLinear;
    use ptqtp::threads::Pool;

    check_seeded(0x51AD_D00D, 30, |g: &mut Gen| {
        let rows = g.usize_in(1, 140).max(1);
        // 0: aligned (G % 4 == 0, cols % 4 == 0, interleaved-tail rows)
        // 1: ragged group (G % 4 != 0) — no interleave, scalar fallback
        // 2: ragged cols (cols % 4 != 0) — no interleave either
        let (cols, group) = match g.usize_in(0, 2) {
            0 => (4 * g.usize_in(1, 20).max(1), 4 * *g.pick(&[1usize, 2, 8, 32])),
            1 => (4 * g.usize_in(1, 20).max(1), *g.pick(&[6usize, 10, 14])),
            _ => (g.usize_in(1, 70).max(1), *g.pick(&[4usize, 10])),
        };
        let mut lin = TernaryLinear::new(rows, cols, group);
        let zero_planes = g.usize_in(0, 3) == 0;
        if !zero_planes {
            for t in lin.t1.trits.iter_mut().chain(lin.t2.trits.iter_mut()) {
                *t = g.rng.below(3) as i8 - 1;
            }
            for a in lin.alpha1.iter_mut().chain(lin.alpha2.iter_mut()) {
                *a = g.rng.normal() * 0.2;
            }
        }
        let packed = lin.to_packed();
        let m = g.usize_in(1, 12).max(1);
        let x = Matrix::from_vec(m, cols, g.vec_normal(m * cols, 1.0));

        let mut lanes_cases: Vec<Option<usize>> = vec![None, Some(4)];
        if simd::detected_lanes() != 4 {
            lanes_cases.push(Some(simd::detected_lanes()));
        }
        for lanes in lanes_cases {
            let mut ql = QuantLinear::from_packed(packed.clone());
            let Backend::Ternary(t) = &mut ql.backend else {
                return Err("expected ternary backend".to_string());
            };
            t.set_interleave_lanes(lanes);
            // scalar per-row reference
            let mut refs: Vec<Vec<f32>> = Vec::with_capacity(m);
            for r in 0..m {
                let mut yv = vec![0.0f32; rows];
                ql.forward_vec(x.row(r), &mut yv);
                refs.push(yv);
            }
            for threads in [1usize, 2] {
                for simd_on in [false, true] {
                    let mut scratch = GemmScratch::new();
                    scratch.pool = Pool::new(threads);
                    scratch.simd = simd_on;
                    let mut y = Matrix::zeros(m, rows);
                    ql.forward_rows_into(&x, &mut y, &mut scratch);
                    for (r, want) in refs.iter().enumerate() {
                        if y.row(r) != want.as_slice() {
                            return Err(format!(
                                "row {r} drifted (rows={rows} cols={cols} G={group} m={m} \
                                 lanes={lanes:?} threads={threads} simd={simd_on} zero={zero_planes})"
                            ));
                        }
                    }
                    // single-row (decode) dispatch path
                    let x1 = Matrix::from_vec(1, cols, x.row(0).to_vec());
                    let mut y1 = Matrix::zeros(1, rows);
                    ql.forward_rows_into(&x1, &mut y1, &mut scratch);
                    if y1.row(0) != refs[0].as_slice() {
                        return Err(format!(
                            "single-row drifted (rows={rows} cols={cols} G={group} \
                             lanes={lanes:?} threads={threads} simd={simd_on})"
                        ));
                    }
                }
            }
        }
        prop_assert(true, "")
    });
}

/// `ServeEngine::step` with SIMD forced on vs off (and threads 1 vs 2)
/// must serve token-for-token identical output — the `--simd off`
/// escape hatch is exact, and SIMD×threads composes bit-identically
/// through the whole fused serving path.
#[test]
fn engine_simd_on_off_token_for_token() {
    use ptqtp::model::linear::Backend;
    use ptqtp::ternary::simd;

    let mut cfg = ModelConfig::family("tiny").unwrap();
    cfg.vocab_size = 32;
    cfg.max_seq = 48;
    let mut rng = Rng::new(61);
    let mut model = Transformer::random(cfg, &mut rng);
    // aligned G so the LUT + SIMD tiers genuinely engage
    model.quantize_with(
        quant::by_name("ptqtp", 128).unwrap().as_ref(),
        &QuantCtx::default(),
    );
    // force-build the interleaved layouts so set_simd(true) really runs
    // the SIMD kernels even when the process-wide mode is `off` (the
    // CI simd-off leg must still exercise this parity, not vacuously
    // compare scalar against scalar)
    for b in model.blocks.iter_mut() {
        for l in [
            &mut b.attn.wq,
            &mut b.attn.wk,
            &mut b.attn.wv,
            &mut b.attn.wo,
            &mut b.w_gate,
            &mut b.w_up,
            &mut b.w_down,
        ] {
            if let Backend::Ternary(t) = &mut l.backend {
                t.set_interleave_lanes(Some(simd::detected_lanes()));
            }
        }
    }
    let run = |simd_on: bool, threads: usize| {
        let mut e = ServeEngine::with_threads(model.clone(), Default::default(), threads);
        e.set_simd(simd_on);
        for i in 0..5u64 {
            let mut params = SamplingParams::greedy(5).with_stop(None);
            if i % 2 == 1 {
                params = params.with_temperature(0.7, 21 + i);
            }
            let prompt: Vec<u32> = (0..=(i % 3) + 1).map(|j| (j as u32 * 5 + i as u32) % 32).collect();
            e.submit(Request::new(i, prompt, params));
        }
        let mut out = e.run_to_completion();
        out.sort_by_key(|r| r.id);
        out.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
    };
    let base = run(false, 1);
    for threads in [1usize, 2] {
        for simd_on in [false, true] {
            assert_eq!(
                run(simd_on, threads),
                base,
                "simd={simd_on} threads={threads} diverged from scalar sequential"
            );
        }
    }
}

/// Head-major attention tier parity: for every GQA shape (equal,
/// grouped, odd-ratio), ragged and chunk-exact head dims, horizons
/// with `t % lanes != 0` tails, lane widths {scalar, portable-4,
/// 8-wide, detected} and thread counts {1, 2}, the tiered
/// `attend_rows` must reproduce the scalar `attend_one` **bitwise** —
/// the attention mirror of the ternary SIMD parity matrix.
#[test]
fn attention_simd_threads_parity() {
    use ptqtp::model::attention::{Attention, AttnScratch};
    use ptqtp::model::{KvCache, QuantLinear};
    use ptqtp::tensor::Matrix;
    use ptqtp::threads::Pool;

    let mut rng = Rng::new(0xA77E);
    let mk_cache = |kv_heads: usize, hd: usize, t: usize, rng: &mut Rng| {
        let mut c = KvCache::new(1, kv_heads, hd, t.max(1));
        let kv_dim = kv_heads * hd;
        for _ in 0..t {
            let k: Vec<f32> = (0..kv_dim).map(|_| rng.normal()).collect();
            let v: Vec<f32> = (0..kv_dim).map(|_| rng.normal()).collect();
            c.append(0, &k, &v);
            c.commit();
        }
        c
    };
    for &(heads, kv_heads) in &[(8usize, 8usize), (8, 2), (6, 3)] {
        // hd=10: ragged head-dim tail for both the 4-chunk score fold
        // and the 8-wide V-sum; hd=64: chunk-exact
        for &hd in &[10usize, 64] {
            let q_dim = heads * hd;
            // projections are not exercised by the attend stage
            let attn = Attention {
                wq: QuantLinear::dense(Matrix::zeros(1, 1)),
                wk: QuantLinear::dense(Matrix::zeros(1, 1)),
                wv: QuantLinear::dense(Matrix::zeros(1, 1)),
                wo: QuantLinear::dense(Matrix::zeros(1, 1)),
                n_heads: heads,
                n_kv_heads: kv_heads,
                head_dim: hd,
            };
            for &t in &[1usize, 3, 64, 257] {
                // two rows with different horizons over two caches
                let t2 = t.div_ceil(2);
                let mut c0 = mk_cache(kv_heads, hd, t, &mut rng);
                let mut c1 = mk_cache(kv_heads, hd, t2, &mut rng);
                let q = Matrix::randn(2, q_dim, 1.0, &mut rng);
                let ts = [t, t2];
                let cof = [0usize, 1];
                let mut scores = Vec::new();
                let mut expect = Matrix::zeros(2, q_dim);
                attn.attend_one(q.row(0), &c0, 0, t, &mut scores, expect.row_mut(0));
                attn.attend_one(q.row(1), &c1, 0, t2, &mut scores, expect.row_mut(1));
                // None = detected width; Some(8) exercises the portable
                // 8-lane block on machines without AVX2
                for lanes in [Some(1usize), Some(4), Some(8), None] {
                    for threads in [1usize, 2] {
                        let mut scratch = AttnScratch::default();
                        scratch.set_simd(true);
                        scratch.set_lanes(lanes);
                        scratch.set_pool(Pool::new(threads));
                        let mut out = Matrix::zeros(2, q_dim);
                        let refs: Vec<&mut KvCache> = vec![&mut c0, &mut c1];
                        attn.attend_rows(&q, &ts, &cof, &refs, 0, &mut scratch, &mut out);
                        assert_eq!(
                            out.data, expect.data,
                            "heads={heads}/{kv_heads} hd={hd} t={t} lanes={lanes:?} threads={threads}"
                        );
                    }
                }
            }
        }
    }
}

/// Long-context serving with the attention SIMD tier on vs off (and
/// threads 1 vs 2) must be token-for-token identical through
/// `ServeEngine::step` — prompts long enough that the attend stage
/// dominates and its SIMD blocks + scalar tails + head-parallel spans
/// all genuinely run.
#[test]
fn engine_attention_simd_long_context_token_for_token() {
    let mut cfg = ModelConfig::family("tiny").unwrap();
    cfg.vocab_size = 32;
    cfg.max_seq = 288;
    let mut rng = Rng::new(71);
    let mut model = Transformer::random(cfg, &mut rng);
    model.quantize_with(
        quant::by_name("ptqtp", 128).unwrap().as_ref(),
        &QuantCtx::default(),
    );
    let run = |simd_on: bool, threads: usize| {
        let mut e = ServeEngine::with_threads(model.clone(), Default::default(), threads);
        e.set_simd(simd_on);
        for i in 0..3u64 {
            let prompt: Vec<u32> = (0..200 + i as u32 * 23)
                .map(|j| (j * 7 + 3 + i as u32) % 32)
                .collect();
            let mut params = SamplingParams::greedy(6).with_stop(None);
            if i == 1 {
                params = params.with_temperature(0.6, 91);
            }
            e.submit(Request::new(i, prompt, params));
        }
        let mut out = e.run_to_completion();
        out.sort_by_key(|r| r.id);
        out.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
    };
    let base = run(false, 1);
    assert!(base.iter().all(|t| t.len() == 6), "all requests generated");
    for threads in [1usize, 2] {
        for simd_on in [false, true] {
            assert_eq!(
                run(simd_on, threads),
                base,
                "attention simd={simd_on} threads={threads} diverged at long context"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Packed checkpoints (PTW2): quantize once, serve many
// ---------------------------------------------------------------------

/// Save/load of packed trit-plane checkpoints must be lossless at the
/// logits level — exact bit equality, not tolerance — across aligned
/// (G=128) and ragged (G % 4 != 0) group packing, zero-plane rows, and
/// tied vs untied LM heads.
#[test]
fn packed_checkpoint_roundtrip_property() {
    use ptqtp::model::linear::Backend;
    use ptqtp::model::QuantLinear;
    use ptqtp::proptest::{check_seeded, prop_assert, Gen};
    use ptqtp::tensor::Matrix;

    let dir = std::env::temp_dir().join("ptqtp_it_packed_rt");
    std::fs::create_dir_all(&dir).unwrap();
    check_seeded(0x9A5BED, 6, |g: &mut Gen| {
        let vocab = 32usize;
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = vocab;
        cfg.max_seq = 48;
        let untied = g.usize_in(0, 1) == 1;
        cfg.tied_embeddings = !untied;
        let mut rng = Rng::new(g.rng.next_u64());
        let mut model = Transformer::random(cfg, &mut rng);
        if untied {
            model.lm_head = Some(QuantLinear::dense(Matrix::randn(
                vocab,
                model.config.d_model,
                0.05,
                &mut rng,
            )));
        }
        let group = *g.pick(&[128usize, 10, 6]);
        model.quantize_with(
            quant::by_name("ptqtp", group).unwrap().as_ref(),
            &QuantCtx::default(),
        );
        if g.usize_in(0, 1) == 1 {
            // force a fully-zero row (planes AND scales) — the packed
            // format must carry it, not canonicalize it away
            let Backend::Ternary(t) = &mut model.blocks[0].w_gate.backend else {
                return Err("expected ternary backend".to_string());
            };
            let stride = t.row_stride;
            t.p1[..stride].fill(0);
            t.p2[..stride].fill(0);
            let gpr = t.groups_per_row();
            t.alpha1[..gpr].fill(0.0);
            t.alpha2[..gpr].fill(0.0);
            // the SIMD interleave is a derived copy of the planes —
            // direct mutation requires a rebuild (documented contract)
            t.refresh_interleave();
        }

        let path = dir.join(format!("m{}.ptw", g.rng.next_u64() & 0xffff));
        model.save(&path).unwrap();
        let loaded = Transformer::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(path.with_extension("json")).ok();
        std::fs::remove_file(path.with_extension("manifest.json")).ok();

        if loaded.ternary_layers() != model.ternary_layers() {
            return Err("ternary backends lost in roundtrip".to_string());
        }
        let mut c1 = model.new_cache();
        let mut c2 = loaded.new_cache();
        for t in [1u32, 9, 4, 0] {
            let a = model.decode_step(t, &mut c1);
            let b = loaded.decode_step(t, &mut c2);
            if a != b {
                return Err(format!(
                    "logits diverged after roundtrip (G={group}, untied={untied})"
                ));
            }
        }
        prop_assert(true, "")
    });
}

/// The acceptance invariant: `quantize --out q.ptw` then serving from
/// `q.ptw` is **token-for-token identical** to quantizing in memory
/// and serving directly — greedy and seeded temperature, with
/// `threads > 1` engines and with `replicas > 1` servers.
#[test]
fn quantize_once_serve_many_bit_identical() {
    use ptqtp::coordinator::batcher::BatchPolicy;
    use ptqtp::coordinator::router::RoutePolicy;
    use ptqtp::coordinator::ServerBuilder;

    let mut cfg = ModelConfig::family("tiny").unwrap();
    cfg.vocab_size = 32;
    cfg.max_seq = 48;
    let mut rng = Rng::new(51);
    let mut model = Transformer::random(cfg, &mut rng);
    // ragged group keeps the packed kernel tier in play
    model.quantize_with(
        quant::by_name("ptqtp", 10).unwrap().as_ref(),
        &QuantCtx::default(),
    );

    let dir = std::env::temp_dir().join("ptqtp_it_serve_many");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("q.ptw");
    model.save(&path).unwrap();
    let loaded = Transformer::load(&path).unwrap();
    assert_eq!(
        loaded.ternary_layers(),
        model.ternary_layers(),
        "serve path must not need a quantization pass"
    );

    let reqs: Vec<(Vec<u32>, f32, u64)> = (0..6u64)
        .map(|i| {
            let prompt: Vec<u32> = (0..=(i % 4) as u32 + 1)
                .map(|j| (j * 7 + i as u32) % 32)
                .collect();
            let temperature = if i % 2 == 1 { 0.8 } else { 0.0 };
            (prompt, temperature, 31 + i)
        })
        .collect();
    let params = |temperature: f32, seed: u64| {
        SamplingParams::greedy(5)
            .with_stop(None)
            .with_temperature(temperature, seed)
    };

    // threads > 1 single engine
    let engine_tokens = |m: &Transformer, threads: usize| {
        let mut e = ServeEngine::with_threads(m.clone(), Default::default(), threads);
        for (i, (prompt, temp, seed)) in reqs.iter().enumerate() {
            e.submit(Request::new(i as u64, prompt.clone(), params(*temp, *seed)));
        }
        let mut out = e.run_to_completion();
        out.sort_by_key(|r| r.id);
        out.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
    };
    for threads in [1usize, 2] {
        assert_eq!(
            engine_tokens(&model, threads),
            engine_tokens(&loaded, threads),
            "threads={threads}: disk-loaded model diverged from in-memory quantization"
        );
    }

    // replicas > 1 server front-end (each replica clones the ONE
    // loaded model — no per-replica quantization)
    let server_tokens = |m: &Transformer| {
        let mut server = ServerBuilder::new()
            .replicas(2)
            .batch(BatchPolicy::default())
            .route(RoutePolicy::RoundRobin)
            .threads(2)
            .start(m.clone());
        let mut ids = Vec::new();
        for (prompt, temp, seed) in reqs.iter() {
            ids.push(
                server
                    .submit(prompt.clone(), params(*temp, *seed), 0)
                    .try_id()
                    .unwrap(),
            );
        }
        let mut out = server.wait_for(ids.len(), std::time::Duration::from_secs(60));
        server.shutdown();
        out.sort_by_key(|r| r.id);
        out.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
    };
    assert_eq!(
        server_tokens(&model),
        server_tokens(&loaded),
        "replicated serve diverged between in-memory and disk-loaded quantization"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The footprint acceptance: a ternary-quantized checkpoint serializes
/// to ≤ 1/8 of the same model's FP32 `.ptw` — whole file AND per
/// ternary layer (base-3 archival planes + lossless f32 scales).
#[test]
fn packed_checkpoint_disk_footprint_within_eighth() {
    use ptqtp::model::linear::Backend;
    use ptqtp::serialize::TensorFile;

    // "small": every linear is ≥ 128 columns, so the per-layer scale
    // overhead stays amortized (the bound genuinely needs that; a
    // 64-column tiny layer pays 8/64 B/weight in f32 scales alone)
    let mut cfg = ModelConfig::family("small").unwrap();
    cfg.vocab_size = 8;
    cfg.max_seq = 32;
    let mut rng = Rng::new(40);
    let model = Transformer::random(cfg, &mut rng);
    let dir = std::env::temp_dir().join("ptqtp_it_footprint");
    std::fs::create_dir_all(&dir).unwrap();
    let fp_path = dir.join("fp.ptw");
    model.save(&fp_path).unwrap();

    let mut q = model.clone();
    q.quantize_with(
        quant::by_name("ptqtp", 128).unwrap().as_ref(),
        &QuantCtx::default(),
    );
    let q_path = dir.join("q.ptw");
    q.save(&q_path).unwrap();

    let fp_bytes = std::fs::metadata(&fp_path).unwrap().len();
    let q_bytes = std::fs::metadata(&q_path).unwrap().len();
    assert!(
        q_bytes * 8 <= fp_bytes,
        "whole checkpoint: {q_bytes} * 8 > {fp_bytes}"
    );

    for (name, l) in q.linear_layers() {
        let Backend::Ternary(t) = &l.backend else {
            panic!("{name}: expected ternary backend after quantization")
        };
        let mut tf_p = TensorFile::new();
        tf_p.insert_packed("w", t);
        let mut packed = Vec::new();
        tf_p.write_to(&mut packed).unwrap();
        let mut tf_d = TensorFile::new();
        tf_d.insert_matrix("w", &l.dense_weights());
        let mut dense = Vec::new();
        tf_d.write_to(&mut dense).unwrap();
        assert!(
            packed.len() * 8 <= dense.len(),
            "{name}: packed {} * 8 > fp32 {}",
            packed.len(),
            dense.len()
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// Paged KV + prefix cache parity (the ISSUE 6 tentpole guarantee)
// ---------------------------------------------------------------------

/// Random shared-prefix workloads served on the paged allocator with
/// copy-on-write pages and radix prefix adoption must be
/// token-for-token identical to a fresh contiguous cache — across
/// thread counts {1, 2} × SIMD on/off, with non-page-aligned prefix
/// forks, greedy and seeded temperature sampling, and a second warm
/// wave that adopts donated pages.
#[test]
fn paged_prefix_serving_matches_contiguous_property() {
    use ptqtp::coordinator::batcher::BatchPolicy;
    use ptqtp::coordinator::PagedKvOpts;
    use ptqtp::proptest::{check_seeded, prop_assert, Gen};

    check_seeded(0xFA6ED, 8, |g: &mut Gen| {
        let vocab = 32usize;
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = vocab;
        cfg.max_seq = 64;
        let mut rng = Rng::new(g.rng.next_u64());
        let mut model = Transformer::random(cfg, &mut rng);
        if g.usize_in(0, 1) == 1 {
            // ragged group: packed ternary tier in play
            model.quantize_with(
                quant::by_name("ptqtp", 10).unwrap().as_ref(),
                &QuantCtx::default(),
            );
        }

        // a few prefix families with non-page-aligned lengths (page
        // size 8 below), so forks land mid-page and exercise COW
        let n_families = g.usize_in(1, 3);
        let families: Vec<Vec<u32>> = (0..n_families)
            .map(|_| {
                let plen = g.usize_in(3, 21);
                (0..plen).map(|_| g.rng.below(vocab) as u32).collect()
            })
            .collect();
        let n_req = g.usize_in(2, 6);
        let reqs: Vec<(Vec<u32>, usize, f32, u64)> = (0..n_req)
            .map(|_| {
                let mut prompt = g.pick(&families).clone();
                let suffix = g.usize_in(0, 5);
                prompt.extend((0..suffix).map(|_| g.rng.below(vocab) as u32));
                (prompt, g.usize_in(1, 6), *g.pick(&[0.0f32, 0.8]), g.rng.next_u64())
            })
            .collect();
        let policy = BatchPolicy {
            max_running: *g.pick(&[2usize, 4]),
            prefill_token_budget: *g.pick(&[5usize, 64]),
            fcfs_prefill: true,
        };

        let serve = |kv: PagedKvOpts, threads: usize, simd: bool, waves: usize| {
            let mut e = ServeEngine::with_opts(model.clone(), policy, threads, kv);
            e.set_simd(simd);
            let mut all = Vec::new();
            for wave in 0..waves {
                for (i, (prompt, max_new, temperature, seed)) in reqs.iter().enumerate() {
                    e.submit(Request::new(
                        (wave * 100 + i) as u64,
                        prompt.clone(),
                        SamplingParams::greedy(*max_new)
                            .with_stop(None)
                            .with_temperature(*temperature, *seed),
                    ));
                }
                let mut out = e.run_to_completion();
                out.sort_by_key(|r| r.id);
                // waves are identical workloads ⇒ identical tokens; keep
                // only token vectors for comparison
                all.push(out.into_iter().map(|r| r.tokens).collect::<Vec<_>>());
            }
            all
        };

        let legacy = PagedKvOpts {
            page_size: 64,
            prefix_cache: false,
            page_budget: None,
        };
        let want = serve(legacy, 1, false, 1).remove(0);
        let paged = PagedKvOpts {
            page_size: 8,
            prefix_cache: true,
            page_budget: None,
        };
        for &threads in &[1usize, 2] {
            for &simd in &[false, true] {
                let waves = serve(paged, threads, simd, 2);
                for (w, wave_toks) in waves.iter().enumerate() {
                    if *wave_toks != want {
                        return Err(format!(
                            "paged serve diverged (threads={threads} simd={simd} wave={w}): \
                             {wave_toks:?} vs {want:?}"
                        ));
                    }
                }
            }
        }
        prop_assert(true, "unreachable")
    });
}

/// Forced preemption end-to-end: a page budget far below the workload's
/// working set preempts sequences mid-decode, and every request still
/// completes with output identical to an unconstrained serve — greedy
/// and seeded-temperature sampling replay bitwise through the
/// recompute.
#[test]
fn preempted_requests_complete_identically() {
    use ptqtp::coordinator::batcher::BatchPolicy;
    use ptqtp::coordinator::PagedKvOpts;

    let mut cfg = ModelConfig::family("tiny").unwrap();
    cfg.vocab_size = 32;
    cfg.max_seq = 64;
    let mut rng = Rng::new(44);
    let mut model = Transformer::random(cfg, &mut rng);
    model.quantize_with(
        quant::by_name("ptqtp", 10).unwrap().as_ref(),
        &QuantCtx::default(),
    );
    let policy = BatchPolicy {
        max_running: 3,
        prefill_token_budget: 16,
        fcfs_prefill: true,
    };
    let submit = |e: &mut ServeEngine| {
        for i in 0..6u64 {
            let prompt: Vec<u32> = (0..12).map(|j| 1 + ((3 * i as u32 + j) % 30)).collect();
            let mut params = SamplingParams::greedy(6).with_stop(None);
            if i % 2 == 1 {
                params = params.with_temperature(0.8, 17 + i);
            }
            e.submit(Request::new(i, prompt, params));
        }
    };
    let mut free = ServeEngine::with_opts(
        model.clone(),
        policy,
        1,
        PagedKvOpts {
            page_size: 8,
            prefix_cache: true,
            page_budget: None,
        },
    );
    submit(&mut free);
    let mut want = free.run_to_completion();
    want.sort_by_key(|r| r.id);
    assert_eq!(free.metrics.preemptions, 0, "unconstrained run never preempts");

    // 12-token prompts + 6 generated ⇒ 18 positions ⇒ 3 pages of 8;
    // 4 shared pages cannot hold 3 such sequences
    let mut tight = ServeEngine::with_opts(
        model,
        policy,
        1,
        PagedKvOpts {
            page_size: 8,
            prefix_cache: true,
            page_budget: Some(4),
        },
    );
    submit(&mut tight);
    let mut got = tight.run_to_completion();
    got.sort_by_key(|r| r.id);

    assert_eq!(got.len(), want.len());
    for (a, b) in got.iter().zip(&want) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "req {} changed under preemption", a.id);
        assert_eq!(a.finish, b.finish, "req {}", a.id);
    }
    assert!(tight.metrics.preemptions > 0, "tiny budget must force preemption");
}

// ---------------------------------------------------------------------
// PJRT integration (requires `make artifacts`)
// ---------------------------------------------------------------------

fn artifacts_ready() -> bool {
    if !cfg!(all(feature = "pjrt", xla_backend)) {
        eprintln!("skipping: built without the `pjrt` feature + `--cfg xla_backend`");
        return false;
    }
    std::path::Path::new("artifacts/manifest.json").exists()
}

#[test]
fn pjrt_artifacts_execute_and_match_reference() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = ptqtp::runtime::ArtifactManifest::load("artifacts").unwrap();
    let mut engine = ptqtp::runtime::PjrtEngine::cpu().unwrap();
    manifest.load_all(&mut engine).unwrap();

    // ternary_matmul: cross-check PJRT output against the Rust kernels
    let spec = manifest.get("ternary_matmul").unwrap();
    let (m, d) = (spec.inputs[0][0], spec.inputs[0][1]);
    let n = spec.inputs[1][0];
    let gpr = spec.inputs[3][1];
    let group = d / gpr;
    let mut rng = Rng::new(11);
    let x: Vec<f32> = (0..m * d).map(|_| rng.normal()).collect();
    let t1: Vec<f32> = (0..n * d).map(|_| rng.below(3) as f32 - 1.0).collect();
    let t2: Vec<f32> = (0..n * d).map(|_| rng.below(3) as f32 - 1.0).collect();
    let a1: Vec<f32> = (0..n * gpr).map(|_| rng.normal()).collect();
    let a2: Vec<f32> = (0..n * gpr).map(|_| rng.normal()).collect();
    let out = engine
        .run_f32(
            "ternary_matmul",
            &[
                (&[m, d], x.as_slice()),
                (&[n, d], t1.as_slice()),
                (&[n, d], t2.as_slice()),
                (&[n, gpr], a1.as_slice()),
                (&[n, gpr], a2.as_slice()),
            ],
        )
        .unwrap();
    assert_eq!(out[0].len(), m * n);

    // Rust-side reference via TernaryLinear
    let mut lin = ptqtp::ternary::TernaryLinear::new(n, d, group);
    lin.t1.trits = t1.iter().map(|&v| v as i8).collect();
    lin.t2.trits = t2.iter().map(|&v| v as i8).collect();
    lin.alpha1 = a1;
    lin.alpha2 = a2;
    for row in 0..m {
        let y = ptqtp::ternary::gemv::gemv(&lin, &x[row * d..(row + 1) * d]);
        for (j, &v) in y.iter().enumerate() {
            let got = out[0][row * n + j];
            assert!(
                (got - v).abs() < 1e-3 * (1.0 + v.abs()),
                "({row},{j}): pjrt {got} vs rust {v}"
            );
        }
    }
}

#[test]
fn pjrt_ptqtp_step_runs() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = ptqtp::runtime::ArtifactManifest::load("artifacts").unwrap();
    let mut engine = ptqtp::runtime::PjrtEngine::cpu().unwrap();
    engine
        .load_hlo_text("ptqtp_step", manifest.path_of("ptqtp_step").unwrap())
        .unwrap();
    let spec = manifest.get("ptqtp_step").unwrap();
    let (g, gg) = (spec.inputs[0][0], spec.inputs[0][1]);
    let mut rng = Rng::new(13);
    let w: Vec<f32> = (0..g * gg).map(|_| rng.normal() * 0.05).collect();
    let t: Vec<f32> = w.iter().map(|&v| if v < 0.0 { -1.0 } else { 1.0 }).collect();
    let lam = vec![1e-8f32; g];
    let out = engine
        .run_f32(
            "ptqtp_step",
            &[
                (&[g, gg], w.as_slice()),
                (&[g, gg], t.as_slice()),
                (&[g, gg], t.as_slice()),
                (&[g, 1], lam.as_slice()),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 5, "t1,t2,a1,a2,lam outputs");
    // trits legal
    assert!(out[0].iter().all(|&v| v == -1.0 || v == 0.0 || v == 1.0));
}

//! Cross-module integration tests: quantize → model → eval → serve,
//! plus PJRT artifact execution when `make artifacts` has run.

use ptqtp::coordinator::{Request, SamplingParams, ServeEngine};
use ptqtp::data::{CorpusGen, TaskSuite, Tokenizer};
use ptqtp::eval::{eval_suite, perplexity};
use ptqtp::model::{ModelConfig, Transformer};
use ptqtp::quant::{self, QuantCtx, Quantizer};
use ptqtp::rng::Rng;

fn test_model(vocab: usize, seed: u64) -> Transformer {
    let mut cfg = ModelConfig::family("tiny").unwrap();
    cfg.vocab_size = vocab;
    cfg.max_seq = 64;
    let mut rng = Rng::new(seed);
    Transformer::random(cfg, &mut rng)
}

#[test]
fn quantize_then_eval_pipeline() {
    let tok = Tokenizer::from_text("abcdefghij 0123456789+-*=?.:QA");
    let model = test_model(tok.vocab_size(), 1);
    let text = CorpusGen::new(5).domain_text(ptqtp::data::CorpusDomain::WikiSyn, 20);
    let ppl_fp = perplexity(&model, &tok, &text);

    for method in ["ptqtp", "rtn4", "billm"] {
        let q = quant::by_name(method, 64).unwrap();
        let mut m = model.clone();
        m.quantize_with(q.as_ref(), &QuantCtx::default());
        let ppl_q = perplexity(&m, &tok, &text);
        assert!(ppl_q.is_finite(), "{method} ppl finite");
        // random-weight models have near-uniform predictions; quantized
        // ppl must stay in a sane band around the fp ppl
        assert!(
            ppl_q < ppl_fp * 50.0,
            "{method}: ppl exploded {ppl_q} vs {ppl_fp}"
        );
    }
}

#[test]
fn ptqtp_preserves_more_than_binary_on_trained_like_weights() {
    // reconstruction ordering on every layer of a model
    let model = test_model(32, 2);
    let mut err_ptqtp = 0.0f64;
    let mut err_billm = 0.0f64;
    let ptq = quant::by_name("ptqtp", 128).unwrap();
    let bil = quant::by_name("billm", 128).unwrap();
    for (_, lin) in model.linear_layers() {
        let w = lin.dense_weights();
        err_ptqtp += w.sq_err(&ptq.quantize(&w, &QuantCtx::default()).w_hat);
        err_billm += w.sq_err(&bil.quantize(&w, &QuantCtx::default()).w_hat);
    }
    assert!(err_ptqtp < err_billm, "{err_ptqtp} !< {err_billm}");
}

#[test]
fn serve_quantized_model_end_to_end() {
    let tok = Tokenizer::from_text("abcdefgh 0123456789+-*=?.:QA");
    let mut model = test_model(tok.vocab_size(), 3);
    model.quantize_with(
        quant::by_name("ptqtp", 128).unwrap().as_ref(),
        &QuantCtx::default(),
    );
    let mut engine = ServeEngine::new(model, Default::default());
    for i in 0..6 {
        engine.submit(Request::new(
            i,
            tok.encode("Q:2+2=? A:"),
            SamplingParams {
                max_new_tokens: 4,
                stop_token: None,
                ..Default::default()
            },
        ));
    }
    let out = engine.run_to_completion();
    assert_eq!(out.len(), 6);
    assert!(out.iter().all(|r| r.tokens.len() == 4));
}

#[test]
fn task_suite_eval_runs_on_quantized_model() {
    let tok = Tokenizer::from_text("abcdefghijklmnopqrstuvwxyz 0123456789+-*=?.:!>()[]{}QA");
    let mut model = test_model(tok.vocab_size(), 4);
    model.quantize_with(
        quant::by_name("ptqtp", 128).unwrap().as_ref(),
        &QuantCtx::default(),
    );
    let suite = TaskSuite::standard(9, 5, 8, 5);
    let s = eval_suite(&model, &tok, &suite);
    assert!(s.math_acc >= 0.0 && s.cloze_acc <= 1.0);
}

#[test]
fn checkpoint_roundtrip_preserves_quantized_eval() {
    let tok = Tokenizer::from_text("abcdef 0123456789+-*=?.:QA");
    let mut model = test_model(tok.vocab_size(), 5);
    model.quantize_with(
        quant::by_name("ptqtp", 128).unwrap().as_ref(),
        &QuantCtx::default(),
    );
    let dir = std::env::temp_dir().join("ptqtp_it_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("q.ptw");
    model.save(&path).unwrap();
    let loaded = Transformer::load(&path).unwrap();
    // saved form densifies ternary backends; logits must match exactly
    let mut c1 = model.new_cache();
    let mut c2 = loaded.new_cache();
    let a = model.decode_step(1, &mut c1);
    let b = loaded.decode_step(1, &mut c2);
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 1e-5);
    }
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(dir.join("q.json")).ok();
}

// ---------------------------------------------------------------------
// Fused-batch engine parity (the tentpole guarantee)
// ---------------------------------------------------------------------

/// Random mixed-length workloads through `ServeEngine` with
/// `max_running ∈ {1, N}` must generate identical tokens per request:
/// the fused batch path is bit-identical per row to sequential
/// decoding. Covers dense and ternary backends, aligned (G=128) and
/// ragged (G % 4 != 0) group packing, greedy and seeded temperature
/// sampling, and prefill budgets small enough to split prompts across
/// steps.
#[test]
fn fused_batch_matches_sequential_property() {
    use ptqtp::coordinator::batcher::BatchPolicy;
    use ptqtp::proptest::{check_seeded, prop_assert, Gen};

    check_seeded(0xBA7C4ED, 10, |g: &mut Gen| {
        let vocab = 32usize;
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = vocab;
        cfg.max_seq = 48;
        let mut rng = Rng::new(g.rng.next_u64());
        let mut model = Transformer::random(cfg, &mut rng);
        // 0 = dense fp32, 1 = ptqtp aligned G, 2 = ptqtp ragged G%4!=0
        match g.usize_in(0, 2) {
            1 => model.quantize_with(
                quant::by_name("ptqtp", 128).unwrap().as_ref(),
                &QuantCtx::default(),
            ),
            2 => model.quantize_with(
                quant::by_name("ptqtp", *g.pick(&[6usize, 10, 14])).unwrap().as_ref(),
                &QuantCtx::default(),
            ),
            _ => {}
        }

        let n_req = g.usize_in(1, 6);
        let reqs: Vec<(Vec<u32>, usize, f32, u64)> = (0..n_req)
            .map(|_| {
                let plen = g.usize_in(1, 9);
                let prompt: Vec<u32> = (0..plen).map(|_| g.rng.below(vocab) as u32).collect();
                let max_new = g.usize_in(1, 6);
                let temperature = *g.pick(&[0.0f32, 0.8]);
                (prompt, max_new, temperature, g.rng.next_u64())
            })
            .collect();

        let prefill_token_budget = *g.pick(&[3usize, 8, 64]);
        let max_running = *g.pick(&[2usize, 4, 8]);
        let run = |max_running: usize| {
            let mut e = ServeEngine::new(
                model.clone(),
                BatchPolicy {
                    max_running,
                    prefill_token_budget,
                    fcfs_prefill: true,
                },
            );
            for (i, (prompt, max_new, temperature, seed)) in reqs.iter().enumerate() {
                e.submit(Request::new(
                    i as u64,
                    prompt.clone(),
                    SamplingParams {
                        temperature: *temperature,
                        max_new_tokens: *max_new,
                        stop_token: None,
                        seed: *seed,
                    },
                ));
            }
            let mut out = e.run_to_completion();
            out.sort_by_key(|r| r.id);
            out
        };

        let batched = run(max_running);
        let sequential = run(1);
        for (a, b) in batched.iter().zip(&sequential) {
            if a.tokens != b.tokens {
                return Err(format!(
                    "req {} diverged: batched {:?} vs sequential {:?} (max_running={max_running}, budget={prefill_token_budget})",
                    a.id, a.tokens, b.tokens
                ));
            }
        }
        prop_assert(batched.len() == sequential.len(), "response counts differ")
    });
}

// ---------------------------------------------------------------------
// Row-parallel execution parity (quantize → serve, any thread count)
// ---------------------------------------------------------------------

/// The whole pipeline under `--threads`: matrix-parallel quantization
/// must produce a bit-identical model, and a threaded engine must then
/// serve token-for-token what the sequential engine serves. Ragged
/// G = 10 keeps the packed tier in play; the aligned pass exercises the
/// activation-indexed LUT tier.
#[test]
fn threaded_pipeline_matches_sequential_end_to_end() {
    let tok = Tokenizer::from_text("abcdefgh 0123456789+-*=?.:QA");
    for group in [128usize, 10] {
        let base = test_model(tok.vocab_size(), 7);
        let q = quant::by_name("ptqtp", group).unwrap();

        let mut m_seq = base.clone();
        m_seq.quantize_with(q.as_ref(), &QuantCtx::default());
        let mut m_par = base.clone();
        m_par.quantize_with(q.as_ref(), &QuantCtx::with_threads(4));

        // quantized weights identical regardless of quantization threads
        let mut c1 = m_seq.new_cache();
        let mut c2 = m_par.new_cache();
        assert_eq!(
            m_seq.decode_step(1, &mut c1),
            m_par.decode_step(1, &mut c2),
            "G={group}: threaded quantization changed the model"
        );

        let serve = |model: &Transformer, threads: usize| {
            let mut e = ServeEngine::with_threads(model.clone(), Default::default(), threads);
            for i in 0..4 {
                e.submit(Request::new(
                    i,
                    tok.encode("Q:2+2=? A:"),
                    SamplingParams {
                        max_new_tokens: 5,
                        stop_token: None,
                        ..Default::default()
                    },
                ));
            }
            let mut out = e.run_to_completion();
            out.sort_by_key(|r| r.id);
            out
        };
        let seq = serve(&m_seq, 1);
        let par = serve(&m_par, 4);
        assert_eq!(seq.len(), par.len());
        for (a, b) in par.iter().zip(&seq) {
            assert_eq!(a.tokens, b.tokens, "G={group} req {}", a.id);
        }
    }
}

// ---------------------------------------------------------------------
// PJRT integration (requires `make artifacts`)
// ---------------------------------------------------------------------

fn artifacts_ready() -> bool {
    if !cfg!(feature = "pjrt") {
        eprintln!("skipping: built without the `pjrt` feature");
        return false;
    }
    std::path::Path::new("artifacts/manifest.json").exists()
}

#[test]
fn pjrt_artifacts_execute_and_match_reference() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = ptqtp::runtime::ArtifactManifest::load("artifacts").unwrap();
    let mut engine = ptqtp::runtime::PjrtEngine::cpu().unwrap();
    manifest.load_all(&mut engine).unwrap();

    // ternary_matmul: cross-check PJRT output against the Rust kernels
    let spec = manifest.get("ternary_matmul").unwrap();
    let (m, d) = (spec.inputs[0][0], spec.inputs[0][1]);
    let n = spec.inputs[1][0];
    let gpr = spec.inputs[3][1];
    let group = d / gpr;
    let mut rng = Rng::new(11);
    let x: Vec<f32> = (0..m * d).map(|_| rng.normal()).collect();
    let t1: Vec<f32> = (0..n * d).map(|_| rng.below(3) as f32 - 1.0).collect();
    let t2: Vec<f32> = (0..n * d).map(|_| rng.below(3) as f32 - 1.0).collect();
    let a1: Vec<f32> = (0..n * gpr).map(|_| rng.normal()).collect();
    let a2: Vec<f32> = (0..n * gpr).map(|_| rng.normal()).collect();
    let out = engine
        .run_f32(
            "ternary_matmul",
            &[
                (&[m, d], x.as_slice()),
                (&[n, d], t1.as_slice()),
                (&[n, d], t2.as_slice()),
                (&[n, gpr], a1.as_slice()),
                (&[n, gpr], a2.as_slice()),
            ],
        )
        .unwrap();
    assert_eq!(out[0].len(), m * n);

    // Rust-side reference via TernaryLinear
    let mut lin = ptqtp::ternary::TernaryLinear::new(n, d, group);
    lin.t1.trits = t1.iter().map(|&v| v as i8).collect();
    lin.t2.trits = t2.iter().map(|&v| v as i8).collect();
    lin.alpha1 = a1;
    lin.alpha2 = a2;
    for row in 0..m {
        let y = ptqtp::ternary::gemv::gemv(&lin, &x[row * d..(row + 1) * d]);
        for (j, &v) in y.iter().enumerate() {
            let got = out[0][row * n + j];
            assert!(
                (got - v).abs() < 1e-3 * (1.0 + v.abs()),
                "({row},{j}): pjrt {got} vs rust {v}"
            );
        }
    }
}

#[test]
fn pjrt_ptqtp_step_runs() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = ptqtp::runtime::ArtifactManifest::load("artifacts").unwrap();
    let mut engine = ptqtp::runtime::PjrtEngine::cpu().unwrap();
    engine
        .load_hlo_text("ptqtp_step", manifest.path_of("ptqtp_step").unwrap())
        .unwrap();
    let spec = manifest.get("ptqtp_step").unwrap();
    let (g, gg) = (spec.inputs[0][0], spec.inputs[0][1]);
    let mut rng = Rng::new(13);
    let w: Vec<f32> = (0..g * gg).map(|_| rng.normal() * 0.05).collect();
    let t: Vec<f32> = w.iter().map(|&v| if v < 0.0 { -1.0 } else { 1.0 }).collect();
    let lam = vec![1e-8f32; g];
    let out = engine
        .run_f32(
            "ptqtp_step",
            &[
                (&[g, gg], w.as_slice()),
                (&[g, gg], t.as_slice()),
                (&[g, gg], t.as_slice()),
                (&[g, 1], lam.as_slice()),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 5, "t1,t2,a1,a2,lam outputs");
    // trits legal
    assert!(out[0].iter().all(|&v| v == -1.0 || v == 0.0 || v == 1.0));
}

//! `cargo bench` entrypoint (custom harness; criterion is unavailable
//! offline). Runs the kernel micro-benches plus the per-table
//! end-to-end reproductions in quick mode.
//!
//! Filters: `cargo bench -- kernels` / `-- tables` / `-- figs`.

use ptqtp::bench::harness::bench_fn;
use ptqtp::bench::workload::bench_weight;
use ptqtp::cli::Args;
use ptqtp::quant::ptqtp::Ptqtp;
use ptqtp::quant::{self, QuantCtx};
use ptqtp::tensor::{ops, Matrix};
use ptqtp::ternary::int4::{Aqlm2x2Linear, Int4Linear};
use std::time::Duration;

fn main() {
    let filter: String = std::env::args().skip(1).collect::<Vec<_>>().join(" ");
    let run_all = filter.is_empty() || filter == "--bench";
    let budget = Duration::from_millis(800);

    if run_all || filter.contains("kernel") {
        println!("== kernel micro-benches ==");
        let (n, d) = (512, 1024);
        let w = bench_weight(n, d, 1);
        let mut rng = ptqtp::rng::Rng::new(2);
        let x: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let wt = w.transpose();

        let (lin, _) = Ptqtp::default().quantize_with_report(&w);
        let packed = lin.to_packed();
        let int4 = Int4Linear::quantize(&w, 128);
        let aqlm = Aqlm2x2Linear::quantize(&w, 128);

        let mut y = vec![0.0f32; n];
        println!("{}", bench_fn("gemv/dense-f32", 3, 400, budget, || ops::matvec_into(&w, &x, &mut y)).summary());
        println!("{}", bench_fn("gemv/ptqtp-unpacked", 3, 400, budget, || ptqtp::ternary::gemv::gemv_fused(&lin, &x, &mut y)).summary());
        println!("{}", bench_fn("gemv/ptqtp-packed", 3, 400, budget, || ptqtp::ternary::gemv::gemv_packed(&packed, &x, &mut y)).summary());
        println!("{}", bench_fn("gemv/int4", 3, 400, budget, || int4.gemv(&x, &mut y)).summary());
        println!("{}", bench_fn("gemv/aqlm-2x2", 3, 400, budget, || aqlm.gemv(&x, &mut y)).summary());
        let xb = Matrix::from_vec(64, d, (0..64 * d).map(|i| (i % 17) as f32 * 0.1).collect());
        println!("{}", bench_fn("gemm/dense-f32 m=64", 2, 50, budget, || ops::matmul(&xb, &wt)).summary());
        println!("{}", bench_fn("gemm/ptqtp-decoded m=64", 2, 50, budget, || ptqtp::ternary::gemm::gemm_decoded(&packed, &xb)).summary());

        println!("\n== quantizer micro-benches (512x1024 layer) ==");
        let calib = Matrix::randn(32, d, 1.0, &mut ptqtp::rng::Rng::new(3));
        let ctx = QuantCtx::with_calib(calib);
        for method in ["rtn3", "absmean", "ptqtp", "awq3", "billm", "arb", "gptq3"] {
            let q = quant::by_name(method, 128).unwrap();
            let r = bench_fn(
                &format!("quant/{method}"),
                0,
                8,
                Duration::from_secs(5),
                || q.quantize(&w, &ctx),
            );
            println!("{}", r.summary());
        }
    }

    if run_all || filter.contains("batched") {
        println!("\n== batched forward (fused vs per-token) ==");
        let args = Args::parse("bench", std::iter::empty(), &[]);
        if let Err(e) = ptqtp::bench::batched::run(true, &args) {
            println!("batched bench failed: {e}");
        }
    }

    if run_all || filter.contains("attention") {
        println!("\n== attention tiers (head-major scalar vs SIMD vs threaded) ==");
        let args = Args::parse("bench", std::iter::empty(), &[]);
        if let Err(e) = ptqtp::bench::attention::run(true, &args) {
            println!("attention bench failed: {e}");
        }
    }

    if run_all || filter.contains("table") {
        println!("\n== paper tables (quick mode) ==");
        let args = Args::parse("bench", std::iter::empty(), &[]);
        for t in ["1", "2", "3", "4", "5", "6", "7", "8", "10", "11", "12"] {
            println!("\n---- table {t} ----");
            if let Err(e) = ptqtp::bench::run_table(t, true, &args) {
                println!("table {t} failed: {e}");
            }
        }
    }

    if run_all || filter.contains("fig") {
        println!("\n== paper figures (quick mode) ==");
        let args = Args::parse("bench", std::iter::empty(), &[]);
        for f in ["1", "3", "4", "5"] {
            println!("\n---- fig {f} ----");
            if let Err(e) = ptqtp::bench::run_fig(f, true, &args) {
                println!("fig {f} failed: {e}");
            }
        }
    }
}

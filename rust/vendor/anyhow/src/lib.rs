//! Offline shim for the `anyhow` crate (no registry access in this
//! build environment). Implements exactly the subset the `ptqtp` crate
//! uses: [`Result`], [`Error`], the [`anyhow!`]/[`bail!`]/[`ensure!`]
//! macros, and `?`-conversion from any `std::error::Error`.
//!
//! Semantics match real anyhow for that subset: `Error` is an opaque
//! display-able error value, `{:#}` formats the same as `{}` (we store
//! a flattened message rather than a cause chain).

use std::fmt;

/// Opaque error type carrying a formatted message.
pub struct Error(String);

impl Error {
    /// Construct from anything displayable (what `anyhow!` lowers to).
    pub fn msg(m: impl fmt::Display) -> Error {
        Error(m.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error(e.to_string())
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] when the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: `", stringify!($cond), "`"));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/here")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_format() {
        let x = 3;
        let e = anyhow!("bad value {x}");
        assert_eq!(e.to_string(), "bad value 3");
        let r: Result<()> = (|| {
            ensure!(x == 4, "x was {x}");
            Ok(())
        })();
        assert_eq!(r.unwrap_err().to_string(), "x was 3");
        let r: Result<()> = (|| bail!("stop"))();
        assert_eq!(format!("{:#}", r.unwrap_err()), "stop");
    }
}

//! Checkpoint sidecar manifest (`X.manifest.json` next to `X.ptw`).
//!
//! A quantized checkpoint is an immutable deployment artifact: replicas
//! cold-start from it without re-running the progressive-approximation
//! pass, so the manifest records everything a serving fleet needs to
//! trust the file — the container revision, the quantization method and
//! its hyper-parameters, a summary report of the quantization that
//! produced it, and an FNV-1a-64 checksum of the full `.ptw` payload
//! that [`Transformer::load`](crate::model::Transformer::load) verifies
//! before deserializing.
//!
//! The manifest is optional on load (checkpoints written by the Python
//! build path, and pre-PTW2 files, have none); when present, a checksum
//! or size mismatch is a hard error.

use super::json::Json;
use std::path::{Path, PathBuf};

/// Streaming FNV-1a 64-bit accumulator — the integrity checksum for
/// `.ptw` payloads. Not cryptographic; it guards against truncation
/// and bit-rot, which is the failure mode for an artifact store, and
/// needs no deps.
#[derive(Clone, Copy, Debug)]
pub struct Fnv1a64 {
    state: u64,
}

impl Default for Fnv1a64 {
    fn default() -> Self {
        Fnv1a64 {
            state: 0xcbf29ce484222325,
        }
    }
}

impl Fnv1a64 {
    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= b as u64;
            self.state = self.state.wrapping_mul(0x100000001b3);
        }
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

/// One-shot FNV-1a 64 over a byte slice.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a64::default();
    h.update(bytes);
    h.finish()
}

/// `Write` adapter that checksums and counts exactly the bytes the
/// inner writer accepted — checkpoints stream to disk without a
/// second in-memory copy just for the digest.
pub struct HashingWriter<W: std::io::Write> {
    inner: W,
    hash: Fnv1a64,
    count: usize,
}

impl<W: std::io::Write> HashingWriter<W> {
    pub fn new(inner: W) -> Self {
        HashingWriter {
            inner,
            hash: Fnv1a64::default(),
            count: 0,
        }
    }

    /// Flush the inner writer and return (bytes written, digest).
    pub fn finish(mut self) -> std::io::Result<(usize, u64)> {
        self.inner.flush()?;
        Ok((self.count, self.hash.finish()))
    }
}

impl<W: std::io::Write> std::io::Write for HashingWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let n = self.inner.write(buf)?;
        self.hash.update(&buf[..n]);
        self.count += n;
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// `Read` adapter mirroring [`HashingWriter`]: checksums and counts
/// everything read. [`HashingReader::finish`] drains to EOF so the
/// digest covers the whole file (trailing garbage fails the size
/// check).
pub struct HashingReader<R: std::io::Read> {
    inner: R,
    hash: Fnv1a64,
    count: usize,
}

impl<R: std::io::Read> HashingReader<R> {
    pub fn new(inner: R) -> Self {
        HashingReader {
            inner,
            hash: Fnv1a64::default(),
            count: 0,
        }
    }

    /// Consume the rest of the stream and return (total bytes, digest).
    pub fn finish(mut self) -> std::io::Result<(usize, u64)> {
        let mut buf = [0u8; 8192];
        loop {
            let n = self.inner.read(&mut buf)?;
            if n == 0 {
                break;
            }
            self.hash.update(&buf[..n]);
            self.count += n;
        }
        Ok((self.count, self.hash.finish()))
    }
}

impl<R: std::io::Read> std::io::Read for HashingReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.hash.update(&buf[..n]);
        self.count += n;
        Ok(n)
    }
}

const CHECKSUM_ALGO: &str = "fnv1a64";

/// Sidecar metadata for one `.ptw` checkpoint.
#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointManifest {
    /// Container revision the payload serialized as ("PTW1" | "PTW2").
    pub format: String,
    /// Quantization method that produced the weights ("fp32" when the
    /// checkpoint is dense/unquantized).
    pub method: String,
    /// Quantizer hyper-parameters (e.g. serialized `PtqtpOpts`).
    pub quant_opts: Option<Json>,
    /// Quantization report/summary (per-model aggregates).
    pub report: Option<Json>,
    /// `"fnv1a64:<16 hex digits>"` over the full `.ptw` file bytes.
    pub checksum: String,
    /// Exact `.ptw` file size in bytes.
    pub payload_bytes: usize,
    /// Plain tensor records in the payload.
    pub tensors: usize,
    /// Packed trit-plane records in the payload.
    pub packed_tensors: usize,
}

impl CheckpointManifest {
    /// Build a manifest from a streamed payload size + digest (what
    /// [`HashingWriter::finish`] returns).
    pub fn from_digest(
        format: &str,
        method: &str,
        payload_bytes: usize,
        digest: u64,
        tensors: usize,
        packed_tensors: usize,
    ) -> CheckpointManifest {
        CheckpointManifest {
            format: format.to_string(),
            method: method.to_string(),
            quant_opts: None,
            report: None,
            checksum: format!("{CHECKSUM_ALGO}:{digest:016x}"),
            payload_bytes,
            tensors,
            packed_tensors,
        }
    }

    /// Build a manifest for in-memory checkpoint bytes.
    pub fn for_payload(
        format: &str,
        method: &str,
        payload: &[u8],
        tensors: usize,
        packed_tensors: usize,
    ) -> CheckpointManifest {
        Self::from_digest(
            format,
            method,
            payload.len(),
            fnv1a64(payload),
            tensors,
            packed_tensors,
        )
    }

    /// Sidecar path for a checkpoint path: `m.ptw` → `m.manifest.json`.
    pub fn path_for(ckpt: impl AsRef<Path>) -> PathBuf {
        ckpt.as_ref().with_extension("manifest.json")
    }

    /// Verify a streamed (size, digest) pair against this manifest.
    pub fn verify_digest(&self, payload_bytes: usize, digest: u64) -> anyhow::Result<()> {
        anyhow::ensure!(
            payload_bytes == self.payload_bytes,
            "checkpoint size {payload_bytes} != manifest payload_bytes {} (truncated or swapped file?)",
            self.payload_bytes
        );
        let got = format!("{CHECKSUM_ALGO}:{digest:016x}");
        anyhow::ensure!(
            got == self.checksum,
            "checkpoint checksum mismatch: file {got} vs manifest {} (corrupt artifact)",
            self.checksum
        );
        Ok(())
    }

    /// Verify `bytes` (the full `.ptw` file) against this manifest.
    pub fn verify(&self, bytes: &[u8]) -> anyhow::Result<()> {
        self.verify_digest(bytes.len(), fnv1a64(bytes))
    }

    // ---------- json ----------

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("format", self.format.as_str())
            .set("method", self.method.as_str())
            .set("checksum", self.checksum.as_str())
            .set("payload_bytes", self.payload_bytes)
            .set("tensors", self.tensors)
            .set("packed_tensors", self.packed_tensors);
        if let Some(q) = &self.quant_opts {
            j = j.set("quant_opts", q.clone());
        }
        if let Some(r) = &self.report {
            j = j.set("report", r.clone());
        }
        j
    }

    pub fn from_json(j: &Json) -> anyhow::Result<CheckpointManifest> {
        Ok(CheckpointManifest {
            format: j.req_str("format")?.to_string(),
            method: j.req_str("method")?.to_string(),
            quant_opts: j.get("quant_opts").cloned(),
            report: j.get("report").cloned(),
            checksum: j.req_str("checksum")?.to_string(),
            payload_bytes: j.req_usize("payload_bytes")?,
            tensors: j.req_usize("tensors")?,
            packed_tensors: j.req_usize("packed_tensors")?,
        })
    }

    /// Write the sidecar next to `ckpt`.
    pub fn save_for(&self, ckpt: impl AsRef<Path>) -> anyhow::Result<()> {
        std::fs::write(Self::path_for(ckpt), self.to_json().pretty())?;
        Ok(())
    }

    /// Load the sidecar for `ckpt`, if one exists. A present-but-invalid
    /// manifest is an error (it means the artifact pair is damaged).
    pub fn load_for(ckpt: impl AsRef<Path>) -> anyhow::Result<Option<CheckpointManifest>> {
        let path = Self::path_for(ckpt);
        if !path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("read {path:?}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parse {path:?}: {e}"))?;
        Ok(Some(CheckpointManifest::from_json(&j)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_matches_reference_vectors() {
        // standard FNV-1a 64 test vectors
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn streaming_hash_matches_one_shot() {
        use std::io::{Read, Write};
        let payload: Vec<u8> = (0..10_000u32).map(|i| (i * 7 + 3) as u8).collect();
        let mut w = HashingWriter::new(Vec::new());
        // uneven chunks: digest must be split-invariant
        for chunk in payload.chunks(307) {
            w.write_all(chunk).unwrap();
        }
        let (n, digest) = w.finish().unwrap();
        assert_eq!((n, digest), (payload.len(), fnv1a64(&payload)));

        let mut r = HashingReader::new(payload.as_slice());
        let mut head = [0u8; 123];
        r.read_exact(&mut head).unwrap();
        let (n, digest) = r.finish().unwrap(); // drains the rest
        assert_eq!((n, digest), (payload.len(), fnv1a64(&payload)));
    }

    #[test]
    fn json_roundtrip_with_and_without_quant() {
        let mut m = CheckpointManifest::for_payload("PTW2", "ptqtp", b"payload", 3, 7);
        assert_eq!(CheckpointManifest::from_json(&m.to_json()).unwrap(), m);
        m.quant_opts = Some(Json::obj().set("group", 128usize));
        m.report = Some(Json::obj().set("layers_ternary", 14usize));
        assert_eq!(CheckpointManifest::from_json(&m.to_json()).unwrap(), m);
    }

    #[test]
    fn verify_accepts_exact_and_rejects_tampered() {
        let payload = b"some checkpoint bytes".to_vec();
        let m = CheckpointManifest::for_payload("PTW2", "ptqtp", &payload, 1, 1);
        m.verify(&payload).unwrap();
        let mut flipped = payload.clone();
        flipped[4] ^= 0x40;
        let err = m.verify(&flipped).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        let err = m.verify(&payload[..payload.len() - 1]).unwrap_err().to_string();
        assert!(err.contains("payload_bytes"), "{err}");
    }

    #[test]
    fn sidecar_path_and_file_roundtrip() {
        let dir = std::env::temp_dir().join("ptqtp_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let ckpt = dir.join("m.ptw");
        assert_eq!(
            CheckpointManifest::path_for(&ckpt),
            dir.join("m.manifest.json")
        );
        let m = CheckpointManifest::for_payload("PTW1", "fp32", b"x", 2, 0);
        m.save_for(&ckpt).unwrap();
        assert_eq!(CheckpointManifest::load_for(&ckpt).unwrap(), Some(m));
        std::fs::remove_file(dir.join("m.manifest.json")).ok();
        assert_eq!(CheckpointManifest::load_for(&ckpt).unwrap(), None);
    }
}

//! `.ptw` — PTQTP tensor-file container.
//!
//! Little-endian binary format shared between the Python build path
//! (`python/compile/ptw.py` writes checkpoints) and the Rust engine:
//!
//! ```text
//! magic   : 4 bytes  = "PTW1"
//! count   : u32      = number of tensors
//! repeat count times:
//!   name_len : u32
//!   name     : utf-8 bytes
//!   dtype    : u8   (0=f32, 1=i8, 2=u8, 3=i32)
//!   ndim     : u32
//!   dims     : ndim × u64
//!   payload  : product(dims) × sizeof(dtype) bytes
//! ```

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"PTW1";

/// Supported element types.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32 = 0,
    I8 = 1,
    U8 = 2,
    I32 = 3,
}

impl DType {
    fn from_u8(x: u8) -> anyhow::Result<DType> {
        Ok(match x {
            0 => DType::F32,
            1 => DType::I8,
            2 => DType::U8,
            3 => DType::I32,
            other => anyhow::bail!("unknown dtype tag {other}"),
        })
    }

    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 | DType::U8 => 1,
        }
    }
}

/// One named tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorEntry {
    pub dtype: DType,
    pub dims: Vec<usize>,
    /// Raw little-endian payload.
    pub bytes: Vec<u8>,
}

impl TensorEntry {
    pub fn from_f32(dims: Vec<usize>, data: &[f32]) -> TensorEntry {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for &x in data {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        TensorEntry {
            dtype: DType::F32,
            dims,
            bytes,
        }
    }

    pub fn from_i8(dims: Vec<usize>, data: &[i8]) -> TensorEntry {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        TensorEntry {
            dtype: DType::I8,
            dims,
            bytes: data.iter().map(|&x| x as u8).collect(),
        }
    }

    pub fn from_u8(dims: Vec<usize>, data: Vec<u8>) -> TensorEntry {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        TensorEntry {
            dtype: DType::U8,
            dims,
            bytes: data,
        }
    }

    pub fn to_f32(&self) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(self.dtype == DType::F32, "tensor is not f32");
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    pub fn to_i8(&self) -> anyhow::Result<Vec<i8>> {
        anyhow::ensure!(self.dtype == DType::I8, "tensor is not i8");
        Ok(self.bytes.iter().map(|&b| b as i8).collect())
    }

    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    /// View as a [`crate::tensor::Matrix`]; requires 2-D f32.
    pub fn to_matrix(&self) -> anyhow::Result<crate::tensor::Matrix> {
        anyhow::ensure!(self.dims.len() == 2, "tensor is not 2-D: {:?}", self.dims);
        Ok(crate::tensor::Matrix::from_vec(
            self.dims[0],
            self.dims[1],
            self.to_f32()?,
        ))
    }
}

/// Ordered collection of named tensors.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TensorFile {
    pub tensors: BTreeMap<String, TensorEntry>,
}

impl TensorFile {
    pub fn new() -> TensorFile {
        TensorFile::default()
    }

    pub fn insert(&mut self, name: &str, entry: TensorEntry) {
        self.tensors.insert(name.to_string(), entry);
    }

    pub fn insert_matrix(&mut self, name: &str, m: &crate::tensor::Matrix) {
        self.insert(name, TensorEntry::from_f32(vec![m.rows, m.cols], &m.data));
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&TensorEntry> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("tensor '{name}' not found in checkpoint"))
    }

    pub fn matrix(&self, name: &str) -> anyhow::Result<crate::tensor::Matrix> {
        self.get(name)?.to_matrix()
    }

    pub fn vec_f32(&self, name: &str) -> anyhow::Result<Vec<f32>> {
        self.get(name)?.to_f32()
    }

    // ---------- io ----------

    pub fn write_to(&self, w: &mut impl Write) -> anyhow::Result<()> {
        w.write_all(MAGIC)?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            w.write_all(&[t.dtype as u8])?;
            w.write_all(&(t.dims.len() as u32).to_le_bytes())?;
            for &d in &t.dims {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            anyhow::ensure!(
                t.bytes.len() == t.numel() * t.dtype.size(),
                "payload size mismatch for '{name}'"
            );
            w.write_all(&t.bytes)?;
        }
        Ok(())
    }

    pub fn save(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
        self.write_to(&mut f)
    }

    pub fn read_from(r: &mut impl Read) -> anyhow::Result<TensorFile> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "bad magic: {magic:?}");
        let count = read_u32(r)? as usize;
        let mut tf = TensorFile::new();
        for _ in 0..count {
            let name_len = read_u32(r)? as usize;
            anyhow::ensure!(name_len < 4096, "unreasonable name length {name_len}");
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name)?;
            let mut tag = [0u8; 1];
            r.read_exact(&mut tag)?;
            let dtype = DType::from_u8(tag[0])?;
            let ndim = read_u32(r)? as usize;
            anyhow::ensure!(ndim <= 8, "unreasonable rank {ndim}");
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                let mut b = [0u8; 8];
                r.read_exact(&mut b)?;
                dims.push(u64::from_le_bytes(b) as usize);
            }
            let numel: usize = dims.iter().product();
            let mut bytes = vec![0u8; numel * dtype.size()];
            r.read_exact(&mut bytes)?;
            tf.insert(&name, TensorEntry { dtype, dims, bytes });
        }
        Ok(tf)
    }

    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<TensorFile> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path.as_ref())
                .map_err(|e| anyhow::anyhow!("open {:?}: {e}", path.as_ref()))?,
        );
        TensorFile::read_from(&mut f)
    }
}

fn read_u32(r: &mut impl Read) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::Matrix;

    #[test]
    fn roundtrip_in_memory() {
        let mut rng = Rng::new(1);
        let m = Matrix::randn(5, 7, 1.0, &mut rng);
        let mut tf = TensorFile::new();
        tf.insert_matrix("w.0", &m);
        tf.insert("trits", TensorEntry::from_i8(vec![3, 2], &[-1, 0, 1, 1, 0, -1]));
        tf.insert("packed", TensorEntry::from_u8(vec![4], vec![0xde, 0xad, 0xbe, 0xef]));

        let mut buf = Vec::new();
        tf.write_to(&mut buf).unwrap();
        let tf2 = TensorFile::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(tf, tf2);
        assert_eq!(tf2.matrix("w.0").unwrap(), m);
        assert_eq!(tf2.get("trits").unwrap().to_i8().unwrap(), vec![-1, 0, 1, 1, 0, -1]);
    }

    #[test]
    fn roundtrip_on_disk() {
        let dir = std::env::temp_dir().join("ptqtp_test_tf");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.ptw");
        let mut tf = TensorFile::new();
        tf.insert("alpha", TensorEntry::from_f32(vec![2, 2], &[1.0, 2.0, 3.0, 4.0]));
        tf.save(&path).unwrap();
        let tf2 = TensorFile::load(&path).unwrap();
        assert_eq!(tf2.vec_f32("alpha").unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let buf = b"NOPE\x00\x00\x00\x00".to_vec();
        assert!(TensorFile::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn missing_tensor_names_key() {
        let tf = TensorFile::new();
        let err = tf.get("absent").unwrap_err().to_string();
        assert!(err.contains("absent"));
    }

    #[test]
    fn non_2d_matrix_rejected() {
        let e = TensorEntry::from_f32(vec![8], &[0.0; 8]);
        assert!(e.to_matrix().is_err());
    }
}

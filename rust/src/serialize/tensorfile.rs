//! `.ptw` — PTQTP tensor-file container.
//!
//! Little-endian binary format shared between the Python build path
//! (`python/compile/ptw.py` writes `PTW1` checkpoints) and the Rust
//! engine. Two on-disk revisions exist:
//!
//! **`PTW1`** — plain named tensors only (what Python writes/reads):
//!
//! ```text
//! magic   : 4 bytes  = "PTW1"
//! count   : u32      = number of tensors
//! repeat count times:
//!   name_len : u32
//!   name     : utf-8 bytes
//!   dtype    : u8   (0=f32, 1=i8, 2=u8, 3=i32)
//!   ndim     : u32
//!   dims     : ndim × u64
//!   payload  : product(dims) × sizeof(dtype) bytes
//! ```
//!
//! **`PTW2`** — adds a packed-ternary record kind so quantized models
//! persist their trit-planes directly (quantize once, serve many — no
//! densify, no requantize). Every record gains a leading `kind` byte:
//!
//! ```text
//! magic   : 4 bytes  = "PTW2"
//! count   : u32
//! repeat count times:
//!   name_len : u32
//!   name     : utf-8 bytes
//!   kind     : u8   (0 = plain tensor, 1 = packed ternary linear)
//!   kind 0 → dtype/ndim/dims/payload exactly as in PTW1
//!   kind 1 →
//!     coding : u8   (0 = 2-bit rows [resident layout],
//!                    1 = base-3 rows [archival, 1.6 bits/trit])
//!     rows   : u64
//!     cols   : u64
//!     group  : u64  (column group size G of the α scales)
//!     stride : u64  (bytes per packed row in `coding`; alignment
//!                    metadata — must equal bytes_2bit(cols) or
//!                    bytes_base3(cols) respectively)
//!     p1     : rows × stride bytes   (plane T⁽¹⁾, row-aligned)
//!     p2     : rows × stride bytes   (plane T⁽²⁾, row-aligned)
//!     alpha1 : rows × ceil(cols/G) × f32 LE
//!     alpha2 : rows × ceil(cols/G) × f32 LE
//! ```
//!
//! The writer emits `PTW1` whenever no packed records are present (so
//! FP checkpoints stay readable by the Python tooling) and `PTW2`
//! otherwise; the reader accepts both. Plane payloads default to the
//! base-3 archival coding — trits survive either coding exactly, and
//! base-3 is what brings a ternary layer to ≤ 1/8 of its FP32
//! serialization while the α scales stay lossless f32 (bit-exact
//! round-trip is a hard requirement of the serving parity tests).
//! Readers decode both codings back to the resident 2-bit layout.

use crate::ternary::linear::PackedTernaryLinear;
use crate::ternary::pack::{bytes_2bit, bytes_base3, pack2bit, unpack2bit, unpack_base3};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC_V1: &[u8; 4] = b"PTW1";
const MAGIC_V2: &[u8; 4] = b"PTW2";

/// Hard ceiling on a single record's payload; a hostile header past it
/// is rejected before any allocation happens.
const MAX_PAYLOAD_BYTES: usize = 1 << 34; // 16 GiB

/// Supported element types.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32 = 0,
    I8 = 1,
    U8 = 2,
    I32 = 3,
}

impl DType {
    fn from_u8(x: u8) -> anyhow::Result<DType> {
        Ok(match x {
            0 => DType::F32,
            1 => DType::I8,
            2 => DType::U8,
            3 => DType::I32,
            other => anyhow::bail!("unknown dtype tag {other}"),
        })
    }

    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 | DType::U8 => 1,
        }
    }
}

/// On-disk coding of the packed trit-plane payloads (PTW2 kind-1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlaneCoding {
    /// 4 trits/byte — mirrors the resident kernel layout, zero-transform load.
    TwoBit = 0,
    /// 5 trits/byte (3⁵ = 243 ≤ 256) — the dense archival default.
    Base3 = 1,
}

impl PlaneCoding {
    fn from_u8(x: u8) -> anyhow::Result<PlaneCoding> {
        Ok(match x {
            0 => PlaneCoding::TwoBit,
            1 => PlaneCoding::Base3,
            other => anyhow::bail!("unknown plane coding {other}"),
        })
    }

    /// Bytes per packed row of `cols` trits in this coding.
    pub fn row_bytes(self, cols: usize) -> usize {
        match self {
            PlaneCoding::TwoBit => bytes_2bit(cols),
            PlaneCoding::Base3 => bytes_base3(cols),
        }
    }
}

/// One named tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorEntry {
    pub dtype: DType,
    pub dims: Vec<usize>,
    /// Raw little-endian payload.
    pub bytes: Vec<u8>,
}

impl TensorEntry {
    pub fn from_f32(dims: Vec<usize>, data: &[f32]) -> TensorEntry {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for &x in data {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        TensorEntry {
            dtype: DType::F32,
            dims,
            bytes,
        }
    }

    pub fn from_i8(dims: Vec<usize>, data: &[i8]) -> TensorEntry {
        // checked fast path: validate the shape with overflow-checked
        // arithmetic, then reinterpret the payload with a presized cast
        // loop (i8 and u8 are layout-identical, so this lowers to a
        // memcpy — no iterator-collect bookkeeping per element)
        let numel = dims
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .expect("tensor dims product overflows");
        assert_eq!(numel, data.len(), "dims {dims:?} vs {} elements", data.len());
        let mut bytes = vec![0u8; data.len()];
        for (dst, &src) in bytes.iter_mut().zip(data) {
            *dst = src as u8;
        }
        TensorEntry {
            dtype: DType::I8,
            dims,
            bytes,
        }
    }

    pub fn from_u8(dims: Vec<usize>, data: Vec<u8>) -> TensorEntry {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        TensorEntry {
            dtype: DType::U8,
            dims,
            bytes: data,
        }
    }

    pub fn to_f32(&self) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(self.dtype == DType::F32, "tensor is not f32");
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    pub fn to_i8(&self) -> anyhow::Result<Vec<i8>> {
        anyhow::ensure!(self.dtype == DType::I8, "tensor is not i8");
        // reinterpret the byte payload in place: i8/u8 share a layout,
        // so a presized safe cast loop replaces the per-element
        // map/collect round-trip (the compiler lowers it to a memcpy)
        let mut out = vec![0i8; self.bytes.len()];
        for (dst, &src) in out.iter_mut().zip(&self.bytes) {
            *dst = src as i8;
        }
        Ok(out)
    }

    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    /// View as a [`crate::tensor::Matrix`]; requires 2-D f32.
    pub fn to_matrix(&self) -> anyhow::Result<crate::tensor::Matrix> {
        anyhow::ensure!(self.dims.len() == 2, "tensor is not 2-D: {:?}", self.dims);
        Ok(crate::tensor::Matrix::from_vec(
            self.dims[0],
            self.dims[1],
            self.to_f32()?,
        ))
    }
}

/// Ordered collection of named tensors: plain entries plus (PTW2)
/// packed ternary linears. The two namespaces are disjoint.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TensorFile {
    pub tensors: BTreeMap<String, TensorEntry>,
    /// Packed trit-plane records, kept in the resident 2-bit layout
    /// (whatever the on-disk coding was).
    pub packed: BTreeMap<String, PackedTernaryLinear>,
}

impl TensorFile {
    pub fn new() -> TensorFile {
        TensorFile::default()
    }

    pub fn insert(&mut self, name: &str, entry: TensorEntry) {
        assert!(
            !self.packed.contains_key(name),
            "'{name}' already present as a packed record"
        );
        self.tensors.insert(name.to_string(), entry);
    }

    pub fn insert_matrix(&mut self, name: &str, m: &crate::tensor::Matrix) {
        self.insert(name, TensorEntry::from_f32(vec![m.rows, m.cols], &m.data));
    }

    /// Add a packed ternary linear under `name` (forces the `PTW2`
    /// revision on write).
    pub fn insert_packed(&mut self, name: &str, lin: &PackedTernaryLinear) {
        assert!(
            !self.tensors.contains_key(name),
            "'{name}' already present as a plain tensor"
        );
        debug_assert_eq!(lin.row_stride, bytes_2bit(lin.cols));
        self.packed.insert(name.to_string(), lin.clone());
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&TensorEntry> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("tensor '{name}' not found in checkpoint"))
    }

    /// Packed record under `name`, if any.
    pub fn get_packed(&self, name: &str) -> Option<&PackedTernaryLinear> {
        self.packed.get(name)
    }

    /// True when `name` exists as either a plain or a packed record.
    pub fn has(&self, name: &str) -> bool {
        self.tensors.contains_key(name) || self.packed.contains_key(name)
    }

    /// On-disk revision this file serializes as.
    pub fn format(&self) -> &'static str {
        if self.packed.is_empty() {
            "PTW1"
        } else {
            "PTW2"
        }
    }

    pub fn matrix(&self, name: &str) -> anyhow::Result<crate::tensor::Matrix> {
        self.get(name)?.to_matrix()
    }

    pub fn vec_f32(&self, name: &str) -> anyhow::Result<Vec<f32>> {
        self.get(name)?.to_f32()
    }

    // ---------- io ----------

    /// Serialize with the default archival plane coding (base-3).
    pub fn write_to(&self, w: &mut impl Write) -> anyhow::Result<()> {
        self.write_to_coded(w, PlaneCoding::Base3)
    }

    /// Serialize with an explicit plane coding for packed records.
    /// `PTW1` is emitted when there are no packed records (Python
    /// interop); `PTW2` otherwise.
    pub fn write_to_coded(&self, w: &mut impl Write, coding: PlaneCoding) -> anyhow::Result<()> {
        let v2 = !self.packed.is_empty();
        w.write_all(if v2 { MAGIC_V2 } else { MAGIC_V1 })?;
        let count = self.tensors.len() + self.packed.len();
        w.write_all(&(count as u32).to_le_bytes())?;

        // deterministic order: merged name-sorted view over both maps
        enum Rec<'a> {
            Plain(&'a TensorEntry),
            Packed(&'a PackedTernaryLinear),
        }
        let mut recs: BTreeMap<&str, Rec> = BTreeMap::new();
        for (name, t) in &self.tensors {
            recs.insert(name, Rec::Plain(t));
        }
        for (name, p) in &self.packed {
            anyhow::ensure!(
                recs.insert(name, Rec::Packed(p)).is_none(),
                "duplicate record name '{name}'"
            );
        }

        for (name, rec) in recs {
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            match rec {
                Rec::Plain(t) => {
                    if v2 {
                        w.write_all(&[0u8])?; // kind: plain
                    }
                    w.write_all(&[t.dtype as u8])?;
                    w.write_all(&(t.dims.len() as u32).to_le_bytes())?;
                    for &d in &t.dims {
                        w.write_all(&(d as u64).to_le_bytes())?;
                    }
                    anyhow::ensure!(
                        t.bytes.len() == t.numel() * t.dtype.size(),
                        "payload size mismatch for '{name}'"
                    );
                    w.write_all(&t.bytes)?;
                }
                Rec::Packed(p) => {
                    debug_assert!(v2);
                    write_packed(w, name, p, coding)?;
                }
            }
        }
        Ok(())
    }

    pub fn save(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
        self.write_to(&mut f)
    }

    pub fn read_from(r: &mut impl Read) -> anyhow::Result<TensorFile> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        let v2 = match &magic {
            m if m == MAGIC_V1 => false,
            m if m == MAGIC_V2 => true,
            _ => anyhow::bail!("bad magic: {magic:?} (expected PTW1 or PTW2)"),
        };
        let count = read_u32(r)? as usize;
        anyhow::ensure!(count < 1 << 24, "unreasonable tensor count {count}");
        let mut tf = TensorFile::new();
        for _ in 0..count {
            let name_len = read_u32(r)? as usize;
            anyhow::ensure!(name_len < 4096, "unreasonable name length {name_len}");
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name)?;
            let kind = if v2 { read_u8(r)? } else { 0 };
            match kind {
                0 => {
                    let entry = read_plain(r, &name)?;
                    anyhow::ensure!(!tf.has(&name), "duplicate record '{name}'");
                    tf.insert(&name, entry);
                }
                1 => {
                    let lin = read_packed(r, &name)?;
                    anyhow::ensure!(!tf.has(&name), "duplicate record '{name}'");
                    tf.insert_packed(&name, &lin);
                }
                other => anyhow::bail!("unknown record kind {other} for '{name}'"),
            }
        }
        Ok(tf)
    }

    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<TensorFile> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path.as_ref())
                .map_err(|e| anyhow::anyhow!("open {:?}: {e}", path.as_ref()))?,
        );
        TensorFile::read_from(&mut f)
    }
}

fn write_packed(
    w: &mut impl Write,
    name: &str,
    p: &PackedTernaryLinear,
    coding: PlaneCoding,
) -> anyhow::Result<()> {
    let gpr = p.groups_per_row();
    anyhow::ensure!(
        p.row_stride == bytes_2bit(p.cols),
        "packed '{name}': resident stride {} != bytes_2bit({})",
        p.row_stride,
        p.cols
    );
    anyhow::ensure!(
        p.p1.len() == p.rows * p.row_stride && p.p2.len() == p.rows * p.row_stride,
        "packed '{name}': plane payload size mismatch"
    );
    anyhow::ensure!(
        p.alpha1.len() == p.rows * gpr && p.alpha2.len() == p.rows * gpr,
        "packed '{name}': scale length mismatch"
    );
    w.write_all(&[1u8])?; // kind: packed ternary
    w.write_all(&[coding as u8])?;
    w.write_all(&(p.rows as u64).to_le_bytes())?;
    w.write_all(&(p.cols as u64).to_le_bytes())?;
    w.write_all(&(p.group as u64).to_le_bytes())?;
    let stride = coding.row_bytes(p.cols);
    w.write_all(&(stride as u64).to_le_bytes())?;
    for plane in [&p.p1, &p.p2] {
        match coding {
            PlaneCoding::TwoBit => w.write_all(plane)?,
            PlaneCoding::Base3 => {
                // re-encode row-by-row so rows stay byte-aligned (the
                // stride metadata stays meaningful in both codings)
                for row in 0..p.rows {
                    let src = &plane[row * p.row_stride..(row + 1) * p.row_stride];
                    let trits = unpack2bit(src, p.cols);
                    let mut enc = crate::ternary::pack_base3(&trits);
                    enc.resize(stride, 0);
                    w.write_all(&enc)?;
                }
            }
        }
    }
    for alphas in [&p.alpha1, &p.alpha2] {
        for &a in alphas.iter() {
            w.write_all(&a.to_le_bytes())?;
        }
    }
    Ok(())
}

fn read_plain(r: &mut impl Read, name: &str) -> anyhow::Result<TensorEntry> {
    let dtype = DType::from_u8(read_u8(r)?)?;
    let ndim = read_u32(r)? as usize;
    anyhow::ensure!(ndim <= 8, "unreasonable rank {ndim} for '{name}'");
    let mut dims = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        dims.push(read_dim(r, name)?);
    }
    let numel = checked_product(&dims)
        .ok_or_else(|| anyhow::anyhow!("dims product overflows for '{name}': {dims:?}"))?;
    let payload = numel
        .checked_mul(dtype.size())
        .filter(|&n| n <= MAX_PAYLOAD_BYTES)
        .ok_or_else(|| anyhow::anyhow!("payload size overflows for '{name}': {dims:?}"))?;
    let mut bytes = vec![0u8; payload];
    r.read_exact(&mut bytes)
        .map_err(|e| anyhow::anyhow!("truncated payload for '{name}' ({payload} bytes): {e}"))?;
    Ok(TensorEntry { dtype, dims, bytes })
}

fn read_packed(r: &mut impl Read, name: &str) -> anyhow::Result<PackedTernaryLinear> {
    let coding = PlaneCoding::from_u8(read_u8(r)?)
        .map_err(|e| anyhow::anyhow!("packed '{name}': {e}"))?;
    let rows = read_dim(r, name)?;
    let cols = read_dim(r, name)?;
    let group = read_dim(r, name)?;
    let stride = read_dim(r, name)?;
    anyhow::ensure!(group > 0, "packed '{name}': group size must be positive");
    anyhow::ensure!(
        stride == coding.row_bytes(cols),
        "packed '{name}': stride {stride} inconsistent with cols {cols} under {coding:?}"
    );
    let plane_bytes = rows
        .checked_mul(stride)
        .filter(|&n| n <= MAX_PAYLOAD_BYTES)
        .ok_or_else(|| anyhow::anyhow!("plane size overflows for '{name}' ({rows}×{stride})"))?;
    let gpr = cols.div_ceil(group);
    let alpha_len = rows
        .checked_mul(gpr)
        .filter(|&n| n.checked_mul(4).is_some_and(|b| b <= MAX_PAYLOAD_BYTES))
        .ok_or_else(|| anyhow::anyhow!("scale size overflows for '{name}' ({rows}×{gpr})"))?;

    let row_stride = bytes_2bit(cols);
    let mut planes: [Vec<u8>; 2] = [Vec::new(), Vec::new()];
    for plane in planes.iter_mut() {
        let mut raw = vec![0u8; plane_bytes];
        r.read_exact(&mut raw)
            .map_err(|e| anyhow::anyhow!("truncated plane for '{name}': {e}"))?;
        *plane = match coding {
            PlaneCoding::TwoBit => raw,
            PlaneCoding::Base3 => {
                // decode each archival row back to the resident 2-bit layout
                let mut out = vec![0u8; rows * row_stride];
                for row in 0..rows {
                    let trits = unpack_base3(&raw[row * stride..(row + 1) * stride], cols);
                    let packed = pack2bit(&trits);
                    out[row * row_stride..row * row_stride + packed.len()]
                        .copy_from_slice(&packed);
                }
                out
            }
        };
    }
    let mut alphas: [Vec<f32>; 2] = [Vec::new(), Vec::new()];
    for alpha in alphas.iter_mut() {
        let mut bytes = vec![0u8; alpha_len * 4];
        r.read_exact(&mut bytes)
            .map_err(|e| anyhow::anyhow!("truncated scales for '{name}': {e}"))?;
        *alpha = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
    }
    let [p1, p2] = planes;
    let [alpha1, alpha2] = alphas;
    // NOTE: the derived SIMD interleave is NOT built here — the
    // serializer stays layout-agnostic (re-save and inspection paths
    // would pay the build + ~2x plane memory for nothing). The model
    // layer rebuilds it where serving starts: `QuantLinear::from_packed`.
    Ok(PackedTernaryLinear {
        rows,
        cols,
        group,
        row_stride,
        p1,
        p2,
        alpha1,
        alpha2,
        interleave: None,
    })
}

fn checked_product(dims: &[usize]) -> Option<usize> {
    dims.iter().try_fold(1usize, |acc, &d| acc.checked_mul(d))
}

fn read_u8(r: &mut impl Read) -> anyhow::Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

fn read_u32(r: &mut impl Read) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_dim(r: &mut impl Read, name: &str) -> anyhow::Result<usize> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    usize::try_from(u64::from_le_bytes(b))
        .map_err(|_| anyhow::anyhow!("dimension overflows usize for '{name}'"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::Matrix;
    use crate::ternary::TernaryLinear;

    fn random_packed(rows: usize, cols: usize, group: usize, seed: u64) -> PackedTernaryLinear {
        let mut rng = Rng::new(seed);
        let mut lin = TernaryLinear::new(rows, cols, group);
        for t in lin.t1.trits.iter_mut().chain(lin.t2.trits.iter_mut()) {
            *t = rng.below(3) as i8 - 1;
        }
        for a in lin.alpha1.iter_mut().chain(lin.alpha2.iter_mut()) {
            *a = rng.normal() * 0.1;
        }
        lin.to_packed()
    }

    #[test]
    fn roundtrip_in_memory() {
        let mut rng = Rng::new(1);
        let m = Matrix::randn(5, 7, 1.0, &mut rng);
        let mut tf = TensorFile::new();
        tf.insert_matrix("w.0", &m);
        tf.insert("trits", TensorEntry::from_i8(vec![3, 2], &[-1, 0, 1, 1, 0, -1]));
        tf.insert("packed", TensorEntry::from_u8(vec![4], vec![0xde, 0xad, 0xbe, 0xef]));

        let mut buf = Vec::new();
        tf.write_to(&mut buf).unwrap();
        assert_eq!(&buf[..4], b"PTW1", "dense-only files stay PTW1");
        let tf2 = TensorFile::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(tf, tf2);
        assert_eq!(tf2.matrix("w.0").unwrap(), m);
        assert_eq!(tf2.get("trits").unwrap().to_i8().unwrap(), vec![-1, 0, 1, 1, 0, -1]);
    }

    #[test]
    fn i8_cast_roundtrip_full_range() {
        // the presized cast loops must reinterpret every i8 value
        // exactly, sign bit included
        let all: Vec<i8> = (-128i16..=127).map(|v| v as i8).collect();
        let e = TensorEntry::from_i8(vec![16, 16], &all);
        assert_eq!(e.bytes.len(), 256);
        assert_eq!(e.to_i8().unwrap(), all);
        assert!(e.to_f32().is_err(), "dtype check still enforced");
    }

    #[test]
    fn roundtrip_on_disk() {
        let dir = std::env::temp_dir().join("ptqtp_test_tf");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.ptw");
        let mut tf = TensorFile::new();
        tf.insert("alpha", TensorEntry::from_f32(vec![2, 2], &[1.0, 2.0, 3.0, 4.0]));
        tf.save(&path).unwrap();
        let tf2 = TensorFile::load(&path).unwrap();
        assert_eq!(tf2.vec_f32("alpha").unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn packed_roundtrip_both_codings() {
        // aligned (G=4-divisible cols) and ragged cols/groups, zero-plane
        // rows included: trits and f32 scales must survive bit-exactly in
        // either plane coding
        for (rows, cols, group) in [(6usize, 16usize, 4usize), (9, 37, 8), (3, 10, 128)] {
            let mut p = random_packed(rows, cols, group, 7 + cols as u64);
            // row 0: all-zero planes and scales (converged-to-zero group)
            for b in p.p1[..p.row_stride].iter_mut() {
                *b = 0;
            }
            for b in p.p2[..p.row_stride].iter_mut() {
                *b = 0;
            }
            let gpr = p.groups_per_row();
            for a in p.alpha1[..gpr].iter_mut().chain(p.alpha2[..gpr].iter_mut()) {
                *a = 0.0;
            }
            let mut tf = TensorFile::new();
            tf.insert_packed("w", &p);
            tf.insert_matrix("dense", &Matrix::from_vec(1, 2, vec![0.5, -0.5]));
            assert_eq!(tf.format(), "PTW2");
            for coding in [PlaneCoding::TwoBit, PlaneCoding::Base3] {
                let mut buf = Vec::new();
                tf.write_to_coded(&mut buf, coding).unwrap();
                assert_eq!(&buf[..4], b"PTW2");
                let tf2 = TensorFile::read_from(&mut buf.as_slice()).unwrap();
                assert_eq!(tf, tf2, "coding {coding:?} ({rows}x{cols} G={group})");
                assert_eq!(tf2.get_packed("w").unwrap(), &p);
            }
        }
    }

    #[test]
    fn base3_coding_denser_than_two_bit() {
        let p = random_packed(32, 320, 128, 3);
        let mut tf = TensorFile::new();
        tf.insert_packed("w", &p);
        let mut b2 = Vec::new();
        tf.write_to_coded(&mut b2, PlaneCoding::TwoBit).unwrap();
        let mut b3 = Vec::new();
        tf.write_to_coded(&mut b3, PlaneCoding::Base3).unwrap();
        assert!(b3.len() < b2.len(), "{} !< {}", b3.len(), b2.len());
    }

    #[test]
    fn bad_magic_rejected() {
        let buf = b"NOPE\x00\x00\x00\x00".to_vec();
        let err = TensorFile::read_from(&mut buf.as_slice()).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");
    }

    #[test]
    fn truncated_payload_rejected_with_name() {
        let mut tf = TensorFile::new();
        tf.insert("weights", TensorEntry::from_f32(vec![4, 4], &[0.25; 16]));
        let mut buf = Vec::new();
        tf.write_to(&mut buf).unwrap();
        buf.truncate(buf.len() - 10);
        let err = TensorFile::read_from(&mut buf.as_slice()).unwrap_err().to_string();
        assert!(err.contains("weights"), "{err}");
    }

    #[test]
    fn truncated_packed_rejected() {
        let mut tf = TensorFile::new();
        tf.insert_packed("w", &random_packed(4, 16, 8, 5));
        let mut buf = Vec::new();
        tf.write_to(&mut buf).unwrap();
        buf.truncate(buf.len() - 4);
        assert!(TensorFile::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn dims_product_overflow_rejected() {
        // hand-craft a PTW1 header whose dims product overflows usize
        let mut buf = Vec::new();
        buf.extend_from_slice(b"PTW1");
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.push(b'x');
        buf.push(0); // dtype f32
        buf.extend_from_slice(&2u32.to_le_bytes()); // ndim
        buf.extend_from_slice(&(u64::MAX / 2).to_le_bytes());
        buf.extend_from_slice(&16u64.to_le_bytes());
        let err = TensorFile::read_from(&mut buf.as_slice()).unwrap_err().to_string();
        assert!(err.contains("overflow"), "{err}");
    }

    #[test]
    fn packed_stride_mismatch_rejected() {
        let mut tf = TensorFile::new();
        tf.insert_packed("w", &random_packed(2, 16, 8, 9));
        let mut buf = Vec::new();
        tf.write_to_coded(&mut buf, PlaneCoding::TwoBit).unwrap();
        // stride field sits after magic(4)+count(4)+name_len(4)+name(1)
        // +kind(1)+coding(1)+rows(8)+cols(8)+group(8)
        let stride_off = 4 + 4 + 4 + 1 + 1 + 1 + 8 + 8 + 8;
        buf[stride_off] = buf[stride_off].wrapping_add(1);
        let err = TensorFile::read_from(&mut buf.as_slice()).unwrap_err().to_string();
        assert!(err.contains("stride"), "{err}");
    }

    #[test]
    fn unknown_kind_and_coding_rejected() {
        let mut tf = TensorFile::new();
        tf.insert_packed("w", &random_packed(2, 8, 8, 11));
        let mut buf = Vec::new();
        tf.write_to(&mut buf).unwrap();
        let kind_off = 4 + 4 + 4 + 1;
        let mut bad_kind = buf.clone();
        bad_kind[kind_off] = 9;
        let err = TensorFile::read_from(&mut bad_kind.as_slice()).unwrap_err().to_string();
        assert!(err.contains("kind"), "{err}");
        let mut bad_coding = buf;
        bad_coding[kind_off + 1] = 7;
        let err = TensorFile::read_from(&mut bad_coding.as_slice()).unwrap_err().to_string();
        assert!(err.contains("coding"), "{err}");
    }

    #[test]
    fn missing_tensor_names_key() {
        let tf = TensorFile::new();
        let err = tf.get("absent").unwrap_err().to_string();
        assert!(err.contains("absent"));
    }

    #[test]
    fn non_2d_matrix_rejected() {
        let e = TensorEntry::from_f32(vec![8], &[0.0; 8]);
        assert!(e.to_matrix().is_err());
    }

    #[test]
    fn prop_packed_roundtrip() {
        use crate::proptest::{check, prop_assert, Gen};
        check(60, |g: &mut Gen| {
            let rows = g.usize_in(1, 12);
            let cols = g.usize_in(1, 70);
            let group = g.usize_in(1, 160);
            let p = random_packed(rows, cols, group, g.rng.next_u64());
            let mut tf = TensorFile::new();
            tf.insert_packed("w", &p);
            let coding = *g.pick(&[PlaneCoding::TwoBit, PlaneCoding::Base3]);
            let mut buf = Vec::new();
            tf.write_to_coded(&mut buf, coding).unwrap();
            let tf2 = TensorFile::read_from(&mut buf.as_slice()).unwrap();
            prop_assert(
                tf2.get_packed("w") == Some(&p),
                "packed roundtrip mismatch",
            )
        });
    }
}

//! Serialization substrates.
//!
//! The offline crate cache has no `serde`, so this module provides the
//! two formats the system needs, implemented from scratch:
//!
//! * [`json`] — a complete JSON parser/writer (configs, metadata,
//!   benchmark reports, checkpoint manifests shared with the Python
//!   build path).
//! * [`tensorfile`] — `.ptw`, a little-endian binary tensor container
//!   (magic + named f32/i8/u8 tensors; the `PTW2` revision adds packed
//!   trit-plane records) used for model checkpoints written by
//!   `python/compile/train.py` and read by the Rust engine, and for
//!   persisted quantized models (quantize once, serve many).
//! * [`manifest`] — the `X.manifest.json` checkpoint sidecar: method,
//!   quantizer options, quantization report, payload checksum.

pub mod json;
pub mod manifest;
pub mod tensorfile;

pub use json::Json;
pub use manifest::{CheckpointManifest, HashingReader, HashingWriter};
pub use tensorfile::{PlaneCoding, TensorEntry, TensorFile};

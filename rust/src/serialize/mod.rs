//! Serialization substrates.
//!
//! The offline crate cache has no `serde`, so this module provides the
//! two formats the system needs, implemented from scratch:
//!
//! * [`json`] — a complete JSON parser/writer (configs, metadata,
//!   benchmark reports, checkpoint manifests shared with the Python
//!   build path).
//! * [`tensorfile`] — `.ptw`, a little-endian binary tensor container
//!   (magic + named f32/i8/u8 tensors) used for model checkpoints
//!   written by `python/compile/train.py` and read by the Rust engine,
//!   and for persisted quantized models.

pub mod json;
pub mod tensorfile;

pub use json::Json;
pub use tensorfile::{TensorEntry, TensorFile};

//! Minimal-but-complete JSON implementation (RFC 8259 subset:
//! no surrogate-pair escapes beyond \uXXXX basic handling).
//!
//! Substrate for configs and reports — `serde` is unavailable offline.

use std::collections::BTreeMap;
use std::fmt;

/// JSON value. Object keys are sorted (BTreeMap) so output is
/// deterministic — important for content-hash-based artifact caching.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------- constructors ----------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), val.into());
        } else {
            panic!("set() on non-object Json");
        }
        self
    }

    // ---------- accessors ----------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// `get` chained with type coercion; errors name the key for
    /// actionable config diagnostics.
    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid numeric field '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid numeric field '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field '{key}'"))
    }

    // ---------- parsing ----------
    pub fn parse(src: &str) -> anyhow::Result<Json> {
        let mut p = Parser {
            s: src.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            anyhow::bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    /// Pretty-print with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        f.write_str(&out)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

impl Json {
    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    x.write(out, indent, pretty);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.s.len() && matches!(self.s[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            )
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> anyhow::Result<Json> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.s[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.s
                                    .get(self.i + 1..self.i + 5)
                                    .ok_or_else(|| anyhow::anyhow!("short \\u escape"))?,
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => anyhow::bail!("bad escape {:?}", other.map(|b| b as char)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.s[self.i..])?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(xs));
                }
                other => anyhow::bail!("expected , or ] (got {:?})", other.map(|b| b as char)),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => anyhow::bail!("expected , or }} (got {:?})", other.map(|b| b as char)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-3.5", "1e3", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            let re = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, re, "src={src}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn roundtrip_complex() {
        let v = Json::obj()
            .set("name", "ptqtp")
            .set("bits", 1.58f64)
            .set("sizes", vec![1usize, 2, 3])
            .set("nested", Json::obj().set("ok", true));
        let text = v.pretty();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("quote\" slash\\ tab\t nl\n unicode→".to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape_parses() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(128.0).to_string(), "128");
        assert_eq!(Json::Num(1.5).to_string(), "1.5");
    }

    #[test]
    fn req_accessors_error_with_key() {
        let v = Json::obj().set("g", 128usize);
        assert_eq!(v.req_usize("g").unwrap(), 128);
        let err = v.req_usize("missing").unwrap_err().to_string();
        assert!(err.contains("missing"), "{err}");
    }

    #[test]
    fn deterministic_key_order() {
        let a = Json::obj().set("z", 1usize).set("a", 2usize);
        assert_eq!(a.to_string(), r#"{"a":2,"z":1}"#);
    }
}

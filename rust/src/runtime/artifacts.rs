//! Artifact manifest: `artifacts/manifest.json` written by
//! `python/compile/aot.py` describing every lowered HLO module (name,
//! file, input shapes, outputs), so the Rust engine can validate calls
//! before handing them to PJRT.

use crate::serialize::Json;
use std::path::{Path, PathBuf};

/// One lowered module's metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    /// Input shapes in call order.
    pub inputs: Vec<Vec<usize>>,
    /// Number of tuple outputs.
    pub n_outputs: usize,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub specs: Vec<ArtifactSpec>,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<ArtifactManifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("read {:?}/manifest.json: {e} (run `make artifacts`)", dir))?;
        let j = Json::parse(&text)?;
        let arr = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'artifacts' array"))?;
        let mut specs = Vec::new();
        for a in arr {
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("artifact missing inputs"))?
                .iter()
                .map(|shape| {
                    shape
                        .as_arr()
                        .map(|dims| dims.iter().filter_map(Json::as_usize).collect())
                        .ok_or_else(|| anyhow::anyhow!("bad shape entry"))
                })
                .collect::<anyhow::Result<Vec<Vec<usize>>>>()?;
            specs.push(ArtifactSpec {
                name: a.req_str("name")?.to_string(),
                file: a.req_str("file")?.to_string(),
                inputs,
                n_outputs: a.req_usize("n_outputs")?,
            });
        }
        Ok(ArtifactManifest { dir, specs })
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&ArtifactSpec> {
        self.specs
            .iter()
            .find(|s| s.name == name)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest"))
    }

    pub fn path_of(&self, name: &str) -> anyhow::Result<PathBuf> {
        Ok(self.dir.join(&self.get(name)?.file))
    }

    /// Load every artifact listed in the manifest into `engine`.
    pub fn load_all(&self, engine: &mut super::PjrtEngine) -> anyhow::Result<()> {
        for s in &self.specs {
            engine.load_hlo_text(&s.name, self.dir.join(&s.file))?;
        }
        Ok(())
    }

    /// Validate input shapes against the spec before an execute call.
    pub fn check_inputs(&self, name: &str, shapes: &[&[usize]]) -> anyhow::Result<()> {
        let spec = self.get(name)?;
        anyhow::ensure!(
            spec.inputs.len() == shapes.len(),
            "artifact '{name}' expects {} inputs, got {}",
            spec.inputs.len(),
            shapes.len()
        );
        for (i, (want, got)) in spec.inputs.iter().zip(shapes).enumerate() {
            anyhow::ensure!(
                want.as_slice() == *got,
                "artifact '{name}' input {i}: expected shape {want:?}, got {got:?}"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": [
                {"name": "ternary_matmul", "file": "ternary_matmul.hlo.txt",
                 "inputs": [[4, 64], [16, 64], [16, 64], [16, 1], [16, 1]], "n_outputs": 1},
                {"name": "decode_step", "file": "decode_step.hlo.txt",
                 "inputs": [[1, 128]], "n_outputs": 2}
            ]}"#,
        )
        .unwrap();
    }

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join("ptqtp_manifest_test");
        write_manifest(&dir);
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.specs.len(), 2);
        let spec = m.get("ternary_matmul").unwrap();
        assert_eq!(spec.inputs.len(), 5);
        assert_eq!(spec.inputs[0], vec![4, 64]);
        assert!(m.path_of("decode_step").unwrap().ends_with("decode_step.hlo.txt"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shape_validation() {
        let dir = std::env::temp_dir().join("ptqtp_manifest_test2");
        write_manifest(&dir);
        let m = ArtifactManifest::load(&dir).unwrap();
        assert!(m.check_inputs("decode_step", &[&[1, 128]]).is_ok());
        assert!(m.check_inputs("decode_step", &[&[2, 128]]).is_err());
        assert!(m.check_inputs("decode_step", &[]).is_err());
        assert!(m.check_inputs("unknown", &[]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_mentions_make() {
        let err = ArtifactManifest::load("/nonexistent/dir").unwrap_err().to_string();
        assert!(err.contains("make artifacts"));
    }
}

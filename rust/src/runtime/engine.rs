//! The PJRT execution engine: compile HLO-text artifacts once, execute
//! many times from the Rust hot path.
//!
//! The real engine needs the `xla` crate (PJRT C-API bindings), which
//! is only present in some build environments. Gating is two-level so
//! the stub path can never rot unbuilt (CI checks it):
//!
//! * `--features pjrt` — opts into the PJRT runtime surface. On its
//!   own it still compiles the **stub** (same API, errors at
//!   construction), because the `xla` dependency may be absent from
//!   the offline crate cache.
//! * `RUSTFLAGS="--cfg xla_backend"` — asserts the environment has
//!   added `xla = "0.5"` under `[dependencies]`; only
//!   `pjrt` + `xla_backend` together compile the real engine.
//!
//! Every caller (CLI `runtime` subcommand, PJRT integration tests)
//! fails fast with a clear message on the stub instead of breaking
//! the build.

#[cfg(all(feature = "pjrt", xla_backend))]
mod imp {
    use crate::tensor::Matrix;
    use std::collections::BTreeMap;
    use std::path::Path;

    /// A compiled artifact registry bound to one PJRT client.
    pub struct PjrtEngine {
        client: xla::PjRtClient,
        executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    }

    impl PjrtEngine {
        /// CPU-backed engine (the only backend in this environment; the same
        /// HLO would compile for TPU through a TPU PJRT plugin).
        pub fn cpu() -> anyhow::Result<PjrtEngine> {
            let client =
                xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
            Ok(PjrtEngine {
                client,
                executables: BTreeMap::new(),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile one HLO text file under `name`.
        pub fn load_hlo_text(&mut self, name: &str, path: impl AsRef<Path>) -> anyhow::Result<()> {
            let path = path.as_ref();
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| anyhow::anyhow!("parse HLO text {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
            self.executables.insert(name.to_string(), exe);
            Ok(())
        }

        pub fn has(&self, name: &str) -> bool {
            self.executables.contains_key(name)
        }

        pub fn names(&self) -> Vec<&str> {
            self.executables.keys().map(String::as_str).collect()
        }

        /// Execute an artifact on f32 inputs. Each input is (shape, data);
        /// the module's tuple output is flattened to a list of f32 vectors.
        pub fn run_f32(
            &self,
            name: &str,
            inputs: &[(&[usize], &[f32])],
        ) -> anyhow::Result<Vec<Vec<f32>>> {
            let exe = self
                .executables
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not loaded"))?;
            let mut literals = Vec::with_capacity(inputs.len());
            for (shape, data) in inputs {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| anyhow::anyhow!("reshape input for {name}: {e:?}"))?;
                literals.push(lit);
            }
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
            let out_lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetch result of {name}: {e:?}"))?;
            // outputs are lowered with return_tuple=True
            let elements = out_lit
                .to_tuple()
                .map_err(|e| anyhow::anyhow!("decompose tuple of {name}: {e:?}"))?;
            let mut out = Vec::with_capacity(elements.len());
            for el in elements {
                out.push(
                    el.to_vec::<f32>()
                        .map_err(|e| anyhow::anyhow!("read f32 output of {name}: {e:?}"))?,
                );
            }
            Ok(out)
        }

        /// Convenience: single-output artifact on matrix inputs.
        pub fn run_matrices(&self, name: &str, inputs: &[&Matrix]) -> anyhow::Result<Vec<f32>> {
            let shaped: Vec<(Vec<usize>, &[f32])> = inputs
                .iter()
                .map(|m| (vec![m.rows, m.cols], m.data.as_slice()))
                .collect();
            let borrowed: Vec<(&[usize], &[f32])> =
                shaped.iter().map(|(s, d)| (s.as_slice(), *d)).collect();
            let mut outs = self.run_f32(name, &borrowed)?;
            anyhow::ensure!(!outs.is_empty(), "artifact '{name}' produced no outputs");
            Ok(outs.remove(0))
        }
    }
}

#[cfg(not(all(feature = "pjrt", xla_backend)))]
mod imp {
    use crate::tensor::Matrix;
    use std::path::Path;

    const UNAVAILABLE: &str = if cfg!(feature = "pjrt") {
        "PJRT runtime unavailable: built with the `pjrt` feature but without the XLA \
         backend (add `xla = \"0.5\"` to [dependencies] and rebuild with \
         RUSTFLAGS=\"--cfg xla_backend\")"
    } else {
        "PJRT runtime unavailable: ptqtp was built without the `pjrt` feature \
         (rebuild with `--features pjrt`, the `xla` crate in the crate cache, and \
         RUSTFLAGS=\"--cfg xla_backend\")"
    };

    /// Stub with the same API as the real engine; errors at construction.
    pub struct PjrtEngine {
        _priv: (),
    }

    impl PjrtEngine {
        pub fn cpu() -> anyhow::Result<PjrtEngine> {
            anyhow::bail!("{UNAVAILABLE}")
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn load_hlo_text(
            &mut self,
            _name: &str,
            _path: impl AsRef<Path>,
        ) -> anyhow::Result<()> {
            anyhow::bail!("{UNAVAILABLE}")
        }

        pub fn has(&self, _name: &str) -> bool {
            false
        }

        pub fn names(&self) -> Vec<&str> {
            Vec::new()
        }

        pub fn run_f32(
            &self,
            _name: &str,
            _inputs: &[(&[usize], &[f32])],
        ) -> anyhow::Result<Vec<Vec<f32>>> {
            anyhow::bail!("{UNAVAILABLE}")
        }

        pub fn run_matrices(&self, _name: &str, _inputs: &[&Matrix]) -> anyhow::Result<Vec<f32>> {
            anyhow::bail!("{UNAVAILABLE}")
        }
    }
}

pub use imp::PjrtEngine;

impl std::fmt::Debug for PjrtEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtEngine")
            .field("platform", &self.platform())
            .field("artifacts", &self.names())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    // PJRT-dependent tests live in `rust/tests/integration.rs` (they
    // need artifacts from `make artifacts`); here we only check engine
    // construction and error paths that need no artifacts.
    use super::*;

    #[test]
    #[cfg(all(feature = "pjrt", xla_backend))]
    fn cpu_engine_constructs() {
        let engine = PjrtEngine::cpu().expect("PJRT CPU client");
        assert!(!engine.platform().is_empty());
    }

    #[test]
    #[cfg(all(feature = "pjrt", xla_backend))]
    fn missing_artifact_errors() {
        let engine = PjrtEngine::cpu().unwrap();
        let err = engine.run_f32("nope", &[]).unwrap_err().to_string();
        assert!(err.contains("nope"));
    }

    #[test]
    #[cfg(all(feature = "pjrt", xla_backend))]
    fn bad_path_errors() {
        let mut engine = PjrtEngine::cpu().unwrap();
        assert!(engine
            .load_hlo_text("x", "/definitely/not/here.hlo.txt")
            .is_err());
    }

    #[test]
    #[cfg(not(all(feature = "pjrt", xla_backend)))]
    fn stub_errors_with_clear_message() {
        let err = PjrtEngine::cpu().unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
    }
}

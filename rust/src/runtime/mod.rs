//! PJRT runtime — loads and executes the AOT HLO artifacts produced by
//! `python/compile/aot.py` (`make artifacts`).
//!
//! Interchange is HLO **text**: jax ≥ 0.5 serializes HloModuleProto with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the
//! text parser reassigns ids (see /opt/xla-example/README.md). All
//! modules are lowered with `return_tuple=True`, so results unwrap via
//! `to_tuple1` for single outputs.
//!
//! Python never runs at serving time: the artifacts are compiled once at
//! engine start and executed natively through the PJRT C API.

pub mod artifacts;
pub mod engine;

pub use artifacts::ArtifactManifest;
pub use engine::PjrtEngine;

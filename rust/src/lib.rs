//! # PTQTP — Post-Training Quantization to Trit-Planes for LLMs
//!
//! Full-system reproduction of the PTQTP paper as a three-layer stack:
//!
//! * **L3 (this crate)** — the deployable coordinator: quantization
//!   pipeline, serving engine (router / continuous batcher / KV-cache /
//!   scheduler), native implementations of PTQTP and every baseline
//!   quantizer, a complete inference transformer, evaluation suites, and
//!   the benchmark harness that regenerates every table and figure in the
//!   paper.
//! * **L2 (python/compile)** — the JAX model + quantization graphs,
//!   AOT-lowered to HLO text at build time (`make artifacts`).
//! * **L1 (python/compile/kernels)** — Pallas kernels (trit-plane matmul,
//!   PTQTP iteration step) called from L2, verified against a pure-jnp
//!   oracle.
//!
//! Python never runs on the request path: `rust/src/runtime` loads the
//! AOT artifacts through the PJRT C API (`xla` crate) and everything else
//! is native Rust.
//!
//! ## Crate map
//!
//! | module | role |
//! |---|---|
//! | [`tensor`] | dense f32 matrix/vector substrate |
//! | [`rng`] | deterministic xoshiro256** PRNG substrate |
//! | [`serialize`] | JSON + binary tensor/checkpoint formats |
//! | [`cli`] | argument-parsing substrate |
//! | [`ternary`] | trit-plane storage, bit-packing, multiply-free kernels |
//! | [`quant`] | PTQTP (paper §3) + RTN/GPTQ/AWQ/PB-LLM/BiLLM/ARB-LLM baselines |
//! | [`model`] | decoder-only transformer (RMSNorm/RoPE/GQA/SwiGLU) |
//! | [`data`] | synthetic corpora, tasks, tokenizer |
//! | [`eval`] | perplexity + task-accuracy evaluators |
//! | [`runtime`] | PJRT engine for AOT HLO artifacts |
//! | [`threads`] | deterministic row-parallel worker pool substrate |
//! | [`coordinator`] | serving engine: router, batcher, kv-cache, scheduler |
//! | [`bench`] | timing harness + per-table/figure reproductions |
//! | [`report`] | table rendering for paper-style output |
//! | [`proptest`] | mini property-testing substrate |

pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod model;
pub mod proptest;
pub mod quant;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod serialize;
pub mod tensor;
pub mod ternary;
pub mod threads;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Paper constants (§3, §4.1) collected in one place.
pub mod consts {
    /// Default group size G (paper §3.2, "we set G=128").
    pub const GROUP_SIZE: usize = 128;
    /// Default maximum progressive-search iterations T_max (paper §4.1).
    pub const T_MAX: usize = 50;
    /// Default convergence tolerance ε (paper §4.1).
    pub const EPSILON: f32 = 1e-4;
    /// Initial ridge regularization λ₀ (paper Appendix B).
    pub const LAMBDA_INIT: f32 = 1e-8;
    /// Maximum ridge regularization λ_max (paper Eq. 3).
    pub const LAMBDA_MAX: f32 = 1.0;
    /// Condition-number threshold triggering λ adaptation (paper Eq. 3).
    pub const KAPPA_THRESHOLD: f64 = 1e12;
    /// Effective bits per weight for the 2-trit-plane format:
    /// two planes at log2(3) ≈ 1.58 bits each, stored as 2-bit fields.
    pub const PTQTP_BITS: f64 = 2.0 * 1.58;
}

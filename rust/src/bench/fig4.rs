//! Fig. 4 reproduction: tolerance-bound (ε) ablation — quantization
//! time vs perplexity trade-off.
//!
//! Paper shape: tightening ε improves PPL at super-linear time cost;
//! returns diminish past ε ≈ 1e-2, giving the recommended
//! ε ∈ [1e-3, 1e-2] operating range.

use super::workload::{ppl_quick, Zoo};
use crate::cli::Args;
use crate::quant::{Ptqtp, PtqtpOpts, QuantCtx};
use crate::report::{ascii_plot, Table};

pub fn run(quick: bool, args: &Args) -> anyhow::Result<()> {
    let fams: Vec<&str> = if quick { vec!["small"] } else { vec!["small", "medium"] };
    let zoo = Zoo::load(&fams);
    println!("{}", zoo.banner());
    let budget = if quick { 1000 } else { 2000 };
    let group = args.usize_or("group-size", 128);
    let eps_grid: Vec<f32> = if quick {
        vec![1e-1, 1e-3]
    } else {
        vec![0.5, 1e-1, 1e-2, 1e-3, 1e-4, 1e-5]
    };

    for (name, model) in &zoo.models {
        let mut table = Table::new(
            &format!("Fig 4 — tolerance ε ablation, {name}"),
            &["eps", "quant time (ms)", "wiki-syn PPL"],
        );
        let mut xs = Vec::new();
        let mut ppls = Vec::new();
        let mut times = Vec::new();
        for &eps in &eps_grid {
            let q = Ptqtp::new(PtqtpOpts {
                group,
                eps,
                ..Default::default()
            });
            let mut m = model.clone();
            let t0 = std::time::Instant::now();
            m.quantize_with(&q, &QuantCtx::default());
            let dur = t0.elapsed();
            let ppl = ppl_quick(&m, &zoo.tok, &zoo.eval_texts["wiki-syn"], budget);
            table.row(vec![
                format!("{eps:.0e}"),
                format!("{:.1}", dur.as_secs_f64() * 1e3),
                crate::report::fmt_metric(ppl),
            ]);
            xs.push(-(eps as f64).log10());
            ppls.push(ppl);
            times.push(dur.as_secs_f64() * 1e3);
        }
        println!("{}", table.render());
        println!("{}", ascii_plot(
            &format!("PPL vs -log10(eps) ({name})"),
            &xs,
            &[("ppl", ppls)],
            8,
        ));
        println!("{}", ascii_plot(
            &format!("quant time (ms) vs -log10(eps) ({name})"),
            &xs,
            &[("ms", times)],
            8,
        ));
    }
    Ok(())
}

//! Table 6 reproduction: full attention-layer decode latency, FP vs
//! PTQTP, across model scales — reporting the speedup ratio.
//!
//! Paper shape: PTQTP attention decode is slightly *faster* than FP16
//! (weight-memory-bound decode benefits from 4× smaller weights),
//! with the ratio growing with model size (1.14×–1.16× on 7B–70B).

use super::harness::bench_fn;
use super::workload::Zoo;
use crate::cli::Args;
use crate::model::KvCache;
use crate::report::Table;
use crate::quant::{ptqtp::Ptqtp, QuantCtx};
use std::time::Duration;

pub fn run(quick: bool, _args: &Args) -> anyhow::Result<()> {
    let families: Vec<&str> = if quick {
        vec!["small", "medium"]
    } else {
        vec!["tiny", "small", "medium", "large"]
    };
    let zoo = Zoo::load(&families);
    println!("{}", zoo.banner());
    let budget = Duration::from_millis(if quick { 300 } else { 1500 });
    let ctx_len = 64usize;

    let mut table = Table::new(
        "Table 6 — attention decode latency (us) and speedup",
        &["Model", "FP32", "PTQTP-1.58bit", "Speedup"],
    );
    for (name, model) in &zoo.models {
        let block = &model.blocks[0];
        let attn_fp = block.attn.clone();
        let mut attn_q = block.attn.clone();
        let q = Ptqtp::default();
        let ctx = QuantCtx::default();
        attn_q.wq.quantize_with(&q, &ctx);
        attn_q.wk.quantize_with(&q, &ctx);
        attn_q.wv.quantize_with(&q, &ctx);
        attn_q.wo.quantize_with(&q, &ctx);

        let d = model.config.d_model;
        let mut rng = crate::rng::Rng::new(3);
        let x: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let rope = &model.rope;

        // pre-warm a cache to ctx_len, then measure one decode step;
        // the scratch is held across steps (the long-context decode
        // pattern — no per-token allocation inside the timed region)
        fn mk_cache(
            attn: &crate::model::attention::Attention,
            rope: &crate::model::rope::Rope,
            x: &[f32],
            ctx_len: usize,
            scratch: &mut crate::model::DecodeScratch,
        ) -> KvCache {
            let mut c = KvCache::new(1, attn.n_kv_heads, attn.head_dim, ctx_len + 8);
            let mut out = vec![0.0; x.len()];
            for pos in 0..ctx_len {
                attn.decode_with(x, rope, &mut c, 0, pos, scratch, &mut out);
                c.commit();
            }
            c
        }
        let mut scratch = crate::model::DecodeScratch::default();
        let mut cache_fp = mk_cache(&attn_fp, rope, &x, ctx_len, &mut scratch);
        let mut cache_q = mk_cache(&attn_q, rope, &x, ctx_len, &mut scratch);
        let mut out = vec![0.0f32; d];
        let fp = bench_fn("fp", 3, 200, budget, || {
            cache_fp.truncate(ctx_len);
            attn_fp.decode_with(&x, rope, &mut cache_fp, 0, ctx_len, &mut scratch, &mut out);
            cache_fp.commit();
            out[0]
        });
        let qn = bench_fn("ptqtp", 3, 200, budget, || {
            cache_q.truncate(ctx_len);
            attn_q.decode_with(&x, rope, &mut cache_q, 0, ctx_len, &mut scratch, &mut out);
            cache_q.commit();
            out[0]
        });
        table.row(vec![
            name.clone(),
            format!("{:.1}", fp.median_us()),
            format!("{:.1}", qn.median_us()),
            format!("{:.3}x", fp.median.as_secs_f64() / qn.median.as_secs_f64()),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

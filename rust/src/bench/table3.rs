//! Table 3 reproduction: PTQTP-quantized models vs FP16 baselines and
//! the 1.58-bit QAT (BitNet-style) comparator trained by
//! `python/compile/train.py --qat`.
//!
//! Paper shape: PTQTP on a larger model rivals the QAT model of similar
//! size without any retraining.

use super::workload::{quantized, Zoo};
use crate::cli::Args;
use crate::data::TaskSuite;
use crate::eval::eval_suite;
use crate::report::Table;

pub fn run(quick: bool, args: &Args) -> anyhow::Result<()> {
    let zoo = Zoo::load(&["tiny", "small", "medium"]);
    println!("{}", zoo.banner());
    let n = if quick { 20 } else { 50 };
    let suite = TaskSuite::standard(args.u64_or("seed", 1), n, n, n);
    let group = args.usize_or("group-size", 128);

    let mut table = Table::new(
        "Table 3 — PTQTP vs FP16 vs 1.58-bit QAT (accuracy %)",
        &["Model", "Math*", "Cloze*", "Code*", "Mean"],
    );

    for (name, model) in &zoo.models {
        let s = eval_suite(model, &zoo.tok, &suite);
        table.metric_row(
            &format!("{name} (FP16)"),
            &[s.math_acc * 100.0, s.cloze_acc * 100.0, s.code_acc * 100.0, s.mean() * 100.0],
        );
    }
    if let Some(qat) = zoo.qat_model() {
        let s = eval_suite(&qat, &zoo.tok, &suite);
        table.metric_row(
            "small (BitNet-QAT b1.58)",
            &[s.math_acc * 100.0, s.cloze_acc * 100.0, s.code_acc * 100.0, s.mean() * 100.0],
        );
    } else {
        println!("(QAT checkpoint missing — run `make artifacts`)");
    }
    for (name, model) in &zoo.models {
        let (qm, _) = quantized(model, "ptqtp", group);
        let s = eval_suite(&qm, &zoo.tok, &suite);
        table.metric_row(
            &format!("{name}-PTQTP (b1.58)"),
            &[s.math_acc * 100.0, s.cloze_acc * 100.0, s.code_acc * 100.0, s.mean() * 100.0],
        );
    }
    println!("{}", table.render());
    println!("(*synthetic stand-ins; see DESIGN.md §2 substitutions)");
    Ok(())
}

//! Prefix-cache serving bench (`ptqtp bench --prefix`): cold vs warm
//! prefill over shared-prefix workloads, swept prefix length × batch.
//!
//! Each cell serves the same batch three times: once on the legacy
//! contiguous layout (`--prefix-cache off`, one max_seq page — the
//! token reference), once on a **cold** paged engine (empty radix
//! tree), and once more on the *same* engine **warm** (prompt pages
//! donated by the cold wave are adopted, only suffixes prefill). All
//! three are asserted token-identical before any timing — the same
//! hard parity gate as `bench --kernels`/`--attention` — and warm
//! cells with a ≥128-token shared prefix must prefill ≥ 4× fewer
//! prompt tokens than cold (the ISSUE 6 acceptance bar). Results go to
//! stdout and `BENCH_prefix_cache.json` (`--out` to relocate).

use crate::cli::Args;
use crate::coordinator::{PagedKvOpts, Request, SamplingParams, ServeEngine};
use crate::coordinator::batcher::BatchPolicy;
use crate::model::{ModelConfig, Transformer};
use crate::rng::Rng;
use crate::serialize::Json;
use crate::ternary::simd;

const PAGE_SIZE: usize = 64;
const SUFFIX_LEN: usize = 16;
const MAX_NEW: usize = 4;

/// The shared-prefix workload for one cell: request `i` is
/// `prefix(plen) ++ suffix_i(16)` over a 64-token vocabulary.
fn prompts(plen: usize, bs: usize) -> Vec<Vec<u32>> {
    let prefix: Vec<u32> = (0..plen).map(|j| 1 + (j % 60) as u32).collect();
    (0..bs)
        .map(|i| {
            let mut p = prefix.clone();
            p.extend((0..SUFFIX_LEN).map(|j| 1 + ((7 * i + j + plen) % 60) as u32));
            p
        })
        .collect()
}

/// Serve one wave and return `(tokens sorted by id, prefill-token
/// delta, adopted-token delta, wall seconds)`.
fn wave(engine: &mut ServeEngine, prompts: &[Vec<u32>], id_base: u64) -> (Vec<Vec<u32>>, u64, u64, f64) {
    let params = SamplingParams::greedy(MAX_NEW).with_stop(None);
    let prefill0 = engine.metrics.prefill_tokens;
    let adopted0 = engine.metrics.adopted_tokens;
    let t0 = std::time::Instant::now();
    for (i, p) in prompts.iter().enumerate() {
        engine.submit(Request::new(id_base + i as u64, p.clone(), params));
    }
    let mut out = engine.run_to_completion();
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(out.len(), prompts.len(), "wave dropped requests");
    out.sort_by_key(|r| r.id);
    let tokens = out.into_iter().map(|r| r.tokens).collect();
    (
        tokens,
        engine.metrics.prefill_tokens - prefill0,
        engine.metrics.adopted_tokens - adopted0,
        wall,
    )
}

pub fn run(quick: bool, args: &Args) -> anyhow::Result<()> {
    let threads = args.threads_or_default();
    let (prefix_lens, batches): (Vec<usize>, Vec<usize>) = if quick {
        (vec![0, 128], vec![4])
    } else {
        (vec![0, 128, 512, 2048], vec![4, 16])
    };
    let max_seq = prefix_lens.iter().max().unwrap() + SUFFIX_LEN + MAX_NEW + PAGE_SIZE;
    let simd_label = simd::label();

    let mut cfg = ModelConfig::family("tiny")?;
    cfg.vocab_size = 64;
    cfg.max_seq = max_seq;
    let mut rng = Rng::new(23);
    let mut model = Transformer::random(cfg, &mut rng);
    // ragged group so both ternary kernel tiers are exercised
    model.quantize_with(
        crate::quant::by_name("ptqtp", 10)?.as_ref(),
        &crate::quant::QuantCtx::default(),
    );
    println!(
        "== prefix-cache race: paged-kv page {PAGE_SIZE}, shared prefix × batch \
         (threads={threads}, simd={simd_label}) =="
    );

    let mut rows = Vec::new();
    for &plen in &prefix_lens {
        for &bs in &batches {
            let policy = BatchPolicy {
                max_running: bs,
                prefill_token_budget: 512,
                fcfs_prefill: true,
            };
            let workload = prompts(plen, bs);

            // token reference: legacy contiguous layout, nothing shared
            let legacy_kv = PagedKvOpts {
                page_size: max_seq,
                prefix_cache: false,
                page_budget: None,
            };
            let mut legacy = ServeEngine::with_opts(model.clone(), policy, threads, legacy_kv);
            let (want, _, _, _) = wave(&mut legacy, &workload, 0);

            // cold then warm on one paged engine
            let paged_kv = PagedKvOpts {
                page_size: PAGE_SIZE,
                prefix_cache: true,
                page_budget: None,
            };
            let mut paged = ServeEngine::with_opts(model.clone(), policy, threads, paged_kv);
            let (cold_tok, cold_prefill, cold_adopted, cold_wall) = wave(&mut paged, &workload, 0);
            let (warm_tok, warm_prefill, warm_adopted, warm_wall) =
                wave(&mut paged, &workload, 1000);

            // hard parity gates before any number is reported
            assert_eq!(cold_tok, want, "paged cold drifted from legacy (plen={plen} b={bs})");
            assert_eq!(warm_tok, want, "prefix-adopted warm drifted (plen={plen} b={bs})");
            assert_eq!(cold_adopted, 0, "cold wave must start from an empty tree");
            if plen >= 128 {
                assert!(
                    cold_prefill >= 4 * warm_prefill,
                    "warm prefill not ≥4× cheaper: cold {cold_prefill} vs warm {warm_prefill} \
                     (plen={plen} b={bs})"
                );
            }

            let savings = cold_prefill as f64 / (warm_prefill as f64).max(1.0);
            let speedup = cold_wall / warm_wall.max(1e-9);
            println!(
                "  prefix {plen:>4} b={bs:<2}  cold {cold_prefill:>6} prefill tok {:>8.1}ms   \
                 warm {warm_prefill:>6} prefill tok {:>8.1}ms  ({savings:>5.1}x fewer, \
                 {speedup:>4.2}x faster, {warm_adopted} adopted)",
                cold_wall * 1e3,
                warm_wall * 1e3,
            );
            rows.push(
                Json::obj()
                    .set("prefix_len", plen)
                    .set("batch", bs)
                    .set("cold_prefill_tokens", cold_prefill)
                    .set("warm_prefill_tokens", warm_prefill)
                    .set("warm_adopted_tokens", warm_adopted)
                    .set("cold_ms", cold_wall * 1e3)
                    .set("warm_ms", warm_wall * 1e3)
                    .set("prefill_savings", savings)
                    .set("warm_speedup", speedup),
            );
        }
    }

    let out_path = args.str_or("out", "BENCH_prefix_cache.json");
    let json = Json::obj()
        .set("bench", "prefix-cache")
        // real measured numbers (the committed placeholder says
        // "pending-first-toolchain-run"; CI's bench-baselines job
        // rejects that marker in generated output)
        .set("status", "measured")
        .set("threads", threads)
        .set("quick", quick)
        .set("simd_tier", simd_label)
        .set("cpu_features", simd::cpu_features().join(","))
        .set("layout", "paged-kv")
        .set("page_size", PAGE_SIZE)
        .set("suffix_len", SUFFIX_LEN)
        .set(
            "parity",
            "cold + prefix-adopted warm paged serves asserted token-identical to the legacy \
             contiguous layout before timing; warm prefill asserted ≥4x cheaper at prefix ≥ 128",
        )
        .set("results", Json::Arr(rows));
    std::fs::write(out_path, json.pretty())?;
    println!("  wrote {out_path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_quick_and_emits_json() {
        let dir = std::env::temp_dir().join("ptqtp_bench_prefix");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("p.json");
        let raw = vec![
            "--out".to_string(),
            out.to_string_lossy().to_string(),
            "--threads".to_string(),
            "2".to_string(),
        ];
        let args = Args::parse("ptqtp", raw, &[]);
        run(true, &args).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(j.req_str("bench").unwrap(), "prefix-cache");
        assert_eq!(j.req_str("status").unwrap(), "measured");
        assert_eq!(j.req_str("layout").unwrap(), "paged-kv");
        let rows = j.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 2); // 2 prefix lengths × 1 batch in quick mode
        std::fs::remove_file(out).ok();
    }
}

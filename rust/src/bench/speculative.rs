//! Speculative-decoding serving bench (`ptqtp bench --speculative`):
//! prompt-lookup drafting vs plain one-token-per-step decode.
//!
//! Two workloads over the same tiny quantized model: a **repetitive**
//! corpus (one templated, pattern-cycled prompt served batch-wide —
//! the n-gram-reuse regime prompt-lookup feeds on) and a **random**
//! corpus (per-request random prompts — the adversarial regime where
//! drafting rarely fires). Each corpus is served twice on identical
//! engines, `--spec-decode off` then on, and the two waves are
//! asserted **token-for-token identical** before any number is
//! reported — speculation is a scheduling optimization, never a
//! sampling change. On the repetitive corpus the spec wave must also
//! finish in ≥ 1.3× fewer engine steps (a deterministic stand-in for
//! the tokens/sec bar that is immune to CI machine load; the measured
//! wall-clock speedup is additionally gated in full runs). Results go
//! to stdout and `BENCH_speculative.json` (`--out` to relocate).

use crate::cli::Args;
use crate::coordinator::speculator::SpecDecodeOpts;
use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::{PagedKvOpts, Request, SamplingParams, ServeEngine};
use crate::model::{ModelConfig, Transformer};
use crate::rng::Rng;
use crate::serialize::Json;
use crate::ternary::simd;

const PAGE_SIZE: usize = 8;
const PROMPT_LEN: usize = 16;
const MAX_NEW: usize = 64;

/// The repetitive workload: one pattern-cycled prompt (`[a b c d]`
/// repeated to [`PROMPT_LEN`]) served `bs` times — batch-identical
/// trajectories with maximal n-gram reuse.
fn repetitive_prompts(bs: usize, rng: &mut Rng) -> Vec<Vec<u32>> {
    let period = 4;
    let pattern: Vec<u32> = (0..period).map(|_| 1 + rng.below(30) as u32).collect();
    let prompt: Vec<u32> = (0..PROMPT_LEN).map(|j| pattern[j % period]).collect();
    vec![prompt; bs]
}

/// The adversarial workload: `bs` distinct prompts of uniform random
/// tokens — no n-gram structure for the drafter to match.
fn random_prompts(bs: usize, rng: &mut Rng) -> Vec<Vec<u32>> {
    (0..bs)
        .map(|_| (0..PROMPT_LEN).map(|_| 1 + rng.below(30) as u32).collect())
        .collect()
}

/// Serve one wave, counting engine steps ourselves, and return
/// `(tokens sorted by id, steps, committed decode tokens, wall secs)`.
fn wave(engine: &mut ServeEngine, prompts: &[Vec<u32>], max_new: usize) -> (Vec<Vec<u32>>, u64, u64, f64) {
    let params = SamplingParams::greedy(max_new).with_stop(None);
    let decode0 = engine.metrics.decode_tokens;
    let t0 = std::time::Instant::now();
    for (i, p) in prompts.iter().enumerate() {
        engine.submit(Request::new(i as u64, p.clone(), params));
    }
    let mut out = Vec::new();
    let mut steps = 0u64;
    while engine.pending() > 0 {
        out.extend(engine.step());
        steps += 1;
        assert!(steps < 1_000_000, "bench livelock");
    }
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(out.len(), prompts.len(), "wave dropped requests");
    out.sort_by_key(|r| r.id);
    let tokens = out.into_iter().map(|r| r.tokens).collect();
    (tokens, steps, engine.metrics.decode_tokens - decode0, wall)
}

pub fn run(quick: bool, args: &Args) -> anyhow::Result<()> {
    let threads = args.threads_or_default();
    // small batch on purpose: speculation is a low-batch latency
    // optimization — its win comes from amortizing per-step fixed cost
    // (pool dispatch, weight-plane streaming) across draft rows, and
    // large decode batches already amortize that across sequences
    let bs = 4;
    let max_new = if quick { 48 } else { MAX_NEW };
    let spec = SpecDecodeOpts::default();
    let simd_label = simd::label();

    let mut cfg = ModelConfig::family("tiny")?;
    cfg.vocab_size = 32;
    cfg.max_seq = PROMPT_LEN + MAX_NEW + PAGE_SIZE;
    let mut rng = Rng::new(29);
    let mut model = Transformer::random(cfg, &mut rng);
    // ragged group so both ternary kernel tiers are exercised
    model.quantize_with(
        crate::quant::by_name("ptqtp", 10)?.as_ref(),
        &crate::quant::QuantCtx::default(),
    );
    println!(
        "== speculative decode: prompt-lookup k={} vs plain, batch {bs} × {max_new} new \
         (threads={threads}, simd={simd_label}) ==",
        spec.k
    );

    let policy = BatchPolicy {
        max_running: bs,
        prefill_token_budget: 256,
        fcfs_prefill: true,
    };
    let kv = PagedKvOpts {
        page_size: PAGE_SIZE,
        prefix_cache: true,
        page_budget: None,
    };

    let mut rows = Vec::new();
    for (corpus, prompts) in [
        ("repetitive", repetitive_prompts(bs, &mut Rng::new(31))),
        ("random", random_prompts(bs, &mut Rng::new(37))),
    ] {
        let mut plain = ServeEngine::with_opts(model.clone(), policy, threads, kv);
        let (want, plain_steps, plain_decode, plain_wall) = wave(&mut plain, &prompts, max_new);

        let mut fast = ServeEngine::with_opts(model.clone(), policy, threads, kv);
        fast.set_spec_decode(Some(spec));
        let (got, spec_steps, spec_decode, spec_wall) = wave(&mut fast, &prompts, max_new);
        let (drafted, accepted, rollback) = (
            fast.metrics.spec_drafted,
            fast.metrics.spec_accepted,
            fast.metrics.spec_rollback_pages,
        );

        // hard parity gates before any number is reported: speculation
        // must be invisible in the output
        assert_eq!(got, want, "speculative decode drifted from plain ({corpus})");
        assert_eq!(spec_decode, plain_decode, "committed-token accounting drifted ({corpus})");

        let step_ratio = plain_steps as f64 / spec_steps as f64;
        let speedup = plain_wall / spec_wall.max(1e-9);
        let accept_rate = if drafted == 0 { 0.0 } else { accepted as f64 / drafted as f64 };
        if corpus == "repetitive" {
            // the ISSUE 9 acceptance bar, in its deterministic form:
            // accepted drafts collapse decode steps ≥ 1.3× (steps are a
            // pure function of model + workload, so this cannot flake
            // on a loaded CI machine the way wall time can)
            assert!(
                step_ratio >= 1.3,
                "speculative steps not ≥1.3x fewer on the repetitive corpus: \
                 plain {plain_steps} vs spec {spec_steps} ({step_ratio:.2}x, \
                 accept rate {accept_rate:.2})"
            );
            if !quick {
                // full runs also hold the wall-clock tokens/sec bar
                assert!(
                    speedup >= 1.3,
                    "speculative decode not ≥1.3x faster on the repetitive corpus: \
                     {speedup:.2}x (steps {step_ratio:.2}x, accept rate {accept_rate:.2})"
                );
            }
        }

        let plain_tok_s = plain_decode as f64 / plain_wall.max(1e-9);
        let spec_tok_s = spec_decode as f64 / spec_wall.max(1e-9);
        println!(
            "  {corpus:>10}  plain {plain_steps:>4} steps {:>8.1}ms   spec {spec_steps:>4} steps \
             {:>8.1}ms  ({step_ratio:>4.2}x fewer steps, {speedup:>4.2}x faster, \
             accept {:.0}%, {rollback} rollback pages)",
            plain_wall * 1e3,
            spec_wall * 1e3,
            accept_rate * 100.0,
        );
        rows.push(
            Json::obj()
                .set("corpus", corpus)
                .set("requests", bs)
                .set("plain_steps", plain_steps)
                .set("spec_steps", spec_steps)
                .set("step_ratio", step_ratio)
                .set("plain_ms", plain_wall * 1e3)
                .set("spec_ms", spec_wall * 1e3)
                .set("plain_tok_s", plain_tok_s)
                .set("spec_tok_s", spec_tok_s)
                .set("speedup", speedup)
                .set("drafted", drafted)
                .set("accepted", accepted)
                .set("accept_rate", accept_rate)
                .set("rollback_pages", rollback),
        );
    }

    let out_path = args.str_or("out", "BENCH_speculative.json");
    let json = Json::obj()
        .set("bench", "speculative")
        // real measured numbers (the committed placeholder says
        // "pending-first-toolchain-run"; CI's bench-baselines job
        // rejects that marker in generated output)
        .set("status", "measured")
        .set("threads", threads)
        .set("quick", quick)
        .set("simd_tier", simd_label)
        .set("cpu_features", simd::cpu_features().join(","))
        .set("spec_k", spec.k)
        .set("min_match", spec.min_match)
        .set("max_new", max_new)
        .set("page_size", PAGE_SIZE)
        .set(
            "parity",
            "spec-on serves asserted token-for-token identical to spec-off before timing; \
             repetitive corpus asserted ≥1.3x fewer engine steps (and ≥1.3x wall speedup in \
             full runs)",
        )
        .set("results", Json::Arr(rows));
    std::fs::write(out_path, json.pretty())?;
    println!("  wrote {out_path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_quick_and_emits_json() {
        let dir = std::env::temp_dir().join("ptqtp_bench_speculative");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("s.json");
        let raw = vec![
            "--out".to_string(),
            out.to_string_lossy().to_string(),
            "--threads".to_string(),
            "2".to_string(),
        ];
        let args = Args::parse("ptqtp", raw, &[]);
        run(true, &args).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(j.req_str("bench").unwrap(), "speculative");
        assert_eq!(j.req_str("status").unwrap(), "measured");
        let rows = j.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 2); // repetitive + random
        std::fs::remove_file(out).ok();
    }
}

//! Batched-forward throughput: old per-token stepping vs the fused
//! batch path, at batch sizes {1, 4, 16}, for decode and prefill.
//!
//! The paper's deployment claim (4.63× end-to-end from multiply-free
//! inference) needs the ternary kernels to see enough rows to amortize
//! plane decoding; this bench measures exactly that amortization on
//! the CPU kernels. Results go to stdout and to
//! `BENCH_batched_forward.json` (`--out` to relocate).
//!
//! Invoke: `ptqtp bench --batched [--quick]` or `cargo bench -- batched`.

use super::harness::bench_fn;
use crate::cli::Args;
use crate::model::{ForwardBatch, ForwardScratch, KvCache, ModelConfig, Transformer};
use crate::quant::{self, QuantCtx};
use crate::rng::Rng;
use crate::serialize::Json;
use crate::threads::Pool;
use std::time::Duration;

/// Context depth each decode row attends over.
const CTX_LEN: usize = 16;
/// Prompt length for the prefill comparison.
const PROMPT_LEN: usize = 64;

pub fn run(quick: bool, args: &Args) -> anyhow::Result<()> {
    let family = args.str_or("family", "tiny");
    let mut cfg = ModelConfig::family(family)?;
    cfg.vocab_size = 64;
    cfg.max_seq = 128;
    let mut rng = Rng::new(args.u64_or("seed", 0));
    let mut model = Transformer::random(cfg, &mut rng);
    model.quantize_with(
        quant::by_name("ptqtp", 128)?.as_ref(),
        &QuantCtx::default(),
    );
    let budget = Duration::from_millis(if quick { 250 } else { 1500 });
    let iters = if quick { 60 } else { 400 };

    println!("== batched forward: per-token vs fused ({family}, ptqtp) ==");
    let mut decode_rows = Vec::new();
    for &bs in &[1usize, 4, 16] {
        // bs sequences, each with CTX_LEN committed positions
        let mut scratch = model.new_scratch();
        let mut caches: Vec<KvCache> = (0..bs).map(|_| model.new_cache()).collect();
        let prompt: Vec<u32> = (0..CTX_LEN as u32).map(|i| (i * 7 + 3) % 64).collect();
        for cache in caches.iter_mut() {
            model.prefill(&prompt, cache, &mut scratch, 32);
        }
        let toks: Vec<u32> = (0..bs as u32).map(|i| (i * 11 + 5) % 64).collect();

        // old path: one decode_step per sequence (fresh scratch per
        // call — exactly the pre-refactor allocation behavior)
        let per_token = bench_fn(&format!("decode/per-token/b{bs}"), 3, iters, budget, || {
            for (i, cache) in caches.iter_mut().enumerate() {
                let logits = model.decode_step(toks[i], cache);
                std::hint::black_box(&logits);
                cache.truncate(CTX_LEN);
            }
        });

        // fused path: all bs rows in one forward_batch
        let mut batch = ForwardBatch::new();
        for (i, &t) in toks.iter().enumerate() {
            batch.push(t, CTX_LEN, i, true);
        }
        let fused = bench_fn(&format!("decode/fused/b{bs}"), 3, iters, budget, || {
            {
                let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
                model.forward_batch(&batch, &mut refs, &mut scratch);
            }
            std::hint::black_box(&scratch.logits);
            for cache in caches.iter_mut() {
                cache.truncate(CTX_LEN);
            }
        });

        let tps_old = per_token.throughput(bs as f64);
        let tps_new = fused.throughput(bs as f64);
        let speedup = tps_new / tps_old;
        println!(
            "  decode  b={bs:<2}  per-token {tps_old:>9.0} tok/s   fused {tps_new:>9.0} tok/s   {speedup:>5.2}x"
        );
        decode_rows.push(
            Json::obj()
                .set("batch", bs)
                .set("per_token_tps", tps_old)
                .set("fused_tps", tps_new)
                .set("speedup", speedup),
        );
    }

    // prefill: one PROMPT_LEN prompt, per-token vs chunked-batched
    let prompt: Vec<u32> = (0..PROMPT_LEN as u32).map(|i| (i * 13 + 1) % 64).collect();
    let mut cache = model.new_cache();
    let per_token = bench_fn("prefill/per-token", 2, iters, budget, || {
        cache.reset();
        for &t in &prompt {
            let logits = model.decode_step(t, &mut cache);
            std::hint::black_box(&logits);
        }
    });
    let mut scratch = model.new_scratch();
    let fused = bench_fn("prefill/fused", 2, iters, budget, || {
        cache.reset();
        let logits = model.prefill(&prompt, &mut cache, &mut scratch, 32);
        std::hint::black_box(&logits);
    });
    let ptps_old = per_token.throughput(PROMPT_LEN as f64);
    let ptps_new = fused.throughput(PROMPT_LEN as f64);
    println!(
        "  prefill n={PROMPT_LEN}  per-token {ptps_old:>9.0} tok/s   fused {ptps_new:>9.0} tok/s   {:>5.2}x",
        ptps_new / ptps_old
    );

    // --threads scaling on the fused prefill path: each lane count must
    // first reproduce the sequential logits bit-for-bit, then race
    cache.reset();
    let logits_seq = model.prefill(&prompt, &mut cache, &mut scratch, 32);
    let mut scaling_rows = Vec::new();
    let mut tps1 = f64::NAN;
    for n in [1usize, 2, 4] {
        let mut scratch_n = ForwardScratch::with_pool(Pool::new(n));
        cache.reset();
        let check = model.prefill(&prompt, &mut cache, &mut scratch_n, 32);
        assert_eq!(check, logits_seq, "threaded prefill drifted at {n} threads");
        let r = bench_fn(&format!("prefill/threads{n}"), 2, iters, budget, || {
            cache.reset();
            let logits = model.prefill(&prompt, &mut cache, &mut scratch_n, 32);
            std::hint::black_box(&logits);
        });
        let tps = r.throughput(PROMPT_LEN as f64);
        if n == 1 {
            tps1 = tps;
        }
        let speedup = tps / tps1;
        println!("  prefill threads={n}  {tps:>9.0} tok/s   {speedup:>5.2}x vs sequential");
        scaling_rows.push(
            Json::obj()
                .set("threads", n)
                .set("tps", tps)
                .set("speedup_vs_1", speedup),
        );
    }

    let out_path = args.str_or("out", "BENCH_batched_forward.json");
    let json = Json::obj()
        .set("bench", "batched_forward")
        // real measured numbers (the committed placeholders say
        // "pending-first-toolchain-run"; CI's bench-baselines job
        // rejects that marker in generated output)
        .set("status", "measured")
        .set("family", family)
        .set("method", "ptqtp")
        .set("ctx_len", CTX_LEN)
        .set("decode", Json::Arr(decode_rows))
        .set(
            "prefill",
            Json::obj()
                .set("prompt_len", PROMPT_LEN)
                .set("per_token_tps", ptps_old)
                .set("fused_tps", ptps_new)
                .set("speedup", ptps_new / ptps_old),
        )
        .set("prefill_scaling", Json::Arr(scaling_rows));
    std::fs::write(out_path, json.pretty())?;
    println!("  wrote {out_path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cli::Args;

    #[test]
    fn bench_runs_quick_and_emits_json() {
        let dir = std::env::temp_dir().join("ptqtp_bench_batched");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("b.json");
        let raw = vec!["--out".to_string(), out.to_string_lossy().to_string()];
        let args = Args::parse("ptqtp", raw, &[]);
        run(true, &args).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(j.req_str("bench").unwrap(), "batched_forward");
        let decode = j.get("decode").and_then(Json::as_arr).unwrap();
        assert_eq!(decode.len(), 3);
        let scaling = j.get("prefill_scaling").and_then(Json::as_arr).unwrap();
        assert_eq!(scaling.len(), 3);
        std::fs::remove_file(out).ok();
    }
}

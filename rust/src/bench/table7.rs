//! Table 7 reproduction: condition-number-bound ablation — PPL as the
//! κ threshold of Eq. 3 sweeps from 1 to 10¹⁸.
//!
//! Paper shape: PPL improves monotonically as the bound loosens from
//! 10⁰ to ~10², then saturates — over-eager λ adaptation (small bound)
//! over-regularizes the ridge step.

use super::workload::{ppl_quick, Zoo};
use crate::cli::Args;
use crate::quant::{PtqtpOpts, Ptqtp};
use crate::report::Table;

pub fn run(quick: bool, args: &Args) -> anyhow::Result<()> {
    let fams: Vec<&str> = if quick { vec!["small"] } else { vec!["small", "medium"] };
    let zoo = Zoo::load(&fams);
    println!("{}", zoo.banner());
    let budget = if quick { 1000 } else { 2000 };
    let group = args.usize_or("group-size", 128);
    let bounds: Vec<f64> = if quick {
        vec![1.0, 1e2, 1e12]
    } else {
        vec![1.0, 5.0, 1e1, 1e2, 1e4, 1e8, 1e12, 1e18]
    };

    for (name, model) in &zoo.models {
        let mut table = Table::new(
            &format!("Table 7 — κ-bound ablation, {name}"),
            &["Condition", "wiki-syn", "ptb-syn", "c4-syn", "mean λ"],
        );
        for &bound in &bounds {
            let q = Ptqtp::new(PtqtpOpts {
                group,
                kappa_threshold: bound,
                ..Default::default()
            });
            let mut m = model.clone();
            // capture mean λ via a single-layer report probe
            let probe_w = model.blocks[0].w_gate.dense_weights();
            let (_, rep) = q.quantize_with_report(&probe_w);
            m.quantize_with(&q, &crate::quant::QuantCtx::default());
            let mut cells = vec![format!("1e{:.0}", bound.log10())];
            for domain in ["wiki-syn", "ptb-syn", "c4-syn"] {
                let p = ppl_quick(&m, &zoo.tok, &zoo.eval_texts[domain], budget);
                cells.push(crate::report::fmt_metric(p));
            }
            cells.push(format!("{:.2e}", rep.mean_lambda));
            table.row(cells);
        }
        println!("{}", table.render());
    }
    Ok(())
}

//! Table 11 reproduction: per-task retention (PTQTP/FP16, %) across
//! all model sizes — the "retention grows with scale" matrix.

use super::workload::{quantized, Zoo};
use crate::cli::Args;
use crate::data::TaskSuite;
use crate::eval::eval_suite;
use crate::report::Table;

pub fn run(quick: bool, args: &Args) -> anyhow::Result<()> {
    let fams: Vec<&str> = if quick { vec!["tiny", "small"] } else { vec!["tiny", "small", "medium"] };
    let zoo = Zoo::load(&fams);
    println!("{}", zoo.banner());
    let n = if quick { 20 } else { 50 };
    let suite = TaskSuite::standard(args.u64_or("seed", 1), n, n, n);

    let mut table = Table::new(
        "Table 11 — FP16 vs PTQTP per task (acc %, retention %)",
        &{
            let mut h = vec!["Task", "Row"];
            h.extend(zoo.models.iter().map(|(n, _)| n.as_str()));
            h
        },
    );

    let mut fp_scores = Vec::new();
    let mut q_scores = Vec::new();
    for (_, model) in &zoo.models {
        fp_scores.push(eval_suite(model, &zoo.tok, &suite));
        let (qm, _) = quantized(model, "ptqtp", 128);
        q_scores.push(eval_suite(&qm, &zoo.tok, &suite));
    }

    let tasks: [(&str, fn(&crate::eval::SuiteScores) -> f64); 3] = [
        ("Math*", |s| s.math_acc),
        ("Cloze*", |s| s.cloze_acc),
        ("Code*", |s| s.code_acc),
    ];
    for (task, get) in tasks {
        let mut fp_cells = vec![task.to_string(), "FP16".to_string()];
        let mut q_cells = vec![task.to_string(), "PTQTP-b1.58".to_string()];
        let mut r_cells = vec![task.to_string(), "retention %".to_string()];
        for i in 0..zoo.models.len() {
            let f = get(&fp_scores[i]);
            let q = get(&q_scores[i]);
            fp_cells.push(format!("{:.1}", f * 100.0));
            q_cells.push(format!("{:.1}", q * 100.0));
            r_cells.push(if f > 0.0 {
                format!("{:.1}", q / f * 100.0)
            } else {
                "-".into()
            });
        }
        table.row(fp_cells);
        table.row(q_cells);
        table.row(r_cells);
    }
    println!("{}", table.render());
    println!("(*synthetic stand-ins; see DESIGN.md §2 substitutions)");
    Ok(())
}

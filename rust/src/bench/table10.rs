//! Table 10 reproduction: MMLU-style accuracy vs bit-width across
//! sizes, with retention percentages against FP16.
//!
//! Paper shape: 8-bit ≈ lossless, 4-bit minor loss, 2-bit collapses to
//! chance, binary (BiLLM) at/near chance, PTQTP recovers most of FP16 —
//! with retention improving on larger models.

use super::workload::{quantized, Zoo};
use crate::cli::Args;
use crate::data::TaskSuite;
use crate::eval::suite::eval_choices;
use crate::report::Table;

pub fn run(quick: bool, args: &Args) -> anyhow::Result<()> {
    let fams: Vec<&str> = if quick { vec!["tiny", "small"] } else { vec!["tiny", "small", "medium"] };
    let zoo = Zoo::load(&fams);
    println!("{}", zoo.banner());
    let n = if quick { 30 } else { 60 };
    let suite = TaskSuite::standard(args.u64_or("seed", 1), 0, n, 0);

    let methods: Vec<(&str, &str)> = if quick {
        vec![("fp16", "16"), ("rtn4", "4"), ("rtn2", "2"), ("billm", "1.06"), ("ptqtp", "1.58")]
    } else {
        vec![
            ("fp16", "16"), ("rtn8", "8"), ("gptq4", "4"), ("awq4", "4"),
            ("gptq2", "2"), ("awq2", "2"), ("billm", "1.06"), ("ptqtp", "1.58"),
        ]
    };

    let mut table = Table::new(
        "Table 10 — cloze (MMLU stand-in) accuracy / retention (%)",
        &{
            let mut h = vec!["Method", "#W bits"];
            h.extend(zoo.models.iter().map(|(n, _)| n.as_str()));
            h
        },
    );
    // FP16 reference per model
    let fp_acc: Vec<f64> = zoo
        .models
        .iter()
        .map(|(_, m)| eval_choices(m, &zoo.tok, &suite.cloze))
        .collect();
    for (method, bits) in methods {
        let mut cells = vec![
            crate::quant::by_name(method, 128)?.name(),
            bits.to_string(),
        ];
        for (i, (_, model)) in zoo.models.iter().enumerate() {
            let (qm, _) = quantized(model, method, 128);
            let acc = eval_choices(&qm, &zoo.tok, &suite.cloze);
            let retention = if fp_acc[i] > 0.0 { acc / fp_acc[i] * 100.0 } else { 0.0 };
            cells.push(format!("{:.1}/{:.1}", acc * 100.0, retention));
        }
        table.row(cells);
    }
    println!("{}", table.render());
    Ok(())
}

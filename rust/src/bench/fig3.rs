//! Fig. 3 reproduction: progressive-search iterations ablation —
//! quantization time and perplexity vs T_max.
//!
//! Paper shape: PPL collapses from the catastrophic sign-init within
//! the first ~10 iterations, converges by ~30, while quantization time
//! grows linearly in T_max.

use super::workload::{ppl_quick, Zoo};
use crate::cli::Args;
use crate::quant::{Ptqtp, PtqtpOpts, QuantCtx};
use crate::report::{ascii_plot, Table};

pub fn run(quick: bool, args: &Args) -> anyhow::Result<()> {
    let fams: Vec<&str> = if quick { vec!["small"] } else { vec!["small", "medium"] };
    let zoo = Zoo::load(&fams);
    println!("{}", zoo.banner());
    let budget = if quick { 1000 } else { 2000 };
    let group = args.usize_or("group-size", 128);
    let iter_grid: Vec<usize> = if quick {
        vec![1, 5, 30]
    } else {
        vec![1, 2, 5, 10, 20, 30, 50]
    };

    for (name, model) in &zoo.models {
        let mut table = Table::new(
            &format!("Fig 3 — iterations ablation, {name}"),
            &["T_max", "quant time (ms)", "wiki-syn PPL"],
        );
        let mut xs = Vec::new();
        let mut ppls = Vec::new();
        let mut times = Vec::new();
        for &t_max in &iter_grid {
            let q = Ptqtp::new(PtqtpOpts {
                group,
                t_max,
                // disable the α-delta early exit so T_max is binding
                eps: 0.0,
                ..Default::default()
            });
            let mut m = model.clone();
            let t0 = std::time::Instant::now();
            m.quantize_with(&q, &QuantCtx::default());
            let dur = t0.elapsed();
            let ppl = ppl_quick(&m, &zoo.tok, &zoo.eval_texts["wiki-syn"], budget);
            table.row(vec![
                format!("{t_max}"),
                format!("{:.1}", dur.as_secs_f64() * 1e3),
                crate::report::fmt_metric(ppl),
            ]);
            xs.push(t_max as f64);
            ppls.push(ppl.ln()); // log-scale like the paper's axis
            times.push(dur.as_secs_f64() * 1e3);
        }
        println!("{}", table.render());
        println!("{}", ascii_plot(
            &format!("log-PPL vs T_max ({name})"),
            &xs,
            &[("log ppl", ppls)],
            10,
        ));
        println!("{}", ascii_plot(
            &format!("quant time (ms) vs T_max ({name})"),
            &xs,
            &[("ms", times)],
            8,
        ));
    }
    Ok(())
}

//! Table 1 / Table 9 reproduction: perplexity across model sizes ×
//! quantization methods × corpora.
//!
//! Paper shape to reproduce: FP16 best; AWQ/GPTQ at 2-bit explode;
//! binary PTQ (PB-LLM/BiLLM) catastrophic, ARB better but still far;
//! PTQTP closest to FP16 of all ≤3-bit methods, especially on the
//! smallest models.

use super::workload::{ppl_quick, quantized, table1_methods, Zoo};
use crate::cli::Args;
use crate::report::Table;

pub fn run(quick: bool, args: &Args) -> anyhow::Result<()> {
    let families: Vec<&str> = if quick { vec!["tiny", "small"] } else { vec!["tiny", "small", "medium"] };
    let zoo = Zoo::load(&families);
    println!("{}", zoo.banner());
    let budget = if quick { 1200 } else { 2500 };
    let group = args.usize_or("group-size", 128);
    let domains = ["wiki-syn", "ptb-syn", "c4-syn"];

    for domain in domains {
        let text = zoo.eval_texts[domain].clone();
        let mut table = Table::new(
            &format!("Table 1 — Perplexity on {domain} (G={group})"),
            &{
                let mut h = vec!["Method", "#Bits"];
                h.extend(zoo.models.iter().map(|(n, _)| n.as_str()));
                h
            },
        );
        for method in table1_methods(quick) {
            let q = crate::quant::by_name(method, group)?;
            let mut cells = vec![q.name(), format!("{:.2}", q.nominal_bits())];
            for (_, model) in &zoo.models {
                let (qm, _) = quantized(model, method, group);
                let ppl = ppl_quick(&qm, &zoo.tok, &text, budget);
                cells.push(crate::report::fmt_metric(ppl));
            }
            table.row(cells);
        }
        println!("{}", table.render());
    }
    Ok(())
}

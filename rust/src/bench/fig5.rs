//! Fig. 5 reproduction: the single trit-plane update process across
//! optimization iterations — per-sweep flip counts, reconstruction
//! error, and the evolving trit-value distribution of both planes.

use super::workload::{bench_weight, Zoo};
use crate::cli::Args;
use crate::quant::{Ptqtp, PtqtpOpts};
use crate::report::Table;
use crate::tensor::stats::sparkline;

pub fn run(quick: bool, args: &Args) -> anyhow::Result<()> {
    let group = args.usize_or("group-size", 128);
    // one representative layer: the trained small model's first gate
    // projection if available, else a synthetic heavy-tailed layer
    let zoo = Zoo::load(&["small"]);
    let w = if zoo.trained {
        zoo.models[0].1.blocks[0].w_gate.dense_weights()
    } else {
        bench_weight(344, 128, 9)
    };
    println!("{} (layer L0.w_gate {}x{})", zoo.banner(), w.rows, w.cols);

    let q = Ptqtp::new(PtqtpOpts {
        group,
        t_max: if quick { 10 } else { 30 },
        eps: 0.0, // run all sweeps so the full trajectory is visible
        track_history: true,
        ..Default::default()
    });
    let (lin, rep) = q.quantize_with_report(&w);

    let mut table = Table::new(
        "Fig 5 — trit-plane update process (per sweep)",
        &["sweep", "flips", "flip %", "||W-What||_F^2"],
    );
    let total = (w.rows * w.cols) as f64;
    for (i, (&flips, &err)) in rep.flip_history.iter().zip(&rep.err_history).enumerate() {
        table.row(vec![
            format!("{}", i + 1),
            format!("{flips}"),
            format!("{:.2}", flips as f64 / total * 100.0),
            format!("{err:.5}"),
        ]);
    }
    println!("{}", table.render());

    // final plane statistics (the paper's plane visualizations)
    let c1 = lin.t1.value_counts();
    let c2 = lin.t2.value_counts();
    println!("plane T1 counts [-1,0,+1] = {c1:?}  sparsity {:.1}%", lin.t1.sparsity() * 100.0);
    println!("plane T2 counts [-1,0,+1] = {c2:?}  sparsity {:.1}%", lin.t2.sparsity() * 100.0);

    // weight-vs-reconstruction histograms as sparklines
    let hist_w = crate::tensor::stats::histogram(&w.data, 48, 3.0 * w.abs_max().max(1e-6));
    let recon = lin.reconstruct();
    let hist_r = crate::tensor::stats::histogram(&recon.data, 48, 3.0 * w.abs_max().max(1e-6));
    println!("W     |{}|", sparkline(&hist_w));
    println!("What  |{}|", sparkline(&hist_r));
    println!(
        "final sq err {:.6}, rel err {:.4}, mean iters {:.1}",
        rep.final_sq_err,
        w.rel_err(&recon),
        rep.mean_iters()
    );
    Ok(())
}

//! Table 5 reproduction: linear-layer (gate_proj) inference latency
//! across kernels × sequence lengths — FP16(dense f32 here), GPTQ-4bit
//! (packed int4), AQLM 2×2bit (additive codebooks), PTQTP trit-planes.
//!
//! Paper shape to reproduce: at seq=1 all are close; as sequence grows,
//! AQLM's per-element gather blows up, int4 stays nearest dense, PTQTP
//! sits between int4 and dense with a modest prefill penalty. The
//! PTQTP-LUT column races the activation-indexed table tier (bit-exact
//! with the packed tier) against the throughput-tuned dispatch.

use super::harness::bench_fn;
use super::workload::bench_weight;
use crate::cli::Args;
use crate::report::Table;
use crate::tensor::{ops, Matrix};
use crate::ternary::gemm::GemmScratch;
use crate::ternary::int4::{Aqlm2x2Linear, Int4Linear};
use crate::ternary::lut::{gemm_lut_into, gemv_lut};
use crate::quant::ptqtp::Ptqtp;
use std::time::Duration;

pub fn run(quick: bool, _args: &Args) -> anyhow::Result<()> {
    // gate_proj-like shapes scaled to this testbed: (ff, d)
    let shapes: Vec<(&str, usize, usize)> = if quick {
        vec![("small-ff", 344, 128)]
    } else {
        vec![("small-ff", 344, 128), ("medium-ff", 512, 192), ("large-ff", 688, 256)]
    };
    let seqs: Vec<usize> = if quick { vec![1, 32] } else { vec![1, 32, 256] };
    let budget = Duration::from_millis(if quick { 300 } else { 1200 });

    for (name, n, d) in shapes {
        let w = bench_weight(n, d, 42);
        let int4 = Int4Linear::quantize(&w, 128.min(d));
        let aqlm = Aqlm2x2Linear::quantize(&w, 128.min(d));
        let ptqtp = {
            let (lin, _) = Ptqtp::default().quantize_with_report(&w);
            lin.to_packed()
        };
        let wt = w.transpose();

        let mut table = Table::new(
            &format!("Table 5 — gate_proj latency (ms), {name} ({n}x{d})"),
            &["seq", "FP32-dense", "GPTQ-4bit", "AQLM-2x2bit", "PTQTP-1.58bit", "PTQTP-LUT"],
        );
        // this exhibit's LUT column measures the *scalar* LUT tier (the
        // PR-2 baseline); pin SIMD off so the numbers stay comparable
        // across machines and to pre-SIMD baselines — the SIMD tier is
        // raced (with parity gates) in `bench --kernels` instead
        let mut lut_scratch = GemmScratch::new();
        lut_scratch.simd = false;
        for &seq in &seqs {
            let mut rng = crate::rng::Rng::new(7 + seq as u64);
            let x = Matrix::randn(seq, d, 1.0, &mut rng);
            let dense = bench_fn("dense", 2, 60, budget, || ops::matmul(&x, &wt));
            let i4 = bench_fn("int4", 2, 60, budget, || int4.gemm(&x));
            let aq = bench_fn("aqlm", 2, 60, budget, || aqlm.gemm(&x));
            let tp = bench_fn("ptqtp", 2, 60, budget, || {
                if seq >= 8 {
                    crate::ternary::gemm::gemm_decoded(&ptqtp, &x)
                } else {
                    crate::ternary::gemm::gemm_packed(&ptqtp, &x)
                }
            });
            let mut y = Matrix::zeros(seq, n);
            let mut gemv_table = Vec::new();
            let tp_lut = bench_fn("ptqtp-lut", 2, 60, budget, || {
                if seq == 1 {
                    gemv_lut(&ptqtp, x.row(0), y.row_mut(0), &mut gemv_table);
                } else {
                    gemm_lut_into(&ptqtp, &x, &mut y, &mut lut_scratch);
                }
            });
            table.row(vec![
                format!("{seq}"),
                format!("{:.3}", dense.median_ms()),
                format!("{:.3}", i4.median_ms()),
                format!("{:.3}", aq.median_ms()),
                format!("{:.3}", tp.median_ms()),
                format!("{:.3}", tp_lut.median_ms()),
            ]);
        }
        println!("{}", table.render());
    }
    Ok(())
}

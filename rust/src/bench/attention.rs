//! Attention-tier race (`ptqtp bench --attention`): scalar
//! `attend_one` vs the head-major SIMD kernels vs SIMD + head-parallel
//! threading, swept over context length × batch size — the regime the
//! head-major KV layout targets (long-context decode, where the
//! quadratic attend stage dominates once the ternary linears run on
//! the LUT/SIMD tiers).
//!
//! Before any timing, every racer's output is asserted `==` (bitwise)
//! against the scalar reference — the same hard parity gate as `bench
//! --kernels`, so the release-mode CI run doubles as the attention
//! parity regression smoke. Results go to stdout and
//! `BENCH_attention.json` (`--out` to relocate) with the detected CPU
//! features and active SIMD tier stamped in.

use super::harness::bench_fn;
use crate::cli::Args;
use crate::model::attention::{Attention, AttnScratch};
use crate::model::{KvCache, QuantLinear};
use crate::rng::Rng;
use crate::serialize::Json;
use crate::tensor::Matrix;
use crate::ternary::simd;
use crate::threads::Pool;
use std::time::Duration;

pub fn run(quick: bool, args: &Args) -> anyhow::Result<()> {
    let threads = args.threads_or_default();
    let budget = Duration::from_millis(if quick { 150 } else { 700 });
    let iters = if quick { 40 } else { 200 };
    let (ctxs, batches): (Vec<usize>, Vec<usize>) = if quick {
        (vec![128, 512], vec![1, 4])
    } else {
        (vec![128, 512, 2048, 4096], vec![1, 8])
    };
    let simd_label = simd::label();
    let cpu_features = simd::cpu_features().join(",");

    // llama-style GQA geometry: 8 query heads share 2 KV heads at
    // head_dim 64 (q_dim 512). Projections are irrelevant here — the
    // ternary benches own them — so they stay 1×1 placeholders and the
    // racers drive the attend stage directly.
    let (heads, kv_heads, hd) = (8usize, 2usize, 64usize);
    let q_dim = heads * hd;
    let attn = Attention {
        wq: QuantLinear::dense(Matrix::zeros(1, 1)),
        wk: QuantLinear::dense(Matrix::zeros(1, 1)),
        wv: QuantLinear::dense(Matrix::zeros(1, 1)),
        wo: QuantLinear::dense(Matrix::zeros(1, 1)),
        n_heads: heads,
        n_kv_heads: kv_heads,
        head_dim: hd,
    };
    println!(
        "== attention race: head-major layout, {heads}q/{kv_heads}kv heads × hd {hd} \
         (threads={threads}, simd={simd_label}) =="
    );

    let pool = Pool::new(threads);
    let mut rng = Rng::new(17);
    let mut rows = Vec::new();
    for &ctx in &ctxs {
        for &bs in &batches {
            // one prewarmed cache per batch row
            let mut caches: Vec<KvCache> = (0..bs)
                .map(|_| KvCache::new(1, kv_heads, hd, ctx))
                .collect();
            let kv_dim = kv_heads * hd;
            for cache in caches.iter_mut() {
                for _ in 0..ctx {
                    let k: Vec<f32> = (0..kv_dim).map(|_| rng.normal()).collect();
                    let v: Vec<f32> = (0..kv_dim).map(|_| rng.normal()).collect();
                    cache.append(0, &k, &v);
                    cache.commit();
                }
            }
            let q = Matrix::randn(bs, q_dim, 1.0, &mut rng);
            let ts = vec![ctx; bs];
            let cache_of: Vec<usize> = (0..bs).collect();

            // scalar reference + hard bitwise parity gates
            let mut scores = Vec::new();
            let mut expect = Matrix::zeros(bs, q_dim);
            for i in 0..bs {
                attn.attend_one(q.row(i), &caches[i], 0, ctx, &mut scores, expect.row_mut(i));
            }
            let mut out = Matrix::zeros(bs, q_dim);
            let mut check = |scratch: &mut AttnScratch, out: &mut Matrix, label: &str| {
                let refs: Vec<&mut KvCache> = caches.iter_mut().collect();
                attn.attend_rows(&q, &ts, &cache_of, &refs, 0, scratch, out);
                assert_eq!(
                    out.data, expect.data,
                    "{label} drifted from scalar attend_one (ctx={ctx} b={bs})"
                );
            };
            let mut scratch_scalar = AttnScratch::default();
            scratch_scalar.set_simd(false);
            scratch_scalar.set_lanes(Some(1));
            check(&mut scratch_scalar, &mut out, "scalar attend_rows");
            let mut scratch_simd = AttnScratch::default();
            scratch_simd.set_simd(true);
            check(&mut scratch_simd, &mut out, "SIMD tier");
            let mut scratch_simd_par = AttnScratch::default();
            scratch_simd_par.set_simd(true);
            scratch_simd_par.set_pool(pool.clone());
            check(&mut scratch_simd_par, &mut out, "threaded SIMD tier");

            // timings (per decode step over the whole batch)
            let refs: Vec<&mut KvCache> = caches.iter_mut().collect();
            let scalar_t = bench_fn(&format!("attn/scalar/c{ctx}b{bs}"), 2, iters, budget, || {
                attn.attend_rows(&q, &ts, &cache_of, &refs, 0, &mut scratch_scalar, &mut out)
            });
            let simd_t = bench_fn(&format!("attn/simd/c{ctx}b{bs}"), 2, iters, budget, || {
                attn.attend_rows(&q, &ts, &cache_of, &refs, 0, &mut scratch_simd, &mut out)
            });
            let simd_par_t =
                bench_fn(&format!("attn/simd-par/c{ctx}b{bs}"), 2, iters, budget, || {
                    attn.attend_rows(&q, &ts, &cache_of, &refs, 0, &mut scratch_simd_par, &mut out)
                });
            let simd_speedup = scalar_t.median.as_secs_f64() / simd_t.median.as_secs_f64();
            let par_speedup = scalar_t.median.as_secs_f64() / simd_par_t.median.as_secs_f64();
            println!(
                "  ctx {ctx:>4} b={bs:<2}  scalar {:>9.1}us  simd {:>9.1}us ({simd_speedup:>4.2}x)  simd@{threads}t {:>9.1}us ({par_speedup:>4.2}x)",
                scalar_t.median_us(),
                simd_t.median_us(),
                simd_par_t.median_us(),
            );
            rows.push(
                Json::obj()
                    .set("ctx", ctx)
                    .set("batch", bs)
                    .set("scalar_us", scalar_t.median_us())
                    .set("simd_us", simd_t.median_us())
                    .set("simd_par_us", simd_par_t.median_us())
                    .set("simd_speedup_vs_scalar", simd_speedup)
                    .set("simd_par_speedup_vs_scalar", par_speedup),
            );
        }
    }

    let out_path = args.str_or("out", "BENCH_attention.json");
    let json = Json::obj()
        .set("bench", "attention")
        // real measured numbers (the committed placeholder says
        // "pending-first-toolchain-run"; CI's bench-baselines job
        // rejects that marker in generated output)
        .set("status", "measured")
        .set("threads", threads)
        .set("quick", quick)
        .set("simd_tier", simd_label)
        .set("cpu_features", cpu_features)
        .set("layout", "head-major")
        .set("n_heads", heads)
        .set("n_kv_heads", kv_heads)
        .set("head_dim", hd)
        .set(
            "parity",
            "all tiers (SIMD, threaded×SIMD) asserted bit-identical to scalar attend_one before timing",
        )
        .set("results", Json::Arr(rows));
    std::fs::write(out_path, json.pretty())?;
    println!("  wrote {out_path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_quick_and_emits_json() {
        let dir = std::env::temp_dir().join("ptqtp_bench_attention");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("a.json");
        let raw = vec![
            "--out".to_string(),
            out.to_string_lossy().to_string(),
            "--threads".to_string(),
            "2".to_string(),
        ];
        let args = Args::parse("ptqtp", raw, &[]);
        run(true, &args).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(j.req_str("bench").unwrap(), "attention");
        assert_eq!(j.req_str("layout").unwrap(), "head-major");
        assert!(!j.req_str("cpu_features").unwrap().is_empty());
        assert!(!j.req_str("simd_tier").unwrap().is_empty());
        let rows = j.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 4); // 2 ctx × 2 batch in quick mode
        std::fs::remove_file(out).ok();
    }
}

//! Kernel-tier race (`ptqtp bench --kernels`): branchless-FMA → packed
//! LUT-decode → activation-indexed LUT → SIMD row-block tier,
//! sequential and row-parallel, at decode (gemv, rows ≥ 256) and
//! prefill (gemm, m = 64) shapes.
//!
//! Before any timing, every racer's output is asserted `==` (bitwise)
//! against `gemv_packed` — so running this bench in release mode (where
//! `debug_assert!`s are off) doubles as the kernel-parity regression
//! smoke CI runs; a SIMD/scalar mismatch aborts the bench (hard parity
//! gate). The int8-activation tier is value-changing, so its gate is
//! *self*-parity instead: every (threads × SIMD) configuration must be
//! bitwise identical to the sequential-scalar int reference, and a
//! whole-model ppl A/B must stay within
//! [`ACT_QUANT_PPL_TOL`](crate::eval::ACT_QUANT_PPL_TOL) — both hard
//! asserts, so CI fails on drift. Results go to stdout and
//! `BENCH_kernels.json` (`--out` to relocate) together with the
//! detected CPU features and active SIMD tier, so baselines are
//! interpretable across machines.

use super::harness::bench_fn;
use super::workload::{quantized, random_ternary, Zoo};
use crate::cli::Args;
use crate::eval::{act_quant_ppl_delta, ACT_QUANT_PPL_TOL};
use crate::rng::Rng;
use crate::serialize::Json;
use crate::tensor::Matrix;
use crate::ternary::gemm::{gemm_packed_blocked, gemm_packed_blocked_par_into, GemmScratch};
use crate::ternary::gemv::{gemv_fused, gemv_packed, gemv_packed_par};
use crate::ternary::int_act::{gemm_int_into, gemv_int_into};
use crate::ternary::lut::{gemm_lut_into, gemv_lut, gemv_lut_into};
use crate::ternary::simd;
use crate::threads::Pool;
use std::time::Duration;

pub fn run(quick: bool, args: &Args) -> anyhow::Result<()> {
    let threads = args.threads_or_default();
    let budget = Duration::from_millis(if quick { 200 } else { 900 });
    let iters = if quick { 80 } else { 400 };
    let pool = Pool::new(threads);
    let simd_label = simd::label();
    let cpu_features = simd::cpu_features().join(",");
    println!("cpu features: {cpu_features} (simd tier: {simd_label})");

    // ---- decode: gemv over projection-shaped matrices (rows ≥ 256) ----
    let decode_shapes: Vec<(usize, usize)> = if quick {
        vec![(256, 128)]
    } else {
        vec![(256, 128), (688, 256), (1024, 512)]
    };
    println!("== kernel race: decode gemv (threads={threads}, simd={simd_label}) ==");
    let mut decode_rows = Vec::new();
    for &(rows, cols) in &decode_shapes {
        let lin = random_ternary(rows, cols, 128, 1 + rows as u64);
        let packed = lin.to_packed();
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();

        // parity gates: every racer bitwise-equal to gemv_packed.
        // A SIMD mismatch fails here, before any timing is recorded.
        let mut y_ref = vec![0.0f32; rows];
        gemv_packed(&packed, &x, &mut y_ref);
        let mut table = Vec::new();
        let mut y = vec![0.0f32; rows];
        gemv_lut(&packed, &x, &mut y, &mut table);
        assert_eq!(y, y_ref, "LUT tier drifted from gemv_packed ({rows}x{cols})");
        y.fill(0.0);
        gemv_packed_par(&packed, &x, &mut y, &pool);
        assert_eq!(y, y_ref, "parallel packed drifted ({rows}x{cols})");
        // scalar-forced scratch (the non-SIMD LUT tier), threaded
        let mut scratch_scalar = GemmScratch::new();
        scratch_scalar.pool = pool.clone();
        scratch_scalar.simd = false;
        y.fill(0.0);
        gemv_lut_into(&packed, &x, &mut y, &mut scratch_scalar);
        assert_eq!(y, y_ref, "parallel LUT drifted ({rows}x{cols})");
        // SIMD-forced scratches: sequential and threaded
        let mut scratch_simd_seq = GemmScratch::new();
        scratch_simd_seq.simd = true;
        let mut scratch_simd = GemmScratch::new();
        scratch_simd.pool = pool.clone();
        scratch_simd.simd = true;
        y.fill(0.0);
        gemv_lut_into(&packed, &x, &mut y, &mut scratch_simd_seq);
        assert_eq!(y, y_ref, "SIMD LUT tier drifted ({rows}x{cols})");
        y.fill(0.0);
        gemv_lut_into(&packed, &x, &mut y, &mut scratch_simd);
        assert_eq!(y, y_ref, "threaded SIMD LUT drifted ({rows}x{cols})");
        if let Some(il) = packed.interleave.clone() {
            y.fill(0.0);
            simd::gemv_packed_simd(&packed, &il, &x, &mut y, &Pool::sequential());
            assert_eq!(y, y_ref, "SIMD packed tier drifted ({rows}x{cols})");
        }
        // int8-activation tier determinism gates: value-changing vs
        // y_ref, so parity is against its own sequential-scalar run —
        // exact `==` across threads and SIMD widths, no tolerance
        let mut scratch_int_seq = GemmScratch::new();
        scratch_int_seq.simd = false;
        scratch_int_seq.act_quant = true;
        let mut y_int = vec![0.0f32; rows];
        gemv_int_into(&packed, &x, &mut y_int, &mut scratch_int_seq);
        assert_ne!(y_int, y_ref, "int8 tier failed to engage ({rows}x{cols})");
        let mut scratch_int_par = GemmScratch::new();
        scratch_int_par.pool = pool.clone();
        scratch_int_par.simd = false;
        scratch_int_par.act_quant = true;
        let mut scratch_int_simd_seq = GemmScratch::new();
        scratch_int_simd_seq.simd = true;
        scratch_int_simd_seq.act_quant = true;
        let mut scratch_int_simd_par = GemmScratch::new();
        scratch_int_simd_par.pool = pool.clone();
        scratch_int_simd_par.simd = true;
        scratch_int_simd_par.act_quant = true;
        for (cfg, s) in [
            ("threads", &mut scratch_int_par),
            ("simd", &mut scratch_int_simd_seq),
            ("simd+threads", &mut scratch_int_simd_par),
        ] {
            y.fill(0.0);
            gemv_int_into(&packed, &x, &mut y, s);
            assert_eq!(y, y_int, "int8 tier drifted under {cfg} ({rows}x{cols})");
        }

        let fused = bench_fn(&format!("gemv/fused/{rows}x{cols}"), 3, iters, budget, || {
            gemv_fused(&lin, &x, &mut y)
        });
        let packed_t = bench_fn(&format!("gemv/packed/{rows}x{cols}"), 3, iters, budget, || {
            gemv_packed(&packed, &x, &mut y)
        });
        let lut_t = bench_fn(&format!("gemv/lut/{rows}x{cols}"), 3, iters, budget, || {
            gemv_lut(&packed, &x, &mut y, &mut table)
        });
        let simd_t = bench_fn(&format!("gemv/simd/{rows}x{cols}"), 3, iters, budget, || {
            gemv_lut_into(&packed, &x, &mut y, &mut scratch_simd_seq)
        });
        let simd_par_t = bench_fn(&format!("gemv/simd-par/{rows}x{cols}"), 3, iters, budget, || {
            gemv_lut_into(&packed, &x, &mut y, &mut scratch_simd)
        });
        // packed-SIMD tier (the dispatch for aligned layers below
        // LUT_MIN_ROWS) gets its own baseline; without an interleave
        // (mode off) this honestly times the scalar packed kernel —
        // the top-level simd_tier field says which it was
        let seq_pool = Pool::sequential();
        let il = packed.interleave.clone();
        let simd_packed_t =
            bench_fn(&format!("gemv/simd-packed/{rows}x{cols}"), 3, iters, budget, || {
                match &il {
                    Some(il) => simd::gemv_packed_simd(&packed, il, &x, &mut y, &seq_pool),
                    None => gemv_packed(&packed, &x, &mut y),
                }
            });
        let int8_t = bench_fn(&format!("gemv/int8/{rows}x{cols}"), 3, iters, budget, || {
            gemv_int_into(&packed, &x, &mut y, &mut scratch_int_simd_seq)
        });
        let lut_speedup = packed_t.median.as_secs_f64() / lut_t.median.as_secs_f64();
        let simd_speedup = lut_t.median.as_secs_f64() / simd_t.median.as_secs_f64();
        let par_speedup = simd_t.median.as_secs_f64() / simd_par_t.median.as_secs_f64();
        let int8_speedup = simd_t.median.as_secs_f64() / int8_t.median.as_secs_f64();
        println!(
            "  {rows:>4}x{cols:<4}  fused {:>8.1}us  packed {:>8.1}us  lut {:>8.1}us ({lut_speedup:>4.2}x)  simd {:>8.1}us ({simd_speedup:>4.2}x)  simd@{threads}t {:>8.1}us ({par_speedup:>4.2}x)  simd-packed {:>8.1}us  int8 {:>8.1}us ({int8_speedup:>4.2}x)",
            fused.median_us(),
            packed_t.median_us(),
            lut_t.median_us(),
            simd_t.median_us(),
            simd_par_t.median_us(),
            simd_packed_t.median_us(),
            int8_t.median_us(),
        );
        decode_rows.push(
            Json::obj()
                .set("rows", rows)
                .set("cols", cols)
                .set("fused_us", fused.median_us())
                .set("packed_us", packed_t.median_us())
                .set("lut_us", lut_t.median_us())
                .set("simd_us", simd_t.median_us())
                .set("simd_par_us", simd_par_t.median_us())
                .set("simd_packed_us", simd_packed_t.median_us())
                .set("int8_us", int8_t.median_us())
                .set("lut_speedup_vs_packed", lut_speedup)
                .set("simd_speedup_vs_lut", simd_speedup)
                .set("par_speedup_vs_simd", par_speedup)
                .set("int8_speedup_vs_simd", int8_speedup),
        );
    }

    // ---- prefill: gemm over an m-row activation stack ----
    let m = 64usize;
    let prefill_shapes: Vec<(usize, usize)> = if quick {
        vec![(344, 128)]
    } else {
        vec![(344, 128), (512, 192)]
    };
    println!("== kernel race: prefill gemm m={m} (threads={threads}, simd={simd_label}) ==");
    let mut prefill_rows = Vec::new();
    for &(rows, cols) in &prefill_shapes {
        let packed = random_ternary(rows, cols, 128, 7 + rows as u64).to_packed();
        let mut rng = Rng::new(8);
        let x = Matrix::randn(m, cols, 1.0, &mut rng);

        let y_ref = gemm_packed_blocked(&packed, &x);
        let mut scratch_scalar_seq = GemmScratch::new();
        scratch_scalar_seq.simd = false;
        let mut scratch_scalar_par = GemmScratch::new();
        scratch_scalar_par.pool = pool.clone();
        scratch_scalar_par.simd = false;
        let mut scratch_simd_seq = GemmScratch::new();
        scratch_simd_seq.simd = true;
        let mut scratch_simd_par = GemmScratch::new();
        scratch_simd_par.pool = pool.clone();
        scratch_simd_par.simd = true;
        let mut y = Matrix::zeros(m, rows);
        gemm_lut_into(&packed, &x, &mut y, &mut scratch_scalar_seq);
        assert_eq!(y.data, y_ref.data, "LUT gemm drifted ({rows}x{cols})");
        y.data.fill(0.0);
        gemm_lut_into(&packed, &x, &mut y, &mut scratch_scalar_par);
        assert_eq!(y.data, y_ref.data, "parallel LUT gemm drifted ({rows}x{cols})");
        y.data.fill(0.0);
        gemm_packed_blocked_par_into(&packed, &x, &mut y, &mut scratch_scalar_par);
        assert_eq!(y.data, y_ref.data, "parallel blocked gemm drifted ({rows}x{cols})");
        y.data.fill(0.0);
        gemm_lut_into(&packed, &x, &mut y, &mut scratch_simd_seq);
        assert_eq!(y.data, y_ref.data, "SIMD LUT gemm drifted ({rows}x{cols})");
        y.data.fill(0.0);
        gemm_lut_into(&packed, &x, &mut y, &mut scratch_simd_par);
        assert_eq!(y.data, y_ref.data, "threaded SIMD LUT gemm drifted ({rows}x{cols})");
        if let Some(il) = packed.interleave.clone() {
            y.data.fill(0.0);
            simd::gemm_packed_simd(&packed, &il, &x, &mut y, &pool);
            assert_eq!(y.data, y_ref.data, "SIMD packed gemm drifted ({rows}x{cols})");
        }
        // int8 tier self-parity (see the decode-side note): every
        // configuration exactly equals the sequential-scalar int run
        let mut scratch_int_seq = GemmScratch::new();
        scratch_int_seq.simd = false;
        scratch_int_seq.act_quant = true;
        let mut y_int = Matrix::zeros(m, rows);
        gemm_int_into(&packed, &x, &mut y_int, &mut scratch_int_seq);
        assert_ne!(y_int.data, y_ref.data, "int8 gemm failed to engage ({rows}x{cols})");
        let mut scratch_int_par = GemmScratch::new();
        scratch_int_par.pool = pool.clone();
        scratch_int_par.simd = false;
        scratch_int_par.act_quant = true;
        let mut scratch_int_simd_seq = GemmScratch::new();
        scratch_int_simd_seq.simd = true;
        scratch_int_simd_seq.act_quant = true;
        let mut scratch_int_simd_par = GemmScratch::new();
        scratch_int_simd_par.pool = pool.clone();
        scratch_int_simd_par.simd = true;
        scratch_int_simd_par.act_quant = true;
        for (cfg, s) in [
            ("threads", &mut scratch_int_par),
            ("simd", &mut scratch_int_simd_seq),
            ("simd+threads", &mut scratch_int_simd_par),
        ] {
            y.data.fill(0.0);
            gemm_int_into(&packed, &x, &mut y, s);
            assert_eq!(y.data, y_int.data, "int8 gemm drifted under {cfg} ({rows}x{cols})");
        }

        let blocked = bench_fn(&format!("gemm/blocked/{rows}x{cols}"), 2, iters, budget, || {
            gemm_packed_blocked_par_into(&packed, &x, &mut y, &mut scratch_scalar_seq)
        });
        let lut_t = bench_fn(&format!("gemm/lut/{rows}x{cols}"), 2, iters, budget, || {
            gemm_lut_into(&packed, &x, &mut y, &mut scratch_scalar_seq)
        });
        let simd_t = bench_fn(&format!("gemm/simd/{rows}x{cols}"), 2, iters, budget, || {
            gemm_lut_into(&packed, &x, &mut y, &mut scratch_simd_seq)
        });
        let simd_par = bench_fn(&format!("gemm/simd-par/{rows}x{cols}"), 2, iters, budget, || {
            gemm_lut_into(&packed, &x, &mut y, &mut scratch_simd_par)
        });
        // packed-SIMD gemm baseline (scalar blocked fallback when no
        // interleave exists — see the decode-side note)
        let il = packed.interleave.clone();
        let simd_packed_t =
            bench_fn(&format!("gemm/simd-packed/{rows}x{cols}"), 2, iters, budget, || {
                match &il {
                    Some(il) => simd::gemm_packed_simd(&packed, il, &x, &mut y, &pool),
                    None => gemm_packed_blocked_par_into(&packed, &x, &mut y, &mut scratch_scalar_par),
                }
            });
        let int8_t = bench_fn(&format!("gemm/int8/{rows}x{cols}"), 2, iters, budget, || {
            gemm_int_into(&packed, &x, &mut y, &mut scratch_int_simd_par)
        });
        let tps = |b: &crate::bench::BenchResult| b.throughput(m as f64);
        println!(
            "  {rows:>4}x{cols:<4}  blocked {:>9.0} tok/s  lut {:>9.0} tok/s  simd {:>9.0} tok/s  simd@{threads}t {:>9.0} tok/s  simd-packed {:>9.0} tok/s  int8@{threads}t {:>9.0} tok/s",
            tps(&blocked),
            tps(&lut_t),
            tps(&simd_t),
            tps(&simd_par),
            tps(&simd_packed_t),
            tps(&int8_t),
        );
        prefill_rows.push(
            Json::obj()
                .set("rows", rows)
                .set("cols", cols)
                .set("m", m)
                .set("blocked_tps", tps(&blocked))
                .set("lut_tps", tps(&lut_t))
                .set("simd_tps", tps(&simd_t))
                .set("simd_par_tps", tps(&simd_par))
                .set("simd_packed_tps", tps(&simd_packed_t))
                .set("int8_tps", tps(&int8_t))
                .set("lut_speedup_vs_blocked", tps(&lut_t) / tps(&blocked))
                .set("simd_speedup_vs_lut", tps(&simd_t) / tps(&lut_t))
                .set("par_speedup_vs_simd", tps(&simd_par) / tps(&simd_t))
                .set("int8_speedup_vs_simd_par", tps(&int8_t) / tps(&simd_par)),
        );
    }

    // ---- int8-activation accuracy: the hard CI gate ----
    // A/B one whole quantized model, f32 vs int8 activations, on the
    // bench corpus. The assert below *is* the CI gate: `bench
    // --kernels` aborts when the tier's relative ppl drift exceeds
    // the documented tolerance, so a quantization regression cannot
    // land while the bench is green.
    let zoo = Zoo::load(&["tiny"]);
    let (mut qmodel, _) = quantized(&zoo.models[0].1, "ptqtp", 128);
    let text: String = zoo.eval_texts["wiki-syn"].chars().take(800).collect();
    let (ppl_f32, ppl_int8, ppl_delta) = act_quant_ppl_delta(&mut qmodel, &zoo.tok, &text);
    println!(
        "== act-quant ppl gate: f32 {ppl_f32:.3} vs int8 {ppl_int8:.3} (delta {ppl_delta:+.4}, tol ±{ACT_QUANT_PPL_TOL}) =="
    );
    assert!(
        ppl_delta.is_finite() && ppl_delta.abs() <= ACT_QUANT_PPL_TOL,
        "int8-activation ppl drift {ppl_delta:+.4} exceeds tolerance ±{ACT_QUANT_PPL_TOL}"
    );

    let out_path = args.str_or("out", "BENCH_kernels.json");
    let json = Json::obj()
        .set("bench", "kernels")
        // real measured numbers (the committed placeholders say
        // "pending-first-toolchain-run"; CI's bench-baselines job
        // rejects that marker in generated output)
        .set("status", "measured")
        .set("threads", threads)
        .set("quick", quick)
        .set("simd_tier", simd_label)
        .set("cpu_features", cpu_features)
        .set(
            "parity",
            "f32 tiers (incl. SIMD row-block) asserted bit-identical to gemv_packed before \
             timing; int8 tier asserted bit-identical to its own sequential-scalar run \
             across threads/SIMD, plus the ppl gate below",
        )
        .set("act_quant_ppl_f32", ppl_f32)
        .set("act_quant_ppl_int8", ppl_int8)
        .set("act_quant_ppl_delta", ppl_delta)
        .set("act_quant_ppl_tol", ACT_QUANT_PPL_TOL)
        .set("decode", Json::Arr(decode_rows))
        .set("prefill", Json::Arr(prefill_rows));
    std::fs::write(out_path, json.pretty())?;
    println!("  wrote {out_path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_quick_and_emits_json() {
        let dir = std::env::temp_dir().join("ptqtp_bench_kernels");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("k.json");
        let raw = vec![
            "--out".to_string(),
            out.to_string_lossy().to_string(),
            "--threads".to_string(),
            "2".to_string(),
        ];
        let args = Args::parse("ptqtp", raw, &[]);
        run(true, &args).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(j.req_str("bench").unwrap(), "kernels");
        assert!(!j.req_str("cpu_features").unwrap().is_empty());
        assert!(!j.req_str("simd_tier").unwrap().is_empty());
        let decode = j.get("decode").and_then(Json::as_arr).unwrap();
        assert_eq!(decode.len(), 1);
        assert!(decode[0].get("int8_us").is_some(), "int8 decode column stamped");
        let prefill = j.get("prefill").and_then(Json::as_arr).unwrap();
        assert_eq!(prefill.len(), 1);
        assert!(prefill[0].get("int8_tps").is_some(), "int8 prefill column stamped");
        // the accuracy gate ran and stamped its numbers
        let delta = j.get("act_quant_ppl_delta").and_then(Json::as_f64).unwrap();
        assert!(delta.abs() <= crate::eval::ACT_QUANT_PPL_TOL);
        std::fs::remove_file(out).ok();
    }
}

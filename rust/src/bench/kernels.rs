//! Kernel-tier race (`ptqtp bench --kernels`): branchless-FMA → packed
//! LUT-decode → activation-indexed LUT, sequential and row-parallel, at
//! decode (gemv, rows ≥ 256) and prefill (gemm, m = 64) shapes.
//!
//! Before any timing, every racer's output is asserted `==` (bitwise)
//! against `gemv_packed` — so running this bench in release mode (where
//! `debug_assert!`s are off) doubles as the kernel-parity regression
//! smoke CI runs. Results go to stdout and `BENCH_kernels.json`
//! (`--out` to relocate), the perf-trajectory baseline for the LUT tier
//! and `--threads` scaling.

use super::harness::bench_fn;
use super::workload::random_ternary;
use crate::cli::Args;
use crate::rng::Rng;
use crate::serialize::Json;
use crate::tensor::Matrix;
use crate::ternary::gemm::{gemm_packed_blocked, gemm_packed_blocked_par_into, GemmScratch};
use crate::ternary::gemv::{gemv_fused, gemv_packed, gemv_packed_par};
use crate::ternary::lut::{gemm_lut_into, gemv_lut};
use crate::threads::Pool;
use std::time::Duration;

pub fn run(quick: bool, args: &Args) -> anyhow::Result<()> {
    let threads = args.threads_or_default();
    let budget = Duration::from_millis(if quick { 200 } else { 900 });
    let iters = if quick { 80 } else { 400 };
    let pool = Pool::new(threads);

    // ---- decode: gemv over projection-shaped matrices (rows ≥ 256) ----
    let decode_shapes: Vec<(usize, usize)> = if quick {
        vec![(256, 128)]
    } else {
        vec![(256, 128), (688, 256), (1024, 512)]
    };
    println!("== kernel race: decode gemv (threads={threads}) ==");
    let mut decode_rows = Vec::new();
    for &(rows, cols) in &decode_shapes {
        let lin = random_ternary(rows, cols, 128, 1 + rows as u64);
        let packed = lin.to_packed();
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();

        // parity gate: every racer bitwise-equal to gemv_packed
        let mut y_ref = vec![0.0f32; rows];
        gemv_packed(&packed, &x, &mut y_ref);
        let mut table = Vec::new();
        let mut y = vec![0.0f32; rows];
        gemv_lut(&packed, &x, &mut y, &mut table);
        assert_eq!(y, y_ref, "LUT tier drifted from gemv_packed ({rows}x{cols})");
        y.fill(0.0);
        gemv_packed_par(&packed, &x, &mut y, &pool);
        assert_eq!(y, y_ref, "parallel packed drifted ({rows}x{cols})");
        let mut scratch = GemmScratch::new();
        scratch.pool = pool.clone();
        y.fill(0.0);
        crate::ternary::lut::gemv_lut_into(&packed, &x, &mut y, &mut scratch);
        assert_eq!(y, y_ref, "parallel LUT drifted ({rows}x{cols})");

        let fused = bench_fn(&format!("gemv/fused/{rows}x{cols}"), 3, iters, budget, || {
            gemv_fused(&lin, &x, &mut y)
        });
        let packed_t = bench_fn(&format!("gemv/packed/{rows}x{cols}"), 3, iters, budget, || {
            gemv_packed(&packed, &x, &mut y)
        });
        let lut_t = bench_fn(&format!("gemv/lut/{rows}x{cols}"), 3, iters, budget, || {
            gemv_lut(&packed, &x, &mut y, &mut table)
        });
        let lut_par_t = bench_fn(&format!("gemv/lut-par/{rows}x{cols}"), 3, iters, budget, || {
            crate::ternary::lut::gemv_lut_into(&packed, &x, &mut y, &mut scratch)
        });
        let lut_speedup = packed_t.median.as_secs_f64() / lut_t.median.as_secs_f64();
        let par_speedup = lut_t.median.as_secs_f64() / lut_par_t.median.as_secs_f64();
        println!(
            "  {rows:>4}x{cols:<4}  fused {:>8.1}us  packed {:>8.1}us  lut {:>8.1}us ({lut_speedup:>4.2}x)  lut@{threads}t {:>8.1}us ({par_speedup:>4.2}x)",
            fused.median_us(),
            packed_t.median_us(),
            lut_t.median_us(),
            lut_par_t.median_us(),
        );
        decode_rows.push(
            Json::obj()
                .set("rows", rows)
                .set("cols", cols)
                .set("fused_us", fused.median_us())
                .set("packed_us", packed_t.median_us())
                .set("lut_us", lut_t.median_us())
                .set("lut_par_us", lut_par_t.median_us())
                .set("lut_speedup_vs_packed", lut_speedup)
                .set("par_speedup_vs_lut", par_speedup),
        );
    }

    // ---- prefill: gemm over an m-row activation stack ----
    let m = 64usize;
    let prefill_shapes: Vec<(usize, usize)> = if quick {
        vec![(344, 128)]
    } else {
        vec![(344, 128), (512, 192)]
    };
    println!("== kernel race: prefill gemm m={m} (threads={threads}) ==");
    let mut prefill_rows = Vec::new();
    for &(rows, cols) in &prefill_shapes {
        let packed = random_ternary(rows, cols, 128, 7 + rows as u64).to_packed();
        let mut rng = Rng::new(8);
        let x = Matrix::randn(m, cols, 1.0, &mut rng);

        let y_ref = gemm_packed_blocked(&packed, &x);
        let mut scratch_seq = GemmScratch::new();
        let mut scratch_par = GemmScratch::new();
        scratch_par.pool = pool.clone();
        let mut y = Matrix::zeros(m, rows);
        gemm_lut_into(&packed, &x, &mut y, &mut scratch_seq);
        assert_eq!(y.data, y_ref.data, "LUT gemm drifted ({rows}x{cols})");
        y.data.fill(0.0);
        gemm_lut_into(&packed, &x, &mut y, &mut scratch_par);
        assert_eq!(y.data, y_ref.data, "parallel LUT gemm drifted ({rows}x{cols})");
        y.data.fill(0.0);
        gemm_packed_blocked_par_into(&packed, &x, &mut y, &mut scratch_par);
        assert_eq!(y.data, y_ref.data, "parallel blocked gemm drifted ({rows}x{cols})");

        let blocked = bench_fn(&format!("gemm/blocked/{rows}x{cols}"), 2, iters, budget, || {
            gemm_packed_blocked_par_into(&packed, &x, &mut y, &mut scratch_seq)
        });
        let lut_t = bench_fn(&format!("gemm/lut/{rows}x{cols}"), 2, iters, budget, || {
            gemm_lut_into(&packed, &x, &mut y, &mut scratch_seq)
        });
        let blocked_par = bench_fn(&format!("gemm/blocked-par/{rows}x{cols}"), 2, iters, budget, || {
            gemm_packed_blocked_par_into(&packed, &x, &mut y, &mut scratch_par)
        });
        let lut_par = bench_fn(&format!("gemm/lut-par/{rows}x{cols}"), 2, iters, budget, || {
            gemm_lut_into(&packed, &x, &mut y, &mut scratch_par)
        });
        let tps = |b: &crate::bench::BenchResult| b.throughput(m as f64);
        println!(
            "  {rows:>4}x{cols:<4}  blocked {:>9.0} tok/s  lut {:>9.0} tok/s  blocked@{threads}t {:>9.0} tok/s  lut@{threads}t {:>9.0} tok/s",
            tps(&blocked),
            tps(&lut_t),
            tps(&blocked_par),
            tps(&lut_par),
        );
        prefill_rows.push(
            Json::obj()
                .set("rows", rows)
                .set("cols", cols)
                .set("m", m)
                .set("blocked_tps", tps(&blocked))
                .set("lut_tps", tps(&lut_t))
                .set("blocked_par_tps", tps(&blocked_par))
                .set("lut_par_tps", tps(&lut_par))
                .set("lut_speedup_vs_blocked", tps(&lut_t) / tps(&blocked))
                .set("par_speedup_vs_lut", tps(&lut_par) / tps(&lut_t)),
        );
    }

    let out_path = args.str_or("out", "BENCH_kernels.json");
    let json = Json::obj()
        .set("bench", "kernels")
        // real measured numbers (the committed placeholders say
        // "pending-first-toolchain-run"; CI's bench-baselines job
        // rejects that marker in generated output)
        .set("status", "measured")
        .set("threads", threads)
        .set("quick", quick)
        .set("parity", "all tiers asserted bit-identical to gemv_packed before timing")
        .set("decode", Json::Arr(decode_rows))
        .set("prefill", Json::Arr(prefill_rows));
    std::fs::write(out_path, json.pretty())?;
    println!("  wrote {out_path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_quick_and_emits_json() {
        let dir = std::env::temp_dir().join("ptqtp_bench_kernels");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("k.json");
        let raw = vec![
            "--out".to_string(),
            out.to_string_lossy().to_string(),
            "--threads".to_string(),
            "2".to_string(),
        ];
        let args = Args::parse("ptqtp", raw, &[]);
        run(true, &args).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(j.req_str("bench").unwrap(), "kernels");
        let decode = j.get("decode").and_then(Json::as_arr).unwrap();
        assert_eq!(decode.len(), 1);
        let prefill = j.get("prefill").and_then(Json::as_arr).unwrap();
        assert_eq!(prefill.len(), 1);
        std::fs::remove_file(out).ok();
    }
}

//! Fig. 1 reproduction — the paper's headline四panel:
//!   (a) PPL vs method at matched storage;
//!   (b) quantization runtime: PTQTP ≫ faster than ARB, ~1.5× vs AWQ;
//!   (c) PPL across model scales vs 4-bit / FP16;
//!   (d) per-benchmark retention of PTQTP on the largest model.

use super::workload::{ppl_quick, quantized, Zoo};
use crate::cli::Args;
use crate::data::TaskSuite;
use crate::eval::eval_suite;
use crate::report::Table;

pub fn run(quick: bool, args: &Args) -> anyhow::Result<()> {
    let fams: Vec<&str> = if quick { vec!["tiny", "small"] } else { vec!["tiny", "small", "medium"] };
    let zoo = Zoo::load(&fams);
    println!("{}", zoo.banner());
    let budget = if quick { 800 } else { 2000 };
    let group = args.usize_or("group-size", 128);
    let text = zoo.eval_texts["wiki-syn"].clone();

    // ---- (a) PPL vs method on the mid model
    let mid = &zoo.models[zoo.models.len() / 2];
    let mut ta = Table::new(
        &format!("Fig 1(a) — wiki-syn PPL by method, {}", mid.0),
        &["Method", "#Bits", "PPL"],
    );
    for m in ["fp16", "gptq3", "gptq2", "billm", "arb", "ptqtp"] {
        let q = crate::quant::by_name(m, group)?;
        let (qm, _) = quantized(&mid.1, m, group);
        ta.row(vec![
            q.name(),
            format!("{:.2}", q.nominal_bits()),
            crate::report::fmt_metric(ppl_quick(&qm, &zoo.tok, &text, budget)),
        ]);
    }
    println!("{}", ta.render());

    // ---- (b) quantization runtime by method on the largest model
    let big = zoo.models.last().unwrap();
    let mut tb = Table::new(
        &format!("Fig 1(b) — quantization wall-clock, {}", big.0),
        &["Method", "time (ms)", "speedup vs ARB"],
    );
    let mut times = Vec::new();
    for m in ["rtn3", "awq3", "gptq3", "billm", "arb", "ptqtp"] {
        let (_, dur) = quantized(&big.1, m, group);
        times.push((m, dur));
    }
    let arb_time = times.iter().find(|(m, _)| *m == "arb").unwrap().1;
    for (m, dur) in &times {
        tb.row(vec![
            crate::quant::by_name(m, group)?.name(),
            format!("{:.1}", dur.as_secs_f64() * 1e3),
            format!("{:.2}x", arb_time.as_secs_f64() / dur.as_secs_f64().max(1e-9)),
        ]);
    }
    println!("{}", tb.render());

    // ---- (c) PPL across scales: FP16 vs 4-bit vs PTQTP
    let mut tc = Table::new(
        "Fig 1(c) — wiki-syn PPL across model scales",
        &{
            let mut h = vec!["Method"];
            h.extend(zoo.models.iter().map(|(n, _)| n.as_str()));
            h
        },
    );
    for m in ["fp16", "gptq4", "ptqtp"] {
        let mut cells = vec![crate::quant::by_name(m, group)?.name()];
        for (_, model) in &zoo.models {
            let (qm, _) = quantized(model, m, group);
            cells.push(crate::report::fmt_metric(ppl_quick(&qm, &zoo.tok, &text, budget)));
        }
        tc.row(cells);
    }
    println!("{}", tc.render());

    // ---- (d) per-benchmark degradation on the largest model
    let n = if quick { 20 } else { 40 };
    let suite = TaskSuite::standard(args.u64_or("seed", 1), n, n, n);
    let fp = eval_suite(&big.1, &zoo.tok, &suite);
    let (qm, _) = quantized(&big.1, "ptqtp", group);
    let qs = eval_suite(&qm, &zoo.tok, &suite);
    let mut td = Table::new(
        &format!("Fig 1(d) — PTQTP retention on {}", big.0),
        &["Benchmark", "FP16 %", "PTQTP %", "retention %"],
    );
    for (name, f, q) in [
        ("Math*", fp.math_acc, qs.math_acc),
        ("Cloze*", fp.cloze_acc, qs.cloze_acc),
        ("Code*", fp.code_acc, qs.code_acc),
    ] {
        td.row(vec![
            name.into(),
            format!("{:.1}", f * 100.0),
            format!("{:.1}", q * 100.0),
            if f > 0.0 { format!("{:.1}", q / f * 100.0) } else { "-".into() },
        ]);
    }
    println!("{}", td.render());
    Ok(())
}

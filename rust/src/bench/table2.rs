//! Table 2 reproduction: downstream accuracy on the largest model
//! across PTQ methods — the paper's headline "math survives PTQTP,
//! collapses under binary PTQ" experiment.

use super::workload::{quantized, Zoo};
use crate::cli::Args;
use crate::data::TaskSuite;
use crate::eval::eval_suite;
use crate::report::Table;

pub fn run(quick: bool, args: &Args) -> anyhow::Result<()> {
    let fam = if quick { "small" } else { "medium" };
    let zoo = Zoo::load(&[fam]);
    println!("{}", zoo.banner());
    let model = &zoo.models[0].1;
    let group = args.usize_or("group-size", 128);
    let n = if quick { 20 } else { 50 };
    let suite = TaskSuite::standard(args.u64_or("seed", 1), n, n, n);

    let methods: Vec<&str> = if quick {
        vec!["fp16", "gptq3", "billm", "arb", "ptqtp"]
    } else {
        vec!["fp16", "awq4", "gptq3", "pbllm", "billm", "arb", "ptqtp"]
    };

    let mut table = Table::new(
        &format!("Table 2 — Accuracy (%) on {fam} across methods"),
        &["Method", "Math-500*", "GSM8K*", "Cloze(ARC/MMLU)*", "Code*"],
    );
    for method in methods {
        let q = crate::quant::by_name(method, group)?;
        let (qm, _) = quantized(model, method, group);
        let s = eval_suite(&qm, &zoo.tok, &suite);
        // math suite doubles for both math rows (paper lists two math
        // benchmarks; our generator is one family — reported identically)
        table.metric_row(
            &q.name(),
            &[
                s.math_acc * 100.0,
                s.math_acc * 100.0,
                s.cloze_acc * 100.0,
                s.code_acc * 100.0,
            ],
        );
    }
    println!("{}", table.render());
    println!("(*synthetic stand-ins; see DESIGN.md §2 substitutions)");
    Ok(())
}

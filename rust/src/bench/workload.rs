//! Shared bench fixtures: the model zoo, eval corpora, quantization
//! helpers, and the quick-perplexity protocol every table uses.
//!
//! Benches prefer the checkpoints trained by `make artifacts`
//! (`artifacts/models/*.ptw`); when absent (e.g. CI unit runs) they fall
//! back to deterministic heavy-tailed random models and mark the output
//! accordingly — the *shape* claims still hold because they are driven
//! by weight statistics, but absolute PPLs are then meaningless.

use crate::data::{CorpusDomain, CorpusGen, Tokenizer};
use crate::model::{ModelConfig, Transformer};
use crate::quant::{self, QuantCtx};
use crate::rng::Rng;
use std::collections::BTreeMap;

/// Fixture bundle for the bench suite.
pub struct Zoo {
    /// (family name, model), ordered small → large.
    pub models: Vec<(String, Transformer)>,
    pub tok: Tokenizer,
    /// domain name → held-out text.
    pub eval_texts: BTreeMap<&'static str, String>,
    /// True when real trained checkpoints were found.
    pub trained: bool,
}

impl Zoo {
    /// Load the fixture set. `families` trims the grid (quick mode).
    pub fn load(families: &[&str]) -> Zoo {
        let model_dir = std::path::Path::new("artifacts/models");
        let data_dir = std::path::Path::new("data");

        // tokenizer + eval texts: from data/ if present, else generated
        let (tok, eval_texts) = if data_dir.join("tokenizer.json").exists() {
            let tok = Tokenizer::load(data_dir.join("tokenizer.json")).expect("tokenizer");
            let mut texts = BTreeMap::new();
            for d in CorpusDomain::all() {
                let t = std::fs::read_to_string(data_dir.join(format!("eval_{}.txt", d.name())))
                    .unwrap_or_default();
                texts.insert(d.name(), t);
            }
            (tok, texts)
        } else {
            let mut gen = CorpusGen::new(0xBEAC4);
            let mut texts = BTreeMap::new();
            let mut all = String::new();
            for d in CorpusDomain::all() {
                let t = gen.domain_text(d, 200);
                all.push_str(&t);
                texts.insert(d.name(), t);
            }
            (Tokenizer::from_text(&all), texts)
        };

        let mut models = Vec::new();
        let mut trained = true;
        for fam in families {
            let path = model_dir.join(format!("{fam}.ptw"));
            let model = if path.exists() {
                Transformer::load(&path).expect("load checkpoint")
            } else {
                trained = false;
                let mut cfg = ModelConfig::family(fam).expect("family");
                cfg.vocab_size = tok.vocab_size();
                let mut rng = Rng::new(0xF0 + fam.len() as u64);
                Transformer::random(cfg, &mut rng)
            };
            models.push((fam.to_string(), model));
        }
        Zoo {
            models,
            tok,
            eval_texts,
            trained,
        }
    }

    /// Load the QAT comparator checkpoint if trained.
    pub fn qat_model(&self) -> Option<Transformer> {
        let path = std::path::Path::new("artifacts/models/small-qat.ptw");
        if path.exists() {
            Some(Transformer::load(path).expect("load qat"))
        } else {
            None
        }
    }

    pub fn banner(&self) -> String {
        if self.trained {
            "models: trained checkpoints (make artifacts)".into()
        } else {
            "models: RANDOM-INIT fallback (run `make artifacts` for trained PPLs)".into()
        }
    }
}

/// Quantize a copy of `model` with `method` and return it with the
/// quantization wall-clock.
pub fn quantized(
    model: &Transformer,
    method: &str,
    group: usize,
) -> (Transformer, std::time::Duration) {
    let mut m = model.clone();
    if method == "fp16" || method == "fp" {
        return (m, std::time::Duration::ZERO);
    }
    let q = quant::by_name(method, group).expect("method");
    let ctx = calib_ctx(model.config.d_model, 7);
    let t0 = std::time::Instant::now();
    m.quantize_with(q.as_ref(), &ctx);
    (m, t0.elapsed())
}

/// Random two-plane ternary layer (uniform trits, N(0, 0.2²) group
/// scales) — the one shared weight population for the kernel parity
/// tests and the `bench --kernels` race, so they never silently drift
/// onto different distributions.
pub fn random_ternary(rows: usize, cols: usize, group: usize, seed: u64) -> crate::ternary::TernaryLinear {
    let mut rng = Rng::new(seed);
    let mut lin = crate::ternary::TernaryLinear::new(rows, cols, group);
    for t in lin.t1.trits.iter_mut().chain(lin.t2.trits.iter_mut()) {
        *t = rng.below(3) as i8 - 1;
    }
    for a in lin.alpha1.iter_mut().chain(lin.alpha2.iter_mut()) {
        *a = rng.normal() * 0.2;
    }
    lin
}

/// Synthetic calibration context (per-layer widths are fixed up inside
/// `QuantLinear::quantize_with`).
pub fn calib_ctx(d: usize, seed: u64) -> QuantCtx {
    let mut rng = Rng::new(seed);
    QuantCtx {
        calib: Some(crate::tensor::Matrix::randn(32, d, 1.0, &mut rng)),
        seed,
        pool: crate::threads::Pool::sequential(),
    }
}

/// Perplexity on a budgeted prefix (keeps the full table grid tractable
/// on one core; protocol otherwise identical to eval::perplexity).
pub fn ppl_quick(model: &Transformer, tok: &Tokenizer, text: &str, char_budget: usize) -> f64 {
    let prefix: String = text.chars().take(char_budget).collect();
    crate::eval::perplexity(model, tok, &prefix)
}

/// The method grid of Table 1 (ordered as in the paper).
pub fn table1_methods(quick: bool) -> Vec<&'static str> {
    if quick {
        vec!["fp16", "gptq3", "billm", "arb", "ptqtp"]
    } else {
        vec![
            "fp16", "awq3", "awq2", "gptq3", "gptq2", "pbllm", "billm", "arb", "ptqtp",
        ]
    }
}

/// A synthetic "layer" with trained-LLM-like statistics, for kernel and
/// quantizer micro-benches that don't need a whole model.
pub fn bench_weight(n: usize, d: usize, seed: u64) -> crate::tensor::Matrix {
    let mut rng = Rng::new(seed);
    crate::tensor::Matrix::rand_heavy(n, d, 0.03, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_loads_with_fallback() {
        let zoo = Zoo::load(&["tiny"]);
        assert_eq!(zoo.models.len(), 1);
        assert_eq!(zoo.eval_texts.len(), 3);
        assert!(!zoo.banner().is_empty());
    }

    #[test]
    fn quantized_returns_modified_model() {
        let zoo = Zoo::load(&["tiny"]);
        let (m, dur) = quantized(&zoo.models[0].1, "ptqtp", 128);
        assert!(m.blocks[0].attn.wq.is_ternary());
        assert!(dur.as_nanos() > 0);
        let (m2, d2) = quantized(&zoo.models[0].1, "fp16", 128);
        assert!(!m2.blocks[0].attn.wq.is_ternary());
        assert_eq!(d2.as_nanos(), 0);
    }

    #[test]
    fn ppl_quick_budget_respected() {
        let zoo = Zoo::load(&["tiny"]);
        let text = zoo.eval_texts["wiki-syn"].clone();
        let p = ppl_quick(&zoo.models[0].1, &zoo.tok, &text, 300);
        assert!(p.is_finite() && p > 1.0);
    }
}

//! Benchmark substrate (criterion is unavailable offline) and the
//! per-table/figure reproduction harness.
//!
//! [`harness`] provides warmup + timed iterations with median/p95
//! reporting; the `table*` / `fig*` submodules regenerate every exhibit
//! in the paper's evaluation (see DESIGN.md §5 for the index) and are
//! invoked through `ptqtp bench --table N` / `--fig N` or `cargo bench`.
//! [`batched`] (`--batched`), [`kernels`] (`--kernels`),
//! [`attention`] (`--attention`), and [`prefix`] (`--prefix`) are the
//! perf-trajectory benches: fused-batch throughput + thread scaling,
//! the ternary kernel-tier race, the head-major attention-tier race,
//! and the paged-KV prefix-cache cold/warm race — all behind
//! bit-identity parity gates.

pub mod attention;
pub mod batched;
pub mod harness;
pub mod kernels;
pub mod prefix;
pub mod speculative;
pub mod workload;

pub mod fig1;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;
pub mod table6;
pub mod table7;
pub mod table8;
pub mod table10;
pub mod table11;
pub mod table12;

pub use harness::{bench_fn, BenchResult};

use crate::cli::Args;

/// Dispatch a paper-table reproduction by number.
pub fn run_table(table: &str, quick: bool, args: &Args) -> anyhow::Result<()> {
    match table {
        "1" | "9" => table1::run(quick, args),
        "2" => table2::run(quick, args),
        "3" => table3::run(quick, args),
        "4" => table4::run(quick, args),
        "5" => table5::run(quick, args),
        "6" => table6::run(quick, args),
        "7" => table7::run(quick, args),
        "8" => table8::run(quick, args),
        "10" => table10::run(quick, args),
        "11" => table11::run(quick, args),
        "12" => table12::run(quick, args),
        other => anyhow::bail!("unknown table '{other}' (valid: 1-12; 9 aliases 1)"),
    }
}

/// Dispatch a paper-figure reproduction by number.
pub fn run_fig(fig: &str, quick: bool, args: &Args) -> anyhow::Result<()> {
    match fig {
        "1" => fig1::run(quick, args),
        "3" => fig3::run(quick, args),
        "4" => fig4::run(quick, args),
        "5" => fig5::run(quick, args),
        other => anyhow::bail!("unknown figure '{other}' (valid: 1, 3, 4, 5)"),
    }
}

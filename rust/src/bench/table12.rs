//! Table 12 reproduction: code-generation benchmark (HumanEval/MBPP
//! stand-in: bracket-completion exact match) across models and PTQTP.

use super::workload::{quantized, Zoo};
use crate::cli::Args;
use crate::data::TaskSuite;
use crate::eval::suite::eval_exact_match;
use crate::report::Table;

pub fn run(quick: bool, args: &Args) -> anyhow::Result<()> {
    let fams: Vec<&str> = if quick { vec!["tiny", "small"] } else { vec!["tiny", "small", "medium"] };
    let zoo = Zoo::load(&fams);
    println!("{}", zoo.banner());
    let n = if quick { 25 } else { 60 };
    let suite = TaskSuite::standard(args.u64_or("seed", 1), 0, 0, n);

    let mut table = Table::new(
        "Table 12 — code benchmark (bracket-completion exact match %)",
        &["Model", "HumanEval*", "MBPP*"],
    );
    // two disjoint task draws stand in for the two code suites
    let suite2 = TaskSuite::standard(args.u64_or("seed", 1) ^ 0xC0DE, 0, 0, n);
    for (name, model) in &zoo.models {
        let a = eval_exact_match(model, &zoo.tok, &suite.code);
        let b = eval_exact_match(model, &zoo.tok, &suite2.code);
        table.metric_row(&format!("{name} (FP16)"), &[a * 100.0, b * 100.0]);
    }
    for (name, model) in &zoo.models {
        let (qm, _) = quantized(model, "ptqtp", 128);
        let a = eval_exact_match(&qm, &zoo.tok, &suite.code);
        let b = eval_exact_match(&qm, &zoo.tok, &suite2.code);
        table.metric_row(&format!("{name}-PTQTP"), &[a * 100.0, b * 100.0]);
    }
    println!("{}", table.render());
    println!("(*synthetic stand-ins; see DESIGN.md §2 substitutions)");
    Ok(())
}

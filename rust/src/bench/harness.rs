//! Timing harness: warmup, calibrated iteration counts, robust stats.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn median_ms(&self) -> f64 {
        self.median.as_secs_f64() * 1e3
    }

    pub fn median_us(&self) -> f64 {
        self.median.as_secs_f64() * 1e6
    }

    /// Throughput in ops/s given `work` units per iteration.
    pub fn throughput(&self, work: f64) -> f64 {
        work / self.median.as_secs_f64()
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<32} {:>10.3} ms median  {:>10.3} ms p95  ({} iters)",
            self.name,
            self.median_ms(),
            self.p95.as_secs_f64() * 1e3,
            self.iters
        )
    }
}

/// Benchmark a closure: warm up for `warmup` iterations, then run either
/// `max_iters` iterations or until `budget` elapses, whichever first.
/// The closure's return value is consumed through `std::hint::black_box`
/// so the optimizer cannot elide the work.
pub fn bench_fn<T>(name: &str, warmup: usize, max_iters: usize, budget: Duration, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(max_iters.min(4096));
    let start = Instant::now();
    for _ in 0..max_iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
        if start.elapsed() > budget {
            break;
        }
    }
    summarize(name, samples)
}

/// Quick preset: 3 warmups, ≤200 iters, 2 s budget.
pub fn quick<T>(name: &str, f: impl FnMut() -> T) -> BenchResult {
    bench_fn(name, 3, 200, Duration::from_secs(2), f)
}

fn summarize(name: &str, mut samples: Vec<Duration>) -> BenchResult {
    assert!(!samples.is_empty(), "no samples for bench '{name}'");
    samples.sort_unstable();
    let iters = samples.len();
    let median = samples[iters / 2];
    let p95 = samples[((iters as f64 * 0.95) as usize).min(iters - 1)];
    let min = samples[0];
    let mean_ns = samples.iter().map(|d| d.as_nanos()).sum::<u128>() / iters as u128;
    BenchResult {
        name: name.to_string(),
        iters,
        median,
        mean: Duration::from_nanos(mean_ns as u64),
        p95,
        min,
    }
}

/// Measure one invocation (used for long quantization runs where
/// repeating is impractical; paper Fig 1b style wall-clock).
pub fn once<T>(name: &str, f: impl FnOnce() -> T) -> (T, BenchResult) {
    let t0 = Instant::now();
    let out = std::hint::black_box(f());
    let d = t0.elapsed();
    (
        out,
        BenchResult {
            name: name.to_string(),
            iters: 1,
            median: d,
            mean: d,
            p95: d,
            min: d,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let r = bench_fn("noop", 1, 50, Duration::from_millis(200), || 1 + 1);
        assert!(r.iters >= 1);
        assert!(r.median <= r.p95);
        assert!(r.min <= r.median);
    }

    #[test]
    fn budget_caps_runtime() {
        let t0 = Instant::now();
        let _ = bench_fn("sleepy", 0, 1_000_000, Duration::from_millis(50), || {
            std::thread::sleep(Duration::from_millis(1))
        });
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn once_returns_value() {
        let (v, r) = once("compute", || 21 * 2);
        assert_eq!(v, 42);
        assert_eq!(r.iters, 1);
    }

    #[test]
    fn throughput_computation() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            median: Duration::from_secs(2),
            mean: Duration::from_secs(2),
            p95: Duration::from_secs(2),
            min: Duration::from_secs(2),
        };
        assert!((r.throughput(10.0) - 5.0).abs() < 1e-9);
    }
}

//! Table 4 reproduction: memory footprint of PTQTP vs binary methods —
//! both from the paper's analytic formulas (Eqs. 9–13, exact) and from
//! our measured packed representations.

use crate::cli::Args;
use crate::quant::metrics::*;
use crate::report::Table;

fn gib(bytes: usize) -> f64 {
    bytes as f64 / (1024.0 * 1024.0 * 1024.0)
}

pub fn run(_quick: bool, _args: &Args) -> anyhow::Result<()> {
    // LLaMA-7B / 13B layer grids (the paper's Table 4 subjects): we sum
    // the analytic per-layer formulas over the real architectures.
    // LLaMA-7B: d=4096, ff=11008, 32 layers; 13B: d=5120, ff=13824, 40.
    for (name, d, ff, layers) in [("LLaMA-7B", 4096usize, 11008usize, 32usize),
                                  ("LLaMA-13B", 5120, 13824, 40)] {
        let layer_dims: Vec<(usize, usize)> = vec![
            (d, d), (d, d), (d, d), (d, d),       // q k v o (MHA era: kv=d)
            (ff, d), (ff, d), (d, ff),            // gate up down
        ];
        let k = 128;
        let sum = |f: &dyn Fn(usize, usize) -> usize| -> usize {
            layers * layer_dims.iter().map(|&(n, dd)| f(n, dd)).sum::<usize>()
        };
        let c_of = |dd: usize| dd / 10; // 10% salient columns
        let mut table = Table::new(
            &format!("Table 4 — Memory footprint, {name} (G={k})"),
            &["Method", "Group", "Memory (GB)"],
        );
        table.row(vec!["FP16".into(), "-".into(), format!("{:.2}", gib(sum(&|n, dd| mem_fp16(n, dd))))]);
        table.row(vec!["PB-LLM".into(), "-".into(), format!("{:.2}", gib(sum(&|n, dd| mem_pbllm(n, dd, k, 0.10))))]);
        table.row(vec!["BiLLM".into(), "-".into(), format!("{:.2}", gib(sum(&|n, dd| mem_billm(n, dd, k, c_of(dd)))))]);
        table.row(vec!["ARB-LLM_RC".into(), "x".into(), format!("{:.2}", gib(sum(&|n, dd| mem_arb_rc(n, dd, dd, c_of(dd)))))]);
        table.row(vec!["ARB-LLM_RC".into(), "ok".into(), format!("{:.2}", gib(sum(&|n, dd| mem_arb_rc(n, dd, k, c_of(dd)))))]);
        table.row(vec!["PTQTP".into(), "x".into(), format!("{:.2}", gib(sum(&|n, dd| mem_ptqtp(n, dd, dd))))]);
        table.row(vec!["PTQTP".into(), "ok".into(), format!("{:.2}", gib(sum(&|n, dd| mem_ptqtp(n, dd, k))))]);
        println!("{}", table.render());
    }

    // measured: pack a real layer and compare against Eq. 13
    let w = super::workload::bench_weight(1024, 4096, 5);
    let q = crate::quant::ptqtp::Ptqtp::default();
    let (lin, _) = q.quantize_with_report(&w);
    let packed = lin.to_packed();
    let mut t = Table::new(
        "Table 4b — measured vs analytic (1024×4096 layer, G=128)",
        &["quantity", "bytes"],
    );
    t.row(vec!["Eq. 13 analytic".into(), format!("{}", mem_ptqtp(1024, 4096, 128))]);
    t.row(vec!["measured packed (f32 α)".into(), format!("{}", packed.resident_bytes())]);
    t.row(vec!["measured deploy (fp16 α)".into(), format!("{}", lin.memory_bytes())]);
    t.row(vec!["fp16 dense".into(), format!("{}", 1024 * 4096 * 2)]);
    println!("{}", t.render());
    Ok(())
}

//! Table 8 reproduction: group-wise vs whole-row quantization ablation.
//!
//! Paper shape: G=128 grouping improves every method; the gap is
//! largest for the grid methods (AWQ/GPTQ) and modest for PTQTP, whose
//! local trit search already adapts to group statistics.

use super::workload::{ppl_quick, quantized, Zoo};
use crate::cli::Args;
use crate::report::Table;

pub fn run(quick: bool, _args: &Args) -> anyhow::Result<()> {
    let fams: Vec<&str> = if quick { vec!["small"] } else { vec!["small", "medium"] };
    let zoo = Zoo::load(&fams);
    println!("{}", zoo.banner());
    let budget = if quick { 1000 } else { 2000 };
    let methods: Vec<&str> = if quick {
        vec!["gptq3", "ptqtp"]
    } else {
        vec!["awq3", "gptq3", "rtn3", "ptqtp"]
    };

    for (name, model) in &zoo.models {
        let text = zoo.eval_texts["wiki-syn"].clone();
        let mut table = Table::new(
            &format!("Table 8 — w/o group-wise (G=128) PPL on wiki-syn, {name}"),
            &["Method", "no group", "G=128"],
        );
        for method in &methods {
            let q = crate::quant::by_name(method, 128)?;
            let (m_nog, _) = quantized(model, method, 0);
            let (m_g, _) = quantized(model, method, 128);
            table.row(vec![
                q.name(),
                crate::report::fmt_metric(ppl_quick(&m_nog, &zoo.tok, &text, budget)),
                crate::report::fmt_metric(ppl_quick(&m_g, &zoo.tok, &text, budget)),
            ]);
        }
        let fp = ppl_quick(model, &zoo.tok, &text, budget);
        table.row(vec!["FP16".into(), crate::report::fmt_metric(fp), crate::report::fmt_metric(fp)]);
        println!("{}", table.render());
    }
    Ok(())
}

//! Character-level tokenizer with persisted vocabulary.
//!
//! Shared contract with `python/compile/data.py`: the vocab JSON lists
//! characters in id order; id 0 is reserved for `<pad>`, id 1 for
//! `<unk>`, id 2 for `<eos>` (also used as the generation stop token).

use crate::serialize::Json;
use std::collections::BTreeMap;

pub const PAD: u32 = 0;
pub const UNK: u32 = 1;
pub const EOS: u32 = 2;

/// Character tokenizer.
#[derive(Clone, Debug, PartialEq)]
pub struct Tokenizer {
    /// id → char (ids 0..3 are specials, not in this list's chars).
    chars: Vec<char>,
    /// char → id
    map: BTreeMap<char, u32>,
}

impl Tokenizer {
    /// Build from the set of characters appearing in `text` (sorted for
    /// determinism).
    pub fn from_text(text: &str) -> Tokenizer {
        let mut set: Vec<char> = {
            let mut s: Vec<char> = text.chars().collect();
            s.sort_unstable();
            s.dedup();
            s
        };
        set.retain(|c| *c != '\u{0}');
        let mut map = BTreeMap::new();
        for (i, &c) in set.iter().enumerate() {
            map.insert(c, i as u32 + 3);
        }
        Tokenizer { chars: set, map }
    }

    pub fn vocab_size(&self) -> usize {
        self.chars.len() + 3
    }

    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.chars()
            .map(|c| self.map.get(&c).copied().unwrap_or(UNK))
            .collect()
    }

    /// Encode and append EOS.
    pub fn encode_with_eos(&self, text: &str) -> Vec<u32> {
        let mut v = self.encode(text);
        v.push(EOS);
        v
    }

    /// Encode multi-line text with EOS separating lines — the training
    /// contract (`python/compile/data.py` joins corpus lines with EOS,
    /// so evaluation must do the same; raw `\n` is never trained on).
    pub fn encode_lines(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::with_capacity(text.len());
        for line in text.lines() {
            out.extend(self.encode(line));
            out.push(EOS);
        }
        out
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .filter_map(|&id| match id {
                PAD | EOS => None,
                UNK => Some('\u{fffd}'),
                i => self.chars.get(i as usize - 3).copied(),
            })
            .collect()
    }

    // ---------- io (shared with python) ----------

    pub fn to_json(&self) -> Json {
        Json::obj().set(
            "chars",
            Json::Str(self.chars.iter().collect::<String>()),
        )
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Tokenizer> {
        let chars = j.req_str("chars")?;
        let mut t = Tokenizer {
            chars: chars.chars().collect(),
            map: BTreeMap::new(),
        };
        for (i, c) in t.chars.clone().into_iter().enumerate() {
            t.map.insert(c, i as u32 + 3);
        }
        Ok(t)
    }

    pub fn save(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().pretty())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> anyhow::Result<Tokenizer> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| anyhow::anyhow!("read {:?}: {e}", path.as_ref()))?;
        Tokenizer::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_text() {
        let t = Tokenizer::from_text("hello world 123+=?");
        let ids = t.encode("wold 31+");
        assert_eq!(t.decode(&ids), "wold 31+");
    }

    #[test]
    fn unknown_chars_map_to_unk() {
        let t = Tokenizer::from_text("abc");
        let ids = t.encode("abz");
        assert_eq!(ids[2], UNK);
    }

    #[test]
    fn specials_reserved() {
        let t = Tokenizer::from_text("ab");
        let ids = t.encode("ab");
        assert!(ids.iter().all(|&i| i >= 3));
        assert_eq!(t.vocab_size(), 5);
    }

    #[test]
    fn deterministic_ordering() {
        let a = Tokenizer::from_text("cba");
        let b = Tokenizer::from_text("abcabc");
        assert_eq!(a, b);
    }

    #[test]
    fn eos_terminates_decode() {
        let t = Tokenizer::from_text("xy");
        let mut ids = t.encode("xy");
        ids.push(EOS);
        ids.extend(t.encode("x"));
        // decode skips EOS but keeps following chars (caller splits)
        assert_eq!(t.decode(&ids), "xyx");
    }

    #[test]
    fn json_roundtrip() {
        let t = Tokenizer::from_text("abc déf!");
        let j = t.to_json();
        let back = Tokenizer::from_json(&j).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.encode("déf"), t.encode("déf"));
    }

    #[test]
    fn file_roundtrip() {
        let t = Tokenizer::from_text("0123456789+-*= QA:?");
        let p = std::env::temp_dir().join("ptqtp_tok_test.json");
        t.save(&p).unwrap();
        assert_eq!(Tokenizer::load(&p).unwrap(), t);
        std::fs::remove_file(p).ok();
    }
}

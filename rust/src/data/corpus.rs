//! Deterministic synthetic corpora.
//!
//! Three text domains with distinct statistics stand in for the paper's
//! WikiText2 / PTB / C4 (perplexity datasets), plus the arithmetic and
//! fact corpora that give the tiny models the math / knowledge skills
//! whose post-quantization *retention* the paper measures (Table 2).
//!
//! Generation is a template grammar over fixed word banks driven by the
//! deterministic RNG, so `make artifacts` always produces byte-identical
//! data for a given seed.

use crate::rng::Rng;

/// The three perplexity domains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorpusDomain {
    /// Encyclopedic, longer sentences — WikiText2 stand-in.
    WikiSyn,
    /// Telegraphic newswire — PTB stand-in.
    PtbSyn,
    /// Noisy web text — C4 stand-in.
    C4Syn,
}

impl CorpusDomain {
    pub fn all() -> [CorpusDomain; 3] {
        [CorpusDomain::WikiSyn, CorpusDomain::PtbSyn, CorpusDomain::C4Syn]
    }

    pub fn name(&self) -> &'static str {
        match self {
            CorpusDomain::WikiSyn => "wiki-syn",
            CorpusDomain::PtbSyn => "ptb-syn",
            CorpusDomain::C4Syn => "c4-syn",
        }
    }

    pub fn from_name(name: &str) -> anyhow::Result<CorpusDomain> {
        Ok(match name {
            "wiki-syn" | "wikitext2" | "wiki" => CorpusDomain::WikiSyn,
            "ptb-syn" | "ptb" => CorpusDomain::PtbSyn,
            "c4-syn" | "c4" => CorpusDomain::C4Syn,
            other => anyhow::bail!("unknown corpus domain '{other}'"),
        })
    }
}

// word banks — small, lowercase, shared char alphabet across domains
const SUBJECTS: &[&str] = &[
    "the river", "a mountain", "the ancient city", "this region", "the empire",
    "the species", "a traveler", "the scientist", "the library", "an island",
    "the festival", "a glacier", "the harbor", "the observatory", "the valley",
];
const VERBS: &[&str] = &[
    "contains", "borders", "produces", "describes", "influences", "preserves",
    "supports", "surrounds", "predates", "resembles", "supplies", "attracts",
];
const OBJECTS: &[&str] = &[
    "many villages", "rare minerals", "old manuscripts", "several lakes",
    "trade routes", "stone bridges", "vast forests", "local legends",
    "migratory birds", "deep canyons", "small farms", "historic walls",
];
const MODIFIERS: &[&str] = &[
    "in the north", "during winter", "for centuries", "near the coast",
    "under the stars", "after the flood", "despite the drought", "by tradition",
];
const PTB_HEADS: &[&str] = &[
    "prices rose", "shares fell", "the index gained", "traders said",
    "the company reported", "analysts expect", "output slipped", "demand grew",
];
const PTB_TAILS: &[&str] = &[
    "amid light trading", "on strong earnings", "despite the forecast",
    "in early trading", "for the third month", "as rates climbed",
];
const C4_BITS: &[&str] = &[
    "click here to learn more", "best tips and tricks", "we love this recipe",
    "sign up for our newsletter", "read the full story", "top ten reasons",
    "you wont believe what happened", "free shipping on all orders",
];

/// The fixed fact bank: the knowledge the models are trained on and the
/// cloze suite quizzes (so quantization-induced forgetting is
/// measurable). (subject, relation, correct, distractors)
pub const FACTS: &[(&str, &str, &str, [&str; 3])] = &[
    ("grass", "color", "green", ["blue", "red", "violet"]),
    ("snow", "color", "white", ["black", "green", "orange"]),
    ("the sun rises in the", "direction", "east", ["west", "north", "south"]),
    ("ice feels", "property", "cold", ["hot", "loud", "soft"]),
    ("fire feels", "property", "hot", ["cold", "quiet", "wet"]),
    ("a week has", "count", "seven days", ["three days", "ten days", "two days"]),
    ("a triangle has", "count", "three sides", ["four sides", "five sides", "six sides"]),
    ("fish live in", "habitat", "water", ["sand", "clouds", "trees"]),
    ("birds can", "ability", "fly", ["swim only", "dig only", "melt"]),
    ("night is", "property", "dark", ["bright", "loud", "dry"]),
];

/// Corpus generator.
pub struct CorpusGen {
    rng: Rng,
}

impl CorpusGen {
    pub fn new(seed: u64) -> CorpusGen {
        CorpusGen { rng: Rng::new(seed) }
    }

    fn wiki_sentence(&mut self) -> String {
        let s = self.rng.choose(SUBJECTS);
        let v = self.rng.choose(VERBS);
        let o = self.rng.choose(OBJECTS);
        if self.rng.chance(0.5) {
            let m = self.rng.choose(MODIFIERS);
            format!("{s} {v} {o} {m}.")
        } else {
            format!("{s} {v} {o}.")
        }
    }

    fn ptb_sentence(&mut self) -> String {
        let h = self.rng.choose(PTB_HEADS);
        let t = self.rng.choose(PTB_TAILS);
        let n = self.rng.range(1, 99);
        if self.rng.chance(0.4) {
            format!("{h} {n} percent {t}.")
        } else {
            format!("{h} {t}.")
        }
    }

    fn c4_sentence(&mut self) -> String {
        let a = self.rng.choose(C4_BITS);
        if self.rng.chance(0.3) {
            let b = self.rng.choose(C4_BITS);
            format!("{a}! {b}...")
        } else if self.rng.chance(0.3) {
            format!("{a} >> page {}", self.rng.range(1, 40))
        } else {
            format!("{a}.")
        }
    }

    /// A fact sentence (training phrasing).
    fn fact_sentence(&mut self) -> String {
        let &(subj, _, correct, _) = self.rng.choose(FACTS);
        format!("{subj} {correct}.")
    }

    /// One arithmetic QA line. The task space is deliberately finite
    /// (single-digit operands, three ops ⇒ ~200 distinct facts) so the
    /// tiny models can *master* it during pretraining — the paper's
    /// math experiment measures quantization-induced *forgetting* of a
    /// learned capability, which requires the FP16 baseline to be
    /// strong in the first place.
    pub fn math_line(&mut self) -> (String, String) {
        let a = self.rng.range(2, 10);
        let b = self.rng.range(2, 10);
        let (expr, ans) = match self.rng.below(3) {
            0 => (format!("{a}+{b}"), (a + b) as i64),
            1 => {
                let (hi, lo) = if a >= b { (a, b) } else { (b, a) };
                (format!("{hi}-{lo}"), (hi - lo) as i64)
            }
            _ => (format!("{a}*{b}"), (a * b) as i64),
        };
        (format!("Q:{expr}=? A:"), format!("{ans}."))
    }

    /// One bracket-completion "code" line: prefix + the closing suffix.
    pub fn code_line(&mut self) -> (String, String) {
        const OPEN: [char; 3] = ['(', '[', '{'];
        const CLOSE: [char; 3] = [')', ']', '}'];
        let depth = self.rng.range(1, 5);
        let mut prefix = String::from("code:");
        let mut stack = Vec::new();
        for _ in 0..depth {
            let k = self.rng.below(3);
            prefix.push(OPEN[k]);
            stack.push(k);
        }
        let mut suffix = String::new();
        while let Some(k) = stack.pop() {
            suffix.push(CLOSE[k]);
        }
        suffix.push('.');
        (prefix, suffix)
    }

    /// Generate `n_sentences` of one perplexity domain.
    pub fn domain_text(&mut self, domain: CorpusDomain, n_sentences: usize) -> String {
        let mut out = String::new();
        for _ in 0..n_sentences {
            let s = match domain {
                CorpusDomain::WikiSyn => self.wiki_sentence(),
                CorpusDomain::PtbSyn => self.ptb_sentence(),
                CorpusDomain::C4Syn => self.c4_sentence(),
            };
            out.push_str(&s);
            out.push('\n');
        }
        out
    }

    /// `n` serving prompts that share one `prefix_words`-word prefix
    /// (a synthetic system prompt) and diverge in a short per-prompt
    /// question — the radix-prefix-cache workload (`gen-corpus
    /// --shared-prefix`, `ptqtp bench --prefix`). Deterministic for a
    /// given generator state.
    pub fn shared_prefix_prompts(&mut self, prefix_words: usize, n: usize) -> Vec<String> {
        let mut prefix = String::from("system:");
        for i in 0..prefix_words {
            if i > 0 {
                prefix.push(' ');
            }
            // reuse the fixed wiki banks so the tokenizer already
            // covers every word
            prefix.push_str(match i % 3 {
                0 => self.rng.choose(SUBJECTS),
                1 => self.rng.choose(VERBS),
                _ => self.rng.choose(OBJECTS),
            });
        }
        (0..n)
            .map(|_| {
                let (q, _) = self.math_line();
                format!("{prefix} {q}")
            })
            .collect()
    }

    /// `n` repetitive serving prompts (`gen-corpus --repetitive`,
    /// `ptqtp bench --speculative`): templated config/code-like lines
    /// where a small per-prompt pool of `set key = value ;` statements
    /// repeats several times, so the text has very high n-gram reuse.
    /// This is the workload where prompt-lookup speculative decoding
    /// shines — a greedy continuation keeps re-entering statement
    /// patterns already present in the context, so the drafter's
    /// suffix match fires on nearly every step. Deterministic for a
    /// given generator state.
    pub fn repetitive_prompts(&mut self, n: usize) -> Vec<String> {
        const KEYS: &[&str] = &["alpha", "beta", "gamma", "delta", "omega", "sigma"];
        (0..n)
            .map(|_| {
                // 2–3 distinct statements, repeated 3–5 times in order
                let n_stmts = self.rng.range(2, 4);
                let stmts: Vec<String> = (0..n_stmts)
                    .map(|_| {
                        let k = self.rng.choose(KEYS);
                        let v = self.rng.range(1, 9);
                        format!("set {k} = {v} ;")
                    })
                    .collect();
                let reps = self.rng.range(3, 6);
                let mut p = String::from("cfg:");
                for _ in 0..reps {
                    for s in &stmts {
                        p.push(' ');
                        p.push_str(s);
                    }
                }
                p
            })
            .collect()
    }

    /// The full training mixture: all three domains + facts + math +
    /// code, interleaved. This is what `python/compile/train.py`
    /// consumes.
    pub fn training_mixture(&mut self, n_lines: usize) -> String {
        let mut out = String::new();
        for _ in 0..n_lines {
            let line = match self.rng.below(10) {
                0 => self.wiki_sentence(),
                1 => self.ptb_sentence(),
                2 => self.c4_sentence(),
                3..=7 => {
                    // math-heavy mixture: the Table 2 retention experiment
                    // needs the FP16 baseline to *master* arithmetic
                    let (q, a) = self.math_line();
                    format!("{q}{a}")
                }
                8 => {
                    let (p, s) = self.code_line();
                    format!("{p}{s}")
                }
                _ => self.fact_sentence(),
            };
            out.push_str(&line);
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = CorpusGen::new(7).training_mixture(50);
        let b = CorpusGen::new(7).training_mixture(50);
        assert_eq!(a, b);
        let c = CorpusGen::new(8).training_mixture(50);
        assert_ne!(a, c);
    }

    #[test]
    fn domains_have_distinct_statistics() {
        let mut g = CorpusGen::new(1);
        let wiki = g.domain_text(CorpusDomain::WikiSyn, 200);
        let ptb = g.domain_text(CorpusDomain::PtbSyn, 200);
        let c4 = g.domain_text(CorpusDomain::C4Syn, 200);
        let avg_line = |s: &str| {
            let lines: Vec<&str> = s.lines().collect();
            lines.iter().map(|l| l.len()).sum::<usize>() as f64 / lines.len() as f64
        };
        // distinct mean lengths (stable under the fixed banks)
        let (w, p, c) = (avg_line(&wiki), avg_line(&ptb), avg_line(&c4));
        assert!((w - p).abs() > 2.0, "wiki {w} vs ptb {p}");
        assert!((w - c).abs() > 2.0 || (p - c).abs() > 2.0);
    }

    #[test]
    fn shared_prefix_prompts_share_exact_prefix() {
        let prompts = CorpusGen::new(5).shared_prefix_prompts(24, 8);
        assert_eq!(prompts.len(), 8);
        let prefix = prompts[0].rsplit_once(" Q:").unwrap().0;
        assert!(prefix.starts_with("system:"));
        for p in &prompts {
            assert!(p.starts_with(prefix), "{p}");
            assert!(p.contains("=? A:"), "divergent question present: {p}");
        }
        // deterministic across generators with the same seed
        assert_eq!(prompts, CorpusGen::new(5).shared_prefix_prompts(24, 8));
        // zero-length prefix degenerates to bare questions
        let bare = CorpusGen::new(5).shared_prefix_prompts(0, 2);
        assert!(bare[0].starts_with("system: Q:"), "{}", bare[0]);
    }

    /// Fraction of word-level trigrams in `s` that already occurred
    /// earlier in `s` — the statistic prompt-lookup drafting feeds on.
    fn trigram_repeat_rate(s: &str) -> f64 {
        let words: Vec<&str> = s.split_whitespace().collect();
        if words.len() < 4 {
            return 0.0;
        }
        let mut seen = std::collections::HashSet::new();
        let (mut repeats, mut total) = (0usize, 0usize);
        for w in words.windows(3) {
            total += 1;
            if !seen.insert(w.to_vec()) {
                repeats += 1;
            }
        }
        repeats as f64 / total as f64
    }

    #[test]
    fn repetitive_prompts_have_high_ngram_reuse() {
        let prompts = CorpusGen::new(6).repetitive_prompts(16);
        assert_eq!(prompts.len(), 16);
        for p in &prompts {
            assert!(p.starts_with("cfg:"), "{p}");
            let rate = trigram_repeat_rate(p);
            // ≥ 3 repetitions of the statement block ⇒ at least 2/3 of
            // trigrams are re-occurrences (minus block-boundary noise)
            assert!(rate > 0.5, "trigram repeat rate {rate} too low for: {p}");
        }
        // contrast: ordinary prose has almost no within-line reuse
        let mut g = CorpusGen::new(6);
        let wiki = g.domain_text(CorpusDomain::WikiSyn, 40);
        let avg: f64 = wiki.lines().map(trigram_repeat_rate).sum::<f64>()
            / wiki.lines().count() as f64;
        assert!(avg < 0.2, "wiki prose repeat rate {avg} unexpectedly high");
        // deterministic across generators with the same seed
        assert_eq!(prompts, CorpusGen::new(6).repetitive_prompts(16));
    }

    #[test]
    fn math_lines_are_correct() {
        let mut g = CorpusGen::new(2);
        for _ in 0..200 {
            let (q, a) = g.math_line();
            let expr = q.strip_prefix("Q:").unwrap().strip_suffix("=? A:").unwrap();
            let ans: i64 = a.strip_suffix('.').unwrap().parse().unwrap();
            let eval = if let Some((x, y)) = expr.split_once('+') {
                x.parse::<i64>().unwrap() + y.parse::<i64>().unwrap()
            } else if let Some((x, y)) = expr.split_once('-') {
                x.parse::<i64>().unwrap() - y.parse::<i64>().unwrap()
            } else {
                let (x, y) = expr.split_once('*').unwrap();
                x.parse::<i64>().unwrap() * y.parse::<i64>().unwrap()
            };
            assert_eq!(eval, ans, "{q}{a}");
        }
    }

    #[test]
    fn code_lines_balanced() {
        let mut g = CorpusGen::new(3);
        for _ in 0..100 {
            let (p, s) = g.code_line();
            let text = format!("{}{}", p.strip_prefix("code:").unwrap(), s.strip_suffix('.').unwrap());
            let mut stack = Vec::new();
            for ch in text.chars() {
                match ch {
                    '(' | '[' | '{' => stack.push(ch),
                    ')' => assert_eq!(stack.pop(), Some('(')),
                    ']' => assert_eq!(stack.pop(), Some('[')),
                    '}' => assert_eq!(stack.pop(), Some('{')),
                    _ => panic!("unexpected char {ch}"),
                }
            }
            assert!(stack.is_empty());
        }
    }

    #[test]
    fn mixture_contains_all_kinds() {
        let text = CorpusGen::new(4).training_mixture(400);
        assert!(text.contains("Q:"), "math lines present");
        assert!(text.contains("code:"), "code lines present");
        assert!(text.contains('.'), "sentences present");
        // at least one fact phrasing
        assert!(FACTS.iter().any(|(s, _, c, _)| text.contains(&format!("{s} {c}"))));
    }

    #[test]
    fn domain_names_roundtrip() {
        for d in CorpusDomain::all() {
            assert_eq!(CorpusDomain::from_name(d.name()).unwrap(), d);
        }
        assert!(CorpusDomain::from_name("nope").is_err());
    }
}

//! Synthetic data substrate (see DESIGN.md §2 substitution table).
//!
//! The paper evaluates on WikiText2/PTB/C4 perplexity plus reasoning,
//! math, and code suites. None of those are reachable offline, so this
//! module generates deterministic synthetic equivalents that exercise
//! the same evaluation code paths:
//!
//! * [`corpus`] — three text domains with distinct statistics
//!   (`wiki-syn`, `ptb-syn`, `c4-syn`) from a seeded grammar+Markov
//!   generator, plus the arithmetic-QA corpus the models are trained on
//!   so the math-retention experiment (Table 2) is meaningful.
//! * [`tokenizer`] — character-level tokenizer with persisted vocab,
//!   shared byte-for-byte with the Python training path.
//! * [`tasks`] — evaluation task generators: math QA (exact match),
//!   cloze multiple choice (logprob ranking), bracket-completion "code"
//!   tasks (Table 12 analogue).

pub mod corpus;
pub mod tasks;
pub mod tokenizer;

pub use corpus::{CorpusDomain, CorpusGen};
pub use tasks::{ChoiceTask, MathTask, TaskSuite};
pub use tokenizer::Tokenizer;

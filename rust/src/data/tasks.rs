//! Evaluation task generators — the downstream suites of Tables 2/3/11/12.
//!
//! * [`MathTask`] — arithmetic QA scored by exact match on greedy
//!   decode (GSM8K / Math-500 stand-in; the paper's headline retention
//!   experiment).
//! * [`ChoiceTask`] — cloze multiple choice scored by per-token
//!   logprob ranking (ARC / BoolQ / HellaSwag / MMLU stand-in).
//! * code tasks — bracket completion, exact match (HumanEval / MBPP
//!   stand-in, Table 12).

use super::corpus::{CorpusGen, FACTS};
use crate::rng::Rng;

/// Exact-match generation task.
#[derive(Clone, Debug)]
pub struct MathTask {
    pub prompt: String,
    pub answer: String,
}

/// Multiple-choice ranking task.
#[derive(Clone, Debug)]
pub struct ChoiceTask {
    pub prompt: String,
    pub choices: Vec<String>,
    pub correct: usize,
}

/// A bundle of evaluation tasks (one per paper benchmark family).
#[derive(Clone, Debug, Default)]
pub struct TaskSuite {
    pub math: Vec<MathTask>,
    pub cloze: Vec<ChoiceTask>,
    pub code: Vec<MathTask>,
}

impl TaskSuite {
    /// Build the standard evaluation suite. `seed` controls the held-out
    /// sampling; use a seed disjoint from training generation.
    pub fn standard(seed: u64, n_math: usize, n_cloze: usize, n_code: usize) -> TaskSuite {
        let mut gen = CorpusGen::new(seed ^ EVAL_SEED);
        let math = (0..n_math)
            .map(|_| {
                let (prompt, answer) = gen.math_line();
                MathTask { prompt, answer }
            })
            .collect();
        let code = (0..n_code)
            .map(|_| {
                let (prompt, answer) = gen.code_line();
                MathTask { prompt, answer }
            })
            .collect();
        let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1);
        let cloze = (0..n_cloze)
            .map(|_| {
                let &(subj, _rel, correct, distractors) = rng.choose(FACTS);
                // shuffle answer positions deterministically
                let mut options: Vec<String> = vec![
                    correct.to_string(),
                    distractors[0].to_string(),
                    distractors[1].to_string(),
                    distractors[2].to_string(),
                ];
                let mut order: Vec<usize> = (0..4).collect();
                rng.shuffle(&mut order);
                let correct_pos = order.iter().position(|&i| i == 0).unwrap();
                options = order.iter().map(|&i| options[i].clone()).collect();
                ChoiceTask {
                    prompt: format!("{subj} "),
                    choices: options,
                    correct: correct_pos,
                }
            })
            .collect();
        TaskSuite { math, cloze, code }
    }
}

/// XOR'd into the user seed so evaluation sampling is disjoint from the
/// training-corpus stream even when both use the same base seed.
const EVAL_SEED: u64 = 0x0E7A_15EE_D000_0001;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_sizes() {
        let s = TaskSuite::standard(1, 20, 30, 10);
        assert_eq!(s.math.len(), 20);
        assert_eq!(s.cloze.len(), 30);
        assert_eq!(s.code.len(), 10);
    }

    #[test]
    fn deterministic() {
        let a = TaskSuite::standard(5, 5, 5, 5);
        let b = TaskSuite::standard(5, 5, 5, 5);
        assert_eq!(a.math[0].prompt, b.math[0].prompt);
        assert_eq!(a.cloze[3].correct, b.cloze[3].correct);
    }

    #[test]
    fn cloze_correct_is_valid_index() {
        let s = TaskSuite::standard(2, 0, 50, 0);
        for t in &s.cloze {
            assert!(t.correct < t.choices.len());
            // the correct choice must be one of the fact bank's truths
            let c = &t.choices[t.correct];
            assert!(
                FACTS.iter().any(|(_, _, truth, _)| truth == c),
                "choice '{c}' not a known truth"
            );
        }
    }

    #[test]
    fn cloze_positions_vary() {
        let s = TaskSuite::standard(3, 0, 60, 0);
        let mut seen = [false; 4];
        for t in &s.cloze {
            seen[t.correct] = true;
        }
        assert!(seen.iter().filter(|&&x| x).count() >= 3, "positions {seen:?}");
    }

    #[test]
    fn math_prompts_well_formed() {
        let s = TaskSuite::standard(4, 30, 0, 0);
        for t in &s.math {
            assert!(t.prompt.starts_with("Q:"));
            assert!(t.prompt.ends_with("A:"));
            assert!(t.answer.ends_with('.'));
        }
    }
}

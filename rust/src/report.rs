//! Table rendering for paper-style benchmark output.
//!
//! Every bench binary prints its rows through [`Table`], so all paper
//! exhibits share one look and can be diffed run-to-run; tables also
//! serialize to TSV and JSON for EXPERIMENTS.md tooling.

use crate::serialize::Json;

/// Cell formatting for floats: mimic the paper's mixed notation —
/// plain decimals for small values, scientific (`1.2E5`) for blown-up
/// perplexities.
pub fn fmt_metric(x: f64) -> String {
    if !x.is_finite() {
        return "NAN".into();
    }
    if x == 0.0 {
        return "0.00".into();
    }
    let a = x.abs();
    if a >= 1e4 {
        format!("{:.2E}", x)
    } else if a >= 100.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.2}")
    }
}

/// A simple column-aligned table.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
        self
    }

    /// Convenience: leading label + metric-formatted numbers.
    pub fn metric_row(&mut self, label: &str, values: &[f64]) -> &mut Self {
        let mut cells = vec![label.to_string()];
        cells.extend(values.iter().map(|&v| fmt_metric(v)));
        self.row(cells)
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    s.push_str("  ");
                }
                // left-align first col, right-align the rest
                if i == 0 {
                    s.push_str(&format!("{:<w$}", c, w = widths[i]));
                } else {
                    s.push_str(&format!("{:>w$}", c, w = widths[i]));
                }
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.headers, &widths));
        out.push_str(&format!("{}\n", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1))));
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }

    /// Tab-separated dump (machine-readable).
    pub fn to_tsv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join("\t"));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join("\t"));
            out.push('\n');
        }
        out
    }

    /// JSON dump: {title, headers, rows}.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("title", self.title.as_str())
            .set(
                "headers",
                Json::Arr(self.headers.iter().map(|h| Json::Str(h.clone())).collect()),
            )
            .set(
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                        .collect(),
                ),
            )
    }

    /// Markdown rendering (for EXPERIMENTS.md embedding).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("**{}**\n\n", self.title));
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// ASCII line plot for figure reproductions (Fig 3/4 series).
pub fn ascii_plot(title: &str, xs: &[f64], series: &[(&str, Vec<f64>)], height: usize) -> String {
    let mut out = format!("-- {title} --\n");
    let all: Vec<f64> = series
        .iter()
        .flat_map(|(_, ys)| ys.iter().copied())
        .filter(|y| y.is_finite())
        .collect();
    if all.is_empty() || xs.is_empty() {
        return out + "(no data)\n";
    }
    let (ymin, ymax) = all
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &y| {
            (lo.min(y), hi.max(y))
        });
    let span = (ymax - ymin).max(1e-12);
    let width = xs.len();
    let marks = ['*', '+', 'o', 'x', '#'];
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        for (xi, &y) in ys.iter().enumerate().take(width) {
            if !y.is_finite() {
                continue;
            }
            let level = ((y - ymin) / span * (height - 1) as f64).round() as usize;
            let row = height - 1 - level.min(height - 1);
            grid[row][xi] = marks[si % marks.len()];
        }
    }
    for (i, row) in grid.iter().enumerate() {
        let yval = ymax - span * i as f64 / (height - 1) as f64;
        out.push_str(&format!("{:>10.3} |{}\n", yval, row.iter().collect::<String>()));
    }
    out.push_str(&format!(
        "{:>10} +{}\n", "", "-".repeat(width)
    ));
    out.push_str(&format!(
        "{:>12}x: {:.3} .. {:.3}   ", "", xs[0], xs[xs.len() - 1]
    ));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("[{}]={} ", marks[si % marks.len()], name));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_metric_matches_paper_style() {
        assert_eq!(fmt_metric(9.75), "9.75");
        assert_eq!(fmt_metric(164.3), "164.3");
        assert_eq!(fmt_metric(164000.0), "1.64E5");
        assert_eq!(fmt_metric(f64::NAN), "NAN");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["Method", "PPL"]);
        t.metric_row("PTQTP", &[17.15]);
        t.metric_row("AWQ-2bit", &[164000.0]);
        let s = t.render();
        assert!(s.contains("PTQTP"));
        assert!(s.contains("1.64E5"));
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines.len() >= 4);
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn tsv_and_markdown() {
        let mut t = Table::new("T", &["m", "v"]);
        t.row(vec!["a".into(), "1".into()]);
        assert_eq!(t.to_tsv(), "m\tv\na\t1\n");
        assert!(t.to_markdown().contains("| a | 1 |"));
    }

    #[test]
    fn json_roundtrip() {
        let mut t = Table::new("T", &["m"]);
        t.row(vec!["a".into()]);
        let j = t.to_json();
        assert_eq!(j.get("title").unwrap().as_str(), Some("T"));
    }

    #[test]
    fn plot_handles_series() {
        let xs = vec![1.0, 2.0, 3.0, 4.0];
        let p = ascii_plot(
            "ppl vs iters",
            &xs,
            &[("ptqtp", vec![100.0, 20.0, 10.0, 9.0])],
            8,
        );
        assert!(p.contains("ppl vs iters"));
        assert!(p.contains("[*]=ptqtp"));
    }

    #[test]
    fn plot_empty_safe() {
        let p = ascii_plot("empty", &[], &[], 5);
        assert!(p.contains("(no data)"));
    }
}

//! `ptqtp` — the PTQTP system CLI (leader entrypoint).
//!
//! Subcommands:
//!   gen-corpus   write the synthetic corpora + tokenizer (build path)
//!   gen-ckpt     write a random FP32 checkpoint (CI / dev, no JAX)
//!   quantize     quantize a checkpoint with any method, save + report
//!   eval         perplexity + task suites for a (quantized) checkpoint
//!   serve        run the batching server on a workload and report
//!   bench        regenerate a paper table/figure (--table N | --fig N)
//!   runtime      smoke-run the AOT artifacts through PJRT
//!
//! Deployment workflow is **quantize once, serve many**: `quantize
//! --out Q.ptw` persists the packed trit-planes (PTW2) + a manifest,
//! and every later `serve`/`eval` of `Q.ptw` cold-starts from the
//! packed artifact without re-running the quantization pass.

use ptqtp::bench;
use ptqtp::cli::{usage, Args, OptSpec};
use ptqtp::coordinator::kv_pool::DEFAULT_PAGE_SIZE;
use ptqtp::coordinator::{
    serve_metrics_json, FaultPlan, PagedKvOpts, RetryPolicy, SamplingParams, ServerBuilder,
    ServerEvent, SpecDecodeOpts, SubmitOutcome,
};
use ptqtp::data::{CorpusDomain, CorpusGen, TaskSuite, Tokenizer};
use ptqtp::eval;
use ptqtp::model::{ModelConfig, Transformer};
use ptqtp::quant::{self, QuantCtx};
use ptqtp::runtime::{ArtifactManifest, PjrtEngine};
use ptqtp::serialize::{CheckpointManifest, Json};

const SUBCOMMANDS: &[&str] = &[
    "gen-corpus",
    "gen-ckpt",
    "quantize",
    "eval",
    "serve",
    "bench",
    "runtime",
];

fn main() {
    let args = Args::from_env(SUBCOMMANDS);
    // Pin the SIMD kernel-tier mode before any packed layer is built:
    // --simd > PTQTP_SIMD > auto. `off` is the exact scalar escape
    // hatch (output is bit-identical either way).
    match args.tri_state_opt("simd", true) {
        Ok(Some(v)) => ptqtp::ternary::simd::set_mode(
            ptqtp::ternary::simd::SimdMode::parse(v.as_str()).expect("tri-state spellings parse"),
        ),
        Ok(None) => {}
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    }
    // Pin the int8-activation tier mode the same way: --act-quant >
    // PTQTP_ACT_QUANT > auto. Unlike --simd this tier is
    // value-changing, so auto resolves *off*; `on` is an explicit
    // accuracy/speed trade (DESIGN.md §Integer-Kernels).
    match args.tri_state_opt("act-quant", true) {
        Ok(Some(v)) => ptqtp::ternary::int_act::set_mode(
            ptqtp::ternary::int_act::ActQuantMode::parse(v.as_str())
                .expect("tri-state spellings parse"),
        ),
        Ok(None) => {}
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    }
    let result = match args.subcommand.as_deref() {
        Some("gen-corpus") => cmd_gen_corpus(&args),
        Some("gen-ckpt") => cmd_gen_ckpt(&args),
        Some("quantize") => cmd_quantize(&args),
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some("bench") => cmd_bench(&args),
        Some("runtime") => cmd_runtime(&args),
        _ => {
            print!("{}", help());
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn help() -> String {
    usage(
        "ptqtp",
        "Post-Training Quantization to Trit-Planes — full-system reproduction",
        &[
            ("gen-corpus", "generate synthetic corpora + tokenizer into --out [--shared-prefix W: also write prompts_shared.txt] [--repetitive: also write prompts_repetitive.txt]"),
            ("gen-ckpt", "gen-ckpt --out X.ptw [--family tiny] [--data DIR|--vocab N]  (random FP32 checkpoint)"),
            ("quantize", "quantize --model X.ptw --method ptqtp --out Q.ptw  (Q.ptw = packed PTW2 artifact + manifest)"),
            ("eval", "eval --model X.ptw [--method ptqtp] [--data DIR]  (packed checkpoints skip quantization)"),
            ("serve", "serve --model X.ptw [--method ptqtp] --requests N [--replicas R]  (packed checkpoints skip quantization)"),
            ("bench", "bench --table N | --fig N | --batched | --kernels | --attention | --prefix | --speculative  (paper exhibits + perf benches)"),
            ("runtime", "runtime --artifacts DIR  (PJRT smoke test)"),
        ],
        &[
            OptSpec { name: "out", help: "output path/dir", default: None },
            OptSpec { name: "seed", help: "RNG seed", default: Some("0") },
            OptSpec { name: "group-size", help: "quantization group size G", default: Some("128") },
            OptSpec { name: "method", help: "fp16|rtn*|gptq*|awq*|pbllm|billm|arb|absmean|ptqtp", default: Some("ptqtp") },
            OptSpec { name: "threads", help: "worker lanes for row-parallel kernels/quantization (1 = exact sequential path; env PTQTP_THREADS)", default: Some("cores") },
            OptSpec { name: "simd", help: "SIMD kernel tier: auto|on|off (off = exact scalar path; env PTQTP_SIMD); bit-identical output either way", default: Some("auto") },
            OptSpec { name: "act-quant", help: "int8-activation kernel tier: auto|on|off (auto resolves off — value-changing; env PTQTP_ACT_QUANT)", default: Some("auto") },
            OptSpec { name: "n", help: "serve: parallel samples per request (prompt prefilled once, KV forked copy-on-write)", default: Some("1") },
            OptSpec { name: "replicas", help: "serve: engine replicas, each with its own pool", default: Some("1") },
            OptSpec { name: "page-size", help: "serve: KV positions per page, ≥ 8 (0 = one max_seq page, i.e. contiguous; env PTQTP_PAGE_SIZE)", default: Some("64") },
            OptSpec { name: "prefix-cache", help: "serve: radix prefix cache on|off (off = exact legacy layout: contiguous, nothing shared)", default: Some("on") },
            OptSpec { name: "kv-pages", help: "serve: per-replica KV page budget; exhaustion preempts + recomputes", default: Some("capacity×⌈max_seq/page⌉") },
            OptSpec { name: "spec-decode", help: "serve: prompt-lookup speculative decoding on|off (output token-for-token identical; env PTQTP_SPEC_DECODE)", default: Some("off") },
            OptSpec { name: "spec-k", help: "serve: max speculative draft tokens per step (≥ 1; needs --spec-decode on)", default: Some("4") },
            OptSpec { name: "print-tokens", help: "serve: print each response's token ids (sorted by request id) for cross-config parity diffs", default: None },
            OptSpec { name: "prompts", help: "serve: prompt file (one per line, cycled to --requests; e.g. prompts_shared.txt)", default: None },
            OptSpec { name: "intake-limit", help: "serve: max accepted-but-unfinished requests per replica; beyond it submit rejects (QueueFull)", default: Some("1024") },
            OptSpec { name: "deadline-ms", help: "serve: per-request deadline in ms; queued or running requests past it finish DeadlineExceeded", default: None },
            OptSpec { name: "metrics-json", help: "serve: write the serve-metrics artifact (admission counters + per-replica metrics + latency histograms) to PATH", default: Some("serve-metrics.json when bare") },
            OptSpec { name: "fault-plan", help: "serve: JSON fault-injection schedule (ptqtp-fault-plan/1: panics, page exhaustion, ckpt I/O errors, slow steps); overrides PTQTP_FAULT_SEED", default: None },
            OptSpec { name: "retry-max", help: "serve: replays allowed per request orphaned by a replica death before it fails ReplicaLost", default: Some("4") },
            OptSpec { name: "retry-base-ms", help: "serve: first retry backoff in ms (doubles each attempt, deterministic jitter < base)", default: Some("10") },
            OptSpec { name: "retry-cap-ms", help: "serve: ceiling on the exponential retry backoff in ms", default: Some("500") },
        ],
    )
}

/// `gen-corpus --out data/ [--train-lines N] [--eval-sentences N]
/// [--shared-prefix W [--prefix-prompts N]]`
fn cmd_gen_corpus(args: &Args) -> anyhow::Result<()> {
    let out = args.str_or("out", "data");
    let seed = args.u64_or("seed", 0);
    let train_lines = args.usize_or("train-lines", 20_000);
    let eval_sentences = args.usize_or("eval-sentences", 400);
    let shared_prefix = args.usize_opt("shared-prefix")?;
    std::fs::create_dir_all(out)?;

    let mut gen = CorpusGen::new(seed);
    let train = gen.training_mixture(train_lines);
    std::fs::write(format!("{out}/corpus_train.txt"), &train)?;

    // held-out eval texts per domain (disjoint RNG stream)
    let mut eval_gen = CorpusGen::new(seed ^ 0xE7A1);
    let mut all_text = train;
    for domain in CorpusDomain::all() {
        let text = eval_gen.domain_text(domain, eval_sentences);
        std::fs::write(format!("{out}/eval_{}.txt", domain.name()), &text)?;
        all_text.push_str(&text);
    }
    // shared-prefix serving prompts (the prefix-cache workload) are
    // generated *before* the tokenizer is built so their vocabulary is
    // covered
    if let Some(prefix_words) = shared_prefix {
        let n = args.usize_or("prefix-prompts", 16);
        let mut prompt_gen = CorpusGen::new(seed ^ 0x5A3D);
        let prompts = prompt_gen.shared_prefix_prompts(prefix_words, n);
        let joined = prompts.join("\n");
        std::fs::write(format!("{out}/prompts_shared.txt"), &joined)?;
        all_text.push_str(&joined);
        println!("wrote {n} shared-prefix prompts ({prefix_words} prefix words) to {out}/prompts_shared.txt");
    }
    // repetitive prompts (the speculative-decoding workload: templated
    // code-like lines with heavy n-gram reuse, so prompt-lookup
    // drafting fires) — also pre-tokenizer so their vocabulary is
    // covered
    if args.flag("repetitive") {
        let n = args.usize_or("repetitive-prompts", 16);
        let mut rep_gen = CorpusGen::new(seed ^ 0x7EC1);
        let prompts = rep_gen.repetitive_prompts(n);
        let joined = prompts.join("\n");
        std::fs::write(format!("{out}/prompts_repetitive.txt"), &joined)?;
        all_text.push_str(&joined);
        println!("wrote {n} repetitive prompts to {out}/prompts_repetitive.txt");
    }
    let tok = Tokenizer::from_text(&all_text);
    tok.save(format!("{out}/tokenizer.json"))?;
    println!(
        "corpus written to {out}/ (train {} bytes, vocab {})",
        std::fs::metadata(format!("{out}/corpus_train.txt"))?.len(),
        tok.vocab_size()
    );
    Ok(())
}

/// A model ready to serve/eval, plus where its quantization came from.
struct LoadedModel {
    model: Transformer,
    /// Method that produced the weights (from `--method` or, for a
    /// packed checkpoint, its manifest).
    method: String,
    /// Quantizer hyper-parameters for the manifest (when a pass ran).
    quant_opts: Option<Json>,
    /// Wall-clock seconds of the quantization pass (0 when skipped).
    quantize_secs: f64,
    /// True when the checkpoint already carried packed trit-planes and
    /// the quantization pass was skipped.
    from_packed: bool,
}

/// Shared: load model, optionally quantize with --method. Quantization
/// runs matrix-parallel on `--threads` lanes (bit-identical to
/// sequential; see DESIGN.md §Threading).
///
/// A checkpoint that already holds packed trit-planes (PTW2) **skips
/// the quantization pass entirely** — that's the quantize-once /
/// serve-many contract: replicas cold-start from the immutable packed
/// artifact instead of re-running progressive approximation per
/// process.
fn load_and_quantize(args: &Args) -> anyhow::Result<LoadedModel> {
    let model_path = args.require("model")?;
    let mut model = Transformer::load(model_path)?;
    // the resolved int8-activation mode rides on the model: every
    // scratch and engine built from it inherits the knob
    model.set_act_quant(ptqtp::ternary::int_act::enabled());
    let threads = args.threads_or_default();
    let requested = args.str_or("method", "fp16").to_string();
    let group = args.usize_or("group-size", 128);

    let n_packed = model.ternary_layers();
    if n_packed > 0 {
        // carry provenance forward from the artifact's own manifest so
        // a re-save doesn't lose how the weights were produced
        let (method, quant_opts) = match CheckpointManifest::load_for(model_path)? {
            Some(m) => (m.method, m.quant_opts),
            None => ("packed".to_string(), None),
        };
        // any explicitly passed quantization knob is a no-op on a
        // packed artifact — say so instead of silently ignoring it
        if (args.get("method").is_some() && requested != method)
            || args.get("group-size").is_some()
        {
            eprintln!(
                "note: quantization options (--method/--group-size) ignored — checkpoint is \
                 already quantized with {method}; re-quantize from the FP32 checkpoint to \
                 change them"
            );
        }
        eprintln!(
            "loaded packed trit-plane checkpoint ({n_packed} ternary layers, method {method}) — skipping quantization pass"
        );
        return Ok(LoadedModel {
            model,
            method,
            quant_opts,
            quantize_secs: 0.0,
            from_packed: true,
        });
    }

    let mut quant_opts = None;
    let mut quantize_secs = 0.0;
    if requested != "fp16" && requested != "fp" {
        let q = quant::by_name(&requested, group)?;
        let t0 = std::time::Instant::now();
        model.quantize_with(q.as_ref(), &QuantCtx::with_threads(threads));
        quantize_secs = t0.elapsed().as_secs_f64();
        eprintln!(
            "quantized with {} in {:.2?} ({threads} threads)",
            q.name(),
            t0.elapsed()
        );
        quant_opts = Some(q.meta_json());
    }
    Ok(LoadedModel {
        model,
        method: requested,
        quant_opts,
        quantize_secs,
        from_packed: false,
    })
}

/// `quantize --model in.ptw --method ptqtp --out out.ptw`
///
/// The output is the deployable artifact: packed trit-planes for
/// ternary methods (PTW2, ≤ 1/8 of the FP32 serialization per ternary
/// layer) plus a `out.manifest.json` sidecar recording method, options,
/// a quantization report, and the payload checksum.
fn cmd_quantize(args: &Args) -> anyhow::Result<()> {
    let lm = load_and_quantize(args)?;
    let out = args.require("out")?;
    let report = lm
        .model
        .quant_summary()
        .set("quantize_secs", lm.quantize_secs)
        .set("threads", args.threads_or_default());
    lm.model
        .save_with_manifest(out, &lm.method, lm.quant_opts.clone(), Some(report))?;
    let disk = std::fs::metadata(out)?.len();
    println!(
        "saved {}-quantized model to {out} ({}, {} resident bytes, {disk} bytes on disk)",
        lm.method,
        lm.model.checkpoint_format(),
        lm.model.resident_bytes()
    );
    Ok(())
}

/// `gen-ckpt --out fp.ptw [--family tiny] [--data DIR | --vocab N]
/// [--max-seq N] [--seed S]` — write a random FP32 checkpoint so the
/// quantize→serve pipeline (and CI) can run without the JAX build path.
/// Vocab resolution: `--vocab`, else the tokenizer at `--data`, else 64.
fn cmd_gen_ckpt(args: &Args) -> anyhow::Result<()> {
    let out = args.require("out")?;
    let family = args.str_or("family", "tiny");
    let mut cfg = ModelConfig::family(family)?;
    cfg.vocab_size = match args.get("vocab") {
        Some(v) => v.parse().map_err(|_| anyhow::anyhow!("bad --vocab '{v}'"))?,
        None => match args.get("data") {
            Some(dir) => Tokenizer::load(format!("{dir}/tokenizer.json"))?.vocab_size(),
            None => 64,
        },
    };
    cfg.max_seq = args.usize_or("max-seq", 128);
    cfg.validate()?;
    let mut rng = ptqtp::rng::Rng::new(args.u64_or("seed", 0));
    let model = Transformer::random(cfg, &mut rng);
    model.save(out)?;
    println!(
        "wrote random {family} FP32 checkpoint to {out} (vocab {}, {} params)",
        model.config.vocab_size,
        model.config.param_count()
    );
    Ok(())
}

/// `eval --model X.ptw [--method M] [--data data/] [--threads T]`
fn cmd_eval(args: &Args) -> anyhow::Result<()> {
    let lm = load_and_quantize(args)?;
    let (mut model, method) = (lm.model, lm.method);
    // eval's forward passes use the model's self-managed scratch, so
    // bind --threads here (serve binds pools per engine instead)
    model.set_threads(args.threads_or_default());
    let data_dir = args.str_or("data", "data");
    let tok = Tokenizer::load(format!("{data_dir}/tokenizer.json"))?;
    println!("model: {} ({} params)", model.config.name, model.config.param_count());
    println!("method: {method}");
    for domain in CorpusDomain::all() {
        let text = std::fs::read_to_string(format!("{data_dir}/eval_{}.txt", domain.name()))?;
        let ppl = eval::perplexity(&model, &tok, &text);
        println!("  ppl[{}] = {:.3}", domain.name(), ppl);
    }
    let suite = TaskSuite::standard(args.u64_or("seed", 1), 50, 60, 30);
    let scores = eval::eval_suite(&model, &tok, &suite);
    println!(
        "  math = {:.1}%  cloze = {:.1}%  code = {:.1}%",
        scores.math_acc * 100.0,
        scores.cloze_acc * 100.0,
        scores.code_acc * 100.0
    );
    Ok(())
}

/// Resolve the paged-KV serving knobs.
///
/// Page size: `--page-size N` > `PTQTP_PAGE_SIZE` env > default. The
/// default is [`DEFAULT_PAGE_SIZE`] (64), except that `--prefix-cache
/// off` with no explicit size picks one `max_seq` page per sequence —
/// the exact legacy contiguous layout, byte-for-byte. `0` also means
/// "one max_seq page". Explicit sizes must be ≥ 8 so the widest SIMD
/// attention lane block never straddles a page boundary.
fn resolve_kv_opts(args: &Args, max_seq: usize) -> anyhow::Result<PagedKvOpts> {
    let prefix_cache = match args.tri_state_opt("prefix-cache", false)? {
        Some(v) => v == ptqtp::cli::TriState::On,
        None => true,
    };
    let cli = args.usize_opt("page-size")?;
    let env = match std::env::var("PTQTP_PAGE_SIZE") {
        Ok(v) => Some(v.parse::<usize>().map_err(|_| {
            anyhow::anyhow!("invalid PTQTP_PAGE_SIZE '{v}' (expected an unsigned integer)")
        })?),
        Err(_) => None,
    };
    let page_size = match cli.or(env) {
        Some(0) => max_seq, // contiguous: one page spans the whole context
        Some(n) if n < 8 => {
            anyhow::bail!(
                "--page-size {n} too small: pages must hold ≥ 8 positions so SIMD \
                 attention lane blocks never straddle a page (use 0 for one \
                 max_seq-sized page)"
            )
        }
        Some(n) => n,
        None if !prefix_cache => max_seq, // legacy escape hatch
        None => DEFAULT_PAGE_SIZE,
    };
    let page_budget = args.usize_opt("kv-pages")?;
    if page_budget == Some(0) {
        anyhow::bail!("--kv-pages must be ≥ 1");
    }
    Ok(PagedKvOpts {
        page_size,
        prefix_cache,
        page_budget,
    })
}

/// Resolve the speculative-decoding knobs: `--spec-decode on|off` >
/// `PTQTP_SPEC_DECODE` env > default off. `--spec-k N` sets the max
/// draft length (default 4, must be ≥ 1 — `k = 0` is just `off`
/// spelled confusingly, so it's rejected). Speculation is a pure
/// scheduling optimization: output is token-for-token identical to
/// plain decode (see `coordinator::speculator`).
fn resolve_spec_opts(args: &Args) -> anyhow::Result<Option<SpecDecodeOpts>> {
    let on = args.on_off_env("spec-decode", "PTQTP_SPEC_DECODE")?.unwrap_or(false);
    let k = args.usize_opt("spec-k")?;
    if !on {
        return Ok(None);
    }
    match k {
        Some(0) => anyhow::bail!("--spec-k must be ≥ 1 (use --spec-decode off to disable)"),
        Some(k) => Ok(Some(SpecDecodeOpts::default().with_k(k))),
        None => Ok(Some(SpecDecodeOpts::default())),
    }
}

/// Resolve the deterministic fault-injection schedule: `--fault-plan
/// FILE` (a `ptqtp-fault-plan/1` JSON document) > `PTQTP_FAULT_SEED`
/// env (a seed-derived schedule, see `FaultPlan::from_seed`) > none.
/// The layer is always compiled in; without a plan it is inert.
fn resolve_fault_plan(args: &Args, replicas: usize) -> anyhow::Result<Option<FaultPlan>> {
    if let Some(path) = args.get("fault-plan") {
        return Ok(Some(FaultPlan::load(path)?));
    }
    if let Ok(seed) = std::env::var("PTQTP_FAULT_SEED") {
        let seed: u64 = seed
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("PTQTP_FAULT_SEED must be an integer, got {seed:?}"))?;
        return Ok(Some(FaultPlan::from_seed(seed, replicas)));
    }
    Ok(None)
}

/// `serve --model X.ptw [--method M] [--requests N] [--data data/]
/// [--threads T] [--replicas R] [--page-size N] [--prefix-cache on|off]
/// [--kv-pages N] [--spec-decode on|off] [--spec-k N] [--prompts FILE]
/// [--intake-limit N] [--deadline-ms MS] [--metrics-json [PATH]]
/// [--print-tokens] [--fault-plan FILE] [--retry-max N]
/// [--retry-base-ms MS] [--retry-cap-ms MS]`
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let lm = load_and_quantize(args)?;
    let (model, method) = (lm.model, lm.method);
    if lm.from_packed {
        eprintln!("serving from packed planes (no quantization pass; replicas clone the one loaded model)");
    }
    let n_requests = args.usize_or("requests", 32);
    let data_dir = args.str_or("data", "data");
    let threads = args.threads_or_default();
    let replicas = args.usize_or("replicas", 1).max(1);
    // tier label + how many layers actually carry an interleaved
    // layout (0 on ragged/dense models ⇒ the pass ran scalar even
    // when the tier says e.g. "avx2")
    let simd_desc = format!(
        "{} ({} layers interleaved)",
        ptqtp::ternary::simd::label(),
        model.simd_layers()
    );
    // active activation-quant tier + how many layers it can actually
    // serve (ragged/short layers stay f32 even when the tier is on)
    eprintln!(
        "act-quant: {} ({} layers int8-eligible)",
        ptqtp::ternary::int_act::label(),
        model.act_quant_layers()
    );
    let tok = Tokenizer::load(format!("{data_dir}/tokenizer.json"))?;
    let kv = resolve_kv_opts(args, model.config.max_seq)?;
    eprintln!(
        "paged-kv: page size {} ({}), prefix cache {}, page budget {}",
        kv.page_size,
        if kv.page_size >= model.config.max_seq { "contiguous" } else { "paged" },
        if kv.prefix_cache { "on" } else { "off" },
        match kv.page_budget {
            Some(b) => b.to_string(),
            None => "default".to_string(),
        }
    );
    let spec = resolve_spec_opts(args)?;
    match spec {
        Some(s) => eprintln!(
            "spec-decode: on (prompt-lookup, k={}, min-match {})",
            s.k, s.min_match
        ),
        None => eprintln!("spec-decode: off"),
    }

    // workload: prompts from --prompts FILE (cycled to --requests, the
    // shared-prefix serving path) or generated math tasks (realistic
    // mixed lengths)
    let prompts: Vec<String> = match args.get("prompts") {
        Some(path) => {
            let lines: Vec<String> = std::fs::read_to_string(path)?
                .lines()
                .filter(|l| !l.trim().is_empty())
                .map(str::to_string)
                .collect();
            anyhow::ensure!(!lines.is_empty(), "no prompts in {path}");
            (0..n_requests)
                .map(|i| lines[i % lines.len()].clone())
                .collect()
        }
        None => {
            let suite = TaskSuite::standard(args.u64_or("seed", 2), n_requests, 0, 0);
            suite.math.iter().map(|t| t.prompt.clone()).collect()
        }
    };
    let n_samples = args.usize_or("n", 1).max(1);
    let params = SamplingParams::greedy(8).with_n(n_samples);
    let deadline = args.duration_ms_opt("deadline-ms")?;
    let intake_limit = args.usize_opt("intake-limit")?;
    // `--metrics-json PATH` writes the artifact there; the bare flag
    // uses the default path; absent writes nothing
    let metrics_path: Option<String> = args
        .get("metrics-json")
        .map(str::to_string)
        .or_else(|| args.flag("metrics-json").then(|| "serve-metrics.json".to_string()));

    // event-driven front-end: one worker thread per replica, bounded
    // intake, per-request deadlines — the single-replica path goes
    // through the same server so admission metrics always exist
    let mut builder = ServerBuilder::new()
        .replicas(replicas)
        .route(ptqtp::coordinator::router::RoutePolicy::LeastLoaded)
        .threads(threads)
        .paged_kv(kv)
        .spec_decode(spec)
        .retry(RetryPolicy {
            max_attempts: args.usize_or("retry-max", 4) as u32,
            base: std::time::Duration::from_millis(args.u64_or("retry-base-ms", 10)),
            cap: std::time::Duration::from_millis(args.u64_or("retry-cap-ms", 500)),
        });
    if let Some(limit) = intake_limit {
        builder = builder.intake_limit(limit);
    }
    if let Some(d) = deadline {
        builder = builder.default_deadline(d);
    }
    if let Some(plan) = resolve_fault_plan(args, replicas)? {
        eprintln!("fault-plan: {} deterministic fault(s) armed", plan.len());
        builder = builder.fault_plan(plan);
    }
    if lm.from_packed {
        // supervisor restarts reload the packed PTW2 file cold instead
        // of cloning the in-memory model (quantize-once / serve-many)
        builder = builder.checkpoint(args.require("model")?);
    }
    let mut server = builder.start(model);
    let t0 = std::time::Instant::now();
    let mut rejected = 0usize;
    for prompt in &prompts {
        match server.submit(tok.encode(prompt), params, 0) {
            SubmitOutcome::Accepted(_) => {}
            SubmitOutcome::Rejected(e) => {
                rejected += 1;
                eprintln!("rejected: {e}");
            }
        }
    }
    // graceful drain is the completion barrier: stop intake, finish (or
    // deadline-expire) everything in flight — replaying past any replica
    // deaths — then join the workers
    let report = server.drain();
    let wall = t0.elapsed();
    println!(
        "served {} requests with method {method} ({replicas} replicas × {threads} threads, simd {simd_desc}, wall {wall:.2?})",
        report.responses().len()
    );
    if rejected > 0 {
        println!("rejected {rejected} of {} submissions at admission", prompts.len());
    }
    // supervision log: one line per death notice, one summary line the
    // chaos-smoke CI job greps for ("replica restarted")
    for ev in &report.events {
        if let ServerEvent::ReplicaDown { replica, cause } = ev {
            println!("replica {replica} went down: {cause}");
        }
    }
    if report.stats.replica_restarts > 0 {
        println!(
            "replica restarted {} time(s): {} request(s) requeued, {} replay submission(s), {} lost",
            report.stats.replica_restarts,
            report.stats.requeued,
            report.stats.retries,
            report.stats.replica_lost
        );
    }
    // `--print-tokens`: one deterministic line per response, sorted by
    // (request id, sample) — CI diffs this across serve configurations
    // (e.g. --spec-decode on vs off) to pin token-for-token parity
    if args.flag("print-tokens") {
        let mut responses = report.responses();
        responses.sort_by_key(|r| (r.id, r.sample));
        for r in &responses {
            let toks: Vec<String> = r.tokens.iter().map(u32::to_string).collect();
            println!("tokens {}/{}: {}", r.id, r.sample, toks.join(" "));
        }
    }
    for (i, m) in report.metrics.iter().enumerate() {
        println!("replica {i}:\n{}", m.render(wall));
    }
    if let Some(path) = metrics_path {
        let artifact = serve_metrics_json(&report.stats, &report.metrics, wall);
        std::fs::write(&path, artifact.pretty())?;
        println!("wrote serve metrics to {path}");
    }
    Ok(())
}

/// `bench --table N | --fig N | --batched | --kernels | --attention |
/// --prefix | --speculative [--quick]`
fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    let quick = args.flag("quick");
    if args.flag("batched") {
        return bench::batched::run(quick, args);
    }
    if args.flag("kernels") {
        return bench::kernels::run(quick, args);
    }
    if args.flag("attention") {
        return bench::attention::run(quick, args);
    }
    if args.flag("prefix") {
        return bench::prefix::run(quick, args);
    }
    if args.flag("speculative") {
        return bench::speculative::run(quick, args);
    }
    if let Some(t) = args.get("table") {
        return bench::run_table(t, quick, args);
    }
    if let Some(f) = args.get("fig") {
        return bench::run_fig(f, quick, args);
    }
    if args.flag("all") {
        for t in ["1", "2", "3", "4", "5", "6", "7", "8", "10", "11", "12"] {
            bench::run_table(t, true, args)?;
        }
        for f in ["1", "3", "4", "5"] {
            bench::run_fig(f, true, args)?;
        }
        return Ok(());
    }
    anyhow::bail!(
        "bench requires --table N, --fig N, --batched, --kernels, --attention, --prefix, \
         --speculative, or --all"
    )
}

/// `runtime --artifacts artifacts/` — PJRT smoke test of the AOT chain.
fn cmd_runtime(args: &Args) -> anyhow::Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let manifest = ArtifactManifest::load(dir)?;
    let mut engine = PjrtEngine::cpu()?;
    manifest.load_all(&mut engine)?;
    println!("platform: {}", engine.platform());
    for spec in &manifest.specs {
        println!("  loaded {} ({} inputs)", spec.name, spec.inputs.len());
    }
    // execute ternary_matmul with deterministic inputs
    let spec = manifest.get("ternary_matmul")?;
    let mut rng = ptqtp::rng::Rng::new(7);
    let inputs: Vec<Vec<f32>> = spec
        .inputs
        .iter()
        .enumerate()
        .map(|(i, shape)| {
            let n: usize = shape.iter().product();
            (0..n)
                .map(|_| {
                    if i == 1 || i == 2 {
                        (rng.below(3) as f32) - 1.0 // trits
                    } else {
                        rng.normal()
                    }
                })
                .collect()
        })
        .collect();
    let borrowed: Vec<(&[usize], &[f32])> = spec
        .inputs
        .iter()
        .zip(&inputs)
        .map(|(s, d)| (s.as_slice(), d.as_slice()))
        .collect();
    let t0 = std::time::Instant::now();
    let out = engine.run_f32("ternary_matmul", &borrowed)?;
    println!(
        "ternary_matmul executed in {:.2?}: {} outputs, first = {:?}",
        t0.elapsed(),
        out.len(),
        &out[0][..4.min(out[0].len())]
    );
    Ok(())
}

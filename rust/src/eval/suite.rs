//! Downstream task evaluation: exact-match generation (math/code) and
//! logprob choice ranking (cloze) — the Tables 2/3/11/12 metrics.

use crate::data::tasks::{ChoiceTask, MathTask, TaskSuite};
use crate::data::{tokenizer, Tokenizer};
use crate::model::Transformer;

/// Accuracy scores over one [`TaskSuite`].
#[derive(Clone, Copy, Debug, Default)]
pub struct SuiteScores {
    pub math_acc: f64,
    pub cloze_acc: f64,
    pub code_acc: f64,
}

impl SuiteScores {
    pub fn mean(&self) -> f64 {
        (self.math_acc + self.cloze_acc + self.code_acc) / 3.0
    }
}

/// Exact-match accuracy on generation tasks: greedy-decode after the
/// prompt and require the answer string as a prefix of the output.
pub fn eval_exact_match(model: &Transformer, tok: &Tokenizer, tasks: &[MathTask]) -> f64 {
    if tasks.is_empty() {
        return 0.0;
    }
    let mut correct = 0usize;
    for t in tasks {
        let prompt = tok.encode(&t.prompt);
        let want = tok.encode(&t.answer);
        let got = model.generate_greedy(&prompt, want.len() + 2, Some(tokenizer::EOS));
        if got.len() >= want.len() && got[..want.len()] == want[..] {
            correct += 1;
        }
    }
    correct as f64 / tasks.len() as f64
}

/// Choice-ranking accuracy: each choice is scored by the mean logprob
/// of its tokens given the prompt; highest mean wins (length-normalized,
/// the lm-eval "acc_norm" convention).
pub fn eval_choices(model: &Transformer, tok: &Tokenizer, tasks: &[ChoiceTask]) -> f64 {
    if tasks.is_empty() {
        return 0.0;
    }
    let mut correct = 0usize;
    for t in tasks {
        let mut best = (f64::NEG_INFINITY, 0usize);
        for (ci, choice) in t.choices.iter().enumerate() {
            let full = format!("{}{}", t.prompt, choice);
            let ids = tok.encode(&full);
            let prompt_len = tok.encode(&t.prompt).len();
            if ids.len() < 2 || prompt_len == 0 || prompt_len >= ids.len() {
                continue;
            }
            let nll = model.sequence_nll(&ids);
            // nll[i] scores token i+1; choice tokens start at prompt_len
            let choice_nll: f64 = nll[prompt_len - 1..].iter().sum();
            let n = (ids.len() - prompt_len) as f64;
            let mean_lp = -choice_nll / n;
            if mean_lp > best.0 {
                best = (mean_lp, ci);
            }
        }
        if best.1 == t.correct {
            correct += 1;
        }
    }
    correct as f64 / tasks.len() as f64
}

/// CI tolerance for the int8-activation tier's **relative** perplexity
/// drift: `|ppl_int8 − ppl_f32| / ppl_f32` must stay within this bound
/// on the bench corpus. The kernel bench stamps the measured delta into
/// `BENCH_kernels.json` and asserts it under this constant, so a
/// quantization regression in the tier fails CI rather than shipping
/// silently (DESIGN.md §Integer-Kernels).
pub const ACT_QUANT_PPL_TOL: f64 = 0.05;

/// A/B the int8-activation tier end-to-end on held-out text. Returns
/// `(ppl_f32, ppl_int8, relative delta)`, where the delta is signed
/// (`> 0` ⇒ int8 is worse). The model's `exec_act_quant` knob is
/// toggled for each leg and restored before returning.
pub fn act_quant_ppl_delta(
    model: &mut Transformer,
    tok: &Tokenizer,
    text: &str,
) -> (f64, f64, f64) {
    let was = model.exec_act_quant;
    model.set_act_quant(false);
    let ppl_f32 = super::ppl::perplexity(model, tok, text);
    model.set_act_quant(true);
    let ppl_int8 = super::ppl::perplexity(model, tok, text);
    model.set_act_quant(was);
    (ppl_f32, ppl_int8, (ppl_int8 - ppl_f32) / ppl_f32)
}

/// Run the full suite.
pub fn eval_suite(model: &Transformer, tok: &Tokenizer, suite: &TaskSuite) -> SuiteScores {
    SuiteScores {
        math_acc: eval_exact_match(model, tok, &suite.math),
        cloze_acc: eval_choices(model, tok, &suite.cloze),
        code_acc: eval_exact_match(model, tok, &suite.code),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::rng::Rng;

    fn setup() -> (Transformer, Tokenizer) {
        let tok = Tokenizer::from_text(
            "abcdefghijklmnopqrstuvwxyz 0123456789+-*=?.:!>()[]{}",
        );
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = tok.vocab_size();
        cfg.max_seq = 64;
        let mut rng = Rng::new(9);
        (Transformer::random(cfg, &mut rng), tok)
    }

    #[test]
    fn random_model_cloze_near_chance() {
        let (m, tok) = setup();
        let suite = TaskSuite::standard(1, 0, 40, 0);
        let acc = eval_choices(&m, &tok, &suite.cloze);
        // 4 choices → chance = 0.25; random model should be broadly near it
        assert!(acc < 0.7, "acc {acc}");
    }

    #[test]
    fn random_model_math_near_zero() {
        let (m, tok) = setup();
        let suite = TaskSuite::standard(2, 25, 0, 0);
        let acc = eval_exact_match(&m, &tok, &suite.math);
        assert!(acc < 0.2, "acc {acc}");
    }

    #[test]
    fn exact_match_detects_perfect_answers() {
        // fabricate tasks whose answer is what the model will greedily
        // emit: probe the model first, then make that the expected answer
        let (m, tok) = setup();
        let prompt = "Q:1+1=? A:";
        let pids = tok.encode(prompt);
        let got = m.generate_greedy(&pids, 3, None);
        let answer = tok.decode(&got);
        if answer.is_empty() {
            return; // degenerate random model; nothing to assert
        }
        let tasks = vec![MathTask {
            prompt: prompt.into(),
            answer,
        }];
        assert_eq!(eval_exact_match(&m, &tok, &tasks), 1.0);
    }

    #[test]
    fn suite_scores_in_range() {
        let (m, tok) = setup();
        let suite = TaskSuite::standard(3, 5, 10, 5);
        let s = eval_suite(&m, &tok, &suite);
        for v in [s.math_acc, s.cloze_acc, s.code_acc, s.mean()] {
            assert!((0.0..=1.0).contains(&v));
        }
    }

    #[test]
    fn act_quant_ppl_delta_measures_and_restores() {
        let (mut m, tok) = setup();
        m.quantize_with(
            crate::quant::by_name("ptqtp", 8).unwrap().as_ref(),
            &crate::quant::QuantCtx::default(),
        );
        assert!(m.act_quant_layers() > 0, "tiny/G=8 must have eligible layers");
        let text = "abc def ghij abc def ghij abc def ghij abc";
        let (f32_ppl, int8_ppl, delta) = act_quant_ppl_delta(&mut m, &tok, text);
        assert!(f32_ppl.is_finite() && int8_ppl.is_finite());
        assert_eq!(delta, (int8_ppl - f32_ppl) / f32_ppl);
        assert!(!m.exec_act_quant, "knob restored to its prior value");
        // int8 activations perturb but must not wreck a tiny model's
        // ppl; this loose bound catches sign/scale bugs, the tight
        // CI gate lives in the kernel bench
        assert!(delta.abs() < 0.5, "delta {delta}");
        m.set_act_quant(true);
        let _ = act_quant_ppl_delta(&mut m, &tok, text);
        assert!(m.exec_act_quant, "restore works from the on state too");
    }

    #[test]
    fn empty_suite_zero() {
        let (m, tok) = setup();
        let s = eval_suite(&m, &tok, &TaskSuite::default());
        assert_eq!(s.mean(), 0.0);
    }
}

//! Evaluation suites: perplexity (Table 1/9 metric) and downstream task
//! accuracy (Tables 2/3/11/12 metrics).

pub mod ppl;
pub mod suite;

pub use ppl::perplexity;
pub use suite::{act_quant_ppl_delta, eval_suite, SuiteScores, ACT_QUANT_PPL_TOL};

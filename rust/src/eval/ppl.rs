//! Perplexity evaluation — the metric of Tables 1, 7, 8, 9.
//!
//! Identical protocol to the paper's WikiText2/PTB/C4 measurement:
//! tokenize the held-out text, run teacher-forced next-token prediction
//! in chunks of the model's context length, and report
//! `exp(mean NLL)` in nats.

use crate::data::Tokenizer;
use crate::model::Transformer;

/// Perplexity of `model` on `text`. Chunks of `max_seq` tokens are
/// evaluated independently (fresh cache per chunk), matching the
/// standard lm-eval sliding protocol with stride = context. Lines are
/// joined with EOS, matching the training tokenization contract.
pub fn perplexity(model: &Transformer, tok: &Tokenizer, text: &str) -> f64 {
    let ids = tok.encode_lines(text);
    perplexity_ids(model, &ids)
}

/// Perplexity over pre-tokenized ids.
pub fn perplexity_ids(model: &Transformer, ids: &[u32]) -> f64 {
    let ctx = model.config.max_seq;
    let mut total_nll = 0.0f64;
    let mut total_tok = 0usize;
    for chunk in ids.chunks(ctx) {
        if chunk.len() < 2 {
            continue;
        }
        let nll = model.sequence_nll(chunk);
        total_nll += nll.iter().sum::<f64>();
        total_tok += nll.len();
    }
    if total_tok == 0 {
        return f64::NAN;
    }
    (total_nll / total_tok as f64).exp()
}

/// Mean NLL (nats/token) — used where a linear-scale metric is easier
/// to compare (Fig 3 convergence curves).
pub fn mean_nll(model: &Transformer, tok: &Tokenizer, text: &str) -> f64 {
    let ids = tok.encode_lines(text);
    let ctx = model.config.max_seq;
    let mut total = 0.0;
    let mut count = 0usize;
    for chunk in ids.chunks(ctx) {
        if chunk.len() < 2 {
            continue;
        }
        let nll = model.sequence_nll(chunk);
        total += nll.iter().sum::<f64>();
        count += nll.len();
    }
    total / count.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::rng::Rng;

    fn setup() -> (Transformer, Tokenizer) {
        let tok = Tokenizer::from_text("abcdefghij .:");
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = tok.vocab_size();
        cfg.max_seq = 32;
        let mut rng = Rng::new(1);
        (Transformer::random(cfg, &mut rng), tok)
    }

    #[test]
    fn random_model_ppl_near_uniform() {
        let (m, tok) = setup();
        let text = "abc def ghij abc def ghij abc def";
        let ppl = perplexity(&m, &tok, text);
        // random logits ⇒ ppl in the vicinity of vocab size
        assert!(ppl.is_finite());
        assert!(ppl > 2.0 && ppl < 100.0, "ppl {ppl}");
    }

    #[test]
    fn ppl_consistent_with_mean_nll() {
        let (m, tok) = setup();
        let text = "abcd abcd abcd abcd";
        let ppl = perplexity(&m, &tok, text);
        let nll = mean_nll(&m, &tok, text);
        assert!((ppl - nll.exp()).abs() < 1e-9);
    }

    #[test]
    fn empty_text_is_nan() {
        let (m, tok) = setup();
        assert!(perplexity(&m, &tok, "").is_nan());
        // a single char still yields one transition (char -> EOS)
        assert!(perplexity(&m, &tok, "a").is_finite());
    }

    #[test]
    fn long_text_chunks() {
        let (m, tok) = setup();
        let text: String = std::iter::repeat("abc def. ").take(20).collect();
        let ppl = perplexity(&m, &tok, &text);
        assert!(ppl.is_finite());
    }
}

//! Distribution statistics over matrices — used by the quantizer
//! diagnostics (Fig 5 analogue) and the synthetic-weight validators.

use super::Matrix;

/// Summary statistics of a weight matrix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MatrixStats {
    pub mean: f64,
    pub std: f64,
    pub abs_mean: f64,
    pub abs_max: f64,
    pub kurtosis: f64,
    /// Fraction of entries with |x| > 4·std (outlier mass).
    pub outlier_frac: f64,
    /// Fraction of exact zeros.
    pub zero_frac: f64,
}

impl MatrixStats {
    pub fn of(m: &Matrix) -> MatrixStats {
        let n = m.data.len().max(1) as f64;
        let mean = m.data.iter().map(|&x| x as f64).sum::<f64>() / n;
        let var = m
            .data
            .iter()
            .map(|&x| {
                let d = x as f64 - mean;
                d * d
            })
            .sum::<f64>()
            / n;
        let std = var.sqrt();
        let m4 = m
            .data
            .iter()
            .map(|&x| {
                let d = x as f64 - mean;
                d * d * d * d
            })
            .sum::<f64>()
            / n;
        let kurtosis = if var > 0.0 { m4 / (var * var) } else { 0.0 };
        let thresh = 4.0 * std;
        let outliers = m.data.iter().filter(|&&x| (x as f64 - mean).abs() > thresh).count();
        let zeros = m.data.iter().filter(|&&x| x == 0.0).count();
        MatrixStats {
            mean,
            std,
            abs_mean: m.data.iter().map(|&x| x.abs() as f64).sum::<f64>() / n,
            abs_max: m.data.iter().fold(0.0f64, |a, &x| a.max(x.abs() as f64)),
            kurtosis,
            outlier_frac: outliers as f64 / n,
            zero_frac: zeros as f64 / n,
        }
    }
}

/// Histogram over fixed bins in [-range, range]; the Fig-5 style
/// trit-plane visualizations reuse this.
pub fn histogram(data: &[f32], bins: usize, range: f32) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    let scale = bins as f32 / (2.0 * range);
    for &x in data {
        let idx = ((x + range) * scale).floor();
        let idx = idx.clamp(0.0, bins as f32 - 1.0) as usize;
        h[idx] += 1;
    }
    h
}

/// Render a histogram as a compact ASCII sparkline (for `--fig 5` dumps).
pub fn sparkline(h: &[usize]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let max = *h.iter().max().unwrap_or(&1) as f64;
    h.iter()
        .map(|&c| {
            if max == 0.0 {
                BARS[0]
            } else {
                let lvl = ((c as f64 / max) * 7.0).round() as usize;
                BARS[lvl.min(7)]
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::Matrix;

    #[test]
    fn normal_stats_sane() {
        let mut rng = Rng::new(1);
        let m = Matrix::randn(128, 128, 0.05, &mut rng);
        let s = MatrixStats::of(&m);
        assert!(s.mean.abs() < 0.002);
        assert!((s.std - 0.05).abs() < 0.005);
        assert!((s.kurtosis - 3.0).abs() < 0.3, "kurtosis {}", s.kurtosis);
    }

    #[test]
    fn heavy_tail_has_higher_kurtosis() {
        let mut rng = Rng::new(2);
        let n = Matrix::randn(128, 128, 0.05, &mut rng);
        let h = Matrix::rand_heavy(128, 128, 0.05, &mut rng);
        assert!(MatrixStats::of(&h).kurtosis > MatrixStats::of(&n).kurtosis + 0.5);
    }

    #[test]
    fn histogram_counts_all() {
        let data = vec![-1.0f32, -0.5, 0.0, 0.5, 0.99, 5.0, -5.0];
        let h = histogram(&data, 4, 1.0);
        assert_eq!(h.iter().sum::<usize>(), data.len());
        // clamped extremes land in edge bins
        assert!(h[0] >= 2);
        assert!(h[3] >= 2);
    }

    #[test]
    fn sparkline_length_matches() {
        let h = vec![0usize, 1, 5, 10];
        let s = sparkline(&h);
        assert_eq!(s.chars().count(), 4);
    }

    #[test]
    fn zero_frac_detects_sparsity() {
        let mut m = Matrix::zeros(4, 4);
        m.data[3] = 1.0;
        let s = MatrixStats::of(&m);
        assert!((s.zero_frac - 15.0 / 16.0).abs() < 1e-9);
    }
}

//! Matrix/vector operations: blocked matmul, matvec, softmax.
//!
//! These back the FP16/FP32 baselines in the latency benches (Table 5/6)
//! and the Rust inference path; they are written cache-blocked so the
//! dense baseline is a fair comparator for the ternary kernels.

use super::Matrix;

/// Cache-block edge for the blocked matmul (elements).
const BLOCK: usize = 64;

/// C = A(m×k) · B(k×n), blocked over k for cache reuse.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// C += nothing; C is overwritten. Panics on shape mismatch.
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows, "matmul inner dim mismatch");
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    c.data.iter_mut().for_each(|x| *x = 0.0);
    // i-k-j loop order with k blocking: streams B rows, accumulates C rows.
    for kb in (0..k).step_by(BLOCK) {
        let ke = (kb + BLOCK).min(k);
        for i in 0..m {
            let a_row = &a.data[i * k..(i + 1) * k];
            let c_row = &mut c.data[i * n..(i + 1) * n];
            for kk in kb..ke {
                let av = a_row[kk];
                if av == 0.0 {
                    continue;
                }
                let b_row = &b.data[kk * n..(kk + 1) * n];
                for j in 0..n {
                    c_row[j] += av * b_row[j];
                }
            }
        }
    }
}

/// y = W(n×d) · x(d): the decode-path linear primitive.
pub fn matvec(w: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(w.cols, x.len(), "matvec dim mismatch");
    let mut y = vec![0.0f32; w.rows];
    matvec_into(w, x, &mut y);
    y
}

/// y (len n) = W(n×d) · x(d), unrolled 4-wide accumulators.
pub fn matvec_into(w: &Matrix, x: &[f32], y: &mut [f32]) {
    matvec_span_into(w, x, 0, y);
}

/// Span form of [`matvec_into`]: `y[i]` = row `row0 + i` of `W·x`. The
/// single numerics body shared by the sequential and row-parallel
/// drivers (`QuantLinear::forward_rows_into`, the tied LM head), so
/// partitioning output rows across threads cannot change any value.
pub fn matvec_span_into(w: &Matrix, x: &[f32], row0: usize, y: &mut [f32]) {
    let d = w.cols;
    debug_assert!(row0 + y.len() <= w.rows);
    for (i, yi) in y.iter_mut().enumerate() {
        let r = row0 + i;
        let row = &w.data[r * d..(r + 1) * d];
        let mut s0 = 0.0f32;
        let mut s1 = 0.0f32;
        let mut s2 = 0.0f32;
        let mut s3 = 0.0f32;
        let chunks = d / 4;
        for c in 0..chunks {
            let b = c * 4;
            s0 += row[b] * x[b];
            s1 += row[b + 1] * x[b + 1];
            s2 += row[b + 2] * x[b + 2];
            s3 += row[b + 3] * x[b + 3];
        }
        let mut s = s0 + s1 + s2 + s3;
        for b in chunks * 4..d {
            s += row[b] * x[b];
        }
        *yi = s;
    }
}

/// Pool-parallel batched matvec: row `r` of `y` = `W · x.row(r)`.
/// Lanes take contiguous spans of batch rows, or — for a single source
/// row — contiguous spans of W's output rows (when W is tall enough to
/// amortize dispatch); empty batches are a no-op. Either way every
/// output element runs the same [`matvec_span_into`] body, so results
/// are bit-identical to the sequential loop for any lane count
/// (DESIGN.md §Threading). Shared by `QuantLinear::forward_rows_into`'s
/// dense arm and the tied LM head.
pub fn matvec_rows_pooled(w: &Matrix, x: &Matrix, y: &mut Matrix, pool: &crate::threads::Pool) {
    debug_assert_eq!(x.cols, w.cols);
    debug_assert_eq!(y.rows, x.rows);
    debug_assert_eq!(y.cols, w.rows);
    let lanes = pool.threads();
    let n = w.rows;
    // same engagement policy as the ternary drivers: dispatch to the
    // pool only when the total work amortizes the condvar round trip
    if lanes > 1 && x.rows > 1 && crate::threads::worth_parallel(x.rows * n, w.cols) {
        crate::threads::run_spans(pool, x.rows, n, &mut y.data, |_, rows, span| {
            for (i, r) in rows.enumerate() {
                matvec_into(w, x.row(r), &mut span[i * n..(i + 1) * n]);
            }
        });
    } else if lanes > 1 && x.rows == 1 && crate::threads::worth_parallel(n, w.cols) {
        crate::threads::run_spans(pool, n, 1, &mut y.data, |_, chans, span| {
            matvec_span_into(w, x.row(0), chans.start, span);
        });
    } else {
        for r in 0..x.rows {
            matvec_into(w, x.row(r), y.row_mut(r));
        }
    }
}

/// Numerically-stable row-wise softmax in place.
pub fn softmax_rows(m: &mut Matrix) {
    for r in 0..m.rows {
        let row = m.row_mut(r);
        softmax_inplace(row);
    }
}

/// Stable softmax over a slice.
pub fn softmax_inplace(xs: &mut [f32]) {
    let max = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in xs.iter_mut() {
        *x *= inv;
    }
}

/// log-softmax over a slice (returns new vec) — used by the perplexity
/// evaluator where we need log-probabilities.
pub fn log_softmax(xs: &[f32]) -> Vec<f32> {
    let max = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let lse = xs.iter().map(|&x| ((x - max) as f64).exp()).sum::<f64>().ln() as f32 + max;
    xs.iter().map(|&x| x - lse).collect()
}

/// Dot product with 4-wide accumulators.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s0 = 0.0f32;
    let mut s1 = 0.0f32;
    let mut s2 = 0.0f32;
    let mut s3 = 0.0f32;
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f64;
                for kk in 0..a.cols {
                    s += a.at(i, kk) as f64 * b.at(kk, j) as f64;
                }
                *c.at_mut(i, j) = s as f32;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(3);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 128, 65)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            let c_ref = naive_matmul(&a, &b);
            for (x, y) in c.data.iter().zip(&c_ref.data) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(4);
        let w = Matrix::randn(19, 37, 1.0, &mut rng);
        let x: Vec<f32> = (0..37).map(|_| rng.normal()).collect();
        let y = matvec(&w, &x);
        let xm = Matrix::from_vec(37, 1, x);
        let y2 = matmul(&w, &xm);
        for (a, b) in y.iter().zip(&y2.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(8);
        let mut m = Matrix::randn(5, 12, 3.0, &mut rng);
        softmax_rows(&mut m);
        for r in 0..5 {
            let s: f32 = m.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(m.row(r).iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn softmax_stable_under_shift() {
        let mut a = vec![1000.0f32, 1001.0, 1002.0];
        softmax_inplace(&mut a);
        let mut b = vec![0.0f32, 1.0, 2.0];
        softmax_inplace(&mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn log_softmax_normalizes() {
        let xs = vec![0.3f32, -1.2, 2.0, 0.0];
        let ls = log_softmax(&xs);
        let total: f64 = ls.iter().map(|&x| (x as f64).exp()).sum();
        assert!((total - 1.0).abs() < 1e-6);
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::new(10);
        let a: Vec<f32> = (0..103).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..103).map(|_| rng.normal()).collect();
        let expect: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - expect).abs() < 1e-4);
    }
}

//! Dense tensor substrate: row-major f32 matrices and helpers.
//!
//! Deliberately small — just what the quantizers, model, and serving
//! engine need. Heavy lifting (blocked matmul, transposes, stats) lives
//! in [`ops`]; the [`Matrix`] type owns storage and shape.

pub mod ops;
pub mod stats;

pub use ops::{matmul, matmul_into, matvec, softmax_rows};
pub use stats::MatrixStats;

/// Row-major 2-D f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Default for Matrix {
    /// Empty 0×0 matrix (scratch-buffer initial state).
    fn default() -> Matrix {
        Matrix::zeros(0, 0)
    }
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Build from an existing buffer; panics if the length mismatches.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    /// iid normal(0, std) entries from a deterministic RNG.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut crate::rng::Rng) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut m.data, std);
        m
    }

    /// Heavy-tailed (student-t df=4) entries scaled to `std` — mimics
    /// trained-LLM weight outlier structure for synthetic benchmarks.
    pub fn rand_heavy(rows: usize, cols: usize, std: f32, rng: &mut crate::rng::Rng) -> Self {
        // var of t(df) is df/(df-2) => scale to unit variance then by std
        let df = 4.0f32;
        let unit = (df / (df - 2.0)).sqrt();
        Matrix::from_fn(rows, cols, |_, _| rng.student_t(df) / unit * std)
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Number of elements.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Reshape in place (row-major reinterpretation). Panics on size
    /// mismatch. This is how group-wise quantization views `n×d` as
    /// `(n·d/G)×G` (paper §3.2).
    pub fn reshape(mut self, rows: usize, cols: usize) -> Matrix {
        assert_eq!(rows * cols, self.data.len(), "reshape size mismatch");
        self.rows = rows;
        self.cols = cols;
        self
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// ‖self − other‖_F² (the paper's reconstruction objective).
    pub fn sq_err(&self, other: &Matrix) -> f64 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum()
    }

    /// Relative Frobenius error ‖A−B‖_F / ‖A‖_F.
    pub fn rel_err(&self, approx: &Matrix) -> f64 {
        let denom = self.fro_norm().max(1e-30);
        self.sq_err(approx).sqrt() / denom
    }

    /// Elementwise scale.
    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// self += other
    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!(self.data.len(), other.data.len());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Max |x|.
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Mean |x|.
    pub fn abs_mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|x| x.abs()).sum::<f32>() / self.data.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn index_roundtrip() {
        let m = Matrix::from_fn(3, 4, |r, c| (r * 10 + c) as f32);
        assert_eq!(m.at(2, 3), 23.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(5);
        let m = Matrix::randn(7, 11, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn transpose_values() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t = m.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t.at(0, 1), 4.0);
        assert_eq!(t.at(2, 0), 3.0);
    }

    #[test]
    fn reshape_preserves_data() {
        let m = Matrix::from_vec(2, 6, (0..12).map(|i| i as f32).collect());
        let g = m.clone().reshape(4, 3);
        assert_eq!(g.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(g.data, m.data);
    }

    #[test]
    #[should_panic]
    fn reshape_mismatch_panics() {
        Matrix::zeros(2, 3).reshape(4, 2);
    }

    #[test]
    fn fro_and_sq_err() {
        let a = Matrix::from_vec(1, 3, vec![3.0, 0.0, 4.0]);
        let b = Matrix::zeros(1, 3);
        assert!((a.fro_norm() - 5.0).abs() < 1e-9);
        assert!((a.sq_err(&b) - 25.0).abs() < 1e-9);
        assert!((a.rel_err(&b) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn heavy_tailed_has_outliers() {
        let mut rng = Rng::new(77);
        let m = Matrix::rand_heavy(64, 64, 0.02, &mut rng);
        // abs_max should exceed what a pure normal with same std would
        // essentially always produce over 4096 draws (~4 sigma)
        assert!(m.abs_max() > 0.02 * 4.5, "max {}", m.abs_max());
    }
}

//! Deterministic row-parallel execution substrate (std-only).
//!
//! A small persistent worker pool that partitions work by *output
//! channel* (or row). Determinism argument: every output element is
//! computed in full by exactly one lane, running the identical
//! sequential kernel code over that element — the floating-point
//! operation order within an element never changes, and the partition
//! is a pure function of `(total, lanes)` — so parallel output is
//! **bit-identical** to sequential output for any thread count. There
//! is no work stealing and no atomically-reduced accumulator anywhere
//! in the crate; cross-lane reductions are always performed by the
//! leader in a fixed order.
//!
//! Sizing: `--threads N` on the CLI, else the `PTQTP_THREADS`
//! environment variable, else all available cores
//! ([`default_threads`]). `threads = 1` *is* the sequential path — no
//! workers are spawned and [`Pool::run`] invokes the job inline — the
//! documented escape hatch for debugging.
//!
//! Lifecycle: [`Pool::new`] spawns `n - 1` parked workers (the caller
//! is lane 0); handles are cheap clones sharing one pool; the last
//! handle to drop signals shutdown and joins the workers. The
//! process-wide [`Pool::global`] pool is shared by every engine that
//! doesn't ask for its own size and lives for the whole process.
//!
//! Nesting rule: a job body must never call [`Pool::run`] on the same
//! pool (the leader holds the dispatch lock while workers run, so a
//! nested call deadlocks). Callers that fan out at an outer level pass
//! [`Pool::sequential`] to inner layers — see
//! `Transformer::quantize_with`.

use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Minimum total multiply-add work (output elements × reduction
/// length) before the parallel drivers engage: a pool dispatch costs a
/// condvar round trip (order of microseconds), so only matrices with
/// comfortably-larger kernels go to the lanes. Below it, drivers stay
/// inline — identical output either way.
pub const PAR_MIN_WORK: usize = 32_768;

/// Dispatch gate for the row-parallel drivers: `out_rows` output
/// elements each reducing over `cols` inputs. Batch kernels pass
/// `x_rows * out_rows` so the whole stack amortizes one dispatch.
#[inline]
pub fn worth_parallel(out_rows: usize, cols: usize) -> bool {
    out_rows.saturating_mul(cols) >= PAR_MIN_WORK
}

/// Resolve the default lane count: `PTQTP_THREADS` if set and valid,
/// else the number of available cores.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PTQTP_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Contiguous near-even span of `0..total` owned by `lane` out of
/// `lanes`. Pure function of its arguments (the determinism anchor):
/// the first `total % lanes` lanes take one extra item.
pub fn chunk_range(total: usize, lanes: usize, lane: usize) -> std::ops::Range<usize> {
    debug_assert!(lane < lanes);
    let base = total / lanes;
    let rem = total % lanes;
    let start = lane * base + lane.min(rem);
    let len = base + usize::from(lane < rem);
    start..start + len
}

/// Raw-pointer wrapper so kernels can hand each lane its disjoint
/// output span through a shared `Fn` closure. Safety contract is the
/// caller's: lanes must write non-overlapping regions.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr<T>(pub *mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    #[inline]
    pub fn get(self) -> *mut T {
        self.0
    }
}

/// Partition `y` into per-lane contiguous spans — `chunk_range(total,
/// lanes, lane)` items of `stride` elements each — and invoke
/// `f(lane, items, span)` with each lane's disjoint `&mut` view. This
/// is the one place the span-aliasing argument lives; parallel kernels
/// should prefer it over hand-rolled [`SendPtr`] arithmetic.
pub fn run_spans<T: Send>(
    pool: &Pool,
    total: usize,
    stride: usize,
    y: &mut [T],
    f: impl Fn(usize, std::ops::Range<usize>, &mut [T]) + Sync,
) {
    debug_assert!(y.len() >= total * stride);
    let lanes = pool.threads();
    if lanes <= 1 {
        if total > 0 {
            f(0, 0..total, &mut y[..total * stride]);
        }
        return;
    }
    let yp = SendPtr(y.as_mut_ptr());
    pool.run(|lane| {
        let items = chunk_range(total, lanes, lane);
        if items.is_empty() {
            return;
        }
        // SAFETY: chunk_range tiles 0..total disjointly across lanes,
        // so the [start·stride, end·stride) element spans never
        // overlap, and `y` outlives the call because `run` blocks the
        // leader until every lane returns.
        let span = unsafe {
            std::slice::from_raw_parts_mut(
                yp.get().add(items.start * stride),
                items.len() * stride,
            )
        };
        f(lane, items, span);
    });
}

/// Job handed to the workers: a lifetime-erased pointer to the caller's
/// closure. Valid only while the leader blocks in [`Pool::run`].
#[derive(Clone, Copy)]
struct Job {
    f: *const (dyn Fn(usize) + Sync),
}

unsafe impl Send for Job {}

struct JobState {
    /// Monotone dispatch counter; workers run each epoch exactly once.
    epoch: u64,
    job: Option<Job>,
    /// Workers still inside the current epoch's job.
    remaining: usize,
    /// First worker panic of the epoch, preserved so the leader can
    /// rethrow the original payload (e.g. a parity-assert message).
    panic_payload: Option<Box<dyn std::any::Any + Send>>,
    /// Set under the mutex (so parked workers can't miss it) when the
    /// last pool handle drops.
    shutdown: bool,
}

struct Shared {
    state: Mutex<JobState>,
    /// Workers park here waiting for a new epoch.
    work_cv: Condvar,
    /// The leader parks here waiting for `remaining == 0`.
    done_cv: Condvar,
}

struct PoolInner {
    shared: Arc<Shared>,
    /// Total lanes including the leader.
    lanes: usize,
    /// Serializes concurrent `run` calls from different leader threads.
    run_lock: Mutex<()>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl Drop for PoolInner {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        if let Ok(mut handles) = self.handles.lock() {
            for h in handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

/// Cheaply-cloneable handle to a worker pool (or to the sequential
/// no-pool when `threads == 1`).
#[derive(Clone, Default)]
pub struct Pool {
    inner: Option<Arc<PoolInner>>,
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool").field("threads", &self.threads()).finish()
    }
}

impl Pool {
    /// The inline, single-lane pool: `run` calls the job on the caller.
    pub fn sequential() -> Pool {
        Pool { inner: None }
    }

    /// Spawn a pool with `threads` total lanes (`threads - 1` workers;
    /// the calling thread is always lane 0). `threads <= 1` spawns
    /// nothing and behaves exactly like [`Pool::sequential`].
    pub fn new(threads: usize) -> Pool {
        if threads <= 1 {
            return Pool::sequential();
        }
        let shared = Arc::new(Shared {
            state: Mutex::new(JobState {
                epoch: 0,
                job: None,
                remaining: 0,
                panic_payload: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(threads - 1);
        for lane in 1..threads {
            let shared = Arc::clone(&shared);
            handles.push(std::thread::spawn(move || worker_loop(&shared, lane)));
        }
        Pool {
            inner: Some(Arc::new(PoolInner {
                shared,
                lanes: threads,
                run_lock: Mutex::new(()),
                handles: Mutex::new(handles),
            })),
        }
    }

    /// Process-wide shared pool sized by [`default_threads`]. Engines
    /// and benches that don't request a size clone this.
    pub fn global() -> &'static Pool {
        static GLOBAL: OnceLock<Pool> = OnceLock::new();
        GLOBAL.get_or_init(|| Pool::new(default_threads()))
    }

    /// Total lanes including the leader (1 = sequential).
    pub fn threads(&self) -> usize {
        self.inner.as_ref().map(|i| i.lanes).unwrap_or(1)
    }

    /// True when `run` executes inline on the caller only.
    pub fn is_sequential(&self) -> bool {
        self.inner.is_none()
    }

    /// Execute `f(lane)` once per lane in `0..threads()`, in parallel;
    /// the caller runs lane 0. Blocks until every lane returns, so `f`
    /// may borrow the caller's stack. Panics in any lane are surfaced
    /// here after all lanes finish.
    pub fn run(&self, f: impl Fn(usize) + Sync) {
        let Some(inner) = self.inner.as_ref() else {
            f(0);
            return;
        };
        let shared = &inner.shared;
        let guard = inner.run_lock.lock().unwrap();
        // Erase the borrow: workers only dereference while we block below.
        let job = Job {
            f: &f as &(dyn Fn(usize) + Sync) as *const (dyn Fn(usize) + Sync),
        };
        {
            let mut st = shared.state.lock().unwrap();
            st.epoch += 1;
            st.job = Some(job);
            st.remaining = inner.lanes - 1;
            st.panic_payload = None;
            shared.work_cv.notify_all();
        }
        let lead = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(0)));
        let worker_payload = {
            let mut st = shared.state.lock().unwrap();
            while st.remaining > 0 {
                st = shared.done_cv.wait(st).unwrap();
            }
            st.job = None;
            st.panic_payload.take()
        };
        drop(guard);
        if let Err(p) = lead {
            std::panic::resume_unwind(p);
        }
        if let Some(p) = worker_payload {
            // rethrow the worker's original payload so e.g. a kernel
            // parity assert keeps its message
            std::panic::resume_unwind(p);
        }
    }
}

fn worker_loop(shared: &Shared, lane: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                match st.job {
                    Some(job) if st.epoch != seen => {
                        seen = st.epoch;
                        break job;
                    }
                    _ => st = shared.work_cv.wait(st).unwrap(),
                }
            }
        };
        // The leader blocks until `remaining == 0`, so the closure
        // behind `job.f` is alive for the whole call.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
            (&*job.f)(lane)
        }));
        let mut st = shared.state.lock().unwrap();
        if let Err(payload) = result {
            if st.panic_payload.is_none() {
                st.panic_payload = Some(payload);
            }
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunk_ranges_cover_exactly() {
        for total in [0usize, 1, 5, 64, 97, 1000] {
            for lanes in [1usize, 2, 3, 4, 7] {
                let mut next = 0usize;
                for lane in 0..lanes {
                    let r = chunk_range(total, lanes, lane);
                    assert_eq!(r.start, next, "total={total} lanes={lanes} lane={lane}");
                    next = r.end;
                }
                assert_eq!(next, total);
            }
        }
    }

    #[test]
    fn sequential_pool_runs_inline() {
        let pool = Pool::sequential();
        assert_eq!(pool.threads(), 1);
        assert!(pool.is_sequential());
        let hits = AtomicUsize::new(0);
        pool.run(|lane| {
            assert_eq!(lane, 0);
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn every_lane_runs_once_per_job() {
        let pool = Pool::new(4);
        assert_eq!(pool.threads(), 4);
        for _round in 0..20 {
            let mask = AtomicUsize::new(0);
            pool.run(|lane| {
                mask.fetch_or(1 << lane, Ordering::SeqCst);
            });
            assert_eq!(mask.load(Ordering::SeqCst), 0b1111);
        }
    }

    #[test]
    fn disjoint_spans_fill_a_buffer() {
        let pool = Pool::new(3);
        let lanes = pool.threads();
        let mut buf = vec![0u32; 101];
        let ptr = SendPtr(buf.as_mut_ptr());
        pool.run(|lane| {
            let r = chunk_range(101, lanes, lane);
            for i in r {
                unsafe { *ptr.get().add(i) = i as u32 + 1 };
            }
        });
        assert!(buf.iter().enumerate().all(|(i, &v)| v == i as u32 + 1));
    }

    #[test]
    fn run_spans_hands_each_lane_its_items() {
        for (pool, total, stride) in
            [(Pool::new(3), 10usize, 4usize), (Pool::sequential(), 7, 2), (Pool::new(4), 0, 3)]
        {
            let mut buf = vec![0usize; total * stride];
            run_spans(&pool, total, stride, &mut buf, |_, items, span| {
                assert_eq!(span.len(), items.len() * stride);
                for (i, item) in items.enumerate() {
                    for k in 0..stride {
                        span[i * stride + k] = item * stride + k + 1;
                    }
                }
            });
            assert!(
                buf.iter().enumerate().all(|(i, &v)| v == i + 1),
                "total={total} stride={stride}"
            );
        }
    }

    #[test]
    fn clones_share_workers_and_drop_cleanly() {
        let pool = Pool::new(2);
        let clone = pool.clone();
        let hits = AtomicUsize::new(0);
        clone.run(|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        drop(pool);
        // remaining handle still works
        clone.run(|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 4);
        drop(clone); // joins workers without hanging
    }

    #[test]
    fn worker_panic_propagates_to_leader() {
        let pool = Pool::new(2);
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(|lane| {
                if lane == 1 {
                    panic!("lane 1 exploded");
                }
            });
        }));
        // the worker's original payload must survive to the leader
        let payload = boom.unwrap_err();
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(msg.contains("lane 1 exploded"), "payload lost: {msg:?}");
        // pool survives a panicked job
        let hits = AtomicUsize::new(0);
        pool.run(|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
    }
}

//! Mini property-testing substrate (the offline cache has no `proptest`).
//!
//! Quickcheck-style: a [`Gen`] wraps the deterministic [`crate::rng::Rng`];
//! properties run over many generated cases; on failure the framework
//! greedily shrinks size-like parameters and reports the seed so the case
//! reproduces exactly.
//!
//! ```ignore
//! check(200, |g| {
//!     let n = g.usize_in(1, 64);
//!     let v = g.vec_f32(n, -1.0, 1.0);
//!     prop_assert(roundtrip(&v) == v, "roundtrip failed")
//! });
//! ```

use crate::rng::Rng;

/// Case generator handed to each property invocation.
pub struct Gen {
    pub rng: Rng,
    /// Current complexity budget; grows with the case index so early
    /// cases are tiny (fast shrinking-by-construction).
    pub size: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let hi_eff = lo + ((hi - lo).min(self.size.max(1)));
        self.rng.range(lo, hi_eff + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, len: usize, std: f32) -> Vec<f32> {
        (0..len).map(|_| self.rng.normal() * std).collect()
    }

    /// Vector over {-1, 0, 1} — trit generator.
    pub fn vec_trits(&mut self, len: usize) -> Vec<i8> {
        (0..len).map(|_| self.rng.below(3) as i8 - 1).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }
}

/// Property outcome.
pub type PropResult = Result<(), String>;

/// Assertion helper for property bodies.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Approximate float equality helper.
pub fn approx_eq(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

/// Run `cases` property invocations with growing size. Panics with the
/// failing seed + case index on the first violation.
pub fn check(cases: usize, prop: impl Fn(&mut Gen) -> PropResult) {
    check_seeded(0x5055_0051_u64 ^ 0x9e37_79b9, cases, prop)
}

/// Like [`check`] with an explicit base seed (reproduce failures).
pub fn check_seeded(base_seed: u64, cases: usize, prop: impl Fn(&mut Gen) -> PropResult) {
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        // size ramps 1..=64 across the run
        let size = 1 + (case * 64) / cases.max(1);
        let mut g = Gen {
            rng: Rng::new(seed),
            size,
        };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed at case {case}/{cases} (seed={seed:#x}, size={size}): {msg}\n\
                 reproduce with check_seeded({seed:#x}, 1, ..) and size={size}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::RefCell::new(&mut count);
        check(50, |g| {
            **counter.borrow_mut() += 1;
            let n = g.usize_in(1, 10);
            prop_assert(n >= 1 && n <= 10, "bounds")
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(100, |g| {
            let n = g.usize_in(1, 64);
            prop_assert(n < 50, format!("n={n} too big"))
        });
    }

    #[test]
    fn trit_generator_in_range() {
        check(100, |g| {
            let v = g.vec_trits(g.size);
            prop_assert(v.iter().all(|&t| (-1..=1).contains(&t)), "trit out of range")
        });
    }

    #[test]
    fn sizes_ramp_up() {
        let seen = std::cell::RefCell::new(Vec::new());
        check(64, |g| {
            seen.borrow_mut().push(g.size);
            Ok(())
        });
        let v = seen.borrow();
        assert!(v[0] < v[v.len() - 1]);
    }

    #[test]
    fn approx_eq_scales() {
        assert!(approx_eq(1000.0, 1000.5, 1e-3));
        assert!(!approx_eq(0.0, 0.1, 1e-3));
    }
}

//! Trit-plane storage and multiply-free compute (paper §3, Appendix A).
//!
//! PTQTP represents a weight matrix `W (n×d)` as two ternary planes
//! `T⁽¹⁾,T⁽²⁾ ∈ {-1,0,1}^{n×d}` plus per-group scales, reconstructing
//!
//! ```text
//! Ŵ = diag(α⁽¹⁾)·T⁽¹⁾ + diag(α⁽²⁾)·T⁽²⁾
//! ```
//!
//! Modules:
//! * [`plane`]  — [`TritPlane`]: unpacked i8 trits with shape.
//! * [`pack`]   — 2-bit packing (hardware format, Eq. 13) and base-3
//!   packing (5 trits/byte, the Appendix G "future work" layout — we
//!   implement it as an extension).
//! * [`linear`] — [`TernaryLinear`]: the deployable two-plane layer with
//!   group-wise scales, reconstruction and quality metrics.
//! * [`gemv`]   — multiply-free matrix–vector kernels (decode path).
//! * [`gemm`]   — multiply-free matrix–matrix kernels (prefill path).
//! * [`lut`]    — activation-indexed table kernels (one table load +
//!   add per byte per plane, bit-identical to the packed tiers) and
//!   the shared byte-decode LUT.
//! * [`simd`]   — runtime-dispatched row-vectorized tier (AVX2 /
//!   SSE2 / NEON / scalar fallback) over a row-interleaved plane
//!   layout; N lanes = N consecutive output rows, bit-identical to
//!   the scalar tiers.
//! * [`int_act`] — opt-in integer-activation tier: int8 activations ×
//!   ternary planes with exact i32 accumulation; value-changing but
//!   deterministic by construction for any thread count / SIMD width.

pub mod gemm;
pub mod gemv;
pub mod int4;
pub mod int_act;
pub mod linear;
pub mod lut;
pub mod pack;
pub mod plane;
pub mod simd;

pub use linear::TernaryLinear;
pub use pack::{pack2bit, pack_base3, unpack2bit, unpack_base3};
pub use plane::TritPlane;

//! Multiply-free matrix–vector kernels (the decode hot path).
//!
//! The paper's Appendix A.1 observation: a ternary weight contributes
//! `+x`, `-x`, or nothing — so the inner loop needs only adds.
//! CPU mapping of the paper's CUDA kernel (see `rust/DESIGN.md`
//! §Hardware-Adaptation): we stream the 2-bit packed planes, decode 4
//! trits per byte via a 256-entry LUT, accumulate each plane in its own
//! register, and apply the two group scales once per group at the
//! epilogue — weights are never multiplied inside the loop.
//!
//! Three implementations here, cross-checked by tests and raced in
//! Table 5 and `bench --kernels`:
//! * [`gemv_unpacked`] — i8 planes, branch on trit (reference).
//! * [`gemv_fused`]    — i8 planes, branchless select-add, both planes in
//!   one pass.
//! * [`gemv_packed`]   — 2-bit packed planes + LUT decode (deployment);
//!   [`gemv_packed_par`] row-partitions it across a worker pool with
//!   bit-identical output.
//!
//! The activation-indexed table tier ([`super::lut`]) sits above these:
//! one table load + add per byte per plane, amortized over output rows.

use super::linear::{PackedTernaryLinear, TernaryLinear};
use super::lut::decode_lut_f32;
use super::pack::dec2;
use crate::threads::{run_spans, worth_parallel, Pool};

/// Reference kernel: explicit branches, reads the unpacked planes.
pub fn gemv_unpacked(lin: &TernaryLinear, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), lin.cols, "gemv dim mismatch");
    assert_eq!(y.len(), lin.rows);
    let gpr = lin.groups_per_row();
    for r in 0..lin.rows {
        let t1 = lin.t1.row(r);
        let t2 = lin.t2.row(r);
        let mut acc = 0.0f32;
        for g in 0..gpr {
            let (s, e) = lin.group_span(g);
            let mut s1 = 0.0f32;
            let mut s2 = 0.0f32;
            for c in s..e {
                match t1[c] {
                    1 => s1 += x[c],
                    -1 => s1 -= x[c],
                    _ => {}
                }
                match t2[c] {
                    1 => s2 += x[c],
                    -1 => s2 -= x[c],
                    _ => {}
                }
            }
            let ai = lin.alpha_idx(r, g);
            acc += lin.alpha1[ai] * s1 + lin.alpha2[ai] * s2;
        }
        y[r] = acc;
    }
}

/// Branchless fused kernel: trit used as an f32 factor in {-1,0,1}; the
/// compiler vectorizes the select-add. Both planes accumulate in one
/// pass over x.
pub fn gemv_fused(lin: &TernaryLinear, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), lin.cols, "gemv dim mismatch");
    assert_eq!(y.len(), lin.rows);
    let gpr = lin.groups_per_row();
    for r in 0..lin.rows {
        let t1 = lin.t1.row(r);
        let t2 = lin.t2.row(r);
        let mut acc = 0.0f32;
        for g in 0..gpr {
            let (s, e) = lin.group_span(g);
            let mut s1 = 0.0f32;
            let mut s2 = 0.0f32;
            for c in s..e {
                let xv = x[c];
                s1 += t1[c] as f32 * xv;
                s2 += t2[c] as f32 * xv;
            }
            let ai = lin.alpha_idx(r, g);
            acc += lin.alpha1[ai] * s1 + lin.alpha2[ai] * s2;
        }
        y[r] = acc;
    }
}

/// Deployment kernel over the 2-bit packed planes.
///
/// Decodes four trits per byte and fuses both planes; group boundaries
/// are byte-aligned whenever `G % 4 == 0` (G=128 default), which the
/// fast path exploits.
pub fn gemv_packed(lin: &PackedTernaryLinear, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), lin.cols, "gemv dim mismatch");
    assert_eq!(y.len(), lin.rows);
    gemv_packed_rows(lin, x, 0..lin.rows, y);
}

/// Row-span core of [`gemv_packed`]: output rows `rows` into `y_span`
/// (`y_span[i]` = row `rows.start + i`). The single numerics body
/// shared by the sequential and row-parallel drivers (and the SIMD
/// tier's ragged tail rows), so they cannot drift.
pub(crate) fn gemv_packed_rows(
    lin: &PackedTernaryLinear,
    x: &[f32],
    rows: std::ops::Range<usize>,
    y_span: &mut [f32],
) {
    debug_assert_eq!(y_span.len(), rows.len());
    let gpr = lin.groups_per_row();
    let stride = lin.row_stride;
    let aligned = lin.group % 4 == 0 && lin.cols % 4 == 0;
    let y0 = rows.start;
    for r in rows {
        let p1 = &lin.p1[r * stride..(r + 1) * stride];
        let p2 = &lin.p2[r * stride..(r + 1) * stride];
        let mut acc = 0.0f32;
        for g in 0..gpr {
            let start = g * lin.group;
            let end = (start + lin.group).min(lin.cols);
            let (s1, s2) = if aligned {
                plane_pair_sum_aligned(p1, p2, x, start, end)
            } else {
                plane_pair_sum_scalar(p1, p2, x, start, end)
            };
            let ai = r * gpr + g;
            acc += lin.alpha1[ai] * s1 + lin.alpha2[ai] * s2;
        }
        y_span[r - y0] = acc;
    }
}

/// Row-parallel [`gemv_packed`]: output rows are partitioned into
/// contiguous spans, one per pool lane; each row keeps its sequential
/// FP order, so the result is bit-identical to the sequential kernel
/// for any thread count. Falls back inline when the matrix's work is
/// below [`crate::threads::PAR_MIN_WORK`].
pub fn gemv_packed_par(lin: &PackedTernaryLinear, x: &[f32], y: &mut [f32], pool: &Pool) {
    assert_eq!(x.len(), lin.cols, "gemv dim mismatch");
    assert_eq!(y.len(), lin.rows);
    if pool.threads() <= 1 || !worth_parallel(lin.rows, lin.cols) {
        gemv_packed_rows(lin, x, 0..lin.rows, y);
        return;
    }
    run_spans(pool, lin.rows, 1, y, |_, rows, span| {
        gemv_packed_rows(lin, x, rows, span);
    });
}

/// Byte-aligned group: process 4 trits per byte per plane via the LUT.
#[inline]
fn plane_pair_sum_aligned(p1: &[u8], p2: &[u8], x: &[f32], start: usize, end: usize) -> (f32, f32) {
    let lut = decode_lut_f32();
    let mut s1 = 0.0f32;
    let mut s2 = 0.0f32;
    let b0 = start / 4;
    let b1 = end / 4;
    for b in b0..b1 {
        let d1 = &lut[p1[b] as usize];
        let d2 = &lut[p2[b] as usize];
        let xb = &x[b * 4..b * 4 + 4];
        s1 += d1[0] * xb[0] + d1[1] * xb[1] + d1[2] * xb[2] + d1[3] * xb[3];
        s2 += d2[0] * xb[0] + d2[1] * xb[1] + d2[2] * xb[2] + d2[3] * xb[3];
    }
    (s1, s2)
}

/// Decode one packed plane row to f32 trits (whole bytes via the LUT,
/// ragged tail per-trit). Produces exactly the values the packed gemv
/// sees, so kernels working from the decoded buffer stay bit-identical
/// to [`gemv_packed`] — the property the batched forward path relies on
/// (see `rust/DESIGN.md` §Batched-Forward).
pub(crate) fn decode_plane_row(p: &[u8], cols: usize, out: &mut [f32]) {
    debug_assert!(out.len() >= cols);
    let lut = decode_lut_f32();
    let full = cols / 4;
    for b in 0..full {
        out[b * 4..b * 4 + 4].copy_from_slice(&lut[p[b] as usize]);
    }
    for c in full * 4..cols {
        let sh = (c % 4) * 2;
        out[c] = dec2(p[c / 4] >> sh) as f32;
    }
}

/// Ragged fallback: per-trit decode.
#[inline]
fn plane_pair_sum_scalar(p1: &[u8], p2: &[u8], x: &[f32], start: usize, end: usize) -> (f32, f32) {
    let mut s1 = 0.0f32;
    let mut s2 = 0.0f32;
    for c in start..end {
        let sh = (c % 4) * 2;
        let t1 = dec2(p1[c / 4] >> sh);
        let t2 = dec2(p2[c / 4] >> sh);
        s1 += t1 as f32 * x[c];
        s2 += t2 as f32 * x[c];
    }
    (s1, s2)
}

/// Convenience allocating wrappers.
pub fn gemv(lin: &TernaryLinear, x: &[f32]) -> Vec<f32> {
    let mut y = vec![0.0; lin.rows];
    gemv_fused(lin, x, &mut y);
    y
}

pub fn gemv_packed_alloc(lin: &PackedTernaryLinear, x: &[f32]) -> Vec<f32> {
    let mut y = vec![0.0; lin.rows];
    gemv_packed(lin, x, &mut y);
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{check, prop_assert, Gen};
    use crate::rng::Rng;
    use crate::tensor::ops::matvec;

    fn random_linear(rows: usize, cols: usize, group: usize, seed: u64) -> TernaryLinear {
        let mut rng = Rng::new(seed);
        let mut lin = TernaryLinear::new(rows, cols, group);
        for t in lin.t1.trits.iter_mut().chain(lin.t2.trits.iter_mut()) {
            *t = rng.below(3) as i8 - 1;
        }
        for a in lin.alpha1.iter_mut().chain(lin.alpha2.iter_mut()) {
            *a = rng.normal() * 0.2;
        }
        lin
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!(
                (x - y).abs() < tol * (1.0 + x.abs()),
                "idx {i}: {x} vs {y}"
            );
        }
    }

    #[test]
    fn unpacked_matches_dense_reconstruction() {
        let mut rng = Rng::new(10);
        let lin = random_linear(13, 40, 8, 11);
        let x: Vec<f32> = (0..40).map(|_| rng.normal()).collect();
        let dense = matvec(&lin.reconstruct(), &x);
        let mut y = vec![0.0; 13];
        gemv_unpacked(&lin, &x, &mut y);
        assert_close(&y, &dense, 1e-4);
    }

    #[test]
    fn fused_matches_unpacked() {
        let mut rng = Rng::new(20);
        let lin = random_linear(7, 64, 16, 21);
        let x: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let mut a = vec![0.0; 7];
        let mut b = vec![0.0; 7];
        gemv_unpacked(&lin, &x, &mut a);
        gemv_fused(&lin, &x, &mut b);
        assert_close(&a, &b, 1e-5);
    }

    #[test]
    fn packed_matches_fused_aligned() {
        let mut rng = Rng::new(30);
        let lin = random_linear(9, 128, 32, 31);
        let packed = lin.to_packed();
        let x: Vec<f32> = (0..128).map(|_| rng.normal()).collect();
        let mut a = vec![0.0; 9];
        let mut b = vec![0.0; 9];
        gemv_fused(&lin, &x, &mut a);
        gemv_packed(&packed, &x, &mut b);
        assert_close(&a, &b, 1e-5);
    }

    #[test]
    fn packed_matches_fused_ragged() {
        let mut rng = Rng::new(40);
        // cols=37, group=10 → ragged groups and tail bits in the packing
        let lin = random_linear(5, 37, 10, 41);
        let packed = lin.to_packed();
        let x: Vec<f32> = (0..37).map(|_| rng.normal()).collect();
        let mut a = vec![0.0; 5];
        let mut b = vec![0.0; 5];
        gemv_fused(&lin, &x, &mut a);
        gemv_packed(&packed, &x, &mut b);
        assert_close(&a, &b, 1e-5);
    }

    #[test]
    fn zero_planes_give_zero_output() {
        let lin = TernaryLinear::new(4, 16, 4);
        let x = vec![1.0; 16];
        let y = gemv(&lin, &x);
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn parallel_gemv_bit_identical_for_any_thread_count() {
        let mut rng = Rng::new(70);
        // work above the PAR_MIN_WORK gate (parallel engages, aligned +
        // ragged packing) and below it (inline fallback)
        for (rows, cols, group) in [(600, 64, 32), (400, 96, 10), (9, 128, 32)] {
            let packed = random_linear(rows, cols, group, 71 + rows as u64).to_packed();
            let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
            let mut seq = vec![0.0; rows];
            gemv_packed(&packed, &x, &mut seq);
            for threads in [1usize, 2, 3, 4] {
                let pool = Pool::new(threads);
                let mut par = vec![0.0; rows];
                gemv_packed_par(&packed, &x, &mut par, &pool);
                assert_eq!(par, seq, "threads={threads} rows={rows} G={group}");
            }
        }
    }

    #[test]
    fn prop_all_kernels_agree() {
        check(60, |g: &mut Gen| {
            let rows = g.usize_in(1, 12);
            let cols = g.usize_in(1, 70);
            let group = *g.pick(&[4usize, 8, 10, 16, 128]);
            let seed = g.rng.next_u64();
            let lin = random_linear(rows, cols, group, seed);
            let x = g.vec_normal(cols, 1.0);
            let mut a = vec![0.0; rows];
            let mut b = vec![0.0; rows];
            let mut c = vec![0.0; rows];
            gemv_unpacked(&lin, &x, &mut a);
            gemv_fused(&lin, &x, &mut b);
            gemv_packed(&lin.to_packed(), &x, &mut c);
            for i in 0..rows {
                let tol = 1e-4 * (1.0 + a[i].abs());
                if (a[i] - b[i]).abs() > tol || (a[i] - c[i]).abs() > tol {
                    return Err(format!(
                        "kernel disagreement at row {i}: {} {} {} (rows={rows} cols={cols} G={group})",
                        a[i], b[i], c[i]
                    ));
                }
            }
            prop_assert(true, "")
        });
    }
}

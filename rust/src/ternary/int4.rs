//! 4-bit grouped-uniform packed linear — the GPTQ/AWQ *kernel* stand-in
//! for the Table 5 latency comparison (numerics are RTN-4; what's
//! benchmarked is the packed int4 decode + multiply inner loop).

use crate::tensor::Matrix;

/// 4-bit packed weights: codes 2-per-byte, per-(row, group) scale+zero.
#[derive(Clone, Debug)]
pub struct Int4Linear {
    pub rows: usize,
    pub cols: usize,
    pub group: usize,
    /// Packed codes, row-major, row stride = ceil(cols/2).
    pub codes: Vec<u8>,
    pub row_stride: usize,
    /// scale[row * gpr + g], zero likewise (dequant: (q - zero) * scale).
    pub scales: Vec<f32>,
    pub zeros: Vec<f32>,
}

impl Int4Linear {
    pub fn groups_per_row(&self) -> usize {
        self.cols.div_ceil(self.group)
    }

    /// Quantize a dense matrix to grouped int4.
    pub fn quantize(w: &Matrix, group: usize) -> Int4Linear {
        let group = if group == 0 { w.cols } else { group };
        let gpr = w.cols.div_ceil(group);
        let row_stride = w.cols.div_ceil(2);
        let mut lin = Int4Linear {
            rows: w.rows,
            cols: w.cols,
            group,
            codes: vec![0u8; w.rows * row_stride],
            row_stride,
            scales: vec![1.0; w.rows * gpr],
            zeros: vec![0.0; w.rows * gpr],
        };
        for r in 0..w.rows {
            for g in 0..gpr {
                let s = g * group;
                let e = (s + group).min(w.cols);
                let chunk = &w.row(r)[s..e];
                let (scale, zero) = crate::quant::grid_params(chunk, 4);
                lin.scales[r * gpr + g] = scale;
                lin.zeros[r * gpr + g] = zero;
                for (j, &x) in chunk.iter().enumerate() {
                    let q = ((x / scale + zero).round().clamp(0.0, 15.0)) as u8;
                    let c = s + j;
                    lin.codes[r * row_stride + c / 2] |= q << ((c % 2) * 4);
                }
            }
        }
        lin
    }

    /// Dense reconstruction (for correctness tests).
    pub fn reconstruct(&self) -> Matrix {
        let gpr = self.groups_per_row();
        Matrix::from_fn(self.rows, self.cols, |r, c| {
            let q = (self.codes[r * self.row_stride + c / 2] >> ((c % 2) * 4)) & 0xF;
            let gi = r * gpr + c / self.group;
            (q as f32 - self.zeros[gi]) * self.scales[gi]
        })
    }

    /// Packed int4 GEMV: y = W·x.
    pub fn gemv(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let gpr = self.groups_per_row();
        for r in 0..self.rows {
            let codes = &self.codes[r * self.row_stride..(r + 1) * self.row_stride];
            let mut acc = 0.0f32;
            for g in 0..gpr {
                let s = g * self.group;
                let e = (s + self.group).min(self.cols);
                let scale = self.scales[r * gpr + g];
                let zero = self.zeros[r * gpr + g];
                // Σ (q - z)·s·x = s·(Σ q·x) − s·z·(Σ x)
                let mut qx = 0.0f32;
                let mut xs = 0.0f32;
                for c in s..e {
                    let q = (codes[c / 2] >> ((c % 2) * 4)) & 0xF;
                    qx += q as f32 * x[c];
                    xs += x[c];
                }
                acc += scale * (qx - zero * xs);
            }
            y[r] = acc;
        }
    }

    /// Packed GEMM via per-row gemv.
    pub fn gemm(&self, x: &Matrix) -> Matrix {
        let mut y = Matrix::zeros(x.rows, self.rows);
        for r in 0..x.rows {
            let row = &mut y.data[r * self.rows..(r + 1) * self.rows];
            self.gemv(x.row(r), row);
        }
        y
    }

    pub fn resident_bytes(&self) -> usize {
        self.codes.len() + 4 * (self.scales.len() + self.zeros.len())
    }
}

/// AQLM-style 2×2-bit additive-codebook linear (Table 5's AQLM column).
/// Each weight is the sum of two codebook entries selected by 2-bit
/// codes; codebooks are per-(row, group). The gather-per-element inner
/// loop is what makes real AQLM kernels slow at prefill — preserved.
#[derive(Clone, Debug)]
pub struct Aqlm2x2Linear {
    pub rows: usize,
    pub cols: usize,
    pub group: usize,
    /// Two 2-bit code streams (packed 4/byte), each row-major.
    pub c1: Vec<u8>,
    pub c2: Vec<u8>,
    pub row_stride: usize,
    /// Codebooks: per-(row, group) 4 entries each.
    pub cb1: Vec<[f32; 4]>,
    pub cb2: Vec<[f32; 4]>,
}

impl Aqlm2x2Linear {
    pub fn groups_per_row(&self) -> usize {
        self.cols.div_ceil(self.group)
    }

    /// Greedy additive quantization: codebooks from quartile residuals.
    pub fn quantize(w: &Matrix, group: usize) -> Aqlm2x2Linear {
        let group = if group == 0 { w.cols } else { group };
        let gpr = w.cols.div_ceil(group);
        let row_stride = w.cols.div_ceil(4);
        let mut lin = Aqlm2x2Linear {
            rows: w.rows,
            cols: w.cols,
            group,
            c1: vec![0; w.rows * row_stride],
            c2: vec![0; w.rows * row_stride],
            row_stride,
            cb1: vec![[0.0; 4]; w.rows * gpr],
            cb2: vec![[0.0; 4]; w.rows * gpr],
        };
        for r in 0..w.rows {
            for g in 0..gpr {
                let s = g * group;
                let e = (s + group).min(w.cols);
                let chunk = &w.row(r)[s..e];
                let gi = r * gpr + g;
                // codebook 1: 4 quantile levels of the values
                let mut sorted: Vec<f32> = chunk.to_vec();
                sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let q = |p: f64| sorted[((sorted.len() - 1) as f64 * p) as usize];
                lin.cb1[gi] = [q(0.125), q(0.375), q(0.625), q(0.875)];
                // assign codes 1, compute residuals
                let mut resid = vec![0.0f32; chunk.len()];
                for (j, &x) in chunk.iter().enumerate() {
                    let (code, val) = nearest(&lin.cb1[gi], x);
                    let c = s + j;
                    lin.c1[r * row_stride + c / 4] |= code << ((c % 4) * 2);
                    resid[j] = x - val;
                }
                // codebook 2 on residuals
                let mut rs = resid.clone();
                rs.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let q2 = |p: f64| rs[((rs.len() - 1) as f64 * p) as usize];
                lin.cb2[gi] = [q2(0.125), q2(0.375), q2(0.625), q2(0.875)];
                for (j, &x) in resid.iter().enumerate() {
                    let (code, _) = nearest(&lin.cb2[gi], x);
                    let c = s + j;
                    lin.c2[r * row_stride + c / 4] |= code << ((c % 4) * 2);
                }
            }
        }
        lin
    }

    pub fn reconstruct(&self) -> Matrix {
        let gpr = self.groups_per_row();
        Matrix::from_fn(self.rows, self.cols, |r, c| {
            let gi = r * gpr + c / self.group;
            let k1 = (self.c1[r * self.row_stride + c / 4] >> ((c % 4) * 2)) & 0b11;
            let k2 = (self.c2[r * self.row_stride + c / 4] >> ((c % 4) * 2)) & 0b11;
            self.cb1[gi][k1 as usize] + self.cb2[gi][k2 as usize]
        })
    }

    /// GEMV with per-element double codebook gather (the AQLM cost model).
    pub fn gemv(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        let gpr = self.groups_per_row();
        for r in 0..self.rows {
            let mut acc = 0.0f32;
            for c in 0..self.cols {
                let gi = r * gpr + c / self.group;
                let byte = r * self.row_stride + c / 4;
                let sh = (c % 4) * 2;
                let k1 = (self.c1[byte] >> sh) & 0b11;
                let k2 = (self.c2[byte] >> sh) & 0b11;
                acc += (self.cb1[gi][k1 as usize] + self.cb2[gi][k2 as usize]) * x[c];
            }
            y[r] = acc;
        }
    }

    pub fn gemm(&self, x: &Matrix) -> Matrix {
        let mut y = Matrix::zeros(x.rows, self.rows);
        for r in 0..x.rows {
            let row = &mut y.data[r * self.rows..(r + 1) * self.rows];
            self.gemv(x.row(r), row);
        }
        y
    }
}

#[inline]
fn nearest(cb: &[f32; 4], x: f32) -> (u8, f32) {
    let mut best = 0u8;
    let mut bv = f32::INFINITY;
    for (i, &v) in cb.iter().enumerate() {
        let d = (x - v).abs();
        if d < bv {
            bv = d;
            best = i as u8;
        }
    }
    (best, cb[best as usize])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::ops::matvec;

    #[test]
    fn int4_gemv_matches_reconstruction() {
        let mut rng = Rng::new(1);
        let w = Matrix::rand_heavy(12, 64, 0.05, &mut rng);
        let lin = Int4Linear::quantize(&w, 32);
        let x: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let mut y = vec![0.0; 12];
        lin.gemv(&x, &mut y);
        let dense = matvec(&lin.reconstruct(), &x);
        for (a, b) in y.iter().zip(&dense) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn int4_reconstruction_close_to_original() {
        let mut rng = Rng::new(2);
        let w = Matrix::randn(8, 128, 0.05, &mut rng);
        let lin = Int4Linear::quantize(&w, 64);
        assert!(w.rel_err(&lin.reconstruct()) < 0.1);
    }

    #[test]
    fn int4_smaller_than_f32() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(64, 256, 0.05, &mut rng);
        let lin = Int4Linear::quantize(&w, 128);
        assert!(lin.resident_bytes() * 6 < w.len() * 4);
    }

    #[test]
    fn aqlm_gemv_matches_reconstruction() {
        let mut rng = Rng::new(4);
        let w = Matrix::rand_heavy(10, 64, 0.05, &mut rng);
        let lin = Aqlm2x2Linear::quantize(&w, 32);
        let x: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let mut y = vec![0.0; 10];
        lin.gemv(&x, &mut y);
        let dense = matvec(&lin.reconstruct(), &x);
        for (a, b) in y.iter().zip(&dense) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()));
        }
    }

    #[test]
    fn aqlm_reconstruction_reasonable() {
        let mut rng = Rng::new(5);
        let w = Matrix::randn(8, 128, 0.05, &mut rng);
        let lin = Aqlm2x2Linear::quantize(&w, 64);
        let rel = w.rel_err(&lin.reconstruct());
        assert!(rel < 0.5, "rel {rel}");
    }

    #[test]
    fn ragged_cols_handled() {
        let mut rng = Rng::new(6);
        let w = Matrix::randn(4, 37, 0.05, &mut rng);
        let i4 = Int4Linear::quantize(&w, 16);
        let aq = Aqlm2x2Linear::quantize(&w, 16);
        assert_eq!(i4.reconstruct().cols, 37);
        assert_eq!(aq.reconstruct().cols, 37);
    }
}

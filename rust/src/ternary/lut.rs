//! Activation-indexed lookup-table kernels — the fifth kernel tier
//! (DESIGN.md §LUT-Kernels), plus the one shared byte-decode LUT used
//! by pack, gemv, and this tier.
//!
//! The packed tiers spend 2 FMAs *per trit per plane*: decode a byte to
//! four f32 trits, multiply each against its activation, accumulate.
//! But within one 4-column chunk there are only 3⁴ = 81 distinct trit
//! patterns (≤ 256 byte codes), while every projection in the model has
//! 64–1024 output rows reading the *same* activation chunk. So, in the
//! spirit of bitnet.cpp / T-MAC, we precompute per chunk a 256-entry
//! table
//!
//! ```text
//! lut[b] = d₀(b)·x₀ + d₁(b)·x₁ + d₂(b)·x₂ + d₃(b)·x₃
//! ```
//!
//! once per activation vector, and the inner loop collapses to **one
//! table load + one add per byte per plane** — the 2-bit packing turned
//! from a memory format into a compute shortcut. The build amortizes
//! whenever output rows ≳ [`LUT_MIN_ROWS`].
//!
//! **Bit-identity invariant**: each table entry is produced by the
//! exact left-fold `((d₀·x₀ + d₁·x₁) + d₂·x₂) + d₃·x₃` that
//! `gemv::plane_pair_sum_aligned` evaluates per byte, and the per-group
//! byte loop and α epilogue mirror [`gemv_packed`] line for line — so
//! LUT outputs are `==` (bitwise) to the packed tier, which is what
//! lets the model dispatch between tiers freely without perturbing any
//! served token. Ragged layouts (`G % 4 != 0` or `cols % 4 != 0`) stay
//! on the packed tier's scalar path; see [`is_aligned`].
//!
//! [`gemv_packed`]: super::gemv::gemv_packed

use super::gemm::GemmScratch;
use super::linear::PackedTernaryLinear;
use super::pack::dec2;
use super::simd;
use crate::tensor::Matrix;
use crate::threads::{run_spans, worth_parallel, Pool, SendPtr};
use std::sync::OnceLock;

/// Minimum output rows before the table build amortizes over the row
/// sweep (~340 flops of build per chunk vs ~14 flops saved per row).
pub const LUT_MIN_ROWS: usize = 64;

/// The one 256-entry byte → 4-trit decode table (i8 form), shared by
/// every consumer that used to build its own copy.
pub fn decode_lut_i8() -> &'static [[i8; 4]; 256] {
    static LUT: OnceLock<Box<[[i8; 4]; 256]>> = OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = Box::new([[0i8; 4]; 256]);
        for (b, entry) in t.iter_mut().enumerate() {
            let byte = b as u8;
            *entry = [dec2(byte), dec2(byte >> 2), dec2(byte >> 4), dec2(byte >> 6)];
        }
        t
    })
}

/// f32 view of the decode table (4 KiB, L1-resident) for the FMA-style
/// kernels that multiply trits as {-1.0, 0.0, 1.0} factors.
pub fn decode_lut_f32() -> &'static [[f32; 4]; 256] {
    static LUT: OnceLock<Box<[[f32; 4]; 256]>> = OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = Box::new([[0.0f32; 4]; 256]);
        for (b, entry) in t.iter_mut().enumerate() {
            let d = decode_lut_i8()[b];
            *entry = [d[0] as f32, d[1] as f32, d[2] as f32, d[3] as f32];
        }
        t
    })
}

/// True when every group spans whole packed bytes, which the LUT tier
/// (and the packed tier's fast path) requires.
pub fn is_aligned(lin: &PackedTernaryLinear) -> bool {
    lin.group % 4 == 0 && lin.cols % 4 == 0
}

/// Build the per-chunk activation tables for one activation vector
/// (`x.len() % 4 == 0`): `table[c*256 + b]` is chunk `c`'s partial sum
/// for byte code `b`. The buffer is caller-owned scratch, reused across
/// calls without reallocation.
pub fn fill_tables(x: &[f32], table: &mut Vec<f32>) {
    debug_assert_eq!(x.len() % 4, 0, "LUT tier requires 4-aligned activations");
    let chunks = x.len() / 4;
    table.resize(chunks * 256, 0.0);
    for (xc, seg) in x.chunks_exact(4).zip(table.chunks_exact_mut(256)) {
        fill_chunk(xc, seg);
    }
}

/// Fill one 256-entry chunk table by left-fold dynamic programming:
/// level `t` extends every level-`t-1` prefix with trit `t`'s
/// contribution, appended at the end of the fold — i.e. entry `b`
/// is computed as exactly `((d₀·x₀ + d₁·x₁) + d₂·x₂) + d₃·x₃`, the
/// association `plane_pair_sum_aligned` uses, so downstream sums are
/// bit-identical to the packed tier. ~4·(4 + 16 + 64) adds per chunk
/// instead of 256·7 for the direct build.
#[inline]
fn fill_chunk(x: &[f32], seg: &mut [f32]) {
    // 2-bit code → trit factor, matching `pack::dec2` (0b11 → 0).
    const DEC: [f32; 4] = [0.0, 1.0, -1.0, 0.0];
    debug_assert_eq!(x.len(), 4);
    debug_assert_eq!(seg.len(), 256);
    for (code, slot) in seg.iter_mut().enumerate().take(4) {
        *slot = DEC[code] * x[0];
    }
    for trit in 1..4 {
        let width = 1usize << (2 * trit); // 4^trit entries already valid
        // high codes first so the level-(t-1) prefix at [0, width) is
        // still intact when code 0 finally overwrites it in place
        for code in (0..4usize).rev() {
            let add = DEC[code] * x[trit];
            let base = code * width;
            for lo in 0..width {
                seg[base + lo] = seg[lo] + add;
            }
        }
    }
}

/// Core row sweep: compute output rows `rows` into `y_span`
/// (`y_span[i]` = row `rows.start + i`). Group loop and α epilogue
/// mirror `gemv_packed` exactly; the per-byte body is one table load +
/// add per plane. Shared with the SIMD tier, which uses it for ragged
/// tail rows (`rows % lanes`).
pub(crate) fn lut_rows_span(
    lin: &PackedTernaryLinear,
    table: &[f32],
    rows: std::ops::Range<usize>,
    y_span: &mut [f32],
) {
    debug_assert_eq!(y_span.len(), rows.len());
    let gpr = lin.groups_per_row();
    let stride = lin.row_stride;
    let y0 = rows.start;
    for r in rows {
        let p1 = &lin.p1[r * stride..(r + 1) * stride];
        let p2 = &lin.p2[r * stride..(r + 1) * stride];
        let mut acc = 0.0f32;
        for g in 0..gpr {
            let start = g * lin.group;
            let end = (start + lin.group).min(lin.cols);
            let mut s1 = 0.0f32;
            let mut s2 = 0.0f32;
            for b in start / 4..end / 4 {
                let seg = &table[b * 256..b * 256 + 256];
                s1 += seg[p1[b] as usize];
                s2 += seg[p2[b] as usize];
            }
            let ai = r * gpr + g;
            acc += lin.alpha1[ai] * s1 + lin.alpha2[ai] * s2;
        }
        y_span[r - y0] = acc;
    }
}

/// Sequential LUT gemv over a caller-owned table buffer. Panics on
/// ragged layouts — dispatchers gate on [`is_aligned`].
pub fn gemv_lut(lin: &PackedTernaryLinear, x: &[f32], y: &mut [f32], table: &mut Vec<f32>) {
    assert!(is_aligned(lin), "gemv_lut requires byte-aligned groups");
    assert_eq!(x.len(), lin.cols, "gemv dim mismatch");
    assert_eq!(y.len(), lin.rows);
    fill_tables(x, table);
    lut_rows_span(lin, table, 0..lin.rows, y);
}

/// Partition one output vector's rows across the pool's lanes; each
/// lane writes its contiguous disjoint span with the sequential sweep,
/// so output is bit-identical to [`gemv_lut`] for any lane count.
fn lut_row_par(lin: &PackedTernaryLinear, table: &[f32], y_row: &mut [f32], pool: &Pool) {
    run_spans(pool, lin.rows, 1, y_row, |_, rows, span| {
        lut_rows_span(lin, table, rows, span);
    });
}

/// Pool-aware LUT gemv over engine scratch (decode path). Builds the
/// table once on the leader, then row-partitions the sweep. When the
/// scratch has SIMD enabled and the layer carries an interleaved
/// layout, the sweep runs on the SIMD row-block tier — bit-identical
/// by construction (DESIGN.md §SIMD-Kernels), so the choice is purely
/// a speed policy.
pub fn gemv_lut_into(lin: &PackedTernaryLinear, x: &[f32], y: &mut [f32], scratch: &mut GemmScratch) {
    assert!(is_aligned(lin), "gemv_lut requires byte-aligned groups");
    assert_eq!(x.len(), lin.cols, "gemv dim mismatch");
    assert_eq!(y.len(), lin.rows);
    let pool = scratch.pool.clone();
    let lanes = pool.threads();
    let il = if scratch.simd {
        lin.interleave.as_deref()
    } else {
        None
    };
    scratch.ensure_lanes(lanes);
    let table = &mut scratch.lut_tables[0];
    fill_tables(x, table);
    if let Some(il) = il {
        simd::lut_sweep(lin, il, table, y, &pool);
    } else if lanes <= 1 || !worth_parallel(lin.rows, lin.cols) {
        lut_rows_span(lin, table, 0..lin.rows, y);
    } else {
        lut_row_par(lin, table, y, &pool);
    }
}

/// Pool-aware LUT gemm `Y = X · Ŵᵀ` (prefill / batched serving path).
/// Every output element carries `gemv_packed`'s exact FP order, so this
/// is bit-identical per row to the packed tiers. Parallel split: by X
/// row when the batch is deep enough (each lane builds its own tables),
/// else by output channel.
pub fn gemm_lut_into(lin: &PackedTernaryLinear, x: &Matrix, y: &mut Matrix, scratch: &mut GemmScratch) {
    assert!(is_aligned(lin), "gemm_lut requires byte-aligned groups");
    assert_eq!(x.cols, lin.cols, "gemm inner dim mismatch");
    assert_eq!(y.rows, x.rows, "gemm out rows mismatch");
    assert_eq!(y.cols, lin.rows, "gemm out cols mismatch");
    let pool = scratch.pool.clone();
    let lanes = pool.threads();
    let il = if scratch.simd {
        lin.interleave.as_deref()
    } else {
        None
    };
    scratch.ensure_lanes(lanes);
    if lanes > 1 && x.rows >= lanes && worth_parallel(x.rows * lin.rows, lin.cols) {
        // deep batch: lanes own disjoint X-row spans end to end
        let tables = SendPtr(scratch.lut_tables.as_mut_ptr());
        let n_out = lin.rows;
        run_spans(&pool, x.rows, n_out, &mut y.data, |lane, rows, span| {
            // SAFETY: one table buffer per lane (ensure_lanes sized the
            // vec), alive past `run` because the leader blocks in it.
            let table = unsafe { &mut *tables.get().add(lane) };
            for (i, r) in rows.enumerate() {
                fill_tables(x.row(r), table);
                let out = &mut span[i * n_out..(i + 1) * n_out];
                match il {
                    Some(il) => simd::lut_rows_all(lin, il, table, out),
                    None => lut_rows_span(lin, table, 0..n_out, out),
                }
            }
        });
        return;
    }
    // shallow batch: per X row, build once and split output channels
    let table = &mut scratch.lut_tables[0];
    for r in 0..x.rows {
        fill_tables(x.row(r), table);
        let row = &mut y.data[r * lin.rows..(r + 1) * lin.rows];
        if let Some(il) = il {
            simd::lut_sweep(lin, il, table, row, &pool);
        } else if lanes <= 1 || !worth_parallel(lin.rows, lin.cols) {
            lut_rows_span(lin, table, 0..lin.rows, row);
        } else {
            lut_row_par(lin, table, row, &pool);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workload::random_ternary as random_linear;
    use crate::proptest::{check, prop_assert, Gen};
    use crate::rng::Rng;
    use crate::ternary::gemm::{gemm_packed_blocked, GemmScratch};
    use crate::ternary::gemv::gemv_packed;
    use crate::ternary::linear::TernaryLinear;

    #[test]
    fn shared_decode_lut_matches_scalar_decode() {
        let i8lut = decode_lut_i8();
        let f32lut = decode_lut_f32();
        for b in 0u16..256 {
            let b = b as u8;
            let expect = [dec2(b), dec2(b >> 2), dec2(b >> 4), dec2(b >> 6)];
            assert_eq!(i8lut[b as usize], expect);
            for (got, want) in f32lut[b as usize].iter().zip(expect.iter()) {
                assert_eq!(*got, *want as f32);
            }
        }
    }

    #[test]
    fn chunk_table_matches_direct_expression() {
        // DP build must equal the packed tier's per-byte left fold bitwise
        let lutf = decode_lut_f32();
        let mut rng = Rng::new(3);
        for case in 0..50 {
            let x: [f32; 4] = if case == 0 {
                [0.0, -0.0, 1.5, -2.25]
            } else {
                [rng.normal(), rng.normal(), rng.normal() * 100.0, rng.normal() * 1e-3]
            };
            let mut seg = vec![0.0f32; 256];
            fill_chunk(&x, &mut seg);
            for (b, (got, d)) in seg.iter().zip(lutf.iter()).enumerate() {
                let direct = d[0] * x[0] + d[1] * x[1] + d[2] * x[2] + d[3] * x[3];
                assert_eq!(got.to_bits(), direct.to_bits(), "byte {b} x={x:?}");
            }
        }
    }

    #[test]
    fn gemv_lut_bit_identical_to_gemv_packed() {
        let mut rng = Rng::new(7);
        let mut table = Vec::new();
        for (rows, cols, group) in [(9, 128, 32), (64, 64, 128), (3, 16, 4), (130, 48, 8)] {
            let packed = random_linear(rows, cols, group, 70 + rows as u64).to_packed();
            let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
            let mut a = vec![0.0; rows];
            let mut b = vec![0.0; rows];
            gemv_packed(&packed, &x, &mut a);
            gemv_lut(&packed, &x, &mut b, &mut table);
            assert_eq!(a, b, "rows={rows} cols={cols} G={group}");
        }
    }

    #[test]
    fn zero_planes_give_zero_output() {
        let packed = TernaryLinear::new(8, 16, 4).to_packed();
        let x = vec![1.0f32; 16];
        let mut y = vec![9.0f32; 8];
        gemv_lut(&packed, &x, &mut y, &mut Vec::new());
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn threaded_gemv_lut_bit_identical_to_sequential() {
        // 360×96 clears the PAR_MIN_WORK dispatch gate
        let mut rng = Rng::new(11);
        let packed = random_linear(360, 96, 32, 12).to_packed();
        let x: Vec<f32> = (0..96).map(|_| rng.normal()).collect();
        let mut seq = vec![0.0; 360];
        gemv_lut(&packed, &x, &mut seq, &mut Vec::new());
        for threads in [1usize, 2, 3, 5] {
            let mut scratch = GemmScratch::new();
            scratch.pool = Pool::new(threads);
            let mut par = vec![0.0; 360];
            gemv_lut_into(&packed, &x, &mut par, &mut scratch);
            assert_eq!(par, seq, "threads={threads}");
        }
    }

    #[test]
    fn gemm_lut_bit_identical_to_blocked_and_gemv() {
        // covers the inline fallback (small), the shallow channel-split
        // (m=1, work over the gate), and the deep X-row split (m=40)
        let mut rng = Rng::new(13);
        for (rows, cols, group, m) in [(10, 64, 32, 5), (1040, 32, 4, 1), (65, 48, 12, 40)] {
            let packed = random_linear(rows, cols, group, 50 + m as u64).to_packed();
            let x = Matrix::randn(m, cols, 1.0, &mut rng);
            let blocked = gemm_packed_blocked(&packed, &x);
            for threads in [1usize, 2, 4] {
                let mut scratch = GemmScratch::new();
                scratch.pool = Pool::new(threads);
                let mut y = Matrix::zeros(m, rows);
                gemm_lut_into(&packed, &x, &mut y, &mut scratch);
                assert_eq!(y.data, blocked.data, "threads={threads} m={m} rows={rows}");
            }
            for r in 0..m {
                let mut yv = vec![0.0; rows];
                gemv_packed(&packed, x.row(r), &mut yv);
                assert_eq!(&blocked.data[r * rows..(r + 1) * rows], yv.as_slice());
            }
        }
    }

    #[test]
    fn prop_lut_tier_always_bit_identical() {
        check(60, |g: &mut Gen| {
            let rows = g.usize_in(1, 150);
            let cols = 4 * g.usize_in(1, 24);
            let group = 4 * *g.pick(&[1usize, 2, 4, 8, 32]);
            let seed = g.rng.next_u64();
            let packed = random_linear(rows, cols, group, seed).to_packed();
            let x = g.vec_normal(cols, 1.0);
            let mut a = vec![0.0; rows];
            let mut b = vec![0.0; rows];
            gemv_packed(&packed, &x, &mut a);
            gemv_lut(&packed, &x, &mut b, &mut Vec::new());
            prop_assert(
                a == b,
                format!("LUT/packed drift (rows={rows} cols={cols} G={group})"),
            )
        });
    }
}

//! SIMD ternary kernel tier — row-vectorized LUT / packed kernels over
//! a row-interleaved plane layout (DESIGN.md §SIMD-Kernels).
//!
//! The scalar tiers compute one output row at a time; every projection
//! in the model has 64–1024 output rows reading the *same* activation
//! chunk, so the natural SIMD axis is **across consecutive output
//! rows**: N lanes = N consecutive rows sharing one activation-chunk
//! table load (LUT tier) or one decoded activation chunk (packed tier).
//! Each lane performs the exact per-row left-fold operation order of
//! the scalar kernel — lanewise IEEE adds/muls are the same operations
//! the scalar kernel issues, in the same order — so SIMD output is
//! **bitwise `==`** to the scalar tiers for any dispatch decision, and
//! the dispatcher stays free to pick purely on speed.
//!
//! Implementations, runtime-selected:
//! * **AVX2** (x86/x86_64, `is_x86_feature_detected!("avx2")`) — 8-lane
//!   f32 with `vpgatherdps` table gathers; the interleaved layout makes
//!   the 8 plane-byte loads one contiguous 64-bit load.
//! * **4-lane portable** — `[f32; 4]` row-block kernels with the exact
//!   scalar fold per lane; on x86_64 the SSE2 baseline vectorizes the
//!   adds/muls, on aarch64 the NEON baseline does. This is also the
//!   safe scalar fallback: it compiles and is bit-exact on any arch.
//!
//! Lane loads are made contiguous by [`InterleavedPlanes`]: blocks of N
//! rows with their plane bytes interleaved byte-by-byte (and their
//! group scales lane-interleaved), built once at pack / checkpoint-load
//! time. Ragged layouts (`G % 4 != 0` or `cols % 4 != 0`) and tail rows
//! (`rows % N`) stay on the flat layout and the scalar kernels.
//!
//! Mode resolution: `--simd auto|on|off` (CLI, [`set_mode`]) >
//! `PTQTP_SIMD` env > `auto`. `off` is an exact escape hatch: no
//! interleave is built and every dispatcher takes the scalar tiers —
//! output is identical either way, so the knob is perf-only.

use super::linear::PackedTernaryLinear;
use super::lut::decode_lut_f32;
use crate::tensor::Matrix;
use crate::threads::{run_spans, worth_parallel, Pool};
use std::ops::Range;
use std::sync::OnceLock;

/// Process-wide SIMD policy (see module docs for resolution order).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// Use the best detected tier (the default).
    Auto,
    /// Explicit affirm — same tier selection as `Auto`, recorded so
    /// benches/logs can show the operator forced it on.
    On,
    /// Exact escape hatch: no interleave built, scalar tiers only.
    Off,
}

impl SimdMode {
    /// Parse a CLI/env value. Empty means unset (`Auto`).
    pub fn parse(s: &str) -> Option<SimdMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "" | "auto" => Some(SimdMode::Auto),
            "on" | "1" | "true" | "force" => Some(SimdMode::On),
            "off" | "0" | "false" => Some(SimdMode::Off),
            _ => None,
        }
    }
}

static MODE: OnceLock<SimdMode> = OnceLock::new();

/// Pin the process-wide mode (the CLI calls this for `--simd` before
/// any packed layer is built). First caller wins; later calls are
/// no-ops so tests cannot race the CLI.
pub fn set_mode(m: SimdMode) {
    let _ = MODE.set(m);
}

/// Resolved mode: pinned value, else `PTQTP_SIMD`, else `Auto`.
pub fn mode() -> SimdMode {
    *MODE.get_or_init(|| {
        std::env::var("PTQTP_SIMD")
            .ok()
            .and_then(|v| SimdMode::parse(&v))
            .unwrap_or(SimdMode::Auto)
    })
}

/// True unless the mode is the `off` escape hatch.
pub fn enabled() -> bool {
    mode() != SimdMode::Off
}

/// True when the 8-lane AVX2 kernels can run on this machine.
pub fn avx2_available() -> bool {
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    {
        if is_x86_feature_detected!("avx2") {
            return true;
        }
    }
    false
}

/// Lane width [`PackedTernaryLinear::ensure_interleave`] builds for on
/// this machine: 8 with AVX2, else the portable 4.
pub fn detected_lanes() -> usize {
    if avx2_available() { 8 } else { 4 }
}

/// Effective kernel lane width for a per-scratch SIMD flag: the
/// detected width when the flag is on, scalar (1) otherwise. Shared by
/// the ternary dispatchers and the attention tier
/// (`model::attn_kernels`) so every kernel family resolves the flag
/// the same way.
pub fn lanes_for(simd_flag: bool) -> usize {
    if simd_flag {
        detected_lanes()
    } else {
        1
    }
}

/// Human name of the active kernel tier (dispatch table in
/// DESIGN.md §SIMD-Kernels).
pub fn tier_name() -> &'static str {
    if avx2_available() {
        "avx2"
    } else if cfg!(target_arch = "aarch64") {
        "neon"
    } else if cfg!(any(target_arch = "x86", target_arch = "x86_64")) {
        "sse2"
    } else {
        "scalar4"
    }
}

/// Tier label honoring the mode ("off" when disabled) — what serve
/// logs and bench JSON print.
pub fn label() -> &'static str {
    if enabled() { tier_name() } else { "off" }
}

/// Detected CPU features relevant to the kernel tiers, most capable
/// first — stamped into `BENCH_kernels.json` so baselines are
/// interpretable across machines.
pub fn cpu_features() -> Vec<&'static str> {
    let mut f = Vec::new();
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    {
        if is_x86_feature_detected!("avx512f") {
            f.push("avx512f");
        }
        if is_x86_feature_detected!("avx2") {
            f.push("avx2");
        }
        if is_x86_feature_detected!("avx") {
            f.push("avx");
        }
        if is_x86_feature_detected!("sse4.2") {
            f.push("sse4.2");
        }
        if is_x86_feature_detected!("sse2") {
            f.push("sse2");
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        f.push("neon");
    }
    if f.is_empty() {
        f.push("scalar");
    }
    f
}

/// Row-interleaved copy of a packed layer's planes + scales for the
/// row-block kernels. Rows are grouped into `blocks` of `lanes`
/// consecutive rows; within a block:
///
/// * plane byte `b` of lanes `0..N` is stored contiguously at
///   `p[(block·stride + b)·N + lane]` — one contiguous N-byte load
///   replaces N row-strided loads;
/// * group scale `g` interleaves as `a[(block·gpr + g)·N + lane]`.
///
/// This is **derived** data (a second copy of the 2-bit planes): any
/// direct mutation of the flat planes/scales must be followed by
/// [`PackedTernaryLinear::refresh_interleave`]. Tail rows
/// (`rows % lanes`) have no interleaved form and always take the
/// scalar kernels.
#[derive(Clone, Debug)]
pub struct InterleavedPlanes {
    /// Rows per block (SIMD width): 8 (AVX2) or 4 (portable).
    pub lanes: usize,
    /// Full blocks (`rows / lanes`).
    pub blocks: usize,
    pub p1: Vec<u8>,
    pub p2: Vec<u8>,
    pub a1: Vec<f32>,
    pub a2: Vec<f32>,
}

/// Build the interleaved layout, or `None` when it cannot help: ragged
/// group/column packing (the SIMD tier requires the byte-aligned fast
/// path), fewer rows than one block, or an unsupported lane width.
pub fn build_interleave(lin: &PackedTernaryLinear, lanes: usize) -> Option<InterleavedPlanes> {
    if !(lanes == 4 || lanes == 8) || !super::lut::is_aligned(lin) || lin.rows < lanes {
        return None;
    }
    let stride = lin.row_stride;
    let gpr = lin.groups_per_row();
    let blocks = lin.rows / lanes;
    let mut p1 = vec![0u8; blocks * stride * lanes];
    let mut p2 = vec![0u8; blocks * stride * lanes];
    let mut a1 = vec![0.0f32; blocks * gpr * lanes];
    let mut a2 = vec![0.0f32; blocks * gpr * lanes];
    for k in 0..blocks {
        for l in 0..lanes {
            let r = k * lanes + l;
            let src1 = &lin.p1[r * stride..(r + 1) * stride];
            let src2 = &lin.p2[r * stride..(r + 1) * stride];
            for (b, (&v1, &v2)) in src1.iter().zip(src2).enumerate() {
                p1[(k * stride + b) * lanes + l] = v1;
                p2[(k * stride + b) * lanes + l] = v2;
            }
            for g in 0..gpr {
                a1[(k * gpr + g) * lanes + l] = lin.alpha1[r * gpr + g];
                a2[(k * gpr + g) * lanes + l] = lin.alpha2[r * gpr + g];
            }
        }
    }
    Some(InterleavedPlanes {
        lanes,
        blocks,
        p1,
        p2,
        a1,
        a2,
    })
}

// ---------------------------------------------------------------------
// LUT-tier row-block kernels (activation-indexed tables)
// ---------------------------------------------------------------------

/// One N-row block of the LUT sweep, portable form: per lane the exact
/// group loop / byte fold / α epilogue of `lut::lut_rows_span`, so each
/// lane's output is bitwise the scalar row.
#[allow(clippy::too_many_arguments)]
fn lut_block_portable<const N: usize>(
    table: &[f32],
    p1: &[u8],
    p2: &[u8],
    a1: &[f32],
    a2: &[f32],
    group: usize,
    cols: usize,
    out: &mut [f32],
) {
    let gpr = cols.div_ceil(group);
    let mut acc = [0.0f32; N];
    for g in 0..gpr {
        let start = g * group;
        let end = (start + group).min(cols);
        let mut s1 = [0.0f32; N];
        let mut s2 = [0.0f32; N];
        for b in start / 4..end / 4 {
            let seg = &table[b * 256..b * 256 + 256];
            let q1 = &p1[b * N..b * N + N];
            let q2 = &p2[b * N..b * N + N];
            for (s, &q) in s1.iter_mut().zip(q1) {
                *s += seg[q as usize];
            }
            for (s, &q) in s2.iter_mut().zip(q2) {
                *s += seg[q as usize];
            }
        }
        let ga1 = &a1[g * N..g * N + N];
        let ga2 = &a2[g * N..g * N + N];
        for l in 0..N {
            acc[l] += ga1[l] * s1[l] + ga2[l] * s2[l];
        }
    }
    out.copy_from_slice(&acc);
}

/// One N-row block of the packed sweep, portable form: per lane the
/// exact per-byte 4-wide fold of `gemv::plane_pair_sum_aligned`.
#[allow(clippy::too_many_arguments)]
fn packed_block_portable<const N: usize>(
    x: &[f32],
    p1: &[u8],
    p2: &[u8],
    a1: &[f32],
    a2: &[f32],
    group: usize,
    cols: usize,
    out: &mut [f32],
) {
    let lutf = decode_lut_f32();
    let gpr = cols.div_ceil(group);
    let mut acc = [0.0f32; N];
    for g in 0..gpr {
        let start = g * group;
        let end = (start + group).min(cols);
        let mut s1 = [0.0f32; N];
        let mut s2 = [0.0f32; N];
        for b in start / 4..end / 4 {
            let q1 = &p1[b * N..b * N + N];
            let q2 = &p2[b * N..b * N + N];
            let xb = &x[b * 4..b * 4 + 4];
            for (s, &q) in s1.iter_mut().zip(q1) {
                let d = &lutf[q as usize];
                *s += d[0] * xb[0] + d[1] * xb[1] + d[2] * xb[2] + d[3] * xb[3];
            }
            for (s, &q) in s2.iter_mut().zip(q2) {
                let d = &lutf[q as usize];
                *s += d[0] * xb[0] + d[1] * xb[1] + d[2] * xb[2] + d[3] * xb[3];
            }
        }
        let ga1 = &a1[g * N..g * N + N];
        let ga2 = &a2[g * N..g * N + N];
        for l in 0..N {
            acc[l] += ga1[l] * s1[l] + ga2[l] * s2[l];
        }
    }
    out.copy_from_slice(&acc);
}

/// One N-row block of the int8 sweep, portable form: per lane the
/// exact i32 group sums plus the fixed rescale epilogue of
/// `int_act::int_rows_span`. The integer sums need no fold-order
/// argument; the epilogue is evaluated lanewise in the scalar order.
#[allow(clippy::too_many_arguments)]
fn int_block_portable<const N: usize>(
    tables: &[i32],
    scales: &[f32],
    p1: &[u8],
    p2: &[u8],
    a1: &[f32],
    a2: &[f32],
    group: usize,
    cols: usize,
    out: &mut [f32],
) {
    let gpr = cols.div_ceil(group);
    let mut acc = [0.0f32; N];
    for g in 0..gpr {
        let start = g * group;
        let end = (start + group).min(cols);
        let mut s1 = [0i32; N];
        let mut s2 = [0i32; N];
        for b in start / 4..end / 4 {
            let seg = &tables[b * 256..b * 256 + 256];
            let q1 = &p1[b * N..b * N + N];
            let q2 = &p2[b * N..b * N + N];
            for (s, &q) in s1.iter_mut().zip(q1) {
                *s += seg[q as usize];
            }
            for (s, &q) in s2.iter_mut().zip(q2) {
                *s += seg[q as usize];
            }
        }
        let ga1 = &a1[g * N..g * N + N];
        let ga2 = &a2[g * N..g * N + N];
        let sc = scales[g];
        for l in 0..N {
            acc[l] += sc * (ga1[l] * s1[l] as f32 + ga2[l] * s2[l] as f32);
        }
    }
    out.copy_from_slice(&acc);
}

#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
mod x86 {
    //! 8-lane AVX2 row-block kernels. Bit-identity argument: every
    //! vector op here is the lanewise IEEE operation the scalar kernel
    //! issues (`vaddps`/`vmulps`, no FMA contraction — Rust never
    //! contracts), gathers load exact table bits, and the loop order is
    //! byte-for-byte the scalar order — so each lane reproduces the
    //! scalar row exactly.
    #[cfg(target_arch = "x86")]
    use core::arch::x86::*;
    #[cfg(target_arch = "x86_64")]
    use core::arch::x86_64::*;

    /// 8 interleaved plane bytes → 8 zero-extended i32 gather indices.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn load_indices(p: *const u8) -> __m256i {
        _mm256_cvtepu8_epi32(_mm_loadl_epi64(p as *const __m128i))
    }

    /// LUT-tier block: one gather + add per byte per plane.
    ///
    /// Safety: caller must have verified AVX2; `p1`/`p2` hold
    /// `(cols/4)·8` interleaved bytes, `a1`/`a2` hold `gpr·8`
    /// interleaved scales, `table` holds `(cols/4)·256` entries,
    /// `out` holds 8 rows.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn lut_block8(
        table: &[f32],
        p1: &[u8],
        p2: &[u8],
        a1: &[f32],
        a2: &[f32],
        group: usize,
        cols: usize,
        out: &mut [f32],
    ) {
        let gpr = cols.div_ceil(group);
        let mut acc = _mm256_setzero_ps();
        for g in 0..gpr {
            let start = g * group;
            let end = (start + group).min(cols);
            let mut s1 = _mm256_setzero_ps();
            let mut s2 = _mm256_setzero_ps();
            for b in start / 4..end / 4 {
                let seg = table.as_ptr().add(b * 256);
                let i1 = load_indices(p1.as_ptr().add(b * 8));
                let i2 = load_indices(p2.as_ptr().add(b * 8));
                s1 = _mm256_add_ps(s1, _mm256_i32gather_ps::<4>(seg, i1));
                s2 = _mm256_add_ps(s2, _mm256_i32gather_ps::<4>(seg, i2));
            }
            let va1 = _mm256_loadu_ps(a1.as_ptr().add(g * 8));
            let va2 = _mm256_loadu_ps(a2.as_ptr().add(g * 8));
            acc = _mm256_add_ps(
                acc,
                _mm256_add_ps(_mm256_mul_ps(va1, s1), _mm256_mul_ps(va2, s2)),
            );
        }
        _mm256_storeu_ps(out.as_mut_ptr(), acc);
    }

    /// Int8-tier block: one i32 gather + integer add per byte per
    /// plane (exact — no ordering argument needed), then per group the
    /// lanewise rescale `acc += sc·(α₁·s₁ + α₂·s₂)` in the scalar
    /// epilogue's operation order. `_mm256_cvtepi32_ps` is exact for
    /// |s| < 2²⁴, which holds up to ~132 K columns per group
    /// (DESIGN.md §Integer-Kernels). The tables store i16-range values
    /// as i32 because AVX2 has no 16-bit gather.
    ///
    /// Safety: caller must have verified AVX2; `p1`/`p2` hold
    /// `(cols/4)·8` interleaved bytes, `a1`/`a2` hold `gpr·8`
    /// interleaved scales, `tables` holds `(cols/4)·256` i32 entries,
    /// `scales` holds `gpr` activation scales, `out` holds 8 rows.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn int_block8(
        tables: &[i32],
        scales: &[f32],
        p1: &[u8],
        p2: &[u8],
        a1: &[f32],
        a2: &[f32],
        group: usize,
        cols: usize,
        out: &mut [f32],
    ) {
        let gpr = cols.div_ceil(group);
        let mut acc = _mm256_setzero_ps();
        for g in 0..gpr {
            let start = g * group;
            let end = (start + group).min(cols);
            let mut s1 = _mm256_setzero_si256();
            let mut s2 = _mm256_setzero_si256();
            for b in start / 4..end / 4 {
                let seg = tables.as_ptr().add(b * 256);
                let i1 = load_indices(p1.as_ptr().add(b * 8));
                let i2 = load_indices(p2.as_ptr().add(b * 8));
                s1 = _mm256_add_epi32(s1, _mm256_i32gather_epi32::<4>(seg, i1));
                s2 = _mm256_add_epi32(s2, _mm256_i32gather_epi32::<4>(seg, i2));
            }
            let s1f = _mm256_cvtepi32_ps(s1);
            let s2f = _mm256_cvtepi32_ps(s2);
            let va1 = _mm256_loadu_ps(a1.as_ptr().add(g * 8));
            let va2 = _mm256_loadu_ps(a2.as_ptr().add(g * 8));
            let sc = _mm256_set1_ps(scales[g]);
            acc = _mm256_add_ps(
                acc,
                _mm256_mul_ps(
                    sc,
                    _mm256_add_ps(_mm256_mul_ps(va1, s1f), _mm256_mul_ps(va2, s2f)),
                ),
            );
        }
        _mm256_storeu_ps(out.as_mut_ptr(), acc);
    }

    /// `((d0·x0 + d1·x1) + d2·x2) + d3·x3` for 8 rows: 4 gathers into
    /// the flat byte-decode LUT, folded in the scalar association.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn byte_dot(lut: *const f32, base: __m256i, xv: &[__m256; 4]) -> __m256 {
        let one = _mm256_set1_epi32(1);
        let d0 = _mm256_i32gather_ps::<4>(lut, base);
        let i1 = _mm256_add_epi32(base, one);
        let d1 = _mm256_i32gather_ps::<4>(lut, i1);
        let i2 = _mm256_add_epi32(i1, one);
        let d2 = _mm256_i32gather_ps::<4>(lut, i2);
        let i3 = _mm256_add_epi32(i2, one);
        let d3 = _mm256_i32gather_ps::<4>(lut, i3);
        let mut t = _mm256_mul_ps(d0, xv[0]);
        t = _mm256_add_ps(t, _mm256_mul_ps(d1, xv[1]));
        t = _mm256_add_ps(t, _mm256_mul_ps(d2, xv[2]));
        _mm256_add_ps(t, _mm256_mul_ps(d3, xv[3]))
    }

    /// Packed-tier block (no activation table): decode via LUT gathers,
    /// multiply against broadcast activation chunk.
    ///
    /// Safety: as [`lut_block8`], with `lut` = the flat 1024-entry
    /// byte-decode table and `x` holding `cols` activations.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn packed_block8(
        lut: *const f32,
        x: &[f32],
        p1: &[u8],
        p2: &[u8],
        a1: &[f32],
        a2: &[f32],
        group: usize,
        cols: usize,
        out: &mut [f32],
    ) {
        let gpr = cols.div_ceil(group);
        let mut acc = _mm256_setzero_ps();
        for g in 0..gpr {
            let start = g * group;
            let end = (start + group).min(cols);
            let mut s1 = _mm256_setzero_ps();
            let mut s2 = _mm256_setzero_ps();
            for b in start / 4..end / 4 {
                let base1 = _mm256_slli_epi32::<2>(load_indices(p1.as_ptr().add(b * 8)));
                let base2 = _mm256_slli_epi32::<2>(load_indices(p2.as_ptr().add(b * 8)));
                let xb = &x[b * 4..b * 4 + 4];
                let xv = [
                    _mm256_set1_ps(xb[0]),
                    _mm256_set1_ps(xb[1]),
                    _mm256_set1_ps(xb[2]),
                    _mm256_set1_ps(xb[3]),
                ];
                s1 = _mm256_add_ps(s1, byte_dot(lut, base1, &xv));
                s2 = _mm256_add_ps(s2, byte_dot(lut, base2, &xv));
            }
            let va1 = _mm256_loadu_ps(a1.as_ptr().add(g * 8));
            let va2 = _mm256_loadu_ps(a2.as_ptr().add(g * 8));
            acc = _mm256_add_ps(
                acc,
                _mm256_add_ps(_mm256_mul_ps(va1, s1), _mm256_mul_ps(va2, s2)),
            );
        }
        _mm256_storeu_ps(out.as_mut_ptr(), acc);
    }
}

/// Dispatch one LUT block to the widest kernel its lane count allows.
#[allow(clippy::too_many_arguments)]
#[inline]
fn lut_block_one(
    lanes: usize,
    table: &[f32],
    p1: &[u8],
    p2: &[u8],
    a1: &[f32],
    a2: &[f32],
    group: usize,
    cols: usize,
    out: &mut [f32],
) {
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    {
        if lanes == 8 && avx2_available() {
            // SAFETY: AVX2 presence just checked; slices carry the
            // 8-lane block shapes `build_interleave` produced.
            unsafe { x86::lut_block8(table, p1, p2, a1, a2, group, cols, out) };
            return;
        }
    }
    match lanes {
        8 => lut_block_portable::<8>(table, p1, p2, a1, a2, group, cols, out),
        _ => {
            debug_assert_eq!(lanes, 4, "unsupported interleave lane width");
            lut_block_portable::<4>(table, p1, p2, a1, a2, group, cols, out)
        }
    }
}

/// Dispatch one int8 block to the widest kernel its lane count allows.
#[allow(clippy::too_many_arguments)]
#[inline]
fn int_block_one(
    lanes: usize,
    tables: &[i32],
    scales: &[f32],
    p1: &[u8],
    p2: &[u8],
    a1: &[f32],
    a2: &[f32],
    group: usize,
    cols: usize,
    out: &mut [f32],
) {
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    {
        if lanes == 8 && avx2_available() {
            // SAFETY: AVX2 presence just checked; slices carry the
            // 8-lane block shapes `build_interleave` produced.
            unsafe { x86::int_block8(tables, scales, p1, p2, a1, a2, group, cols, out) };
            return;
        }
    }
    match lanes {
        8 => int_block_portable::<8>(tables, scales, p1, p2, a1, a2, group, cols, out),
        _ => {
            debug_assert_eq!(lanes, 4, "unsupported interleave lane width");
            int_block_portable::<4>(tables, scales, p1, p2, a1, a2, group, cols, out)
        }
    }
}

/// Dispatch one packed block likewise.
#[allow(clippy::too_many_arguments)]
#[inline]
fn packed_block_one(
    lanes: usize,
    x: &[f32],
    p1: &[u8],
    p2: &[u8],
    a1: &[f32],
    a2: &[f32],
    group: usize,
    cols: usize,
    out: &mut [f32],
) {
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    {
        if lanes == 8 && avx2_available() {
            let lut = decode_lut_f32().as_ptr() as *const f32;
            // SAFETY: AVX2 presence just checked; slices carry the
            // 8-lane block shapes `build_interleave` produced.
            unsafe { x86::packed_block8(lut, x, p1, p2, a1, a2, group, cols, out) };
            return;
        }
    }
    match lanes {
        8 => packed_block_portable::<8>(x, p1, p2, a1, a2, group, cols, out),
        _ => {
            debug_assert_eq!(lanes, 4, "unsupported interleave lane width");
            packed_block_portable::<4>(x, p1, p2, a1, a2, group, cols, out)
        }
    }
}

/// Debug-build spot check that the interleave still mirrors the flat
/// planes/scales: mutating `p1`/`p2`/`alpha1`/`alpha2` in place without
/// [`PackedTernaryLinear::refresh_interleave`] would otherwise serve
/// silently wrong outputs (SIMD reads the stale copy, scalar reads the
/// new planes). Samples the first and last interleaved positions —
/// cheap enough to run once per sweep, loud where it matters.
fn debug_check_sync(lin: &PackedTernaryLinear, il: &InterleavedPlanes) {
    if !cfg!(debug_assertions) || il.blocks == 0 || lin.row_stride == 0 {
        return;
    }
    let n = il.lanes;
    let stride = lin.row_stride;
    let gpr = lin.groups_per_row();
    let k = il.blocks - 1; // last block, last byte, last lane
    debug_assert!(
        il.p1[0] == lin.p1[0]
            && il.p1[(k * stride + stride - 1) * n + n - 1]
                == lin.p1[(k * n + n - 1) * stride + stride - 1]
            && il.a1[n - 1] == lin.alpha1[(n - 1) * gpr]
            && il.a2[(k * gpr + gpr - 1) * n + n - 1] == lin.alpha2[(k * n + n - 1) * gpr + gpr - 1],
        "SIMD interleave out of sync with flat planes — call refresh_interleave() \
         after mutating p1/p2/alpha1/alpha2 in place"
    );
}

/// The one place the interleaved block-span arithmetic lives: hand
/// each block `k` of `blks` its plane/scale slices and its output span
/// (`y_span[i·N..]` receives block `blks.start + i`).
fn blocks_by(
    lin: &PackedTernaryLinear,
    il: &InterleavedPlanes,
    blks: Range<usize>,
    y_span: &mut [f32],
    block: impl Fn(&[u8], &[u8], &[f32], &[f32], &mut [f32]),
) {
    let n = il.lanes;
    debug_assert_eq!(y_span.len(), blks.len() * n);
    let stride = lin.row_stride;
    let gpr = lin.groups_per_row();
    let b0 = blks.start;
    for k in blks {
        block(
            &il.p1[k * stride * n..(k + 1) * stride * n],
            &il.p2[k * stride * n..(k + 1) * stride * n],
            &il.a1[k * gpr * n..(k + 1) * gpr * n],
            &il.a2[k * gpr * n..(k + 1) * gpr * n],
            &mut y_span[(k - b0) * n..(k - b0 + 1) * n],
        );
    }
}

/// The one sweep driver behind every SIMD entry point: full blocks run
/// `blocks` (pool-partitioned into contiguous *block* spans so SIMD
/// blocks are never split mid-block; inline when sequential or below
/// the dispatch gate), then the ragged row tail runs `tail` (a scalar
/// row-span kernel) on the leader. Bit-identical to the sequential
/// sweep for any thread count.
fn sweep_by(
    lin: &PackedTernaryLinear,
    il: &InterleavedPlanes,
    y: &mut [f32],
    pool: &Pool,
    blocks: impl Fn(Range<usize>, &mut [f32]) + Sync,
    tail: impl FnOnce(Range<usize>, &mut [f32]),
) {
    debug_assert_eq!(y.len(), lin.rows);
    debug_check_sync(lin, il);
    let full = il.blocks * il.lanes;
    let (head, rest) = y.split_at_mut(full);
    if pool.threads() <= 1 || !worth_parallel(lin.rows, lin.cols) {
        blocks(0..il.blocks, head);
    } else {
        run_spans(pool, il.blocks, il.lanes, head, |_, blks, span| blocks(blks, span));
    }
    if !rest.is_empty() {
        tail(full..lin.rows, rest);
    }
}

/// LUT sweep over interleaved blocks `blks`.
pub(crate) fn lut_blocks(
    lin: &PackedTernaryLinear,
    il: &InterleavedPlanes,
    table: &[f32],
    blks: Range<usize>,
    y_span: &mut [f32],
) {
    blocks_by(lin, il, blks, y_span, |p1, p2, a1, a2, out| {
        lut_block_one(il.lanes, table, p1, p2, a1, a2, lin.group, lin.cols, out)
    });
}

/// Packed sweep over interleaved blocks `blks`.
pub(crate) fn packed_blocks(
    lin: &PackedTernaryLinear,
    il: &InterleavedPlanes,
    x: &[f32],
    blks: Range<usize>,
    y_span: &mut [f32],
) {
    blocks_by(lin, il, blks, y_span, |p1, p2, a1, a2, out| {
        packed_block_one(il.lanes, x, p1, p2, a1, a2, lin.group, lin.cols, out)
    });
}

/// Int8 sweep over interleaved blocks `blks`.
pub(crate) fn int_blocks(
    lin: &PackedTernaryLinear,
    il: &InterleavedPlanes,
    tables: &[i32],
    scales: &[f32],
    blks: Range<usize>,
    y_span: &mut [f32],
) {
    blocks_by(lin, il, blks, y_span, |p1, p2, a1, a2, out| {
        int_block_one(il.lanes, tables, scales, p1, p2, a1, a2, lin.group, lin.cols, out)
    });
}

/// Full-row int8 sweep: SIMD blocks then scalar tail — sequential.
pub fn int_rows_all(
    lin: &PackedTernaryLinear,
    il: &InterleavedPlanes,
    tables: &[i32],
    scales: &[f32],
    y: &mut [f32],
) {
    int_sweep(lin, il, tables, scales, y, &Pool::sequential());
}

/// Pool-partitioned int8 sweep — `==`-exact to the scalar sweep for
/// any thread count and lane width, because the group sums are integer
/// and the rescale epilogue is shared (DESIGN.md §Integer-Kernels).
pub fn int_sweep(
    lin: &PackedTernaryLinear,
    il: &InterleavedPlanes,
    tables: &[i32],
    scales: &[f32],
    y: &mut [f32],
    pool: &Pool,
) {
    sweep_by(
        lin,
        il,
        y,
        pool,
        |blks, span| int_blocks(lin, il, tables, scales, blks, span),
        |rows, span| super::int_act::int_rows_span(lin, tables, scales, rows, span),
    );
}

/// Full-row LUT sweep: SIMD blocks then scalar tail — sequential.
pub fn lut_rows_all(
    lin: &PackedTernaryLinear,
    il: &InterleavedPlanes,
    table: &[f32],
    y: &mut [f32],
) {
    lut_sweep(lin, il, table, y, &Pool::sequential());
}

/// Pool-partitioned LUT sweep — bit-identical to the scalar sweep for
/// any thread count.
pub fn lut_sweep(
    lin: &PackedTernaryLinear,
    il: &InterleavedPlanes,
    table: &[f32],
    y: &mut [f32],
    pool: &Pool,
) {
    sweep_by(
        lin,
        il,
        y,
        pool,
        |blks, span| lut_blocks(lin, il, table, blks, span),
        |rows, span| super::lut::lut_rows_span(lin, table, rows, span),
    );
}

/// Full-row packed sweep: SIMD blocks then scalar tail — sequential.
pub fn packed_rows_all(
    lin: &PackedTernaryLinear,
    il: &InterleavedPlanes,
    x: &[f32],
    y: &mut [f32],
) {
    gemv_packed_simd(lin, il, x, y, &Pool::sequential());
}

/// SIMD gemv over the packed planes — the decode-path entry for
/// byte-aligned layouts below the LUT threshold. Bit-identical to
/// [`super::gemv::gemv_packed`] for any thread count.
pub fn gemv_packed_simd(
    lin: &PackedTernaryLinear,
    il: &InterleavedPlanes,
    x: &[f32],
    y: &mut [f32],
    pool: &Pool,
) {
    assert_eq!(x.len(), lin.cols, "gemv dim mismatch");
    assert_eq!(y.len(), lin.rows);
    sweep_by(
        lin,
        il,
        y,
        pool,
        |blks, span| packed_blocks(lin, il, x, blks, span),
        |rows, span| super::gemv::gemv_packed_rows(lin, x, rows, span),
    );
}

/// SIMD gemm `Y = X · Ŵᵀ` over the packed planes: per X row the exact
/// [`gemv_packed_simd`] sweep, deep batches split X rows across pool
/// lanes. Bit-identical to `gemm_packed_blocked` (and hence to
/// `gemv_packed` per row) for any thread count.
pub fn gemm_packed_simd(
    lin: &PackedTernaryLinear,
    il: &InterleavedPlanes,
    x: &Matrix,
    y: &mut Matrix,
    pool: &Pool,
) {
    assert_eq!(x.cols, lin.cols, "gemm inner dim mismatch");
    assert_eq!(y.rows, x.rows, "gemm out rows mismatch");
    assert_eq!(y.cols, lin.rows, "gemm out cols mismatch");
    let n_out = lin.rows;
    if pool.threads() > 1 && x.rows >= pool.threads() && worth_parallel(x.rows * n_out, lin.cols) {
        run_spans(pool, x.rows, n_out, &mut y.data, |_, rows, span| {
            for (i, r) in rows.enumerate() {
                packed_rows_all(lin, il, x.row(r), &mut span[i * n_out..(i + 1) * n_out]);
            }
        });
        return;
    }
    for r in 0..x.rows {
        let row = &mut y.data[r * n_out..(r + 1) * n_out];
        // sweep_by re-applies the threads/worth_parallel gate, so the
        // shallow-batch path needs no duplicate policy here
        gemv_packed_simd(lin, il, x.row(r), row, pool);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::ternary::gemm::gemm_packed_blocked;
    use crate::ternary::gemv::gemv_packed;
    use crate::ternary::linear::TernaryLinear;
    use crate::ternary::lut::{fill_tables, gemv_lut};

    fn random_packed(rows: usize, cols: usize, group: usize, seed: u64) -> PackedTernaryLinear {
        let mut rng = Rng::new(seed);
        let mut lin = TernaryLinear::new(rows, cols, group);
        for t in lin.t1.trits.iter_mut().chain(lin.t2.trits.iter_mut()) {
            *t = rng.below(3) as i8 - 1;
        }
        for a in lin.alpha1.iter_mut().chain(lin.alpha2.iter_mut()) {
            *a = rng.normal() * 0.2;
        }
        lin.to_packed()
    }

    #[test]
    fn mode_parsing() {
        assert_eq!(SimdMode::parse("auto"), Some(SimdMode::Auto));
        assert_eq!(SimdMode::parse(""), Some(SimdMode::Auto));
        assert_eq!(SimdMode::parse("ON"), Some(SimdMode::On));
        assert_eq!(SimdMode::parse("off"), Some(SimdMode::Off));
        assert_eq!(SimdMode::parse("0"), Some(SimdMode::Off));
        assert_eq!(SimdMode::parse("sideways"), None);
        assert!(!cpu_features().is_empty());
        assert!(detected_lanes() == 4 || detected_lanes() == 8);
    }

    #[test]
    fn interleave_layout_positions() {
        let packed = random_packed(11, 24, 8, 5);
        for lanes in [4usize, 8] {
            let Some(il) = build_interleave(&packed, lanes) else {
                panic!("aligned layout must interleave at {lanes} lanes");
            };
            assert_eq!(il.blocks, 11 / lanes);
            let stride = packed.row_stride;
            let gpr = packed.groups_per_row();
            for k in 0..il.blocks {
                for l in 0..lanes {
                    let r = k * lanes + l;
                    for b in 0..stride {
                        assert_eq!(
                            il.p1[(k * stride + b) * lanes + l],
                            packed.p1[r * stride + b]
                        );
                        assert_eq!(
                            il.p2[(k * stride + b) * lanes + l],
                            packed.p2[r * stride + b]
                        );
                    }
                    for g in 0..gpr {
                        assert_eq!(il.a1[(k * gpr + g) * lanes + l], packed.alpha1[r * gpr + g]);
                        assert_eq!(il.a2[(k * gpr + g) * lanes + l], packed.alpha2[r * gpr + g]);
                    }
                }
            }
        }
    }

    #[test]
    fn ragged_layouts_do_not_interleave() {
        // G % 4 != 0 and cols % 4 != 0 must both refuse
        assert!(build_interleave(&random_packed(16, 40, 10, 1), 4).is_none());
        assert!(build_interleave(&random_packed(16, 37, 4, 2), 4).is_none());
        // fewer rows than one block refuses too
        assert!(build_interleave(&random_packed(3, 16, 4, 3), 4).is_none());
        // unsupported lane width refuses
        assert!(build_interleave(&random_packed(16, 16, 4, 4), 3).is_none());
    }

    #[test]
    fn lut_sweep_bit_identical_to_scalar_incl_tail() {
        let mut rng = Rng::new(7);
        // rows chosen to leave ragged tails at both lane widths; the
        // 370x96 shape clears PAR_MIN_WORK so the block-partitioned
        // pool path genuinely runs
        for (rows, cols, group) in [(37usize, 32usize, 8usize), (370, 96, 32), (8, 16, 16)] {
            let packed = random_packed(rows, cols, group, 70 + rows as u64);
            let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
            let mut y_ref = vec![0.0f32; rows];
            let mut table = Vec::new();
            gemv_lut(&packed, &x, &mut y_ref, &mut table);
            for lanes in [4usize, 8] {
                let Some(il) = build_interleave(&packed, lanes) else {
                    assert!(rows < lanes, "rows={rows} lanes={lanes}");
                    continue;
                };
                let mut y = vec![9.0f32; rows];
                lut_rows_all(&packed, &il, &table, &mut y);
                assert_eq!(y, y_ref, "seq rows={rows} lanes={lanes}");
                for threads in [2usize, 3] {
                    let pool = Pool::new(threads);
                    let mut y = vec![9.0f32; rows];
                    lut_sweep(&packed, &il, &table, &mut y, &pool);
                    assert_eq!(y, y_ref, "rows={rows} lanes={lanes} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn packed_sweep_bit_identical_to_gemv_packed() {
        let mut rng = Rng::new(9);
        // 300x128 clears PAR_MIN_WORK (threaded span path engages)
        for (rows, cols, group) in [(37usize, 32usize, 8usize), (9, 16, 4), (300, 128, 128)] {
            let packed = random_packed(rows, cols, group, 90 + rows as u64);
            let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
            let mut y_ref = vec![0.0f32; rows];
            gemv_packed(&packed, &x, &mut y_ref);
            for lanes in [4usize, 8] {
                let Some(il) = build_interleave(&packed, lanes) else {
                    continue;
                };
                for threads in [1usize, 2, 4] {
                    let pool = Pool::new(threads);
                    let mut y = vec![9.0f32; rows];
                    gemv_packed_simd(&packed, &il, &x, &mut y, &pool);
                    assert_eq!(y, y_ref, "rows={rows} lanes={lanes} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn gemm_simd_bit_identical_to_blocked() {
        let mut rng = Rng::new(11);
        for (rows, cols, group, m) in [(22usize, 32usize, 8usize, 5usize), (70, 64, 16, 40)] {
            let packed = random_packed(rows, cols, group, 110 + m as u64);
            let x = Matrix::randn(m, cols, 1.0, &mut rng);
            let y_ref = gemm_packed_blocked(&packed, &x);
            for lanes in [4usize, 8] {
                let Some(il) = build_interleave(&packed, lanes) else {
                    continue;
                };
                for threads in [1usize, 2, 4] {
                    let pool = Pool::new(threads);
                    let mut y = Matrix::zeros(m, rows);
                    gemm_packed_simd(&packed, &il, &x, &mut y, &pool);
                    assert_eq!(y.data, y_ref.data, "lanes={lanes} threads={threads} m={m}");
                }
            }
        }
    }

    #[test]
    fn int_sweep_exact_across_lanes_and_threads() {
        use crate::ternary::int_act::{fill_tables_int, int_rows_span, quantize_row_groups};
        let mut rng = Rng::new(17);
        // ragged row tails at both lane widths; 370×96 clears the
        // PAR_MIN_WORK gate so the block-partitioned pool path runs.
        // On AVX2 machines lanes=8 exercises the i32-gather kernel
        // against the same scalar reference the portable lanes hit.
        for (rows, cols, group) in [(37usize, 32usize, 8usize), (370, 96, 32), (8, 16, 16)] {
            let packed = random_packed(rows, cols, group, 170 + rows as u64);
            let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
            let mut q = Vec::new();
            let mut scales = Vec::new();
            quantize_row_groups(&x, group, &mut q, &mut scales);
            let mut tables = Vec::new();
            fill_tables_int(&q, &mut tables);
            let mut y_ref = vec![0.0f32; rows];
            int_rows_span(&packed, &tables, &scales, 0..rows, &mut y_ref);
            for lanes in [4usize, 8] {
                let Some(il) = build_interleave(&packed, lanes) else {
                    assert!(rows < lanes, "rows={rows} lanes={lanes}");
                    continue;
                };
                let mut y = vec![9.0f32; rows];
                int_rows_all(&packed, &il, &tables, &scales, &mut y);
                assert_eq!(y, y_ref, "seq rows={rows} lanes={lanes}");
                for threads in [2usize, 3] {
                    let pool = Pool::new(threads);
                    let mut y = vec![9.0f32; rows];
                    int_sweep(&packed, &il, &tables, &scales, &mut y, &pool);
                    assert_eq!(y, y_ref, "rows={rows} lanes={lanes} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn zero_planes_stay_zero_through_simd() {
        let packed = TernaryLinear::new(12, 16, 4).to_packed();
        let il = build_interleave(&packed, 4).unwrap();
        let x = vec![1.0f32; 16];
        let mut y = vec![9.0f32; 12];
        gemv_packed_simd(&packed, &il, &x, &mut y, &Pool::sequential());
        assert!(y.iter().all(|&v| v == 0.0));
        let mut table = Vec::new();
        fill_tables(&x, &mut table);
        let mut y = vec![9.0f32; 12];
        lut_rows_all(&packed, &il, &table, &mut y);
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn avx2_path_matches_portable_when_available() {
        if !avx2_available() {
            eprintln!("skipping: no AVX2 on this machine (portable path covered elsewhere)");
            return;
        }
        let mut rng = Rng::new(13);
        let packed = random_packed(24, 32, 8, 21);
        let il8 = build_interleave(&packed, 8).unwrap();
        let il4 = build_interleave(&packed, 4).unwrap();
        let x: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
        let mut table = Vec::new();
        fill_tables(&x, &mut table);
        let (mut a, mut b) = (vec![0.0f32; 24], vec![0.0f32; 24]);
        lut_rows_all(&packed, &il8, &table, &mut a);
        lut_rows_all(&packed, &il4, &table, &mut b);
        assert_eq!(a, b, "avx2 vs portable LUT");
        let pool = Pool::sequential();
        gemv_packed_simd(&packed, &il8, &x, &mut a, &pool);
        gemv_packed_simd(&packed, &il4, &x, &mut b, &pool);
        assert_eq!(a, b, "avx2 vs portable packed");
    }
}

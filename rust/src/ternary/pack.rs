//! Trit packing formats.
//!
//! * **2-bit** (paper Eq. 13, "each trit-plane containing 3 states has to
//!   be stored as a 2-bit datatype"): 4 trits per byte, encoding
//!   `{-1→0b10, 0→0b00, +1→0b01}` (0b11 unused). This is the hardware
//!   format and the one the multiply-free kernels stream.
//! * **base-3** (paper Appendix G future work: "8 ternary elements ...
//!   bit-packing" density direction): 5 trits per byte (3⁵ = 243 ≤ 256),
//!   1.6 bits/trit — the dense archival format.

/// Encode one trit into its 2-bit code.
#[inline]
fn enc2(t: i8) -> u8 {
    match t {
        0 => 0b00,
        1 => 0b01,
        -1 => 0b10,
        _ => panic!("invalid trit {t}"),
    }
}

/// Decode a 2-bit code into a trit. 0b11 decodes to 0 (defensive).
#[inline]
pub fn dec2(code: u8) -> i8 {
    match code & 0b11 {
        0b01 => 1,
        0b10 => -1,
        _ => 0,
    }
}

/// Pack trits 4-per-byte, little-endian within the byte (trit i occupies
/// bits 2i..2i+2). Trailing slots are zero-filled.
pub fn pack2bit(trits: &[i8]) -> Vec<u8> {
    let mut out = vec![0u8; trits.len().div_ceil(4)];
    for (i, &t) in trits.iter().enumerate() {
        out[i / 4] |= enc2(t) << ((i % 4) * 2);
    }
    out
}

/// Unpack `n` trits from a 2-bit stream. Whole bytes decode through the
/// shared 256-entry LUT ([`super::lut::decode_lut_i8`] — the one copy
/// the packed kernels use too); the ragged tail decodes per trit.
pub fn unpack2bit(bytes: &[u8], n: usize) -> Vec<i8> {
    assert!(bytes.len() * 4 >= n, "packed buffer too short");
    let lut = super::lut::decode_lut_i8();
    let mut out = Vec::with_capacity(n);
    for &b in bytes.iter().take(n / 4) {
        out.extend_from_slice(&lut[b as usize]);
    }
    for i in out.len()..n {
        out.push(dec2(bytes[i / 4] >> ((i % 4) * 2)));
    }
    out
}

/// Pack trits 5-per-byte in base 3 (digit value = trit + 1).
pub fn pack_base3(trits: &[i8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(trits.len().div_ceil(5));
    for chunk in trits.chunks(5) {
        let mut v: u16 = 0;
        // little-endian digits: first trit = least-significant digit
        for &t in chunk.iter().rev() {
            debug_assert!((-1..=1).contains(&t));
            v = v * 3 + (t + 1) as u16;
        }
        debug_assert!(v < 243);
        out.push(v as u8);
    }
    out
}

/// Unpack `n` trits from a base-3 stream.
pub fn unpack_base3(bytes: &[u8], n: usize) -> Vec<i8> {
    assert!(bytes.len() * 5 >= n, "packed buffer too short");
    let mut out = Vec::with_capacity(n);
    'outer: for &b in bytes {
        let mut v = b as u16;
        for _ in 0..5 {
            out.push((v % 3) as i8 - 1);
            v /= 3;
            if out.len() == n {
                break 'outer;
            }
        }
    }
    out
}

/// Bytes needed to store `n` trits in each format — the Table 4 memory
/// model uses these.
pub fn bytes_2bit(n: usize) -> usize {
    n.div_ceil(4)
}

pub fn bytes_base3(n: usize) -> usize {
    n.div_ceil(5)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{check, prop_assert, Gen};

    #[test]
    fn pack2_roundtrip_exact() {
        let trits = vec![-1i8, 0, 1, 1, -1, 0, 0, 1, -1];
        let packed = pack2bit(&trits);
        assert_eq!(packed.len(), 3);
        assert_eq!(unpack2bit(&packed, trits.len()), trits);
    }

    #[test]
    fn pack2_density() {
        assert_eq!(bytes_2bit(128), 32);
        assert_eq!(bytes_2bit(129), 33);
        assert_eq!(pack2bit(&vec![1i8; 128]).len(), 32);
    }

    #[test]
    fn base3_roundtrip_exact() {
        let trits = vec![-1i8, -1, 0, 1, 1, 0, -1, 1, 0, 0, 1];
        let packed = pack_base3(&trits);
        assert_eq!(packed.len(), 3);
        assert_eq!(unpack_base3(&packed, trits.len()), trits);
    }

    #[test]
    fn base3_denser_than_2bit() {
        assert!(bytes_base3(1000) < bytes_2bit(1000));
        assert_eq!(bytes_base3(1000), 200);
        assert_eq!(bytes_2bit(1000), 250);
    }

    #[test]
    fn empty_inputs() {
        assert!(pack2bit(&[]).is_empty());
        assert!(unpack2bit(&[], 0).is_empty());
        assert!(pack_base3(&[]).is_empty());
        assert!(unpack_base3(&[], 0).is_empty());
    }

    #[test]
    fn prop_pack2_roundtrip() {
        check(200, |g: &mut Gen| {
            let n = g.usize_in(0, 300);
            let trits = g.vec_trits(n);
            prop_assert(
                unpack2bit(&pack2bit(&trits), n) == trits,
                "2-bit roundtrip mismatch",
            )
        });
    }

    #[test]
    fn prop_base3_roundtrip() {
        check(200, |g: &mut Gen| {
            let n = g.usize_in(0, 300);
            let trits = g.vec_trits(n);
            prop_assert(
                unpack_base3(&pack_base3(&trits), n) == trits,
                "base-3 roundtrip mismatch",
            )
        });
    }

    #[test]
    fn prop_formats_agree() {
        check(100, |g: &mut Gen| {
            let n = g.usize_in(1, 200);
            let trits = g.vec_trits(n);
            let a = unpack2bit(&pack2bit(&trits), n);
            let b = unpack_base3(&pack_base3(&trits), n);
            prop_assert(a == b, "format decode disagreement")
        });
    }
}

//! Unpacked trit-plane: a shape-carrying matrix over {-1, 0, 1}.

use crate::tensor::Matrix;

/// A ternary matrix stored as i8 (debug/compute-friendly layout; the
/// storage formats live in [`super::pack`]).
#[derive(Clone, Debug, PartialEq)]
pub struct TritPlane {
    pub rows: usize,
    pub cols: usize,
    pub trits: Vec<i8>,
}

impl TritPlane {
    pub fn zeros(rows: usize, cols: usize) -> TritPlane {
        TritPlane {
            rows,
            cols,
            trits: vec![0; rows * cols],
        }
    }

    /// Sign-initialization used by PTQTP (Algorithm 2 line 2):
    /// `T = sign(W)` with `0 → 1` replacement so every trit starts active.
    pub fn sign_init(w: &Matrix) -> TritPlane {
        TritPlane {
            rows: w.rows,
            cols: w.cols,
            trits: w
                .data
                .iter()
                .map(|&x| if x < 0.0 { -1 } else { 1 })
                .collect(),
        }
    }

    pub fn from_vec(rows: usize, cols: usize, trits: Vec<i8>) -> TritPlane {
        assert_eq!(trits.len(), rows * cols);
        debug_assert!(trits.iter().all(|&t| (-1..=1).contains(&t)));
        TritPlane { rows, cols, trits }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> i8 {
        self.trits[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[i8] {
        &self.trits[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [i8] {
        &mut self.trits[r * self.cols..(r + 1) * self.cols]
    }

    pub fn len(&self) -> usize {
        self.trits.len()
    }

    pub fn is_empty(&self) -> bool {
        self.trits.is_empty()
    }

    /// Fraction of zero trits — drives the sparsity-aware kernels and the
    /// Appendix-A sparsity discussion.
    pub fn sparsity(&self) -> f64 {
        if self.trits.is_empty() {
            return 0.0;
        }
        self.trits.iter().filter(|&&t| t == 0).count() as f64 / self.trits.len() as f64
    }

    /// Count positions where two planes differ (Fig 5: per-iteration
    /// plane-update visualization).
    pub fn diff_count(&self, other: &TritPlane) -> usize {
        assert_eq!(self.trits.len(), other.trits.len());
        self.trits
            .iter()
            .zip(&other.trits)
            .filter(|(a, b)| a != b)
            .count()
    }

    /// Dense f32 copy (for reconstruction/debug).
    pub fn to_matrix(&self) -> Matrix {
        Matrix::from_vec(
            self.rows,
            self.cols,
            self.trits.iter().map(|&t| t as f32).collect(),
        )
    }

    /// Histogram over {-1, 0, +1}.
    pub fn value_counts(&self) -> [usize; 3] {
        let mut c = [0usize; 3];
        for &t in &self.trits {
            c[(t + 1) as usize] += 1;
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn sign_init_never_zero() {
        let mut rng = Rng::new(1);
        let mut w = Matrix::randn(8, 8, 1.0, &mut rng);
        w.data[5] = 0.0;
        let t = TritPlane::sign_init(&w);
        assert!(t.trits.iter().all(|&x| x == 1 || x == -1));
        assert_eq!(t.trits[5], 1, "zero maps to +1 per Appendix B");
    }

    #[test]
    fn sign_init_matches_signs() {
        let w = Matrix::from_vec(1, 4, vec![-2.0, 3.0, -0.5, 0.0]);
        let t = TritPlane::sign_init(&w);
        assert_eq!(t.trits, vec![-1, 1, -1, 1]);
    }

    #[test]
    fn sparsity_counts_zeros() {
        let t = TritPlane::from_vec(2, 2, vec![0, 1, -1, 0]);
        assert!((t.sparsity() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn value_counts_sums() {
        let t = TritPlane::from_vec(1, 6, vec![-1, -1, 0, 1, 1, 1]);
        assert_eq!(t.value_counts(), [2, 1, 3]);
    }

    #[test]
    fn diff_count_symmetric() {
        let a = TritPlane::from_vec(1, 4, vec![-1, 0, 1, 1]);
        let b = TritPlane::from_vec(1, 4, vec![-1, 1, 1, 0]);
        assert_eq!(a.diff_count(&b), 2);
        assert_eq!(b.diff_count(&a), 2);
    }

    #[test]
    fn to_matrix_roundtrip_values() {
        let t = TritPlane::from_vec(2, 2, vec![-1, 0, 1, -1]);
        let m = t.to_matrix();
        assert_eq!(m.data, vec![-1.0, 0.0, 1.0, -1.0]);
    }
}

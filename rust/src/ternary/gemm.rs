//! Multiply-free matrix–matrix kernel (the prefill path).
//!
//! Computes `Y (m×n) = X (m×d) · Ŵᵀ` where Ŵ is the two-plane ternary
//! factorization. Strategy per DESIGN.md §Hardware-Adaptation: iterate
//! output channels (rows of W); each channel's trits are decoded once
//! per row-block of X so plane bytes stream exactly once per block —
//! the CPU analogue of the paper's threadblock HBM schedule.

use super::gemv::{decode_plane_row, gemv_fused, gemv_packed};
use super::linear::{PackedTernaryLinear, TernaryLinear};
use crate::tensor::Matrix;
use crate::threads::{chunk_range, worth_parallel, Pool, SendPtr};

/// Row-block edge for X; keeps a block of X plus one decoded channel in
/// L2 cache.
const XBLOCK: usize = 32;

/// Reusable buffers + execution policy for the packed matrix kernels.
/// Owned by the caller (the model's `ForwardScratch`) so the serving
/// hot loop never allocates: channel-decode buffers for the blocked
/// tier (one pair per pool lane), activation-indexed tables for the
/// LUT tier (one per lane), and the worker pool the row-parallel
/// drivers dispatch on (sequential by default — the exact legacy path).
#[derive(Clone, Debug)]
pub struct GemmScratch {
    dec1: Vec<f32>,
    dec2: Vec<f32>,
    /// Per-lane channel-decode buffers for the parallel blocked kernel
    /// (lane 0's pair is distinct from `dec1`/`dec2`, which stay
    /// dedicated to the sequential path).
    pub(crate) lane_dec: Vec<(Vec<f32>, Vec<f32>)>,
    /// Per-lane activation-indexed tables for the LUT tier.
    pub(crate) lut_tables: Vec<Vec<f32>>,
    /// Per-lane int8 activation scratch (codes + scales + i32 tables)
    /// for the integer-activation tier.
    pub(crate) int_lanes: Vec<crate::ternary::int_act::IntActScratch>,
    /// Worker pool driving the row-parallel kernels. `threads == 1`
    /// forces the exact sequential path.
    pub pool: Pool,
    /// SIMD row-block tier toggle consulted by the dispatchers using
    /// this scratch. Defaults to the process-wide mode
    /// (`--simd`/`PTQTP_SIMD`); flip per scratch for exact A/B runs —
    /// outputs are bit-identical either way (DESIGN.md §SIMD-Kernels).
    pub simd: bool,
    /// Integer-activation tier toggle (DESIGN.md §Integer-Kernels).
    /// Unlike `simd` this tier is **value-changing** (activations are
    /// quantized to int8), so it defaults to off unconditionally — the
    /// process-wide `--act-quant`/`PTQTP_ACT_QUANT` mode is applied
    /// only at the CLI / serve entry points, never by library defaults,
    /// keeping every existing output bitwise unchanged unless asked.
    pub act_quant: bool,
}

impl Default for GemmScratch {
    fn default() -> GemmScratch {
        GemmScratch {
            dec1: Vec::new(),
            dec2: Vec::new(),
            lane_dec: Vec::new(),
            lut_tables: Vec::new(),
            int_lanes: Vec::new(),
            pool: Pool::default(),
            simd: crate::ternary::simd::enabled(),
            act_quant: false,
        }
    }
}

impl GemmScratch {
    pub fn new() -> GemmScratch {
        GemmScratch::default()
    }

    /// Grow the per-lane buffer sets to at least `lanes` entries.
    pub(crate) fn ensure_lanes(&mut self, lanes: usize) {
        if self.lane_dec.len() < lanes {
            self.lane_dec.resize_with(lanes, Default::default);
        }
        if self.lut_tables.len() < lanes {
            self.lut_tables.resize_with(lanes, Vec::new);
        }
        if self.int_lanes.len() < lanes {
            self.int_lanes.resize_with(lanes, Default::default);
        }
    }
}

/// Row-blocked `Y = X · Ŵᵀ` over the packed deployment form.
///
/// The serving batch kernel: each output channel's planes are decoded
/// once per `XBLOCK` rows of X (amortizing the 2-bit→f32 decode over
/// the whole block), and the inner loop is a pure f32 multiply-add over
/// the decoded trits. Every output element is computed with the exact
/// FP operation order of [`gemv_packed`], so the batched forward path
/// is **bit-identical** to per-token decoding — the property the
/// engine's batched-vs-sequential parity tests pin down.
pub fn gemm_packed_blocked_into(
    lin: &PackedTernaryLinear,
    x: &Matrix,
    y: &mut Matrix,
    scratch: &mut GemmScratch,
) {
    assert_eq!(x.cols, lin.cols, "gemm inner dim mismatch");
    assert_eq!(y.rows, x.rows, "gemm out rows mismatch");
    assert_eq!(y.cols, lin.rows, "gemm out cols mismatch");
    let yp = SendPtr(y.data.as_mut_ptr());
    gemm_blocked_chans(lin, x, 0..lin.rows, &mut scratch.dec1, &mut scratch.dec2, yp);
}

/// Channel-span core shared by the sequential and channel-parallel
/// blocked kernels — the single FP-order body (the `gemv_packed_rows`
/// pattern), so the bit-identity invariant is maintained in one place.
/// Computes output channels `chans` for every row of X, writing
/// `y[xr·n_out + ch]` through the raw output pointer. Caller contract:
/// exclusive access to exactly those elements, with the output buffer
/// alive for the whole call.
fn gemm_blocked_chans(
    lin: &PackedTernaryLinear,
    x: &Matrix,
    chans: std::ops::Range<usize>,
    dec1: &mut Vec<f32>,
    dec2: &mut Vec<f32>,
    yp: SendPtr<f32>,
) {
    let gpr = lin.groups_per_row();
    let aligned = lin.group % 4 == 0 && lin.cols % 4 == 0;
    let n_out = lin.rows;
    dec1.resize(lin.cols, 0.0);
    dec2.resize(lin.cols, 0.0);
    for rb in (0..x.rows).step_by(XBLOCK) {
        let re = (rb + XBLOCK).min(x.rows);
        for ch in chans.clone() {
            let p1 = &lin.p1[ch * lin.row_stride..(ch + 1) * lin.row_stride];
            let p2 = &lin.p2[ch * lin.row_stride..(ch + 1) * lin.row_stride];
            decode_plane_row(p1, lin.cols, dec1);
            decode_plane_row(p2, lin.cols, dec2);
            for xr in rb..re {
                let xrow = x.row(xr);
                let mut acc = 0.0f32;
                for g in 0..gpr {
                    let start = g * lin.group;
                    let end = (start + lin.group).min(lin.cols);
                    let (s1, s2) = if aligned {
                        decoded_pair_sum_aligned(dec1, dec2, xrow, start, end)
                    } else {
                        decoded_pair_sum_scalar(dec1, dec2, xrow, start, end)
                    };
                    let ai = ch * gpr + g;
                    acc += lin.alpha1[ai] * s1 + lin.alpha2[ai] * s2;
                }
                // SAFETY: caller grants exclusive access to the `chans`
                // columns of `y` (see function doc).
                unsafe { *yp.get().add(xr * n_out + ch) = acc };
            }
        }
    }
}

/// Allocating wrapper around [`gemm_packed_blocked_into`].
pub fn gemm_packed_blocked(lin: &PackedTernaryLinear, x: &Matrix) -> Matrix {
    let mut y = Matrix::zeros(x.rows, lin.rows);
    let mut scratch = GemmScratch::new();
    gemm_packed_blocked_into(lin, x, &mut y, &mut scratch);
    y
}

/// Channel-parallel [`gemm_packed_blocked_into`]: output channels are
/// partitioned into contiguous spans, one per lane of `scratch.pool`;
/// each lane decodes its own channels into its own lane buffers and
/// runs the identical blocked sweep, so every output element carries
/// the sequential FP order — output is bit-identical to the sequential
/// kernel (and hence to `gemv_packed` per row) for any thread count.
/// Falls back inline when the pool is sequential or the whole stack's
/// work is below [`crate::threads::PAR_MIN_WORK`].
pub fn gemm_packed_blocked_par_into(
    lin: &PackedTernaryLinear,
    x: &Matrix,
    y: &mut Matrix,
    scratch: &mut GemmScratch,
) {
    let pool = scratch.pool.clone();
    let lanes = pool.threads();
    if lanes <= 1 || !worth_parallel(x.rows * lin.rows, lin.cols) {
        gemm_packed_blocked_into(lin, x, y, scratch);
        return;
    }
    assert_eq!(x.cols, lin.cols, "gemm inner dim mismatch");
    assert_eq!(y.rows, x.rows, "gemm out rows mismatch");
    assert_eq!(y.cols, lin.rows, "gemm out cols mismatch");
    scratch.ensure_lanes(lanes);
    let n_out = lin.rows;
    let yp = SendPtr(y.data.as_mut_ptr());
    let lane_bufs = SendPtr(scratch.lane_dec.as_mut_ptr());
    pool.run(|lane| {
        let chans = chunk_range(n_out, lanes, lane);
        if chans.is_empty() {
            return;
        }
        // SAFETY: one decode-buffer pair per lane (ensure_lanes sized
        // the vec); lanes own disjoint channel columns of `y`; both
        // outlive `run` because the leader blocks inside it.
        let bufs = unsafe { &mut *lane_bufs.get().add(lane) };
        gemm_blocked_chans(lin, x, chans, &mut bufs.0, &mut bufs.1, yp);
    });
}

/// Mirror of `gemv::plane_pair_sum_aligned` over decoded-f32 planes:
/// the same 4-wide sum expression per byte, so results are bit-equal.
#[inline]
fn decoded_pair_sum_aligned(d1: &[f32], d2: &[f32], x: &[f32], start: usize, end: usize) -> (f32, f32) {
    let mut s1 = 0.0f32;
    let mut s2 = 0.0f32;
    for b in start / 4..end / 4 {
        let i = b * 4;
        s1 += d1[i] * x[i] + d1[i + 1] * x[i + 1] + d1[i + 2] * x[i + 2] + d1[i + 3] * x[i + 3];
        s2 += d2[i] * x[i] + d2[i + 1] * x[i + 1] + d2[i + 2] * x[i + 2] + d2[i + 3] * x[i + 3];
    }
    (s1, s2)
}

/// Mirror of `gemv::plane_pair_sum_scalar` over decoded-f32 planes.
#[inline]
fn decoded_pair_sum_scalar(d1: &[f32], d2: &[f32], x: &[f32], start: usize, end: usize) -> (f32, f32) {
    let mut s1 = 0.0f32;
    let mut s2 = 0.0f32;
    for c in start..end {
        s1 += d1[c] * x[c];
        s2 += d2[c] * x[c];
    }
    (s1, s2)
}

/// Y = X · Ŵᵀ with unpacked planes (reference path).
pub fn gemm(lin: &TernaryLinear, x: &Matrix) -> Matrix {
    assert_eq!(x.cols, lin.cols, "gemm inner dim mismatch");
    let mut y = Matrix::zeros(x.rows, lin.rows);
    // m==1 degenerates to the tuned gemv
    if x.rows == 1 {
        gemv_fused(lin, x.row(0), y.row_mut(0));
        return y;
    }
    let gpr = lin.groups_per_row();
    for rb in (0..x.rows).step_by(XBLOCK) {
        let re = (rb + XBLOCK).min(x.rows);
        for ch in 0..lin.rows {
            let t1 = lin.t1.row(ch);
            let t2 = lin.t2.row(ch);
            for xr in rb..re {
                let xrow = x.row(xr);
                let mut acc = 0.0f32;
                for g in 0..gpr {
                    let (s, e) = lin.group_span(g);
                    let mut s1 = 0.0f32;
                    let mut s2 = 0.0f32;
                    for c in s..e {
                        let xv = xrow[c];
                        s1 += t1[c] as f32 * xv;
                        s2 += t2[c] as f32 * xv;
                    }
                    let ai = lin.alpha_idx(ch, g);
                    acc += lin.alpha1[ai] * s1 + lin.alpha2[ai] * s2;
                }
                *y.at_mut(xr, ch) = acc;
            }
        }
    }
    y
}

/// Y = X · Ŵᵀ over the packed deployment form: per row of X, run the
/// packed gemv (plane bytes stream once per X row; at large m a decoded
/// cache would win — see `gemm_decoded`).
pub fn gemm_packed(lin: &PackedTernaryLinear, x: &Matrix) -> Matrix {
    assert_eq!(x.cols, lin.cols, "gemm inner dim mismatch");
    let mut y = Matrix::zeros(x.rows, lin.rows);
    for r in 0..x.rows {
        // split borrow: row r of y
        let row = &mut y.data[r * lin.rows..(r + 1) * lin.rows];
        gemv_packed(lin, x.row(r), row);
    }
    y
}

/// Prefill-optimized: dequantize Ŵᵀ to a dense f32 tile once, then run
/// the cache-blocked dense matmul. Amortizes the decode over all m rows
/// — the standard "dequant-to-tile" strategy serving engines use for
/// prefill (decode-path stays packed/multiply-free). Wins for m ≳ 8;
/// ~15× faster than the per-channel trit sweep it replaced
/// (EXPERIMENTS.md §Perf).
pub fn gemm_decoded(lin: &PackedTernaryLinear, x: &Matrix) -> Matrix {
    let w_hat_t = reconstruct_transposed(lin);
    crate::tensor::ops::matmul(x, &w_hat_t)
}

/// Dense Ŵᵀ (d×n) straight from the packed planes (single pass, no
/// intermediate unpacked planes).
fn reconstruct_transposed(lin: &PackedTernaryLinear) -> Matrix {
    let gpr = lin.groups_per_row();
    let mut out = Matrix::zeros(lin.cols, lin.rows);
    for r in 0..lin.rows {
        let p1 = &lin.p1[r * lin.row_stride..(r + 1) * lin.row_stride];
        let p2 = &lin.p2[r * lin.row_stride..(r + 1) * lin.row_stride];
        for g in 0..gpr {
            let s = g * lin.group;
            let e = (s + lin.group).min(lin.cols);
            let a1 = lin.alpha1[r * gpr + g];
            let a2 = lin.alpha2[r * gpr + g];
            for c in s..e {
                let sh = (c % 4) * 2;
                let t1 = super::pack::dec2(p1[c / 4] >> sh);
                let t2 = super::pack::dec2(p2[c / 4] >> sh);
                out.data[c * lin.rows + r] = a1 * t1 as f32 + a2 * t2 as f32;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::ops::matmul;

    fn random_linear(rows: usize, cols: usize, group: usize, seed: u64) -> TernaryLinear {
        let mut rng = Rng::new(seed);
        let mut lin = TernaryLinear::new(rows, cols, group);
        for t in lin.t1.trits.iter_mut().chain(lin.t2.trits.iter_mut()) {
            *t = rng.below(3) as i8 - 1;
        }
        for a in lin.alpha1.iter_mut().chain(lin.alpha2.iter_mut()) {
            *a = rng.normal() * 0.2;
        }
        lin
    }

    #[test]
    fn gemm_matches_dense() {
        let mut rng = Rng::new(50);
        let lin = random_linear(11, 48, 16, 51);
        let x = Matrix::randn(9, 48, 1.0, &mut rng);
        let dense = matmul(&x, &lin.reconstruct().transpose());
        let y = gemm(&lin, &x);
        for (a, b) in y.data.iter().zip(&dense.data) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn gemm_single_row_equals_gemv() {
        let mut rng = Rng::new(52);
        let lin = random_linear(6, 32, 8, 53);
        let x = Matrix::randn(1, 32, 1.0, &mut rng);
        let y = gemm(&lin, &x);
        let yv = super::super::gemv::gemv(&lin, x.row(0));
        assert_eq!(y.data, yv);
    }

    #[test]
    fn packed_variants_match() {
        let mut rng = Rng::new(54);
        let lin = random_linear(10, 64, 32, 55);
        let packed = lin.to_packed();
        let x = Matrix::randn(5, 64, 1.0, &mut rng);
        let a = gemm(&lin, &x);
        let b = gemm_packed(&packed, &x);
        let c = gemm_decoded(&packed, &x);
        for i in 0..a.data.len() {
            assert!((a.data[i] - b.data[i]).abs() < 1e-4 * (1.0 + a.data[i].abs()));
            assert!((a.data[i] - c.data[i]).abs() < 1e-4 * (1.0 + a.data[i].abs()));
        }
    }

    #[test]
    fn blocked_bit_identical_to_gemv_packed() {
        // the parity guarantee the batched forward path relies on:
        // every output element equals the per-token gemv bit-for-bit,
        // for aligned (G%4==0) and ragged (G%4!=0, cols%4!=0) layouts
        let mut rng = Rng::new(58);
        for (rows, cols, group) in [(10, 64, 32), (5, 37, 10), (7, 48, 12), (3, 16, 128)] {
            let lin = random_linear(rows, cols, group, 59 + rows as u64);
            let packed = lin.to_packed();
            let x = Matrix::randn(XBLOCK + 7, cols, 1.0, &mut rng);
            let y = gemm_packed_blocked(&packed, &x);
            for r in 0..x.rows {
                let mut yv = vec![0.0; rows];
                gemv_packed(&packed, x.row(r), &mut yv);
                assert_eq!(&y.data[r * rows..(r + 1) * rows], yv.as_slice(),
                    "row {r} (rows={rows} cols={cols} G={group})");
            }
        }
    }

    #[test]
    fn parallel_blocked_bit_identical_for_any_thread_count() {
        let mut rng = Rng::new(62);
        // work above the PAR_MIN_WORK gate (parallel engages, aligned +
        // ragged) and below it (inline fallback)
        for (rows, cols, group) in [(100, 64, 32), (80, 37, 10), (12, 24, 8)] {
            let lin = random_linear(rows, cols, group, 63 + rows as u64).to_packed();
            let x = Matrix::randn(XBLOCK + 5, cols, 1.0, &mut rng);
            let seq = gemm_packed_blocked(&lin, &x);
            for threads in [1usize, 2, 4] {
                let mut scratch = GemmScratch::new();
                scratch.pool = crate::threads::Pool::new(threads);
                let mut y = Matrix::zeros(x.rows, rows);
                gemm_packed_blocked_par_into(&lin, &x, &mut y, &mut scratch);
                assert_eq!(y.data, seq.data, "threads={threads} rows={rows} G={group}");
            }
        }
    }

    #[test]
    fn blocked_scratch_reuse_across_shapes() {
        let mut rng = Rng::new(60);
        let mut scratch = super::GemmScratch::new();
        for (rows, cols, group) in [(6, 40, 8), (4, 24, 6)] {
            let lin = random_linear(rows, cols, group, 61).to_packed();
            let x = Matrix::randn(5, cols, 1.0, &mut rng);
            let mut y = Matrix::zeros(5, rows);
            gemm_packed_blocked_into(&lin, &x, &mut y, &mut scratch);
            let expect = gemm_packed(&lin, &x);
            assert_eq!(y.data, expect.data);
        }
    }

    #[test]
    fn block_boundary_sizes() {
        // m spanning multiple XBLOCKs including a ragged tail
        let mut rng = Rng::new(56);
        let lin = random_linear(3, 16, 4, 57);
        let x = Matrix::randn(XBLOCK * 2 + 3, 16, 1.0, &mut rng);
        let dense = matmul(&x, &lin.reconstruct().transpose());
        let y = gemm(&lin, &x);
        for (a, b) in y.data.iter().zip(&dense.data) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()));
        }
    }
}

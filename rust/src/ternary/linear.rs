//! The deployable two-trit-plane linear layer (paper §3.1–§3.2).
//!
//! Stores `T⁽¹⁾, T⁽²⁾` and group-wise scales `α⁽¹⁾, α⁽²⁾` for a weight
//! matrix `W (n×d)` divided into groups of `G` consecutive columns
//! (paper §3.2 reshapes `n×d → (nd/G)×G`; for the kernels we keep the
//! equivalent `(row, group)` indexing so inference never reshapes).

use super::pack::{bytes_2bit, pack2bit, unpack2bit};
use super::plane::TritPlane;
use super::simd::{self, InterleavedPlanes};
use crate::tensor::Matrix;
use std::sync::Arc;

/// Two-plane ternary factorization of one linear layer.
#[derive(Clone, Debug, PartialEq)]
pub struct TernaryLinear {
    /// Output features (rows of W).
    pub rows: usize,
    /// Input features (cols of W).
    pub cols: usize,
    /// Group size G along the column dimension.
    pub group: usize,
    pub t1: TritPlane,
    pub t2: TritPlane,
    /// α⁽¹⁾ indexed `[row * groups_per_row + g]`.
    pub alpha1: Vec<f32>,
    /// α⁽²⁾ indexed the same way.
    pub alpha2: Vec<f32>,
}

impl TernaryLinear {
    /// Groups per weight row. The final group may be ragged when
    /// `G ∤ cols`.
    pub fn groups_per_row(&self) -> usize {
        self.cols.div_ceil(self.group)
    }

    /// Column span of group `g`.
    #[inline]
    pub fn group_span(&self, g: usize) -> (usize, usize) {
        let start = g * self.group;
        (start, (start + self.group).min(self.cols))
    }

    pub fn new(rows: usize, cols: usize, group: usize) -> TernaryLinear {
        assert!(group > 0, "group size must be positive");
        let gpr = cols.div_ceil(group);
        TernaryLinear {
            rows,
            cols,
            group,
            t1: TritPlane::zeros(rows, cols),
            t2: TritPlane::zeros(rows, cols),
            alpha1: vec![0.0; rows * gpr],
            alpha2: vec![0.0; rows * gpr],
        }
    }

    #[inline]
    pub fn alpha_idx(&self, row: usize, g: usize) -> usize {
        row * self.groups_per_row() + g
    }

    /// Dense reconstruction Ŵ = diag(α⁽¹⁾)T⁽¹⁾ + diag(α⁽²⁾)T⁽²⁾
    /// (group-wise scales).
    pub fn reconstruct(&self) -> Matrix {
        let mut w = Matrix::zeros(self.rows, self.cols);
        let gpr = self.groups_per_row();
        for r in 0..self.rows {
            for g in 0..gpr {
                let (s, e) = self.group_span(g);
                let a1 = self.alpha1[self.alpha_idx(r, g)];
                let a2 = self.alpha2[self.alpha_idx(r, g)];
                for c in s..e {
                    w.data[r * self.cols + c] =
                        a1 * self.t1.at(r, c) as f32 + a2 * self.t2.at(r, c) as f32;
                }
            }
        }
        w
    }

    /// ‖W − Ŵ‖²_F against a reference weight matrix.
    pub fn sq_err(&self, w: &Matrix) -> f64 {
        w.sq_err(&self.reconstruct())
    }

    /// Effective stored bits per weight: 2 planes × 2 bits + amortized
    /// FP16 scales (Eq. 13).
    pub fn bits_per_weight(&self) -> f64 {
        let trit_bits = 2.0 * 2.0; // two planes, 2-bit codes
        let scale_bits = 2.0 * 16.0 / self.group as f64; // two α per group
        trit_bits + scale_bits
    }

    /// Total storage bytes in the deployment format (Eq. 13):
    /// `2 planes × 2bit × n·d + 2 α-vectors × FP16 × n·(d/G)`.
    pub fn memory_bytes(&self) -> usize {
        let plane_bytes = 2 * bytes_2bit(self.rows * self.cols);
        let alpha_bytes = 2 * self.rows * self.groups_per_row() * 2; // fp16
        plane_bytes + alpha_bytes
    }

    /// Pack both planes into the 2-bit deployment format (row-major,
    /// per-plane streams). Also builds the row-interleaved SIMD layout
    /// when the process-wide SIMD mode allows it (quantize-time cost,
    /// serve-time win).
    pub fn to_packed(&self) -> PackedTernaryLinear {
        let mut p = PackedTernaryLinear {
            rows: self.rows,
            cols: self.cols,
            group: self.group,
            row_stride: bytes_2bit(self.cols),
            p1: pack_rows(&self.t1),
            p2: pack_rows(&self.t2),
            alpha1: self.alpha1.clone(),
            alpha2: self.alpha2.clone(),
            interleave: None,
        };
        p.ensure_interleave();
        p
    }

    /// Mean |α| over both planes (diagnostic; bounded per Appendix C.2).
    pub fn mean_abs_alpha(&self) -> f64 {
        let n = (self.alpha1.len() + self.alpha2.len()).max(1) as f64;
        (self.alpha1.iter().chain(&self.alpha2).map(|a| a.abs() as f64).sum::<f64>()) / n
    }
}

/// Pack every row independently so rows start byte-aligned (needed for
/// row-parallel kernels).
fn pack_rows(t: &TritPlane) -> Vec<u8> {
    let stride = bytes_2bit(t.cols);
    let mut out = vec![0u8; t.rows * stride];
    for r in 0..t.rows {
        let packed = pack2bit(t.row(r));
        out[r * stride..r * stride + packed.len()].copy_from_slice(&packed);
    }
    out
}

/// 2-bit packed deployment form — what the serving engine keeps resident.
#[derive(Clone, Debug)]
pub struct PackedTernaryLinear {
    pub rows: usize,
    pub cols: usize,
    pub group: usize,
    /// Bytes per packed row.
    pub row_stride: usize,
    pub p1: Vec<u8>,
    pub p2: Vec<u8>,
    pub alpha1: Vec<f32>,
    pub alpha2: Vec<f32>,
    /// Derived row-interleaved copy for the SIMD row-block kernels
    /// (DESIGN.md §SIMD-Kernels) — `None` on ragged layouts, when the
    /// SIMD mode is `off`, or until [`PackedTernaryLinear::ensure_interleave`]
    /// runs. `Arc` so model/replica clones share one copy. **Not part of
    /// layer identity** (excluded from `PartialEq`); after mutating the
    /// flat planes/scales directly, call
    /// [`PackedTernaryLinear::refresh_interleave`].
    pub interleave: Option<Arc<InterleavedPlanes>>,
}

/// Equality is over the logical layer (shape, planes, scales); the
/// interleave is derived data and deliberately excluded, so a loaded
/// layer equals its in-memory source regardless of SIMD mode.
impl PartialEq for PackedTernaryLinear {
    fn eq(&self, o: &PackedTernaryLinear) -> bool {
        self.rows == o.rows
            && self.cols == o.cols
            && self.group == o.group
            && self.row_stride == o.row_stride
            && self.p1 == o.p1
            && self.p2 == o.p2
            && self.alpha1 == o.alpha1
            && self.alpha2 == o.alpha2
    }
}

impl PackedTernaryLinear {
    pub fn groups_per_row(&self) -> usize {
        self.cols.div_ceil(self.group)
    }

    /// Build the row-interleaved SIMD layout when the process-wide SIMD
    /// mode allows it and the layout qualifies (byte-aligned groups, at
    /// least one full lane block). Idempotent; `--simd off` makes this
    /// a no-op, which is the exact scalar escape hatch.
    pub fn ensure_interleave(&mut self) {
        if self.interleave.is_some() || !simd::enabled() {
            return;
        }
        self.interleave = simd::build_interleave(self, simd::detected_lanes()).map(Arc::new);
    }

    /// Drop and rebuild the derived SIMD layout — required after any
    /// direct mutation of `p1`/`p2`/`alpha1`/`alpha2` (the interleave
    /// is a copy, not a view).
    pub fn refresh_interleave(&mut self) {
        self.interleave = None;
        self.ensure_interleave();
    }

    /// Test/bench hook: force a specific lane width, or strip the
    /// interleave with `None` (guaranteed scalar dispatch). Ignores the
    /// process-wide mode by design.
    pub fn set_interleave_lanes(&mut self, lanes: Option<usize>) {
        self.interleave = lanes
            .and_then(|n| simd::build_interleave(self, n))
            .map(Arc::new);
    }

    /// Unpack back to the i8 working form (tests / cross-checks).
    pub fn unpack(&self) -> TernaryLinear {
        let mut t1 = TritPlane::zeros(self.rows, self.cols);
        let mut t2 = TritPlane::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let row1 = unpack2bit(
                &self.p1[r * self.row_stride..(r + 1) * self.row_stride],
                self.cols,
            );
            let row2 = unpack2bit(
                &self.p2[r * self.row_stride..(r + 1) * self.row_stride],
                self.cols,
            );
            t1.row_mut(r).copy_from_slice(&row1);
            t2.row_mut(r).copy_from_slice(&row2);
        }
        TernaryLinear {
            rows: self.rows,
            cols: self.cols,
            group: self.group,
            t1,
            t2,
            alpha1: self.alpha1.clone(),
            alpha2: self.alpha2.clone(),
        }
    }

    /// Resident bytes of the deployment format (planes + f32 scales as
    /// stored here). Deliberately excludes the derived SIMD interleave:
    /// exhibits compare this against the paper's Eq. 13 memory model,
    /// and the checkpoint manifest's report must not depend on which
    /// machine (or SIMD mode) packed the layer — see
    /// [`PackedTernaryLinear::interleave_bytes`] for the extra copy.
    pub fn resident_bytes(&self) -> usize {
        self.p1.len() + self.p2.len() + 4 * (self.alpha1.len() + self.alpha2.len())
    }

    /// Bytes held by the derived SIMD interleave (0 when not built) —
    /// roughly a second copy of the planes and scales for full blocks.
    pub fn interleave_bytes(&self) -> usize {
        self.interleave.as_deref().map_or(0, |il| {
            il.p1.len() + il.p2.len() + 4 * (il.a1.len() + il.a2.len())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_linear(rows: usize, cols: usize, group: usize, seed: u64) -> TernaryLinear {
        let mut rng = Rng::new(seed);
        let mut lin = TernaryLinear::new(rows, cols, group);
        for t in lin.t1.trits.iter_mut().chain(lin.t2.trits.iter_mut()) {
            *t = rng.below(3) as i8 - 1;
        }
        for a in lin.alpha1.iter_mut().chain(lin.alpha2.iter_mut()) {
            *a = rng.normal() * 0.1;
        }
        lin
    }

    #[test]
    fn reconstruct_shapes() {
        let lin = random_linear(6, 10, 4, 1);
        let w = lin.reconstruct();
        assert_eq!((w.rows, w.cols), (6, 10));
        assert_eq!(lin.groups_per_row(), 3); // 4+4+2 ragged tail
    }

    #[test]
    fn reconstruct_values_groupwise() {
        let mut lin = TernaryLinear::new(1, 4, 2);
        lin.t1.trits = vec![1, -1, 0, 1];
        lin.t2.trits = vec![0, 1, 1, -1];
        lin.alpha1 = vec![2.0, 10.0];
        lin.alpha2 = vec![0.5, 1.0];
        let w = lin.reconstruct();
        // col0: 2*1 + 0.5*0 = 2 ; col1: 2*-1 + 0.5*1 = -1.5
        // col2: 10*0 + 1*1 = 1 ; col3: 10*1 + 1*-1 = 9
        assert_eq!(w.data, vec![2.0, -1.5, 1.0, 9.0]);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let lin = random_linear(9, 37, 8, 2);
        let packed = lin.to_packed();
        let back = packed.unpack();
        assert_eq!(back.t1, lin.t1);
        assert_eq!(back.t2, lin.t2);
        assert_eq!(back.alpha1, lin.alpha1);
    }

    #[test]
    fn bits_per_weight_near_paper_value() {
        // G=128: 4 bits of trits + 32/128 bits of scales = 4.25
        let lin = TernaryLinear::new(4, 256, 128);
        assert!((lin.bits_per_weight() - 4.25).abs() < 1e-9);
    }

    #[test]
    fn memory_model_eq13() {
        // n=1024, d=4096, G=128 → paper Appendix A.3 example:
        // planes = 2 * (1024*4096)/4 bytes = 2 MiB, α = 2*1024*32*2 B
        let lin = TernaryLinear::new(1024, 4096, 128);
        let m = lin.memory_bytes();
        assert_eq!(m, 2 * 1024 * 4096 / 4 + 2 * 1024 * 32 * 2);
    }

    #[test]
    fn ragged_group_span() {
        let lin = TernaryLinear::new(2, 10, 4);
        assert_eq!(lin.group_span(2), (8, 10));
    }

    #[test]
    fn sq_err_zero_for_own_reconstruction() {
        let lin = random_linear(5, 16, 4, 3);
        let w = lin.reconstruct();
        assert!(lin.sq_err(&w) < 1e-12);
    }
}

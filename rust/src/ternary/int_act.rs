//! Integer-activation kernel tier — int8 activations × ternary planes
//! with exact i32 accumulation (DESIGN.md §Integer-Kernels).
//!
//! Every f32 tier (packed, LUT, SIMD) argues determinism through
//! fold-order discipline: parallel and vector kernels must replay the
//! scalar kernel's FP operation order bit for bit. This tier removes
//! the argument instead of repeating it. Activations are quantized to
//! int8 per LUT group (symmetric absmax, one scale per group per
//! activation row), the per-chunk tables hold **integer** partial sums
//!
//! ```text
//! tab[b] = d₀(b)·q₀ + d₁(b)·q₁ + d₂(b)·q₂ + d₃(b)·q₃   (i32, |·| ≤ 508)
//! ```
//!
//! and the inner loop is one table load + one i32 add per byte per
//! plane. Integer addition is associative, so **any** thread split,
//! SIMD width, or dispatch shape produces the same group sums exactly;
//! the single f32 rescale `a_scale·(α₁·s₁ + α₂·s₂)` per (row, group)
//! happens in one fixed place at the end. Range safety:
//!
//! * table entries: 4 trits × |q| ≤ 127 → |tab| ≤ 508 < i16::MAX
//!   (stored as i32 anyway — AVX2 has no 16-bit gather; see
//!   `simd::int_block8`);
//! * group sums: ≤ (G/4)·508 per group — i32 overflows only past
//!   ~16.9 M columns per group, and `s as f32` is exact (< 2²⁴) up to
//!   ~132 K columns per group. Model groups are ≤ a few hundred.
//!
//! Unlike the f32 tiers this one is **value-changing** (activations are
//! rounded), so it is opt-in: `Auto` resolves *off*, and the dispatch
//! gate is a per-scratch `act_quant` flag that defaults to off — the
//! mode only reaches inference through the CLI / serve entry points.
//! The parity discipline shifts accordingly: int8 output must be
//! `==`-exact across threads / SIMD lanes / batch shapes (pinned by
//! `int_tier_deterministic_matrix`), and within a perplexity tolerance
//! of the f32 tiers (gated in `bench --kernels`).

use super::gemm::GemmScratch;
use super::linear::PackedTernaryLinear;
use super::lut::is_aligned;
use super::simd;
use crate::tensor::Matrix;
use crate::threads::{run_spans, worth_parallel, Pool, SendPtr};
use std::ops::Range;
use std::sync::OnceLock;

/// Process-wide activation-quantization policy, mirroring
/// [`simd::SimdMode`]: `--act-quant auto|on|off` (CLI, [`set_mode`]) >
/// `PTQTP_ACT_QUANT` env > `Auto`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ActQuantMode {
    /// Defer to the default. Because the tier changes served values,
    /// the default is **off** — opposite of `SimdMode::Auto`.
    Auto,
    /// Run aligned ternary layers on the int8 tier.
    On,
    /// Keep every layer on the f32 tiers (bitwise-legacy outputs).
    Off,
}

impl ActQuantMode {
    /// Parse a CLI/env value. Empty means unset (`Auto`).
    pub fn parse(s: &str) -> Option<ActQuantMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "" | "auto" => Some(ActQuantMode::Auto),
            "on" | "1" | "true" | "force" => Some(ActQuantMode::On),
            "off" | "0" | "false" => Some(ActQuantMode::Off),
            _ => None,
        }
    }

    /// Whether this mode turns the tier on. `Auto` resolves off: the
    /// tier perturbs outputs, so it must be asked for explicitly.
    pub fn resolves_on(self) -> bool {
        self == ActQuantMode::On
    }
}

static MODE: OnceLock<ActQuantMode> = OnceLock::new();

/// Pin the process-wide mode (the CLI calls this for `--act-quant`
/// before any model is loaded). First caller wins; later calls are
/// no-ops so tests cannot race the CLI.
pub fn set_mode(m: ActQuantMode) {
    let _ = MODE.set(m);
}

/// Resolved mode: pinned value, else `PTQTP_ACT_QUANT`, else `Auto`.
pub fn mode() -> ActQuantMode {
    *MODE.get_or_init(|| {
        std::env::var("PTQTP_ACT_QUANT")
            .ok()
            .and_then(|v| ActQuantMode::parse(&v))
            .unwrap_or(ActQuantMode::Auto)
    })
}

/// True only for an explicit `on` — `auto` keeps the exact f32 tiers.
pub fn enabled() -> bool {
    mode().resolves_on()
}

/// Tier label honoring the mode — what serve logs and bench JSON print.
pub fn label() -> &'static str {
    if enabled() { "int8" } else { "off" }
}

/// Per-lane scratch for the int tier: the quantized activation row,
/// its per-group scales, and the i32 per-chunk tables. Owned by
/// [`GemmScratch`] (one per pool lane) so the hot loop never allocates.
#[derive(Clone, Debug, Default)]
pub struct IntActScratch {
    pub(crate) q: Vec<i8>,
    pub(crate) scales: Vec<f32>,
    pub(crate) tables: Vec<i32>,
}

impl IntActScratch {
    /// Quantize one activation row and build its chunk tables.
    pub(crate) fn prepare(&mut self, x: &[f32], group: usize) {
        quantize_row_groups(x, group, &mut self.q, &mut self.scales);
        fill_tables_int(&self.q, &mut self.tables);
    }
}

/// Symmetric per-group int8 quantization of one activation row:
/// `scales[g] = absmax_g / 127`, `q = round(x·127/absmax_g)` clamped to
/// ±127. An all-zero group gets scale 0 and zero codes, so zero
/// activations stay exactly zero through the tier. Deterministic by
/// construction — a pure per-element function of `x`.
pub fn quantize_row_groups(x: &[f32], group: usize, q: &mut Vec<i8>, scales: &mut Vec<f32>) {
    let cols = x.len();
    let gpr = cols.div_ceil(group.max(1));
    q.resize(cols, 0);
    scales.resize(gpr, 0.0);
    for g in 0..gpr {
        let start = g * group;
        let end = (start + group).min(cols);
        let mut m = 0.0f32;
        for &v in &x[start..end] {
            m = m.max(v.abs());
        }
        if m == 0.0 {
            scales[g] = 0.0;
            q[start..end].fill(0);
        } else {
            let inv = 127.0 / m;
            scales[g] = m / 127.0;
            for (qv, &xv) in q[start..end].iter_mut().zip(&x[start..end]) {
                *qv = (xv * inv).round().clamp(-127.0, 127.0) as i8;
            }
        }
    }
}

/// Build the per-chunk integer tables for one quantized activation row
/// (`q.len() % 4 == 0`): `tables[c*256 + b]` is chunk `c`'s partial sum
/// for byte code `b`. Every entry fits i16 (|·| ≤ 4·127 = 508); stored
/// as i32 so the AVX2 kernel can gather them directly.
pub fn fill_tables_int(q: &[i8], tables: &mut Vec<i32>) {
    debug_assert_eq!(q.len() % 4, 0, "int tier requires 4-aligned activations");
    let chunks = q.len() / 4;
    tables.resize(chunks * 256, 0);
    for (qc, seg) in q.chunks_exact(4).zip(tables.chunks_exact_mut(256)) {
        fill_chunk_int(qc, seg);
    }
}

/// Fill one 256-entry chunk table by the same dynamic program as the
/// f32 `lut::fill_chunk` — but over integers, where association is
/// irrelevant: the build order is a speed choice only.
#[inline]
fn fill_chunk_int(q: &[i8], seg: &mut [i32]) {
    // 2-bit code → trit factor, matching `pack::dec2` (0b11 → 0).
    const DEC: [i32; 4] = [0, 1, -1, 0];
    debug_assert_eq!(q.len(), 4);
    debug_assert_eq!(seg.len(), 256);
    for (code, slot) in seg.iter_mut().enumerate().take(4) {
        *slot = DEC[code] * q[0] as i32;
    }
    for trit in 1..4 {
        let width = 1usize << (2 * trit); // 4^trit entries already valid
        for code in (0..4usize).rev() {
            let add = DEC[code] * q[trit] as i32;
            let base = code * width;
            for lo in 0..width {
                seg[base + lo] = seg[lo] + add;
            }
        }
    }
}

/// Core int row sweep: output rows `rows` into `y_span`
/// (`y_span[i]` = row `rows.start + i`). Group sums are exact i32; the
/// only FP work is the fixed per-group rescale
/// `acc += a_scale·(α₁·s₁ + α₂·s₂)`, evaluated groups-ascending in
/// this one place — shared verbatim (lanewise) by the SIMD blocks, so
/// every dispatch shape produces identical bits.
pub(crate) fn int_rows_span(
    lin: &PackedTernaryLinear,
    tables: &[i32],
    scales: &[f32],
    rows: Range<usize>,
    y_span: &mut [f32],
) {
    debug_assert_eq!(y_span.len(), rows.len());
    let gpr = lin.groups_per_row();
    let stride = lin.row_stride;
    let y0 = rows.start;
    for r in rows {
        let p1 = &lin.p1[r * stride..(r + 1) * stride];
        let p2 = &lin.p2[r * stride..(r + 1) * stride];
        let mut acc = 0.0f32;
        for g in 0..gpr {
            let start = g * lin.group;
            let end = (start + lin.group).min(lin.cols);
            let mut s1 = 0i32;
            let mut s2 = 0i32;
            for b in start / 4..end / 4 {
                let seg = &tables[b * 256..b * 256 + 256];
                s1 += seg[p1[b] as usize];
                s2 += seg[p2[b] as usize];
            }
            let ai = r * gpr + g;
            acc += scales[g] * (lin.alpha1[ai] * s1 as f32 + lin.alpha2[ai] * s2 as f32);
        }
        y_span[r - y0] = acc;
    }
}

/// Partition one output vector's rows across the pool's lanes — the
/// shared read-only tables/scales make this embarrassingly parallel,
/// and the integer sums make it exact for any lane count.
fn int_row_par(
    lin: &PackedTernaryLinear,
    tables: &[i32],
    scales: &[f32],
    y_row: &mut [f32],
    pool: &Pool,
) {
    run_spans(pool, lin.rows, 1, y_row, |_, rows, span| {
        int_rows_span(lin, tables, scales, rows, span);
    });
}

/// Pool-aware int8 gemv over engine scratch (decode path). Quantizes
/// the row + builds tables once on the leader, then sweeps — SIMD
/// row-blocked when the layer carries an interleaved layout, else
/// scalar (row-partitioned when the pool has lanes). All three paths
/// are `==`-exact to each other.
pub fn gemv_int_into(lin: &PackedTernaryLinear, x: &[f32], y: &mut [f32], scratch: &mut GemmScratch) {
    assert!(is_aligned(lin), "int tier requires byte-aligned groups");
    assert_eq!(x.len(), lin.cols, "gemv dim mismatch");
    assert_eq!(y.len(), lin.rows);
    let pool = scratch.pool.clone();
    let lanes = pool.threads();
    let il = if scratch.simd {
        lin.interleave.as_deref()
    } else {
        None
    };
    scratch.ensure_lanes(lanes);
    let act = &mut scratch.int_lanes[0];
    act.prepare(x, lin.group);
    let (tables, scales) = (&act.tables[..], &act.scales[..]);
    if let Some(il) = il {
        simd::int_sweep(lin, il, tables, scales, y, &pool);
    } else if lanes <= 1 || !worth_parallel(lin.rows, lin.cols) {
        int_rows_span(lin, tables, scales, 0..lin.rows, y);
    } else {
        int_row_par(lin, tables, scales, y, &pool);
    }
}

/// Pool-aware int8 gemm `Y = X · Ŵᵀ` (prefill / batched serving path).
/// Each X row is quantized independently, so per-row output is
/// `==`-exact to [`gemv_int_into`] on the same row regardless of batch
/// shape — the property the engine's batched-vs-sequential parity
/// rests on for this tier. Parallel split mirrors the LUT tier: by X
/// row when the batch is deep enough (each lane quantizes into its own
/// scratch), else by output channel.
pub fn gemm_int_into(lin: &PackedTernaryLinear, x: &Matrix, y: &mut Matrix, scratch: &mut GemmScratch) {
    assert!(is_aligned(lin), "int tier requires byte-aligned groups");
    assert_eq!(x.cols, lin.cols, "gemm inner dim mismatch");
    assert_eq!(y.rows, x.rows, "gemm out rows mismatch");
    assert_eq!(y.cols, lin.rows, "gemm out cols mismatch");
    let pool = scratch.pool.clone();
    let lanes = pool.threads();
    let il = if scratch.simd {
        lin.interleave.as_deref()
    } else {
        None
    };
    scratch.ensure_lanes(lanes);
    if lanes > 1 && x.rows >= lanes && worth_parallel(x.rows * lin.rows, lin.cols) {
        // deep batch: lanes own disjoint X-row spans end to end
        let acts = SendPtr(scratch.int_lanes.as_mut_ptr());
        let n_out = lin.rows;
        run_spans(&pool, x.rows, n_out, &mut y.data, |lane, rows, span| {
            // SAFETY: one int scratch per lane (ensure_lanes sized the
            // vec), alive past `run` because the leader blocks in it.
            let act = unsafe { &mut *acts.get().add(lane) };
            for (i, r) in rows.enumerate() {
                act.prepare(x.row(r), lin.group);
                let out = &mut span[i * n_out..(i + 1) * n_out];
                match il {
                    Some(il) => simd::int_rows_all(lin, il, &act.tables, &act.scales, out),
                    None => int_rows_span(lin, &act.tables, &act.scales, 0..n_out, out),
                }
            }
        });
        return;
    }
    // shallow batch: per X row, quantize once and split output channels
    for r in 0..x.rows {
        let act = &mut scratch.int_lanes[0];
        act.prepare(x.row(r), lin.group);
        let (tables, scales) = (&act.tables[..], &act.scales[..]);
        let row = &mut y.data[r * lin.rows..(r + 1) * lin.rows];
        if let Some(il) = il {
            simd::int_sweep(lin, il, tables, scales, row, &pool);
        } else if lanes <= 1 || !worth_parallel(lin.rows, lin.cols) {
            int_rows_span(lin, tables, scales, 0..lin.rows, row);
        } else {
            int_row_par(lin, tables, scales, row, &pool);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workload::random_ternary as random_linear;
    use crate::model::linear::{Backend, QuantLinear};
    use crate::proptest::{check, prop_assert, Gen};
    use crate::rng::Rng;
    use crate::ternary::gemv::gemv_packed;
    use crate::ternary::lut::LUT_MIN_ROWS;

    #[test]
    fn mode_parsing_and_auto_resolves_off() {
        assert_eq!(ActQuantMode::parse("auto"), Some(ActQuantMode::Auto));
        assert_eq!(ActQuantMode::parse(""), Some(ActQuantMode::Auto));
        assert_eq!(ActQuantMode::parse("ON"), Some(ActQuantMode::On));
        assert_eq!(ActQuantMode::parse("force"), Some(ActQuantMode::On));
        assert_eq!(ActQuantMode::parse("off"), Some(ActQuantMode::Off));
        assert_eq!(ActQuantMode::parse("0"), Some(ActQuantMode::Off));
        assert_eq!(ActQuantMode::parse("int8"), None);
        // the tier changes values, so only an explicit `on` enables it
        assert!(!ActQuantMode::Auto.resolves_on());
        assert!(!ActQuantMode::Off.resolves_on());
        assert!(ActQuantMode::On.resolves_on());
    }

    #[test]
    fn quantize_row_groups_basics() {
        let x = [0.0f32, 0.0, 0.0, 0.0, 2.0, -4.0, 1.0, 0.5];
        let mut q = Vec::new();
        let mut scales = Vec::new();
        quantize_row_groups(&x, 4, &mut q, &mut scales);
        // all-zero group: scale 0, zero codes
        assert_eq!(scales[0], 0.0);
        assert_eq!(&q[0..4], &[0i8, 0, 0, 0]);
        // absmax 4 → scale 4/127; the extreme hits −127 exactly and
        // 2.0·(127/4) = 63.5 rounds half-away-from-zero to 64
        assert_eq!(scales[1], 4.0 / 127.0);
        assert_eq!(q[4], 64);
        assert_eq!(q[5], -127);
        assert_eq!(q[6], 32);
        assert_eq!(q[7], 16);
    }

    #[test]
    fn int_tables_match_direct_sums_and_fit_i16() {
        let mut rng = Rng::new(5);
        let mut q: Vec<i8> = (0..32).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        // force the extreme magnitudes into the first chunk
        q[0] = 127;
        q[1] = -127;
        q[2] = 127;
        q[3] = -127;
        let mut tables = Vec::new();
        fill_tables_int(&q, &mut tables);
        let lut = crate::ternary::lut::decode_lut_i8();
        for (c, seg) in tables.chunks_exact(256).enumerate() {
            let qc = &q[c * 4..c * 4 + 4];
            for (b, &got) in seg.iter().enumerate() {
                let d = lut[b];
                let want: i32 = (0..4).map(|i| d[i] as i32 * qc[i] as i32).sum();
                assert_eq!(got, want, "chunk {c} byte {b}");
                assert!((-508..=508).contains(&got), "i16 range safety violated");
            }
        }
    }

    #[test]
    fn int_gemv_within_quantization_error_bound() {
        // the tier is value-changing but boundedly so: per element the
        // dequantized activation is within scale/2 of the original, and
        // trits are in {−1,0,1}, so each output row differs from the
        // f32 tier by at most Σ_g (|α₁|+|α₂|)·|group|·scale_g/2
        let mut rng = Rng::new(77);
        for (rows, cols, group) in [(64usize, 128usize, 32usize), (96, 64, 64), (80, 24, 16)] {
            let packed = random_linear(rows, cols, group, 700 + rows as u64).to_packed();
            let x: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
            let mut y_f32 = vec![0.0f32; rows];
            gemv_packed(&packed, &x, &mut y_f32);
            let mut scratch = GemmScratch::new();
            scratch.act_quant = true;
            let mut y_int = vec![0.0f32; rows];
            gemv_int_into(&packed, &x, &mut y_int, &mut scratch);
            let mut q = Vec::new();
            let mut scales = Vec::new();
            quantize_row_groups(&x, group, &mut q, &mut scales);
            let gpr = packed.groups_per_row();
            for r in 0..rows {
                let mut bound = 1e-3f32;
                for g in 0..gpr {
                    let start = g * group;
                    let end = (start + group).min(cols);
                    let ai = r * gpr + g;
                    let amag = packed.alpha1[ai].abs() + packed.alpha2[ai].abs();
                    bound += amag * scales[g] * 0.51 * (end - start) as f32;
                }
                let diff = (y_int[r] - y_f32[r]).abs();
                assert!(diff <= bound, "row {r}: |{} - {}| > {bound}", y_int[r], y_f32[r]);
            }
        }
    }

    #[test]
    fn int_gemm_matches_gemv_per_row_exactly() {
        // shallow (m=3) and deep (m=40, clears the X-row split gate)
        // batches, every thread count and SIMD setting: `==`-exact
        let mut rng = Rng::new(21);
        for (rows, cols, group, m) in [(64usize, 32usize, 8usize, 3usize), (200, 64, 16, 40)] {
            let packed = random_linear(rows, cols, group, 210 + m as u64).to_packed();
            let x = Matrix::randn(m, cols, 1.0, &mut rng);
            let mut y_ref = Matrix::zeros(m, rows);
            let mut scratch = GemmScratch::new();
            scratch.act_quant = true;
            scratch.simd = false;
            for r in 0..m {
                let row = &mut y_ref.data[r * rows..(r + 1) * rows];
                gemv_int_into(&packed, x.row(r), row, &mut scratch);
            }
            for threads in [1usize, 2, 4] {
                for simd_on in [false, true] {
                    let mut scratch = GemmScratch::new();
                    scratch.pool = Pool::new(threads);
                    scratch.act_quant = true;
                    scratch.simd = simd_on;
                    let mut y = Matrix::zeros(m, rows);
                    gemm_int_into(&packed, &x, &mut y, &mut scratch);
                    assert_eq!(y.data, y_ref.data, "threads={threads} simd={simd_on} m={m}");
                }
            }
        }
    }

    #[test]
    fn int_tier_deterministic_matrix() {
        // the satellite property: random aligned / ragged / zero-plane
        // layouts × interleave lanes {none, 4, detected} × threads
        // {1, 2} × batched-vs-single-row dispatch — one `==`-exact
        // output per case across the whole matrix. Ragged layouts fall
        // back to the (bit-identical) f32 tiers under the same gate the
        // model dispatch uses, so they are in-matrix deliberately.
        check(24, |g: &mut Gen| {
            let kind = *g.pick(&[0usize, 1, 2]); // aligned / ragged / zero-plane
            let rows = LUT_MIN_ROWS + g.usize_in(0, 80);
            let (cols, group) = if kind == 1 {
                (36, 10) // G % 4 != 0: dispatch falls back to f32 tiers
            } else {
                (4 * g.usize_in(2, 16), 4 * *g.pick(&[1usize, 2, 4, 8]))
            };
            let mut lin = random_linear(rows, cols, group, g.rng.next_u64());
            if kind == 2 {
                for t in lin.t1.trits.iter_mut().chain(lin.t2.trits.iter_mut()) {
                    *t = 0;
                }
            }
            let packed = lin.to_packed();
            let m = 1 + g.usize_in(0, 4);
            let x = Matrix::randn(m, cols, 1.0, &mut g.rng);
            let x1 = Matrix::from_vec(1, cols, x.row(0).to_vec());
            let mut reference: Option<Vec<f32>> = None;
            for lanes in [None, Some(4), Some(simd::detected_lanes())] {
                let mut p = packed.clone();
                p.set_interleave_lanes(lanes);
                let shape = (p.rows, p.cols);
                let ql = QuantLinear {
                    backend: Backend::Ternary(p),
                    shape,
                };
                for threads in [1usize, 2] {
                    let mut scratch = GemmScratch::new();
                    scratch.pool = Pool::new(threads);
                    scratch.simd = lanes.is_some();
                    scratch.act_quant = true;
                    let mut y = Matrix::zeros(m, rows);
                    ql.forward_rows_into(&x, &mut y, &mut scratch);
                    let mut y1 = Matrix::zeros(1, rows);
                    ql.forward_rows_into(&x1, &mut y1, &mut scratch);
                    prop_assert(
                        y.row(0) == y1.row(0),
                        format!("batched vs single-row drift (kind={kind} lanes={lanes:?} threads={threads})"),
                    )?;
                    if kind == 2 {
                        prop_assert(
                            y.data.iter().all(|&v| v == 0.0),
                            "zero planes must give exactly zero output",
                        )?;
                    }
                    match &reference {
                        None => reference = Some(y.data.clone()),
                        Some(want) => prop_assert(
                            &y.data == want,
                            format!("int tier drift (kind={kind} lanes={lanes:?} threads={threads})"),
                        )?,
                    }
                }
            }
            Ok(())
        });
    }
}

//! BiLLM (Huang et al., 2024) — structured salient/non-salient split
//! with residual binarization.
//!
//! Salient weights (top fraction by second-order saliency `w²·h_j`,
//! where `h_j` is the Hessian diagonal from calibration, or `w²` without
//! calibration) receive **residual binarization** — two binary planes,
//! `α₁·sign(w)` then `α₂·sign(residual)`. Non-salient weights follow the
//! "bell-shaped distribution splitting": each group's remainder is split
//! at an optimal magnitude break into two sub-sets, each binarized with
//! its own scale. Effective bits ≈ 1.06–1.1 (1 bit + masks + scales).

use super::{QuantCtx, QuantRepr, QuantResult, Quantizer};
use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug)]
pub struct BiLlm {
    pub group: usize,
    pub salient_frac: f64,
}

impl BiLlm {
    pub fn new(group: usize) -> BiLlm {
        BiLlm {
            group,
            salient_frac: 0.05,
        }
    }
}

/// Least-squares binarization of an index subset: α = mean|w|, b=sign.
/// Writes `α·sign(w)` into `out` and returns the squared error.
fn binarize_subset(w: &[f32], idx: &[usize], out: &mut [f32]) -> f64 {
    if idx.is_empty() {
        return 0.0;
    }
    let alpha = idx.iter().map(|&j| w[j].abs()).sum::<f32>() / idx.len() as f32;
    let mut err = 0.0f64;
    for &j in idx {
        let v = alpha * w[j].signum();
        out[j] = v;
        err += ((w[j] - v) as f64).powi(2);
    }
    err
}

/// Residual (second-order) binarization of a subset.
fn residual_binarize_subset(w: &[f32], idx: &[usize], out: &mut [f32]) {
    if idx.is_empty() {
        return;
    }
    let a1 = idx.iter().map(|&j| w[j].abs()).sum::<f32>() / idx.len() as f32;
    let a2 = idx
        .iter()
        .map(|&j| (w[j] - a1 * w[j].signum()).abs())
        .sum::<f32>()
        / idx.len() as f32;
    for &j in idx {
        let b1 = w[j].signum();
        let r = w[j] - a1 * b1;
        let b2 = if r < 0.0 { -1.0 } else { 1.0 };
        out[j] = a1 * b1 + a2 * b2;
    }
}

impl Quantizer for BiLlm {
    fn name(&self) -> String {
        "BiLLM-b1.06".into()
    }

    fn nominal_bits(&self) -> f64 {
        1.06
    }

    fn quantize(&self, w: &Matrix, ctx: &QuantCtx) -> QuantResult {
        let group = if self.group == 0 { w.cols } else { self.group };
        // Hessian diagonal proxy for saliency
        let hdiag: Vec<f32> = match ctx.calib.as_ref() {
            Some(x) => {
                let mut h = vec![0.0f32; w.cols];
                for r in 0..x.rows {
                    for (j, &v) in x.row(r).iter().enumerate() {
                        h[j] += v * v;
                    }
                }
                h
            }
            None => vec![1.0; w.cols],
        };

        let mut w_hat = Matrix::zeros(w.rows, w.cols);
        for r in 0..w.rows {
            let row = w.row(r);
            for (gs, chunk) in row.chunks(group).enumerate() {
                let start = gs * group;
                let g = chunk.len();
                // saliency ranking within the group
                let mut order: Vec<usize> = (0..g).collect();
                order.sort_by(|&a, &b| {
                    let sa = chunk[a] * chunk[a] * hdiag[start + a];
                    let sb = chunk[b] * chunk[b] * hdiag[start + b];
                    sb.partial_cmp(&sa).unwrap()
                });
                let n_sal = ((g as f64) * self.salient_frac).ceil() as usize;
                let salient: Vec<usize> = order[..n_sal.min(g)].to_vec();
                let rest: Vec<usize> = order[n_sal.min(g)..].to_vec();

                let out = &mut w_hat.data[r * w.cols + start..r * w.cols + start + g];
                // salient: residual binarization
                residual_binarize_subset(chunk, &salient, out);

                // non-salient: bell-shape split — search the magnitude
                // break that minimizes total binarization error
                if !rest.is_empty() {
                    let mut by_mag = rest.clone();
                    by_mag.sort_by(|&a, &b| chunk[a].abs().partial_cmp(&chunk[b].abs()).unwrap());
                    let mut best_err = f64::INFINITY;
                    let mut best_split = by_mag.len();
                    // coarse search over 8 candidate breaks
                    let candidates: Vec<usize> = (1..8)
                        .map(|i| i * by_mag.len() / 8)
                        .chain([by_mag.len()])
                        .collect();
                    let mut tmp = vec![0.0f32; g];
                    for &split in &candidates {
                        let (lowidx, highidx) = by_mag.split_at(split);
                        let e = binarize_subset(chunk, lowidx, &mut tmp)
                            + binarize_subset(chunk, highidx, &mut tmp);
                        if e < best_err {
                            best_err = e;
                            best_split = split;
                        }
                    }
                    let (lowidx, highidx) = by_mag.split_at(best_split);
                    binarize_subset(chunk, lowidx, out);
                    binarize_subset(chunk, highidx, out);
                }
            }
        }

        // memory model (Eq. 10): binary planes + salient residual plane +
        // group bitmap + scales
        let n = w.rows;
        let d = w.cols;
        let c = ((d as f64) * self.salient_frac) as usize;
        let bytes = (2 * n * c) / 8 + d.div_ceil(group) * 3 * n * 2 + n * d / 8 + d / 8 + 1;
        QuantResult {
            w_hat,
            repr: QuantRepr::Dense,
            bits_per_weight: 1.06 + 32.0 / group as f64,
            memory_bytes: bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn better_than_naive_sign_binarization() {
        let mut rng = Rng::new(1);
        let w = Matrix::rand_heavy(8, 256, 0.04, &mut rng);
        let q = BiLlm::new(128).quantize(&w, &QuantCtx::default());
        // naive: one α per row, sign
        let mut naive = Matrix::zeros(8, 256);
        for r in 0..8 {
            let alpha = w.row(r).iter().map(|x| x.abs()).sum::<f32>() / 256.0;
            for (j, &x) in w.row(r).iter().enumerate() {
                *naive.at_mut(r, j) = alpha * x.signum();
            }
        }
        assert!(w.sq_err(&q.w_hat) < w.sq_err(&naive));
    }

    #[test]
    fn worse_than_ptqtp_reconstruction() {
        // the paper's headline ordering
        let mut rng = Rng::new(2);
        let w = Matrix::rand_heavy(8, 256, 0.04, &mut rng);
        let bi = BiLlm::new(128).quantize(&w, &QuantCtx::default());
        let tp = crate::quant::ptqtp::Ptqtp::default().quantize(&w, &QuantCtx::default());
        let eb = w.sq_err(&bi.w_hat);
        let et = w.sq_err(&tp.w_hat);
        assert!(et < eb * 0.8, "ptqtp {et} vs billm {eb}");
    }

    #[test]
    fn calibration_changes_saliency() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(4, 64, 0.03, &mut rng);
        let x = Matrix::from_fn(32, 64, |_, j| if j < 8 { rng.normal() * 10.0 } else { rng.normal() });
        let with = BiLlm::new(64).quantize(&w, &QuantCtx::with_calib(x));
        let without = BiLlm::new(64).quantize(&w, &QuantCtx::default());
        // reconstructions should differ (different salient sets)
        assert!(with.w_hat != without.w_hat);
    }

    #[test]
    fn handles_tiny_groups() {
        let mut rng = Rng::new(4);
        let w = Matrix::randn(2, 5, 0.05, &mut rng);
        let q = BiLlm::new(3).quantize(&w, &QuantCtx::default());
        assert_eq!(q.w_hat.cols, 5);
        assert!(q.w_hat.data.iter().all(|x| x.is_finite()));
    }
}

//! Small dense linear algebra needed by GPTQ: symmetric positive
//! definite Cholesky factorization and inversion.

use crate::tensor::Matrix;

/// Cholesky factorization A = L·Lᵀ (lower triangular). Returns `None`
/// if A is not positive definite.
pub fn cholesky(a: &Matrix) -> Option<Matrix> {
    assert_eq!(a.rows, a.cols, "cholesky needs a square matrix");
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j) as f64;
            for k in 0..j {
                sum -= l.at(i, k) as f64 * l.at(j, k) as f64;
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                *l.at_mut(i, j) = sum.sqrt() as f32;
            } else {
                *l.at_mut(i, j) = (sum / l.at(j, j) as f64) as f32;
            }
        }
    }
    Some(l)
}

/// Inverse of an SPD matrix via Cholesky: A⁻¹ = L⁻ᵀ·L⁻¹.
pub fn spd_inverse(a: &Matrix) -> Option<Matrix> {
    let l = cholesky(a)?;
    let n = a.rows;
    // Invert L (lower triangular) by forward substitution per column.
    let mut linv = Matrix::zeros(n, n);
    for col in 0..n {
        // solve L x = e_col
        for i in col..n {
            let mut sum = if i == col { 1.0f64 } else { 0.0 };
            for k in col..i {
                sum -= l.at(i, k) as f64 * linv.at(k, col) as f64;
            }
            *linv.at_mut(i, col) = (sum / l.at(i, i) as f64) as f32;
        }
    }
    // A⁻¹ = Lᵀ⁻¹ L⁻¹ = linvᵀ · linv
    let mut inv = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0f64;
            // linvᵀ[i,k] = linv[k,i]; only k ≥ max(i,j) contribute
            for k in i.max(j)..n {
                s += linv.at(k, i) as f64 * linv.at(k, j) as f64;
            }
            *inv.at_mut(i, j) = s as f32;
        }
    }
    Some(inv)
}

/// Upper Cholesky of the *inverse*: the factor GPTQ streams. Computes
/// `U` with `A⁻¹ = Uᵀ·U`... concretely we return `chol(A⁻¹)ᵀ` (upper
/// triangular), matching the reference GPTQ implementation's
/// `cholesky(inv(H), upper=True)`.
pub fn cholesky_inv_upper(a: &Matrix) -> Option<Matrix> {
    let inv = spd_inverse(a)?;
    let l = cholesky(&inv)?;
    Some(l.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::ops::matmul;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let b = Matrix::randn(n, n, 1.0, &mut rng);
        let mut a = matmul(&b, &b.transpose());
        for i in 0..n {
            *a.at_mut(i, i) += n as f32 * 0.1; // ensure well-conditioned
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = random_spd(12, 1);
        let l = cholesky(&a).expect("spd");
        let rec = matmul(&l, &l.transpose());
        for (x, y) in rec.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-2 * (1.0 + y.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Matrix::zeros(2, 2);
        *a.at_mut(0, 0) = 1.0;
        *a.at_mut(1, 1) = -1.0;
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn spd_inverse_correct() {
        let a = random_spd(8, 2);
        let inv = spd_inverse(&a).expect("spd");
        let prod = matmul(&a, &inv);
        for i in 0..8 {
            for j in 0..8 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (prod.at(i, j) - expect).abs() < 1e-3,
                    "({i},{j}) = {}",
                    prod.at(i, j)
                );
            }
        }
    }

    #[test]
    fn cholesky_inv_upper_is_upper_triangular() {
        let a = random_spd(6, 3);
        let u = cholesky_inv_upper(&a).expect("spd");
        for i in 0..6 {
            for j in 0..i {
                assert_eq!(u.at(i, j), 0.0, "({i},{j}) below diagonal");
            }
        }
        // Uᵀ·U == A⁻¹
        let inv = spd_inverse(&a).unwrap();
        let rec = matmul(&u.transpose(), &u);
        for (x, y) in rec.data.iter().zip(&inv.data) {
            assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()));
        }
    }
}

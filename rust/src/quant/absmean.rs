//! Single-plane ternary "absmean" quantizer — the BitNet-b1.58
//! projection (Ma et al., 2024) applied post-training.
//!
//! Serves two roles in the reproduction: (a) the 1-plane ablation that
//! shows why PTQTP's second plane matters, and (b) the projection the
//! JAX QAT trainer (`python/compile/train.py`) uses for the Table 3
//! BitNet comparator, so the two sides share exact semantics.
//!
//! Per group: `γ = mean|w|`, `T = clamp(round(w/γ), -1, 1)`, `Ŵ = γ·T`,
//! with a closed-form least-squares rescale of γ afterwards (keeps the
//! comparison honest — it strictly helps the baseline).

use super::{QuantCtx, QuantRepr, QuantResult, Quantizer};
use crate::tensor::Matrix;
use crate::ternary::TernaryLinear;

#[derive(Clone, Copy, Debug)]
pub struct AbsMean {
    pub group: usize,
}

impl AbsMean {
    pub fn new(group: usize) -> AbsMean {
        AbsMean { group }
    }
}

impl Quantizer for AbsMean {
    fn name(&self) -> String {
        "AbsMean-1.58".into()
    }

    fn nominal_bits(&self) -> f64 {
        1.58
    }

    fn quantize(&self, w: &Matrix, _ctx: &QuantCtx) -> QuantResult {
        let group = if self.group == 0 { w.cols } else { self.group };
        let mut lin = TernaryLinear::new(w.rows, w.cols, group);
        let gpr = lin.groups_per_row();
        for r in 0..w.rows {
            for g in 0..gpr {
                let (s, e) = lin.group_span(g);
                let wg = &w.row(r)[s..e];
                let gamma = wg.iter().map(|x| x.abs()).sum::<f32>() / (e - s).max(1) as f32;
                let gi = r * gpr + g;
                if gamma <= 0.0 {
                    lin.alpha1[gi] = 0.0;
                    continue;
                }
                // project
                let base = r * w.cols;
                let mut tt = 0i64; // Σ t²
                let mut tw = 0.0f64; // Σ t·w
                for (j, &x) in wg.iter().enumerate() {
                    let t = (x / gamma).round().clamp(-1.0, 1.0) as i8;
                    lin.t1.trits[base + s + j] = t;
                    tt += (t as i64) * (t as i64);
                    tw += t as f64 * x as f64;
                }
                // optimal rescale: argmin_γ Σ(w − γt)² = Σtw / Σt²
                lin.alpha1[gi] = if tt > 0 { (tw / tt as f64) as f32 } else { 0.0 };
                lin.alpha2[gi] = 0.0;
            }
        }
        // plane 2 stays zero: reconstruction is α1·T1
        QuantResult {
            w_hat: lin.reconstruct(),
            bits_per_weight: 2.0 + 16.0 / group as f64,
            memory_bytes: crate::ternary::pack::bytes_2bit(w.len()) + lin.alpha1.len() * 2,
            repr: QuantRepr::SinglePlane(lin),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn reconstruction_bounded_error_on_gaussian() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(8, 256, 0.02, &mut rng);
        let q = AbsMean::new(64).quantize(&w, &QuantCtx::default());
        let rel = w.rel_err(&q.w_hat);
        // single ternary plane on gaussian: ~0.4–0.6 relative error
        assert!(rel < 0.7, "rel {rel}");
        assert!(rel > 0.1, "suspiciously good for 1 plane: {rel}");
    }

    #[test]
    fn plane_values_ternary() {
        let mut rng = Rng::new(2);
        let w = Matrix::rand_heavy(4, 64, 0.05, &mut rng);
        let q = AbsMean::new(32).quantize(&w, &QuantCtx::default());
        if let QuantRepr::SinglePlane(lin) = &q.repr {
            assert!(lin.t1.trits.iter().all(|&t| (-1..=1).contains(&t)));
            assert!(lin.t2.trits.iter().all(|&t| t == 0));
        } else {
            panic!("expected single plane repr");
        }
    }

    #[test]
    fn rescale_is_least_squares_optimal() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(1, 64, 0.1, &mut rng);
        let q = AbsMean::new(64).quantize(&w, &QuantCtx::default());
        if let QuantRepr::SinglePlane(lin) = &q.repr {
            let a = lin.alpha1[0];
            // perturbing α must not reduce error
            let err = |alpha: f32| -> f64 {
                w.row(0)
                    .iter()
                    .zip(lin.t1.row(0))
                    .map(|(&x, &t)| ((x - alpha * t as f32) as f64).powi(2))
                    .sum()
            };
            assert!(err(a) <= err(a * 1.01) + 1e-12);
            assert!(err(a) <= err(a * 0.99) + 1e-12);
        }
    }

    #[test]
    fn zero_input() {
        let w = Matrix::zeros(2, 32);
        let q = AbsMean::new(16).quantize(&w, &QuantCtx::default());
        assert!(q.w_hat.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn constant_sign_input_saturates() {
        let w = Matrix::from_vec(1, 4, vec![0.5, 0.5, 0.5, 0.5]);
        let q = AbsMean::new(4).quantize(&w, &QuantCtx::default());
        for &x in &q.w_hat.data {
            assert!((x - 0.5).abs() < 1e-6);
        }
    }
}

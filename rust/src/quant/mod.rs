//! Post-training quantizers: PTQTP (the paper, §3) and every baseline it
//! is evaluated against (§4.1): RTN, GPTQ, AWQ, PB-LLM, BiLLM,
//! ARB-LLM(RC), plus the BitNet-style `absmean` ternary projector used
//! both as a 1-plane ablation and as the QAT comparator's PTQ twin.
//!
//! All methods implement [`Quantizer`] and return a [`QuantResult`]
//! carrying (a) the dense reconstruction Ŵ for evaluation, (b) the
//! structured representation when one exists (trit-planes for PTQTP /
//! absmean) so the serving engine can run the multiply-free kernels, and
//! (c) storage accounting for the Table 4 memory model.

pub mod absmean;
pub mod arbllm;
pub mod awq;
pub mod billm;
pub mod gptq;
pub mod linalg;
pub mod metrics;
pub mod pbllm;
pub mod ptqtp;
pub mod rtn;

pub use metrics::QuantMetrics;
pub use ptqtp::{Ptqtp, PtqtpOpts, PtqtpReport};

use crate::tensor::Matrix;
use crate::ternary::TernaryLinear;

/// Quantization context: optional calibration activations (rows =
/// samples, cols = layer input dim) for activation-aware methods, a
/// seed for any stochastic choices, and the worker pool parallel-aware
/// quantizers (PTQTP's per-row progressive approximation, the model
/// loader's per-matrix sweep) partition work on. The sequential default
/// reproduces the legacy path exactly; results are bit-identical for
/// any thread count (DESIGN.md §Threading).
#[derive(Clone, Debug, Default)]
pub struct QuantCtx {
    pub calib: Option<Matrix>,
    pub seed: u64,
    pub pool: crate::threads::Pool,
}

impl QuantCtx {
    pub fn with_calib(calib: Matrix) -> QuantCtx {
        QuantCtx {
            calib: Some(calib),
            ..Default::default()
        }
    }

    /// Context whose parallel-aware quantizers run on `threads` lanes.
    pub fn with_threads(threads: usize) -> QuantCtx {
        QuantCtx {
            pool: crate::threads::Pool::new(threads),
            ..Default::default()
        }
    }
}

/// Structured representation of the quantized weights, when the format
/// admits one beyond a dense reconstruction.
#[derive(Clone, Debug)]
pub enum QuantRepr {
    /// Dense reconstruction only (grid methods).
    Dense,
    /// Two trit-planes + group scales (PTQTP).
    TritPlanes(TernaryLinear),
    /// Single ternary plane + group scales (absmean / BitNet-style).
    SinglePlane(TernaryLinear),
}

/// Output of a quantizer on one weight matrix.
#[derive(Clone, Debug)]
pub struct QuantResult {
    /// Dense reconstruction Ŵ (always present; what evaluation uses).
    pub w_hat: Matrix,
    pub repr: QuantRepr,
    /// Effective stored bits per weight including scale overhead.
    pub bits_per_weight: f64,
    /// Total bytes in the method's deployment format.
    pub memory_bytes: usize,
}

impl QuantResult {
    pub fn metrics(&self, w: &Matrix) -> QuantMetrics {
        QuantMetrics::compute(w, self)
    }
}

/// A post-training weight quantizer. `Send + Sync` so the model
/// loader's matrix-parallel sweep can share one quantizer across the
/// pool's lanes (all implementations are plain parameter structs).
pub trait Quantizer: Send + Sync {
    /// Short method name as used in the paper's tables ("PTQTP", "GPTQ").
    fn name(&self) -> String;
    /// Nominal weight bit-width as reported in the paper's "#Bits" column.
    fn nominal_bits(&self) -> f64;
    /// Quantize one weight matrix.
    fn quantize(&self, w: &Matrix, ctx: &QuantCtx) -> QuantResult;
    /// Hyper-parameters for the checkpoint sidecar manifest. The default
    /// records name + nominal bits; methods with real knobs (PTQTP)
    /// override to serialize them all so a saved artifact is fully
    /// reproducible.
    fn meta_json(&self) -> crate::serialize::Json {
        crate::serialize::Json::obj()
            .set("name", self.name())
            .set("nominal_bits", self.nominal_bits())
    }
}

/// Look up a quantizer by its table name, e.g. `"ptqtp"`, `"gptq3"`,
/// `"awq2"`, `"billm"`, `"arb"`, `"rtn4"`, `"absmean"`.
pub fn by_name(name: &str, group: usize) -> anyhow::Result<Box<dyn Quantizer>> {
    let lower = name.to_ascii_lowercase();
    // trailing digit = bit-width for grid methods
    let (base, bits) = match lower.trim_end_matches(|c: char| c.is_ascii_digit()) {
        b if b.len() < lower.len() => {
            let digits = &lower[b.len()..];
            (b.to_string(), digits.parse::<u32>().ok())
        }
        b => (b.to_string(), None),
    };
    Ok(match base.as_str() {
        "ptqtp" => Box::new(ptqtp::Ptqtp::new(PtqtpOpts {
            group,
            ..PtqtpOpts::default()
        })),
        "rtn" => Box::new(rtn::Rtn::new(bits.unwrap_or(4), group)),
        "gptq" => Box::new(gptq::Gptq::new(bits.unwrap_or(3), group)),
        "awq" => Box::new(awq::Awq::new(bits.unwrap_or(3), group)),
        "pbllm" => Box::new(pbllm::PbLlm::new(group)),
        "billm" => Box::new(billm::BiLlm::new(group)),
        "arb" | "arbllm" | "arbllmrc" => Box::new(arbllm::ArbLlmRc::new(group)),
        "absmean" | "bitnet" => Box::new(absmean::AbsMean::new(group)),
        "fp" | "fp16" | "fp32" => Box::new(Identity),
        other => anyhow::bail!("unknown quantizer '{other}'"),
    })
}

/// All method names used by the comparison benches, in paper order.
pub fn paper_methods() -> Vec<&'static str> {
    vec![
        "fp16", "awq4", "awq3", "awq2", "gptq4", "gptq3", "gptq2", "rtn3", "pbllm", "billm",
        "arb", "absmean", "ptqtp",
    ]
}

/// FP16 passthrough baseline.
pub struct Identity;

impl Quantizer for Identity {
    fn name(&self) -> String {
        "FP16".into()
    }

    fn nominal_bits(&self) -> f64 {
        16.0
    }

    fn quantize(&self, w: &Matrix, _ctx: &QuantCtx) -> QuantResult {
        QuantResult {
            w_hat: w.clone(),
            repr: QuantRepr::Dense,
            bits_per_weight: 16.0,
            memory_bytes: w.len() * 2,
        }
    }
}

// ---------------------------------------------------------------------
// Shared uniform-grid helpers (used by RTN / GPTQ / AWQ)
// ---------------------------------------------------------------------

/// Asymmetric min–max uniform quantization of a slice to `bits` levels;
/// quantizes in place and returns the (scale, zero) used.
pub fn grid_quant_slice(w: &mut [f32], bits: u32) -> (f32, f32) {
    let levels = (1u32 << bits) as f32 - 1.0;
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &x in w.iter() {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if !lo.is_finite() || hi <= lo {
        // constant group: represent exactly
        let v = if lo.is_finite() { lo } else { 0.0 };
        for x in w.iter_mut() {
            *x = v;
        }
        return (1.0, 0.0);
    }
    let scale = (hi - lo) / levels;
    let zero = (-lo / scale).round();
    for x in w.iter_mut() {
        let q = (*x / scale + zero).round().clamp(0.0, levels);
        *x = (q - zero) * scale;
    }
    (scale, zero)
}

/// Quantize a single value against a precomputed (scale, zero, bits) grid.
#[inline]
pub fn grid_quant_value(x: f32, scale: f32, zero: f32, bits: u32) -> f32 {
    let levels = (1u32 << bits) as f32 - 1.0;
    let q = (x / scale + zero).round().clamp(0.0, levels);
    (q - zero) * scale
}

/// Compute the min–max grid for a slice without quantizing.
pub fn grid_params(w: &[f32], bits: u32) -> (f32, f32) {
    let levels = (1u32 << bits) as f32 - 1.0;
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &x in w.iter() {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if !lo.is_finite() || hi <= lo {
        return (1.0, 0.0);
    }
    let scale = (hi - lo) / levels;
    let zero = (-lo / scale).round();
    (scale, zero)
}

/// Grid-method storage model (Eq. 9): `n·d·m` bits + per-group FP16
/// scale+zero.
pub fn grid_memory_bytes(n: usize, d: usize, bits: u32, group: usize) -> usize {
    let weight_bits = n * d * bits as usize;
    let groups = n * d.div_ceil(group);
    weight_bits / 8 + groups * 2 * 2 // fp16 scale + fp16 zero
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn registry_resolves_paper_methods() {
        for m in paper_methods() {
            let q = by_name(m, 128).unwrap_or_else(|_| panic!("method {m}"));
            assert!(!q.name().is_empty());
        }
        assert!(by_name("nonsense", 128).is_err());
    }

    #[test]
    fn registry_parses_bits_suffix() {
        assert_eq!(by_name("gptq2", 64).unwrap().nominal_bits(), 2.0);
        assert_eq!(by_name("awq4", 64).unwrap().nominal_bits(), 4.0);
    }

    #[test]
    fn identity_exact() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(4, 8, 1.0, &mut rng);
        let r = Identity.quantize(&w, &QuantCtx::default());
        assert_eq!(r.w_hat, w);
        assert_eq!(r.bits_per_weight, 16.0);
    }

    #[test]
    fn grid_quant_error_shrinks_with_bits() {
        let mut rng = Rng::new(2);
        let orig: Vec<f32> = (0..256).map(|_| rng.normal()).collect();
        let mut err = f64::INFINITY;
        for bits in [2u32, 3, 4, 8] {
            let mut w = orig.clone();
            grid_quant_slice(&mut w, bits);
            let e: f64 = orig
                .iter()
                .zip(&w)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum();
            assert!(e < err, "bits={bits}: {e} !< {err}");
            err = e;
        }
    }

    #[test]
    fn grid_quant_idempotent() {
        let mut rng = Rng::new(3);
        let mut w: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        grid_quant_slice(&mut w, 4);
        let once = w.clone();
        grid_quant_slice(&mut w, 4);
        for (a, b) in once.iter().zip(&w) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn grid_quant_constant_group() {
        let mut w = vec![0.7f32; 16];
        grid_quant_slice(&mut w, 2);
        assert!(w.iter().all(|&x| (x - 0.7).abs() < 1e-6));
    }

    #[test]
    fn grid_value_matches_slice() {
        let mut rng = Rng::new(4);
        let orig: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
        let (scale, zero) = grid_params(&orig, 3);
        let mut sliced = orig.clone();
        grid_quant_slice(&mut sliced, 3);
        for (i, &x) in orig.iter().enumerate() {
            let v = grid_quant_value(x, scale, zero, 3);
            assert!((v - sliced[i]).abs() < 1e-5, "i={i}");
        }
    }

    #[test]
    fn grid_memory_eq9() {
        // n=1024, d=4096, 4-bit, G=128: 2 MiB weights + 32 groups/row FP16×2
        let m = grid_memory_bytes(1024, 4096, 4, 128);
        assert_eq!(m, 1024 * 4096 / 2 + 1024 * 32 * 4);
    }
}

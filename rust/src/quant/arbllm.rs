//! ARB-LLM\_RC (Li et al., 2025) — alternating refined binarization
//! with residual compensation and column correction.
//!
//! The strongest binary-PTQ baseline in the paper. ARB-LLM's core idea
//! is that one-shot binarization parameters (splits, scales, signs) are
//! suboptimal and should be **alternately refined** until fixed-point.
//! Our implementation realizes that on top of the BiLLM-style salient /
//! bell-split structure:
//!
//! * salient elements (top fraction by magnitude): two residual binary
//!   planes whose scales are re-fit each round;
//! * non-salient elements: 2-class magnitude clustering refined by
//!   Lloyd iterations (reassign → refit scales), strictly improving on
//!   BiLLM's one-shot searched split;
//! * **RC** column correction: a closed-form per-column multiplicative
//!   scale fit at the end of every round.
//!
//! The repeated full passes per round are what make ARB 17–28× slower
//! than PTQTP in Fig. 1(b); our runtime bench preserves that shape.

use super::{QuantCtx, QuantRepr, QuantResult, Quantizer};
use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug)]
pub struct ArbLlmRc {
    pub group: usize,
    /// Alternating refinement rounds (fixed schedule, as in the reference).
    pub rounds: usize,
    /// Salient fraction given residual (second-order) binarization.
    pub salient_frac: f64,
}

impl ArbLlmRc {
    pub fn new(group: usize) -> ArbLlmRc {
        ArbLlmRc {
            group,
            rounds: 25,
            salient_frac: 0.05,
        }
    }
}

/// Mean |w| over an index subset (the LS-optimal binary scale for
/// `sign(w)` codes). Returns 0 for empty subsets.
fn mean_abs(w: &[f32], idx: &[usize]) -> f32 {
    if idx.is_empty() {
        return 0.0;
    }
    idx.iter().map(|&j| w[j].abs()).sum::<f32>() / idx.len() as f32
}

/// One group-chunk (≤ G consecutive weights of one row): alternating
/// refined binarization. Writes the reconstruction into `out`.
fn arb_chunk(w: &[f32], rounds: usize, salient_frac: f64, out: &mut [f32]) {
    let g = w.len();
    if g == 0 {
        return;
    }
    // --- partition: salient by magnitude
    let mut order: Vec<usize> = (0..g).collect();
    order.sort_by(|&x, &y| w[y].abs().partial_cmp(&w[x].abs()).unwrap());
    let n_sal = ((g as f64) * salient_frac).ceil() as usize;
    let salient: Vec<usize> = order[..n_sal.min(g)].to_vec();
    let mut rest: Vec<usize> = order[n_sal.min(g)..].to_vec();
    // keep `rest` magnitude-sorted descending: high class = prefix
    // (classes refined by Lloyd below)
    let split = rest.len() / 2; // initial break, refined by Lloyd below
    let mut high: Vec<usize> = rest.drain(..split.min(rest.len())).collect();
    let mut low: Vec<usize> = rest;

    // salient residual scales
    let mut a1 = mean_abs(w, &salient);
    let mut a2 = 0.0f32;
    // non-salient class scales
    let mut ah = mean_abs(w, &high);
    let mut al = mean_abs(w, &low);

    for _ in 0..rounds {
        // --- refine salient residual planes
        if !salient.is_empty() {
            // residual after plane 1
            a2 = salient
                .iter()
                .map(|&j| (w[j] - a1 * w[j].signum()).abs())
                .sum::<f32>()
                / salient.len() as f32;
            // refit a1 against plane-2-compensated target
            a1 = salient
                .iter()
                .map(|&j| {
                    let r2 = {
                        let r = w[j] - a1 * w[j].signum();
                        a2 * r.signum()
                    };
                    (w[j] - r2).abs()
                })
                .sum::<f32>()
                / salient.len() as f32;
        }

        // --- Lloyd reassignment of non-salient classes
        let mut new_high = Vec::with_capacity(high.len());
        let mut new_low = Vec::with_capacity(low.len());
        for &j in high.iter().chain(low.iter()) {
            let m = w[j].abs();
            if (m - ah).abs() <= (m - al).abs() {
                new_high.push(j);
            } else {
                new_low.push(j);
            }
        }
        // guard: never let a class die while the other has ≥2 members
        if new_high.is_empty() && new_low.len() >= 2 {
            new_high.push(new_low.pop().unwrap());
        }
        if new_low.is_empty() && new_high.len() >= 2 {
            new_low.push(new_high.pop().unwrap());
        }
        high = new_high;
        low = new_low;
        // reference ARB runs a fixed refinement schedule (no early
        // exit): every round re-fits scales and reassigns classes, which
        // is what makes it an order of magnitude slower than PTQTP
        // (Fig 1b); we preserve that cost structure.
        ah = mean_abs(w, &high);
        al = mean_abs(w, &low);
    }
    // --- reconstruct
    for &j in &salient {
        let p1 = a1 * w[j].signum();
        let r = w[j] - p1;
        out[j] = p1 + a2 * r.signum();
    }
    for &j in &high {
        out[j] = ah * w[j].signum();
    }
    for &j in &low {
        out[j] = al * w[j].signum();
    }
}

impl Quantizer for ArbLlmRc {
    fn name(&self) -> String {
        "ARB-LLM_RC-b1.1".into()
    }

    fn nominal_bits(&self) -> f64 {
        1.1
    }

    fn quantize(&self, w: &Matrix, _ctx: &QuantCtx) -> QuantResult {
        let group = if self.group == 0 { w.cols } else { self.group };
        let mut w_hat = Matrix::zeros(w.rows, w.cols);
        for r in 0..w.rows {
            let row = w.row(r);
            let out = w_hat.row_mut(r);
            let mut gs = 0usize;
            while gs < row.len() {
                let ge = (gs + group).min(row.len());
                arb_chunk(&row[gs..ge], self.rounds, self.salient_frac, &mut out[gs..ge]);
                gs = ge;
            }
        }

        // --- RC column correction: per-column LS scale c_j fitting
        // Ŵ[:,j]·c_j to W[:,j] (closed form; can only reduce error)
        for j in 0..w.cols {
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for i in 0..w.rows {
                let hat = w_hat.at(i, j) as f64;
                num += hat * w.at(i, j) as f64;
                den += hat * hat;
            }
            if den > 1e-30 {
                let c = (num / den) as f32;
                for i in 0..w.rows {
                    *w_hat.at_mut(i, j) *= c;
                }
            }
        }

        // memory model (Eq. 11): planes + salient values + bitmaps + scales
        let n = w.rows;
        let d = w.cols;
        let groups = d.div_ceil(group);
        let c = ((d as f64) * self.salient_frac) as usize;
        let bytes = (2 * n * c
            + (groups * 2 * n + 2 * c) * 16
            + n * (d - c)
            + (groups * n + (d - c)) * 16 * 2
            + n * d
            + d)
            / 8;
        QuantResult {
            w_hat,
            repr: QuantRepr::Dense,
            bits_per_weight: 1.1 + 32.0 / group as f64,
            memory_bytes: bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn beats_billm_reconstruction() {
        // Table 1 ordering: ARB < BiLLM perplexity ⇒ lower recon error
        let mut rng = Rng::new(1);
        let w = Matrix::rand_heavy(16, 256, 0.04, &mut rng);
        let arb = ArbLlmRc::new(128).quantize(&w, &QuantCtx::default());
        let bi = crate::quant::billm::BiLlm::new(128).quantize(&w, &QuantCtx::default());
        let ea = w.sq_err(&arb.w_hat);
        let eb = w.sq_err(&bi.w_hat);
        assert!(ea < eb, "arb {ea} !< billm {eb}");
    }

    #[test]
    fn worse_than_ptqtp() {
        let mut rng = Rng::new(2);
        let w = Matrix::rand_heavy(16, 256, 0.04, &mut rng);
        let arb = ArbLlmRc::new(128).quantize(&w, &QuantCtx::default());
        let tp = crate::quant::ptqtp::Ptqtp::default().quantize(&w, &QuantCtx::default());
        assert!(w.sq_err(&tp.w_hat) < w.sq_err(&arb.w_hat));
    }

    #[test]
    fn rounds_improve_error() {
        let mut rng = Rng::new(3);
        let w = Matrix::rand_heavy(8, 128, 0.04, &mut rng);
        let fast = ArbLlmRc {
            group: 64,
            rounds: 1,
            salient_frac: 0.05,
        }
        .quantize(&w, &QuantCtx::default());
        let slow = ArbLlmRc {
            group: 64,
            rounds: 15,
            salient_frac: 0.05,
        }
        .quantize(&w, &QuantCtx::default());
        assert!(w.sq_err(&slow.w_hat) <= w.sq_err(&fast.w_hat) * 1.001);
    }

    #[test]
    fn column_correction_helps_columnwise_scaling() {
        let mut rng = Rng::new(4);
        // weights with strong per-column magnitude structure
        let w = Matrix::from_fn(16, 64, |_, j| rng.normal() * (0.01 + 0.002 * j as f32));
        let q = ArbLlmRc::new(64).quantize(&w, &QuantCtx::default());
        assert!(w.rel_err(&q.w_hat) < 0.5, "rel {}", w.rel_err(&q.w_hat));
    }

    #[test]
    fn finite_on_zero_matrix() {
        let w = Matrix::zeros(4, 32);
        let q = ArbLlmRc::new(16).quantize(&w, &QuantCtx::default());
        assert!(q.w_hat.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn tiny_chunks_no_panic() {
        let mut rng = Rng::new(5);
        let w = Matrix::randn(3, 7, 0.05, &mut rng);
        let q = ArbLlmRc::new(2).quantize(&w, &QuantCtx::default());
        assert!(q.w_hat.data.iter().all(|x| x.is_finite()));
    }
}

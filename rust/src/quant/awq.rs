//! AWQ (Lin et al., 2024) — activation-aware weight quantization.
//!
//! Observation: quantization error on the channels that see large
//! activations hurts most. AWQ scales each input channel by
//! `s_j = mean|x_j|^α` before RTN grid quantization and folds `1/s`
//! back after, grid-searching `α ∈ [0,1]` against the calibration
//! output MSE. No retraining, no mixed precision.

use super::{grid_memory_bytes, grid_quant_slice, QuantCtx, QuantRepr, QuantResult, Quantizer};
use crate::tensor::ops::matmul;
use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug)]
pub struct Awq {
    pub bits: u32,
    pub group: usize,
    /// Number of α grid points in [0, 1].
    pub grid_points: usize,
}

impl Awq {
    pub fn new(bits: u32, group: usize) -> Awq {
        Awq {
            bits,
            group,
            grid_points: 11,
        }
    }

    /// RTN-quantize a scaled copy of `w` (columns pre-multiplied by `s`),
    /// then fold the scales back.
    fn quant_scaled(&self, w: &Matrix, s: &[f32], group: usize) -> Matrix {
        let mut scaled = w.clone();
        for r in 0..w.rows {
            let row = scaled.row_mut(r);
            for (j, x) in row.iter_mut().enumerate() {
                *x *= s[j];
            }
        }
        for r in 0..w.rows {
            let row = scaled.row_mut(r);
            for chunk in row.chunks_mut(group) {
                grid_quant_slice(chunk, self.bits);
            }
        }
        for r in 0..w.rows {
            let row = scaled.row_mut(r);
            for (j, x) in row.iter_mut().enumerate() {
                *x /= s[j];
            }
        }
        scaled
    }
}

impl Quantizer for Awq {
    fn name(&self) -> String {
        format!("AWQ-b{}", self.bits)
    }

    fn nominal_bits(&self) -> f64 {
        self.bits as f64
    }

    fn quantize(&self, w: &Matrix, ctx: &QuantCtx) -> QuantResult {
        let group = if self.group == 0 { w.cols } else { self.group };
        let d = w.cols;

        let result = match ctx.calib.as_ref() {
            None => self.quant_scaled(w, &vec![1.0; d], group), // plain RTN
            Some(x) => {
                assert_eq!(x.cols, d, "calibration dim mismatch");
                // per-channel mean |activation|
                let mut amean = vec![0.0f32; d];
                for r in 0..x.rows {
                    for (j, &v) in x.row(r).iter().enumerate() {
                        amean[j] += v.abs();
                    }
                }
                let inv_n = 1.0 / x.rows.max(1) as f32;
                for a in amean.iter_mut() {
                    *a = (*a * inv_n).max(1e-8);
                }
                // grid search α
                let y_ref = matmul(x, &w.transpose());
                let mut best: Option<(f64, Matrix)> = None;
                for gi in 0..self.grid_points {
                    let alpha = gi as f32 / (self.grid_points - 1).max(1) as f32;
                    let s: Vec<f32> = amean.iter().map(|&a| a.powf(alpha).max(1e-6)).collect();
                    // normalize scales to mean 1 for numerical sanity
                    let mean_s: f32 = s.iter().sum::<f32>() / d as f32;
                    let s: Vec<f32> = s.iter().map(|&v| v / mean_s).collect();
                    let w_hat = self.quant_scaled(w, &s, group);
                    let y = matmul(x, &w_hat.transpose());
                    let err: f64 = y
                        .data
                        .iter()
                        .zip(&y_ref.data)
                        .map(|(a, b)| ((a - b) as f64).powi(2))
                        .sum();
                    if best.as_ref().map(|(e, _)| err < *e).unwrap_or(true) {
                        best = Some((err, w_hat));
                    }
                }
                best.unwrap().1
            }
        };

        QuantResult {
            w_hat: result,
            repr: QuantRepr::Dense,
            // weights + group grids + per-channel fp16 scale vector
            bits_per_weight: self.bits as f64 + 32.0 / group as f64 + 16.0 / w.rows as f64,
            memory_bytes: grid_memory_bytes(w.rows, w.cols, self.bits, group) + d * 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::Rtn;
    use crate::rng::Rng;

    /// Calibration with strongly non-uniform channel magnitudes — the
    /// regime AWQ is designed for.
    fn skewed_calib(samples: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(samples, d, |_, j| {
            let channel_scale = 1.0 + 9.0 * (j as f32 / d as f32);
            rng.normal() * channel_scale
        })
    }

    fn output_err(w: &Matrix, w_hat: &Matrix, x: &Matrix) -> f64 {
        let ya = matmul(x, &w.transpose());
        let yb = matmul(x, &w_hat.transpose());
        ya.data
            .iter()
            .zip(&yb.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum()
    }

    #[test]
    fn beats_rtn_on_skewed_activations() {
        let mut rng = Rng::new(1);
        let d = 64;
        let w = Matrix::rand_heavy(16, d, 0.05, &mut rng);
        let x = skewed_calib(64, d, 2);
        let a = Awq::new(3, 32).quantize(&w, &QuantCtx::with_calib(x.clone()));
        let r = Rtn::new(3, 32).quantize(&w, &QuantCtx::default());
        let ea = output_err(&w, &a.w_hat, &x);
        let er = output_err(&w, &r.w_hat, &x);
        assert!(ea < er, "awq {ea} !< rtn {er}");
    }

    #[test]
    fn no_calib_degenerates_to_rtn() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(8, 32, 0.05, &mut rng);
        let a = Awq::new(4, 16).quantize(&w, &QuantCtx::default());
        let r = Rtn::new(4, 16).quantize(&w, &QuantCtx::default());
        for (x, y) in a.w_hat.data.iter().zip(&r.w_hat.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn two_bit_awq_collapses() {
        // Table 1 shape: AWQ-2bit perplexity explodes
        let mut rng = Rng::new(4);
        let d = 64;
        let w = Matrix::rand_heavy(16, d, 0.05, &mut rng);
        let x = skewed_calib(64, d, 5);
        let ctx = QuantCtx::with_calib(x);
        let a2 = Awq::new(2, 32).quantize(&w, &ctx);
        let a4 = Awq::new(4, 32).quantize(&w, &ctx);
        assert!(w.sq_err(&a2.w_hat) > 5.0 * w.sq_err(&a4.w_hat));
    }

    #[test]
    fn alpha_search_explores_grid() {
        // with a single grid point the search must still return something
        let mut rng = Rng::new(6);
        let w = Matrix::randn(4, 32, 0.05, &mut rng);
        let x = skewed_calib(16, 32, 7);
        let mut awq = Awq::new(3, 16);
        awq.grid_points = 1;
        let q = awq.quantize(&w, &QuantCtx::with_calib(x));
        assert_eq!(q.w_hat.rows, 4);
    }
}

//! Quantization quality metrics and the analytic memory models of the
//! paper's Appendix A.3 (Eqs. 9–13, Table 4).

use super::QuantResult;
use crate::tensor::Matrix;

/// Per-layer quantization metrics.
#[derive(Clone, Copy, Debug)]
pub struct QuantMetrics {
    pub sq_err: f64,
    pub rel_err: f64,
    pub bits_per_weight: f64,
    pub memory_bytes: usize,
    pub compression_vs_fp16: f64,
}

impl QuantMetrics {
    pub fn compute(w: &Matrix, r: &QuantResult) -> QuantMetrics {
        let sq = w.sq_err(&r.w_hat);
        QuantMetrics {
            sq_err: sq,
            rel_err: w.rel_err(&r.w_hat),
            bits_per_weight: r.bits_per_weight,
            memory_bytes: r.memory_bytes,
            compression_vs_fp16: (w.len() * 2) as f64 / r.memory_bytes.max(1) as f64,
        }
    }
}

/// Analytic memory models (bytes) for an `n×d` layer, group size `k`,
/// salient column count `c`. These regenerate Table 4 exactly from the
/// paper's formulas (which count bits; we divide by 8).

/// FP16 baseline.
pub fn mem_fp16(n: usize, d: usize) -> usize {
    2 * n * d
}

/// Eq. 9 — standard m-bit grid quantization with per-group FP16 scale.
pub fn mem_grid(n: usize, d: usize, m: usize, k: usize) -> usize {
    (n * d * m + d.div_ceil(k) * n * 16) / 8
}

/// PB-LLM: 1-bit plane + salient fp16 + bitmap + group scales.
pub fn mem_pbllm(n: usize, d: usize, k: usize, salient_frac: f64) -> usize {
    let salient = ((n * d) as f64 * salient_frac) as usize;
    (n * d        // 1-bit plane
        + salient * 16 // fp16 salient values
        + n * d        // salient bitmap
        + d.div_ceil(k) * n * 16)
        / 8
}

/// Eq. 10 — BiLLM: second-order binarization for c salient columns,
/// first-order + split for the rest, group bitmap + salient bitmap.
pub fn mem_billm(n: usize, d: usize, k: usize, c: usize) -> usize {
    (2 * n * c                      // second-order planes on salient cols
        + d.div_ceil(k) * 3 * n * 16 // 3 group scales (fp16)
        + n * d                      // first-order plane / group bitmap
        + d)                         // salient column bitmap
        / 8
}

/// Eq. 11 — ARB-LLM_RC.
pub fn mem_arb_rc(n: usize, d: usize, k: usize, c: usize) -> usize {
    (2 * n * c + (d.div_ceil(k) * 2 * n + 2 * c) * 16          // 2nd order
        + n * (d - c) + (d.div_ceil(k) * n + (d - c)) * 16 * 2 // 1st order
        + n * d                                                 // group bitmap
        + d)                                                    // salient bitmap
        / 8
}

/// Eq. 12 — ARB-LLM_RC + column-group bitmap (CGB).
pub fn mem_arb_rc_cgb(n: usize, d: usize, k: usize, c: usize) -> usize {
    (2 * n * c + (d.div_ceil(k) * 2 * n + 2 * c) * 16 * 2
        + n * (d - c) + (d.div_ceil(k) * n + (d - c)) * 16 * 2
        + n * d
        + d)
        / 8
}

/// Eq. 13 — PTQTP: two 2-bit trit-planes + 2 FP16 α per group-row.
pub fn mem_ptqtp(n: usize, d: usize, k: usize) -> usize {
    (2 * n * d * 2 + d.div_ceil(k) * 2 * n * 16) / 8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{QuantCtx, Quantizer};
    use crate::rng::Rng;

    #[test]
    fn appendix_a3_example() {
        // n=1024, d=4096: paper says trit-planes 0.5 MB + α ≈ 0.5 MB ≈ 1 MB
        let m = mem_ptqtp(1024, 4096, 128);
        let planes = 2 * 1024 * 4096 * 2 / 8; // 2 MiB? No: 2 planes × 2 bits
        assert_eq!(planes, 2 * 1024 * 1024);
        // the paper's "0.5 MB for trit-planes" counts per plane at 1 bit
        // effective... we follow Eq. 13 exactly:
        assert_eq!(m, planes + 32 * 1024 * 2 * 16 / 8);
    }

    #[test]
    fn ordering_matches_table4() {
        // Table 4 (LLaMA-7B): PB ≈ BiLLM < ARB_RC < PTQTP < FP16
        let (n, d, k) = (4096, 4096, 128);
        let c = d / 10;
        let fp = mem_fp16(n, d);
        let pb = mem_pbllm(n, d, k, 0.1);
        let bi = mem_billm(n, d, k, c);
        let arb = mem_arb_rc(n, d, k, c);
        let tp = mem_ptqtp(n, d, k);
        assert!(pb < tp, "pb {pb} < ptqtp {tp}");
        assert!(bi < tp, "billm {bi} < ptqtp {tp}");
        assert!(tp < fp / 3, "ptqtp {tp} ≪ fp16 {fp}");
        assert!(arb < tp, "arb {arb} < ptqtp {tp}");
    }

    #[test]
    fn ptqtp_compression_ratio_near_4x_for_planes() {
        // trit planes alone compress 4× vs fp16 (2×2bit vs 16bit)
        let (n, d) = (1024, 4096);
        let planes_only = 2 * n * d * 2 / 8;
        assert_eq!(mem_fp16(n, d) / planes_only, 4);
    }

    #[test]
    fn metrics_compute_consistency() {
        let mut rng = Rng::new(1);
        let w = crate::tensor::Matrix::rand_heavy(8, 128, 0.04, &mut rng);
        let q = crate::quant::ptqtp::Ptqtp::default().quantize(&w, &QuantCtx::default());
        let m = q.metrics(&w);
        assert!(m.rel_err > 0.0 && m.rel_err < 1.0);
        assert!((m.rel_err * m.rel_err * (w.fro_norm() * w.fro_norm()) - m.sq_err).abs() / m.sq_err < 1e-6);
        assert!(m.compression_vs_fp16 > 2.0);
    }

    #[test]
    fn cgb_variant_larger_than_rc() {
        let (n, d, k, c) = (4096, 4096, 128, 409);
        assert!(mem_arb_rc_cgb(n, d, k, c) > mem_arb_rc(n, d, k, c));
    }
}

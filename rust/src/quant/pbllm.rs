//! PB-LLM (Shang et al., 2023) — partially binarized LLM quantization.
//!
//! Keeps a small salient fraction (default 10%, by magnitude) of weights
//! in high precision and binarizes the rest group-wise with an
//! `α·sign(w)` codebook. Effective ~1.7 bits/weight with the salient
//! overhead (the paper's Table 9 lists PB-LLM at 1.70 bits).

use super::{QuantCtx, QuantRepr, QuantResult, Quantizer};
use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug)]
pub struct PbLlm {
    pub group: usize,
    /// Fraction of weights kept in fp16.
    pub salient_frac: f64,
}

impl PbLlm {
    pub fn new(group: usize) -> PbLlm {
        PbLlm {
            group,
            salient_frac: 0.10,
        }
    }
}

impl Quantizer for PbLlm {
    fn name(&self) -> String {
        "PB-LLM-b1.7".into()
    }

    fn nominal_bits(&self) -> f64 {
        1.7
    }

    fn quantize(&self, w: &Matrix, _ctx: &QuantCtx) -> QuantResult {
        let group = if self.group == 0 { w.cols } else { self.group };
        // global magnitude threshold for saliency
        let mut mags: Vec<f32> = w.data.iter().map(|x| x.abs()).collect();
        let k = ((w.len() as f64) * self.salient_frac) as usize;
        let thresh = if k == 0 {
            f32::INFINITY
        } else {
            let idx = w.len() - k;
            mags.select_nth_unstable_by(idx.min(w.len() - 1), |a, b| a.partial_cmp(b).unwrap());
            mags[idx.min(w.len() - 1)]
        };

        let mut w_hat = Matrix::zeros(w.rows, w.cols);
        for r in 0..w.rows {
            let row = w.row(r);
            for (gs, chunk) in row.chunks(group).enumerate() {
                let start = gs * group;
                // α over non-salient entries only (reference behaviour)
                let mut sum = 0.0f32;
                let mut cnt = 0usize;
                for &x in chunk {
                    if x.abs() < thresh {
                        sum += x.abs();
                        cnt += 1;
                    }
                }
                let alpha = if cnt > 0 { sum / cnt as f32 } else { 0.0 };
                for (j, &x) in chunk.iter().enumerate() {
                    let v = if x.abs() >= thresh {
                        x // salient: fp16 passthrough
                    } else {
                        alpha * x.signum()
                    };
                    *w_hat.at_mut(r, start + j) = v;
                }
            }
        }
        // memory: 1 bit/weight + salient fp16 + bitmap + group scales
        let n = w.rows;
        let d = w.cols;
        let bytes = n * d / 8 + k * 2 + n * d / 8 + n * d.div_ceil(group) * 2;
        QuantResult {
            w_hat,
            repr: QuantRepr::Dense,
            bits_per_weight: 1.0 + 16.0 * self.salient_frac + 1.0 + 16.0 / group as f64,
            memory_bytes: bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn salient_weights_exact() {
        let mut rng = Rng::new(1);
        let mut w = Matrix::randn(8, 64, 0.02, &mut rng);
        // plant unmistakable outliers
        w.data[5] = 3.0;
        w.data[100] = -2.5;
        let q = PbLlm::new(32).quantize(&w, &QuantCtx::default());
        assert_eq!(q.w_hat.data[5], 3.0);
        assert_eq!(q.w_hat.data[100], -2.5);
    }

    #[test]
    fn nonsalient_are_binary_levels() {
        let mut rng = Rng::new(2);
        let w = Matrix::randn(4, 32, 0.02, &mut rng);
        let q = PbLlm {
            group: 32,
            salient_frac: 0.0,
        }
        .quantize(&w, &QuantCtx::default());
        // with no salient weights each group has ≤2 levels (±α)
        for r in 0..4 {
            let mut vals: Vec<i64> = q.w_hat.row(r).iter().map(|&x| (x * 1e7).round() as i64).collect();
            vals.sort_unstable();
            vals.dedup();
            assert!(vals.len() <= 2, "row {r}: {vals:?}");
        }
    }

    #[test]
    fn better_than_pure_binary_on_outliers() {
        let mut rng = Rng::new(3);
        let w = Matrix::rand_heavy(8, 128, 0.05, &mut rng);
        let pb = PbLlm::new(64).quantize(&w, &QuantCtx::default());
        let pure = PbLlm {
            group: 64,
            salient_frac: 0.0,
        }
        .quantize(&w, &QuantCtx::default());
        assert!(w.sq_err(&pb.w_hat) < w.sq_err(&pure.w_hat));
    }

    #[test]
    fn worse_than_ptqtp() {
        // the paper's central comparison
        let mut rng = Rng::new(4);
        let w = Matrix::rand_heavy(8, 256, 0.04, &mut rng);
        let pb = PbLlm::new(128).quantize(&w, &QuantCtx::default());
        let tp = crate::quant::ptqtp::Ptqtp::default().quantize(&w, &QuantCtx::default());
        assert!(w.sq_err(&tp.w_hat) < w.sq_err(&pb.w_hat));
    }
}

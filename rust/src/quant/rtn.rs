//! RTN — round-to-nearest uniform grid quantization, group-wise.
//!
//! The simplest PTQ baseline: every group is min–max quantized to
//! `2^b` levels independently, no calibration, no error compensation.

use super::{grid_memory_bytes, grid_quant_slice, QuantCtx, QuantRepr, QuantResult, Quantizer};
use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug)]
pub struct Rtn {
    pub bits: u32,
    pub group: usize,
}

impl Rtn {
    pub fn new(bits: u32, group: usize) -> Rtn {
        assert!(bits >= 1 && bits <= 8, "unsupported bit width {bits}");
        Rtn { bits, group }
    }
}

impl Quantizer for Rtn {
    fn name(&self) -> String {
        format!("RTN-b{}", self.bits)
    }

    fn nominal_bits(&self) -> f64 {
        self.bits as f64
    }

    fn quantize(&self, w: &Matrix, _ctx: &QuantCtx) -> QuantResult {
        let group = if self.group == 0 { w.cols } else { self.group };
        let mut w_hat = w.clone();
        for r in 0..w.rows {
            let row = w_hat.row_mut(r);
            for chunk in row.chunks_mut(group) {
                grid_quant_slice(chunk, self.bits);
            }
        }
        QuantResult {
            w_hat,
            repr: QuantRepr::Dense,
            bits_per_weight: self.bits as f64 + 32.0 / group as f64,
            memory_bytes: grid_memory_bytes(w.rows, w.cols, self.bits, group),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn error_decreases_with_bits() {
        let mut rng = Rng::new(1);
        let w = Matrix::rand_heavy(8, 256, 0.03, &mut rng);
        let mut prev = f64::INFINITY;
        for bits in [2u32, 3, 4, 8] {
            let q = Rtn::new(bits, 64).quantize(&w, &QuantCtx::default());
            let e = w.sq_err(&q.w_hat);
            assert!(e < prev, "bits={bits}");
            prev = e;
        }
    }

    #[test]
    fn grouping_helps_with_outliers() {
        let mut rng = Rng::new(2);
        let w = Matrix::rand_heavy(4, 512, 0.03, &mut rng);
        let grouped = Rtn::new(3, 64).quantize(&w, &QuantCtx::default());
        let whole_row = Rtn::new(3, 0).quantize(&w, &QuantCtx::default());
        assert!(w.sq_err(&grouped.w_hat) < w.sq_err(&whole_row.w_hat));
    }

    #[test]
    fn eight_bit_nearly_exact() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(4, 128, 0.02, &mut rng);
        let q = Rtn::new(8, 128).quantize(&w, &QuantCtx::default());
        assert!(w.rel_err(&q.w_hat) < 0.01);
    }

    #[test]
    fn values_on_grid() {
        let mut rng = Rng::new(4);
        let w = Matrix::randn(1, 16, 1.0, &mut rng);
        let q = Rtn::new(2, 16).quantize(&w, &QuantCtx::default());
        // 2-bit → at most 4 distinct values per group
        let mut vals: Vec<i64> = q.w_hat.data.iter().map(|&x| (x * 1e6).round() as i64).collect();
        vals.sort_unstable();
        vals.dedup();
        assert!(vals.len() <= 4, "{vals:?}");
    }
}

//! GPTQ (Frantar et al., 2022) — Hessian-aware layer-wise quantization.
//!
//! Per layer: build the (damped) Hessian `H = 2·XᵀX + λI` from
//! calibration activations, stream over columns in order, quantize each
//! to the group's uniform grid, and propagate the weighted error to the
//! not-yet-quantized columns through the inverse-Hessian Cholesky
//! factor. This is the reference "OBQ with lazy batch updates"
//! formulation; per-iteration cost is O(n·d²) (paper Appendix A.2
//! contrasts this against PTQTP's O(n·d)).

use super::linalg::cholesky_inv_upper;
use super::{grid_memory_bytes, grid_params, grid_quant_value, QuantCtx, QuantRepr, QuantResult, Quantizer};
use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug)]
pub struct Gptq {
    pub bits: u32,
    pub group: usize,
    /// Relative Hessian damping (fraction of mean diagonal), GPTQ's
    /// `percdamp`.
    pub percdamp: f32,
}

impl Gptq {
    pub fn new(bits: u32, group: usize) -> Gptq {
        Gptq {
            bits,
            group,
            percdamp: 0.01,
        }
    }

    /// Build the damped Hessian from calibration activations
    /// (rows = samples, cols = layer input dim d).
    fn hessian(&self, d: usize, calib: Option<&Matrix>) -> Matrix {
        let mut h = match calib {
            Some(x) => {
                assert_eq!(x.cols, d, "calibration dim mismatch");
                // H = 2 XᵀX
                let xt = x.transpose();
                let mut h = crate::tensor::ops::matmul(&xt, x);
                h.scale(2.0);
                h
            }
            None => {
                // no calibration → identity Hessian (falls back to RTN-
                // with-error-feedback, still a valid GPTQ special case)
                let mut h = Matrix::zeros(d, d);
                for i in 0..d {
                    *h.at_mut(i, i) = 1.0;
                }
                h
            }
        };
        // damping: λ = percdamp · mean(diag(H))
        let mean_diag: f32 = (0..d).map(|i| h.at(i, i)).sum::<f32>() / d as f32;
        let damp = (self.percdamp * mean_diag).max(1e-6);
        for i in 0..d {
            *h.at_mut(i, i) += damp;
        }
        h
    }
}

impl Quantizer for Gptq {
    fn name(&self) -> String {
        format!("GPTQ-b{}", self.bits)
    }

    fn nominal_bits(&self) -> f64 {
        self.bits as f64
    }

    fn quantize(&self, w: &Matrix, ctx: &QuantCtx) -> QuantResult {
        let group = if self.group == 0 { w.cols } else { self.group };
        let d = w.cols;
        let h = self.hessian(d, ctx.calib.as_ref());
        // Hinv upper-Cholesky factor; fall back to identity on failure.
        let u = cholesky_inv_upper(&h).unwrap_or_else(|| {
            let mut i_mat = Matrix::zeros(d, d);
            for i in 0..d {
                *i_mat.at_mut(i, i) = 1.0;
            }
            i_mat
        });

        // Work on a mutable copy; rows are independent.
        let mut work = w.clone();
        let mut w_hat = Matrix::zeros(w.rows, w.cols);
        for r in 0..w.rows {
            let row = work.row_mut(r);
            let mut grid: (f32, f32) = (1.0, 0.0);
            for j in 0..d {
                if j % group == 0 {
                    // (re)fit the grid on the *current* (error-updated)
                    // group values — matches reference GPTQ
                    let end = (j + group).min(d);
                    grid = grid_params(&row[j..end], self.bits);
                }
                let q = grid_quant_value(row[j], grid.0, grid.1, self.bits);
                let ujj = u.at(j, j).max(1e-12);
                let err = (row[j] - q) / ujj;
                *w_hat.at_mut(r, j) = q;
                // propagate error to remaining columns
                for k in j + 1..d {
                    row[k] -= err * u.at(j, k);
                }
            }
        }
        QuantResult {
            w_hat,
            repr: QuantRepr::Dense,
            bits_per_weight: self.bits as f64 + 32.0 / group as f64,
            memory_bytes: grid_memory_bytes(w.rows, w.cols, self.bits, group),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::Rtn;
    use crate::rng::Rng;
    use crate::tensor::ops::matmul;

    fn calib(samples: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        // correlated activations: x = z A with random mixing, mimics
        // real layer inputs where GPTQ's Hessian carries information
        let z = Matrix::randn(samples, d, 1.0, &mut rng);
        let mut a = Matrix::randn(d, d, 0.2, &mut rng);
        for i in 0..d {
            *a.at_mut(i, i) += 1.0;
        }
        matmul(&z, &a)
    }

    /// Output-space error ‖X(W−Ŵ)ᵀ‖² — what GPTQ actually minimizes.
    fn output_err(w: &Matrix, w_hat: &Matrix, x: &Matrix) -> f64 {
        let diff = Matrix::from_vec(
            w.rows,
            w.cols,
            w.data.iter().zip(&w_hat.data).map(|(a, b)| a - b).collect(),
        );
        let y = matmul(x, &diff.transpose());
        y.data.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    #[test]
    fn beats_rtn_in_output_space() {
        let mut rng = Rng::new(1);
        let d = 64;
        let w = Matrix::rand_heavy(16, d, 0.05, &mut rng);
        let x = calib(128, d, 2);
        let ctx = QuantCtx::with_calib(x.clone());
        let g = Gptq::new(3, 32).quantize(&w, &ctx);
        let r = Rtn::new(3, 32).quantize(&w, &QuantCtx::default());
        let eg = output_err(&w, &g.w_hat, &x);
        let er = output_err(&w, &r.w_hat, &x);
        assert!(eg < er, "gptq {eg} !< rtn {er}");
    }

    #[test]
    fn no_calib_still_works() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(8, 32, 0.05, &mut rng);
        let q = Gptq::new(4, 16).quantize(&w, &QuantCtx::default());
        assert!(w.rel_err(&q.w_hat) < 0.2);
    }

    #[test]
    fn error_decreases_with_bits() {
        let mut rng = Rng::new(4);
        let d = 32;
        let w = Matrix::rand_heavy(8, d, 0.05, &mut rng);
        let x = calib(64, d, 5);
        let ctx = QuantCtx::with_calib(x.clone());
        let mut prev = f64::INFINITY;
        for bits in [2u32, 3, 4] {
            let q = Gptq::new(bits, 16).quantize(&w, &ctx);
            let e = output_err(&w, &q.w_hat, &x);
            assert!(e < prev, "bits={bits}: {e} !< {prev}");
            prev = e;
        }
    }

    #[test]
    fn two_bit_collapses_hard() {
        // Table 1 shape: GPTQ-2bit catastrophically bad vs 3-bit
        let mut rng = Rng::new(6);
        let d = 64;
        let w = Matrix::rand_heavy(16, d, 0.05, &mut rng);
        let x = calib(96, d, 7);
        let ctx = QuantCtx::with_calib(x.clone());
        let q2 = Gptq::new(2, 32).quantize(&w, &ctx);
        let q4 = Gptq::new(4, 32).quantize(&w, &ctx);
        let e2 = w.sq_err(&q2.w_hat);
        let e4 = w.sq_err(&q4.w_hat);
        assert!(e2 > e4 * 4.0, "2-bit {e2} vs 4-bit {e4}");
    }
}

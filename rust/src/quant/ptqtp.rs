//! PTQTP — Post-Training Quantization to Trit-Planes (paper §3,
//! Algorithms 1 & 2).
//!
//! For each weight group `w̃ᵢ ∈ R^G` (rows of the `nd/G × G` reshape,
//! paper §3.2) the algorithm alternates:
//!
//! 1. **Adaptive ridge regression** (Eq. 1/4/6): with the trit pair
//!    fixed, the 2×2 normal system `A = SᵀS + λI, b = Sᵀw̃` is solved in
//!    closed form by the adjugate (Eq. 7). The regularizer λ adapts to
//!    the condition estimate `κ ≈ ‖A‖_F·‖A⁻¹‖_F` (Eq. 2/3): if
//!    κ ≥ 10¹², λ ← min(λ·√(κ/10¹²), λ_max).
//! 2. **Local exhaustive trit search** (Eq. 5): with α fixed, every
//!    element picks the pair `(c⁽¹⁾,c⁽²⁾) ∈ {-1,0,1}²` minimizing the
//!    squared residual — 9 candidates per weight, O(1) each.
//!
//! Convergence (Appendix C): each half-step is phase-optimal, so the
//! group error is monotonically non-increasing; iteration stops when
//! `‖α_t − α_{t−1}‖_F < ε` or after `T_max` rounds. We additionally
//! record per-iteration error and plane-flip counts to regenerate
//! Fig. 3/4/5 and expose the κ ablation of Table 7.

use super::{QuantCtx, QuantRepr, QuantResult, Quantizer};
use crate::tensor::Matrix;
use crate::ternary::TernaryLinear;
use crate::threads::{chunk_range, Pool, SendPtr};

/// PTQTP hyper-parameters (defaults = paper §4.1).
#[derive(Clone, Copy, Debug)]
pub struct PtqtpOpts {
    /// Group size G (0 ⇒ per-row, i.e. "× Group" rows of Table 8).
    pub group: usize,
    /// Max progressive-search iterations T_max.
    pub t_max: usize,
    /// Convergence tolerance ε on ‖α_t − α_{t−1}‖.
    pub eps: f32,
    /// Initial λ.
    pub lambda_init: f32,
    /// λ ceiling (Eq. 3 constraint λ ≤ λ_max).
    pub lambda_max: f32,
    /// Condition threshold (10¹² in Eq. 3; swept by Table 7).
    pub kappa_threshold: f64,
    /// Record per-iteration error / flip histories (Fig 3/5).
    pub track_history: bool,
}

impl Default for PtqtpOpts {
    fn default() -> Self {
        PtqtpOpts {
            group: crate::consts::GROUP_SIZE,
            t_max: crate::consts::T_MAX,
            eps: crate::consts::EPSILON,
            lambda_init: crate::consts::LAMBDA_INIT,
            lambda_max: crate::consts::LAMBDA_MAX,
            kappa_threshold: crate::consts::KAPPA_THRESHOLD,
            track_history: false,
        }
    }
}

impl PtqtpOpts {
    /// Full hyper-parameter record for the checkpoint manifest, so an
    /// artifact documents exactly how it was produced.
    pub fn to_json(&self) -> crate::serialize::Json {
        crate::serialize::Json::obj()
            .set("group", self.group)
            .set("t_max", self.t_max)
            .set("eps", self.eps as f64)
            .set("lambda_init", self.lambda_init as f64)
            .set("lambda_max", self.lambda_max as f64)
            .set("kappa_threshold", self.kappa_threshold)
    }
}

/// Convergence/diagnostic report (drives Fig 3, Fig 5, Table 7).
#[derive(Clone, Debug, Default)]
pub struct PtqtpReport {
    /// Iterations each group actually ran before converging.
    pub iters_per_group: Vec<usize>,
    /// Global ‖W−Ŵ‖²_F after each sweep (only if `track_history`).
    pub err_history: Vec<f64>,
    /// Total trit flips per sweep across both planes (Fig 5).
    pub flip_history: Vec<usize>,
    /// Final squared error.
    pub final_sq_err: f64,
    /// Mean λ after adaptation (diagnostic for Table 7).
    pub mean_lambda: f64,
}

impl PtqtpReport {
    pub fn mean_iters(&self) -> f64 {
        if self.iters_per_group.is_empty() {
            return 0.0;
        }
        self.iters_per_group.iter().sum::<usize>() as f64 / self.iters_per_group.len() as f64
    }

    pub fn max_iters(&self) -> usize {
        self.iters_per_group.iter().copied().max().unwrap_or(0)
    }
}

/// The PTQTP quantizer.
#[derive(Clone, Debug, Default)]
pub struct Ptqtp {
    pub opts: PtqtpOpts,
}

impl Ptqtp {
    pub fn new(opts: PtqtpOpts) -> Ptqtp {
        Ptqtp { opts }
    }

    /// Quantize `w` and return both the structured result and the
    /// convergence report.
    pub fn quantize_with_report(&self, w: &Matrix) -> (TernaryLinear, PtqtpReport) {
        let o = &self.opts;
        let group = if o.group == 0 { w.cols } else { o.group };
        let mut lin = TernaryLinear::new(w.rows, w.cols, group);
        let gpr = lin.groups_per_row();
        let mut report = PtqtpReport {
            iters_per_group: Vec::with_capacity(w.rows * gpr),
            ..Default::default()
        };

        // history tracking needs synchronized sweeps across groups, so we
        // run two modes: the fast per-group loop (default) and the
        // sweep-synchronized loop (track_history).
        if o.track_history {
            self.quantize_synchronized(w, &mut lin, &mut report);
        } else {
            let mut lambda_sum = 0.0f64;
            quantize_groups(w, &mut lin, o, &mut report, &mut lambda_sum);
            report.mean_lambda = lambda_sum / (w.rows * gpr) as f64;
        }

        report.final_sq_err = lin.sq_err(w);
        (lin, report)
    }

    /// Row-parallel variant of [`Ptqtp::quantize_with_report`]: weight
    /// rows are partitioned into contiguous spans, one per pool lane.
    /// Every group's progressive approximation is row-local and each
    /// lane runs the identical sequential group optimizer with its own
    /// scratch, so planes, scales, and the report are **bit-identical**
    /// to the sequential path for any thread count (the λ mean is
    /// reduced by the leader in group order, not lane order). History
    /// tracking needs sweep-synchronized groups and stays sequential.
    pub fn quantize_with_report_pooled(
        &self,
        w: &Matrix,
        pool: &Pool,
    ) -> (TernaryLinear, PtqtpReport) {
        let o = &self.opts;
        let lanes = pool.threads();
        if o.track_history || lanes <= 1 || w.rows < 2 {
            return self.quantize_with_report(w);
        }
        let group = if o.group == 0 { w.cols } else { o.group };
        let mut lin = TernaryLinear::new(w.rows, w.cols, group);
        let gpr = lin.groups_per_row();
        let n_groups = w.rows * gpr;
        let mut iters = vec![0usize; n_groups];
        let mut lambdas = vec![0.0f32; n_groups];
        let cols = w.cols;
        let t1p = SendPtr(lin.t1.trits.as_mut_ptr());
        let t2p = SendPtr(lin.t2.trits.as_mut_ptr());
        let a1p = SendPtr(lin.alpha1.as_mut_ptr());
        let a2p = SendPtr(lin.alpha2.as_mut_ptr());
        let itp = SendPtr(iters.as_mut_ptr());
        let lmp = SendPtr(lambdas.as_mut_ptr());
        pool.run(|lane| {
            let rows = chunk_range(w.rows, lanes, lane);
            if rows.is_empty() {
                return;
            }
            let mut scratch = Scratch::new(group.min(cols).max(1));
            for r in rows {
                let row_w = w.row(r);
                // SAFETY: lanes own disjoint whole rows of both planes
                // and disjoint `gi` spans of α / report buffers; all
                // buffers outlive `run` (the leader blocks inside it).
                let t1 =
                    unsafe { std::slice::from_raw_parts_mut(t1p.get().add(r * cols), cols) };
                let t2 =
                    unsafe { std::slice::from_raw_parts_mut(t2p.get().add(r * cols), cols) };
                for g in 0..gpr {
                    let s = g * group;
                    let e = (s + group).min(cols);
                    let gi = r * gpr + g;
                    let (a1, a2, it, lam) =
                        optimize_group_full(&row_w[s..e], &mut t1[s..e], &mut t2[s..e], o, &mut scratch);
                    unsafe {
                        *a1p.get().add(gi) = a1;
                        *a2p.get().add(gi) = a2;
                        *itp.get().add(gi) = it;
                        *lmp.get().add(gi) = lam;
                    }
                }
            }
        });
        // deterministic reduction: group order, independent of lanes —
        // the exact addition order of the sequential path
        let lambda_sum: f64 = lambdas.iter().map(|&l| l as f64).sum();
        let mut report = PtqtpReport {
            iters_per_group: iters,
            mean_lambda: lambda_sum / n_groups as f64,
            ..Default::default()
        };
        report.final_sq_err = lin.sq_err(w);
        (lin, report)
    }

    fn quantize_synchronized(&self, w: &Matrix, lin: &mut TernaryLinear, report: &mut PtqtpReport) {
        let o = &self.opts;
        let gpr = lin.groups_per_row();
        let n_groups = w.rows * gpr;
        // init
        lin.t1 = crate::ternary::TritPlane::sign_init(w);
        lin.t2 = lin.t1.clone();
        for a in lin.alpha1.iter_mut().chain(lin.alpha2.iter_mut()) {
            *a = 1.0;
        }
        let mut lambdas = vec![o.lambda_init; n_groups];
        let mut converged = vec![false; n_groups];
        let mut iters = vec![0usize; n_groups];
        for _t in 0..o.t_max {
            let mut flips = 0usize;
            let mut all_done = true;
            for r in 0..w.rows {
                let (t1_row, t2_row) = (lin.t1.row(r).to_vec(), lin.t2.row(r).to_vec());
                let mut t1_new = t1_row.clone();
                let mut t2_new = t2_row.clone();
                for g in 0..gpr {
                    let gi = r * gpr + g;
                    if converged[gi] {
                        continue;
                    }
                    all_done = false;
                    iters[gi] += 1;
                    let (s, e) = lin.group_span(g);
                    let wg = &w.row(r)[s..e];
                    let old_a = (lin.alpha1[gi], lin.alpha2[gi]);
                    // ridge step
                    let (a1, a2, lam) = ridge_step(
                        wg,
                        &t1_row[s..e],
                        &t2_row[s..e],
                        lambdas[gi],
                        o.lambda_max,
                        o.kappa_threshold,
                    );
                    lambdas[gi] = lam;
                    lin.alpha1[gi] = a1;
                    lin.alpha2[gi] = a2;
                    // trit search step
                    flips += trit_search(wg, a1, a2, &mut t1_new[s..e], &mut t2_new[s..e]);
                    // convergence on α delta
                    let d = ((a1 - old_a.0).powi(2) + (a2 - old_a.1).powi(2)).sqrt();
                    if d < o.eps {
                        converged[gi] = true;
                    }
                }
                lin.t1.row_mut(r).copy_from_slice(&t1_new);
                lin.t2.row_mut(r).copy_from_slice(&t2_new);
            }
            report.err_history.push(lin.sq_err(w));
            report.flip_history.push(flips);
            if all_done {
                break;
            }
        }
        report.iters_per_group = iters;
        report.mean_lambda = lambdas.iter().map(|&l| l as f64).sum::<f64>() / n_groups as f64;
    }
}

/// Fast path: optimize every group independently to convergence.
fn quantize_groups(
    w: &Matrix,
    lin: &mut TernaryLinear,
    o: &PtqtpOpts,
    report: &mut PtqtpReport,
    lambda_sum: &mut f64,
) {
    let gpr = lin.groups_per_row();
    let mut scratch = Scratch::new(lin.group.min(w.cols).max(1));
    for r in 0..w.rows {
        // split borrows of the two planes for this row
        let row_w = w.row(r);
        for g in 0..gpr {
            let (s, e) = lin.group_span(g);
            let wg = &row_w[s..e];
            let gi = r * gpr + g;
            let (a1, a2, iters, lambda) = optimize_group_full(
                wg,
                &mut lin.t1.trits[r * w.cols + s..r * w.cols + e],
                &mut lin.t2.trits[r * w.cols + s..r * w.cols + e],
                o,
                &mut scratch,
            );
            lin.alpha1[gi] = a1;
            lin.alpha2[gi] = a2;
            report.iters_per_group.push(iters);
            *lambda_sum += lambda as f64;
        }
    }
}

/// One group's full progressive optimization (Algorithm 1 inner loops).
/// Returns (α1, α2, iterations, final λ).
fn optimize_group_full(
    wg: &[f32],
    t1: &mut [i8],
    t2: &mut [i8],
    o: &PtqtpOpts,
    scratch: &mut Scratch,
) -> (f32, f32, usize, f32) {
    // Algorithm 2 line 2: sign init with 0→1
    for (j, &x) in wg.iter().enumerate() {
        let s = if x < 0.0 { -1 } else { 1 };
        t1[j] = s;
        t2[j] = s;
    }
    let mut a1 = 1.0f32;
    let mut a2 = 1.0f32;
    let mut lambda = o.lambda_init;
    let mut iters = 0usize;
    let mut best_err = group_err(wg, t1, t2, a1, a2);
    for _t in 0..o.t_max {
        iters += 1;
        let (na1, na2, nl) = ridge_step(wg, t1, t2, lambda, o.lambda_max, o.kappa_threshold);
        lambda = nl;
        trit_search_scratch(wg, na1, na2, t1, t2, scratch);
        // Monotonicity tracking (Appendix C.2): each half-step is
        // phase-optimal, so `err` is non-increasing up to float noise;
        // `best_err` records the envelope for the debug assertion below.
        let err = group_err(wg, t1, t2, na1, na2);
        let d = ((na1 - a1).powi(2) + (na2 - a2).powi(2)).sqrt();
        a1 = na1;
        a2 = na2;
        debug_assert!(
            err <= best_err * (1.0 + 1e-4) + 1e-9,
            "group error increased: {best_err} -> {err}"
        );
        best_err = best_err.min(err);
        if d < o.eps {
            break;
        }
    }
    (a1, a2, iters, lambda)
}

/// Ridge half-step (Eq. 1/3/4 + adjugate inverse Eq. 7).
/// Returns (α1, α2, λ_new).
#[inline]
fn ridge_step(
    wg: &[f32],
    t1: &[i8],
    t2: &[i8],
    lambda: f32,
    lambda_max: f32,
    kappa_threshold: f64,
) -> (f32, f32, f32) {
    // A = SᵀS + λI where S = [t1ᵀ t2ᵀ]. The trit sums fit i32 for
    // any realistic G; f32 partials for b vectorize (4-wide unroll).
    let n = wg.len();
    let mut a11i = 0i32;
    let mut a22i = 0i32;
    let mut a12i = 0i32;
    let mut b1p = [0.0f32; 4];
    let mut b2p = [0.0f32; 4];
    for k in 0..n {
        let x1 = t1[k] as i32;
        let x2 = t2[k] as i32;
        a11i += x1 * x1;
        a22i += x2 * x2;
        a12i += x1 * x2;
        let lane = k & 3;
        let w = wg[k];
        b1p[lane] += x1 as f32 * w;
        b2p[lane] += x2 as f32 * w;
    }
    let b1 = (b1p[0] + b1p[1] + b1p[2] + b1p[3]) as f64;
    let b2 = (b2p[0] + b2p[1] + b2p[2] + b2p[3]) as f64;
    let mut lam = lambda;
    loop {
        let a11 = a11i as f64 + lam as f64;
        let a22 = a22i as f64 + lam as f64;
        let a12 = a12i as f64;
        let det = a11 * a22 - a12 * a12;
        // κ ≈ ‖A‖_F · ‖A⁻¹‖_F; for 2×2, ‖A⁻¹‖_F = ‖A‖_F/|det|
        let fro2 = a11 * a11 + a22 * a22 + 2.0 * a12 * a12;
        let kappa = if det.abs() < f64::MIN_POSITIVE {
            f64::INFINITY
        } else {
            fro2 / det.abs()
        };
        if kappa >= kappa_threshold && lam < lambda_max {
            // Eq. 3: λ ← λ·√(κ/threshold), capped at λ_max
            let grow = (kappa / kappa_threshold).sqrt().max(2.0);
            lam = (lam * grow as f32).min(lambda_max).max(lambda * 2.0).min(lambda_max);
            continue;
        }
        if det.abs() < 1e-300 {
            // fully degenerate even at λ_max (e.g. empty group)
            return (0.0, 0.0, lam);
        }
        let inv_det = 1.0 / det;
        let alpha1 = (a22 * b1 - a12 * b2) * inv_det;
        let alpha2 = (-a12 * b1 + a11 * b2) * inv_det;
        return (alpha1 as f32, alpha2 as f32, lam);
    }
}

/// Exhaustive 9-way trit search (Eq. 5). Mutates the planes; returns the
/// number of flipped positions (Fig 5 metric).
///
/// Perf note (EXPERIMENTS.md §Perf): the loop is candidate-outer /
/// element-inner so the inner loop is a branch-free select over f32
/// lanes that LLVM auto-vectorizes — ~3× faster than the original
/// element-outer scan on this CPU.
#[inline]
fn trit_search(wg: &[f32], a1: f32, a2: f32, t1: &mut [i8], t2: &mut [i8]) -> usize {
    let mut scratch = Scratch::new(wg.len());
    trit_search_scratch(wg, a1, a2, t1, t2, &mut scratch)
}

/// Reusable per-thread scratch for the vectorized search (avoids a
/// 40 KiB zero-init per group; see EXPERIMENTS.md §Perf).
pub(crate) struct Scratch {
    err: Vec<f32>,
    idx: Vec<u8>,
}

impl Scratch {
    fn new(n: usize) -> Scratch {
        Scratch {
            err: vec![0.0; n],
            idx: vec![0; n],
        }
    }
}

#[inline]
fn trit_search_scratch(
    wg: &[f32],
    a1: f32,
    a2: f32,
    t1: &mut [i8],
    t2: &mut [i8],
    scratch: &mut Scratch,
) -> usize {
    const C: [i8; 3] = [-1, 0, 1];
    // 9 candidate levels; nearest-level search via sorted midpoints:
    // idx(w) = #(midpoints < w) indexes the sorted levels, so the inner
    // loop is 8 vectorizable compares per element, no branches.
    let mut lv: [(f32, u8); 9] = [(0.0, 0); 9];
    for (i, &c1) in C.iter().enumerate() {
        for (j, &c2) in C.iter().enumerate() {
            let m = i * 3 + j;
            lv[m] = (a1 * c1 as f32 + a2 * c2 as f32, m as u8);
        }
    }
    lv.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut mids = [0.0f32; 8];
    for i in 0..8 {
        mids[i] = 0.5 * (lv[i].0 + lv[i + 1].0);
    }
    let order: [u8; 9] = std::array::from_fn(|i| lv[i].1);

    let n = wg.len();
    if scratch.idx.len() < n {
        scratch.idx.resize(n, 0);
        scratch.err.resize(n, 0.0);
    }
    let pos = &mut scratch.idx[..n];
    pos.fill(0);
    for &mid in mids.iter() {
        for k in 0..n {
            pos[k] += u8::from(wg[k] > mid);
        }
    }
    let mut flips = 0usize;
    for k in 0..n {
        let best = order[pos[k] as usize] as usize;
        let c1 = C[best / 3];
        let c2 = C[best % 3];
        flips += usize::from(t1[k] != c1 || t2[k] != c2);
        t1[k] = c1;
        t2[k] = c2;
    }
    flips
}

/// Group reconstruction error Σ (w − α1·t1 − α2·t2)².
fn group_err(wg: &[f32], t1: &[i8], t2: &[i8], a1: f32, a2: f32) -> f64 {
    let mut e = 0.0f64;
    for j in 0..wg.len() {
        let d = wg[j] as f64 - (a1 * t1[j] as f32 + a2 * t2[j] as f32) as f64;
        e += d * d;
    }
    e
}

impl Quantizer for Ptqtp {
    fn name(&self) -> String {
        "PTQTP".into()
    }

    fn nominal_bits(&self) -> f64 {
        1.58
    }

    fn quantize(&self, w: &Matrix, ctx: &QuantCtx) -> QuantResult {
        let (lin, _report) = self.quantize_with_report_pooled(w, &ctx.pool);
        QuantResult {
            w_hat: lin.reconstruct(),
            bits_per_weight: lin.bits_per_weight(),
            memory_bytes: lin.memory_bytes(),
            repr: QuantRepr::TritPlanes(lin),
        }
    }

    fn meta_json(&self) -> crate::serialize::Json {
        self.opts
            .to_json()
            .set("name", self.name())
            .set("nominal_bits", self.nominal_bits())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{check, prop_assert, Gen};
    use crate::rng::Rng;

    fn heavy(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::rand_heavy(rows, cols, 0.04, &mut rng)
    }

    #[test]
    fn reconstruction_beats_single_plane_absmean() {
        let w = heavy(16, 256, 1);
        let ptqtp = Ptqtp::new(PtqtpOpts {
            group: 64,
            ..Default::default()
        });
        let two = ptqtp.quantize(&w, &QuantCtx::default());
        let one = super::super::absmean::AbsMean::new(64).quantize(&w, &QuantCtx::default());
        let e2 = w.sq_err(&two.w_hat);
        let e1 = w.sq_err(&one.w_hat);
        assert!(e2 < e1 * 0.6, "two-plane {e2} vs one-plane {e1}");
    }

    #[test]
    fn converges_quickly_on_gaussian() {
        let mut rng = Rng::new(2);
        let w = Matrix::randn(8, 128, 0.02, &mut rng);
        let q = Ptqtp::new(PtqtpOpts {
            group: 128,
            ..Default::default()
        });
        let (_lin, rep) = q.quantize_with_report(&w);
        assert!(
            rep.max_iters() <= 50,
            "paper claims ≤50 iterations; got {}",
            rep.max_iters()
        );
        assert!(rep.mean_iters() < 30.0, "mean {}", rep.mean_iters());
    }

    #[test]
    fn error_history_monotone_nonincreasing() {
        let w = heavy(4, 128, 3);
        let q = Ptqtp::new(PtqtpOpts {
            group: 32,
            t_max: 20,
            track_history: true,
            ..Default::default()
        });
        let (_lin, rep) = q.quantize_with_report(&w);
        assert!(rep.err_history.len() >= 2);
        for win in rep.err_history.windows(2) {
            assert!(
                win[1] <= win[0] * (1.0 + 1e-6),
                "error increased: {} -> {}",
                win[0],
                win[1]
            );
        }
    }

    #[test]
    fn flips_decay_over_iterations() {
        let w = heavy(8, 256, 4);
        let q = Ptqtp::new(PtqtpOpts {
            group: 64,
            t_max: 30,
            track_history: true,
            ..Default::default()
        });
        let (_lin, rep) = q.quantize_with_report(&w);
        let first = rep.flip_history[0];
        let last = *rep.flip_history.last().unwrap();
        assert!(last < first / 4, "flips {first} -> {last}");
    }

    #[test]
    fn pooled_quantization_bit_identical_to_sequential() {
        let w = heavy(12, 256, 9);
        let q = Ptqtp::new(PtqtpOpts {
            group: 64,
            ..Default::default()
        });
        let (seq, seq_rep) = q.quantize_with_report(&w);
        for threads in [1usize, 2, 4, 7] {
            let pool = Pool::new(threads);
            let (par, par_rep) = q.quantize_with_report_pooled(&w, &pool);
            assert_eq!(par.t1, seq.t1, "threads={threads}");
            assert_eq!(par.t2, seq.t2, "threads={threads}");
            assert_eq!(par.alpha1, seq.alpha1, "threads={threads}");
            assert_eq!(par.alpha2, seq.alpha2, "threads={threads}");
            assert_eq!(par_rep.iters_per_group, seq_rep.iters_per_group);
            assert_eq!(par_rep.mean_lambda, seq_rep.mean_lambda);
            assert_eq!(par_rep.final_sq_err, seq_rep.final_sq_err);
        }
    }

    #[test]
    fn groupwise_beats_per_row_on_outliers() {
        // Table 8's claim: grouping improves approximation
        let w = heavy(8, 512, 5);
        let grouped = Ptqtp::new(PtqtpOpts {
            group: 128,
            ..Default::default()
        })
        .quantize(&w, &QuantCtx::default());
        let per_row = Ptqtp::new(PtqtpOpts {
            group: 0,
            ..Default::default()
        })
        .quantize(&w, &QuantCtx::default());
        assert!(w.sq_err(&grouped.w_hat) < w.sq_err(&per_row.w_hat));
    }

    #[test]
    fn exact_two_level_weights_recovered() {
        // W built exactly from two planes must quantize with ~zero error
        let mut rng = Rng::new(6);
        let mut lin = TernaryLinear::new(4, 64, 64);
        for t in lin.t1.trits.iter_mut().chain(lin.t2.trits.iter_mut()) {
            *t = rng.below(3) as i8 - 1;
        }
        for (i, a) in lin.alpha1.iter_mut().enumerate() {
            *a = 0.5 + 0.1 * i as f32;
        }
        for a in lin.alpha2.iter_mut() {
            *a = 0.05;
        }
        let w = lin.reconstruct();
        let q = Ptqtp::default().quantize(&w, &QuantCtx::default());
        // alternating minimization from sign-init is not guaranteed to
        // find the planted global optimum, but must land very close
        let rel = w.rel_err(&q.w_hat);
        assert!(rel < 0.1, "rel err {rel}");
    }

    #[test]
    fn alpha_ordering_dominant_plane() {
        // After convergence the first plane typically carries the larger
        // scale only by convention of init; we just check both finite &
        // bounded (Appendix C.2 bound).
        let w = heavy(8, 128, 7);
        let (lin, _) = Ptqtp::default().quantize_with_report(&w);
        for &a in lin.alpha1.iter().chain(&lin.alpha2) {
            assert!(a.is_finite());
            assert!(a.abs() < 10.0 * w.abs_max(), "alpha blow-up: {a}");
        }
    }

    #[test]
    fn zero_matrix_quantizes_to_zero() {
        let w = Matrix::zeros(4, 32);
        let q = Ptqtp::default().quantize(&w, &QuantCtx::default());
        assert!(q.w_hat.data.iter().all(|&x| x.abs() < 1e-6));
    }

    #[test]
    fn ridge_step_handles_degenerate_planes() {
        // identical planes → singular SᵀS. With λ this small the 2×2
        // condition estimate κ ≈ 2G/λ crosses the 10¹² threshold, so
        // Eq. 3 must grow λ; the solution must stay finite either way.
        let wg = [0.5f32, -0.5, 0.25, -0.25];
        let t1 = [1i8, -1, 1, -1];
        let t2 = t1;
        let (a1, a2, lam) = ridge_step(&wg, &t1, &t2, 1e-14, 1.0, 1e12);
        assert!(a1.is_finite() && a2.is_finite());
        assert!(lam > 1e-14, "λ should have adapted (got {lam})");
        // non-degenerate planes at healthy λ must NOT adapt
        let t2b = [1i8, 1, -1, -1];
        let (_, _, lam2) = ridge_step(&wg, &t1, &t2b, 1e-8, 1.0, 1e12);
        assert_eq!(lam2, 1e-8);
    }

    #[test]
    fn trit_search_is_elementwise_optimal() {
        let wg = [0.9f32, -0.1, 0.45, -1.6];
        let mut t1 = [0i8; 4];
        let mut t2 = [0i8; 4];
        trit_search(&wg, 1.0, 0.5, &mut t1, &mut t2);
        for k in 0..4 {
            let chosen = (wg[k] - (t1[k] as f32 + 0.5 * t2[k] as f32)).powi(2);
            for c1 in [-1i8, 0, 1] {
                for c2 in [-1i8, 0, 1] {
                    let e = (wg[k] - (c1 as f32 + 0.5 * c2 as f32)).powi(2);
                    assert!(chosen <= e + 1e-6, "k={k}: better combo exists");
                }
            }
        }
    }

    #[test]
    fn prop_error_never_worse_than_sign_init() {
        check(40, |g: &mut Gen| {
            let rows = g.usize_in(1, 6);
            let cols = 8 * g.usize_in(1, 8);
            let w = Matrix::from_vec(rows, cols, g.vec_normal(rows * cols, 0.05));
            let (lin, rep) = Ptqtp::new(PtqtpOpts {
                group: 32.min(cols),
                ..Default::default()
            })
            .quantize_with_report(&w);
            // sign-init baseline: T=sign(w), α=[1,1] → awful; converged
            // result must be dramatically better (or w==0)
            let base: f64 = w.data.iter().map(|&x| {
                let s = if x < 0.0 { -2.0 } else { 2.0 };
                ((x - s) as f64).powi(2)
            }).sum();
            prop_assert(
                rep.final_sq_err <= base + 1e-9,
                format!("final {} vs init {}", rep.final_sq_err, base),
            )?;
            prop_assert(lin.sq_err(&w) <= base + 1e-9, "recon err mismatch")
        });
    }

    #[test]
    fn prop_relative_error_reasonable_on_gaussian() {
        check(20, |g: &mut Gen| {
            let cols = 64 * g.usize_in(1, 4);
            let w = Matrix::from_vec(2, cols, g.vec_normal(2 * cols, 0.02));
            let q = Ptqtp::new(PtqtpOpts {
                group: 64,
                ..Default::default()
            })
            .quantize(&w, &QuantCtx::default());
            // two trit planes on gaussian data: relative error well under
            // a single-plane's ~0.4
            let rel = w.rel_err(&q.w_hat);
            prop_assert(rel < 0.35, format!("rel err {rel}"))
        });
    }
}

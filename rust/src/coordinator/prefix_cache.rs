//! Radix-tree prefix cache over shared KV pages (per replica).
//!
//! The tree is keyed by **page-aligned token chunks**: each edge from a
//! node carries exactly `page_size` token ids and the [`KvPage`]
//! holding those positions' K/V for every (layer, kv-head). A cached
//! prefix of `n` pages is the path of `n` edges whose concatenated
//! keys equal the first `n · page_size` prompt tokens.
//!
//! On admission the engine calls [`PrefixCache::lookup`]: the walk
//! adopts the longest matching page-aligned prefix by cloning the
//! `Arc<KvPage>`s (refcount bump — zero bytes copied), and the engine
//! prefills only the suffix. Completed sequences donate their prompt
//! pages back via [`PrefixCache::insert`]. Under page-pool pressure the
//! engine calls [`PrefixCache::evict_one`], which releases the
//! least-recently-used **unreferenced leaf** page back to the store —
//! pages still shared with a live sequence are never evicted (their
//! refcount keeps them alive regardless).
//!
//! Adoption is capped so at least one prompt token always prefills:
//! the engine needs logits for the last prompt token to sample the
//! first generated one, and a forward pass must process ≥ 1 row.
//!
//! Per-replica by design: `coordinator::router` session affinity pins
//! sessions to replicas, so a replica's tree sees its tenants' repeat
//! traffic (DESIGN.md §Paged-KV).

use crate::model::kv::{KvPage, PageStore};
use std::collections::HashMap;
use std::sync::Arc;

#[derive(Debug, Default)]
struct Node {
    children: HashMap<Box<[u32]>, Edge>,
}

#[derive(Debug)]
struct Edge {
    page: Arc<KvPage>,
    node: Node,
    /// Logical timestamp of the last lookup/insert touching this edge.
    last_used: u64,
}

/// Hit/miss counters, read by the engine into `coordinator::metrics`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefixStats {
    pub lookups: u64,
    pub hits: u64,
    pub adopted_tokens: u64,
    pub inserted_pages: u64,
    pub evicted_pages: u64,
}

/// Radix prefix cache: token-keyed tree of shared KV pages (module
/// docs). One per replica, owned by the serve engine.
#[derive(Debug)]
pub struct PrefixCache {
    page_size: usize,
    root: Node,
    clock: u64,
    stats: PrefixStats,
}

impl PrefixCache {
    pub fn new(page_size: usize) -> PrefixCache {
        assert!(page_size > 0, "page_size must be positive");
        PrefixCache {
            page_size,
            root: Node::default(),
            clock: 0,
            stats: PrefixStats::default(),
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn stats(&self) -> PrefixStats {
        self.stats
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Longest cached page-aligned prefix of `tokens`, capped at
    /// `(tokens.len() − 1) / page_size` pages so ≥ 1 token remains to
    /// prefill. Returns the pages to adopt (refcount-bumped, in
    /// position order); the adopted token count is `len · page_size`.
    pub fn lookup(&mut self, tokens: &[u32]) -> Vec<Arc<KvPage>> {
        self.stats.lookups += 1;
        let max_pages = if tokens.is_empty() {
            0
        } else {
            (tokens.len() - 1) / self.page_size
        };
        let now = self.tick();
        let mut pages = Vec::new();
        let mut node = &mut self.root;
        for chunk in tokens.chunks_exact(self.page_size).take(max_pages) {
            match node.children.get_mut(chunk) {
                Some(edge) => {
                    edge.last_used = now;
                    pages.push(edge.page.clone());
                    node = &mut edge.node;
                }
                None => break,
            }
        }
        if !pages.is_empty() {
            self.stats.hits += 1;
            self.stats.adopted_tokens += (pages.len() * self.page_size) as u64;
        }
        pages
    }

    /// Donate `pages` as the cached K/V of `tokens` (both page-aligned:
    /// `tokens.len() == pages.len() · page_size`). Existing edges keep
    /// their pages (first donor wins — the bytes are bit-identical by
    /// the parity discipline, so there is nothing to replace); missing
    /// edges take one extra reference to the donor's page.
    pub fn insert(&mut self, tokens: &[u32], pages: &[Arc<KvPage>]) {
        debug_assert_eq!(tokens.len(), pages.len() * self.page_size);
        let now = self.tick();
        let mut node = &mut self.root;
        for (chunk, page) in tokens.chunks_exact(self.page_size).zip(pages) {
            let inserted = &mut self.stats.inserted_pages;
            let edge = node
                .children
                .entry(chunk.to_vec().into_boxed_slice())
                .or_insert_with(|| {
                    *inserted += 1;
                    Edge {
                        page: page.clone(),
                        node: Node::default(),
                        last_used: now,
                    }
                });
            edge.last_used = now;
            node = &mut edge.node;
        }
    }

    /// Evict the least-recently-used **unreferenced leaf** page,
    /// releasing it to `store`. Returns `false` when nothing is
    /// evictable (every leaf is still shared with a live sequence, or
    /// the tree is empty). The engine calls this in a loop under page
    /// exhaustion before falling back to preemption.
    pub fn evict_one(&mut self, store: &PageStore) -> bool {
        let mut path: Vec<Box<[u32]>> = Vec::new();
        if !find_lru_leaf(&self.root, &mut path) {
            return false;
        }
        // detach the edge at `path` from the tree
        let mut node = &mut self.root;
        for key in &path[..path.len() - 1] {
            node = &mut node.children.get_mut(key).expect("path just found").node;
        }
        let edge = node
            .children
            .remove(path.last().expect("non-empty path"))
            .expect("path just found");
        store.release(edge.page);
        self.stats.evicted_pages += 1;
        true
    }

    /// Pages currently held by the tree.
    pub fn pages_held(&self) -> usize {
        fn count(node: &Node) -> usize {
            node.children.values().map(|e| 1 + count(&e.node)).sum()
        }
        count(&self.root)
    }
}

/// Depth-first search for the evictable leaf edge (no children, page
/// refcount 1 — held only by the tree) with the smallest `last_used`.
/// On success `path` holds the edge keys from the root; returns whether
/// one was found.
fn find_lru_leaf(node: &Node, path: &mut Vec<Box<[u32]>>) -> bool {
    fn walk(node: &Node, prefix: &mut Vec<Box<[u32]>>, best: &mut Option<(u64, Vec<Box<[u32]>>)>) {
        for (key, edge) in &node.children {
            prefix.push(key.clone());
            let evictable = edge.node.children.is_empty() && Arc::strong_count(&edge.page) == 1;
            let improves = match best {
                Some((t, _)) => edge.last_used < *t,
                None => true,
            };
            if evictable && improves {
                *best = Some((edge.last_used, prefix.clone()));
            }
            walk(&edge.node, prefix, best);
            prefix.pop();
        }
    }
    let mut best = None;
    let mut prefix = Vec::new();
    walk(node, &mut prefix, &mut best);
    match best {
        Some((_, p)) => {
            *path = p;
            true
        }
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::kv::{KvCache, PageStore};

    const PS: usize = 4; // page_size in positions == tokens per edge

    fn store() -> PageStore {
        PageStore::for_geometry(1, 1, 2, PS, None)
    }

    /// Build a donor cache holding `n_tokens` positions (page-aligned).
    fn donor(st: &PageStore, n_tokens: usize, tag: f32) -> KvCache {
        let mut c = KvCache::paged(1, 1, 2, 64, PS, st.clone());
        for i in 0..n_tokens {
            let x = tag + i as f32;
            c.append(0, &[x, x], &[-x, -x]);
            c.commit();
        }
        c
    }

    #[test]
    fn insert_then_lookup_adopts_page_aligned_prefix() {
        let st = store();
        let mut pc = PrefixCache::new(PS);
        let tokens: Vec<u32> = (0..8).collect();
        let d = donor(&st, 8, 0.0);
        pc.insert(&tokens, d.shared_pages(8));
        assert_eq!(pc.pages_held(), 2);

        // full-prefix query: capped at (len-1)/PS pages ⇒ if the query
        // IS the cached prompt, the last page is left to prefill…
        let hit = pc.lookup(&tokens);
        assert_eq!(hit.len(), 1, "adoption leaves ≥1 token to prefill");
        // …but a longer query adopts both pages
        let longer: Vec<u32> = (0..10).collect();
        let hit = pc.lookup(&longer);
        assert_eq!(hit.len(), 2);
        assert!(Arc::ptr_eq(&hit[0], &d.shared_pages(8)[0]), "same physical page");

        // diverging suffix only matches the shared first page
        let fork: Vec<u32> = vec![0, 1, 2, 3, 99, 98, 97, 96, 95];
        assert_eq!(pc.lookup(&fork).len(), 1);
        // diverging first page matches nothing
        let miss: Vec<u32> = (100..110).collect();
        assert!(pc.lookup(&miss).is_empty());
        let s = pc.stats();
        assert_eq!(s.lookups, 4);
        assert_eq!(s.hits, 3);
        assert_eq!(s.adopted_tokens, (1 + 2 + 1) as u64 * PS as u64);
    }

    #[test]
    fn insert_keeps_existing_pages_and_branches() {
        let st = store();
        let mut pc = PrefixCache::new(PS);
        let a: Vec<u32> = (0..8).collect();
        let da = donor(&st, 8, 0.0);
        pc.insert(&a, da.shared_pages(8));
        let first_page = pc.lookup(&(0..9).collect::<Vec<u32>>())[0].clone();

        // a second donor with the same first chunk but different tail:
        // the shared edge keeps its original page, the tail branches
        let b: Vec<u32> = vec![0, 1, 2, 3, 50, 51, 52, 53];
        let db = donor(&st, 8, 100.0);
        pc.insert(&b, db.shared_pages(8));
        assert_eq!(pc.pages_held(), 3, "one shared + two tails");
        let again = pc.lookup(&(0..9).collect::<Vec<u32>>())[0].clone();
        assert!(Arc::ptr_eq(&first_page, &again), "first donor wins");
    }

    #[test]
    fn evicts_lru_unreferenced_leaf_only() {
        let st = store();
        let mut pc = PrefixCache::new(PS);
        let a: Vec<u32> = (0..8).collect();
        {
            let da = donor(&st, 8, 0.0);
            pc.insert(&a, da.shared_pages(8));
        } // donor dropped: tree holds the only refs
        let live_before = st.stats().live;
        assert_eq!(live_before, 2);

        // an inner edge with children is never evicted — only the leaf
        assert!(pc.evict_one(&st));
        assert_eq!(pc.pages_held(), 1);
        // now the ex-inner edge is a leaf and goes too
        assert!(pc.evict_one(&st));
        assert_eq!(pc.pages_held(), 0);
        assert!(!pc.evict_one(&st), "empty tree has nothing to evict");
        let s = st.stats();
        assert_eq!(s.live, 0);
        assert_eq!(s.free, 2, "evicted pages returned to the store");
        assert_eq!(pc.stats().evicted_pages, 2);
    }

    #[test]
    fn eviction_skips_pages_shared_with_live_sequences() {
        let st = store();
        let mut pc = PrefixCache::new(PS);
        let a: Vec<u32> = (0..4).collect();
        let da = donor(&st, 4, 0.0);
        pc.insert(&a, da.shared_pages(4));
        // the donor still holds a ref ⇒ refcount 2 ⇒ not evictable
        assert!(!pc.evict_one(&st));
        drop(da);
        assert!(pc.evict_one(&st));
    }

    #[test]
    fn lru_order_prefers_stalest_leaf() {
        let st = store();
        let mut pc = PrefixCache::new(PS);
        let a: Vec<u32> = (0..4).collect();
        let b: Vec<u32> = (10..14).collect();
        {
            let da = donor(&st, 4, 0.0);
            pc.insert(&a, da.shared_pages(4));
            let db = donor(&st, 4, 50.0);
            pc.insert(&b, db.shared_pages(4));
        }
        // touch `a` so `b` becomes the LRU leaf
        assert_eq!(pc.lookup(&(0..5).collect::<Vec<u32>>()).len(), 1);
        assert!(pc.evict_one(&st));
        // `a` must still be resident, `b` gone
        assert_eq!(pc.lookup(&(0..5).collect::<Vec<u32>>()).len(), 1);
        assert!(pc.lookup(&(10..15).collect::<Vec<u32>>()).is_empty());
    }

    #[test]
    fn short_prompts_never_adopt_everything() {
        let mut pc = PrefixCache::new(PS);
        // prompt shorter than one page: nothing to adopt
        assert!(pc.lookup(&[1, 2, 3]).is_empty());
        // prompt of exactly one page: still nothing (≥1 token must prefill)
        assert!(pc.lookup(&[1, 2, 3, 4]).is_empty());
    }
}

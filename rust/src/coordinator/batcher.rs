//! Continuous batcher: per-step admission and work composition.
//!
//! Orca-style iteration-level scheduling: every engine step serves one
//! decode token for each running sequence, plus up to
//! `prefill_token_budget` prompt tokens from sequences still in
//! prefill — so long prompts never stall decode latency (the paper's
//! Table 5 prefill/decode split motivates exactly this policy).
//!
//! The plan describes **one fused batch**: the engine stacks every
//! planned prefill token and decode token into a single
//! `ForwardBatch` and executes them in one model pass (see
//! `rust/DESIGN.md` §Batched-Forward) — [`StepPlan::batch_rows`] is
//! the row count of that pass.

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max running sequences (bounded by the KV pool anyway).
    pub max_running: usize,
    /// Prompt tokens admitted per step across all prefilling sequences.
    pub prefill_token_budget: usize,
    /// Prefer finishing prefill of one sequence before starting another.
    pub fcfs_prefill: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_running: 16,
            prefill_token_budget: 64,
            fcfs_prefill: true,
        }
    }
}

impl BatchPolicy {
    /// Chainable override (`BatchPolicy::default().with_max_running(1)`
    /// — the saturation knob the deadline/cancel tests lean on).
    pub fn with_max_running(mut self, n: usize) -> BatchPolicy {
        self.max_running = n;
        self
    }

    /// Chainable override of the per-step prefill token budget.
    pub fn with_prefill_budget(mut self, tokens: usize) -> BatchPolicy {
        self.prefill_token_budget = tokens;
        self
    }
}

/// What one engine step should do: `(sequence index, tokens to prefill)`
/// for prefill work; decode is implicit for all non-prefill sequences.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StepPlan {
    /// (slot index, number of prompt tokens to consume this step)
    pub prefill: Vec<(usize, usize)>,
    /// Slot indices to decode one token for.
    pub decode: Vec<usize>,
}

impl StepPlan {
    /// Upper bound on rows in the fused forward batch this plan
    /// describes: all prefill tokens plus one decode row per decoding
    /// sequence (sequences that finish this step contribute their row's
    /// sampling but no continuation row, so the realized batch can be
    /// smaller). The engine pre-sizes its `ForwardBatch` with this.
    pub fn batch_rows(&self) -> usize {
        self.batch_rows_with_drafts(0)
    }

    /// [`StepPlan::batch_rows`] under speculative decoding: every
    /// decoding sequence may add up to `spec_k` draft rows to its one
    /// committed row, so the fused pass holds `1..=1 + spec_k` rows
    /// per decode slot (`--spec-decode off` ⇒ `spec_k = 0`, the exact
    /// plain bound). Still an upper bound — the speculator drafts
    /// fewer or zero tokens when the context has no matching n-gram,
    /// and the engine clamps drafts to the sequence's remaining token
    /// budget and KV positions.
    pub fn batch_rows_with_drafts(&self, spec_k: usize) -> usize {
        self.prefill.iter().map(|&(_, take)| take).sum::<usize>()
            + self.decode.len() * (1 + spec_k)
    }
}

/// Plan one step given per-slot state snapshots.
/// `slots[i] = (in_prefill, remaining_prompt, has_pending_logits)`.
///
/// The plan is advisory on capacity: the engine re-checks each planned
/// slot against the paged KV allocator (`KvCache::reserve`) when
/// building the batch, and a slot that cannot get pages is preempted —
/// released and re-enqueued for recompute — rather than planned around
/// here, keeping the planner oblivious to page accounting. A resumed
/// sequence's recompute tokens ride the normal prefill budget:
/// `remaining_prompt` covers prompt + prior generation for it.
pub fn plan_step(policy: &BatchPolicy, slots: &[(bool, usize, bool)]) -> StepPlan {
    let mut plan = StepPlan::default();
    let mut budget = policy.prefill_token_budget;
    for (i, &(in_prefill, remaining, has_logits)) in slots.iter().enumerate() {
        if in_prefill {
            if budget == 0 {
                continue;
            }
            let take = remaining.min(budget);
            if take > 0 {
                plan.prefill.push((i, take));
                budget -= take;
                if policy.fcfs_prefill && budget == 0 {
                    // stop scanning; later sequences wait their turn
                    continue;
                }
            }
        } else if has_logits {
            plan.decode.push(i);
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_all_running() {
        let policy = BatchPolicy::default();
        let slots = vec![(false, 0, true), (false, 0, true), (false, 0, true)];
        let plan = plan_step(&policy, &slots);
        assert_eq!(plan.decode, vec![0, 1, 2]);
        assert!(plan.prefill.is_empty());
    }

    #[test]
    fn prefill_budget_split() {
        let policy = BatchPolicy {
            prefill_token_budget: 10,
            ..Default::default()
        };
        let slots = vec![(true, 6, false), (true, 8, false)];
        let plan = plan_step(&policy, &slots);
        assert_eq!(plan.prefill, vec![(0, 6), (1, 4)]);
    }

    #[test]
    fn budget_exhaustion_starves_later_prefills_only() {
        let policy = BatchPolicy {
            prefill_token_budget: 4,
            ..Default::default()
        };
        let slots = vec![(true, 9, false), (false, 0, true), (true, 3, false)];
        let plan = plan_step(&policy, &slots);
        assert_eq!(plan.prefill, vec![(0, 4)]);
        assert_eq!(plan.decode, vec![1], "decode never starved by prefill");
    }

    #[test]
    fn batch_rows_counts_fused_work() {
        let policy = BatchPolicy {
            prefill_token_budget: 10,
            ..Default::default()
        };
        let slots = vec![(true, 6, false), (false, 0, true), (true, 8, false)];
        let plan = plan_step(&policy, &slots);
        // 6 + 4 prefill rows + 1 decode row
        assert_eq!(plan.batch_rows(), 11);
        // with k=3 speculative drafts the decode slot may hold 4 rows
        assert_eq!(plan.batch_rows_with_drafts(3), 14);
        assert_eq!(plan.batch_rows_with_drafts(0), plan.batch_rows());
    }

    #[test]
    fn mixed_interleaving() {
        let policy = BatchPolicy {
            prefill_token_budget: 100,
            ..Default::default()
        };
        let slots = vec![(false, 0, true), (true, 5, false), (false, 0, true)];
        let plan = plan_step(&policy, &slots);
        assert_eq!(plan.decode, vec![0, 2]);
        assert_eq!(plan.prefill, vec![(1, 5)]);
    }

    #[test]
    fn sequences_without_logits_skip_decode() {
        // freshly admitted but zero-length prompt edge case
        let policy = BatchPolicy::default();
        let slots = vec![(false, 0, false)];
        let plan = plan_step(&policy, &slots);
        assert!(plan.decode.is_empty());
    }
}

//! Replica supervision policy: retry budgets, deterministic backoff,
//! and the hardened checkpoint-reload path used when a dead replica is
//! respawned cold from its packed PTW2 file.
//!
//! The actual supervision loop lives in [`Server`](super::server::Server)
//! (it owns the worker threads and the event channel); this module holds
//! the pieces that are policy, not plumbing, so they can be unit-tested
//! without spinning up replicas.

use std::sync::Arc;
use std::time::Duration;

use crate::model::Transformer;
use crate::rng::Rng;

use super::faults::FaultPlan;

/// Bounded retry with exponential backoff for requests orphaned by a
/// replica death. Attempt `k` (1-based) waits
/// `min(cap, base * 2^(k-1))` plus deterministic jitter in `[0, base)`
/// keyed by `(request_id, k)` — jitter decorrelates a thundering herd
/// of requeues without sacrificing run-to-run reproducibility.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Replays allowed per request before it fails typed
    /// [`FinishReason::ReplicaLost`](super::request::FinishReason).
    pub max_attempts: u32,
    /// First-attempt delay; doubles each attempt.
    pub base: Duration,
    /// Ceiling on the exponential term.
    pub cap: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(500),
        }
    }
}

impl RetryPolicy {
    /// Delay before retry attempt `attempt` (1-based) of `request_id`.
    pub fn delay(&self, request_id: u64, attempt: u32) -> Duration {
        let shift = attempt.saturating_sub(1).min(20);
        let exp = self
            .base
            .saturating_mul(1u32 << shift)
            .min(self.cap);
        let jitter_ns = if self.base.is_zero() {
            0
        } else {
            let mut rng = Rng::new(request_id ^ (attempt as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            rng.next_u64() % self.base.as_nanos().min(u64::MAX as u128) as u64
        };
        exp + Duration::from_nanos(jitter_ns)
    }
}

/// Where the supervisor gets weights for a cold respawn.
#[derive(Clone)]
pub enum ModelSource {
    /// Clone an in-memory (already quantized) model — the path used by
    /// `ServerBuilder::start(model)` and every test.
    Memory(Arc<Transformer>),
    /// Reload the packed PTW2 checkpoint from disk (quantize-once /
    /// serve-many: restart skips the quantization pass entirely).
    Checkpoint(String),
    /// No source — dead replicas stay dead and their requests fail over
    /// to the survivors (the pre-supervision `Server::start(engines,..)`
    /// shim lands here).
    Unavailable,
}

impl std::fmt::Debug for ModelSource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ModelSource::Memory(_) => write!(f, "ModelSource::Memory"),
            ModelSource::Checkpoint(p) => write!(f, "ModelSource::Checkpoint({p:?})"),
            ModelSource::Unavailable => write!(f, "ModelSource::Unavailable"),
        }
    }
}

/// Why a cold respawn failed. Never a panic: a replica whose restart
/// fails is marked permanently dead and its pinned requests retire with
/// `ReplicaLost`; the rest of the server keeps serving.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RestartError {
    /// Both checkpoint-read attempts failed (truncation, bad magic,
    /// checksum mismatch, I/O error — `Transformer::load` is already
    /// fully typed and panic-free).
    CheckpointLoad(String),
    /// The server has no [`ModelSource`] to respawn from.
    NoModelSource,
}

impl std::fmt::Display for RestartError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RestartError::CheckpointLoad(e) => write!(f, "checkpoint reload failed: {e}"),
            RestartError::NoModelSource => write!(f, "no model source for respawn"),
        }
    }
}

impl std::error::Error for RestartError {}

/// Produce a fresh model for replica `replica`, retrying a failed
/// checkpoint read exactly once after a `policy.base` backoff. A fault
/// plan with a pending `ckpt_io` entry for this replica poisons the
/// *first* attempt (deterministically), so the retry path is exercised
/// end-to-end in chaos runs; a second consecutive failure — a genuinely
/// truncated or corrupt file — surfaces as a typed
/// [`RestartError::CheckpointLoad`].
pub fn respawn_model(
    source: &ModelSource,
    replica: usize,
    faults: Option<&FaultPlan>,
    policy: &RetryPolicy,
) -> Result<Transformer, RestartError> {
    match source {
        ModelSource::Memory(m) => {
            if faults.is_some_and(|f| f.fire_ckpt(replica)) {
                // Injected I/O fault on an in-memory source still takes
                // the backoff (first "attempt" failed) but always
                // recovers — memory cannot be truncated.
                std::thread::sleep(policy.base.min(Duration::from_millis(50)));
            }
            Ok(m.as_ref().clone())
        }
        ModelSource::Checkpoint(path) => {
            let injected = faults.is_some_and(|f| f.fire_ckpt(replica));
            let first = if injected {
                Err(anyhow::anyhow!(
                    "injected fault: ckpt_io (replica {replica})"
                ))
            } else {
                Transformer::load(path)
            };
            match first {
                Ok(m) => Ok(m),
                Err(e1) => {
                    std::thread::sleep(policy.base.min(Duration::from_millis(50)));
                    Transformer::load(path).map_err(|e2| {
                        RestartError::CheckpointLoad(format!(
                            "attempt 1: {e1}; attempt 2: {e2}"
                        ))
                    })
                }
            }
        }
        ModelSource::Unavailable => Err(RestartError::NoModelSource),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::faults::{FaultEntry, FaultKind};
    use crate::model::ModelConfig;

    #[test]
    fn backoff_doubles_to_cap_and_jitter_is_deterministic() {
        let p = RetryPolicy {
            max_attempts: 5,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(35),
        };
        // exponential term: 10, 20, 35 (capped), 35 ... jitter < base
        for (attempt, floor) in [(1u32, 10u64), (2, 20), (3, 35), (4, 35)] {
            let d = p.delay(42, attempt);
            assert!(d >= Duration::from_millis(floor), "attempt {attempt}: {d:?}");
            assert!(d < Duration::from_millis(floor + 10), "attempt {attempt}: {d:?}");
        }
        assert_eq!(p.delay(42, 2), p.delay(42, 2), "jitter is seeded, not random");
        assert_ne!(
            p.delay(42, 2),
            p.delay(43, 2),
            "different requests decorrelate"
        );
    }

    #[test]
    fn huge_attempt_counts_do_not_overflow() {
        let p = RetryPolicy::default();
        let d = p.delay(7, u32::MAX);
        assert!(d <= p.cap + p.base);
    }

    fn tiny_model(seed: u64) -> Transformer {
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = 16;
        cfg.max_seq = 16;
        Transformer::random(cfg, &mut Rng::new(seed))
    }

    #[test]
    fn corrupt_checkpoint_fails_typed_never_panics() {
        let dir = std::env::temp_dir().join("ptqtp_supervisor_corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.ptw2");
        let m = tiny_model(5);
        m.save(&path).unwrap();
        // truncate to half: both load attempts must fail with a typed
        // error (this is the satellite's corruption-injection test)
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        let policy = RetryPolicy {
            base: Duration::from_millis(1),
            ..RetryPolicy::default()
        };
        let src = ModelSource::Checkpoint(path.to_string_lossy().into_owned());
        match respawn_model(&src, 0, None, &policy) {
            Err(RestartError::CheckpointLoad(msg)) => {
                assert!(msg.contains("attempt 2"), "both attempts recorded: {msg}");
            }
            other => panic!("expected CheckpointLoad, got {other:?}"),
        }
    }

    #[test]
    fn injected_ckpt_io_fault_recovers_on_retry() {
        let dir = std::env::temp_dir().join("ptqtp_supervisor_ckpt_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.ptw2");
        tiny_model(6).save(&path).unwrap();
        let plan = FaultPlan::new(vec![FaultEntry {
            replica: 0,
            step: 0,
            kind: FaultKind::CkptIoError,
        }]);
        let policy = RetryPolicy {
            base: Duration::from_millis(1),
            ..RetryPolicy::default()
        };
        let src = ModelSource::Checkpoint(path.to_string_lossy().into_owned());
        // first attempt is poisoned by the plan; the retry reads the
        // intact file and succeeds
        let m = respawn_model(&src, 0, Some(&plan), &policy).expect("retry recovers");
        assert_eq!(m.config.vocab_size, 16);
        // the latch is spent: a second respawn is clean
        assert!(respawn_model(&src, 0, Some(&plan), &policy).is_ok());
    }

    #[test]
    fn unavailable_source_is_typed() {
        assert_eq!(
            respawn_model(&ModelSource::Unavailable, 0, None, &RetryPolicy::default())
                .err()
                .unwrap(),
            RestartError::NoModelSource
        );
    }
}

//! Threaded serve front-end: admission-controlled intake → router →
//! per-replica worker threads → event channel.
//!
//! tokio is unavailable offline (DESIGN.md §2), so concurrency is
//! std::thread + mpsc: one worker thread per engine replica runs the
//! continuous-batching loop and forwards every [`ServerEvent`] it
//! emits; the handle submits requests and consumes the event stream
//! without blocking workers.
//!
//! The API surface (DESIGN.md §Serve-Frontend):
//!
//! * [`ServerBuilder`] — the one constructor; [`Server::start`]
//!   survives as a shim.
//! * [`Server::submit`] → [`SubmitOutcome`]: `Accepted(RequestHandle)`
//!   or a typed rejection (queue full / invalid params / stopped) —
//!   admission is a bounded per-replica intake window, so callers see
//!   backpressure instead of unbounded channel growth.
//! * [`Server::next_event`] / [`Server::poll_events`] — the streaming
//!   consumption path; [`Server::poll`] / [`Server::wait_for`] remain
//!   as adapters that keep only the `Done` responses.
//! * [`Server::drain`] — stop intake, finish in-flight work, return
//!   every leftover event + final metrics; [`Server::shutdown`] stays
//!   abortive (workers exit at the next step boundary).

use super::engine::ServeEngine;
use super::metrics::{Metrics, ServerStats};
use super::request::{
    Request, RequestHandle, Response, SamplingParams, ServerEvent, SubmitError,
};
use super::router::{RoutePolicy, Router};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

enum WorkerMsg {
    Submit(Request),
    /// Stop intake, keep stepping until the engine is empty, then exit.
    Drain,
    /// Exit at the next loop iteration, abandoning queued work.
    Shutdown,
}

/// Default per-replica intake window: effectively "no backpressure"
/// for test workloads, while still bounding a runaway producer.
pub const DEFAULT_INTAKE_LIMIT: usize = 1024;

/// Accept/reject verdict from [`Server::submit`].
#[must_use]
#[derive(Debug)]
pub enum SubmitOutcome {
    Accepted(RequestHandle),
    Rejected(SubmitError),
}

impl SubmitOutcome {
    pub fn is_accepted(&self) -> bool {
        matches!(self, SubmitOutcome::Accepted(_))
    }

    /// The accepted handle, or `None` on rejection.
    pub fn handle(self) -> Option<RequestHandle> {
        match self {
            SubmitOutcome::Accepted(h) => Some(h),
            SubmitOutcome::Rejected(_) => None,
        }
    }

    /// The rejection reason, if any.
    pub fn err(&self) -> Option<SubmitError> {
        match self {
            SubmitOutcome::Accepted(_) => None,
            SubmitOutcome::Rejected(e) => Some(*e),
        }
    }

    /// The accepted request id; panics on a rejection. For call sites
    /// (mostly tests) that know admission cannot fail.
    pub fn id(&self) -> super::request::RequestId {
        match self {
            SubmitOutcome::Accepted(h) => h.id(),
            SubmitOutcome::Rejected(e) => panic!("submit rejected: {e}"),
        }
    }
}

/// Everything a graceful [`Server::drain`] hands back: the events that
/// had not been consumed yet (in per-replica emission order) and each
/// replica's final [`Metrics`] snapshot, sorted by replica index.
#[derive(Debug)]
pub struct DrainReport {
    pub events: Vec<ServerEvent>,
    pub metrics: Vec<Metrics>,
}

impl DrainReport {
    /// Just the terminal responses among the leftover events.
    pub fn responses(&self) -> Vec<Response> {
        self.events
            .iter()
            .filter_map(|ev| match ev {
                ServerEvent::Done(r) => Some(r.clone()),
                ServerEvent::Token { .. } => None,
            })
            .collect()
    }
}

/// Builder for a running multi-replica [`Server`] — replaces the old
/// `start` / `start_replicas` / `start_replicas_with` constructor trio.
#[derive(Clone, Debug)]
pub struct ServerBuilder {
    replicas: usize,
    route: RoutePolicy,
    batch: super::batcher::BatchPolicy,
    threads: usize,
    kv: super::kv_pool::PagedKvOpts,
    spec: Option<super::speculator::SpecDecodeOpts>,
    intake_limit: usize,
    default_deadline: Option<Duration>,
}

impl Default for ServerBuilder {
    fn default() -> Self {
        ServerBuilder {
            replicas: 1,
            route: RoutePolicy::LeastLoaded,
            batch: super::batcher::BatchPolicy::default(),
            threads: crate::threads::default_threads(),
            kv: super::kv_pool::PagedKvOpts::default(),
            spec: None,
            intake_limit: DEFAULT_INTAKE_LIMIT,
            default_deadline: None,
        }
    }
}

impl ServerBuilder {
    pub fn new() -> ServerBuilder {
        ServerBuilder::default()
    }

    /// Engine replicas (≥ 1), each on its own worker thread.
    pub fn replicas(mut self, n: usize) -> ServerBuilder {
        self.replicas = n.max(1);
        self
    }

    pub fn route(mut self, policy: RoutePolicy) -> ServerBuilder {
        self.route = policy;
        self
    }

    pub fn batch(mut self, policy: super::batcher::BatchPolicy) -> ServerBuilder {
        self.batch = policy;
        self
    }

    /// Kernel-pool lanes **per replica** (so replicas never contend on
    /// a shared pool's dispatch lock); `1` forces the exact sequential
    /// kernel path — the debugging escape hatch `--threads 1` plumbs
    /// through here.
    pub fn threads(mut self, threads: usize) -> ServerBuilder {
        self.threads = threads;
        self
    }

    /// Paged-KV options (`--page-size` / `--prefix-cache` /
    /// `--kv-pages`). Each replica gets its own page store and radix
    /// prefix tree — prefix reuse is per-replica, which is why
    /// session-affinity routing pairs well with the cache.
    pub fn paged_kv(mut self, kv: super::kv_pool::PagedKvOpts) -> ServerBuilder {
        self.kv = kv;
        self
    }

    /// Speculative decoding (`--spec-decode` / `--spec-k`): every
    /// replica drafts with the same prompt-lookup speculator. `None`
    /// (the default) is plain one-token-per-step decode. Purely a
    /// scheduling optimization — output is token-for-token identical
    /// either way (see `coordinator::speculator`).
    pub fn spec_decode(mut self, spec: Option<super::speculator::SpecDecodeOpts>) -> ServerBuilder {
        self.spec = spec;
        self
    }

    /// Bound on accepted-but-unfinished requests per replica; beyond
    /// it [`Server::submit`] rejects with [`SubmitError::QueueFull`].
    pub fn intake_limit(mut self, n: usize) -> ServerBuilder {
        self.intake_limit = n.max(1);
        self
    }

    /// Deadline applied to every request submitted without its own
    /// (`--deadline-ms`).
    pub fn default_deadline(mut self, deadline: Duration) -> ServerBuilder {
        self.default_deadline = Some(deadline);
        self
    }

    /// Spawn `replicas` engines cloned from one model and start a
    /// worker thread per replica.
    pub fn start(self, model: crate::model::Transformer) -> Server {
        let engines = (0..self.replicas)
            .map(|_| {
                let mut e =
                    ServeEngine::with_opts(model.clone(), self.batch, self.threads, self.kv);
                e.set_spec_decode(self.spec);
                e
            })
            .collect();
        self.start_engines(engines)
    }

    /// Start over caller-built engines (heterogeneous replicas, tests).
    /// `replicas`/`batch`/`threads`/`paged_kv`/`spec_decode` settings
    /// are ignored — the engines carry their own.
    pub fn start_engines(self, engines: Vec<ServeEngine>) -> Server {
        assert!(!engines.is_empty(), "need at least one engine replica");
        let n = engines.len();
        let (event_tx, event_rx) = channel::<(usize, ServerEvent)>();
        let (metrics_tx, metrics_rx) = channel::<(usize, Metrics)>();
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut workers = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        let mut intake = Vec::with_capacity(n);
        for (replica, mut engine) in engines.into_iter().enumerate() {
            let (tx, rx) = channel::<WorkerMsg>();
            let event_tx = event_tx.clone();
            let metrics_tx = metrics_tx.clone();
            let stop = shutdown.clone();
            let gauge = Arc::new(AtomicUsize::new(0));
            intake.push(gauge.clone());
            handles.push(std::thread::spawn(move || {
                engine.set_intake_depth(gauge);
                worker_loop(replica, &mut engine, rx, event_tx, metrics_tx, stop);
            }));
            workers.push(tx);
        }
        Server {
            router: Router::new(n, self.route),
            workers,
            events: event_rx,
            metrics_rx,
            handles,
            next_id: AtomicU64::new(1),
            shutdown,
            intake,
            intake_limit: self.intake_limit,
            default_deadline: self.default_deadline,
            stats: ServerStats::default(),
        }
    }
}

/// A running multi-replica server.
pub struct Server {
    router: Router,
    workers: Vec<Sender<WorkerMsg>>,
    events: Receiver<(usize, ServerEvent)>,
    /// Final per-replica metrics snapshots, sent as workers exit.
    metrics_rx: Receiver<(usize, Metrics)>,
    handles: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    shutdown: Arc<AtomicBool>,
    /// Per-replica accepted-but-unfinished gauges, decremented by the
    /// engines as requests retire (see `ServeEngine::set_intake_depth`).
    intake: Vec<Arc<AtomicUsize>>,
    intake_limit: usize,
    default_deadline: Option<Duration>,
    /// Admission counters for the serve-metrics artifact.
    pub stats: ServerStats,
}

impl Server {
    /// Pre-builder shim, kept so old call sites read unchanged.
    /// **Deprecated in favour of [`ServerBuilder`]**:
    /// `ServerBuilder::new().route(policy).start_engines(engines)`.
    pub fn start(engines: Vec<ServeEngine>, policy: RoutePolicy) -> Server {
        ServerBuilder::new().route(policy).start_engines(engines)
    }

    /// Submit a prompt under the server's default deadline (if any).
    pub fn submit(
        &mut self,
        prompt: Vec<u32>,
        params: SamplingParams,
        session: u64,
    ) -> SubmitOutcome {
        self.submit_with_deadline(prompt, params, session, self.default_deadline)
    }

    /// Submit with an explicit per-request deadline (`None` =
    /// unbounded, overriding the server default).
    ///
    /// Admission: parameters are validated first; then the routed
    /// replica must have intake room. Sessionless requests may spill
    /// to any replica with room before rejecting; session-pinned
    /// requests never spill (their KV/prefix locality is the point of
    /// the pin). A worker whose thread has exited surfaces as
    /// [`SubmitError::ServerStopped`] — previously that request was
    /// dropped silently while returning a live-looking id.
    pub fn submit_with_deadline(
        &mut self,
        prompt: Vec<u32>,
        params: SamplingParams,
        session: u64,
        deadline: Option<Duration>,
    ) -> SubmitOutcome {
        self.stats.submitted += 1;
        if let Err(e) = params.validate() {
            self.stats.invalid_params += 1;
            return SubmitOutcome::Rejected(e);
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut req = Request::new(id, prompt, params);
        req.session = session;
        req.deadline = deadline;
        let primary = self.router.route(&req);
        let n = self.workers.len();
        let mut replica = None;
        for k in 0..n {
            let candidate = (primary + k) % n;
            if k > 0 && session != 0 {
                break; // pinned sessions don't spill
            }
            if try_acquire(&self.intake[candidate], self.intake_limit) {
                replica = Some(candidate);
                break;
            }
        }
        let Some(replica) = replica else {
            self.router.unroute(primary);
            self.stats.queue_full += 1;
            return SubmitOutcome::Rejected(SubmitError::QueueFull { replica: primary });
        };
        if replica != primary {
            self.router.unroute(primary);
            self.router.assign(replica);
        }
        let handle = req.handle(replica);
        if self.workers[replica].send(WorkerMsg::Submit(req)).is_err() {
            release(&self.intake[replica]);
            self.router.unroute(replica);
            self.stats.server_stopped += 1;
            return SubmitOutcome::Rejected(SubmitError::ServerStopped);
        }
        self.stats.accepted += 1;
        SubmitOutcome::Accepted(handle)
    }

    /// Non-blocking: next queued event, if any.
    pub fn try_next_event(&mut self) -> Option<ServerEvent> {
        match self.events.try_recv() {
            Ok((replica, ev)) => {
                self.note_event(replica, &ev);
                Some(ev)
            }
            Err(_) => None,
        }
    }

    /// Block up to `timeout` for the next event.
    pub fn next_event(&mut self, timeout: Duration) -> Option<ServerEvent> {
        match self.events.recv_timeout(timeout) {
            Ok((replica, ev)) => {
                self.note_event(replica, &ev);
                Some(ev)
            }
            Err(_) => None,
        }
    }

    /// Non-blocking: drain every event currently queued.
    pub fn poll_events(&mut self) -> Vec<ServerEvent> {
        let mut out = Vec::new();
        while let Some(ev) = self.try_next_event() {
            out.push(ev);
        }
        out
    }

    fn note_event(&mut self, replica: usize, ev: &ServerEvent) {
        if let ServerEvent::Done(_) = ev {
            self.router.complete(replica);
        }
    }

    /// Non-blocking poll for finished responses — the pre-streaming
    /// API, now an adapter that keeps only `Done` events. Token events
    /// drained here are dropped; streaming consumers use
    /// [`Server::poll_events`] / [`Server::next_event`] instead.
    pub fn poll(&mut self) -> Vec<Response> {
        self.poll_events()
            .into_iter()
            .filter_map(|ev| match ev {
                ServerEvent::Done(r) => Some(r),
                ServerEvent::Token { .. } => None,
            })
            .collect()
    }

    /// Block until `n` responses arrive or `timeout` elapses (adapter
    /// over the event stream, like [`Server::poll`]).
    pub fn wait_for(&mut self, n: usize, timeout: Duration) -> Vec<Response> {
        let deadline = std::time::Instant::now() + timeout;
        let mut out = Vec::new();
        while out.len() < n && std::time::Instant::now() < deadline {
            if let Some(ServerEvent::Done(r)) = self.next_event(Duration::from_millis(10)) {
                out.push(r);
            }
        }
        out
    }

    /// Graceful drain: stop intake, let every replica finish its
    /// in-flight and queued work, then hand back all unconsumed events
    /// plus final per-replica metrics. The event channel is unbounded,
    /// so joining the workers before collecting cannot deadlock —
    /// everything they emitted is still buffered.
    pub fn drain(mut self) -> DrainReport {
        for w in &self.workers {
            let _ = w.send(WorkerMsg::Drain);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        let mut events = Vec::new();
        while let Ok((replica, ev)) = self.events.try_recv() {
            self.note_event(replica, &ev);
            events.push(ev);
        }
        let mut metrics: Vec<(usize, Metrics)> = self.metrics_rx.try_iter().collect();
        metrics.sort_by_key(|(replica, _)| *replica);
        DrainReport {
            events,
            metrics: metrics.into_iter().map(|(_, m)| m).collect(),
        }
    }

    /// Abortive shutdown: workers exit at their next loop iteration,
    /// abandoning queued work (contrast [`Server::drain`]). Returns
    /// each replica's final [`Metrics`] snapshot (sorted by replica
    /// index) so multi-replica serves can report the same stats as a
    /// single engine.
    pub fn shutdown(mut self) -> Vec<Metrics> {
        self.shutdown.store(true, Ordering::SeqCst);
        for w in &self.workers {
            let _ = w.send(WorkerMsg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        let mut out: Vec<(usize, Metrics)> = self.metrics_rx.try_iter().collect();
        out.sort_by_key(|(replica, _)| *replica);
        out.into_iter().map(|(_, m)| m).collect()
    }

    /// Kill the worker threads while keeping the front-end alive, to
    /// exercise the [`SubmitError::ServerStopped`] path.
    #[cfg(test)]
    fn abandon_workers(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for w in &self.workers {
            let _ = w.send(WorkerMsg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Increment `gauge` unless it is already at `limit`.
fn try_acquire(gauge: &AtomicUsize, limit: usize) -> bool {
    gauge
        .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| {
            (d < limit).then_some(d + 1)
        })
        .is_ok()
}

/// Give back an intake slot acquired by [`try_acquire`] (send failed —
/// the request never reached the engine).
fn release(gauge: &AtomicUsize) {
    let _ = gauge.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| d.checked_sub(1));
}

fn worker_loop(
    replica: usize,
    engine: &mut ServeEngine,
    rx: Receiver<WorkerMsg>,
    event_tx: Sender<(usize, ServerEvent)>,
    metrics_tx: Sender<(usize, Metrics)>,
    stop: Arc<AtomicBool>,
) {
    let mut draining = false;
    let mut events: Vec<ServerEvent> = Vec::new();
    'serve: loop {
        // drain intake without blocking while work is pending
        loop {
            match rx.try_recv() {
                Ok(WorkerMsg::Submit(req)) => engine.submit(req),
                Ok(WorkerMsg::Drain) => draining = true,
                Ok(WorkerMsg::Shutdown) => break 'serve,
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => break 'serve,
            }
        }
        if stop.load(Ordering::Relaxed) {
            break 'serve;
        }
        if engine.pending() == 0 {
            if draining {
                break 'serve; // drained dry: exit after in-flight work
            }
            // idle: block briefly for new work
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(WorkerMsg::Submit(req)) => engine.submit(req),
                Ok(WorkerMsg::Drain) => {
                    draining = true;
                    continue;
                }
                Ok(WorkerMsg::Shutdown) => break 'serve,
                Err(_) => continue,
            }
        }
        engine.step_events(&mut events);
        for ev in events.drain(..) {
            if event_tx.send((replica, ev)).is_err() {
                break 'serve;
            }
        }
    }
    // final snapshot for the drain/shutdown aggregate report
    let _ = metrics_tx.send((replica, engine.metrics.clone()));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::coordinator::request::{FinishReason, RequestStatus};
    use crate::model::{ModelConfig, Transformer};
    use crate::rng::Rng;

    fn mk_model(seed: u64) -> Transformer {
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = 32;
        cfg.max_seq = 32;
        let mut rng = Rng::new(seed);
        Transformer::random(cfg, &mut rng)
    }

    fn mk_engine(seed: u64) -> ServeEngine {
        ServeEngine::new(mk_model(seed), BatchPolicy::default())
    }

    fn params(n: usize) -> SamplingParams {
        SamplingParams::greedy(n).with_stop(None)
    }

    #[test]
    fn single_replica_end_to_end() {
        let mut server = Server::start(vec![mk_engine(1)], RoutePolicy::LeastLoaded);
        let id = server.submit(vec![1, 2, 3], params(4), 0).id();
        let out = server.wait_for(1, Duration::from_secs(10));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, id);
        assert_eq!(out[0].tokens.len(), 4);
        server.shutdown();
    }

    #[test]
    fn multi_replica_all_requests_served() {
        let engines = vec![mk_engine(1), mk_engine(1)];
        let mut server = Server::start(engines, RoutePolicy::LeastLoaded);
        let mut ids = Vec::new();
        for i in 0..8 {
            ids.push(server.submit(vec![1 + i % 5, 2], params(3), 0).id());
        }
        let out = server.wait_for(8, Duration::from_secs(20));
        assert_eq!(out.len(), 8);
        let mut got: Vec<u64> = out.iter().map(|r| r.id).collect();
        got.sort_unstable();
        ids.sort_unstable();
        assert_eq!(got, ids);
        server.shutdown();
    }

    #[test]
    fn threaded_replicas_match_sequential_replicas() {
        // replica workers with 2-lane kernel pools must serve the same
        // tokens as sequential replicas (determinism across --threads)
        let model = mk_model(5);
        let serve = |threads: usize| {
            let mut server = ServerBuilder::new()
                .replicas(2)
                .route(RoutePolicy::RoundRobin)
                .threads(threads)
                .start(model.clone());
            for i in 0..6u64 {
                let _ = server
                    .submit(vec![1 + (i % 5) as u32, 2, 3], params(4), 0)
                    .id();
            }
            let mut out = server.wait_for(6, Duration::from_secs(30));
            let metrics = server.shutdown();
            assert_eq!(metrics.len(), 2, "one final snapshot per replica");
            assert_eq!(metrics.iter().map(|m| m.completed).sum::<u64>(), 6);
            out.sort_by_key(|r| r.id);
            out
        };
        let seq = serve(1);
        let par = serve(2);
        assert_eq!(seq.len(), 6);
        assert_eq!(par.len(), 6);
        for (a, b) in par.iter().zip(&seq) {
            assert_eq!(a.tokens, b.tokens, "req {}", a.id);
        }
    }

    #[test]
    fn paged_prefix_replicas_match_legacy_layout() {
        // shared-prefix workload through the full server stack: paged
        // pages + prefix adoption must serve token-identical responses
        // to the legacy contiguous layout
        use crate::coordinator::kv_pool::PagedKvOpts;
        let model = mk_model(9);
        let serve = |kv: PagedKvOpts| {
            let mut server = ServerBuilder::new()
                .route(RoutePolicy::RoundRobin)
                .threads(1)
                .paged_kv(kv)
                .start(model.clone());
            let shared: Vec<u32> = (0..12).map(|j| 1 + (j % 7)).collect();
            for i in 0..6u64 {
                let mut prompt = shared.clone();
                prompt.push(10 + (i % 4) as u32); // distinct suffixes
                let _ = server.submit(prompt, params(4), 0).id();
            }
            let mut out = server.wait_for(6, Duration::from_secs(30));
            server.shutdown();
            out.sort_by_key(|r| r.id);
            out
        };
        let legacy = serve(PagedKvOpts {
            page_size: 32,
            prefix_cache: false,
            page_budget: None,
        });
        let paged = serve(PagedKvOpts {
            page_size: 4,
            prefix_cache: true,
            page_budget: None,
        });
        assert_eq!(legacy.len(), 6);
        for (a, b) in paged.iter().zip(&legacy) {
            assert_eq!(a.tokens, b.tokens, "req {}", a.id);
        }
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let server = Server::start(vec![mk_engine(2)], RoutePolicy::RoundRobin);
        server.shutdown(); // no hang
    }

    #[test]
    fn poll_nonblocking_when_empty() {
        let mut server = Server::start(vec![mk_engine(3)], RoutePolicy::RoundRobin);
        let t0 = std::time::Instant::now();
        let out = server.poll();
        assert!(out.is_empty());
        assert!(t0.elapsed() < Duration::from_millis(100));
        server.shutdown();
    }

    #[test]
    fn submit_after_worker_death_surfaces_server_stopped() {
        let mut server = Server::start(vec![mk_engine(4)], RoutePolicy::RoundRobin);
        server.abandon_workers();
        let out = server.submit(vec![1, 2], params(3), 0);
        assert_eq!(out.err(), Some(SubmitError::ServerStopped));
        assert_eq!(server.stats.server_stopped, 1);
        assert_eq!(server.stats.accepted, 0);
    }

    #[test]
    fn invalid_params_rejected_at_submit() {
        let mut server = Server::start(vec![mk_engine(6)], RoutePolicy::RoundRobin);
        let out = server.submit(vec![1], SamplingParams::greedy(0), 0);
        assert_eq!(out.err(), Some(SubmitError::ZeroBudget));
        let out = server.submit(vec![1], params(4).with_n(0), 0);
        assert_eq!(out.err(), Some(SubmitError::ZeroSamples));
        assert_eq!(server.stats.invalid_params, 2);
        assert_eq!(server.stats.submitted, 2);
        server.shutdown();
    }

    #[test]
    fn drain_completes_in_flight_work() {
        let mut server = ServerBuilder::new()
            .replicas(2)
            .route(RoutePolicy::RoundRobin)
            .threads(1)
            .start(mk_model(7));
        for i in 0..6u64 {
            let _ = server.submit(vec![1 + (i % 5) as u32, 2], params(3), 0).id();
        }
        // drain without waiting: every response must still arrive
        let report = server.drain();
        let responses = report.responses();
        assert_eq!(responses.len(), 6, "drain finishes queued + running work");
        assert!(responses.iter().all(|r| r.finish == FinishReason::Length));
        assert_eq!(report.metrics.len(), 2);
        let agg = Metrics::aggregate(&report.metrics);
        assert_eq!(agg.requests_finished, 6);
        assert_eq!(agg.submitted, 6);
    }

    #[test]
    fn queue_full_rejects_then_recovers() {
        let mut server = ServerBuilder::new()
            .threads(1)
            .intake_limit(2)
            .start(mk_model(8));
        let mut accepted = 0usize;
        let mut rejected = 0usize;
        for i in 0..6u64 {
            match server.submit(vec![1 + (i % 5) as u32, 2], params(4), 0) {
                SubmitOutcome::Accepted(_) => accepted += 1,
                SubmitOutcome::Rejected(SubmitError::QueueFull { .. }) => rejected += 1,
                SubmitOutcome::Rejected(e) => panic!("unexpected rejection: {e}"),
            }
        }
        assert!(accepted >= 2, "the intake window admits up to its limit");
        assert!(rejected >= 1, "submitting 6 at once must overflow a window of 2");
        let out = server.wait_for(accepted, Duration::from_secs(30));
        assert_eq!(out.len(), accepted, "accepted requests all complete");
        // the window freed up: a new submit is accepted again
        let retry = server.submit(vec![3, 4], params(2), 0);
        assert!(retry.is_accepted(), "intake recovers after completions");
        let out = server.wait_for(1, Duration::from_secs(10));
        assert_eq!(out.len(), 1);
        let stats = server.stats.clone();
        let report = server.drain();
        assert_eq!(stats.submitted, 7);
        assert_eq!(stats.queue_full, rejected as u64);
        let agg = Metrics::aggregate(&report.metrics);
        // request-granular identity over the whole run
        assert_eq!(
            agg.requests_finished + stats.queue_full,
            stats.submitted,
            "completed + rejected == submitted"
        );
    }

    #[test]
    fn cancel_via_handle_roundtrip() {
        // a single-slot batcher keeps the target queued behind a
        // blocker, so the cancel deterministically lands before the
        // target can run to completion
        let mut server = ServerBuilder::new()
            .threads(1)
            .batch(BatchPolicy::default().with_max_running(1))
            .start(mk_model(10));
        let blocker = server.submit(vec![9, 8], params(20), 0).id();
        let handle = server
            .submit(vec![1, 2, 3], params(20), 0)
            .handle()
            .expect("accepted");
        handle.cancel();
        let out = server.wait_for(2, Duration::from_secs(20));
        assert_eq!(out.len(), 2);
        for r in &out {
            if r.id == blocker {
                assert_eq!(r.finish, FinishReason::Length, "blocker unaffected");
            } else {
                assert_eq!(r.id, handle.id());
                assert_eq!(r.finish, FinishReason::Cancelled);
            }
        }
        assert_eq!(handle.try_status(), RequestStatus::Finished);
        let metrics = server.shutdown();
        assert_eq!(metrics.iter().map(|m| m.cancelled).sum::<u64>(), 1);
    }

    #[test]
    fn streamed_tokens_match_final_response() {
        let mut server = ServerBuilder::new().threads(1).start(mk_model(12));
        let id = server.submit(vec![1, 2, 3], params(5), 0).id();
        let mut stream = Vec::new();
        let mut finished = None;
        let t0 = std::time::Instant::now();
        while finished.is_none() && t0.elapsed() < Duration::from_secs(20) {
            match server.next_event(Duration::from_millis(10)) {
                Some(ServerEvent::Token { id: eid, token, index, .. }) => {
                    assert_eq!(eid, id);
                    assert_eq!(index, stream.len(), "indexes contiguous from 0");
                    stream.push(token);
                }
                Some(ServerEvent::Done(r)) => finished = Some(r),
                None => {}
            }
        }
        let resp = finished.expect("request finished");
        assert_eq!(stream, resp.tokens, "stream == final tokens");
        server.shutdown();
    }
}

//! Threaded serve front-end: admission-controlled intake → router →
//! supervised per-replica worker threads → event channel.
//!
//! tokio is unavailable offline (DESIGN.md §2), so concurrency is
//! std::thread + mpsc: one worker thread per engine replica runs the
//! continuous-batching loop and forwards every [`ServerEvent`] it
//! emits; the handle submits requests and consumes the event stream
//! without blocking workers.
//!
//! The API surface (DESIGN.md §Serve-Frontend, §Fault-Tolerance):
//!
//! * [`ServerBuilder`] — the one constructor; [`Server::start`]
//!   survives as a shim.
//! * [`Server::submit`] → [`SubmitOutcome`]: `Accepted(RequestHandle)`
//!   or a typed rejection (queue full / invalid params / restarting /
//!   stopped) — admission is a bounded per-replica intake window, so
//!   callers see backpressure instead of unbounded channel growth.
//! * [`Server::next_event`] / [`Server::poll_events`] — the streaming
//!   consumption path; [`Server::poll`] / [`Server::wait_for`] remain
//!   as adapters that keep only the `Done` responses.
//! * [`Server::drain`] — stop intake, finish in-flight work, return
//!   every leftover event + final metrics; [`Server::shutdown`] stays
//!   abortive (workers exit at the next step boundary).
//!
//! **Supervision** (the fault-tolerance layer): each worker wraps its
//! engine step in `catch_unwind`, so a panic — an engine bug or an
//! injected [`FaultPlan`] entry — poisons only that replica. The dying
//! worker forwards everything it completed, snapshots its metrics, and
//! emits [`ServerEvent::ReplicaDown`] as its last word; the handle then
//! respawns the replica cold from the [`ModelSource`] and requeues the
//! victim's in-flight requests to healthy replicas under a bounded
//! [`RetryPolicy`]. Replayed requests re-prefill prompt + prior output
//! and continue with the same per-position RNG keying the engine uses
//! for preemption recompute, so a replayed stream is token-for-token
//! identical to a fault-free run — duplicate events from the overlap
//! are suppressed by per-sample token watermarks here in the handle.

use super::engine::ServeEngine;
use super::faults::{FaultInjector, FaultPlan};
use super::metrics::{Metrics, ServerStats};
use super::request::{
    FinishReason, Request, RequestCtl, RequestHandle, RequestId, Response, SamplingParams,
    ServerEvent, SubmitError,
};
use super::router::{RoutePolicy, Router};
use super::supervisor::{respawn_model, ModelSource, RetryPolicy};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

enum WorkerMsg {
    Submit(Request),
    /// Stop intake, keep stepping until the engine is empty, then exit.
    Drain,
    /// Exit at the next loop iteration, abandoning queued work.
    Shutdown,
}

/// Default per-replica intake window: effectively "no backpressure"
/// for test workloads, while still bounding a runaway producer.
pub const DEFAULT_INTAKE_LIMIT: usize = 1024;

/// Accept/reject verdict from [`Server::submit`].
#[must_use]
#[derive(Debug)]
pub enum SubmitOutcome {
    Accepted(RequestHandle),
    Rejected(SubmitError),
}

impl SubmitOutcome {
    pub fn is_accepted(&self) -> bool {
        matches!(self, SubmitOutcome::Accepted(_))
    }

    /// The accepted handle, or `None` on rejection.
    pub fn handle(self) -> Option<RequestHandle> {
        match self {
            SubmitOutcome::Accepted(h) => Some(h),
            SubmitOutcome::Rejected(_) => None,
        }
    }

    /// The rejection reason, if any.
    pub fn err(&self) -> Option<SubmitError> {
        match self {
            SubmitOutcome::Accepted(_) => None,
            SubmitOutcome::Rejected(e) => Some(*e),
        }
    }

    /// The accepted request id, or the typed rejection. Prefer this
    /// over [`SubmitOutcome::id`]: with supervision, admission can fail
    /// transiently ([`SubmitError::ReplicaRestarting`]) even on servers
    /// that "cannot" reject, so call sites should see the error.
    pub fn try_id(&self) -> Result<RequestId, SubmitError> {
        match self {
            SubmitOutcome::Accepted(h) => Ok(h.id()),
            SubmitOutcome::Rejected(e) => Err(*e),
        }
    }

    /// The accepted request id; panics on a rejection.
    #[deprecated(
        since = "0.1.0",
        note = "panics on rejection; use try_id() and handle the SubmitError"
    )]
    pub fn id(&self) -> RequestId {
        match self {
            SubmitOutcome::Accepted(h) => h.id(),
            SubmitOutcome::Rejected(e) => panic!("submit rejected: {e}"),
        }
    }
}

/// Everything a graceful [`Server::drain`] hands back: the events that
/// had not been consumed yet (in per-replica emission order) and each
/// replica's final [`Metrics`] snapshot, sorted by replica index. A
/// replica that died and respawned contributes one folded snapshot:
/// counters summed across its generations, page/queue gauges from the
/// last generation (the only one whose pages still exist).
#[derive(Debug)]
pub struct DrainReport {
    pub events: Vec<ServerEvent>,
    pub metrics: Vec<Metrics>,
    /// Final admission/supervision counters. Prefer this over a
    /// pre-drain `server.stats.clone()`: replica deaths, requeues, and
    /// `ReplicaLost` retirements can all happen *during* the drain.
    pub stats: ServerStats,
}

impl DrainReport {
    /// Just the terminal responses among the leftover events.
    pub fn responses(&self) -> Vec<Response> {
        self.events
            .iter()
            .filter_map(|ev| match ev {
                ServerEvent::Done(r) => Some(r.clone()),
                ServerEvent::Token { .. } | ServerEvent::ReplicaDown { .. } => None,
            })
            .collect()
    }
}

/// Engine construction parameters, kept so the supervisor can rebuild
/// a dead replica's engine exactly as the builder first made it.
#[derive(Clone, Debug)]
struct EngineCfg {
    batch: super::batcher::BatchPolicy,
    threads: usize,
    kv: super::kv_pool::PagedKvOpts,
    spec: Option<super::speculator::SpecDecodeOpts>,
}

/// Builder for a running multi-replica [`Server`] — replaces the old
/// `start` / `start_replicas` / `start_replicas_with` constructor trio.
#[derive(Clone, Debug)]
pub struct ServerBuilder {
    replicas: usize,
    route: RoutePolicy,
    batch: super::batcher::BatchPolicy,
    threads: usize,
    kv: super::kv_pool::PagedKvOpts,
    spec: Option<super::speculator::SpecDecodeOpts>,
    intake_limit: usize,
    default_deadline: Option<Duration>,
    retry: RetryPolicy,
    faults: Option<Arc<FaultPlan>>,
    checkpoint: Option<String>,
}

impl Default for ServerBuilder {
    fn default() -> Self {
        ServerBuilder {
            replicas: 1,
            route: RoutePolicy::LeastLoaded,
            batch: super::batcher::BatchPolicy::default(),
            threads: crate::threads::default_threads(),
            kv: super::kv_pool::PagedKvOpts::default(),
            spec: None,
            intake_limit: DEFAULT_INTAKE_LIMIT,
            default_deadline: None,
            retry: RetryPolicy::default(),
            faults: None,
            checkpoint: None,
        }
    }
}

impl ServerBuilder {
    pub fn new() -> ServerBuilder {
        ServerBuilder::default()
    }

    /// Engine replicas (≥ 1), each on its own worker thread.
    pub fn replicas(mut self, n: usize) -> ServerBuilder {
        self.replicas = n.max(1);
        self
    }

    pub fn route(mut self, policy: RoutePolicy) -> ServerBuilder {
        self.route = policy;
        self
    }

    pub fn batch(mut self, policy: super::batcher::BatchPolicy) -> ServerBuilder {
        self.batch = policy;
        self
    }

    /// Kernel-pool lanes **per replica** (so replicas never contend on
    /// a shared pool's dispatch lock); `1` forces the exact sequential
    /// kernel path — the debugging escape hatch `--threads 1` plumbs
    /// through here.
    pub fn threads(mut self, threads: usize) -> ServerBuilder {
        self.threads = threads;
        self
    }

    /// Paged-KV options (`--page-size` / `--prefix-cache` /
    /// `--kv-pages`). Each replica gets its own page store and radix
    /// prefix tree — prefix reuse is per-replica, which is why
    /// session-affinity routing pairs well with the cache.
    pub fn paged_kv(mut self, kv: super::kv_pool::PagedKvOpts) -> ServerBuilder {
        self.kv = kv;
        self
    }

    /// Speculative decoding (`--spec-decode` / `--spec-k`): every
    /// replica drafts with the same prompt-lookup speculator. `None`
    /// (the default) is plain one-token-per-step decode. Purely a
    /// scheduling optimization — output is token-for-token identical
    /// either way (see `coordinator::speculator`).
    pub fn spec_decode(mut self, spec: Option<super::speculator::SpecDecodeOpts>) -> ServerBuilder {
        self.spec = spec;
        self
    }

    /// Bound on accepted-but-unfinished requests per replica; beyond
    /// it [`Server::submit`] rejects with [`SubmitError::QueueFull`].
    pub fn intake_limit(mut self, n: usize) -> ServerBuilder {
        self.intake_limit = n.max(1);
        self
    }

    /// Deadline applied to every request submitted without its own
    /// (`--deadline-ms`).
    pub fn default_deadline(mut self, deadline: Duration) -> ServerBuilder {
        self.default_deadline = Some(deadline);
        self
    }

    /// Bounded retry-with-backoff for requests orphaned by a replica
    /// death (`--retry-max` / `--retry-base-ms` / `--retry-cap-ms`).
    pub fn retry(mut self, policy: RetryPolicy) -> ServerBuilder {
        self.retry = policy;
        self
    }

    /// Deterministic fault-injection schedule (`--fault-plan FILE` /
    /// `PTQTP_FAULT_SEED`). Always compiled in; a server built without
    /// one runs a single inert `Option` check per engine step.
    pub fn fault_plan(mut self, plan: FaultPlan) -> ServerBuilder {
        self.faults = Some(Arc::new(plan));
        self
    }

    /// Packed PTW2 checkpoint path for cold respawns: a supervisor
    /// restart reloads weights from this file instead of cloning the
    /// in-memory model (quantize-once / serve-many — the restart never
    /// re-runs the quantization pass).
    pub fn checkpoint(mut self, path: impl Into<String>) -> ServerBuilder {
        self.checkpoint = Some(path.into());
        self
    }

    /// Spawn `replicas` engines cloned from one model and start a
    /// worker thread per replica. The model (or the checkpoint path,
    /// if [`ServerBuilder::checkpoint`] was set) is retained as the
    /// [`ModelSource`] for supervisor respawns.
    pub fn start(self, model: crate::model::Transformer) -> Server {
        let engines = (0..self.replicas)
            .map(|_| {
                let mut e =
                    ServeEngine::with_opts(model.clone(), self.batch, self.threads, self.kv);
                e.set_spec_decode(self.spec);
                e
            })
            .collect();
        let source = match self.checkpoint.clone() {
            Some(path) => ModelSource::Checkpoint(path),
            None => ModelSource::Memory(Arc::new(model)),
        };
        let mut server = self.start_engines(engines);
        server.source = source;
        server
    }

    /// Start over caller-built engines (heterogeneous replicas, tests).
    /// `replicas`/`batch`/`threads`/`paged_kv`/`spec_decode` settings
    /// are ignored — the engines carry their own. There is no model to
    /// respawn from ([`ModelSource::Unavailable`]), so a replica that
    /// dies on this path stays dead and its pinned requests retire with
    /// [`FinishReason::ReplicaLost`] once the retry budget is spent.
    pub fn start_engines(self, mut engines: Vec<ServeEngine>) -> Server {
        assert!(!engines.is_empty(), "need at least one engine replica");
        let n = engines.len();
        if let Some(plan) = &self.faults {
            for (replica, engine) in engines.iter_mut().enumerate() {
                engine.set_fault_injector(Some(FaultInjector::new(plan.clone(), replica)));
            }
        }
        let (event_tx, event_rx) = channel::<(usize, ServerEvent)>();
        let (metrics_tx, metrics_rx) = channel::<(usize, Metrics)>();
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut slots = Vec::with_capacity(n);
        let mut intake = Vec::with_capacity(n);
        for (replica, mut engine) in engines.into_iter().enumerate() {
            let (tx, rx) = channel::<WorkerMsg>();
            let event_tx = event_tx.clone();
            let metrics_tx = metrics_tx.clone();
            let stop = shutdown.clone();
            let gauge = Arc::new(AtomicUsize::new(0));
            intake.push(gauge.clone());
            engine.set_intake_depth(gauge);
            let handle = std::thread::spawn(move || {
                worker_loop(replica, &mut engine, rx, event_tx, metrics_tx, stop);
            });
            slots.push(WorkerSlot {
                tx: Some(tx),
                handle: Some(handle),
                dead: None,
            });
        }
        Server {
            router: Router::new(n, self.route),
            slots,
            events: event_rx,
            event_tx,
            metrics_rx,
            metrics_tx,
            next_id: AtomicU64::new(1),
            shutdown,
            intake,
            intake_limit: self.intake_limit,
            default_deadline: self.default_deadline,
            source: ModelSource::Unavailable,
            cfg: EngineCfg {
                batch: self.batch,
                threads: self.threads,
                kv: self.kv,
                spec: self.spec,
            },
            retry: self.retry,
            faults: self.faults,
            tracked: HashMap::new(),
            retry_q: Vec::new(),
            buffered: VecDeque::new(),
            draining: false,
            stats: ServerStats::default(),
        }
    }
}

/// One replica's worker-thread attachment. `tx`/`handle` are taken as
/// the worker dies (or is reaped); `dead` marks a replica whose respawn
/// failed — it never comes back.
struct WorkerSlot {
    tx: Option<Sender<WorkerMsg>>,
    handle: Option<JoinHandle<()>>,
    dead: Option<String>,
}

impl WorkerSlot {
    fn live(&self) -> bool {
        self.tx.is_some() && self.dead.is_none()
    }
}

/// Everything the supervisor needs to replay a request after its
/// replica dies: the original submission (verbatim — same id, prompt,
/// params, deadline clock) plus per-sample dedupe watermarks for the
/// event overlap between the dead run and its replay.
struct Tracked {
    prompt: Vec<u32>,
    params: SamplingParams,
    session: u64,
    deadline: Option<Duration>,
    submitted_at: Instant,
    ctl: Arc<RequestCtl>,
    /// Replica currently (or last) responsible for the request.
    replica: usize,
    /// Replays attempted so far (0 = original submission only).
    attempts: u32,
    /// In `retry_q`, waiting out its backoff.
    queued_retry: bool,
    /// Per-sample count of `Token` events already surfaced: a replayed
    /// sequence re-emits from index 0, and everything below the
    /// watermark is suppressed so consumers see each index once.
    emitted: Vec<usize>,
    /// Per-sample terminal flags: duplicate `Done`s from a replay that
    /// overlapped a completed sample are suppressed too.
    done: Vec<bool>,
}

struct RetryItem {
    id: RequestId,
    not_before: Instant,
}

/// A running multi-replica server with replica supervision.
pub struct Server {
    router: Router,
    slots: Vec<WorkerSlot>,
    events: Receiver<(usize, ServerEvent)>,
    /// Prototype sender cloned into respawned workers. Keeping it here
    /// does not mask server teardown: a worker's send fails as soon as
    /// the receiver drops with the `Server`.
    event_tx: Sender<(usize, ServerEvent)>,
    /// Final per-replica metrics snapshots, sent as workers exit.
    metrics_rx: Receiver<(usize, Metrics)>,
    metrics_tx: Sender<(usize, Metrics)>,
    next_id: AtomicU64,
    shutdown: Arc<AtomicBool>,
    /// Per-replica accepted-but-unfinished gauges, decremented by the
    /// engines as requests retire (see `ServeEngine::set_intake_depth`).
    /// A respawn installs a fresh gauge — the dead engine's count died
    /// with it, and requeued victims are re-admitted outside the limit
    /// (dropping a retry at admission would break the replay guarantee
    /// for work the server already accepted).
    intake: Vec<Arc<AtomicUsize>>,
    intake_limit: usize,
    default_deadline: Option<Duration>,
    /// Where respawned replicas get their weights.
    source: ModelSource,
    cfg: EngineCfg,
    retry: RetryPolicy,
    faults: Option<Arc<FaultPlan>>,
    /// In-flight requests by id — the supervisor's replay ledger.
    tracked: HashMap<RequestId, Tracked>,
    /// Requests waiting out a retry backoff.
    retry_q: Vec<RetryItem>,
    /// Events already pulled off the channel by the supervision pump
    /// but not yet handed to the consumer.
    buffered: VecDeque<ServerEvent>,
    draining: bool,
    /// Admission counters for the serve-metrics artifact.
    pub stats: ServerStats,
}

impl Server {
    /// Pre-builder shim, kept so old call sites read unchanged.
    /// **Deprecated in favour of [`ServerBuilder`]**:
    /// `ServerBuilder::new().route(policy).start_engines(engines)`.
    pub fn start(engines: Vec<ServeEngine>, policy: RoutePolicy) -> Server {
        ServerBuilder::new().route(policy).start_engines(engines)
    }

    /// Submit a prompt under the server's default deadline (if any).
    pub fn submit(
        &mut self,
        prompt: Vec<u32>,
        params: SamplingParams,
        session: u64,
    ) -> SubmitOutcome {
        self.submit_with_deadline(prompt, params, session, self.default_deadline)
    }

    /// Submit with an explicit per-request deadline (`None` =
    /// unbounded, overriding the server default).
    ///
    /// Admission: parameters are validated first; then the routed
    /// replica must be healthy and have intake room. Sessionless
    /// requests may spill to any live replica with room before
    /// rejecting; session-pinned requests never spill (their KV/prefix
    /// locality is the point of the pin) — a pinned request whose
    /// replica is down rejects with [`SubmitError::ReplicaRestarting`]
    /// so the caller can distinguish "back off and retry" from a dead
    /// server ([`SubmitError::ServerStopped`]).
    pub fn submit_with_deadline(
        &mut self,
        prompt: Vec<u32>,
        params: SamplingParams,
        session: u64,
        deadline: Option<Duration>,
    ) -> SubmitOutcome {
        // Process any queued death notices first so routing sees the
        // current replica health, not last poll's.
        self.pump();
        self.stats.submitted += 1;
        if let Err(e) = params.validate() {
            self.stats.invalid_params += 1;
            return SubmitOutcome::Rejected(e);
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut req = Request::new(id, prompt, params);
        req.session = session;
        req.deadline = deadline;
        let primary = self.router.route(&req);
        let n = self.slots.len();
        let mut replica = None;
        let mut saw_live = false;
        for k in 0..n {
            let candidate = (primary + k) % n;
            if k > 0 && session != 0 {
                break; // pinned sessions don't spill
            }
            if !self.slots[candidate].live() {
                continue;
            }
            saw_live = true;
            if try_acquire(&self.intake[candidate], self.intake_limit) {
                replica = Some(candidate);
                break;
            }
        }
        let Some(replica) = replica else {
            self.router.unroute(primary);
            if session != 0 && !self.slots[primary].live() {
                self.stats.replica_restarting += 1;
                let e = SubmitError::ReplicaRestarting { replica: primary };
                return SubmitOutcome::Rejected(e);
            }
            if !saw_live {
                self.stats.server_stopped += 1;
                return SubmitOutcome::Rejected(SubmitError::ServerStopped);
            }
            self.stats.queue_full += 1;
            return SubmitOutcome::Rejected(SubmitError::QueueFull { replica: primary });
        };
        if replica != primary {
            self.router.unroute(primary);
            self.router.assign(replica);
        }
        let handle = req.handle(replica);
        let tracked = Tracked {
            prompt: req.prompt.clone(),
            params: req.params,
            session,
            deadline,
            submitted_at: req.submitted_at,
            ctl: req.ctl.clone(),
            replica,
            attempts: 0,
            queued_retry: false,
            emitted: vec![0; req.params.n],
            done: vec![false; req.params.n],
        };
        let tx = self.slots[replica].tx.as_ref().expect("live slot has tx");
        if tx.send(WorkerMsg::Submit(req)).is_err() {
            release(&self.intake[replica]);
            self.router.unroute(replica);
            self.stats.server_stopped += 1;
            return SubmitOutcome::Rejected(SubmitError::ServerStopped);
        }
        self.tracked.insert(id, tracked);
        self.stats.accepted += 1;
        SubmitOutcome::Accepted(handle)
    }

    /// Non-blocking: next queued event, if any.
    pub fn try_next_event(&mut self) -> Option<ServerEvent> {
        self.pump();
        self.buffered.pop_front()
    }

    /// Block up to `timeout` for the next event. Wakes periodically to
    /// flush due retry backoffs even when the channel is quiet.
    pub fn next_event(&mut self, timeout: Duration) -> Option<ServerEvent> {
        let deadline = Instant::now() + timeout;
        loop {
            self.pump();
            if let Some(ev) = self.buffered.pop_front() {
                return Some(ev);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let wait = (deadline - now).min(Duration::from_millis(5));
            if let Ok((replica, ev)) = self.events.recv_timeout(wait) {
                if let Some(ev) = self.handle_event(replica, ev) {
                    return Some(ev);
                }
            }
        }
    }

    /// Non-blocking: drain every event currently queued.
    pub fn poll_events(&mut self) -> Vec<ServerEvent> {
        let mut out = Vec::new();
        while let Some(ev) = self.try_next_event() {
            out.push(ev);
        }
        out
    }

    /// Pull everything off the wire through the supervision layer:
    /// surviving events land in `buffered`, death notices respawn and
    /// requeue, and due retries replay.
    fn pump(&mut self) {
        while let Ok((replica, ev)) = self.events.try_recv() {
            if let Some(ev) = self.handle_event(replica, ev) {
                self.buffered.push_back(ev);
            }
        }
        self.flush_retries();
    }

    /// Supervision filter for one wire event. Returns the event to
    /// surface to the consumer, or `None` when it is a duplicate from
    /// a replay overlap.
    fn handle_event(&mut self, replica: usize, ev: ServerEvent) -> Option<ServerEvent> {
        match ev {
            ServerEvent::Token {
                id,
                sample,
                token,
                index,
            } => {
                if let Some(t) = self.tracked.get_mut(&id) {
                    if sample < t.emitted.len() {
                        if index < t.emitted[sample] {
                            return None; // replay re-emitted below the watermark
                        }
                        t.emitted[sample] = index + 1;
                    }
                }
                Some(ServerEvent::Token {
                    id,
                    sample,
                    token,
                    index,
                })
            }
            ServerEvent::Done(r) => {
                if let Some(t) = self.tracked.get_mut(&r.id) {
                    if r.sample < t.done.len() {
                        if t.done[r.sample] {
                            return None; // sample already finished pre-death
                        }
                        t.done[r.sample] = true;
                    }
                    if t.done.iter().all(|&d| d) {
                        self.tracked.remove(&r.id);
                    }
                }
                self.router.complete(replica);
                Some(ServerEvent::Done(r))
            }
            ServerEvent::ReplicaDown { replica: r, cause } => {
                self.handle_replica_down(r, &cause);
                Some(ServerEvent::ReplicaDown { replica: r, cause })
            }
        }
    }

    /// A replica's death notice: reap the thread, respawn it from the
    /// model source, and put every request it was carrying on the
    /// retry queue. Runs *after* all the victim's pre-death events
    /// (mpsc preserves per-sender order), so requests it completed are
    /// already out of `tracked` and are not replayed.
    fn handle_replica_down(&mut self, replica: usize, _cause: &str) {
        if let Some(h) = self.slots[replica].handle.take() {
            let _ = h.join();
        }
        self.slots[replica].tx = None;
        self.router.reset(replica);
        if self.respawn(replica) {
            self.stats.replica_restarts += 1;
        }
        let victims: Vec<RequestId> = self
            .tracked
            .iter()
            .filter(|(_, t)| t.replica == replica && !t.queued_retry)
            .map(|(&id, _)| id)
            .collect();
        for id in victims {
            self.schedule_retry(id, true);
        }
    }

    /// Build a cold engine for `replica` from the model source and
    /// spawn its worker. On failure the slot is marked permanently
    /// dead (typed, never a panic — see `supervisor::respawn_model`).
    fn respawn(&mut self, replica: usize) -> bool {
        let src = &self.source;
        let model = match respawn_model(src, replica, self.faults.as_deref(), &self.retry) {
            Ok(m) => m,
            Err(e) => {
                if self.slots[replica].dead.is_none() {
                    self.slots[replica].dead = Some(e.to_string());
                }
                return false;
            }
        };
        let mut engine =
            ServeEngine::with_opts(model, self.cfg.batch, self.cfg.threads, self.cfg.kv);
        engine.set_spec_decode(self.cfg.spec);
        if let Some(plan) = &self.faults {
            // one-shot latches in the plan mean the fresh generation
            // does not re-fire the fault that killed its predecessor
            engine.set_fault_injector(Some(FaultInjector::new(plan.clone(), replica)));
        }
        let gauge = Arc::new(AtomicUsize::new(0));
        self.intake[replica] = gauge.clone();
        engine.set_intake_depth(gauge);
        let (tx, rx) = channel::<WorkerMsg>();
        let event_tx = self.event_tx.clone();
        let metrics_tx = self.metrics_tx.clone();
        let stop = self.shutdown.clone();
        let handle = std::thread::spawn(move || {
            worker_loop(replica, &mut engine, rx, event_tx, metrics_tx, stop);
        });
        if self.draining {
            let _ = tx.send(WorkerMsg::Drain);
        }
        self.slots[replica] = WorkerSlot {
            tx: Some(tx),
            handle: Some(handle),
            dead: None,
        };
        true
    }

    /// Put a tracked request on the retry queue with its next backoff,
    /// or retire it with [`FinishReason::ReplicaLost`] once the budget
    /// is spent. `newly_orphaned` distinguishes a fresh replica-death
    /// victim (counted in `stats.requeued`) from a retry of a retry.
    fn schedule_retry(&mut self, id: RequestId, newly_orphaned: bool) {
        let attempts = {
            let Some(t) = self.tracked.get_mut(&id) else {
                return;
            };
            if t.queued_retry {
                return;
            }
            t.attempts += 1;
            t.attempts
        };
        if attempts > self.retry.max_attempts {
            self.fail_replica_lost(id);
            return;
        }
        if newly_orphaned {
            self.stats.requeued += 1;
        }
        let delay = self.retry.delay(id, attempts);
        if let Some(t) = self.tracked.get_mut(&id) {
            t.queued_retry = true;
        }
        self.retry_q.push(RetryItem {
            id,
            not_before: Instant::now() + delay,
        });
    }

    /// Retire a request the supervisor could not save: synthetic
    /// terminal `Done` per unfinished sample, typed `ReplicaLost`, no
    /// tokens. Counted request-granularly in `stats.replica_lost` so
    /// the accounting identity stays exact.
    fn fail_replica_lost(&mut self, id: RequestId) {
        let Some(t) = self.tracked.remove(&id) else {
            return;
        };
        t.ctl.mark_finished();
        self.stats.replica_lost += 1;
        for (sample, done) in t.done.iter().enumerate() {
            if !done {
                self.buffered.push_back(ServerEvent::Done(Response {
                    id,
                    sample,
                    tokens: Vec::new(),
                    finish: FinishReason::ReplicaLost,
                    ttft: Duration::default(),
                    total: t.submitted_at.elapsed(),
                    prompt_len: t.prompt.len(),
                }));
            }
        }
    }

    /// Replay every retry whose backoff has elapsed.
    fn flush_retries(&mut self) {
        let now = Instant::now();
        let mut i = 0;
        while i < self.retry_q.len() {
            if self.retry_q[i].not_before <= now {
                let item = self.retry_q.swap_remove(i);
                self.try_replay(item.id);
            } else {
                i += 1;
            }
        }
    }

    /// Resubmit a request whose backoff expired. Pinned sessions only
    /// ever go back to their own replica (waiting for it to restart);
    /// sessionless requests go to the least-loaded live replica. When
    /// nothing is live — a death during drain, or every replica down
    /// at once — the supervisor respawns the natural target on demand;
    /// if that also fails the request re-enters the backoff queue
    /// until its budget is spent.
    fn try_replay(&mut self, id: RequestId) {
        let (session, prompt, params, deadline, submitted_at, ctl) = {
            let Some(t) = self.tracked.get_mut(&id) else {
                return;
            };
            t.queued_retry = false;
            (
                t.session,
                t.prompt.clone(),
                t.params,
                t.deadline,
                t.submitted_at,
                t.ctl.clone(),
            )
        };
        let pinned = session != 0;
        let n = self.slots.len();
        let mut target = if pinned {
            let pin = self.router.session_replica(session);
            self.slots[pin].live().then_some(pin)
        } else {
            (0..n)
                .filter(|&r| self.slots[r].live())
                .min_by_key(|&r| self.router.load(r))
        };
        if target.is_none() {
            let fallback = if pinned {
                self.router.session_replica(session)
            } else {
                self.tracked.get(&id).map(|t| t.replica).unwrap_or(0)
            };
            if self.slots[fallback].dead.is_none()
                && self.slots[fallback].tx.is_none()
                && self.respawn(fallback)
            {
                self.stats.replica_restarts += 1;
                self.router.reset(fallback);
            }
            if self.slots[fallback].live() {
                target = Some(fallback);
            }
        }
        let Some(target) = target else {
            self.schedule_retry(id, false);
            return;
        };
        // Verbatim resubmission: same id, prompt, params (seed!), and
        // submitted_at — the deadline clock keeps running across the
        // death, and the engine's replay path (prefill prompt + prior
        // output, RNG keyed by generated.len()) makes the new stream
        // token-identical to the fault-free one.
        let req = Request {
            id,
            prompt,
            params,
            session,
            sample: 0,
            submitted_at,
            deadline,
            ctl,
        };
        let tx = self.slots[target].tx.as_ref().expect("live slot has tx");
        if tx.send(WorkerMsg::Submit(req)).is_ok() {
            if let Some(t) = self.tracked.get_mut(&id) {
                t.replica = target;
            }
            // re-admitted outside the intake limit: the server already
            // accepted this work once, so admission cannot drop it now
            self.intake[target].fetch_add(1, Ordering::Relaxed);
            self.router.assign(target);
            self.stats.retries += 1;
        } else {
            self.schedule_retry(id, false);
        }
    }

    /// Non-blocking poll for finished responses — the pre-streaming
    /// API, now an adapter that keeps only `Done` events. Token and
    /// replica-death events drained here are dropped; streaming
    /// consumers use [`Server::poll_events`] / [`Server::next_event`].
    pub fn poll(&mut self) -> Vec<Response> {
        self.poll_events()
            .into_iter()
            .filter_map(|ev| match ev {
                ServerEvent::Done(r) => Some(r),
                ServerEvent::Token { .. } | ServerEvent::ReplicaDown { .. } => None,
            })
            .collect()
    }

    /// Block until `n` responses arrive or `timeout` elapses (adapter
    /// over the event stream, like [`Server::poll`]).
    pub fn wait_for(&mut self, n: usize, timeout: Duration) -> Vec<Response> {
        let deadline = Instant::now() + timeout;
        let mut out = Vec::new();
        while out.len() < n && Instant::now() < deadline {
            if let Some(ServerEvent::Done(r)) = self.next_event(Duration::from_millis(10)) {
                out.push(r);
            }
        }
        out
    }

    /// Graceful drain: stop intake, let every replica finish its
    /// in-flight and queued work — *including* requests that have to
    /// be replayed because a replica dies mid-drain — then hand back
    /// all unconsumed events plus final per-replica metrics. The event
    /// channel is unbounded, so joining the workers before collecting
    /// cannot deadlock — everything they emitted is still buffered.
    pub fn drain(mut self) -> DrainReport {
        self.draining = true;
        for s in &self.slots {
            if let Some(tx) = &s.tx {
                let _ = tx.send(WorkerMsg::Drain);
            }
        }
        let hard_deadline = Instant::now() + Duration::from_secs(300);
        loop {
            self.pump();
            self.reap_exited();
            let workers_done = self.slots.iter().all(|s| s.handle.is_none());
            if workers_done && self.retry_q.is_empty() {
                if self.tracked.is_empty() {
                    break;
                }
                // every worker is gone and nothing is waiting on a
                // backoff, yet requests remain: no one can serve them
                let ids: Vec<RequestId> = self.tracked.keys().copied().collect();
                for id in ids {
                    self.fail_replica_lost(id);
                }
                continue;
            }
            if Instant::now() >= hard_deadline {
                self.shutdown.store(true, Ordering::SeqCst);
                for s in &self.slots {
                    if let Some(tx) = &s.tx {
                        let _ = tx.send(WorkerMsg::Shutdown);
                    }
                }
                for s in &mut self.slots {
                    if let Some(h) = s.handle.take() {
                        let _ = h.join();
                    }
                }
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        self.pump();
        let mut events: Vec<ServerEvent> = self.buffered.drain(..).collect();
        while let Ok((replica, ev)) = self.events.try_recv() {
            if let Some(ev) = self.handle_event(replica, ev) {
                events.push(ev);
            }
        }
        events.extend(self.buffered.drain(..));
        let metrics = fold_metrics(self.slots.len(), &self.metrics_rx);
        DrainReport {
            events,
            metrics,
            stats: self.stats.clone(),
        }
    }

    /// Sweep worker threads that exited on their own during a drain.
    /// A panic exit's `ReplicaDown` is processed here (respawn +
    /// requeue); a clean exit can still strand a `Submit` that raced
    /// its final intake sweep, so any request still tracked against
    /// the exited replica is requeued explicitly.
    fn reap_exited(&mut self) {
        for replica in 0..self.slots.len() {
            let finished = self.slots[replica]
                .handle
                .as_ref()
                .is_some_and(|h| h.is_finished());
            if !finished {
                continue;
            }
            if let Some(h) = self.slots[replica].handle.take() {
                let _ = h.join();
            }
            self.slots[replica].tx = None;
            // process the exit's event tail (possibly a ReplicaDown,
            // which respawns the slot) before sweeping stragglers
            self.pump();
            let stragglers: Vec<RequestId> = self
                .tracked
                .iter()
                .filter(|(_, t)| t.replica == replica && !t.queued_retry)
                .map(|(&id, _)| id)
                .collect();
            for id in stragglers {
                self.schedule_retry(id, true);
            }
        }
    }

    /// Abortive shutdown: workers exit at their next loop iteration,
    /// abandoning queued work (contrast [`Server::drain`]). Returns
    /// each replica's final [`Metrics`] snapshot (sorted by replica
    /// index, folded across restart generations) so multi-replica
    /// serves can report the same stats as a single engine.
    pub fn shutdown(mut self) -> Vec<Metrics> {
        self.shutdown.store(true, Ordering::SeqCst);
        for s in &self.slots {
            if let Some(tx) = &s.tx {
                let _ = tx.send(WorkerMsg::Shutdown);
            }
        }
        for s in &mut self.slots {
            if let Some(h) = s.handle.take() {
                let _ = h.join();
            }
        }
        fold_metrics(self.slots.len(), &self.metrics_rx)
    }

    /// Kill the worker threads while keeping the front-end alive, to
    /// exercise the [`SubmitError::ServerStopped`] path.
    #[cfg(test)]
    fn abandon_workers(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for s in &self.slots {
            if let Some(tx) = &s.tx {
                let _ = tx.send(WorkerMsg::Shutdown);
            }
        }
        for s in &mut self.slots {
            if let Some(h) = s.handle.take() {
                let _ = h.join();
            }
        }
    }
}

/// Fold per-replica snapshots across restart generations: counters
/// sum ([`Metrics::merge_from`]); point-in-time gauges keep the last
/// generation's value — a dead generation's pages no longer exist, so
/// summing them would fake a leak. Generation order is guaranteed by
/// the channel: generation g's exit snapshot is sent before g+1 is
/// spawned.
fn fold_metrics(n: usize, rx: &Receiver<(usize, Metrics)>) -> Vec<Metrics> {
    let mut acc: Vec<Option<Metrics>> = (0..n).map(|_| None).collect();
    for (replica, m) in rx.try_iter() {
        match &mut acc[replica] {
            slot @ None => *slot = Some(m),
            Some(prev) => {
                prev.merge_from(&m);
                prev.pages_in_use = m.pages_in_use;
                prev.pages_free = m.pages_free;
                prev.page_budget = m.page_budget;
                prev.queue_depth = m.queue_depth;
            }
        }
    }
    acc.into_iter().flatten().collect()
}

/// Increment `gauge` unless it is already at `limit`.
fn try_acquire(gauge: &AtomicUsize, limit: usize) -> bool {
    gauge
        .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| {
            (d < limit).then_some(d + 1)
        })
        .is_ok()
}

/// Give back an intake slot acquired by [`try_acquire`] (send failed —
/// the request never reached the engine).
fn release(gauge: &AtomicUsize) {
    let _ = gauge.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| d.checked_sub(1));
}

/// Human-readable panic payload for the `ReplicaDown` cause string.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".to_string()
    }
}

fn worker_loop(
    replica: usize,
    engine: &mut ServeEngine,
    rx: Receiver<WorkerMsg>,
    event_tx: Sender<(usize, ServerEvent)>,
    metrics_tx: Sender<(usize, Metrics)>,
    stop: Arc<AtomicBool>,
) {
    let mut draining = false;
    let mut events: Vec<ServerEvent> = Vec::new();
    'serve: loop {
        // drain intake without blocking while work is pending
        loop {
            match rx.try_recv() {
                Ok(WorkerMsg::Submit(req)) => engine.submit(req),
                Ok(WorkerMsg::Drain) => draining = true,
                Ok(WorkerMsg::Shutdown) => break 'serve,
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => break 'serve,
            }
        }
        if stop.load(Ordering::Relaxed) {
            break 'serve;
        }
        if engine.pending() == 0 {
            if draining {
                break 'serve; // drained dry: exit after in-flight work
            }
            // idle: block briefly for new work
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(WorkerMsg::Submit(req)) => engine.submit(req),
                Ok(WorkerMsg::Drain) => {
                    draining = true;
                    continue;
                }
                Ok(WorkerMsg::Shutdown) => break 'serve,
                Err(_) => continue,
            }
        }
        // Panic isolation: a panicking step (engine bug or injected
        // fault) poisons only this replica. Events pushed before the
        // panic are forwarded — the handle's dedupe watermarks make
        // the replay overlap safe — then a final metrics snapshot and
        // the death notice, in that order, so per-sender mpsc FIFO
        // guarantees the supervisor has seen everything this replica
        // completed before it requeues the rest. Dropping the engine
        // on return frees its KV pages with it.
        let step = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            engine.step_events(&mut events)
        }));
        let died = step.err().map(panic_message);
        for ev in events.drain(..) {
            if event_tx.send((replica, ev)).is_err() {
                break 'serve;
            }
        }
        if let Some(cause) = died {
            let _ = metrics_tx.send((replica, engine.metrics.clone()));
            let _ = event_tx.send((replica, ServerEvent::ReplicaDown { replica, cause }));
            return;
        }
    }
    // final snapshot for the drain/shutdown aggregate report
    let _ = metrics_tx.send((replica, engine.metrics.clone()));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::coordinator::faults::{FaultEntry, FaultKind};
    use crate::coordinator::request::{FinishReason, RequestStatus};
    use crate::model::{ModelConfig, Transformer};
    use crate::rng::Rng;

    fn mk_model(seed: u64) -> Transformer {
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = 32;
        cfg.max_seq = 32;
        let mut rng = Rng::new(seed);
        Transformer::random(cfg, &mut rng)
    }

    fn mk_engine(seed: u64) -> ServeEngine {
        ServeEngine::new(mk_model(seed), BatchPolicy::default())
    }

    fn params(n: usize) -> SamplingParams {
        SamplingParams::greedy(n).with_stop(None)
    }

    #[test]
    fn single_replica_end_to_end() {
        let mut server = Server::start(vec![mk_engine(1)], RoutePolicy::LeastLoaded);
        let id = server.submit(vec![1, 2, 3], params(4), 0).try_id().unwrap();
        let out = server.wait_for(1, Duration::from_secs(10));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, id);
        assert_eq!(out[0].tokens.len(), 4);
        server.shutdown();
    }

    #[test]
    fn multi_replica_all_requests_served() {
        let engines = vec![mk_engine(1), mk_engine(1)];
        let mut server = Server::start(engines, RoutePolicy::LeastLoaded);
        let mut ids = Vec::new();
        for i in 0..8 {
            ids.push(
                server
                    .submit(vec![1 + i % 5, 2], params(3), 0)
                    .try_id()
                    .unwrap(),
            );
        }
        let out = server.wait_for(8, Duration::from_secs(20));
        assert_eq!(out.len(), 8);
        let mut got: Vec<u64> = out.iter().map(|r| r.id).collect();
        got.sort_unstable();
        ids.sort_unstable();
        assert_eq!(got, ids);
        server.shutdown();
    }

    #[test]
    fn threaded_replicas_match_sequential_replicas() {
        // replica workers with 2-lane kernel pools must serve the same
        // tokens as sequential replicas (determinism across --threads)
        let model = mk_model(5);
        let serve = |threads: usize| {
            let mut server = ServerBuilder::new()
                .replicas(2)
                .route(RoutePolicy::RoundRobin)
                .threads(threads)
                .start(model.clone());
            for i in 0..6u64 {
                let _ = server
                    .submit(vec![1 + (i % 5) as u32, 2, 3], params(4), 0)
                    .try_id()
                    .unwrap();
            }
            let mut out = server.wait_for(6, Duration::from_secs(30));
            let metrics = server.shutdown();
            assert_eq!(metrics.len(), 2, "one final snapshot per replica");
            assert_eq!(metrics.iter().map(|m| m.completed).sum::<u64>(), 6);
            out.sort_by_key(|r| r.id);
            out
        };
        let seq = serve(1);
        let par = serve(2);
        assert_eq!(seq.len(), 6);
        assert_eq!(par.len(), 6);
        for (a, b) in par.iter().zip(&seq) {
            assert_eq!(a.tokens, b.tokens, "req {}", a.id);
        }
    }

    #[test]
    fn paged_prefix_replicas_match_legacy_layout() {
        // shared-prefix workload through the full server stack: paged
        // pages + prefix adoption must serve token-identical responses
        // to the legacy contiguous layout
        use crate::coordinator::kv_pool::PagedKvOpts;
        let model = mk_model(9);
        let serve = |kv: PagedKvOpts| {
            let mut server = ServerBuilder::new()
                .route(RoutePolicy::RoundRobin)
                .threads(1)
                .paged_kv(kv)
                .start(model.clone());
            let shared: Vec<u32> = (0..12).map(|j| 1 + (j % 7)).collect();
            for i in 0..6u64 {
                let mut prompt = shared.clone();
                prompt.push(10 + (i % 4) as u32); // distinct suffixes
                let _ = server.submit(prompt, params(4), 0).try_id().unwrap();
            }
            let mut out = server.wait_for(6, Duration::from_secs(30));
            server.shutdown();
            out.sort_by_key(|r| r.id);
            out
        };
        let legacy = serve(PagedKvOpts {
            page_size: 32,
            prefix_cache: false,
            page_budget: None,
        });
        let paged = serve(PagedKvOpts {
            page_size: 4,
            prefix_cache: true,
            page_budget: None,
        });
        assert_eq!(legacy.len(), 6);
        for (a, b) in paged.iter().zip(&legacy) {
            assert_eq!(a.tokens, b.tokens, "req {}", a.id);
        }
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let server = Server::start(vec![mk_engine(2)], RoutePolicy::RoundRobin);
        server.shutdown(); // no hang
    }

    #[test]
    fn poll_nonblocking_when_empty() {
        let mut server = Server::start(vec![mk_engine(3)], RoutePolicy::RoundRobin);
        let t0 = std::time::Instant::now();
        let out = server.poll();
        assert!(out.is_empty());
        assert!(t0.elapsed() < Duration::from_millis(100));
        server.shutdown();
    }

    #[test]
    fn submit_after_worker_death_surfaces_server_stopped() {
        let mut server = Server::start(vec![mk_engine(4)], RoutePolicy::RoundRobin);
        server.abandon_workers();
        let out = server.submit(vec![1, 2], params(3), 0);
        assert_eq!(out.err(), Some(SubmitError::ServerStopped));
        assert_eq!(server.stats.server_stopped, 1);
        assert_eq!(server.stats.accepted, 0);
    }

    #[test]
    fn invalid_params_rejected_at_submit() {
        let mut server = Server::start(vec![mk_engine(6)], RoutePolicy::RoundRobin);
        let out = server.submit(vec![1], SamplingParams::greedy(0), 0);
        assert_eq!(out.err(), Some(SubmitError::ZeroBudget));
        let out = server.submit(vec![1], params(4).with_n(0), 0);
        assert_eq!(out.err(), Some(SubmitError::ZeroSamples));
        assert_eq!(server.stats.invalid_params, 2);
        assert_eq!(server.stats.submitted, 2);
        server.shutdown();
    }

    #[test]
    fn drain_completes_in_flight_work() {
        let mut server = ServerBuilder::new()
            .replicas(2)
            .route(RoutePolicy::RoundRobin)
            .threads(1)
            .start(mk_model(7));
        for i in 0..6u64 {
            let _ = server
                .submit(vec![1 + (i % 5) as u32, 2], params(3), 0)
                .try_id()
                .unwrap();
        }
        // drain without waiting: every response must still arrive
        let report = server.drain();
        let responses = report.responses();
        assert_eq!(responses.len(), 6, "drain finishes queued + running work");
        assert!(responses.iter().all(|r| r.finish == FinishReason::Length));
        assert_eq!(report.metrics.len(), 2);
        let agg = Metrics::aggregate(&report.metrics);
        assert_eq!(agg.requests_finished, 6);
        assert_eq!(agg.submitted, 6);
    }

    #[test]
    fn queue_full_rejects_then_recovers() {
        let mut server = ServerBuilder::new()
            .threads(1)
            .intake_limit(2)
            .start(mk_model(8));
        let mut accepted = 0usize;
        let mut rejected = 0usize;
        for i in 0..6u64 {
            match server.submit(vec![1 + (i % 5) as u32, 2], params(4), 0) {
                SubmitOutcome::Accepted(_) => accepted += 1,
                SubmitOutcome::Rejected(SubmitError::QueueFull { .. }) => rejected += 1,
                SubmitOutcome::Rejected(e) => panic!("unexpected rejection: {e}"),
            }
        }
        assert!(accepted >= 2, "the intake window admits up to its limit");
        assert!(rejected >= 1, "submitting 6 at once must overflow a window of 2");
        let out = server.wait_for(accepted, Duration::from_secs(30));
        assert_eq!(out.len(), accepted, "accepted requests all complete");
        // the window freed up: a new submit is accepted again
        let retry = server.submit(vec![3, 4], params(2), 0);
        assert!(retry.is_accepted(), "intake recovers after completions");
        let out = server.wait_for(1, Duration::from_secs(10));
        assert_eq!(out.len(), 1);
        let stats = server.stats.clone();
        let report = server.drain();
        assert_eq!(stats.submitted, 7);
        assert_eq!(stats.queue_full, rejected as u64);
        let agg = Metrics::aggregate(&report.metrics);
        // request-granular identity over the whole run
        assert_eq!(
            agg.requests_finished + stats.queue_full,
            stats.submitted,
            "completed + rejected == submitted"
        );
    }

    #[test]
    fn cancel_via_handle_roundtrip() {
        // a single-slot batcher keeps the target queued behind a
        // blocker, so the cancel deterministically lands before the
        // target can run to completion
        let mut server = ServerBuilder::new()
            .threads(1)
            .batch(BatchPolicy::default().with_max_running(1))
            .start(mk_model(10));
        let blocker = server.submit(vec![9, 8], params(20), 0).try_id().unwrap();
        let handle = server
            .submit(vec![1, 2, 3], params(20), 0)
            .handle()
            .expect("accepted");
        handle.cancel();
        let out = server.wait_for(2, Duration::from_secs(20));
        assert_eq!(out.len(), 2);
        for r in &out {
            if r.id == blocker {
                assert_eq!(r.finish, FinishReason::Length, "blocker unaffected");
            } else {
                assert_eq!(r.id, handle.id());
                assert_eq!(r.finish, FinishReason::Cancelled);
            }
        }
        assert_eq!(handle.try_status(), RequestStatus::Finished);
        let metrics = server.shutdown();
        assert_eq!(metrics.iter().map(|m| m.cancelled).sum::<u64>(), 1);
    }

    #[test]
    fn streamed_tokens_match_final_response() {
        let mut server = ServerBuilder::new().threads(1).start(mk_model(12));
        let id = server.submit(vec![1, 2, 3], params(5), 0).try_id().unwrap();
        let mut stream = Vec::new();
        let mut finished = None;
        let t0 = std::time::Instant::now();
        while finished.is_none() && t0.elapsed() < Duration::from_secs(20) {
            match server.next_event(Duration::from_millis(10)) {
                Some(ServerEvent::Token { id: eid, token, index, .. }) => {
                    assert_eq!(eid, id);
                    assert_eq!(index, stream.len(), "indexes contiguous from 0");
                    stream.push(token);
                }
                Some(ServerEvent::Done(r)) => finished = Some(r),
                _ => {}
            }
        }
        let resp = finished.expect("request finished");
        assert_eq!(stream, resp.tokens, "stream == final tokens");
        server.shutdown();
    }

    #[test]
    fn panicking_replica_is_isolated_and_requests_replay() {
        // the tentpole end-to-end: an injected panic kills replica 0
        // mid-run; the supervisor respawns it from the in-memory model
        // and replays its victims, and the final responses are
        // token-for-token identical to a fault-free run
        let model = mk_model(21);
        let run = |faulty: bool| {
            let mut builder = ServerBuilder::new()
                .replicas(2)
                .route(RoutePolicy::RoundRobin)
                .threads(1)
                .retry(RetryPolicy {
                    max_attempts: 4,
                    base: Duration::from_millis(1),
                    cap: Duration::from_millis(20),
                });
            if faulty {
                builder = builder.fault_plan(FaultPlan::new(vec![FaultEntry {
                    replica: 0,
                    step: 2,
                    kind: FaultKind::Panic,
                }]));
            }
            let mut server = builder.start(model.clone());
            for i in 0..6u64 {
                assert!(server
                    .submit(vec![1 + (i % 5) as u32, 2, 3], params(4), 0)
                    .is_accepted());
            }
            let mut out = server.wait_for(6, Duration::from_secs(60));
            let restarts = server.stats.replica_restarts;
            let requeued = server.stats.requeued;
            let report = server.drain();
            out.sort_by_key(|r| r.id);
            (out, restarts, requeued, report.metrics)
        };
        let (clean, restarts0, _, _) = run(false);
        let (chaos, restarts1, requeued, metrics) = run(true);
        assert_eq!(restarts0, 0, "no fault, no restart");
        assert!(restarts1 >= 1, "the injected panic restarts replica 0");
        assert!(requeued >= 1, "the victim's requests were requeued");
        assert_eq!(clean.len(), 6);
        assert_eq!(chaos.len(), 6);
        for (a, b) in chaos.iter().zip(&clean) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.finish, FinishReason::Length);
            assert_eq!(a.tokens, b.tokens, "req {} replays token-identical", a.id);
        }
        // both generations of replica 0 fold into one snapshot
        assert_eq!(metrics.len(), 2);
        assert!(Metrics::aggregate(&metrics).requests_finished >= 6);
    }

    #[test]
    fn replica_restarting_rejection_for_pinned_sessions() {
        // start_engines has no model source, so the respawn after the
        // injected panic fails and replica 0 stays dead: its pinned
        // victim exhausts the retry budget into a typed ReplicaLost,
        // new pinned submits see ReplicaRestarting (not ServerStopped),
        // and sessionless traffic keeps flowing via the survivor
        let probe = Router::new(2, RoutePolicy::LeastLoaded);
        let session = (1..64u64)
            .find(|&s| probe.session_replica(s) == 0)
            .expect("some session pins to replica 0");
        let engines = vec![mk_engine(3), mk_engine(3)];
        let mut server = ServerBuilder::new()
            .retry(RetryPolicy {
                max_attempts: 2,
                base: Duration::from_millis(1),
                cap: Duration::from_millis(4),
            })
            .fault_plan(FaultPlan::new(vec![FaultEntry {
                replica: 0,
                step: 0,
                kind: FaultKind::Panic,
            }]))
            .start_engines(engines);
        let victim = server
            .submit(vec![1, 2], params(4), session)
            .try_id()
            .unwrap();
        let mut lost = None;
        let mut saw_down = false;
        let t0 = std::time::Instant::now();
        while (lost.is_none() || !saw_down) && t0.elapsed() < Duration::from_secs(30) {
            match server.next_event(Duration::from_millis(10)) {
                Some(ServerEvent::ReplicaDown { replica, cause }) => {
                    assert_eq!(replica, 0);
                    assert!(cause.contains("injected fault"), "cause surfaced: {cause}");
                    saw_down = true;
                }
                Some(ServerEvent::Done(r)) => lost = Some(r),
                _ => {}
            }
        }
        assert!(saw_down, "death notice surfaced to the event stream");
        let lost = lost.expect("retry budget exhausts into a typed response");
        assert_eq!(lost.id, victim);
        assert_eq!(lost.finish, FinishReason::ReplicaLost);
        assert!(lost.tokens.is_empty(), "synthetic terminal has no tokens");
        assert_eq!(server.stats.replica_lost, 1);
        assert_eq!(server.stats.requeued, 1);
        // pinned sessions get the typed restarting rejection
        let out = server.submit(vec![1, 2], params(2), session);
        assert_eq!(out.err(), Some(SubmitError::ReplicaRestarting { replica: 0 }));
        assert_eq!(server.stats.replica_restarting, 1);
        // sessionless traffic spills to the healthy replica
        assert!(server.submit(vec![3, 4], params(2), 0).is_accepted());
        let done = server.wait_for(1, Duration::from_secs(10));
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].finish, FinishReason::Length);
        server.shutdown();
    }
}

//! Threaded server front-end: intake channel → router → per-replica
//! worker threads → response channel.
//!
//! tokio is unavailable offline (DESIGN.md §2), so concurrency is
//! std::thread + mpsc: one worker thread per engine replica runs the
//! continuous-batching loop; the handle submits requests and collects
//! responses without blocking workers.

use super::engine::ServeEngine;
use super::metrics::Metrics;
use super::request::{Request, RequestId, Response, SamplingParams};
use super::router::{RoutePolicy, Router};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

enum WorkerMsg {
    Submit(Request),
    Shutdown,
}

/// A running multi-replica server.
pub struct Server {
    router: Router,
    workers: Vec<Sender<WorkerMsg>>,
    responses: Receiver<(usize, Response)>,
    /// Final per-replica metrics snapshots, sent as workers exit.
    metrics_rx: Receiver<(usize, Metrics)>,
    handles: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    shutdown: Arc<AtomicBool>,
}

impl Server {
    /// Spawn one worker thread per engine replica.
    pub fn start(engines: Vec<ServeEngine>, policy: RoutePolicy) -> Server {
        assert!(!engines.is_empty());
        let n = engines.len();
        let (resp_tx, resp_rx) = channel::<(usize, Response)>();
        let (metrics_tx, metrics_rx) = channel::<(usize, Metrics)>();
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut workers = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for (replica, mut engine) in engines.into_iter().enumerate() {
            let (tx, rx) = channel::<WorkerMsg>();
            let resp_tx = resp_tx.clone();
            let metrics_tx = metrics_tx.clone();
            let stop = shutdown.clone();
            handles.push(std::thread::spawn(move || {
                worker_loop(replica, &mut engine, rx, resp_tx, metrics_tx, stop);
            }));
            workers.push(tx);
        }
        Server {
            router: Router::new(n, policy),
            workers,
            responses: resp_rx,
            metrics_rx,
            handles,
            next_id: AtomicU64::new(1),
            shutdown,
        }
    }

    /// Spawn `replicas` engines cloned from one model, each replica
    /// worker with its **own** `threads`-lane kernel pool (so replicas
    /// never contend on a shared pool's dispatch lock). `threads == 1`
    /// forces every replica onto the exact sequential kernel path —
    /// the debugging escape hatch `--threads 1` plumbs through here.
    pub fn start_replicas(
        model: crate::model::Transformer,
        replicas: usize,
        policy: super::batcher::BatchPolicy,
        route: RoutePolicy,
        threads: usize,
    ) -> Server {
        Server::start_replicas_with(
            model,
            replicas,
            policy,
            route,
            threads,
            super::kv_pool::PagedKvOpts::default(),
        )
    }

    /// [`Server::start_replicas`] with explicit paged-KV options
    /// (`--page-size` / `--prefix-cache` / `--kv-pages`). Each replica
    /// gets its own page store and radix prefix tree — prefix reuse is
    /// per-replica, which is why session-affinity routing pairs well
    /// with the cache.
    pub fn start_replicas_with(
        model: crate::model::Transformer,
        replicas: usize,
        policy: super::batcher::BatchPolicy,
        route: RoutePolicy,
        threads: usize,
        kv: super::kv_pool::PagedKvOpts,
    ) -> Server {
        assert!(replicas >= 1, "need at least one replica");
        let engines = (0..replicas)
            .map(|_| ServeEngine::with_opts(model.clone(), policy, threads, kv))
            .collect();
        Server::start(engines, route)
    }

    /// Submit a prompt; returns the assigned request id.
    pub fn submit(&mut self, prompt: Vec<u32>, params: SamplingParams, session: u64) -> RequestId {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut req = Request::new(id, prompt, params);
        req.session = session;
        let replica = self.router.route(&req);
        // worker thread gone ⇒ server shut down; drop silently
        let _ = self.workers[replica].send(WorkerMsg::Submit(req));
        id
    }

    /// Non-blocking poll for finished responses.
    pub fn poll(&mut self) -> Vec<Response> {
        let mut out = Vec::new();
        loop {
            match self.responses.try_recv() {
                Ok((replica, resp)) => {
                    self.router.complete(replica);
                    out.push(resp);
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        out
    }

    /// Block until `n` responses arrive or `timeout` elapses.
    pub fn wait_for(&mut self, n: usize, timeout: Duration) -> Vec<Response> {
        let deadline = std::time::Instant::now() + timeout;
        let mut out = Vec::new();
        while out.len() < n && std::time::Instant::now() < deadline {
            match self.responses.recv_timeout(Duration::from_millis(10)) {
                Ok((replica, resp)) => {
                    self.router.complete(replica);
                    out.push(resp);
                }
                Err(_) => {}
            }
        }
        out
    }

    /// Graceful shutdown: drain workers, join threads, and return each
    /// replica's final [`Metrics`] snapshot (sorted by replica index)
    /// so multi-replica serves can report the same stats as a single
    /// engine.
    pub fn shutdown(mut self) -> Vec<Metrics> {
        self.shutdown.store(true, Ordering::SeqCst);
        for w in &self.workers {
            let _ = w.send(WorkerMsg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        let mut out: Vec<(usize, Metrics)> = self.metrics_rx.try_iter().collect();
        out.sort_by_key(|(replica, _)| *replica);
        out.into_iter().map(|(_, m)| m).collect()
    }
}

fn worker_loop(
    replica: usize,
    engine: &mut ServeEngine,
    rx: Receiver<WorkerMsg>,
    resp_tx: Sender<(usize, Response)>,
    metrics_tx: Sender<(usize, Metrics)>,
    stop: Arc<AtomicBool>,
) {
    'serve: loop {
        // drain intake without blocking while work is pending
        loop {
            match rx.try_recv() {
                Ok(WorkerMsg::Submit(req)) => engine.submit(req),
                Ok(WorkerMsg::Shutdown) => break 'serve,
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => break 'serve,
            }
        }
        if stop.load(Ordering::Relaxed) {
            break 'serve;
        }
        if engine.pending() == 0 {
            // idle: block briefly for new work
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(WorkerMsg::Submit(req)) => engine.submit(req),
                Ok(WorkerMsg::Shutdown) => break 'serve,
                Err(_) => continue,
            }
        }
        for resp in engine.step() {
            if resp_tx.send((replica, resp)).is_err() {
                break 'serve;
            }
        }
    }
    // final snapshot for Server::shutdown's aggregate report
    let _ = metrics_tx.send((replica, engine.metrics.clone()));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::model::{ModelConfig, Transformer};
    use crate::rng::Rng;

    fn mk_engine(seed: u64) -> ServeEngine {
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = 32;
        cfg.max_seq = 32;
        let mut rng = Rng::new(seed);
        ServeEngine::new(Transformer::random(cfg, &mut rng), BatchPolicy::default())
    }

    fn params(n: usize) -> SamplingParams {
        SamplingParams {
            max_new_tokens: n,
            stop_token: None,
            ..Default::default()
        }
    }

    #[test]
    fn single_replica_end_to_end() {
        let mut server = Server::start(vec![mk_engine(1)], RoutePolicy::LeastLoaded);
        let id = server.submit(vec![1, 2, 3], params(4), 0);
        let out = server.wait_for(1, Duration::from_secs(10));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, id);
        assert_eq!(out[0].tokens.len(), 4);
        server.shutdown();
    }

    #[test]
    fn multi_replica_all_requests_served() {
        let engines = vec![mk_engine(1), mk_engine(1)];
        let mut server = Server::start(engines, RoutePolicy::LeastLoaded);
        let mut ids = Vec::new();
        for i in 0..8 {
            ids.push(server.submit(vec![1 + i % 5, 2], params(3), 0));
        }
        let out = server.wait_for(8, Duration::from_secs(20));
        assert_eq!(out.len(), 8);
        let mut got: Vec<u64> = out.iter().map(|r| r.id).collect();
        got.sort_unstable();
        ids.sort_unstable();
        assert_eq!(got, ids);
        server.shutdown();
    }

    #[test]
    fn threaded_replicas_match_sequential_replicas() {
        // replica workers with 2-lane kernel pools must serve the same
        // tokens as sequential replicas (determinism across --threads)
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = 32;
        cfg.max_seq = 32;
        let mut rng = Rng::new(5);
        let model = Transformer::random(cfg, &mut rng);
        let serve = |threads: usize| {
            let mut server = Server::start_replicas(
                model.clone(),
                2,
                BatchPolicy::default(),
                RoutePolicy::RoundRobin,
                threads,
            );
            for i in 0..6u64 {
                server.submit(vec![1 + (i % 5) as u32, 2, 3], params(4), 0);
            }
            let mut out = server.wait_for(6, Duration::from_secs(30));
            let metrics = server.shutdown();
            assert_eq!(metrics.len(), 2, "one final snapshot per replica");
            assert_eq!(metrics.iter().map(|m| m.completed).sum::<u64>(), 6);
            out.sort_by_key(|r| r.id);
            out
        };
        let seq = serve(1);
        let par = serve(2);
        assert_eq!(seq.len(), 6);
        assert_eq!(par.len(), 6);
        for (a, b) in par.iter().zip(&seq) {
            assert_eq!(a.tokens, b.tokens, "req {}", a.id);
        }
    }

    #[test]
    fn paged_prefix_replicas_match_legacy_layout() {
        // shared-prefix workload through the full server stack: paged
        // pages + prefix adoption must serve token-identical responses
        // to the legacy contiguous layout
        use crate::coordinator::kv_pool::PagedKvOpts;
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = 32;
        cfg.max_seq = 32;
        let mut rng = Rng::new(9);
        let model = Transformer::random(cfg, &mut rng);
        let serve = |kv: PagedKvOpts| {
            let mut server = Server::start_replicas_with(
                model.clone(),
                1,
                BatchPolicy::default(),
                RoutePolicy::RoundRobin,
                1,
                kv,
            );
            let shared: Vec<u32> = (0..12).map(|j| 1 + (j % 7)).collect();
            for i in 0..6u64 {
                let mut prompt = shared.clone();
                prompt.push(10 + (i % 4) as u32); // distinct suffixes
                server.submit(prompt, params(4), 0);
            }
            let mut out = server.wait_for(6, Duration::from_secs(30));
            server.shutdown();
            out.sort_by_key(|r| r.id);
            out
        };
        let legacy = serve(PagedKvOpts {
            page_size: 32,
            prefix_cache: false,
            page_budget: None,
        });
        let paged = serve(PagedKvOpts {
            page_size: 4,
            prefix_cache: true,
            page_budget: None,
        });
        assert_eq!(legacy.len(), 6);
        for (a, b) in paged.iter().zip(&legacy) {
            assert_eq!(a.tokens, b.tokens, "req {}", a.id);
        }
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let server = Server::start(vec![mk_engine(2)], RoutePolicy::RoundRobin);
        server.shutdown(); // no hang
    }

    #[test]
    fn poll_nonblocking_when_empty() {
        let mut server = Server::start(vec![mk_engine(3)], RoutePolicy::RoundRobin);
        let t0 = std::time::Instant::now();
        let out = server.poll();
        assert!(out.is_empty());
        assert!(t0.elapsed() < Duration::from_millis(100));
        server.shutdown();
    }
}

//! Deterministic fault injection for the supervised serving layer.
//!
//! A [`FaultPlan`] is a fixed list of faults keyed by `(replica, step)`,
//! built either from an explicit JSON file (`--fault-plan FILE`) or from
//! a seed (`PTQTP_FAULT_SEED`) so CI chaos runs, property tests, and unit
//! tests all share one mechanism. The plan is compiled in always but
//! completely inert unless installed — an engine without an injector
//! executes zero extra branches on the hot path beyond one `Option`
//! check per step.
//!
//! Entries are **one-shot**: a replica that panics at step N and is
//! respawned cold restarts its step counter at 0, so a persistent
//! `(replica, step)` trigger would re-fire forever and the run could
//! never converge. Each entry carries an `AtomicBool` latch instead.
//!
//! JSON schema (`ptqtp-fault-plan/1`):
//!
//! ```json
//! {
//!   "schema": "ptqtp-fault-plan/1",
//!   "faults": [
//!     {"replica": 1, "step": 4, "kind": "panic"},
//!     {"replica": 0, "step": 6, "kind": "pages_exhausted"},
//!     {"replica": 2, "kind": "ckpt_io"},
//!     {"replica": 0, "step": 9, "kind": "slow_step_ms", "ms": 50}
//!   ]
//! }
//! ```
//!
//! `ckpt_io` has no step: it fires on the replica's next checkpoint
//! *load* (i.e. the supervisor's restart path), exercising the
//! retry-with-backoff read hardening.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::rng::Rng;
use crate::serialize::Json;

/// What to do when an armed entry fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic inside the engine step (exercises `catch_unwind` isolation).
    Panic,
    /// Force the paged-KV reserve path to report exhaustion for one
    /// step, driving the recompute-preemption machinery.
    PagesExhausted,
    /// Sleep this many milliseconds inside the step (deadline testing).
    SlowStepMs(u64),
    /// Fail the replica's next checkpoint read during supervisor
    /// restart (exercises the retry-once-with-backoff path).
    CkptIoError,
}

impl std::fmt::Display for FaultKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaultKind::Panic => write!(f, "panic"),
            FaultKind::PagesExhausted => write!(f, "pages_exhausted"),
            FaultKind::SlowStepMs(ms) => write!(f, "slow_step_ms({ms})"),
            FaultKind::CkptIoError => write!(f, "ckpt_io"),
        }
    }
}

/// One scheduled fault. `step` counts engine steps within a replica
/// *generation* (restart resets it to 0); `CkptIoError` ignores it.
#[derive(Clone, Debug)]
pub struct FaultEntry {
    pub replica: usize,
    pub step: u64,
    pub kind: FaultKind,
}

/// A deterministic schedule of faults shared (via `Arc`) between the
/// supervisor and every replica's injector handle.
#[derive(Debug, Default)]
pub struct FaultPlan {
    entries: Vec<FaultEntry>,
    fired: Vec<AtomicBool>,
}

impl FaultPlan {
    pub fn new(entries: Vec<FaultEntry>) -> Self {
        let fired = entries.iter().map(|_| AtomicBool::new(false)).collect();
        FaultPlan { entries, fired }
    }

    /// Derive a small chaos schedule from a seed: 1–2 replica panics in
    /// the early decode steps plus (on odd seeds) one forced page
    /// exhaustion. Kept deliberately mild — the point is determinism,
    /// not volume; explicit plans cover the exotic shapes.
    pub fn from_seed(seed: u64, replicas: usize) -> Self {
        let n = replicas.max(1);
        let mut rng = Rng::new(seed ^ 0xFA01_7517);
        let mut entries = Vec::new();
        let panics = 1 + (rng.next_u64() % 2) as usize;
        for _ in 0..panics.min(n.saturating_sub(1).max(1)) {
            entries.push(FaultEntry {
                replica: rng.below(n),
                step: 2 + rng.next_u64() % 9,
                kind: FaultKind::Panic,
            });
        }
        if seed % 2 == 1 {
            entries.push(FaultEntry {
                replica: rng.below(n),
                step: 3 + rng.next_u64() % 6,
                kind: FaultKind::PagesExhausted,
            });
        }
        FaultPlan::new(entries)
    }

    /// Parse the `ptqtp-fault-plan/1` JSON schema.
    pub fn parse(src: &str) -> anyhow::Result<Self> {
        let j = Json::parse(src)?;
        let schema = j.req_str("schema")?;
        anyhow::ensure!(
            schema == "ptqtp-fault-plan/1",
            "unsupported fault-plan schema {schema:?}"
        );
        let faults = j
            .get("faults")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("fault plan missing `faults` array"))?;
        let mut entries = Vec::with_capacity(faults.len());
        for f in faults {
            let replica = f.req_usize("replica")?;
            let kind = match f.req_str("kind")? {
                "panic" => FaultKind::Panic,
                "pages_exhausted" => FaultKind::PagesExhausted,
                "ckpt_io" => FaultKind::CkptIoError,
                "slow_step_ms" => FaultKind::SlowStepMs(f.req_f64("ms")? as u64),
                other => anyhow::bail!("unknown fault kind {other:?}"),
            };
            let step = match f.get("step") {
                Some(s) => s.as_f64().map(|v| v as u64).unwrap_or(0),
                None => 0,
            };
            entries.push(FaultEntry { replica, step, kind });
        }
        Ok(FaultPlan::new(entries))
    }

    /// Load a plan from a `--fault-plan FILE` path.
    pub fn load(path: &str) -> anyhow::Result<Self> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read fault plan {path}: {e}"))?;
        Self::parse(&src)
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Number of scheduled faults.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Fire the first unfired step-keyed entry matching `(replica,
    /// step)`. One-shot: each entry fires at most once per process.
    pub fn fire_step(&self, replica: usize, step: u64) -> Option<FaultKind> {
        for (i, e) in self.entries.iter().enumerate() {
            if e.replica != replica || e.step != step || e.kind == FaultKind::CkptIoError {
                continue;
            }
            if !self.fired[i].swap(true, Ordering::AcqRel) {
                return Some(e.kind);
            }
        }
        None
    }

    /// Fire a pending checkpoint-I/O fault for this replica, if any.
    pub fn fire_ckpt(&self, replica: usize) -> bool {
        for (i, e) in self.entries.iter().enumerate() {
            if e.replica != replica || e.kind != FaultKind::CkptIoError {
                continue;
            }
            if !self.fired[i].swap(true, Ordering::AcqRel) {
                return true;
            }
        }
        false
    }
}

/// Per-replica handle the engine polls once per step. Cloning is cheap;
/// the latch state lives in the shared plan.
#[derive(Clone, Debug)]
pub struct FaultInjector {
    plan: Arc<FaultPlan>,
    replica: usize,
}

impl FaultInjector {
    pub fn new(plan: Arc<FaultPlan>, replica: usize) -> Self {
        FaultInjector { plan, replica }
    }

    pub fn fire_step(&self, step: u64) -> Option<FaultKind> {
        self.plan.fire_step(self.replica, step)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entries_fire_exactly_once() {
        let plan = FaultPlan::new(vec![FaultEntry {
            replica: 1,
            step: 4,
            kind: FaultKind::Panic,
        }]);
        assert_eq!(plan.fire_step(0, 4), None, "wrong replica");
        assert_eq!(plan.fire_step(1, 3), None, "wrong step");
        assert_eq!(plan.fire_step(1, 4), Some(FaultKind::Panic));
        assert_eq!(plan.fire_step(1, 4), None, "one-shot latch");
    }

    #[test]
    fn ckpt_faults_are_separate_from_step_faults() {
        let plan = FaultPlan::new(vec![
            FaultEntry {
                replica: 0,
                step: 0,
                kind: FaultKind::CkptIoError,
            },
            FaultEntry {
                replica: 0,
                step: 0,
                kind: FaultKind::PagesExhausted,
            },
        ]);
        // step firing skips ckpt entries even at the same (replica, step)
        assert_eq!(plan.fire_step(0, 0), Some(FaultKind::PagesExhausted));
        assert!(plan.fire_ckpt(0));
        assert!(!plan.fire_ckpt(0), "ckpt latch is one-shot too");
        assert!(!plan.fire_ckpt(1));
    }

    #[test]
    fn json_roundtrip_covers_every_kind() {
        let src = r#"{
            "schema": "ptqtp-fault-plan/1",
            "faults": [
                {"replica": 1, "step": 4, "kind": "panic"},
                {"replica": 0, "step": 6, "kind": "pages_exhausted"},
                {"replica": 2, "kind": "ckpt_io"},
                {"replica": 0, "step": 9, "kind": "slow_step_ms", "ms": 50}
            ]
        }"#;
        let plan = FaultPlan::parse(src).unwrap();
        assert_eq!(plan.fire_step(1, 4), Some(FaultKind::Panic));
        assert_eq!(plan.fire_step(0, 6), Some(FaultKind::PagesExhausted));
        assert_eq!(plan.fire_step(0, 9), Some(FaultKind::SlowStepMs(50)));
        assert!(plan.fire_ckpt(2));
    }

    #[test]
    fn bad_schema_and_bad_kind_are_typed_errors() {
        assert!(FaultPlan::parse(r#"{"schema": "nope/9", "faults": []}"#).is_err());
        let bad_kind = r#"{"schema": "ptqtp-fault-plan/1",
                           "faults": [{"replica": 0, "kind": "meteor"}]}"#;
        assert!(FaultPlan::parse(bad_kind).is_err());
    }

    #[test]
    fn seeded_plans_are_deterministic_and_nonempty() {
        let a = FaultPlan::from_seed(7, 3);
        let b = FaultPlan::from_seed(7, 3);
        assert!(!a.is_empty());
        assert_eq!(a.entries.len(), b.entries.len());
        for (x, y) in a.entries.iter().zip(b.entries.iter()) {
            assert_eq!(x.replica, y.replica);
            assert_eq!(x.step, y.step);
            assert_eq!(x.kind, y.kind);
        }
        assert!(a.entries.iter().any(|e| e.kind == FaultKind::Panic));
    }
}

//! The serving engine: owns one model replica, a KV pool, and the set
//! of in-flight sequences; advances them with continuous batching.
//!
//! Control flow is **batch-drives-model**: each [`ServeEngine::step`]
//! turns the scheduler plan into one [`ForwardBatch`] — every planned
//! prefill chunk plus `1..=1 + k` decode rows per running sequence
//! (one committed token, plus up to `k` speculative draft rows when
//! `--spec-decode on`; see `coordinator::speculator`) — and executes
//! it with a single [`Transformer::forward_batch`] call, so the
//! ternary kernels see the whole row stack at once. Sampling and
//! logits storage run through engine-owned scratch buffers; the steady
//! state performs no per-token heap allocation.

use super::batcher::{plan_step, BatchPolicy};
use super::faults::{FaultInjector, FaultKind};
use super::kv_pool::{KvPool, PagedKvOpts};
use super::metrics::Metrics;
use super::prefix_cache::PrefixCache;
use super::request::{
    FinishReason, Request, Response, SequenceState, ServerEvent, SubmitError,
};
use super::speculator::SpecDecodeOpts;
use crate::model::{ForwardBatch, ForwardScratch, KvCache, Transformer};
use crate::rng::Rng;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// A preempted sequence awaiting re-admission: its pages are gone, but
/// the tokens generated so far are kept and recomputed through the
/// prefill path on resume (usually mostly adopted from the prefix
/// tree), after which decoding continues with identical output.
#[derive(Debug)]
struct PreemptedSeq {
    request: Request,
    generated: Vec<u32>,
    first_token_at: Option<std::time::Instant>,
}

/// One model replica + its scheduling state.
pub struct ServeEngine {
    pub model: Transformer,
    pub policy: BatchPolicy,
    pool: KvPool,
    /// Radix prefix cache over shared pages (None with
    /// `--prefix-cache off` — the exact-legacy escape hatch).
    prefix: Option<PrefixCache>,
    waiting: VecDeque<Request>,
    /// Preemption victims awaiting re-admission (before `waiting` —
    /// they were admitted first).
    preempted_q: VecDeque<PreemptedSeq>,
    running: Vec<SequenceState>,
    pub metrics: Metrics,
    /// Fused batch under construction (reused across steps).
    batch: ForwardBatch,
    /// Model-pass scratch (reused across steps).
    scratch: ForwardScratch,
    /// Slot owning each logits row of the current batch, in row order.
    logit_slots: Vec<usize>,
    /// Recycled logits buffers (pending_logits allocations).
    logit_pool: Vec<Vec<f32>>,
    /// Sampling probability scratch.
    prob_buf: Vec<f32>,
    /// Prompt-lookup speculative decoding (`None` = plain decode, the
    /// exact-legacy default; see `coordinator::speculator` and
    /// DESIGN.md §Speculative-Decoding).
    spec: Option<SpecDecodeOpts>,
    /// Speculator context scratch (`prompt ++ generated ++ peeked`).
    spec_ctx: Vec<u32>,
    /// Draft tokens proposed for the slot currently being planned.
    spec_buf: Vec<u32>,
    /// Server-side intake gauge for this replica: accepted-but-not-
    /// finished requests. The engine decrements it as requests retire
    /// so `Server::submit`'s admission check sees live occupancy.
    /// `None` when the engine is driven directly (no admission front).
    intake_depth: Option<Arc<AtomicUsize>>,
    /// Deterministic fault injection (chaos testing): polled once per
    /// step. `None` — the production default — costs one branch.
    faults: Option<FaultInjector>,
    /// Steps executed by this engine *generation* (a respawned replica
    /// starts over at 0); the fault plan is keyed by this.
    steps: u64,
    /// One-step flag set by an injected `PagesExhausted` fault: every
    /// reserve this step reports exhaustion, forcing the preemption
    /// path even though real capacity exists (see
    /// [`ServeEngine::mark_preempt`]'s `forced` parameter).
    force_exhaust: bool,
}

impl ServeEngine {
    /// Engine on the process-wide shared worker pool (sized by
    /// `PTQTP_THREADS` / available cores). Use
    /// [`ServeEngine::with_threads`] for an explicit lane count;
    /// `with_threads(_, _, 1)` forces the exact sequential path.
    pub fn new(model: Transformer, policy: BatchPolicy) -> ServeEngine {
        Self::with_pool_opts(
            model,
            policy,
            crate::threads::Pool::global().clone(),
            PagedKvOpts::default(),
        )
    }

    /// Engine whose model pass runs on its own `threads`-lane pool.
    /// Token output is bit-identical for every thread count (the
    /// row-parallel kernels preserve per-row FP order); `threads == 1`
    /// spawns nothing and is the documented debugging escape hatch.
    pub fn with_threads(model: Transformer, policy: BatchPolicy, threads: usize) -> ServeEngine {
        Self::with_pool_opts(
            model,
            policy,
            crate::threads::Pool::new(threads),
            PagedKvOpts::default(),
        )
    }

    /// [`ServeEngine::with_threads`] with explicit paged-KV options
    /// (page size, prefix cache on/off, page budget). Token output is
    /// bit-identical for every configuration — paging, prefix adoption,
    /// and preemption are capacity mechanisms, not numeric ones.
    pub fn with_opts(
        model: Transformer,
        policy: BatchPolicy,
        threads: usize,
        kv: PagedKvOpts,
    ) -> ServeEngine {
        Self::with_pool_opts(model, policy, crate::threads::Pool::new(threads), kv)
    }

    fn with_pool_opts(
        model: Transformer,
        policy: BatchPolicy,
        worker_pool: crate::threads::Pool,
        kv: PagedKvOpts,
    ) -> ServeEngine {
        let pool = KvPool::for_model_with(&model.config, policy.max_running, &kv);
        let prefix = kv.prefix_cache.then(|| PrefixCache::new(pool.page_size()));
        let mut scratch = ForwardScratch::with_pool(worker_pool);
        // inherit the (value-changing) int8-activation tier from the
        // model — set by the CLI front-ends, off by default
        scratch.set_act_quant(model.exec_act_quant);
        ServeEngine {
            model,
            policy,
            pool,
            prefix,
            waiting: VecDeque::new(),
            preempted_q: VecDeque::new(),
            running: Vec::new(),
            metrics: Metrics::default(),
            batch: ForwardBatch::new(),
            scratch,
            logit_slots: Vec::new(),
            logit_pool: Vec::new(),
            prob_buf: Vec::new(),
            spec: None,
            spec_ctx: Vec::new(),
            spec_buf: Vec::new(),
            intake_depth: None,
            faults: None,
            steps: 0,
            force_exhaust: false,
        }
    }

    /// Install a deterministic fault injector for this replica (chaos
    /// testing; see `coordinator::faults`). `None` — the default — is
    /// completely inert.
    pub fn set_fault_injector(&mut self, inj: Option<FaultInjector>) {
        self.faults = inj;
    }

    /// Enable (`Some`) or disable (`None`) prompt-lookup speculative
    /// decoding for this replica. Speculation is a scheduling
    /// optimization, not a sampling one: greedy sequences may commit
    /// up to `1 + k` tokens per step, but the committed stream is
    /// token-for-token identical to plain decode (the accept rule
    /// compares against the model's own argmax over the same rows a
    /// plain step would have computed); temperature sequences fall
    /// back to plain decode so the seeded RNG path is untouched.
    pub fn set_spec_decode(&mut self, opts: Option<SpecDecodeOpts>) {
        self.spec = opts;
    }

    /// The speculative-decoding configuration, if enabled.
    pub fn spec_decode(&self) -> Option<SpecDecodeOpts> {
        self.spec
    }

    /// Install the server's per-replica intake gauge (see
    /// [`ServeEngine::note_request_retired`]'s decrement).
    pub fn set_intake_depth(&mut self, gauge: Arc<AtomicUsize>) {
        self.intake_depth = Some(gauge);
    }

    /// Page-level accounting of this engine's KV pool — gauges for the
    /// serve log and the cancellation page-release assertions.
    pub fn page_stats(&self) -> crate::model::PageStats {
        self.pool.stats()
    }

    /// Worker lanes driving this engine's model pass.
    pub fn threads(&self) -> usize {
        self.scratch.pool().threads()
    }

    /// Toggle the SIMD kernel tiers for this engine's model pass —
    /// the ternary row-block kernels *and* the head-major attention
    /// kernels (default: the process-wide `--simd`/`PTQTP_SIMD`
    /// mode). Token output is bit-identical either way — every SIMD
    /// tier replays the scalar per-row FP order — so this is a
    /// perf/debug knob, not a numerics one (pinned by the SIMD on/off
    /// engine parity tests).
    ///
    /// `false` always downgrades everything to the scalar tiers.
    /// `true` engages the attention kernels unconditionally (they need
    /// no derived layout), but the ternary kernels only for layers
    /// carrying an interleaved layout — which is every aligned layer
    /// unless the process started with the mode `off` (then no
    /// interleave was built and the ternary half of the flag is a
    /// no-op; force layouts with
    /// `PackedTernaryLinear::set_interleave_lanes` for an A/B run in
    /// that state).
    pub fn set_simd(&mut self, on: bool) {
        self.scratch.set_simd(on);
    }

    /// Toggle the int8-activation tier for this engine's model pass.
    /// Unlike [`ServeEngine::set_simd`] this is **value-changing** —
    /// int8 output is bit-identical across thread counts, SIMD widths,
    /// and paged-vs-contiguous KV (DESIGN.md §Integer-Kernels), but
    /// not to the f32 tiers. Default: inherited from the model's
    /// `exec_act_quant` at construction (off unless the CLI resolved
    /// `--act-quant`/`PTQTP_ACT_QUANT` to on).
    pub fn set_act_quant(&mut self, on: bool) {
        self.scratch.set_act_quant(on);
    }

    /// Whether the int8-activation tier is active for this engine.
    pub fn act_quant(&self) -> bool {
        self.scratch.act_quant()
    }

    /// Enqueue a request (admission happens during [`ServeEngine::step`]).
    /// Panics on invalid [`SamplingParams`] — callers that can't
    /// guarantee validity use [`ServeEngine::try_submit`]; the server
    /// front-end validates at `Server::submit` and rejects with a typed
    /// error instead.
    pub fn submit(&mut self, req: Request) {
        if let Err(e) = self.try_submit(req) {
            panic!("invalid request reached ServeEngine::submit: {e}");
        }
    }

    /// Enqueue after validating the sampling parameters; invalid
    /// requests bounce with a typed [`SubmitError`] and touch no
    /// engine state.
    pub fn try_submit(&mut self, req: Request) -> Result<(), SubmitError> {
        req.params.validate()?;
        self.metrics.submitted += 1;
        self.waiting.push_back(req);
        let depth = self.waiting.len();
        if depth > self.metrics.queue_depth_peak {
            self.metrics.queue_depth_peak = depth;
        }
        Ok(())
    }

    pub fn pending(&self) -> usize {
        self.waiting.len() + self.preempted_q.len() + self.running.len()
    }

    pub fn running(&self) -> usize {
        self.running.len()
    }

    /// Admit while KV caches are available: preemption victims first
    /// (they were admitted earliest), then the waiting queue. Immediate
    /// rejections (e.g. over-long prompts) emit their `Done` events
    /// into `out`.
    fn admit(&mut self, out: &mut Vec<ServerEvent>) {
        while self.running.len() < self.policy.max_running {
            let Some(p) = self.preempted_q.pop_front() else { break };
            let Some(cache) = self.pool.acquire() else {
                self.preempted_q.push_front(p);
                break;
            };
            let mut seq = SequenceState::resume(p.request, p.generated, cache, p.first_token_at);
            self.adopt_prefix(&mut seq);
            self.running.push(seq);
        }
        while self.running.len() < self.policy.max_running {
            let Some(req) = self.waiting.front() else { break };
            // reject over-long prompts outright
            if req.prompt.len() + 1 >= self.model.config.max_seq {
                let req = self.waiting.pop_front().unwrap();
                self.metrics.rejected += 1;
                self.retire_early(req, Vec::new(), None, FinishReason::PromptTooLong, out);
                continue;
            }
            let Some(cache) = self.pool.acquire() else { break };
            let req = self.waiting.pop_front().unwrap();
            req.ctl.mark_running();
            let mut seq = SequenceState::new(req, cache);
            self.adopt_prefix(&mut seq);
            self.running.push(seq);
        }
    }

    /// Cancel/deadline reason for a request, if its lifetime has
    /// lapsed at `now` (cancel wins when both apply).
    fn lapse(req: &Request, now: Instant) -> Option<FinishReason> {
        if req.ctl.is_cancelled() {
            Some(FinishReason::Cancelled)
        } else if req.expired_at(now) {
            Some(FinishReason::DeadlineExceeded)
        } else {
            None
        }
    }

    /// Step-boundary lifecycle sweep: retire cancelled and
    /// deadline-expired requests from every queue *before* admission
    /// and planning, so a lapsed request never costs another model
    /// pass. Running victims release their KV pages eagerly — the
    /// same step-time release path preemption uses — but donate
    /// nothing to the prefix tree (nobody asked for this output);
    /// `PageStats.live` returns to its pre-request baseline.
    fn sweep_lifecycle(&mut self, out: &mut Vec<ServerEvent>) {
        let now = Instant::now();
        let mut i = 0;
        while i < self.waiting.len() {
            match Self::lapse(&self.waiting[i], now) {
                Some(reason) => {
                    let req = self.waiting.remove(i).expect("index in bounds");
                    self.retire_early(req, Vec::new(), None, reason, out);
                }
                None => i += 1,
            }
        }
        let mut i = 0;
        while i < self.preempted_q.len() {
            match Self::lapse(&self.preempted_q[i].request, now) {
                Some(reason) => {
                    let p = self.preempted_q.remove(i).expect("index in bounds");
                    self.retire_early(p.request, p.generated, p.first_token_at, reason, out);
                }
                None => i += 1,
            }
        }
        let mut i = 0;
        while i < self.running.len() {
            let Some(reason) = Self::lapse(&self.running[i].request, now) else {
                i += 1;
                continue;
            };
            let mut s = self.running.swap_remove(i);
            if let Some(buf) = s.pending_logits.take() {
                self.logit_pool.push(buf); // recycle the allocation
            }
            s.cache.reset(); // pages back to the store, this step
            self.pool.release(s.cache);
            self.retire_early(s.request, s.generated, s.first_token_at, reason, out);
        }
    }

    /// Retire a request outside the normal decode path (rejection,
    /// cancel, deadline): build the terminal [`Response`] — keeping
    /// whatever was generated — and emit its `Done` event.
    fn retire_early(
        &mut self,
        req: Request,
        tokens: Vec<u32>,
        first_token_at: Option<Instant>,
        finish: FinishReason,
        out: &mut Vec<ServerEvent>,
    ) {
        let resp = Response {
            id: req.id,
            sample: req.sample,
            ttft: first_token_at
                .map(|t| t - req.submitted_at)
                .unwrap_or_default(),
            total: req.submitted_at.elapsed(),
            prompt_len: req.prompt.len(),
            tokens,
            finish,
        };
        self.note_request_retired(&req, finish);
        out.push(ServerEvent::Done(resp));
    }

    /// Request-granular bookkeeping when one of a request's sequences
    /// retires: once **no** sequence sharing the id remains anywhere in
    /// the engine, the request is over — flip its control block to
    /// `Finished`, free its intake slot, and classify it into exactly
    /// one of the request-level counters (`requests_finished` /
    /// `cancelled` / `deadline_expired`; `PromptTooLong` was already
    /// counted in `rejected` at the rejection site). Per-response
    /// accounting (`completed`, latency reservoirs) stays separate in
    /// [`Metrics::record_response`].
    fn note_request_retired(&mut self, req: &Request, finish: FinishReason) {
        let id = req.id;
        let live = self.running.iter().any(|s| s.request.id == id)
            || self.waiting.iter().any(|r| r.id == id)
            || self.preempted_q.iter().any(|p| p.request.id == id);
        if live {
            return;
        }
        req.ctl.mark_finished();
        if let Some(depth) = &self.intake_depth {
            let _ = depth.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| {
                d.checked_sub(1)
            });
        }
        match finish {
            FinishReason::Cancelled => self.metrics.cancelled += 1,
            FinishReason::DeadlineExceeded => self.metrics.deadline_expired += 1,
            FinishReason::PromptTooLong => {}
            // synthesized by the supervisor, never by an engine — it is
            // accounted server-side in `ServerStats::replica_lost`
            FinishReason::ReplicaLost => {}
            FinishReason::Stop | FinishReason::Length | FinishReason::CacheOverflow => {
                self.metrics.requests_finished += 1;
            }
        }
    }

    /// Walk the radix tree for the sequence's prefill tokens and adopt
    /// the longest page-aligned cached prefix: refcount bumps only —
    /// zero bytes copied, zero prefill rows for the adopted span.
    fn adopt_prefix(&mut self, seq: &mut SequenceState) {
        let Some(pc) = self.prefix.as_mut() else { return };
        debug_assert!(self.pool.store().ptr_eq(seq.cache.store()));
        self.metrics.prefix_lookups += 1;
        let pages = if seq.generated.is_empty() {
            pc.lookup(&seq.request.prompt)
        } else {
            // resumed sequence: the recompute stream is prompt + prior
            // generation, all adoptable
            let mut tokens =
                Vec::with_capacity(seq.request.prompt.len() + seq.generated.len());
            tokens.extend_from_slice(&seq.request.prompt);
            tokens.extend_from_slice(&seq.generated);
            pc.lookup(&tokens)
        };
        if pages.is_empty() {
            return;
        }
        let adopted = pages.len() * self.pool.page_size();
        seq.cache.adopt_pages(pages);
        seq.prefill_cursor = adopted;
        self.metrics.prefix_hits += 1;
        self.metrics.adopted_tokens += adopted as u64;
    }

    /// Reserve pages so slot `slot` can append `n` positions this step,
    /// evicting stale prefix-tree pages under pressure. `false` means
    /// the pool is truly exhausted — the caller preempts.
    fn try_reserve(&mut self, slot: usize, n: usize) -> bool {
        if self.force_exhaust {
            // injected exhaustion: report failure without evicting
            // prefix pages — the shortage is synthetic, the tree is fine
            return false;
        }
        loop {
            match self.running[slot].cache.reserve(n) {
                Ok(()) => return true,
                Err(_) => {
                    let evicted = match self.prefix.as_mut() {
                        Some(pc) => pc.evict_one(self.pool.store()),
                        None => false,
                    };
                    if !evicted {
                        return false;
                    }
                    self.metrics.prefix_evicted_pages += 1;
                }
            }
        }
    }

    /// Choose what page exhaustion means for slot `slot`, and act on it
    /// *immediately*. If another running sequence still holds pages —
    /// victims already marked this step don't count, theirs are gone —
    /// the slot self-preempts: its prompt pages are donated to the
    /// prefix tree and every page it holds is released **now**, not at
    /// retirement. Eager release is what keeps multi-victim steps live:
    /// slots evaluated later in the same step reserve from the freed
    /// pages (or evict the victim's now-unreferenced tree pages)
    /// instead of all failing together, re-adopting the same shared
    /// pages on resume, and mutually preempting forever. If no other
    /// sequence holds pages, recompute would hit the same wall ⇒ retire
    /// with `CacheOverflow`. Every exhausted step therefore either
    /// lets some sequence make progress on the freed capacity or
    /// overflows the last holder standing — the preemption loop
    /// terminates (pinned by
    /// `lockstep_preemption_under_tight_budget_stays_live`).
    /// `forced` marks *injected* exhaustion ([`FaultKind::PagesExhausted`]):
    /// real capacity exists, so the lone-survivor `CacheOverflow` escape
    /// below must not fire — the victim preempts unconditionally and its
    /// resume succeeds next step, keeping output token-identical to a
    /// fault-free run (the PR-6 replay argument).
    fn mark_preempt(&mut self, slot: usize, forced: bool) {
        let others_hold_pages = self
            .running
            .iter()
            .enumerate()
            .any(|(i, s)| i != slot && !s.preempted && s.cache.pages_held() > 0);
        if !forced && !others_hold_pages {
            self.running[slot].overflowed = true;
            return;
        }
        let seq = &mut self.running[slot];
        seq.preempted = true;
        // park the prompt pages in the tree first (refcount bumps keep
        // them alive past the release): the victim's own resume is the
        // likeliest next adopter
        Self::donate_prompt_to(&mut self.prefix, &self.pool, &seq.request.prompt, &seq.cache);
        seq.cache.reset(); // pages back to the store, this step
    }

    /// Donate the sequence's fully-committed, page-aligned prompt pages
    /// to the prefix tree (refcount bumps — the pages stay live after
    /// the cache releases them). Called at retirement *and* preemption:
    /// a victim's donated prompt is what makes its recompute cheap.
    fn donate_prompt(&mut self, s: &SequenceState) {
        Self::donate_prompt_to(&mut self.prefix, &self.pool, &s.request.prompt, &s.cache);
    }

    /// [`ServeEngine::donate_prompt`] body as an associated fn over
    /// split borrows, so `mark_preempt` can donate while holding the
    /// victim's slot mutably.
    fn donate_prompt_to(
        prefix: &mut Option<PrefixCache>,
        pool: &KvPool,
        prompt: &[u32],
        cache: &KvCache,
    ) {
        let Some(pc) = prefix.as_mut() else { return };
        if !pool.store().ptr_eq(cache.store()) {
            return; // foreign cache (tests inject these) — not ours to park
        }
        let ps = pool.page_size();
        let n = (prompt.len().min(cache.len()) / ps) * ps;
        if n == 0 {
            return;
        }
        pc.insert(&prompt[..n], cache.shared_pages(n));
    }

    /// One engine iteration returning only completed [`Response`]s —
    /// a thin adapter over [`ServeEngine::step_events`] that drops the
    /// per-token stream. Every pre-streaming caller keeps working
    /// through this wrapper unchanged.
    pub fn step(&mut self) -> Vec<Response> {
        let mut events = Vec::new();
        self.step_events(&mut events);
        events
            .into_iter()
            .filter_map(|ev| match ev {
                ServerEvent::Done(resp) => Some(resp),
                ServerEvent::Token { .. } | ServerEvent::ReplicaDown { .. } => None,
            })
            .collect()
    }

    /// One engine iteration: sweep lapsed lifetimes, admit, plan, fuse
    /// all planned prefill chunks + decode rows (one committed token
    /// per decoding sequence, plus its speculative draft rows when
    /// spec-decode is on) into **one** [`ForwardBatch`], execute it
    /// with a single model pass, verify drafts and scatter the logits
    /// back, retire finished sequences. Events — one `Token` per
    /// committed token, one `Done` per finished sequence — are
    /// appended to `out` in emission order; see [`ServerEvent`] for
    /// the stream-equals-final-tokens guarantee.
    ///
    /// Produces token-for-token the same per-sequence output as
    /// stepping each sequence alone (`max_running == 1`): the batched
    /// model path is bit-identical per row to sequential decoding.
    pub fn step_events(&mut self, out: &mut Vec<ServerEvent>) {
        let step = self.steps;
        self.steps += 1;
        if let Some(inj) = &self.faults {
            match inj.fire_step(step) {
                Some(FaultKind::Panic) => {
                    panic!("injected fault: panic (step {step})")
                }
                Some(FaultKind::PagesExhausted) => self.force_exhaust = true,
                Some(FaultKind::SlowStepMs(ms)) => {
                    std::thread::sleep(std::time::Duration::from_millis(ms))
                }
                Some(FaultKind::CkptIoError) | None => {}
            }
        }
        self.sweep_lifecycle(out);
        self.admit(out);
        let slots: Vec<(bool, usize, bool)> = self
            .running
            .iter()
            .map(|s| (s.in_prefill(), s.remaining_prompt(), s.pending_logits.is_some()))
            .collect();
        let plan = plan_step(&self.policy, &slots);

        // --- phase 1: build the fused batch (slot-ascending order so
        // rows per sequence stay contiguous) and sample continuations
        // from last step's pending logits
        let mut prefill_take = vec![0usize; self.running.len()];
        for &(slot, take) in &plan.prefill {
            prefill_take[slot] = take;
        }
        let mut decode_slot = vec![false; self.running.len()];
        for &slot in &plan.decode {
            decode_slot[slot] = true;
        }
        self.batch.clear();
        self.batch
            .reserve(plan.batch_rows_with_drafts(self.spec.map_or(0, |o| o.k)));
        self.logit_slots.clear();
        // cache index per participating slot, assigned in slot order
        let mut participates = vec![false; self.running.len()];
        let mut n_caches = 0usize;
        for slot in 0..self.running.len() {
            let mut take = prefill_take[slot];
            if take > 0 {
                // defensive capacity clamp: the KV cache surfaces a
                // recoverable full signal (`remaining`), so a
                // planner/capacity disagreement — e.g. a request
                // admitted past capacity by a buggy scheduler — fails
                // this request with CacheOverflow instead of hitting
                // the append panic and killing the replica
                take = take.min(self.running[slot].cache.remaining());
                if take == 0 {
                    self.running[slot].overflowed = true;
                    continue;
                }
                // reserve pages up front so the appends inside the
                // fused pass can never fail; exhaustion here means
                // preemption, decided before any row is built
                if !self.try_reserve(slot, take) {
                    self.mark_preempt(slot, self.force_exhaust);
                    continue;
                }
                let seq = &mut self.running[slot];
                let ci = n_caches;
                n_caches += 1;
                participates[slot] = true;
                let base = seq.cache.len();
                for j in 0..take {
                    let tok = seq.prefill_token(seq.prefill_cursor);
                    seq.prefill_cursor += 1;
                    // prefill fully consumed ⇒ this row's logits predict
                    // the next (for resumed sequences: the first token
                    // *after* the recomputed generation)
                    let need = !seq.in_prefill();
                    if need {
                        self.logit_slots.push(slot);
                    }
                    self.batch.push(tok, base + j, ci, need);
                }
                self.metrics.prefill_tokens += take as u64;
            } else if decode_slot[slot] {
                let cache_full = {
                    let c = &self.running[slot].cache;
                    c.len() + 1 >= c.max_seq
                };
                // --- speculative planning (greedy sequences only).
                // Greedy sampling is a pure argmax, so this step's
                // committed token can be *peeked* with no RNG or
                // accounting side effects; the speculator then drafts
                // up to k continuation tokens from prompt ++ generated
                // ++ peeked, which ride the fused pass as extra rows
                // for this cache and are verified in phase 3.
                // Temperature sequences fall back to plain decode —
                // their per-step RNG stays keyed to committed tokens
                // only, so preemption replay is untouched.
                self.spec_buf.clear();
                if !cache_full {
                    if let Some(opts) = self.spec {
                        let seq = &self.running[slot];
                        if seq.request.params.temperature <= 0.0 && seq.budget_left() > 1 {
                            let logits = seq
                                .pending_logits
                                .as_deref()
                                .expect("planned decode without logits");
                            let peek = argmax(logits);
                            if Some(peek) != seq.request.params.stop_token {
                                // a draft at position len+1+j must fit
                                // under max_seq, and at most
                                // budget_left - 1 drafts can ever be
                                // committed after the peeked token
                                let cap = (seq.budget_left() - 1)
                                    .min(seq.cache.max_seq - seq.cache.len() - 1);
                                if cap > 0 {
                                    self.spec_ctx.clear();
                                    self.spec_ctx.extend_from_slice(&seq.request.prompt);
                                    self.spec_ctx.extend_from_slice(&seq.generated);
                                    self.spec_ctx.push(peek);
                                    opts.draft(&self.spec_ctx, cap, &mut self.spec_buf);
                                }
                            }
                        }
                    }
                }
                // a continuation row needs one reserved position; when
                // the position ceiling already ends the sequence there
                // is nothing to reserve. Draft rows reserve on top of
                // it, but their exhaustion is not preemption-worthy:
                // drop the drafts and retry the plain single row, so
                // speculation can never preempt a sequence plain
                // decode would have advanced (the liveness argument in
                // mark_preempt is unchanged). Preempt *before*
                // sampling: the pending logits die with the victim,
                // and the resumed recompute regenerates them bitwise
                // before sampling the same token (the per-step RNG is
                // keyed by generated.len(), unchanged by preemption).
                if !cache_full
                    && !self.spec_buf.is_empty()
                    && !self.try_reserve(slot, 1 + self.spec_buf.len())
                {
                    self.spec_buf.clear();
                }
                if !cache_full && !self.try_reserve(slot, 1) {
                    self.mark_preempt(slot, self.force_exhaust);
                    continue;
                }
                let seq = &mut self.running[slot];
                let logits = seq.pending_logits.take().expect("planned decode without logits");
                let next = sample(&logits, &seq.request.params, seq.generated.len(), &mut self.prob_buf);
                self.logit_pool.push(logits); // recycle the allocation
                if seq.first_token_at.is_none() {
                    seq.first_token_at = Some(std::time::Instant::now());
                }
                seq.generated.push(next);
                self.metrics.decode_tokens += 1;
                let stop = Some(next) == seq.request.params.stop_token;
                // a matched stop token never reaches the wire — the
                // retirement below pops it from Response::tokens too,
                // keeping stream == final tokens exactly
                if !stop {
                    out.push(ServerEvent::Token {
                        id: seq.request.id,
                        sample: seq.request.sample,
                        token: next,
                        index: seq.generated.len() - 1,
                    });
                }
                let out_of_budget = seq.budget_left() == 0;
                if !(stop || out_of_budget || cache_full) {
                    let ci = n_caches;
                    n_caches += 1;
                    participates[slot] = true;
                    self.logit_slots.push(slot);
                    let base = seq.cache.len();
                    self.batch.push(next, base, ci, true);
                    // draft rows: same cache, consecutive positions —
                    // exactly the row shape a prefill chunk already
                    // has, so the model pass needs no new machinery
                    for (j, &d) in self.spec_buf.iter().enumerate() {
                        self.logit_slots.push(slot);
                        self.batch.push(d, base + 1 + j, ci, true);
                    }
                    debug_assert!(seq.spec_drafts.is_empty(), "drafts are step-transient");
                    seq.spec_drafts.extend_from_slice(&self.spec_buf);
                    self.metrics.spec_drafted += self.spec_buf.len() as u64;
                }
                // else: finished; pending_logits stays None, retired
                // below. The speculative clamps (budget > 1, peek !=
                // stop, cache not full) guarantee spec_buf is empty on
                // this path — a terminal token never carries drafts.
            }
        }

        // --- phase 2: one fused model pass over the whole stack
        if !self.batch.is_empty() {
            let model = &self.model;
            let batch = &self.batch;
            let mut caches: Vec<&mut KvCache> = Vec::with_capacity(n_caches);
            for (slot, seq) in self.running.iter_mut().enumerate() {
                if participates[slot] {
                    caches.push(&mut seq.cache);
                }
            }
            debug_assert_eq!(caches.len(), n_caches);
            let n_logits = model.forward_batch(batch, &mut caches, &mut self.scratch);
            debug_assert_eq!(n_logits, self.logit_slots.len());

            // --- phase 3: verify draft rows, then scatter logits back.
            // A slot's logit rows are consecutive (phase 1 pushes them
            // together): its committed token's row first, then one row
            // per draft. Plain slots hold exactly one row.
            let mut li = 0usize;
            while li < self.logit_slots.len() {
                let slot = self.logit_slots[li];
                if self.running[slot].spec_drafts.is_empty() {
                    let mut buf = self.logit_pool.pop().unwrap_or_default();
                    buf.clear();
                    buf.extend_from_slice(self.scratch.logits.row(li));
                    self.running[slot].pending_logits = Some(buf);
                    li += 1;
                } else {
                    li += self.verify_drafts(slot, li, out);
                }
            }
        }

        // --- phase 3½: fan out `n > 1` requests whose prompt just
        // finished prefilling. The prompt was computed once; each of
        // the n-1 forks shares its pages copy-on-write
        // (`KvCache::fork`), clones the prompt logits, and decodes as
        // an independent sequence under a per-sample derived seed.
        // The primary's `n` drops to 1 so a later preemption-resume
        // cycle can never fan out a second time.
        let mut forks: Vec<SequenceState> = Vec::new();
        for s in self.running.iter_mut() {
            let n = s.request.params.n;
            // preempted/overflowed slots released their pages already —
            // never fork a reset cache
            if n <= 1
                || s.preempted
                || s.overflowed
                || s.in_prefill()
                || !s.generated.is_empty()
                || s.pending_logits.is_none()
            {
                continue;
            }
            for k in 1..n {
                let mut request = s.request.clone();
                request.sample = k;
                request.params = s.request.params.for_sample(k);
                let mut fork = SequenceState::new(request, s.cache.fork());
                fork.prefill_cursor = fork.prefill_len; // prompt is in the forked cache
                fork.pending_logits = s.pending_logits.clone();
                forks.push(fork);
            }
            s.request.params = s.request.params.for_sample(0); // keep seed, n → 1
        }
        for fork in forks {
            self.pool.register_fork();
            self.running.push(fork);
        }

        // --- retire preempted + finished
        let mut i = 0;
        while i < self.running.len() {
            if self.running[i].preempted {
                let mut s = self.running.swap_remove(i);
                if let Some(buf) = s.pending_logits.take() {
                    self.logit_pool.push(buf);
                }
                // pages were donated + released eagerly at mark_preempt
                // time; only the page-less cache handle returns here
                debug_assert_eq!(s.cache.pages_held(), 0);
                self.pool.release(s.cache);
                self.metrics.preemptions += 1;
                self.preempted_q.push_back(PreemptedSeq {
                    request: s.request,
                    generated: s.generated,
                    first_token_at: s.first_token_at,
                });
                continue;
            }
            let finished = {
                let s = &self.running[i];
                s.overflowed || (!s.in_prefill() && s.pending_logits.is_none())
            };
            if finished {
                let s = self.running.swap_remove(i);
                self.donate_prompt(&s);
                self.pool.release(s.cache);
                let last = s.generated.last().copied();
                let stop_hit = last.is_some() && last == s.request.params.stop_token;
                let mut tokens = s.generated;
                if stop_hit {
                    tokens.pop();
                }
                let finish = if s.overflowed {
                    FinishReason::CacheOverflow
                } else if stop_hit {
                    FinishReason::Stop
                } else {
                    FinishReason::Length
                };
                let resp = Response {
                    id: s.request.id,
                    sample: s.request.sample,
                    ttft: s
                        .first_token_at
                        .map(|t| t - s.request.submitted_at)
                        .unwrap_or_default(),
                    total: s.request.submitted_at.elapsed(),
                    prompt_len: s.request.prompt.len(),
                    tokens,
                    finish,
                };
                self.metrics.record_response(&resp);
                self.note_request_retired(&s.request, finish);
                out.push(ServerEvent::Done(resp));
            } else {
                i += 1;
            }
        }

        // an injected exhaustion lasts exactly one step
        self.force_exhaust = false;

        // --- refresh pool + queue gauges for the serve-log summary
        let ps = self.pool.stats();
        self.metrics.pages_in_use = ps.live;
        self.metrics.pages_free = ps.free;
        self.metrics.pages_peak = ps.peak_live;
        self.metrics.page_budget = ps.budget.unwrap_or(0);
        self.metrics.cow_pages = ps.cow_pages;
        self.metrics.queue_depth = self.waiting.len();
    }

    /// Phase-3 speculative verify for `slot`, whose logit rows start
    /// at `li`: row `li` belongs to the token committed in phase 1,
    /// row `li + j` to draft `j`. Walks the deterministic greedy-accept
    /// rule — commit the longest draft prefix where the model's own
    /// argmax equals the draft — then truncates the KV cache back to
    /// the last committed position, releasing rejected and over-
    /// reserved pages to the store. Returns the logit rows consumed.
    ///
    /// Parity argument (DESIGN.md §Speculative-Decoding): row `li + j`
    /// was computed from the same tokens at the same positions over
    /// the same cache prefix a plain decode step would have used —
    /// causal attention means later draft rows never influence earlier
    /// ones — and `forward_batch` is bit-identical per row to
    /// single-row decode. So `argmax(row li + j)` *is* the token plain
    /// greedy decode would sample next, the accepted prefix is exactly
    /// the plain token stream, and after `truncate` the cache holds
    /// exactly what a plain step sequence would have built. The
    /// stop/budget/position checks mirror the plain continuation rule
    /// token-for-token, so termination matches too.
    fn verify_drafts(&mut self, slot: usize, li: usize, out: &mut Vec<ServerEvent>) -> usize {
        let mut drafts = std::mem::take(&mut self.running[slot].spec_drafts);
        let n_rows = 1 + drafts.len();
        debug_assert!(self.logit_slots[li..li + n_rows].iter().all(|&s| s == slot));
        // committed KV length before this step's rows were appended
        let base = self.running[slot].cache.len() - n_rows;
        let mut accepted = 0usize;
        let mut terminated = false;
        loop {
            let seq = &self.running[slot];
            let last = *seq.generated.last().expect("phase 1 committed a token");
            // mirror plain decode's continuation rule for `last`: a
            // stop token, an exhausted budget, or the position ceiling
            // each end the sequence exactly where plain decode would
            // (phase 1 pre-checked all three for the first token)
            if Some(last) == seq.request.params.stop_token
                || seq.budget_left() == 0
                || base + 1 + accepted >= seq.cache.max_seq
            {
                terminated = true;
                break;
            }
            if accepted == drafts.len() {
                break;
            }
            // the model's own next token after everything committed so
            // far; the first mismatch rejects the rest of the draft
            let next = argmax(self.scratch.logits.row(li + accepted));
            if next != drafts[accepted] {
                break;
            }
            let seq = &mut self.running[slot];
            seq.generated.push(next);
            accepted += 1;
            self.metrics.decode_tokens += 1;
            self.metrics.spec_accepted += 1;
            // same wire rule as phase 1: a matched stop token is never
            // emitted (retirement pops it from Response::tokens too)
            if Some(next) != seq.request.params.stop_token {
                out.push(ServerEvent::Token {
                    id: seq.request.id,
                    sample: seq.request.sample,
                    token: next,
                    index: seq.generated.len() - 1,
                });
            }
        }
        // rollback: keep the committed rows, return every page past
        // them — rejected draft positions and over-reserved pages alike
        let seq = &mut self.running[slot];
        let keep = base + 1 + accepted;
        let before = seq.cache.pages_held();
        seq.cache.truncate(keep);
        self.metrics.spec_rollback_pages += (before - seq.cache.pages_held()) as u64;
        if !terminated {
            // the last committed row's logits seed the next step's
            // sampling, exactly as a plain step's single row would
            let mut buf = self.logit_pool.pop().unwrap_or_default();
            buf.clear();
            buf.extend_from_slice(self.scratch.logits.row(li + accepted));
            self.running[slot].pending_logits = Some(buf);
        }
        // else: pending_logits stays None ⇒ the retirement sweep below
        // finishes the sequence (Stop / Length), as plain decode would
        drafts.clear();
        self.running[slot].spec_drafts = drafts; // hand the buffer back
        n_rows
    }

    /// Drive until every submitted request completes (test/batch mode).
    pub fn run_to_completion(&mut self) -> Vec<Response> {
        let mut out = Vec::new();
        let mut guard = 0usize;
        while self.pending() > 0 {
            out.extend(self.step());
            guard += 1;
            assert!(guard < 1_000_000, "engine livelock");
        }
        out
    }
}

/// Greedy or temperature sampling. `probs` is caller-owned scratch so
/// the decode hot loop allocates nothing.
fn sample(
    logits: &[f32],
    params: &super::request::SamplingParams,
    step: usize,
    probs: &mut Vec<f32>,
) -> u32 {
    if params.temperature <= 0.0 {
        return argmax(logits);
    }
    let mut rng = Rng::new(params.seed ^ (step as u64).wrapping_mul(0x9E3779B97F4A7C15));
    let inv_t = 1.0 / params.temperature;
    probs.clear();
    probs.extend(logits.iter().map(|&x| x * inv_t));
    crate::tensor::ops::softmax_inplace(probs);
    rng.weighted(probs) as u32
}

/// Deterministic argmax, first maximum winning — the single source of
/// truth for greedy token choice: [`sample`]'s greedy branch, the
/// speculative peek, and the draft-verify accept rule all call this,
/// which is what makes speculative output bit-identical to plain
/// greedy decode by construction.
fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &x) in logits.iter().enumerate() {
        if x > best_v {
            best_v = x;
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SamplingParams;
    use crate::model::ModelConfig;

    fn engine(max_running: usize) -> ServeEngine {
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = 32;
        cfg.max_seq = 48;
        let mut rng = Rng::new(11);
        let model = Transformer::random(cfg, &mut rng);
        ServeEngine::new(
            model,
            BatchPolicy {
                max_running,
                prefill_token_budget: 8,
                fcfs_prefill: true,
            },
        )
    }

    fn req(id: u64, prompt: Vec<u32>, max_new: usize) -> Request {
        Request::new(
            id,
            prompt,
            SamplingParams {
                max_new_tokens: max_new,
                stop_token: None,
                ..Default::default()
            },
        )
    }

    #[test]
    fn single_request_completes() {
        let mut e = engine(4);
        e.submit(req(1, vec![1, 2, 3], 5));
        let out = e.run_to_completion();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens.len(), 5);
        assert_eq!(out[0].finish, FinishReason::Length);
    }

    #[test]
    fn batched_requests_all_complete() {
        let mut e = engine(4);
        for i in 0..10 {
            e.submit(req(i, vec![1 + (i as u32 % 5), 2, 3], 4));
        }
        let out = e.run_to_completion();
        assert_eq!(out.len(), 10);
        let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn batched_output_matches_sequential() {
        // continuous batching must not change per-sequence results
        let mut e1 = engine(4);
        e1.submit(req(1, vec![3, 4], 6));
        e1.submit(req(2, vec![7, 8, 9], 6));
        let mut out_batched = e1.run_to_completion();
        out_batched.sort_by_key(|r| r.id);

        let mut e2 = engine(1); // forces sequential
        e2.submit(req(1, vec![3, 4], 6));
        e2.submit(req(2, vec![7, 8, 9], 6));
        let mut out_seq = e2.run_to_completion();
        out_seq.sort_by_key(|r| r.id);

        for (a, b) in out_batched.iter().zip(&out_seq) {
            assert_eq!(a.tokens, b.tokens, "req {}", a.id);
        }
    }

    #[test]
    fn batched_output_matches_sequential_quantized_ragged() {
        // fused path over ternary kernels with G % 4 != 0 (ragged
        // packing) must still be token-for-token identical
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = 32;
        cfg.max_seq = 48;
        let mut rng = Rng::new(21);
        let mut model = Transformer::random(cfg, &mut rng);
        model.quantize_with(
            crate::quant::by_name("ptqtp", 10).unwrap().as_ref(),
            &crate::quant::QuantCtx::default(),
        );
        let policy = |max_running| BatchPolicy {
            max_running,
            prefill_token_budget: 5,
            fcfs_prefill: true,
        };
        let submit = |e: &mut ServeEngine| {
            e.submit(req(1, vec![3, 4, 9, 2, 8, 1, 7], 5));
            e.submit(req(2, vec![7, 8], 6));
            e.submit(req(3, vec![1, 2, 3, 4], 4));
        };
        let mut e1 = ServeEngine::new(model.clone(), policy(4));
        submit(&mut e1);
        let mut out_batched = e1.run_to_completion();
        out_batched.sort_by_key(|r| r.id);
        let mut e2 = ServeEngine::new(model, policy(1));
        submit(&mut e2);
        let mut out_seq = e2.run_to_completion();
        out_seq.sort_by_key(|r| r.id);
        for (a, b) in out_batched.iter().zip(&out_seq) {
            assert_eq!(a.tokens, b.tokens, "req {}", a.id);
        }
    }

    #[test]
    fn threaded_engine_matches_sequential_token_for_token() {
        // the §Threading determinism claim end-to-end: same model, same
        // workload, thread counts {1, 2, 4} — identical tokens through
        // ServeEngine::step, greedy and seeded-temperature, quantized
        // with a ragged group so both kernel tiers are exercised
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = 32;
        cfg.max_seq = 48;
        let mut rng = Rng::new(31);
        let mut model = Transformer::random(cfg, &mut rng);
        model.quantize_with(
            crate::quant::by_name("ptqtp", 10).unwrap().as_ref(),
            &crate::quant::QuantCtx::default(),
        );
        let run = |threads: usize| {
            let mut e = ServeEngine::with_threads(
                model.clone(),
                BatchPolicy {
                    max_running: 3,
                    prefill_token_budget: 6,
                    fcfs_prefill: true,
                },
                threads,
            );
            assert_eq!(e.threads(), threads.max(1));
            for i in 0..5u64 {
                let mut r = req(i, vec![1 + i as u32, 4, 7, 2], 5);
                if i % 2 == 1 {
                    r.params.temperature = 0.7;
                    r.params.seed = 11 + i;
                }
                e.submit(r);
            }
            let mut out = e.run_to_completion();
            out.sort_by_key(|r| r.id);
            out
        };
        let seq = run(1);
        for threads in [2usize, 4] {
            let par = run(threads);
            for (a, b) in par.iter().zip(&seq) {
                assert_eq!(a.tokens, b.tokens, "threads={threads} req {}", a.id);
            }
        }
    }

    #[test]
    fn fused_step_counts_one_model_pass_of_logits() {
        // a step with 2 decoding seqs + 1 prefilling seq builds one
        // batch; pending logits appear for exactly the right slots
        let mut e = engine(4);
        e.submit(req(1, vec![1, 2], 8));
        e.submit(req(2, vec![3], 8));
        e.step(); // admits + prefills (budget 8 covers both prompts)
        assert_eq!(e.running(), 2);
        e.submit(req(3, vec![4, 5, 6], 8));
        let before = e.metrics.decode_tokens;
        e.step(); // decodes seq 1+2, prefills seq 3, in one fused batch
        assert_eq!(e.metrics.decode_tokens - before, 2);
        assert_eq!(e.metrics.prefill_tokens, 2 + 1 + 3);
    }

    #[test]
    fn temperature_sampling_parity_across_batching() {
        // seeded temperature sampling is deterministic given logits, so
        // fused batching must not change sampled tokens either
        let mk = |max_running| {
            let mut e = engine(max_running);
            for i in 0..4 {
                let mut r = req(i, vec![1 + i as u32, 2, 5], 5);
                r.params.temperature = 0.8;
                r.params.seed = 42 + i;
                e.submit(r);
            }
            let mut out = e.run_to_completion();
            out.sort_by_key(|r| r.id);
            out
        };
        let a = mk(4);
        let b = mk(1);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens, "req {}", x.id);
        }
    }

    #[test]
    fn undersized_cache_fails_per_request_not_replica() {
        // regression: a sequence whose KV cache is smaller than its
        // prompt (simulating a scheduler/capacity bug — admission
        // normally prevents this) used to die in KvCache::append's
        // overflow panic, taking the whole replica down. The engine now
        // clamps prefill to the cache's remaining capacity and retires
        // the request with CacheOverflow.
        use crate::coordinator::request::SequenceState;
        use crate::model::KvCache;
        let mut e = engine(2);
        e.submit(req(1, vec![1, 2], 3)); // a healthy request rides along
        // a cache with room for only 3 positions, against a 6-token prompt
        let cfg = &e.model.config;
        let small = KvCache::new(cfg.n_layers, cfg.n_kv_heads, cfg.head_dim(), 3);
        // account the foreign cache so the pool's release bookkeeping
        // stays balanced when the doomed sequence retires
        let _placeholder = e.pool.acquire().expect("pool has capacity");
        e.running.push(SequenceState::new(req(7, vec![1, 2, 3, 4, 5, 6], 4), small));
        let mut out = e.run_to_completion();
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].id, 1);
        assert_eq!(out[0].finish, FinishReason::Length);
        assert_eq!(out[0].tokens.len(), 3, "healthy request unaffected");
        assert_eq!(out[1].id, 7);
        assert_eq!(out[1].finish, FinishReason::CacheOverflow);
        assert!(out[1].tokens.is_empty(), "prompt never finished prefill");
        assert_eq!(e.running(), 0, "replica still alive and drained");
    }

    #[test]
    fn forced_preemption_completes_with_identical_output() {
        // ISSUE 6 acceptance: a page budget too small for the full
        // batch forces ≥1 preemption, yet every request completes with
        // output identical to the unconstrained run
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = 32;
        cfg.max_seq = 48;
        let mut rng = Rng::new(41);
        let model = Transformer::random(cfg, &mut rng);
        let policy = BatchPolicy {
            max_running: 3,
            prefill_token_budget: 16,
            fcfs_prefill: true,
        };
        let submit = |e: &mut ServeEngine| {
            for i in 0..6u64 {
                // 10-token prompts + 8 generated ⇒ 18 positions ⇒ 3
                // pages of 8 per sequence at full length
                let prompt: Vec<u32> = (0..10).map(|j| 1 + ((i as u32 + j) % 30)).collect();
                e.submit(req(i, prompt, 8));
            }
        };
        let mut reference = ServeEngine::with_threads(model.clone(), policy, 1);
        submit(&mut reference);
        let mut want = reference.run_to_completion();
        want.sort_by_key(|r| r.id);

        // 4 pages shared by 3 running sequences needing up to 3 each
        let kv = PagedKvOpts {
            page_size: 8,
            prefix_cache: true,
            page_budget: Some(4),
        };
        let mut tight = ServeEngine::with_opts(model, policy, 1, kv);
        submit(&mut tight);
        let mut got = tight.run_to_completion();
        got.sort_by_key(|r| r.id);

        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.id, w.id);
            assert_eq!(g.tokens, w.tokens, "req {} differs after preemption", g.id);
            assert_eq!(g.finish, w.finish, "req {}", g.id);
        }
        assert!(
            tight.metrics.preemptions > 0,
            "budget of 4 pages must force at least one preemption"
        );
        assert_eq!(tight.running(), 0);
        assert_eq!(tight.pool.outstanding(), 0);
    }

    /// A prompt containing the bigram `[x, t]` for every `t` in
    /// `0..vocab` (and ending in `x`), so the prompt-lookup drafter is
    /// *guaranteed* to fire at the first decode planning no matter
    /// which token the model peeks — whatever `t1 = argmax` turns out
    /// to be, the suffix anchor `[x, t1]` has an earlier occurrence.
    /// Speculation-activity asserts built on these prompts cannot
    /// flake on model behavior.
    fn bigram_complete_prompt(x: u32, vocab: u32) -> Vec<u32> {
        let mut p = Vec::with_capacity(2 * vocab as usize + 1);
        for t in 0..vocab {
            p.push(x);
            p.push(t);
        }
        p.push(x);
        p
    }

    /// Tiny quantized (ragged-group) model over a 12-token vocab —
    /// small enough that `bigram_complete_prompt` fits well inside
    /// `max_seq` with decode room to spare.
    fn spec_model(seed: u64) -> Transformer {
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = 12;
        cfg.max_seq = 48;
        let mut rng = Rng::new(seed);
        let mut model = Transformer::random(cfg, &mut rng);
        model.quantize_with(
            crate::quant::by_name("ptqtp", 10).unwrap().as_ref(),
            &crate::quant::QuantCtx::default(),
        );
        model
    }

    #[test]
    fn speculative_greedy_matches_plain_decode() {
        // tentpole parity: prompt-lookup speculation must be invisible
        // in the output — same tokens, same finish — while actually
        // drafting (the bigram-complete prompts make the first draft
        // unconditional, so the activity assert is deterministic)
        let model = spec_model(61);
        let policy = BatchPolicy {
            max_running: 3,
            prefill_token_budget: 16,
            fcfs_prefill: true,
        };
        let submit = |e: &mut ServeEngine| {
            for (i, x) in [3u32, 5, 7].into_iter().enumerate() {
                e.submit(req(i as u64, bigram_complete_prompt(x, 12), 10));
            }
        };
        let mut plain = ServeEngine::with_threads(model.clone(), policy, 1);
        submit(&mut plain);
        let mut want = plain.run_to_completion();
        want.sort_by_key(|r| r.id);
        assert_eq!(plain.metrics.spec_drafted, 0, "spec off ⇒ no drafting");

        for spec_k in [1usize, 4] {
            let mut e = ServeEngine::with_threads(model.clone(), policy, 1);
            e.set_spec_decode(Some(SpecDecodeOpts::default().with_k(spec_k)));
            submit(&mut e);
            let mut got = e.run_to_completion();
            got.sort_by_key(|r| r.id);
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.tokens, w.tokens, "k={spec_k} req {}", g.id);
                assert_eq!(g.finish, w.finish, "k={spec_k} req {}", g.id);
            }
            assert!(e.metrics.spec_drafted > 0, "k={spec_k}: speculation never fired");
            assert!(
                e.metrics.spec_accepted <= e.metrics.spec_drafted,
                "accounting: accepted {} > drafted {}",
                e.metrics.spec_accepted,
                e.metrics.spec_drafted
            );
            assert_eq!(e.running(), 0);
        }
    }

    #[test]
    fn speculative_temperature_falls_back_and_matches() {
        // temperature sampling is not greedy-verifiable, so a spec
        // engine must take the plain path for those sequences: zero
        // drafts, identical sampled tokens
        let mk = |spec: Option<SpecDecodeOpts>| {
            let mut e = engine(4);
            e.set_spec_decode(spec);
            for i in 0..4u64 {
                let mut r = req(i, bigram_complete_prompt(2 + i as u32, 12), 6);
                r.params.temperature = 0.8;
                r.params.seed = 91 + i;
                e.submit(r);
            }
            let mut out = e.run_to_completion();
            out.sort_by_key(|r| r.id);
            (out, e.metrics.spec_drafted)
        };
        let (want, _) = mk(None);
        let (got, drafted) = mk(Some(SpecDecodeOpts::default()));
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.tokens, w.tokens, "req {}", g.id);
        }
        assert_eq!(drafted, 0, "temperature sequences must never draft");
    }

    #[test]
    fn speculative_stop_token_inside_draft_burst() {
        // the verify loop must cut a committed burst at the stop token
        // exactly where plain decode would — probe the model's greedy
        // continuation first, then pin a mid-stream token as the stop
        let model = spec_model(67);
        let policy = BatchPolicy {
            max_running: 2,
            prefill_token_budget: 32,
            fcfs_prefill: true,
        };
        let prompt = bigram_complete_prompt(4, 12);
        let mut probe = ServeEngine::with_threads(model.clone(), policy, 1);
        probe.submit(req(1, prompt.clone(), 8));
        let g = probe.run_to_completion().remove(0).tokens;
        assert_eq!(g.len(), 8, "probe ran to its budget");
        let stop = g[3];

        let run = |spec: Option<SpecDecodeOpts>| {
            let mut e = ServeEngine::with_threads(model.clone(), policy, 1);
            e.set_spec_decode(spec);
            let mut r = req(1, prompt.clone(), 8);
            r.params.stop_token = Some(stop);
            e.submit(r);
            e.run_to_completion().remove(0)
        };
        let want = run(None);
        let got = run(Some(SpecDecodeOpts::default()));
        assert_eq!(want.finish, FinishReason::Stop, "stop drawn from the probe must hit");
        assert_eq!(got.finish, want.finish);
        assert_eq!(got.tokens, want.tokens, "stop-cut burst drifted from plain decode");
        assert!(!got.tokens.contains(&stop), "matched stop is never emitted");
    }

    #[test]
    fn forced_preemption_mid_speculation_identical_output() {
        // ISSUE 9 satellite: recompute-preemption and speculation
        // compose — a page budget too small for the batch preempts
        // sequences between (never inside) steps, drafts are strictly
        // step-transient, and replay re-drafts from committed tokens
        // only, so output still matches an unconstrained plain run
        let model = spec_model(71);
        let policy = BatchPolicy {
            max_running: 3,
            prefill_token_budget: 16,
            fcfs_prefill: true,
        };
        let submit = |e: &mut ServeEngine| {
            for i in 0..6u64 {
                // distinct first token ⇒ no prefix sharing: 25-token
                // prompt + 8 new = 33 positions = 5 pages of 8, so a
                // 6-page budget can only ever run one sequence at a
                // time and must preempt the rest
                e.submit(req(i, bigram_complete_prompt(1 + i as u32, 12), 8));
            }
        };
        let mut reference = ServeEngine::with_threads(model.clone(), policy, 1);
        submit(&mut reference);
        let mut want = reference.run_to_completion();
        want.sort_by_key(|r| r.id);

        let kv = PagedKvOpts {
            page_size: 8,
            prefix_cache: true,
            page_budget: Some(6),
        };
        let mut tight = ServeEngine::with_opts(model, policy, 1, kv);
        tight.set_spec_decode(Some(SpecDecodeOpts::default()));
        submit(&mut tight);
        let mut got = tight.run_to_completion();
        got.sort_by_key(|r| r.id);

        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.id, w.id);
            assert_eq!(g.tokens, w.tokens, "req {} differs under preemption + spec", g.id);
            assert_eq!(g.finish, w.finish, "req {}", g.id);
        }
        assert!(
            tight.metrics.preemptions > 0,
            "a 6-page budget must force preemption for 5-page sequences"
        );
        assert!(
            tight.metrics.spec_drafted > 0,
            "speculation must stay active under preemption pressure"
        );
        assert_eq!(tight.running(), 0);
        assert_eq!(tight.pool.outstanding(), 0);
    }

    #[test]
    fn lockstep_preemption_under_tight_budget_stays_live() {
        // regression: two sequences whose adopted prefix pages fill the
        // whole budget and which need their next page in the *same*
        // step used to mutually preempt forever — each saw the other
        // (also marked that step) as "holding pages", both re-adopted
        // the same tree-shared (refcount-2, unevictable) pages on
        // resume, and the lone-survivor CacheOverflow fallback never
        // fired. Eager page release at mark_preempt time lets the
        // later slot reserve from the victim's freed pages, so the
        // pair now alternates progress and both complete — with
        // output identical to an unconstrained run.
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = 32;
        cfg.max_seq = 48;
        let mut rng = Rng::new(47);
        let model = Transformer::random(cfg, &mut rng);
        let policy = BatchPolicy {
            max_running: 2,
            prefill_token_budget: 32,
            fcfs_prefill: true,
        };
        // two *distinct* 9-token prompts, 8 new tokens each: at page
        // size 8 every sequence wants 3 pages (positions 0..17), and
        // both cross into page 3 at position 16 in the same step
        let submit = |e: &mut ServeEngine| {
            for i in 0..2u64 {
                let prompt: Vec<u32> = (0..9).map(|j| 1 + ((7 * i as u32 + j) % 30)).collect();
                e.submit(req(i, prompt, 8));
            }
        };
        let mut reference = ServeEngine::with_threads(model.clone(), policy, 1);
        submit(&mut reference);
        let mut want = reference.run_to_completion();
        want.sort_by_key(|r| r.id);

        let kv = PagedKvOpts {
            page_size: 8,
            prefix_cache: true,
            page_budget: Some(4),
        };
        let mut tight = ServeEngine::with_opts(model, policy, 1, kv);
        // cold wave seeds the prefix tree with both prompts' first pages
        submit(&mut tight);
        let mut cold = tight.run_to_completion();
        cold.sort_by_key(|r| r.id);
        // warm wave: both adopt one tree page (refcount 2 ⇒ unevictable
        // while held) + one tail page = 4 live pages, then hit the
        // page-3 wall in lockstep — the reviewed livelock shape
        submit(&mut tight);
        let mut warm = tight.run_to_completion();
        warm.sort_by_key(|r| r.id);

        for wave in [&cold, &warm] {
            assert_eq!(wave.len(), want.len());
            for (g, w) in wave.iter().zip(&want) {
                assert_eq!(g.id, w.id);
                assert_eq!(g.tokens, w.tokens, "req {} differs under preemption", g.id);
                assert_eq!(g.finish, w.finish, "req {}", g.id);
            }
        }
        assert!(
            tight.metrics.preemptions > 0,
            "a 4-page budget must force preemption for 2×3-page sequences"
        );
        assert_eq!(tight.running(), 0);
        assert_eq!(tight.pool.outstanding(), 0);
    }

    #[test]
    fn prefix_adoption_skips_prefill_compute() {
        // two waves of the same prompt: the second adopts the donated
        // prompt pages and prefills only the tail — with identical
        // tokens (the adopted pages are the same physical bytes)
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = 32;
        cfg.max_seq = 48;
        let mut rng = Rng::new(43);
        let model = Transformer::random(cfg, &mut rng);
        let policy = BatchPolicy {
            max_running: 2,
            prefill_token_budget: 32,
            fcfs_prefill: true,
        };
        let prompt: Vec<u32> = (0..17).map(|j| 1 + (j % 29)).collect();
        let kv = PagedKvOpts {
            page_size: 4,
            prefix_cache: true,
            page_budget: None,
        };
        let mut e = ServeEngine::with_opts(model.clone(), policy, 1, kv);
        e.submit(req(1, prompt.clone(), 4));
        let cold = e.run_to_completion();
        let cold_prefill = e.metrics.prefill_tokens;
        assert_eq!(e.metrics.adopted_tokens, 0, "nothing cached yet");

        e.submit(req(2, prompt.clone(), 4));
        let warm = e.run_to_completion();
        let warm_prefill = e.metrics.prefill_tokens - cold_prefill;
        // 17-token prompt, page 4 ⇒ 4 pages adopted, 1 token prefilled
        assert_eq!(e.metrics.adopted_tokens, 16);
        assert_eq!(warm_prefill, 1);
        assert_eq!(cold[0].tokens, warm[0].tokens, "adoption must not change output");
        assert_eq!(e.metrics.prefix_hits, 1);
        assert_eq!(e.metrics.prefix_lookups, 2);

        // legacy escape hatch produces the same tokens with no sharing
        let legacy_kv = PagedKvOpts {
            page_size: 48,
            prefix_cache: false,
            page_budget: None,
        };
        let mut l = ServeEngine::with_opts(model, policy, 1, legacy_kv);
        l.submit(req(3, prompt, 4));
        let legacy = l.run_to_completion();
        assert_eq!(legacy[0].tokens, cold[0].tokens);
        assert_eq!(l.metrics.adopted_tokens, 0);
        assert_eq!(l.metrics.prefix_lookups, 0);
    }

    #[test]
    fn fork_sampling_matches_separate_requests() {
        // `--n K`: one prompt prefill + K COW-forked decode streams
        // must produce token-for-token what K separate requests with
        // the per-sample derived params produce — greedy and seeded
        // temperature — while keeping fewer pages live (the K
        // sequences share the prompt's pages by refcount)
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = 32;
        cfg.max_seq = 64;
        let mut rng = Rng::new(53);
        let model = Transformer::random(cfg, &mut rng);
        let policy = BatchPolicy {
            max_running: 4,
            prefill_token_budget: 32,
            fcfs_prefill: true,
        };
        // prefix cache off so the separate-request run can't share
        // prompt pages through the tree — the page comparison below
        // then isolates what forking alone saves
        let kv = PagedKvOpts {
            page_size: 8,
            prefix_cache: false,
            page_budget: None,
        };
        let prompt: Vec<u32> = (0..16).map(|j| 1 + (j % 29)).collect();
        for temperature in [0.0f32, 0.8] {
            let base = SamplingParams {
                temperature,
                max_new_tokens: 4,
                stop_token: None,
                seed: 77,
                n: 1,
            };
            let mut forked = ServeEngine::with_opts(model.clone(), policy, 1, kv);
            forked.submit(Request::new(
                1,
                prompt.clone(),
                SamplingParams { n: 3, ..base },
            ));
            let mut got = forked.run_to_completion();
            got.sort_by_key(|r| r.sample);
            assert_eq!(got.len(), 3, "one response per sample");
            assert_eq!(got[0].id, got[2].id, "samples share the request id");

            let mut separate = ServeEngine::with_opts(model.clone(), policy, 1, kv);
            for k in 0..3usize {
                let mut r = Request::new(10 + k as u64, prompt.clone(), base.for_sample(k));
                r.sample = k;
                separate.submit(r);
            }
            let mut want = separate.run_to_completion();
            want.sort_by_key(|r| r.sample);

            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.sample, w.sample);
                assert_eq!(
                    g.tokens, w.tokens,
                    "sample {} at temperature {temperature}",
                    g.sample
                );
            }
            if temperature > 0.0 {
                assert_ne!(
                    got[1].tokens, got[2].tokens,
                    "derived seeds must decorrelate samples"
                );
            }
            assert!(
                forked.pool.stats().peak_live < separate.pool.stats().peak_live,
                "forks must share prompt pages: {} vs {} live at peak",
                forked.pool.stats().peak_live,
                separate.pool.stats().peak_live
            );
            assert_eq!(forked.pool.outstanding(), 0, "fork accounting balanced");
        }
    }

    #[test]
    fn act_quant_engine_parity_across_threads_and_paging() {
        // the int8-activation tier end-to-end: value-changing vs f32,
        // but its own output must be identical across thread counts
        // and KV layouts (paged + prefix sharing vs contiguous pages)
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = 32;
        cfg.max_seq = 48;
        let mut rng = Rng::new(59);
        let mut model = Transformer::random(cfg, &mut rng);
        model.quantize_with(
            crate::quant::by_name("ptqtp", 8).unwrap().as_ref(),
            &crate::quant::QuantCtx::default(),
        );
        assert!(model.act_quant_layers() > 0, "tier must have eligible layers");
        model.set_act_quant(true);
        let policy = BatchPolicy {
            max_running: 3,
            prefill_token_budget: 8,
            fcfs_prefill: true,
        };
        let run = |threads: usize, kv: PagedKvOpts| {
            let mut e = ServeEngine::with_opts(model.clone(), policy, threads, kv);
            assert!(e.act_quant(), "engine inherits the model's knob");
            for i in 0..4u64 {
                let mut r = req(i, vec![1 + i as u32, 4, 7, 2, 9], 5);
                if i % 2 == 1 {
                    r.params.temperature = 0.7;
                    r.params.seed = 5 + i;
                }
                e.submit(r);
            }
            let mut out = e.run_to_completion();
            out.sort_by_key(|r| r.id);
            out
        };
        let paged = PagedKvOpts {
            page_size: 8,
            prefix_cache: true,
            page_budget: None,
        };
        let contiguous = PagedKvOpts {
            page_size: 48,
            prefix_cache: false,
            page_budget: None,
        };
        let want = run(1, contiguous);
        for threads in [1usize, 2, 4] {
            for kv in [paged, contiguous] {
                let got = run(threads, kv);
                for (g, w) in got.iter().zip(&want) {
                    assert_eq!(
                        g.tokens, w.tokens,
                        "threads={threads} page_size={} req {}",
                        kv.page_size, g.id
                    );
                }
            }
        }
    }

    #[test]
    fn over_long_prompt_rejected() {
        let mut e = engine(2);
        e.submit(req(5, vec![1; 64], 4)); // max_seq = 48
        let out = e.run_to_completion();
        assert_eq!(out[0].finish, FinishReason::PromptTooLong);
        assert!(out[0].tokens.is_empty());
    }

    #[test]
    fn admission_respects_capacity() {
        let mut e = engine(2);
        for i in 0..6 {
            e.submit(req(i, vec![1, 2], 3));
        }
        e.step();
        assert!(e.running() <= 2);
        let out = e.run_to_completion();
        assert_eq!(out.len(), 6);
    }

    #[test]
    fn metrics_accumulate() {
        let mut e = engine(4);
        e.submit(req(1, vec![1, 2, 3, 4], 3));
        let _ = e.run_to_completion();
        assert_eq!(e.metrics.submitted, 1);
        assert_eq!(e.metrics.prefill_tokens, 4);
        assert_eq!(e.metrics.decode_tokens, 3);
        assert_eq!(e.metrics.completed, 1);
    }

    #[test]
    fn stop_token_ends_generation() {
        let mut e = engine(2);
        // find what the model emits first, then set it as stop token
        let probe = {
            let mut cache = e.model.new_cache();
            let logits = e.model.decode_step(1, &mut cache);
            logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as u32
        };
        let mut r = req(9, vec![1], 10);
        r.params.stop_token = Some(probe);
        e.submit(r);
        let out = e.run_to_completion();
        assert_eq!(out[0].finish, FinishReason::Stop);
        assert!(out[0].tokens.is_empty(), "stop on first token");
    }

    #[test]
    fn try_submit_rejects_invalid_params() {
        use crate::coordinator::request::SubmitError;
        let mut e = engine(2);
        let bad = Request::new(1, vec![1, 2], SamplingParams::greedy(0));
        assert_eq!(e.try_submit(bad), Err(SubmitError::ZeroBudget));
        assert_eq!(e.metrics.submitted, 0, "rejected before any accounting");
        assert_eq!(e.pending(), 0);
        let good = Request::new(2, vec![1, 2], SamplingParams::greedy(3));
        assert!(e.try_submit(good).is_ok());
        assert_eq!(e.metrics.queue_depth_peak, 1);
        assert_eq!(e.run_to_completion().len(), 1);
    }

    #[test]
    fn step_events_stream_matches_step_responses() {
        // the adapter contract in miniature: step_events' Token stream
        // concatenated == the Response tokens step() would return,
        // including the popped stop token (see stop_token_ends_generation
        // for how the probe stop is found)
        let mut e = engine(4);
        e.submit(req(1, vec![1, 2, 3], 5));
        let mut r = req(2, vec![4, 5], 7);
        r.params.temperature = 0.7;
        r.params.seed = 13;
        e.submit(r);
        let mut events = Vec::new();
        let mut guard = 0;
        while e.pending() > 0 {
            e.step_events(&mut events);
            guard += 1;
            assert!(guard < 1000);
        }
        let mut streams: std::collections::HashMap<(u64, usize), Vec<u32>> =
            std::collections::HashMap::new();
        let mut dones = 0;
        for ev in &events {
            match ev {
                ServerEvent::Token { id, sample, token, index } => {
                    let s = streams.entry((*id, *sample)).or_default();
                    assert_eq!(*index, s.len(), "indexes contiguous from 0");
                    s.push(*token);
                }
                ServerEvent::Done(resp) => {
                    dones += 1;
                    let s = streams.remove(&(resp.id, resp.sample)).unwrap_or_default();
                    assert_eq!(s, resp.tokens, "stream == final tokens, req {}", resp.id);
                }
                ServerEvent::ReplicaDown { .. } => {
                    panic!("bare engine never emits ReplicaDown")
                }
            }
        }
        assert_eq!(dones, 2);
        assert!(streams.is_empty(), "every stream terminated by a Done");
    }

    #[test]
    fn cancel_before_admission_costs_no_compute() {
        let mut e = engine(2);
        let r = req(1, vec![1, 2, 3], 50);
        let handle = r.handle(0);
        e.submit(r);
        handle.cancel();
        let out = e.run_to_completion();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].finish, FinishReason::Cancelled);
        assert!(out[0].tokens.is_empty());
        assert_eq!(e.metrics.prefill_tokens, 0, "swept before any model pass");
        assert_eq!(e.metrics.cancelled, 1);
        assert_eq!(e.metrics.completed, 0, "not a normal completion");
        use crate::coordinator::request::RequestStatus;
        assert_eq!(handle.try_status(), RequestStatus::Finished);
    }

    #[test]
    fn cancel_mid_decode_keeps_generated_and_frees_pages() {
        let mut e = engine(2);
        let r = req(1, vec![1, 2, 3], 50);
        let handle = r.handle(0);
        e.submit(r);
        let mut events = Vec::new();
        // decode a few tokens, then cancel at a step boundary
        let mut decoded = 0usize;
        let mut guard = 0;
        while decoded < 3 {
            e.step_events(&mut events);
            decoded = events
                .iter()
                .filter(|ev| matches!(ev, ServerEvent::Token { .. }))
                .count();
            guard += 1;
            assert!(guard < 1000);
        }
        assert!(e.page_stats().live > 0, "sequence holds pages mid-decode");
        handle.cancel();
        e.step_events(&mut events);
        let resp = events
            .iter()
            .find_map(|ev| match ev {
                ServerEvent::Done(r) => Some(r.clone()),
                _ => None,
            })
            .expect("cancel retires within one step");
        assert_eq!(resp.finish, FinishReason::Cancelled);
        let stream: Vec<u32> = events
            .iter()
            .filter_map(|ev| match ev {
                ServerEvent::Token { token, .. } => Some(*token),
                _ => None,
            })
            .collect();
        assert_eq!(stream, resp.tokens, "cancel keeps every emitted token");
        assert_eq!(e.page_stats().live, 0, "all pages released eagerly");
        assert_eq!(e.pool.outstanding(), 0);
        assert_eq!(e.metrics.cancelled, 1);
    }

    #[test]
    fn deadline_expires_waiting_requests_under_saturation() {
        // max_running 1 saturates the batcher: the queued requests
        // with a zero deadline expire at the sweep without ever being
        // admitted, while the running request finishes normally
        let mut e = engine(1);
        e.submit(req(1, vec![1, 2], 4));
        e.submit(req(2, vec![3, 4], 4).with_deadline(std::time::Duration::ZERO));
        e.submit(req(3, vec![5, 6], 4).with_deadline(std::time::Duration::ZERO));
        let mut out = e.run_to_completion();
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].finish, FinishReason::Length);
        assert_eq!(out[0].tokens.len(), 4);
        assert_eq!(out[1].finish, FinishReason::DeadlineExceeded);
        assert_eq!(out[2].finish, FinishReason::DeadlineExceeded);
        assert_eq!(e.metrics.deadline_expired, 2);
        assert_eq!(e.metrics.requests_finished, 1);
    }

    #[test]
    fn deadline_expires_running_sequence_and_frees_pages() {
        let mut e = engine(2);
        e.submit(req(1, vec![1, 2, 3], 500).with_deadline(std::time::Duration::from_millis(30)));
        let mut events = Vec::new();
        e.step_events(&mut events); // admit + prefill
        assert_eq!(e.running(), 1);
        std::thread::sleep(std::time::Duration::from_millis(40));
        let mut guard = 0;
        while e.pending() > 0 {
            e.step_events(&mut events);
            guard += 1;
            assert!(guard < 1000, "expiry must retire the sequence");
        }
        let resp = events
            .iter()
            .find_map(|ev| match ev {
                ServerEvent::Done(r) => Some(r.clone()),
                _ => None,
            })
            .unwrap();
        assert_eq!(resp.finish, FinishReason::DeadlineExceeded);
        let stream: Vec<u32> = events
            .iter()
            .filter_map(|ev| match ev {
                ServerEvent::Token { token, .. } => Some(*token),
                _ => None,
            })
            .collect();
        assert_eq!(stream, resp.tokens, "expiry keeps every emitted token");
        assert_eq!(e.page_stats().live, 0);
        assert_eq!(e.metrics.deadline_expired, 1);
    }
}

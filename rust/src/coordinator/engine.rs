//! The serving engine: owns one model replica, a KV pool, and the set
//! of in-flight sequences; advances them with continuous batching.

use super::batcher::{plan_step, BatchPolicy};
use super::kv_pool::KvPool;
use super::metrics::Metrics;
use super::request::{FinishReason, Request, Response, SequenceState};
use crate::model::Transformer;
use crate::rng::Rng;
use std::collections::VecDeque;

/// One model replica + its scheduling state.
pub struct ServeEngine {
    pub model: Transformer,
    pub policy: BatchPolicy,
    pool: KvPool,
    waiting: VecDeque<Request>,
    running: Vec<SequenceState>,
    pub metrics: Metrics,
}

impl ServeEngine {
    pub fn new(model: Transformer, policy: BatchPolicy) -> ServeEngine {
        let pool = KvPool::for_model(&model.config, policy.max_running);
        ServeEngine {
            model,
            policy,
            pool,
            waiting: VecDeque::new(),
            running: Vec::new(),
            metrics: Metrics::default(),
        }
    }

    /// Enqueue a request (admission happens during [`ServeEngine::step`]).
    pub fn submit(&mut self, req: Request) {
        self.metrics.submitted += 1;
        self.waiting.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.waiting.len() + self.running.len()
    }

    pub fn running(&self) -> usize {
        self.running.len()
    }

    /// Admit from the waiting queue while KV caches are available.
    /// Returns immediate rejections (e.g. over-long prompts).
    fn admit(&mut self) -> Vec<Response> {
        let mut rejected = Vec::new();
        while self.running.len() < self.policy.max_running {
            let Some(req) = self.waiting.front() else { break };
            // reject over-long prompts outright
            if req.prompt.len() + 1 >= self.model.config.max_seq {
                let req = self.waiting.pop_front().unwrap();
                self.metrics.rejected += 1;
                rejected.push(Response {
                    id: req.id,
                    tokens: Vec::new(),
                    finish: FinishReason::PromptTooLong,
                    ttft: req.submitted_at.elapsed(),
                    total: req.submitted_at.elapsed(),
                    prompt_len: req.prompt.len(),
                });
                continue;
            }
            let Some(cache) = self.pool.acquire() else { break };
            let req = self.waiting.pop_front().unwrap();
            self.running.push(SequenceState::new(req, cache));
        }
        rejected
    }

    /// One engine iteration: admit, plan, execute prefill + decode,
    /// retire finished sequences. Returns completed responses.
    pub fn step(&mut self) -> Vec<Response> {
        let mut done = self.admit();
        let slots: Vec<(bool, usize, bool)> = self
            .running
            .iter()
            .map(|s| (s.in_prefill(), s.remaining_prompt(), s.pending_logits.is_some()))
            .collect();
        let plan = plan_step(&self.policy, &slots);

        // --- prefill work
        for &(slot, take) in &plan.prefill {
            let seq = &mut self.running[slot];
            for _ in 0..take {
                let tok = seq.request.prompt[seq.prefill_cursor];
                let logits = self.model.decode_step(tok, &mut seq.cache);
                seq.prefill_cursor += 1;
                if !seq.in_prefill() {
                    // prompt fully consumed: these logits predict token 1
                    seq.pending_logits = Some(logits);
                }
            }
            self.metrics.prefill_tokens += take as u64;
        }

        // --- decode work
        for &slot in &plan.decode {
            let seq = &mut self.running[slot];
            let logits = seq.pending_logits.take().expect("planned decode without logits");
            let next = sample(&logits, &seq.request.params, seq.generated.len());
            if seq.first_token_at.is_none() {
                seq.first_token_at = Some(std::time::Instant::now());
            }
            seq.generated.push(next);
            self.metrics.decode_tokens += 1;
            let stop = Some(next) == seq.request.params.stop_token;
            let out_of_budget = seq.budget_left() == 0;
            let cache_full = seq.cache.len() + 1 >= seq.cache.max_seq;
            if !(stop || out_of_budget || cache_full) {
                seq.pending_logits = Some(self.model.decode_step(next, &mut seq.cache));
            } else {
                seq.pending_logits = None; // finished; retired below
            }
        }

        // --- retire finished
        let mut i = 0;
        while i < self.running.len() {
            let finished = {
                let s = &self.running[i];
                !s.in_prefill() && s.pending_logits.is_none()
            };
            if finished {
                let s = self.running.swap_remove(i);
                self.pool.release(s.cache);
                let last = s.generated.last().copied();
                let stop_hit = last.is_some() && last == s.request.params.stop_token;
                let mut tokens = s.generated;
                if stop_hit {
                    tokens.pop();
                }
                let finish = if stop_hit {
                    FinishReason::Stop
                } else {
                    FinishReason::Length
                };
                let resp = Response {
                    id: s.request.id,
                    ttft: s
                        .first_token_at
                        .map(|t| t - s.request.submitted_at)
                        .unwrap_or_default(),
                    total: s.request.submitted_at.elapsed(),
                    prompt_len: s.request.prompt.len(),
                    tokens,
                    finish,
                };
                self.metrics.record_response(&resp);
                done.push(resp);
            } else {
                i += 1;
            }
        }
        done
    }

    /// Drive until every submitted request completes (test/batch mode).
    pub fn run_to_completion(&mut self) -> Vec<Response> {
        let mut out = Vec::new();
        let mut guard = 0usize;
        while self.pending() > 0 {
            out.extend(self.step());
            guard += 1;
            assert!(guard < 1_000_000, "engine livelock");
        }
        out
    }
}

/// Greedy or temperature sampling.
fn sample(logits: &[f32], params: &super::request::SamplingParams, step: usize) -> u32 {
    if params.temperature <= 0.0 {
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (i, &x) in logits.iter().enumerate() {
            if x > best_v {
                best_v = x;
                best = i;
            }
        }
        return best as u32;
    }
    let mut rng = Rng::new(params.seed ^ (step as u64).wrapping_mul(0x9E3779B97F4A7C15));
    let inv_t = 1.0 / params.temperature;
    let mut probs: Vec<f32> = logits.iter().map(|&x| x * inv_t).collect();
    crate::tensor::ops::softmax_inplace(&mut probs);
    rng.weighted(&probs) as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SamplingParams;
    use crate::model::ModelConfig;

    fn engine(max_running: usize) -> ServeEngine {
        let mut cfg = ModelConfig::family("tiny").unwrap();
        cfg.vocab_size = 32;
        cfg.max_seq = 48;
        let mut rng = Rng::new(11);
        let model = Transformer::random(cfg, &mut rng);
        ServeEngine::new(
            model,
            BatchPolicy {
                max_running,
                prefill_token_budget: 8,
                fcfs_prefill: true,
            },
        )
    }

    fn req(id: u64, prompt: Vec<u32>, max_new: usize) -> Request {
        Request::new(
            id,
            prompt,
            SamplingParams {
                max_new_tokens: max_new,
                stop_token: None,
                ..Default::default()
            },
        )
    }

    #[test]
    fn single_request_completes() {
        let mut e = engine(4);
        e.submit(req(1, vec![1, 2, 3], 5));
        let out = e.run_to_completion();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens.len(), 5);
        assert_eq!(out[0].finish, FinishReason::Length);
    }

    #[test]
    fn batched_requests_all_complete() {
        let mut e = engine(4);
        for i in 0..10 {
            e.submit(req(i, vec![1 + (i as u32 % 5), 2, 3], 4));
        }
        let out = e.run_to_completion();
        assert_eq!(out.len(), 10);
        let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn batched_output_matches_sequential() {
        // continuous batching must not change per-sequence results
        let mut e1 = engine(4);
        e1.submit(req(1, vec![3, 4], 6));
        e1.submit(req(2, vec![7, 8, 9], 6));
        let mut out_batched = e1.run_to_completion();
        out_batched.sort_by_key(|r| r.id);

        let mut e2 = engine(1); // forces sequential
        e2.submit(req(1, vec![3, 4], 6));
        e2.submit(req(2, vec![7, 8, 9], 6));
        let mut out_seq = e2.run_to_completion();
        out_seq.sort_by_key(|r| r.id);

        for (a, b) in out_batched.iter().zip(&out_seq) {
            assert_eq!(a.tokens, b.tokens, "req {}", a.id);
        }
    }

    #[test]
    fn over_long_prompt_rejected() {
        let mut e = engine(2);
        e.submit(req(5, vec![1; 64], 4)); // max_seq = 48
        let out = e.run_to_completion();
        assert_eq!(out[0].finish, FinishReason::PromptTooLong);
        assert!(out[0].tokens.is_empty());
    }

    #[test]
    fn admission_respects_capacity() {
        let mut e = engine(2);
        for i in 0..6 {
            e.submit(req(i, vec![1, 2], 3));
        }
        e.step();
        assert!(e.running() <= 2);
        let out = e.run_to_completion();
        assert_eq!(out.len(), 6);
    }

    #[test]
    fn metrics_accumulate() {
        let mut e = engine(4);
        e.submit(req(1, vec![1, 2, 3, 4], 3));
        let _ = e.run_to_completion();
        assert_eq!(e.metrics.submitted, 1);
        assert_eq!(e.metrics.prefill_tokens, 4);
        assert_eq!(e.metrics.decode_tokens, 3);
        assert_eq!(e.metrics.completed, 1);
    }

    #[test]
    fn stop_token_ends_generation() {
        let mut e = engine(2);
        // find what the model emits first, then set it as stop token
        let probe = {
            let mut cache = e.model.new_cache();
            let logits = e.model.decode_step(1, &mut cache);
            logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as u32
        };
        let mut r = req(9, vec![1], 10);
        r.params.stop_token = Some(probe);
        e.submit(r);
        let out = e.run_to_completion();
        assert_eq!(out[0].finish, FinishReason::Stop);
        assert!(out[0].tokens.is_empty(), "stop on first token");
    }
}

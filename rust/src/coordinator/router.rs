//! Request router: spreads requests across engine replicas.
//!
//! Policies (vllm-project/router-inspired): least-loaded by default,
//! with session affinity — requests carrying the same session key pin
//! to one replica so its KV/prefix locality is preserved.

use super::request::Request;

/// Routing policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastLoaded,
}

/// Router over `n` replicas. The router tracks in-flight counts that the
/// server updates on completion; it holds no engine references so it can
/// live on the intake thread.
#[derive(Debug)]
pub struct Router {
    pub policy: RoutePolicy,
    inflight: Vec<usize>,
    rr_next: usize,
}

impl Router {
    pub fn new(n_replicas: usize, policy: RoutePolicy) -> Router {
        assert!(n_replicas > 0);
        Router {
            policy,
            inflight: vec![0; n_replicas],
            rr_next: 0,
        }
    }

    pub fn n_replicas(&self) -> usize {
        self.inflight.len()
    }

    /// The replica a session key pins to — the same stable hash
    /// [`Router::route`] applies, exposed so the supervisor can replay
    /// a pinned request to its home replica without recording a new
    /// assignment. Only meaningful for `session != 0`.
    pub fn session_replica(&self, session: u64) -> usize {
        (session.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize % self.inflight.len()
    }

    /// Pick the replica for a request and record the assignment.
    pub fn route(&mut self, req: &Request) -> usize {
        let n = self.inflight.len();
        let pick = if req.session != 0 {
            // session affinity: stable hash → replica
            self.session_replica(req.session)
        } else {
            match self.policy {
                RoutePolicy::RoundRobin => {
                    let p = self.rr_next;
                    self.rr_next = (self.rr_next + 1) % n;
                    p
                }
                RoutePolicy::LeastLoaded => {
                    let mut best = 0usize;
                    for i in 1..n {
                        if self.inflight[i] < self.inflight[best] {
                            best = i;
                        }
                    }
                    best
                }
            }
        };
        self.inflight[pick] += 1;
        pick
    }

    /// Mark a request complete on its replica.
    pub fn complete(&mut self, replica: usize) {
        self.inflight[replica] = self.inflight[replica].saturating_sub(1);
    }

    /// Undo a [`Router::route`] assignment that was never delivered —
    /// the server's admission control rejected the request after
    /// routing it. Distinct from [`Router::complete`], which retires
    /// work that actually ran.
    pub fn unroute(&mut self, replica: usize) {
        self.inflight[replica] = self.inflight[replica].saturating_sub(1);
    }

    /// Record an assignment made outside [`Router::route`]: admission
    /// spill-over lands a sessionless request on a replica with intake
    /// room rather than the routed pick.
    pub fn assign(&mut self, replica: usize) {
        self.inflight[replica] += 1;
    }

    pub fn load(&self, replica: usize) -> usize {
        self.inflight[replica]
    }

    /// Zero a replica's in-flight count after the supervisor replaces
    /// its engine: the victim's requests were either completed (their
    /// `Done` arrived before the death notice) or requeued through
    /// [`Router::assign`] on a healthy replica, so the stale count
    /// would otherwise repel load from the fresh engine forever under
    /// `LeastLoaded`.
    pub fn reset(&mut self, replica: usize) {
        self.inflight[replica] = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SamplingParams;

    fn req(id: u64, session: u64) -> Request {
        let mut r = Request::new(id, vec![1], SamplingParams::default());
        r.session = session;
        r
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(3, RoutePolicy::RoundRobin);
        let picks: Vec<usize> = (0..6).map(|i| r.route(&req(i, 0))).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_balances() {
        let mut r = Router::new(2, RoutePolicy::LeastLoaded);
        let a = r.route(&req(1, 0));
        let b = r.route(&req(2, 0));
        assert_ne!(a, b, "second request goes to the idle replica");
        r.complete(a);
        let c = r.route(&req(3, 0));
        assert_eq!(c, a, "freed replica is least loaded again");
    }

    #[test]
    fn session_affinity_stable() {
        let mut r = Router::new(4, RoutePolicy::LeastLoaded);
        let first = r.route(&req(1, 42));
        for i in 2..10 {
            assert_eq!(r.route(&req(i, 42)), first);
        }
    }

    #[test]
    fn sessions_spread_across_replicas() {
        let mut r = Router::new(4, RoutePolicy::LeastLoaded);
        let mut seen = [false; 4];
        for s in 1..64u64 {
            seen[r.route(&req(s, s))] = true;
        }
        assert!(seen.iter().all(|&x| x), "{seen:?}");
    }

    #[test]
    fn complete_underflow_safe() {
        let mut r = Router::new(1, RoutePolicy::RoundRobin);
        r.complete(0);
        assert_eq!(r.load(0), 0);
    }

    #[test]
    fn reset_clears_stale_load_after_respawn() {
        let mut r = Router::new(2, RoutePolicy::LeastLoaded);
        for _ in 0..3 {
            r.assign(0);
        }
        r.assign(1);
        r.reset(0);
        assert_eq!(r.load(0), 0);
        // the fresh replica immediately attracts sessionless load
        assert_eq!(r.route(&req(9, 0)), 0);
    }

    #[test]
    fn unroute_and_assign_rebalance() {
        let mut r = Router::new(2, RoutePolicy::LeastLoaded);
        let a = r.route(&req(1, 0));
        assert_eq!(r.load(a), 1);
        // admission rejected the routed pick and spilled to the other
        r.unroute(a);
        let other = 1 - a;
        r.assign(other);
        assert_eq!(r.load(a), 0);
        assert_eq!(r.load(other), 1);
        // the next sessionless request prefers the now-idle replica
        assert_eq!(r.route(&req(2, 0)), a);
    }
}

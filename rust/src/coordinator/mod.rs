//! The L3 serving coordinator — a vLLM-router-style engine around the
//! quantized model: request router, continuous batcher, KV-cache pool,
//! prefill/decode scheduler, metrics, and a threaded, event-driven
//! server front-end with admission control.
//!
//! The offline crate cache has no tokio, so the event loop is built on
//! `std::thread` + `mpsc` (documented substitution, DESIGN.md §2); the
//! architecture — bounded intake, interleaved prefill/decode,
//! per-token streaming events, cancellation/deadlines at step
//! boundaries — matches the async original move-for-move.
//!
//! The front-end is *supervised* (DESIGN.md §Fault-Tolerance): worker
//! panics are isolated per replica with `catch_unwind`, dead replicas
//! respawn cold from the [`ModelSource`], orphaned requests replay
//! token-identically under a bounded [`RetryPolicy`], and the whole
//! path is exercised by the deterministic fault-injection layer in
//! [`faults`].
//!
//! Data flow:
//!
//! ```text
//! submit() ─→ admission (intake window) ─→ Router ─→ per-worker queue
//!     │ Rejected(QueueFull/…)                              │
//!     ▼                                                    ▼
//! SubmitOutcome                           Scheduler/Batcher + lifecycle
//!                                         sweep (cancel/deadline)
//!                                                          │
//!                                                          ▼
//!                                         Engine.step_events(): decode
//!                                         all active + prefill admitted
//!                                                          │
//!                                                          ▼
//!                                  ServerEvent::Token* → ::Done(Response)
//! ```

pub mod batcher;
pub mod engine;
pub mod faults;
pub mod kv_pool;
pub mod metrics;
pub mod prefix_cache;
pub mod request;
pub mod router;
pub mod server;
pub mod speculator;
pub mod supervisor;

pub use engine::ServeEngine;
pub use faults::{FaultEntry, FaultInjector, FaultKind, FaultPlan};
pub use kv_pool::PagedKvOpts;
pub use metrics::{serve_metrics_json, LatencyHistogram, Metrics, ServerStats};
pub use request::{
    FinishReason, Request, RequestHandle, RequestId, RequestStatus, Response, SamplingParams,
    ServerEvent, SubmitError,
};
pub use server::{DrainReport, Server, ServerBuilder, SubmitOutcome};
pub use speculator::SpecDecodeOpts;
pub use supervisor::{ModelSource, RestartError, RetryPolicy};

//! The L3 serving coordinator — a vLLM-router-style engine around the
//! quantized model: request router, continuous batcher, KV-cache pool,
//! prefill/decode scheduler, metrics, and a threaded server front-end.
//!
//! The offline crate cache has no tokio, so the event loop is built on
//! `std::thread` + `mpsc` (documented substitution, DESIGN.md §2); the
//! architecture — admission control by token budget, interleaved
//! prefill/decode, per-request streaming state — matches the async
//! original move-for-move.
//!
//! Data flow:
//!
//! ```text
//! submit() ─→ Router ─→ per-worker queue ─→ Scheduler/Batcher
//!                                          │   admit prefills (budget)
//!                                          ▼
//!                                     Engine.step(): decode all active
//!                                          │   + prefill admitted
//!                                          ▼
//!                                  responses (finished sequences)
//! ```

pub mod batcher;
pub mod engine;
pub mod kv_pool;
pub mod metrics;
pub mod prefix_cache;
pub mod request;
pub mod router;
pub mod server;

pub use engine::ServeEngine;
pub use kv_pool::PagedKvOpts;
pub use request::{Request, RequestId, Response, SamplingParams};
pub use server::Server;

//! KV-cache pool: bounded, recycling allocator for per-sequence caches.
//!
//! Serving engines live or die on cache memory management; this pool
//! bounds the number of resident caches (= max concurrent sequences),
//! recycles freed caches without reallocation, and tracks watermarks
//! for the metrics endpoint.

use crate::model::KvCache;

/// Bounded pool of KV caches (head-major layout — see `model::kv`).
#[derive(Debug)]
pub struct KvPool {
    n_layers: usize,
    n_kv_heads: usize,
    head_dim: usize,
    max_seq: usize,
    capacity: usize,
    free: Vec<KvCache>,
    outstanding: usize,
    /// High-water mark of simultaneously outstanding caches.
    pub peak_outstanding: usize,
}

impl KvPool {
    pub fn new(
        n_layers: usize,
        n_kv_heads: usize,
        head_dim: usize,
        max_seq: usize,
        capacity: usize,
    ) -> KvPool {
        KvPool {
            n_layers,
            n_kv_heads,
            head_dim,
            max_seq,
            capacity,
            free: Vec::with_capacity(capacity),
            outstanding: 0,
            peak_outstanding: 0,
        }
    }

    /// For a model configuration.
    pub fn for_model(config: &crate::model::ModelConfig, capacity: usize) -> KvPool {
        KvPool::new(
            config.n_layers,
            config.n_kv_heads,
            config.head_dim(),
            config.max_seq,
            capacity,
        )
    }

    /// Try to acquire a cache; `None` when the pool is exhausted
    /// (admission control backpressure).
    pub fn acquire(&mut self) -> Option<KvCache> {
        if self.outstanding >= self.capacity {
            return None;
        }
        self.outstanding += 1;
        self.peak_outstanding = self.peak_outstanding.max(self.outstanding);
        Some(match self.free.pop() {
            Some(mut c) => {
                c.reset();
                c
            }
            None => KvCache::new(self.n_layers, self.n_kv_heads, self.head_dim, self.max_seq),
        })
    }

    /// Return a cache to the pool.
    pub fn release(&mut self, cache: KvCache) {
        debug_assert!(self.outstanding > 0, "release without acquire");
        self.outstanding = self.outstanding.saturating_sub(1);
        if self.free.len() < self.capacity {
            self.free.push(cache);
        }
    }

    pub fn outstanding(&self) -> usize {
        self.outstanding
    }

    pub fn available(&self) -> usize {
        self.capacity - self.outstanding
    }

    /// Total bytes held by pooled (free) caches.
    pub fn pooled_bytes(&self) -> usize {
        self.free.iter().map(KvCache::bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_release_cycle() {
        let mut p = KvPool::new(2, 2, 4, 16, 2);
        let a = p.acquire().unwrap();
        let b = p.acquire().unwrap();
        assert!(p.acquire().is_none(), "capacity enforced");
        assert_eq!(p.outstanding(), 2);
        p.release(a);
        assert_eq!(p.available(), 1);
        let c = p.acquire().unwrap();
        assert!(c.is_empty(), "recycled cache must be reset");
        p.release(b);
        p.release(c);
        assert_eq!(p.outstanding(), 0);
    }

    #[test]
    fn recycling_reuses_buffers() {
        let mut p = KvPool::new(1, 1, 4, 8, 1);
        let mut a = p.acquire().unwrap();
        a.append(0, &[1.0; 4], &[2.0; 4]);
        a.commit();
        p.release(a);
        assert!(p.pooled_bytes() > 0);
        let b = p.acquire().unwrap();
        assert_eq!(b.len(), 0);
    }

    #[test]
    fn peak_watermark() {
        let mut p = KvPool::new(1, 1, 4, 8, 3);
        let a = p.acquire().unwrap();
        let b = p.acquire().unwrap();
        p.release(a);
        let c = p.acquire().unwrap();
        assert_eq!(p.peak_outstanding, 2);
        p.release(b);
        p.release(c);
    }
}

//! KV-cache pool: page-granular allocator for per-sequence caches.
//!
//! Serving engines live or die on cache memory management. Since the
//! paged refactor the pool no longer recycles whole `max_seq` caches —
//! it hands out thin paged [`KvCache`]s that draw fixed-size pages from
//! one shared [`PageStore`], so replica KV memory is bounded and
//! recycled **in pages**: a sequence that generates 40 tokens holds one
//! 64-position page, not a whole `max_seq` allocation, and freed pages
//! are reused by any sequence (or by the radix prefix cache, which
//! parks donated prompt pages in the same store).
//!
//! `capacity` still bounds concurrent sequences (admission control);
//! the page budget bounds bytes. The default budget — `capacity ×
//! ⌈max_seq / page_size⌉` pages — can never starve running sequences
//! on its own (it is exactly the legacy worst case), so preemption only
//! triggers under an explicit tighter `--kv-pages` budget or when the
//! prefix tree's parked pages are not yet evicted.

use crate::model::kv::{KvCache, PageStats, PageStore};

/// Default positions per KV page. Must be ≥ the widest attention lane
/// kernel (8) so lane blocks never straddle a page; 64 amortizes
/// page-chain overhead while keeping fragmentation (≤ 1 partial page
/// per sequence) small.
pub const DEFAULT_PAGE_SIZE: usize = 64;

/// Knobs for the paged KV allocator, resolved from
/// `--page-size`/`PTQTP_PAGE_SIZE`, `--prefix-cache`, and `--kv-pages`.
#[derive(Clone, Copy, Debug)]
pub struct PagedKvOpts {
    /// Positions per page (clamped to `[1, max_seq]` per cache).
    pub page_size: usize,
    /// Enable the radix prefix cache (`--prefix-cache off` is the
    /// exact-legacy escape hatch: nothing shared, nothing parked).
    pub prefix_cache: bool,
    /// Page budget override; `None` = `capacity × ⌈max_seq/page_size⌉`
    /// (the legacy worst case — never binding for running sequences).
    pub page_budget: Option<usize>,
}

impl Default for PagedKvOpts {
    fn default() -> PagedKvOpts {
        PagedKvOpts {
            page_size: DEFAULT_PAGE_SIZE,
            prefix_cache: true,
            page_budget: None,
        }
    }
}

/// Pool of paged KV caches over one shared, budgeted [`PageStore`].
#[derive(Debug)]
pub struct KvPool {
    n_layers: usize,
    n_kv_heads: usize,
    head_dim: usize,
    max_seq: usize,
    capacity: usize,
    page_size: usize,
    store: PageStore,
    outstanding: usize,
    /// High-water mark of simultaneously outstanding caches.
    pub peak_outstanding: usize,
}

impl KvPool {
    /// Pool with the default paged options (page size
    /// [`DEFAULT_PAGE_SIZE`], default budget).
    pub fn new(
        n_layers: usize,
        n_kv_heads: usize,
        head_dim: usize,
        max_seq: usize,
        capacity: usize,
    ) -> KvPool {
        KvPool::with_opts(
            n_layers,
            n_kv_heads,
            head_dim,
            max_seq,
            capacity,
            &PagedKvOpts::default(),
        )
    }

    pub fn with_opts(
        n_layers: usize,
        n_kv_heads: usize,
        head_dim: usize,
        max_seq: usize,
        capacity: usize,
        opts: &PagedKvOpts,
    ) -> KvPool {
        let page_size = opts.page_size.min(max_seq).max(1);
        let budget = opts
            .page_budget
            .unwrap_or_else(|| capacity * max_seq.div_ceil(page_size).max(1));
        KvPool {
            n_layers,
            n_kv_heads,
            head_dim,
            max_seq,
            capacity,
            page_size,
            store: PageStore::for_geometry(n_layers, n_kv_heads, head_dim, page_size, Some(budget)),
            outstanding: 0,
            peak_outstanding: 0,
        }
    }

    /// For a model configuration (default paged options).
    pub fn for_model(config: &crate::model::ModelConfig, capacity: usize) -> KvPool {
        KvPool::for_model_with(config, capacity, &PagedKvOpts::default())
    }

    pub fn for_model_with(
        config: &crate::model::ModelConfig,
        capacity: usize,
        opts: &PagedKvOpts,
    ) -> KvPool {
        KvPool::with_opts(
            config.n_layers,
            config.n_kv_heads,
            config.head_dim(),
            config.max_seq,
            capacity,
            opts,
        )
    }

    /// Try to acquire a cache; `None` when the pool is exhausted
    /// (admission control backpressure). The cache holds no pages yet —
    /// pages are allocated lazily by `KvCache::reserve`/append, so an
    /// idle admitted sequence costs nothing.
    pub fn acquire(&mut self) -> Option<KvCache> {
        if self.outstanding >= self.capacity {
            return None;
        }
        self.outstanding += 1;
        self.peak_outstanding = self.peak_outstanding.max(self.outstanding);
        Some(KvCache::paged(
            self.n_layers,
            self.n_kv_heads,
            self.head_dim,
            self.max_seq,
            self.page_size,
            self.store.clone(),
        ))
    }

    /// Return a cache to the pool. Its pages flow back to the shared
    /// store's free list on drop (minus any still shared with the
    /// prefix tree or a forked sequence, which stay live).
    pub fn release(&mut self, cache: KvCache) {
        debug_assert!(self.outstanding > 0, "release without acquire");
        self.outstanding = self.outstanding.saturating_sub(1);
        drop(cache);
    }

    /// Account for a cache created by `KvCache::fork()` rather than
    /// [`KvPool::acquire`]: the fork shares its parent's pages
    /// copy-on-write but is an outstanding cache like any other, and
    /// must be paired with [`KvPool::release`] when retired. Fork
    /// admission bypasses the capacity gate deliberately — the engine
    /// only fans out a request it has already admitted, and `n` is
    /// bounded per request, so capacity stays an admission-control
    /// knob for *requests*, not samples.
    pub fn register_fork(&mut self) {
        self.outstanding += 1;
        self.peak_outstanding = self.peak_outstanding.max(self.outstanding);
    }

    pub fn outstanding(&self) -> usize {
        self.outstanding
    }

    pub fn available(&self) -> usize {
        // saturating: forks can push `outstanding` past `capacity`
        self.capacity.saturating_sub(self.outstanding)
    }

    /// The shared page store (the engine hands this to the prefix cache
    /// for eviction, and reads gauges from it).
    pub fn store(&self) -> &PageStore {
        &self.store
    }

    /// Positions per page.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Page-level accounting of the shared store.
    pub fn stats(&self) -> PageStats {
        self.store.stats()
    }

    /// Pages currently referenced by live caches or the prefix tree —
    /// the gauge the cancellation tests pin to its pre-request
    /// baseline (cancel/deadline retirement releases eagerly and
    /// donates nothing, so this returns exactly to where it was).
    pub fn live_pages(&self) -> usize {
        self.store.stats().live
    }

    /// Total bytes held by pooled (free-list) pages awaiting reuse.
    pub fn pooled_bytes(&self) -> usize {
        self.stats().free * 2 * self.store.page_floats() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_release_cycle() {
        let mut p = KvPool::new(2, 2, 4, 16, 2);
        let a = p.acquire().unwrap();
        let b = p.acquire().unwrap();
        assert!(p.acquire().is_none(), "capacity enforced");
        assert_eq!(p.outstanding(), 2);
        p.release(a);
        assert_eq!(p.available(), 1);
        let c = p.acquire().unwrap();
        assert!(c.is_empty(), "fresh cache starts empty");
        p.release(b);
        p.release(c);
        assert_eq!(p.outstanding(), 0);
    }

    #[test]
    fn recycling_reuses_buffers() {
        let mut p = KvPool::new(1, 1, 4, 8, 1);
        let mut a = p.acquire().unwrap();
        a.append(0, &[1.0; 4], &[2.0; 4]);
        a.commit();
        p.release(a);
        assert!(p.pooled_bytes() > 0, "released pages sit on the free list");
        let allocs = p.stats().page_allocs;
        let mut b = p.acquire().unwrap();
        assert_eq!(b.len(), 0);
        b.append(0, &[3.0; 4], &[4.0; 4]);
        b.commit();
        assert_eq!(p.stats().page_allocs, allocs, "page buffer recycled, not reallocated");
        p.release(b);
    }

    #[test]
    fn peak_watermark() {
        let mut p = KvPool::new(1, 1, 4, 8, 3);
        let a = p.acquire().unwrap();
        let b = p.acquire().unwrap();
        p.release(a);
        let c = p.acquire().unwrap();
        assert_eq!(p.peak_outstanding, 2);
        p.release(b);
        p.release(c);
    }

    #[test]
    fn default_budget_covers_legacy_worst_case() {
        // capacity 2 × ⌈10/4⌉ = 6 pages: both sequences can reach
        // max_seq simultaneously, exactly like two legacy caches
        let opts = PagedKvOpts {
            page_size: 4,
            ..PagedKvOpts::default()
        };
        let mut p = KvPool::with_opts(1, 1, 2, 10, 2, &opts);
        assert_eq!(p.stats().budget, Some(6));
        let mut a = p.acquire().unwrap();
        let mut b = p.acquire().unwrap();
        assert!(a.reserve(10).is_ok());
        assert!(b.reserve(10).is_ok());
        p.release(a);
        p.release(b);
    }

    #[test]
    fn explicit_budget_binds_and_recovers() {
        let opts = PagedKvOpts {
            page_size: 4,
            page_budget: Some(2),
            ..PagedKvOpts::default()
        };
        let mut p = KvPool::with_opts(1, 1, 2, 32, 2, &opts);
        let mut a = p.acquire().unwrap();
        let mut b = p.acquire().unwrap();
        assert!(a.reserve(8).is_ok(), "a takes both pages");
        assert!(b.reserve(1).is_err(), "budget exhausted");
        p.release(a); // pages return to the store
        assert!(b.reserve(1).is_ok());
        p.release(b);
    }
}

//! Model-free prompt-lookup drafting for speculative decoding.
//!
//! Decode at small batch is latency-bound on one full forward per
//! token — exactly the regime PTQTP's bandwidth savings target. The
//! speculator closes the gap from the scheduling side: propose up to
//! `k` likely continuation tokens *without a second model*, let the
//! engine score them as extra rows of the same fused
//! [`Transformer::forward_batch`] pass, and keep the longest prefix
//! the model itself would have produced (`ServeEngine::step_events`
//! phase 3). A hit turns k+1 forward passes into one; a miss costs
//! one extra row block in a pass that was happening anyway.
//!
//! Drafting is **prompt lookup** (n-gram suffix matching): find the
//! most recent earlier occurrence of the longest n-gram that ends the
//! sequence-so-far (`prompt ++ generated`), and propose the tokens
//! that followed it last time. Repetitive text — code, templated
//! prose, quoted context — makes this fire constantly; random text
//! makes it fire rarely and costs little. There is no checkpoint to
//! load, no RNG, and no state: [`SpecDecodeOpts::draft`] is a pure
//! function of the token context, which is what lets preemption
//! replay and the engine's bitwise-parity discipline extend to
//! speculation unchanged (DESIGN.md §Speculative-Decoding).
//!
//! [`Transformer::forward_batch`]: crate::model::Transformer::forward_batch
//! [`ServeEngine::step_events`]: super::engine::ServeEngine::step_events

/// Default maximum draft tokens proposed per sequence per step.
pub const DEFAULT_SPEC_K: usize = 4;
/// Default smallest suffix n-gram that may anchor a lookup match.
pub const DEFAULT_MIN_MATCH: usize = 2;
/// Default largest suffix n-gram tried (longest first).
pub const DEFAULT_MAX_NGRAM: usize = 4;

/// Prompt-lookup speculative-decoding configuration. Carried by the
/// engine when `--spec-decode on`; `None` at the engine level means
/// plain decode (the exact-legacy escape hatch).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpecDecodeOpts {
    /// Maximum draft tokens per sequence per step (the verify pass
    /// scores `1 + k` rows for the sequence instead of 1).
    pub k: usize,
    /// Smallest anchor n-gram worth matching. 1 fires on any repeated
    /// token; 2+ trades fire rate for accept rate.
    pub min_match: usize,
    /// Largest anchor n-gram, tried first — longer anchors are more
    /// specific, so their continuations are likelier to be accepted.
    pub max_ngram: usize,
}

impl Default for SpecDecodeOpts {
    fn default() -> SpecDecodeOpts {
        SpecDecodeOpts {
            k: DEFAULT_SPEC_K,
            min_match: DEFAULT_MIN_MATCH,
            max_ngram: DEFAULT_MAX_NGRAM,
        }
    }
}

impl SpecDecodeOpts {
    /// Defaults with an explicit draft length `k`.
    pub fn with_k(k: usize) -> SpecDecodeOpts {
        SpecDecodeOpts { k, ..SpecDecodeOpts::default() }
    }

    /// Propose up to `min(cap, self.k)` draft tokens continuing `ctx`
    /// (the sequence's `prompt ++ generated`, including the token just
    /// committed this step). Anchors are tried longest-first from
    /// `max_ngram` down to `min_match`; within one length the **most
    /// recent** earlier occurrence wins — recency tracks the local
    /// repetition structure (loops, templated spans) better than the
    /// first occurrence does. Appends into `out` (cleared first) so
    /// the decode hot loop reuses one buffer; leaves `out` empty when
    /// nothing matches. O(len · max_ngram) scan — contexts here are
    /// bounded by `max_seq`, so this is noise next to a forward pass.
    pub fn draft(&self, ctx: &[u32], cap: usize, out: &mut Vec<u32>) {
        out.clear();
        let cap = cap.min(self.k);
        if cap == 0 {
            return;
        }
        let len = ctx.len();
        let hi = self.max_ngram.max(self.min_match);
        for n in (self.min_match.max(1)..=hi).rev() {
            // need the anchor plus at least one earlier token to follow
            if n + 1 > len {
                continue;
            }
            let anchor = &ctx[len - n..];
            for s in (0..len - n).rev() {
                if &ctx[s..s + n] == anchor {
                    let take = cap.min(len - (s + n));
                    out.extend_from_slice(&ctx[s + n..s + n + take]);
                    return;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn draft(opts: &SpecDecodeOpts, ctx: &[u32], cap: usize) -> Vec<u32> {
        let mut out = Vec::new();
        opts.draft(ctx, cap, &mut out);
        out
    }

    #[test]
    fn repeated_ngram_drafts_its_continuation() {
        let opts = SpecDecodeOpts::default();
        // ... 7 8 9 1 | 7 8  →  anchor [7,8] matched at the front,
        // continuation [9, 1] plus the second occurrence's own tokens
        let ctx = [7, 8, 9, 1, 7, 8];
        assert_eq!(draft(&opts, &ctx, 4), vec![9, 1, 7, 8]);
    }

    #[test]
    fn cap_and_k_clamp_the_draft() {
        let opts = SpecDecodeOpts { k: 2, ..Default::default() };
        let ctx = [7, 8, 9, 1, 2, 3, 7, 8];
        assert_eq!(draft(&opts, &ctx, 8), vec![9, 1], "k clamps");
        assert_eq!(draft(&opts, &ctx, 1), vec![9], "cap clamps below k");
        assert!(draft(&opts, &ctx, 0).is_empty());
    }

    #[test]
    fn no_repetition_drafts_nothing() {
        let opts = SpecDecodeOpts::default();
        assert!(draft(&opts, &[1, 2, 3, 4, 5, 6], 4).is_empty());
        assert!(draft(&opts, &[], 4).is_empty());
        assert!(draft(&opts, &[5], 4).is_empty(), "anchor needs history");
    }

    #[test]
    fn longest_anchor_wins_over_shorter() {
        let opts = SpecDecodeOpts { min_match: 2, max_ngram: 3, k: 1 };
        // trigram [5,1,2] says 8 follows; the more recent bigram [1,2]
        // says 9 follows — the longer, more specific anchor wins
        let ctx = [5, 1, 2, 8, 3, 1, 2, 9, 5, 1, 2];
        assert_eq!(draft(&opts, &ctx, 1), vec![8]);
    }

    #[test]
    fn most_recent_occurrence_wins_within_a_length() {
        let opts = SpecDecodeOpts { min_match: 2, max_ngram: 2, k: 1 };
        // bigram [1,2] occurs twice earlier; the later one (→ 7) wins
        let ctx = [1, 2, 9, 1, 2, 7, 1, 2];
        assert_eq!(draft(&opts, &ctx, 1), vec![7]);
    }

    #[test]
    fn min_match_gates_weak_anchors() {
        let strict = SpecDecodeOpts { min_match: 3, max_ngram: 4, k: 4 };
        let ctx = [1, 2, 9, 1, 2]; // only a bigram repeats
        assert!(draft(&strict, &ctx, 4).is_empty());
        let loose = SpecDecodeOpts { min_match: 1, max_ngram: 4, k: 4 };
        // bigram anchor [1,2] matches at the front → drafts [9, 1, 2]
        assert_eq!(draft(&loose, &ctx, 4), vec![9, 1, 2]);
    }

    #[test]
    fn draft_is_a_pure_function_of_context() {
        let opts = SpecDecodeOpts::default();
        let ctx: Vec<u32> = (0..40).map(|i| i % 7).collect();
        let a = draft(&opts, &ctx, 4);
        let b = draft(&opts, &ctx, 4);
        assert_eq!(a, b);
        assert!(!a.is_empty(), "periodic context must fire");
    }
}

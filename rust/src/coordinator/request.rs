//! Request/response types and per-sequence lifecycle state.

/// Monotonic request identifier.
pub type RequestId = u64;

/// Sampling configuration (greedy when `temperature == 0`).
#[derive(Clone, Copy, Debug)]
pub struct SamplingParams {
    pub temperature: f32,
    pub max_new_tokens: usize,
    /// Stop at this token id (usually EOS).
    pub stop_token: Option<u32>,
    pub seed: u64,
    /// Parallel samples per request (`--n`). The engine prefills the
    /// prompt **once**, then forks the KV cache `n - 1` times
    /// (copy-on-write page sharing), so `n` completions cost one
    /// prompt pass plus `n` decode streams. Each fork samples with
    /// [`SamplingParams::for_sample`]'s derived seed; `n = 1` (the
    /// default) is the exact legacy path.
    pub n: usize,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 0.0,
            max_new_tokens: 32,
            stop_token: Some(crate::data::tokenizer::EOS),
            seed: 0,
            n: 1,
        }
    }
}

impl SamplingParams {
    /// Parameters for fork `k` of an `n > 1` request: same budget and
    /// temperature, seed decorrelated per sample (k = 0 keeps the base
    /// seed, so single-sample behaviour is unchanged), `n` forced back
    /// to 1 so a resumed fork never fans out again.
    pub fn for_sample(&self, k: usize) -> SamplingParams {
        SamplingParams {
            seed: self.seed ^ (k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            n: 1,
            ..*self
        }
    }
}

/// An inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<u32>,
    pub params: SamplingParams,
    /// Session key for router affinity (0 = none).
    pub session: u64,
    /// Which parallel sample this sequence produces (0 for the primary
    /// and for ordinary `n = 1` requests; forks get 1..n).
    pub sample: usize,
    pub submitted_at: std::time::Instant,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<u32>, params: SamplingParams) -> Request {
        Request {
            id,
            prompt,
            params,
            session: 0,
            sample: 0,
            submitted_at: std::time::Instant::now(),
        }
    }
}

/// Why a sequence finished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    Stop,
    Length,
    /// Prompt longer than the model context.
    PromptTooLong,
    /// The sequence's KV cache ran out of positions mid-flight (a
    /// planner/capacity disagreement) — the request is truncated to
    /// what was generated instead of panicking the replica. Since the
    /// paged allocator, *page-pool* exhaustion no longer lands here:
    /// the engine preempts (release pages, re-enqueue, recompute) and
    /// the request still completes; this reason survives only for the
    /// unsatisfiable case where a lone request cannot fit even with
    /// every other sequence evicted.
    CacheOverflow,
}

/// Completed request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    /// Which parallel sample this is (see [`Request::sample`]); an
    /// `n`-sample request yields `n` responses sharing its `id`.
    pub sample: usize,
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    /// Time from submit to first generated token.
    pub ttft: std::time::Duration,
    /// Time from submit to completion.
    pub total: std::time::Duration,
    pub prompt_len: usize,
}

/// Lifecycle of an admitted sequence inside the engine.
///
/// The prefill phase covers `prefill_len` tokens: normally the prompt;
/// for a sequence resumed after preemption ([`SequenceState::resume`])
/// it is prompt **plus** the tokens already generated before eviction,
/// which are recomputed through [`SequenceState::prefill_token`] —
/// greedy/seeded sampling then replays the remaining tokens exactly
/// (the per-step RNG is keyed by `generated.len()`, which resumes at
/// its pre-preemption value).
#[derive(Debug)]
pub struct SequenceState {
    pub request: Request,
    pub cache: crate::model::KvCache,
    /// Prefill tokens consumed so far (`< prefill_len` ⇒ prefilling).
    pub prefill_cursor: usize,
    /// Tokens the prefill phase must cover (see type docs).
    pub prefill_len: usize,
    pub generated: Vec<u32>,
    /// Logits from the last step (None until the prompt is consumed).
    pub pending_logits: Option<Vec<f32>>,
    pub first_token_at: Option<std::time::Instant>,
    /// Set when the sequence's cache filled before its prompt was
    /// consumed — retired with [`FinishReason::CacheOverflow`].
    pub overflowed: bool,
    /// Set by the engine when this sequence is chosen as a preemption
    /// victim: its pages are released at the end of the step and the
    /// request re-enqueues for recompute.
    pub preempted: bool,
}

impl SequenceState {
    pub fn new(request: Request, cache: crate::model::KvCache) -> SequenceState {
        let prefill_len = request.prompt.len();
        SequenceState {
            request,
            cache,
            prefill_cursor: 0,
            prefill_len,
            generated: Vec::new(),
            pending_logits: None,
            first_token_at: None,
            overflowed: false,
            preempted: false,
        }
    }

    /// Re-admit a preempted sequence: everything generated before
    /// eviction joins the prefill phase (prompt + generated recompute
    /// into the fresh cache; the prefix tree usually still holds the
    /// prompt's pages, so most of it is adopted rather than recomputed)
    /// and decoding continues from where it stopped.
    pub fn resume(
        request: Request,
        generated: Vec<u32>,
        cache: crate::model::KvCache,
        first_token_at: Option<std::time::Instant>,
    ) -> SequenceState {
        let prefill_len = request.prompt.len() + generated.len();
        SequenceState {
            request,
            cache,
            prefill_cursor: 0,
            prefill_len,
            generated,
            pending_logits: None,
            first_token_at,
            overflowed: false,
            preempted: false,
        }
    }

    pub fn in_prefill(&self) -> bool {
        self.prefill_cursor < self.prefill_len
    }

    pub fn remaining_prompt(&self) -> usize {
        self.prefill_len - self.prefill_cursor
    }

    /// The `i`-th prefill token: the prompt, then (resumed sequences
    /// only) the previously generated tokens being recomputed.
    pub fn prefill_token(&self, i: usize) -> u32 {
        debug_assert!(i < self.prefill_len);
        if i < self.request.prompt.len() {
            self.request.prompt[i]
        } else {
            self.generated[i - self.request.prompt.len()]
        }
    }

    pub fn budget_left(&self) -> usize {
        self.request
            .params
            .max_new_tokens
            .saturating_sub(self.generated.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::KvCache;

    #[test]
    fn lifecycle_flags() {
        let req = Request::new(1, vec![1, 2, 3], SamplingParams::default());
        let mut s = SequenceState::new(req, KvCache::new(1, 1, 4, 16));
        assert!(s.in_prefill());
        assert_eq!(s.remaining_prompt(), 3);
        s.prefill_cursor = 3;
        assert!(!s.in_prefill());
        assert_eq!(s.budget_left(), 32);
        s.generated = vec![9; 30];
        assert_eq!(s.budget_left(), 2);
    }

    #[test]
    fn resume_recomputes_prompt_plus_generated() {
        let req = Request::new(1, vec![1, 2, 3], SamplingParams::default());
        let s = SequenceState::resume(req, vec![7, 8], KvCache::new(1, 1, 4, 16), None);
        assert!(s.in_prefill());
        assert_eq!(s.remaining_prompt(), 5, "prompt + prior generation");
        let replay: Vec<u32> = (0..5).map(|i| s.prefill_token(i)).collect();
        assert_eq!(replay, vec![1, 2, 3, 7, 8]);
        // decode budget picks up where it left off
        assert_eq!(s.budget_left(), 30);
    }

    #[test]
    fn default_sampling_greedy() {
        let p = SamplingParams::default();
        assert_eq!(p.temperature, 0.0);
        assert!(p.stop_token.is_some());
    }
}

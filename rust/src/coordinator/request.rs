//! Request/response types, per-sequence lifecycle state, and the
//! event vocabulary of the streaming serve front-end.
//!
//! The front-end API is built from four pieces defined here:
//!
//! * [`SamplingParams`] — validated at submit time ([`SamplingParams::
//!   validate`]) and constructed through a chainable builder
//!   ([`SamplingParams::greedy`] / `with_*`).
//! * [`Request`] — carries an optional wall-clock [`Request::deadline`]
//!   and a shared [`RequestCtl`] block through which callers cancel and
//!   observe status without touching the worker thread.
//! * [`ServerEvent`] — the wire vocabulary: one `Token` per decoded
//!   token, one `Done` per finished sequence. The concatenated `Token`
//!   stream is bit-identical to the final [`Response::tokens`].
//! * [`SubmitError`] — typed rejection reasons surfaced by
//!   `Server::submit` instead of panics or silent drops.

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Monotonic request identifier.
pub type RequestId = u64;

/// Sampling configuration (greedy when `temperature == 0`).
#[derive(Clone, Copy, Debug)]
pub struct SamplingParams {
    pub temperature: f32,
    pub max_new_tokens: usize,
    /// Stop at this token id (usually EOS).
    pub stop_token: Option<u32>,
    pub seed: u64,
    /// Parallel samples per request (`--n`). The engine prefills the
    /// prompt **once**, then forks the KV cache `n - 1` times
    /// (copy-on-write page sharing), so `n` completions cost one
    /// prompt pass plus `n` decode streams. Each fork samples with
    /// [`SamplingParams::for_sample`]'s derived seed; `n = 1` (the
    /// default) is the exact legacy path.
    pub n: usize,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 0.0,
            max_new_tokens: 32,
            stop_token: Some(crate::data::tokenizer::EOS),
            seed: 0,
            n: 1,
        }
    }
}

impl SamplingParams {
    /// Builder root: greedy decoding with a token budget (stop token
    /// and everything else from [`Default`]). Chain `with_*` calls to
    /// refine — the type is `Copy`, so the builder is non-consuming in
    /// practice: `SamplingParams::greedy(8).with_n(3)`.
    pub fn greedy(max_new_tokens: usize) -> SamplingParams {
        SamplingParams {
            max_new_tokens,
            ..Default::default()
        }
    }

    /// Seeded stochastic sampling (softmax at `temperature`).
    pub fn with_temperature(mut self, temperature: f32, seed: u64) -> SamplingParams {
        self.temperature = temperature;
        self.seed = seed;
        self
    }

    /// Parallel samples per request (prefill once, fork `n` streams).
    pub fn with_n(mut self, n: usize) -> SamplingParams {
        self.n = n;
        self
    }

    /// Override the stop token (`None` ⇒ run to the budget).
    pub fn with_stop(mut self, stop_token: Option<u32>) -> SamplingParams {
        self.stop_token = stop_token;
        self
    }

    /// Reject parameter combinations the engine cannot serve. Run at
    /// submit time so bad requests bounce with a typed [`SubmitError`]
    /// instead of debug-asserting or looping inside a worker thread.
    pub fn validate(&self) -> Result<(), SubmitError> {
        if self.n == 0 {
            return Err(SubmitError::ZeroSamples);
        }
        if self.max_new_tokens == 0 {
            return Err(SubmitError::ZeroBudget);
        }
        if self.temperature.is_nan() || self.temperature < 0.0 {
            return Err(SubmitError::InvalidTemperature(self.temperature));
        }
        Ok(())
    }

    /// Parameters for fork `k` of an `n > 1` request: same budget and
    /// temperature, seed decorrelated per sample (k = 0 keeps the base
    /// seed, so single-sample behaviour is unchanged), `n` forced back
    /// to 1 so a resumed fork never fans out again.
    pub fn for_sample(&self, k: usize) -> SamplingParams {
        SamplingParams {
            seed: self.seed ^ (k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            n: 1,
            ..*self
        }
    }
}

/// Why a submission was refused (see `Server::submit` /
/// `ServeEngine::try_submit`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SubmitError {
    /// `params.n == 0` — no samples requested.
    ZeroSamples,
    /// `params.max_new_tokens == 0` — nothing to decode.
    ZeroBudget,
    /// Negative or NaN temperature.
    InvalidTemperature(f32),
    /// The routed replica's intake is at `--intake-limit` (and, for
    /// sessionless requests, so is every other replica's).
    QueueFull { replica: usize },
    /// The worker threads have exited; previously this case silently
    /// dropped the request while returning a live-looking id.
    ServerStopped,
    /// The session-pinned replica is being respawned by the supervisor.
    /// Unlike [`SubmitError::ServerStopped`], the rest of the server is
    /// healthy — sessionless requests spill to another replica instead
    /// of seeing this; pinned callers should back off and retry.
    ReplicaRestarting { replica: usize },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::ZeroSamples => write!(f, "n must be >= 1"),
            SubmitError::ZeroBudget => write!(f, "max_new_tokens must be >= 1"),
            SubmitError::InvalidTemperature(t) => {
                write!(f, "temperature must be finite and >= 0 (got {t})")
            }
            SubmitError::QueueFull { replica } => {
                write!(f, "intake queue full (replica {replica})")
            }
            SubmitError::ServerStopped => write!(f, "server stopped"),
            SubmitError::ReplicaRestarting { replica } => {
                write!(f, "replica {replica} is restarting")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// Where a request currently is in its lifecycle, as observed through
/// [`RequestHandle::try_status`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestStatus {
    /// Accepted but not yet admitted into a running batch.
    Queued,
    /// At least one of its sequences is (or has been) in the batch.
    Running,
    /// Every sequence has retired; all its events have been emitted.
    Finished,
}

const PHASE_QUEUED: u8 = 0;
const PHASE_RUNNING: u8 = 1;
const PHASE_FINISHED: u8 = 2;

/// Shared control block between a [`RequestHandle`] and the engine.
///
/// All flags are advisory and `Relaxed`: the engine reads them at step
/// boundaries, so a cancel takes effect within one step — there is no
/// ordering-sensitive data guarded by these atomics (request transfer
/// itself happens-before via the intake channel).
#[derive(Debug, Default)]
pub struct RequestCtl {
    cancelled: AtomicBool,
    phase: AtomicU8,
}

impl RequestCtl {
    /// Ask the engine to retire this request at the next step boundary.
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Relaxed)
    }

    pub(crate) fn mark_running(&self) {
        self.phase.store(PHASE_RUNNING, Ordering::Relaxed);
    }

    pub(crate) fn mark_finished(&self) {
        self.phase.store(PHASE_FINISHED, Ordering::Relaxed);
    }

    pub fn status(&self) -> RequestStatus {
        match self.phase.load(Ordering::Relaxed) {
            PHASE_RUNNING => RequestStatus::Running,
            PHASE_FINISHED => RequestStatus::Finished,
            _ => RequestStatus::Queued,
        }
    }
}

/// Caller-side handle for an accepted request: identity, cancellation,
/// and non-blocking status. Clonable and sendable; does not keep the
/// server alive.
#[derive(Clone, Debug)]
pub struct RequestHandle {
    id: RequestId,
    replica: usize,
    ctl: Arc<RequestCtl>,
}

impl RequestHandle {
    pub fn new(id: RequestId, replica: usize, ctl: Arc<RequestCtl>) -> RequestHandle {
        RequestHandle { id, replica, ctl }
    }

    pub fn id(&self) -> RequestId {
        self.id
    }

    /// Which replica the request was admitted to.
    pub fn replica(&self) -> usize {
        self.replica
    }

    /// Retire the request at the engine's next step boundary with
    /// [`FinishReason::Cancelled`], releasing its KV pages eagerly.
    /// Tokens already generated are kept in the final [`Response`].
    pub fn cancel(&self) {
        self.ctl.cancel();
    }

    /// Non-blocking lifecycle probe.
    pub fn try_status(&self) -> RequestStatus {
        self.ctl.status()
    }
}

/// An inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<u32>,
    pub params: SamplingParams,
    /// Session key for router affinity (0 = none).
    pub session: u64,
    /// Which parallel sample this sequence produces (0 for the primary
    /// and for ordinary `n = 1` requests; forks get 1..n).
    pub sample: usize,
    pub submitted_at: Instant,
    /// Retire with [`FinishReason::DeadlineExceeded`] once this much
    /// wall-clock time has elapsed since `submitted_at` (checked at
    /// step boundaries; `None` = unbounded).
    pub deadline: Option<Duration>,
    /// Control block shared with every [`RequestHandle`] clone and —
    /// via `Request::clone` — with every fork and preemption resume of
    /// this request, so one cancel reaches all of its sequences.
    pub ctl: Arc<RequestCtl>,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<u32>, params: SamplingParams) -> Request {
        Request {
            id,
            prompt,
            params,
            session: 0,
            sample: 0,
            submitted_at: Instant::now(),
            deadline: None,
            ctl: Arc::new(RequestCtl::default()),
        }
    }

    /// Builder-style deadline attachment.
    pub fn with_deadline(mut self, deadline: Duration) -> Request {
        self.deadline = Some(deadline);
        self
    }

    /// A handle for direct-engine callers (the server builds its own).
    pub fn handle(&self, replica: usize) -> RequestHandle {
        RequestHandle::new(self.id, replica, self.ctl.clone())
    }

    /// True once the deadline has lapsed at `now`.
    pub fn expired_at(&self, now: Instant) -> bool {
        match self.deadline {
            Some(d) => now.saturating_duration_since(self.submitted_at) >= d,
            None => false,
        }
    }
}

/// Why a sequence finished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    Stop,
    Length,
    /// Prompt longer than the model context.
    PromptTooLong,
    /// The sequence's KV cache ran out of positions mid-flight (a
    /// planner/capacity disagreement) — the request is truncated to
    /// what was generated instead of panicking the replica. Since the
    /// paged allocator, *page-pool* exhaustion no longer lands here:
    /// the engine preempts (release pages, re-enqueue, recompute) and
    /// the request still completes; this reason survives only for the
    /// unsatisfiable case where a lone request cannot fit even with
    /// every other sequence evicted.
    CacheOverflow,
    /// Retired by [`RequestHandle::cancel`]; tokens generated so far
    /// are kept, KV pages are released eagerly.
    Cancelled,
    /// Retired because [`Request::deadline`] lapsed; tokens generated
    /// so far are kept, KV pages are released eagerly.
    DeadlineExceeded,
    /// The replica serving this request died and the retry budget
    /// ([`RetryPolicy`](crate::coordinator::RetryPolicy)) was exhausted
    /// — or the request was pinned to a session whose replica could not
    /// be restarted. The synthetic terminal [`Response`] carries no
    /// tokens.
    ReplicaLost,
}

/// Completed request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    /// Which parallel sample this is (see [`Request::sample`]); an
    /// `n`-sample request yields `n` responses sharing its `id`.
    pub sample: usize,
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    /// Time from submit to first generated token.
    pub ttft: Duration,
    /// Time from submit to completion.
    pub total: Duration,
    pub prompt_len: usize,
}

/// One event on the serve wire. Per sequence (`(id, sample)` pair) the
/// stream is `Token* Done`, and the `token` fields concatenated in
/// `index` order are exactly the final [`Response::tokens`] — the
/// repo's bit-parity discipline extended to the wire:
///
/// * a matched stop token is never emitted as a `Token` (retirement
///   pops it from `Response::tokens` too);
/// * preemption never rolls back `generated` (victims are chosen
///   *before* sampling), so a resumed sequence never re-emits;
/// * cancel/deadline retirement keeps all generated tokens;
/// * an accepted speculative-draft burst emits one `Token` per
///   committed token with contiguous `index`es — a step may advance a
///   sequence by up to `1 + k` events, but the stream contents are
///   identical to plain decode (rejected drafts emit nothing).
#[derive(Clone, Debug)]
pub enum ServerEvent {
    /// One decoded token, emitted the step it was sampled.
    Token {
        id: RequestId,
        /// Parallel-sample tag (see [`Request::sample`]).
        sample: usize,
        token: u32,
        /// Position in the sequence's output, from 0, contiguous.
        index: usize,
    },
    /// Terminal event for one sequence.
    Done(Response),
    /// A replica's engine loop died (panic, injected fault, or
    /// checkpoint-load failure during restart). Emitted once per death
    /// by the supervision layer *after* every event the replica
    /// produced before dying (the mpsc channel preserves per-sender
    /// order), so a consumer that sees `ReplicaDown` has already seen
    /// everything the victim completed. In-flight requests are requeued
    /// to healthy replicas by the supervisor; this event is
    /// informational.
    ReplicaDown { replica: usize, cause: String },
}

/// Lifecycle of an admitted sequence inside the engine.
///
/// The prefill phase covers `prefill_len` tokens: normally the prompt;
/// for a sequence resumed after preemption ([`SequenceState::resume`])
/// it is prompt **plus** the tokens already generated before eviction,
/// which are recomputed through [`SequenceState::prefill_token`] —
/// greedy/seeded sampling then replays the remaining tokens exactly
/// (the per-step RNG is keyed by `generated.len()`, which resumes at
/// its pre-preemption value).
#[derive(Debug)]
pub struct SequenceState {
    pub request: Request,
    pub cache: crate::model::KvCache,
    /// Prefill tokens consumed so far (`< prefill_len` ⇒ prefilling).
    pub prefill_cursor: usize,
    /// Tokens the prefill phase must cover (see type docs).
    pub prefill_len: usize,
    pub generated: Vec<u32>,
    /// Logits from the last step (None until the prompt is consumed).
    pub pending_logits: Option<Vec<f32>>,
    pub first_token_at: Option<Instant>,
    /// Set when the sequence's cache filled before its prompt was
    /// consumed — retired with [`FinishReason::CacheOverflow`].
    pub overflowed: bool,
    /// Set by the engine when this sequence is chosen as a preemption
    /// victim: its pages are released at the end of the step and the
    /// request re-enqueues for recompute.
    pub preempted: bool,
    /// Draft tokens riding this step's fused pass as extra verify rows
    /// (speculative decoding; see `coordinator::speculator`). Strictly
    /// step-transient: set in the engine's phase 1 only after KV
    /// reservation for every draft row succeeded, consumed and cleared
    /// by the phase-3 verify — empty at every step boundary, so
    /// preemption, cancellation, and resume never see a draft.
    /// `generated` holds committed tokens only.
    pub spec_drafts: Vec<u32>,
}

impl SequenceState {
    pub fn new(request: Request, cache: crate::model::KvCache) -> SequenceState {
        let prefill_len = request.prompt.len();
        SequenceState {
            request,
            cache,
            prefill_cursor: 0,
            prefill_len,
            generated: Vec::new(),
            pending_logits: None,
            first_token_at: None,
            overflowed: false,
            preempted: false,
            spec_drafts: Vec::new(),
        }
    }

    /// Re-admit a preempted sequence: everything generated before
    /// eviction joins the prefill phase (prompt + generated recompute
    /// into the fresh cache; the prefix tree usually still holds the
    /// prompt's pages, so most of it is adopted rather than recomputed)
    /// and decoding continues from where it stopped.
    pub fn resume(
        request: Request,
        generated: Vec<u32>,
        cache: crate::model::KvCache,
        first_token_at: Option<Instant>,
    ) -> SequenceState {
        let prefill_len = request.prompt.len() + generated.len();
        SequenceState {
            request,
            cache,
            prefill_cursor: 0,
            prefill_len,
            generated,
            pending_logits: None,
            first_token_at,
            overflowed: false,
            preempted: false,
            spec_drafts: Vec::new(),
        }
    }

    pub fn in_prefill(&self) -> bool {
        self.prefill_cursor < self.prefill_len
    }

    pub fn remaining_prompt(&self) -> usize {
        self.prefill_len - self.prefill_cursor
    }

    /// The `i`-th prefill token: the prompt, then (resumed sequences
    /// only) the previously generated tokens being recomputed.
    pub fn prefill_token(&self, i: usize) -> u32 {
        debug_assert!(i < self.prefill_len);
        if i < self.request.prompt.len() {
            self.request.prompt[i]
        } else {
            self.generated[i - self.request.prompt.len()]
        }
    }

    pub fn budget_left(&self) -> usize {
        self.request
            .params
            .max_new_tokens
            .saturating_sub(self.generated.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::KvCache;

    #[test]
    fn lifecycle_flags() {
        let req = Request::new(1, vec![1, 2, 3], SamplingParams::default());
        let mut s = SequenceState::new(req, KvCache::new(1, 1, 4, 16));
        assert!(s.in_prefill());
        assert_eq!(s.remaining_prompt(), 3);
        s.prefill_cursor = 3;
        assert!(!s.in_prefill());
        assert_eq!(s.budget_left(), 32);
        s.generated = vec![9; 30];
        assert_eq!(s.budget_left(), 2);
    }

    #[test]
    fn resume_recomputes_prompt_plus_generated() {
        let req = Request::new(1, vec![1, 2, 3], SamplingParams::default());
        let s = SequenceState::resume(req, vec![7, 8], KvCache::new(1, 1, 4, 16), None);
        assert!(s.in_prefill());
        assert_eq!(s.remaining_prompt(), 5, "prompt + prior generation");
        let replay: Vec<u32> = (0..5).map(|i| s.prefill_token(i)).collect();
        assert_eq!(replay, vec![1, 2, 3, 7, 8]);
        // decode budget picks up where it left off
        assert_eq!(s.budget_left(), 30);
    }

    #[test]
    fn default_sampling_greedy() {
        let p = SamplingParams::default();
        assert_eq!(p.temperature, 0.0);
        assert!(p.stop_token.is_some());
    }

    #[test]
    fn builder_chains_from_greedy() {
        let p = SamplingParams::greedy(8).with_temperature(0.7, 42).with_n(3);
        assert_eq!(p.max_new_tokens, 8);
        assert_eq!(p.temperature, 0.7);
        assert_eq!(p.seed, 42);
        assert_eq!(p.n, 3);
        assert!(p.stop_token.is_some(), "greedy keeps the default stop");
        assert_eq!(p.with_stop(None).stop_token, None);
    }

    #[test]
    fn validate_rejects_bad_params() {
        assert!(SamplingParams::greedy(8).validate().is_ok());
        assert_eq!(
            SamplingParams::greedy(8).with_n(0).validate(),
            Err(SubmitError::ZeroSamples)
        );
        assert_eq!(
            SamplingParams::greedy(0).validate(),
            Err(SubmitError::ZeroBudget)
        );
        assert!(matches!(
            SamplingParams::greedy(8)
                .with_temperature(-1.0, 0)
                .validate(),
            Err(SubmitError::InvalidTemperature(_))
        ));
        assert!(matches!(
            SamplingParams::greedy(8)
                .with_temperature(f32::NAN, 0)
                .validate(),
            Err(SubmitError::InvalidTemperature(_))
        ));
    }

    #[test]
    fn ctl_cancel_and_status() {
        let req = Request::new(7, vec![1], SamplingParams::default());
        let h = req.handle(0);
        assert_eq!(h.id(), 7);
        assert_eq!(h.try_status(), RequestStatus::Queued);
        assert!(!req.ctl.is_cancelled());
        h.cancel();
        assert!(req.ctl.is_cancelled());
        req.ctl.mark_running();
        assert_eq!(h.try_status(), RequestStatus::Running);
        req.ctl.mark_finished();
        assert_eq!(h.try_status(), RequestStatus::Finished);
        // clones (forks, resumes) share the same control block
        let fork = req.clone();
        assert!(fork.ctl.is_cancelled());
    }

    #[test]
    fn deadline_expiry() {
        let req = Request::new(1, vec![1], SamplingParams::default());
        let now = Instant::now();
        assert!(!req.expired_at(now), "no deadline never expires");
        let req = req.with_deadline(Duration::ZERO);
        assert!(req.expired_at(now));
        let req = Request::new(2, vec![1], SamplingParams::default())
            .with_deadline(Duration::from_secs(3600));
        assert!(!req.expired_at(Instant::now()));
    }
}

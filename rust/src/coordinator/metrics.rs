//! Serving metrics: counters + latency reservoirs, rendered for the
//! `ptqtp serve --report` output and the Table 5/6-style benches.

use super::request::Response;
use std::time::Duration;

/// Engine-level metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    /// Prompt tokens satisfied by prefix-cache page adoption instead of
    /// prefill compute.
    pub adopted_tokens: u64,
    /// Prefix-cache admissions that adopted ≥ 1 page / total lookups.
    pub prefix_hits: u64,
    pub prefix_lookups: u64,
    /// Prefix-tree pages evicted under page-pool pressure.
    pub prefix_evicted_pages: u64,
    /// Sequences evicted for recompute under page exhaustion.
    pub preemptions: u64,
    /// Copy-on-write page copies (forks writing into shared pages).
    pub cow_pages: u64,
    /// Page-pool gauges, refreshed by the engine each step.
    pub pages_in_use: usize,
    pub pages_free: usize,
    pub pages_peak: usize,
    pub page_budget: usize,
    /// Completed responses retained for percentile queries (bounded).
    pub finished: Vec<Response>,
    ttft_samples: Vec<Duration>,
    total_samples: Vec<Duration>,
}

const RESERVOIR: usize = 4096;

impl Metrics {
    pub fn record_response(&mut self, r: &Response) {
        self.completed += 1;
        if self.ttft_samples.len() < RESERVOIR {
            self.ttft_samples.push(r.ttft);
            self.total_samples.push(r.total);
        }
        if self.finished.len() < RESERVOIR {
            self.finished.push(r.clone());
        }
    }

    pub fn ttft_percentile(&self, p: f64) -> Option<Duration> {
        percentile(&self.ttft_samples, p)
    }

    pub fn total_percentile(&self, p: f64) -> Option<Duration> {
        percentile(&self.total_samples, p)
    }

    /// Tokens/second over a wall-clock window.
    pub fn throughput(&self, wall: Duration) -> f64 {
        self.decode_tokens as f64 / wall.as_secs_f64().max(1e-9)
    }

    /// Prefix-cache hit rate over admissions (0 when the cache is off
    /// or nothing was admitted).
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.prefix_lookups as f64
        }
    }

    pub fn render(&self, wall: Duration) -> String {
        format!(
            "requests: {} submitted, {} completed, {} rejected\n\
             tokens:   {} prefill, {} decode ({:.1} tok/s decode)\n\
             paged-kv: {}/{} pages in use (peak {}, {} free), {} adopted tokens, \
             prefix hit rate {:.0}%, {} tree evictions, {} cow copies, preemptions: {}\n\
             ttft:     p50 {:?}  p95 {:?}\n\
             e2e:      p50 {:?}  p95 {:?}",
            self.submitted,
            self.completed,
            self.rejected,
            self.prefill_tokens,
            self.decode_tokens,
            self.throughput(wall),
            self.pages_in_use,
            self.page_budget,
            self.pages_peak,
            self.pages_free,
            self.adopted_tokens,
            self.prefix_hit_rate() * 100.0,
            self.prefix_evicted_pages,
            self.cow_pages,
            self.preemptions,
            self.ttft_percentile(0.50).unwrap_or_default(),
            self.ttft_percentile(0.95).unwrap_or_default(),
            self.total_percentile(0.50).unwrap_or_default(),
            self.total_percentile(0.95).unwrap_or_default(),
        )
    }
}

fn percentile(samples: &[Duration], p: f64) -> Option<Duration> {
    if samples.is_empty() {
        return None;
    }
    let mut v: Vec<Duration> = samples.to_vec();
    v.sort_unstable();
    let idx = ((v.len() as f64 - 1.0) * p).round() as usize;
    Some(v[idx])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::FinishReason;

    fn resp(ms: u64) -> Response {
        Response {
            id: 0,
            sample: 0,
            tokens: vec![1],
            finish: FinishReason::Length,
            ttft: Duration::from_millis(ms),
            total: Duration::from_millis(ms * 2),
            prompt_len: 1,
        }
    }

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::default();
        for ms in [10u64, 20, 30, 40, 100] {
            m.record_response(&resp(ms));
        }
        let p50 = m.ttft_percentile(0.5).unwrap();
        let p95 = m.ttft_percentile(0.95).unwrap();
        assert!(p50 <= p95);
        assert_eq!(m.completed, 5);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::default();
        assert!(m.ttft_percentile(0.5).is_none());
        assert_eq!(m.throughput(Duration::from_secs(1)), 0.0);
        let s = m.render(Duration::from_secs(1));
        assert!(s.contains("0 submitted"));
        assert!(s.contains("preemptions: 0"));
    }

    #[test]
    fn paged_counters_render() {
        let mut m = Metrics::default();
        m.prefix_lookups = 4;
        m.prefix_hits = 3;
        m.adopted_tokens = 192;
        m.preemptions = 2;
        m.pages_in_use = 5;
        m.page_budget = 8;
        assert!((m.prefix_hit_rate() - 0.75).abs() < 1e-12);
        let s = m.render(Duration::from_secs(1));
        assert!(s.contains("5/8 pages in use"));
        assert!(s.contains("192 adopted tokens"));
        assert!(s.contains("prefix hit rate 75%"));
        assert!(s.contains("preemptions: 2"));
    }

    #[test]
    fn throughput_math() {
        let mut m = Metrics::default();
        m.decode_tokens = 100;
        assert!((m.throughput(Duration::from_secs(2)) - 50.0).abs() < 1e-9);
    }
}
